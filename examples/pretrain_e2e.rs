//! End-to-end pre-training driver (the repo's headline validation run).
//!
//! Proves every layer composes on a real workload: one `Session` generates
//! the five synthetic multi-fidelity datasets, pre-trains the two-level-MTL
//! GFM with **multi-task parallelism x DDP** (5 head sub-groups x M
//! replicas; the EGNN executes on the native pure-rust backend by default,
//! or the L1-Pallas/L2-jax AOT model under PJRT), logs the loss curve per
//! epoch, then scores the cross-dataset MAE matrix and the communication
//! traffic against MTL-base — the Section 5.1 convergence story end to
//! end. Results are recorded in EXPERIMENTS.md.
//!
//! The run writes CRC-guarded checkpoints every epoch; afterwards it
//! simulates an interruption by resuming from the mid-run checkpoint and
//! verifies the resumed tail reproduces the original trajectory
//! bit-for-bit (the fault-tolerance story the exascale runs depend on).
//! It finishes by asserting the train loss actually decreased — a default
//! build on a clean machine (native backend, zero artifacts) completes the
//! whole story.
//!
//! Run: cargo run --release --example pretrain_e2e -- \
//!          [--per-dataset 240] [--epochs 8] [--replicas 1] [--out DIR]
//!          [--backend auto|native|pjrt]

use std::sync::Arc;

use hydra_mtp::config::{RunConfig, TrainMode};
use hydra_mtp::runtime::{BackendKind, Engine};
use hydra_mtp::session::Session;
use hydra_mtp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    args.ensure_known(
        "pretrain_e2e",
        &["per-dataset", "max-atoms", "epochs", "patience", "lr", "replicas", "out", "backend"],
    )?;
    let mut cfg = RunConfig::default();
    cfg.mode = TrainMode::MtlPar;
    cfg.data.per_dataset = args.usize("per-dataset", 240);
    cfg.data.max_atoms = args.usize("max-atoms", 16);
    cfg.train.epochs = args.usize("epochs", 8);
    cfg.train.patience = args.usize("patience", 4);
    cfg.train.lr = args.f64("lr", 1e-3);
    cfg.parallel.replicas = args.usize("replicas", 1);
    cfg.backend = BackendKind::parse(&args.str("backend", "auto"))?;
    let out_dir = args.str("out", "e2e_results");
    std::fs::create_dir_all(&out_dir)?;
    let ckpt_dir = format!("{out_dir}/checkpoints");
    cfg.checkpoint.dir = Some(ckpt_dir.clone());

    println!("== hydra-mtp end-to-end pre-training ==");
    println!(
        "5 datasets x {} structures, {} max epochs, mesh 5 x {}",
        cfg.data.per_dataset, cfg.train.epochs, cfg.parallel.replicas
    );

    let engine = Arc::new(Engine::load_with(&cfg.artifacts_dir, cfg.backend)?);
    println!("backend: {} ({})", engine.backend_name(), engine.platform());
    let mut session = Session::builder()
        .config(cfg.clone())
        .engine(Arc::clone(&engine))
        .build()?;
    let dims = engine.manifest.config.arch_dims();
    println!(
        "model: P_s={} P_h={} ({} params/rank under MTP vs {} under DDP)",
        dims.shared_params(),
        dims.head_params(),
        dims.shared_params() + dims.head_params(),
        dims.total_params(5),
    );

    let t0 = std::time::Instant::now();
    session.generate_data();
    let n_train: usize =
        session.data().unwrap().train.values().map(|v| v.len()).sum();
    println!("generated {n_train} training structures in {:?}\n", t0.elapsed());

    // --- the run ---
    let t1 = std::time::Instant::now();
    let outcome = session.train()?;
    let wall = t1.elapsed();

    println!("loss curve (rank-0 head):");
    for e in &outcome.log.epochs {
        println!("  {}", e.summary());
    }
    println!(
        "\npre-training wall clock: {wall:?} ({} epochs, {} executions)",
        outcome.log.epochs.len(),
        engine.executions()
    );
    println!(
        "gradient traffic per rank: global {:.2} Mf32, head-group {:.2} Mf32",
        outcome.comm_elems.0 as f64 / 1e6,
        outcome.comm_elems.1 as f64 / 1e6
    );

    // --- cross-dataset evaluation ---
    println!("\ncross-dataset test MAE of the pre-trained GFM:");
    let scores = session.evaluate(&outcome.model)?;
    for (d, (mae_e, mae_f)) in &scores {
        println!("  {:<14} energy {mae_e:>8.4}   forces {mae_f:>8.4}", d.name());
    }

    // --- contrast with MTL-base traffic (same budget, 1 epoch) ---
    let mut base_cfg = cfg.clone();
    base_cfg.mode = TrainMode::MtlBase;
    base_cfg.train.epochs = 1;
    // Never into the MTL-par run's checkpoint directory: a foreign-mode
    // epoch_0001.ckpt would both pollute it and break the resume demo below.
    base_cfg.checkpoint.dir = None;
    let base = Session::builder()
        .config(base_cfg)
        .engine(Arc::clone(&engine))
        .build()?
        .train_on(session.data().unwrap())?;
    let par_steps: usize = outcome.log.epochs.iter().map(|e| e.steps).sum();
    let base_steps: usize = base.log.epochs.iter().map(|e| e.steps).sum();
    println!(
        "\ncommunication per step: MTL-par global {:.0} f32 vs MTL-base global {:.0} f32 \
         ({}x reduction, paper Section 4.3)",
        outcome.comm_elems.0 as f64 / par_steps.max(1) as f64,
        base.comm_elems.0 as f64 / base_steps.max(1) as f64,
        ((base.comm_elems.0 as f64 / base_steps.max(1) as f64)
            / (outcome.comm_elems.0 as f64 / par_steps.max(1) as f64))
            .round()
    );

    // --- interrupt-and-resume: restart from the mid-run checkpoint and
    // verify the resumed tail lands on the exact same trajectory ---
    let epochs_run = outcome.log.epochs.len();
    let k = epochs_run / 2;
    if k >= 1 {
        println!(
            "\nsimulating a mid-run kill: resuming from {ckpt_dir}/epoch_{k:04}.ckpt \
             and replaying epochs {k}..{epochs_run}"
        );
        let mut resume_cfg = cfg.clone();
        resume_cfg.checkpoint.dir = None; // don't overwrite the originals
        let mut resumed_session = Session::builder()
            .config(resume_cfg)
            .engine(Arc::clone(&engine))
            .build()?;
        let resumed = resumed_session
            .resume(format!("{ckpt_dir}/epoch_{k:04}.ckpt"))?;
        // Bit-pattern comparison: a NaN val_loss (empty val shard) is
        // "equal" across runs too, where `==` would report a false diverge.
        let mut identical = resumed.log.epochs.len() == epochs_run;
        for (a, b) in resumed.log.epochs.iter().zip(&outcome.log.epochs) {
            identical &= a.train_loss.to_bits() == b.train_loss.to_bits()
                && a.val_loss.to_bits() == b.val_loss.to_bits()
                && a.steps == b.steps;
        }
        if identical {
            println!("resume parity OK: all {epochs_run} epochs bit-identical");
        } else {
            anyhow::bail!("resumed run diverged from the uninterrupted trajectory");
        }
    }

    // --- convergence: the headline validation criterion (needs at least
    // two epochs to compare; a --epochs 1 run has nothing to assert) ---
    if outcome.log.epochs.len() > 1 {
        let first = outcome.log.epochs[0].train_loss;
        let final_loss = outcome.log.epochs.last().unwrap().train_loss;
        anyhow::ensure!(
            final_loss < first,
            "pre-training must reduce the train loss: {first} -> {final_loss}"
        );
        println!("\ntrain loss decreased {first:.4} -> {final_loss:.4} over the run");
    }

    // --- persist artifacts of the run ---
    let curve_path = format!("{out_dir}/loss_curve.csv");
    std::fs::write(&curve_path, outcome.log.to_csv())?;
    let scores_csv: String = std::iter::once("dataset,mae_e,mae_f\n".to_string())
        .chain(
            scores
                .iter()
                .map(|(d, (e, f))| format!("{},{e:.6},{f:.6}\n", d.name())),
        )
        .collect();
    std::fs::write(format!("{out_dir}/test_mae.csv"), scores_csv)?;
    println!("\nwrote {curve_path} and {out_dir}/test_mae.csv");
    Ok(())
}
