//! Quickstart: the smallest end-to-end use of the public API.
//!
//! One `Session` owns the whole lifecycle: load + compile the AOT artifacts,
//! generate a small multi-source dataset for every registered task, train a
//! two-level MTL model with multi-task parallelism, score it per dataset,
//! and serve predictions through the `Predictor`.
//!
//! Run: `make artifacts && cargo run --release --features pjrt --example quickstart`

use std::sync::Arc;

use hydra_mtp::runtime::Engine;
use hydra_mtp::{Session, TrainMode};

fn main() -> anyhow::Result<()> {
    // Graceful skip ONLY when the AOT artifacts are unavailable (a checkout
    // without `make artifacts`, or a build without PJRT); any other error
    // below propagates as a real failure.
    let engine = match Engine::load("artifacts") {
        Ok(e) => Arc::new(e),
        Err(e) => {
            eprintln!("skipping quickstart: artifacts unavailable ({e:#})");
            return Ok(());
        }
    };
    let mut session = Session::builder()
        .engine(engine)
        .mode(TrainMode::MtlPar)
        .per_dataset(96)
        .max_atoms(12)
        .epochs(3)
        .build()?;
    println!("PJRT platform: {}", session.engine().platform());

    // Train (data is generated lazily from the task registry).
    let outcome = session.train()?;
    println!("\ntraining log ({}):", outcome.model.name);
    for e in &outcome.log.epochs {
        println!("  {}", e.summary());
    }

    // Score the pre-trained GFM on every task's held-out test split.
    println!("\nper-dataset test MAE (energy / forces):");
    for (d, (mae_e, mae_f)) in session.evaluate(&outcome.model)? {
        println!("  {:<14} {mae_e:>8.4}  /  {mae_f:>8.4}", d.name());
    }

    // Predict on fresh structures — each routed through the right head.
    let samples = session.test_samples(2)?;
    let mut predictor = session.predictor(&outcome.model);
    println!("\npredicted vs labeled energy-per-atom:");
    for (p, s) in predictor.predict(&samples)?.iter().zip(&samples) {
        println!(
            "  {:<14} ({:>2} atoms): {:>8.4} vs {:>8.4}",
            p.dataset.name(),
            s.natoms(),
            p.energy_per_atom,
            s.energy_per_atom()
        );
    }
    Ok(())
}
