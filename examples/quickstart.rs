//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Loads the AOT artifacts, generates a small multi-source dataset, trains
//! a two-level MTL model with multi-task parallelism for a few epochs, and
//! predicts energies/forces for fresh structures.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use std::sync::Arc;

use hydra_mtp::config::{RunConfig, TrainMode};
use hydra_mtp::coordinator::{evaluate_model, DataBundle, Trainer};
use hydra_mtp::data::batch::BatchBuilder;
use hydra_mtp::data::structures::ALL_DATASETS;
use hydra_mtp::runtime::Engine;

fn main() -> anyhow::Result<()> {
    // 1. Load + compile the AOT artifacts (python never runs again).
    let engine = Arc::new(Engine::load("artifacts")?);
    println!("PJRT platform: {}", engine.platform());

    // 2. Synthetic multi-source, multi-fidelity data (5 datasets).
    let mut cfg = RunConfig::default();
    cfg.mode = TrainMode::MtlPar;
    cfg.data.per_dataset = 96;
    cfg.data.max_atoms = 12;
    cfg.train.epochs = 3;
    let data = DataBundle::generate(&cfg.data, &ALL_DATASETS);

    // 3. Train with multi-task parallelism: 5 head sub-groups x 1 replica.
    let outcome = Trainer::new(Arc::clone(&engine), cfg.clone()).train(&data)?;
    println!("\ntraining log ({}):", outcome.model.name);
    for e in &outcome.log.epochs {
        println!("  {}", e.summary());
    }

    // 4. Score the pre-trained GFM on every dataset's held-out test split.
    println!("\nper-dataset test MAE (energy / forces):");
    for (d, (mae_e, mae_f)) in evaluate_model(&engine, &outcome.model, &data.test)? {
        println!("  {:<14} {mae_e:>8.4}  /  {mae_f:>8.4}", d.name());
    }

    // 5. Predict on fresh structures through the right branch.
    let d = ALL_DATASETS[0];
    let samples: Vec<_> = data.test[&d].iter().take(4).cloned().collect();
    let batch = BatchBuilder::build_all(
        engine.manifest.config.batch_dims(),
        engine.manifest.config.cutoff,
        &samples,
    )
    .remove(0);
    let full = outcome.model.full_params(&engine, d);
    let (energy, _forces) = engine.forward(&full, &batch)?;
    println!("\npredicted vs labeled energy-per-atom ({}):", d.name());
    for (g, s) in samples.iter().enumerate() {
        println!(
            "  structure {g} ({} atoms): {:>8.4} vs {:>8.4}",
            s.natoms(),
            energy.as_f32()[g],
            s.energy_per_atom()
        );
    }
    Ok(())
}
