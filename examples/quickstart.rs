//! Quickstart: the smallest end-to-end use of the public API.
//!
//! One `Session` owns the whole lifecycle: pick an execution backend
//! (native by default — no artifacts, no PJRT, runs anywhere), generate a
//! small multi-source dataset for every registered task, train a two-level
//! MTL model with multi-task parallelism, score it per dataset, and serve
//! predictions through the `Predictor`.
//!
//! Run: `cargo run --release --example quickstart`
//! (optionally `make artifacts` + `--features pjrt` for the accelerated
//! PJRT backend — the code is identical).

use hydra_mtp::{Session, TrainMode};

fn main() -> anyhow::Result<()> {
    let mut session = Session::builder()
        .artifacts("artifacts") // used only if the pjrt backend resolves
        .mode(TrainMode::MtlPar)
        .per_dataset(96)
        .max_atoms(12)
        .epochs(3)
        .build()?;
    println!(
        "backend: {} ({})",
        session.engine().backend_name(),
        session.engine().platform()
    );

    // Train (data is generated lazily from the task registry).
    let outcome = session.train()?;
    println!("\ntraining log ({}):", outcome.model.name);
    for e in &outcome.log.epochs {
        println!("  {}", e.summary());
    }

    // Score the pre-trained GFM on every task's held-out test split.
    println!("\nper-dataset test MAE (energy / forces):");
    for (d, (mae_e, mae_f)) in session.evaluate(&outcome.model)? {
        println!("  {:<14} {mae_e:>8.4}  /  {mae_f:>8.4}", d.name());
    }

    // Predict on fresh structures — each routed through the right head.
    let samples = session.test_samples(2)?;
    let mut predictor = session.predictor(&outcome.model);
    println!("\npredicted vs labeled energy-per-atom:");
    for (p, s) in predictor.predict(&samples)?.iter().zip(&samples) {
        println!(
            "  {:<14} ({:>2} atoms): {:>8.4} vs {:>8.4}",
            p.dataset.name(),
            s.natoms(),
            p.energy_per_atom,
            s.energy_per_atom()
        );
    }
    Ok(())
}
