//! Inspect the multi-source, multi-fidelity data substrate.
//!
//! Quantifies exactly the inconsistency the paper's MTL approach absorbs:
//! the same physical structure relabeled under each dataset's fidelity
//! transform gets systematically different energies (per-element reference
//! shifts) while forces nearly agree. Also prints per-dataset statistical
//! profiles (element palette, atom counts, force scales) and the pairwise
//! label-disagreement matrix.
//!
//! Run: cargo run --release --example multi_fidelity_inspect

use hydra_mtp::data::fidelity::FidelityModel;
use hydra_mtp::data::generators::{element_histogram, DatasetGenerator, GeneratorConfig};
use hydra_mtp::data::potential;
use hydra_mtp::data::structures::ALL_DATASETS;
use hydra_mtp::elements;
use hydra_mtp::tasks::{
    FidelityProfile, GeneratorProfile, StructureKind, TaskRegistry, TaskSpec,
};
use hydra_mtp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let cfg = GeneratorConfig { max_atoms: 14, ..Default::default() };

    // The task set is data: demonstrate by registering a sixth synthetic
    // source (organosilicon, CCSD-like tight noise) next to the presets —
    // it flows through the same profile table below with zero special
    // casing.
    TaskRegistry::global().register(TaskSpec::new(
        "OrganoSi-demo",
        vec![1, 6, 8, 14],
        GeneratorProfile {
            kind: StructureKind::Molecule { min_atoms: 4, atoms_cap: 14 },
            relax_steps: 10,
            relax_step_size: 0.05,
            perturb_factor: 1.0,
        },
        FidelityProfile {
            seed_tag: 61,
            shift_sigma: 0.8,
            scale_jitter: 0.02,
            force_scale_jitter: 0.01,
            energy_noise: 0.001,
            force_noise: 0.002,
            shift_offset: 0.0,
        },
    ))?;

    println!("== per-task profiles (200 samples each; incl. runtime-registered) ==\n");
    println!(
        "{:<14} {:>7} {:>9} {:>10} {:>10} {:>9}",
        "dataset", "elems", "atoms/str", "mean e/a", "mean |F|", "H frac"
    );
    for d in TaskRegistry::global().all() {
        let mut g = DatasetGenerator::new(d, 2025, cfg.clone());
        let ss = g.take(200);
        let hist = element_histogram(&ss);
        let n_elems = hist.iter().filter(|&&c| c > 0).count();
        let total_atoms: usize = ss.iter().map(|s| s.natoms()).sum();
        let mean_atoms = total_atoms as f64 / ss.len() as f64;
        let mean_epa: f64 =
            ss.iter().map(|s| s.energy_per_atom()).sum::<f64>() / ss.len() as f64;
        let mean_f: f64 = ss
            .iter()
            .flat_map(|s| s.forces.iter())
            .map(|f| (f[0] * f[0] + f[1] * f[1] + f[2] * f[2]).sqrt())
            .sum::<f64>()
            / total_atoms as f64;
        let h_frac = hist[1] as f64 / total_atoms as f64;
        println!(
            "{:<14} {n_elems:>7} {mean_atoms:>9.1} {mean_epa:>10.3} {mean_f:>10.3} {h_frac:>9.2}",
            d.name()
        );
    }

    // The controlled experiment: ONE methane-like structure, five labels.
    println!("\n== one structure, five fidelities (the MTL heads' job) ==\n");
    let species: Vec<u8> = vec![6, 1, 1, 1, 1];
    let positions = vec![
        [0.0, 0.0, 0.0],
        [0.63, 0.63, 0.63],
        [-0.63, -0.63, 0.63],
        [-0.63, 0.63, -0.63],
        [0.63, -0.63, -0.63],
    ];
    let (e_true, f_true) = potential::energy_and_forces(&species, &positions);
    println!("ground truth: E = {e_true:.4} ({:.4} / atom)", e_true / 5.0);
    let mut rng = Rng::new(7);
    for &d in &ALL_DATASETS {
        let fm = FidelityModel::for_dataset(d);
        let (e, f) = fm.apply(&species, e_true, &f_true, &mut rng);
        let f_rms: f64 = (f.iter().flat_map(|v| v.iter()).map(|x| x * x).sum::<f64>()
            / (3.0 * f.len() as f64))
            .sqrt();
        println!(
            "  {:<14} E/atom = {:>8.4}  (shift {:>+7.4})   F_rms = {f_rms:.4}",
            d.name(),
            e / 5.0,
            (e - e_true) / 5.0
        );
    }

    // Pairwise energy-label disagreement on CHNO compositions.
    println!("\n== pairwise per-atom energy disagreement (CHNO probe) ==\n");
    let models: Vec<FidelityModel> =
        ALL_DATASETS.iter().map(|&d| FidelityModel::for_dataset(d)).collect();
    print!("{:<14}", "");
    for d in &ALL_DATASETS {
        print!("{:>13}", d.name());
    }
    println!();
    for (i, a) in models.iter().enumerate() {
        print!("{:<14}", ALL_DATASETS[i].name());
        for b in &models {
            print!("{:>13.4}", a.disagreement(b, &species));
        }
        println!();
    }
    println!(
        "\nNote the block structure: the organic datasets disagree with each \
         other\n(different functionals over shared CHNO chemistry) while \
         MPTrj/Alexandria\nnearly agree (same PBE family) — exactly the \
         pattern in the paper's Tables 1-2."
    );

    // Element coverage of the aggregation (Fig 1's point).
    let mut total = vec![0u64; elements::MAX_Z + 1];
    for &d in &ALL_DATASETS {
        let mut g = DatasetGenerator::new(d, 2025, cfg.clone());
        for (z, c) in element_histogram(&g.take(200)).iter().enumerate() {
            total[z] += c;
        }
    }
    let covered = total.iter().filter(|&&c| c > 0).count();
    println!(
        "\naggregated coverage: {covered}/{} natural elements ({}%)",
        elements::MAX_Z,
        covered * 100 / elements::MAX_Z
    );
    Ok(())
}
