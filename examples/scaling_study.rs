//! Scaling study (Figure 4 analogue) with local calibration.
//!
//! Measures the *real* per-step execution time of train_step on this
//! machine (native backend anywhere, PJRT when artifacts are compiled),
//! uses it to sanity-check the analytic performance model's compute term, then sweeps weak and strong scaling of MTL-base vs
//! MTL-par across the Frontier / Perlmutter / Aurora profiles and prints
//! the six panels plus the memory-regime analysis (Cases 1-3).
//!
//! Run: cargo run --release --example scaling_study -- [--csv fig4.csv]

use std::sync::Arc;

use hydra_mtp::data::batch::BatchBuilder;
use hydra_mtp::data::generators::{DatasetGenerator, GeneratorConfig};
use hydra_mtp::data::structures::DatasetId;
use hydra_mtp::model::arch::{self, ArchDims};
use hydra_mtp::model::params::ParamSet;
use hydra_mtp::runtime::Engine;
use hydra_mtp::scalesim::{self, perfmodel, SimMode, Workload, ALL_MACHINES};
use hydra_mtp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    args.ensure_known("scaling_study", &["seed", "csv"])?;
    let seed = args.u64("seed", 2025);

    // --- local calibration: real train_step latency on this host ---
    // Runs on every machine: the native backend is the universal fallback,
    // PJRT takes over when artifacts + the feature are available. The Err
    // arm only fires when the environment pins an unavailable backend
    // (HYDRA_MTP_BACKEND=pjrt without artifacts); the analytic sweeps
    // below still run in that case.
    println!("== local calibration (real train_step execution) ==");
    match Engine::load("artifacts") {
        Err(e) => eprintln!("calibration skipped: engine unavailable ({e:#})\n"),
        Ok(engine) => {
            let engine = Arc::new(engine);
            println!("backend: {} ({})", engine.backend_name(), engine.platform());
            let mut g = DatasetGenerator::new(
                DatasetId::Ani1x,
                seed,
                GeneratorConfig { max_atoms: 16, ..Default::default() },
            );
            let samples = g.take(32);
            let batches = BatchBuilder::build_all(
                engine.manifest.config.batch_dims(),
                engine.manifest.config.cutoff,
                &samples,
            );
            let params = ParamSet::init(&engine.manifest.params, 1);
            // warmup + timed
            engine.train_step(&params, &batches[0])?;
            let t0 = std::time::Instant::now();
            let reps = 10;
            for i in 0..reps {
                engine.train_step(&params, &batches[i % batches.len()])?;
            }
            let step_t = t0.elapsed() / reps as u32;
            let graphs_per_batch = batches[0].n_graphs;
            println!(
                "measured train_step: {step_t:?} for ~{graphs_per_batch} structures \
                 ({:.2} ms/structure on this CPU)",
                step_t.as_secs_f64() * 1e3 / graphs_per_batch as f64
            );

            // Analytic model at the *artifact* dims for comparison.
            let art_dims = engine.manifest.config.arch_dims();
            let w_art = Workload {
                dims: art_dims,
                n_heads: 5,
                avg_nodes: 14.0,
                avg_edges: 160.0,
                efficiency: 0.25,
            };
            let flops = w_art.flops_encoder_per_sample() + w_art.flops_head_per_sample();
            println!(
                "analytic FLOPs/structure at artifact dims: {:.2} MFLOP \
                 (host sustains ~{:.2} GFLOP/s on this workload)\n",
                flops / 1e6,
                flops * graphs_per_batch as f64 / step_t.as_secs_f64() / 1e9
            );
        }
    }

    // --- memory regimes (paper Section 4.3 Cases) ---
    println!("== memory / regime analysis (paper config, 5..60 heads) ==");
    let paper = ArchDims::paper();
    for n_heads in [2usize, 5, 10, 20, 60] {
        let without = arch::memory_without_mtp(&paper, n_heads);
        let with = arch::memory_with_mtp(&paper);
        let regime = arch::classify_regime(&paper, n_heads, 4.0);
        println!(
            "  {n_heads:>3} heads: DDP {:>8.2} GiB/GPU vs MTP {:>6.2} GiB/GPU  -> {:?}",
            without as f64 / (1u64 << 30) as f64,
            with as f64 / (1u64 << 30) as f64,
            regime
        );
    }

    // --- the six Figure-4 panels ---
    println!("\n== Figure 4 sweep (simulated Frontier / Perlmutter / Aurora) ==\n");
    let w = Workload::paper(5);
    let rows = scalesim::fig4_all(&w, seed);
    for m in &ALL_MACHINES {
        println!("{}", scalesim::render_panel(&rows, m.name, "weak"));
        println!("{}", scalesim::render_panel(&rows, m.name, "strong"));
    }

    // Communication-dominance crossover: where MTL-par starts winning.
    println!("== per-step comm time at scale (strong scaling, paper model) ==");
    for m in &ALL_MACHINES {
        print!("  {:<11}", m.name);
        for gpus in scalesim::sweep::gpu_counts(m) {
            let base = perfmodel::step_comm_time(m, &w, SimMode::MtlBase, gpus);
            let par = perfmodel::step_comm_time(m, &w, SimMode::MtlPar, gpus);
            print!(" {gpus}:{:.1}x", base / par);
        }
        println!();
    }

    if let Some(path) = args.opt_str("csv") {
        std::fs::write(path, scalesim::to_csv(&rows))?;
        println!("\nwrote {path}");
    }
    Ok(())
}
