"""Tests for the L1/L2 structural performance analyzer."""

import dataclasses
import os

import pytest

from compile import perf
from compile.config import DEFAULT, ModelConfig


class TestKernelReports:
    def test_vmem_within_budget_at_default_config(self):
        for rep in (
            perf.egnn_message_report(DEFAULT),
            perf.mlp_head_report(DEFAULT),
            perf.mlp_head_report(DEFAULT, backward=True),
        ):
            assert rep.vmem_bytes < perf.VMEM_BYTES, rep.name
            assert rep.flops > 0
            assert rep.hbm_bytes > 0
            assert 0.0 < rep.mxu_utilization <= 1.0

    def test_paper_width_nearly_saturates_mxu(self):
        paper = ModelConfig(
            max_nodes=1024, max_edges=8192, max_graphs=32,
            hidden=872, num_layers=4, head_hidden=896,
            block_edges=512, block_nodes=128,
        )
        rep = perf.egnn_message_report(paper)
        assert rep.mxu_utilization > 0.9, rep.mxu_utilization

    def test_wider_hidden_raises_utilization(self):
        small = perf.egnn_message_report(DEFAULT)
        wide = perf.egnn_message_report(
            dataclasses.replace(DEFAULT, hidden=128)
        )
        assert wide.mxu_utilization > small.mxu_utilization

    def test_sweep_is_monotone_in_vmem(self):
        rows = perf.sweep_block_sizes(DEFAULT)
        vmems = [r[1] for r in rows]
        assert vmems == sorted(vmems)
        # Utilization does not depend on the block size here (tiling keeps
        # the same matmul aspect ratios) but VMEM grows.
        assert len({round(r[2], 6) for r in rows}) == 1


class TestMatmulShape:
    def test_full_tiles_are_perfect(self):
        m = perf.MatmulShape("x", 128, 128, 128)
        assert m.mxu_utilization == 1.0

    def test_narrow_output_is_poor(self):
        m = perf.MatmulShape("gate", 256, 64, 1)
        assert m.mxu_utilization < 0.05

    def test_flops(self):
        assert perf.MatmulShape("x", 2, 3, 4).flops == 48


class TestHloAudit:
    @pytest.mark.skipif(
        not os.path.exists("../artifacts/train_step.hlo.txt"),
        reason="artifacts not built",
    )
    def test_histogram_finds_dots(self):
        ops = perf.hlo_histogram("../artifacts/train_step.hlo.txt")
        assert ops.get("dot", 0) > 10
        assert sum(ops.values()) > 100
