"""L2 model tests: shapes, masking, symmetry, gradient flow."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.config import TINY


@pytest.fixture(scope="module")
def cfg():
    return TINY


@pytest.fixture(scope="module")
def params(cfg):
    return model.init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def batch(cfg):
    return model.random_batch(jax.random.PRNGKey(1), cfg)


class TestShapes:
    def test_forward_shapes(self, params, batch, cfg):
        e_pa, forces = model.forward(params, batch, cfg)
        assert e_pa.shape == (cfg.max_graphs,)
        assert forces.shape == (cfg.max_nodes, 3)

    def test_encoder_shapes(self, params, batch, cfg):
        h, v = model.encoder_apply(params["encoder"], batch, cfg)
        assert h.shape == (cfg.max_nodes, cfg.hidden)
        assert v.shape == (cfg.max_nodes, 3)

    def test_train_step_outputs(self, params, batch, cfg):
        out = model.make_train_step(cfg)(params, batch)
        assert out["loss"].shape == ()
        grads_flat = jax.tree_util.tree_leaves(out["grads"])
        params_flat = jax.tree_util.tree_leaves(params)
        assert len(grads_flat) == len(params_flat)
        for g, p in zip(grads_flat, params_flat):
            assert g.shape == p.shape

    def test_all_grads_finite_and_nonzero_somewhere(self, params, batch, cfg):
        out = model.make_train_step(cfg)(params, batch)
        leaves = jax.tree_util.tree_leaves(out["grads"])
        assert all(np.isfinite(np.asarray(g)).all() for g in leaves)
        total = sum(float(jnp.sum(jnp.abs(g))) for g in leaves)
        assert total > 0.0


class TestMasking:
    def test_padding_nodes_have_zero_output(self, params, batch, cfg):
        _, forces = model.forward(params, batch, cfg)
        pad = np.asarray(batch["node_mask"]) == 0
        assert np.abs(np.asarray(forces)[pad]).max() == 0.0

    def test_padding_graphs_have_zero_energy(self, params, batch, cfg):
        e_pa, _ = model.forward(params, batch, cfg)
        pad = np.asarray(batch["graph_mask"]) == 0
        if pad.any():
            assert np.abs(np.asarray(e_pa)[pad]).max() == 0.0

    def test_garbage_in_padding_does_not_change_result(self, params, batch, cfg):
        """Corrupting padded node/edge slots must not change predictions."""
        e_pa0, f0 = model.forward(params, batch, cfg)
        b = dict(batch)
        nmask = np.asarray(batch["node_mask"])
        emask = np.asarray(batch["edge_mask"])
        species = np.asarray(batch["species"]).copy()
        species[nmask == 0] = 7  # garbage species in padding
        yf = np.asarray(batch["y_forces"]).copy()
        yf[nmask == 0] = 99.0
        b["species"] = jnp.asarray(species)
        b["y_forces"] = jnp.asarray(yf)
        e_pa1, f1 = model.forward(params, b, cfg)
        np.testing.assert_allclose(e_pa0, e_pa1, rtol=1e-6, atol=1e-6)
        real = nmask > 0
        np.testing.assert_allclose(
            np.asarray(f0)[real], np.asarray(f1)[real], rtol=1e-6, atol=1e-6
        )


class TestSymmetry:
    def test_energy_rotation_invariant_forces_equivariant(self, params, batch, cfg):
        """Rotating every edge geometry rotates forces, leaves energy fixed."""
        rng = np.random.default_rng(0)
        # A random rotation matrix via QR.
        q, _ = np.linalg.qr(rng.normal(0, 1, (3, 3)))
        if np.linalg.det(q) < 0:
            q[:, 0] *= -1
        q = q.astype(np.float32)

        e0, f0 = model.forward(params, batch, cfg)
        b = dict(batch)
        b["rel_hat"] = jnp.asarray(np.asarray(batch["rel_hat"]) @ q.T)
        e1, f1 = model.forward(params, b, cfg)
        np.testing.assert_allclose(e0, e1, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(f0) @ q.T, np.asarray(f1), rtol=1e-3, atol=1e-4
        )

    def test_node_permutation_equivariance_of_energy(self, params, batch, cfg):
        """Relabeling atoms within the batch must not change graph energies."""
        perm = np.random.default_rng(3).permutation(cfg.max_nodes)
        inv = np.argsort(perm)
        b = dict(batch)
        for k in ("species", "node_mask", "node_graph"):
            b[k] = jnp.asarray(np.asarray(batch[k])[perm])
        b["y_forces"] = jnp.asarray(np.asarray(batch["y_forces"])[perm])
        # edges: remap endpoints through the inverse permutation
        b["edge_src"] = jnp.asarray(inv[np.asarray(batch["edge_src"])].astype(np.int32))
        b["edge_dst"] = jnp.asarray(inv[np.asarray(batch["edge_dst"])].astype(np.int32))
        e0, _ = model.forward(params, batch, cfg)
        e1, _ = model.forward(params, b, cfg)
        np.testing.assert_allclose(e0, e1, rtol=1e-4, atol=1e-5)


class TestTraining:
    def test_loss_decreases_under_sgd(self, cfg):
        """A few SGD steps on one batch must reduce the loss (sanity)."""
        params = model.init_params(jax.random.PRNGKey(7), cfg)
        batch = model.random_batch(jax.random.PRNGKey(8), cfg)
        step = jax.jit(model.make_train_step(cfg))
        losses = []
        lr = 3e-3
        for _ in range(8):
            out = step(params, batch)
            losses.append(float(out["loss"]))
            params = jax.tree_util.tree_map(
                lambda p, g: p - lr * g, params, out["grads"]
            )
        assert losses[-1] < losses[0], losses

    def test_eval_step_matches_train_step_metrics(self, params, batch, cfg):
        tr = model.make_train_step(cfg)(params, batch)
        ev = model.make_eval_step(cfg)(params, batch)
        np.testing.assert_allclose(tr["loss"], ev["loss"], rtol=1e-6)
        np.testing.assert_allclose(tr["mae_e"], ev["mae_e"], rtol=1e-6)
        np.testing.assert_allclose(tr["mae_f"], ev["mae_f"], rtol=1e-6)

    def test_branch_swap_changes_predictions_encoder_shared(self, batch, cfg):
        """Two branches over the same encoder: the MTL split point."""
        p1 = model.init_params(jax.random.PRNGKey(0), cfg)
        branch2 = model.init_branch(jax.random.PRNGKey(99), cfg)
        p2 = {"encoder": p1["encoder"], "branch": branch2}
        e1, _ = model.forward(p1, batch, cfg)
        e2, _ = model.forward(p2, batch, cfg)
        gm = np.asarray(batch["graph_mask"]) > 0
        assert np.abs(np.asarray(e1 - e2)[gm]).max() > 1e-6

    def test_config_post_init_rejects_bad_tiling(self):
        with pytest.raises(AssertionError):
            dataclasses.replace(TINY, max_edges=33)
