"""Pallas kernels vs pure-jnp oracle — the CORE correctness signal.

Covers values and gradients of both L1 kernels across hypothesis-driven
shape/seed sweeps. Everything runs with interpret=True on the CPU backend,
exactly as the artifacts are lowered.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    egnn_message,
    egnn_message_fwd_pallas,
    mlp_head,
    mlp_head_fwd_pallas,
)
from compile.kernels.ref import egnn_message_ref, mlp_head_ref, rbf_expand


def _edge_inputs(seed, e, n, h, r):
    rng = np.random.default_rng(seed)
    h_src = jnp.asarray(rng.normal(0, 1, (e, h)).astype(np.float32))
    h_dst = jnp.asarray(rng.normal(0, 1, (e, h)).astype(np.float32))
    rbf = jnp.asarray(rng.normal(0, 1, (e, r)).astype(np.float32))
    rel = rng.normal(0, 1, (e, 3))
    rel /= np.maximum(np.linalg.norm(rel, axis=1, keepdims=True), 1e-6)
    rel_hat = jnp.asarray(rel.astype(np.float32))
    dst = jnp.asarray(rng.integers(0, n, e, dtype=np.int32))
    emask = jnp.asarray(
        (rng.uniform(0, 1, (e, 1)) > 0.2).astype(np.float32)
    )
    params = {
        "w1": jnp.asarray(rng.normal(0, 0.3, (2 * h + r, h)).astype(np.float32)),
        "b1": jnp.asarray(rng.normal(0, 0.1, (h,)).astype(np.float32)),
        "w2": jnp.asarray(rng.normal(0, 0.3, (h, h)).astype(np.float32)),
        "b2": jnp.asarray(rng.normal(0, 0.1, (h,)).astype(np.float32)),
        "wg": jnp.asarray(rng.normal(0, 0.3, (h, 1)).astype(np.float32)),
        "bg": jnp.asarray(rng.normal(0, 0.1, (1,)).astype(np.float32)),
    }
    return h_src, h_dst, rbf, rel_hat, dst, emask, params


def _head_inputs(seed, n, h, d):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1, (n, h)).astype(np.float32))
    params = {
        "w1": jnp.asarray(rng.normal(0, 0.3, (h, d)).astype(np.float32)),
        "b1": jnp.asarray(rng.normal(0, 0.1, (d,)).astype(np.float32)),
        "w2": jnp.asarray(rng.normal(0, 0.3, (d, d)).astype(np.float32)),
        "b2": jnp.asarray(rng.normal(0, 0.1, (d,)).astype(np.float32)),
        "w3": jnp.asarray(rng.normal(0, 0.3, (d, d)).astype(np.float32)),
        "b3": jnp.asarray(rng.normal(0, 0.1, (d,)).astype(np.float32)),
    }
    return x, params


# ---------------------------------------------------------------------------
# egnn_message: forward values
# ---------------------------------------------------------------------------

class TestEgnnMessageForward:
    @pytest.mark.parametrize("block", [16, 32, 64])
    def test_matches_ref_across_blocks(self, block):
        e, n, h, r = 64, 24, 16, 8
        args = _edge_inputs(0, e, n, h, r)
        m, hagg, vagg = egnn_message_fwd_pallas(*args, n, block)
        m_r, hagg_r, vagg_r = egnn_message_ref(*args, n)
        np.testing.assert_allclose(m, m_r, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(hagg, hagg_r, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(vagg, vagg_r, rtol=1e-5, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        eb=st.sampled_from([(32, 16), (64, 32), (128, 32), (64, 64)]),
        n=st.sampled_from([8, 17, 24, 40]),
        h=st.sampled_from([8, 16, 24]),
        r=st.sampled_from([4, 8]),
    )
    def test_hypothesis_sweep(self, seed, eb, n, h, r):
        e, block = eb
        args = _edge_inputs(seed, e, n, h, r)
        m, hagg, vagg = egnn_message_fwd_pallas(*args, n, block)
        m_r, hagg_r, vagg_r = egnn_message_ref(*args, n)
        np.testing.assert_allclose(m, m_r, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(hagg, hagg_r, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(vagg, vagg_r, rtol=1e-4, atol=1e-4)

    def test_padding_edges_contribute_nothing(self):
        e, n, h, r = 64, 16, 8, 4
        h_src, h_dst, rbf, rel_hat, dst, _, params = _edge_inputs(3, e, n, h, r)
        all_masked = jnp.zeros((e, 1), jnp.float32)
        m, hagg, vagg = egnn_message_fwd_pallas(
            h_src, h_dst, rbf, rel_hat, dst, all_masked, params, n, 32
        )
        assert np.abs(np.asarray(m)).max() == 0.0
        assert np.abs(np.asarray(hagg)).max() == 0.0
        assert np.abs(np.asarray(vagg)).max() == 0.0

    def test_scatter_targets_correct_nodes(self):
        """Each edge's message must land exactly on its dst row."""
        e, n, h, r = 32, 8, 8, 4
        h_src, h_dst, rbf, rel_hat, _, emask, params = _edge_inputs(7, e, n, h, r)
        dst = jnp.asarray(np.full(e, 3, np.int32))  # all edges -> node 3
        m, hagg, _ = egnn_message_fwd_pallas(
            h_src, h_dst, rbf, rel_hat, dst, emask, params, n, 32
        )
        expected_row3 = np.asarray(m).sum(axis=0)
        np.testing.assert_allclose(hagg[3], expected_row3, rtol=1e-5, atol=1e-5)
        rest = np.delete(np.asarray(hagg), 3, axis=0)
        assert np.abs(rest).max() == 0.0


# ---------------------------------------------------------------------------
# egnn_message: gradients (custom_vjp vs jax.grad of the reference)
# ---------------------------------------------------------------------------

class TestEgnnMessageGrad:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_grads_match_ref_autodiff(self, seed):
        e, n, h, r, block = 64, 16, 8, 4, 32
        h_src, h_dst, rbf, rel_hat, dst, emask, params = _edge_inputs(
            seed, e, n, h, r
        )

        def loss_pallas(h_src, h_dst, rbf, params):
            m, hagg, vagg = egnn_message(
                h_src, h_dst, rbf, rel_hat, dst, emask, params, n, block
            )
            return (
                jnp.sum(jnp.sin(m))
                + jnp.sum(hagg**2)
                + jnp.sum(jnp.cos(vagg))
            )

        def loss_ref(h_src, h_dst, rbf, params):
            m, hagg, vagg = egnn_message_ref(
                h_src, h_dst, rbf, rel_hat, dst, emask, params, n
            )
            return (
                jnp.sum(jnp.sin(m))
                + jnp.sum(hagg**2)
                + jnp.sum(jnp.cos(vagg))
            )

        gp = jax.grad(loss_pallas, argnums=(0, 1, 2, 3))(h_src, h_dst, rbf, params)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(h_src, h_dst, rbf, params)
        for a, b in zip(jax.tree_util.tree_leaves(gp), jax.tree_util.tree_leaves(gr)):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_value_matches_between_vjp_and_raw(self):
        e, n, h, r, block = 64, 16, 8, 4, 32
        args = _edge_inputs(11, e, n, h, r)
        out1 = egnn_message(*args, n, block)
        out2 = egnn_message_fwd_pallas(*args, n, block)
        for a, b in zip(out1, out2):
            np.testing.assert_allclose(a, b, rtol=0, atol=0)


# ---------------------------------------------------------------------------
# mlp_head: forward + backward kernels
# ---------------------------------------------------------------------------

class TestMlpHead:
    @pytest.mark.parametrize("block", [8, 16, 32])
    def test_forward_matches_ref(self, block):
        n, h, d = 64, 16, 24
        x, params = _head_inputs(0, n, h, d)
        z, _ = mlp_head_fwd_pallas(x, params, block)
        z_r = mlp_head_ref(x, params)
        np.testing.assert_allclose(z, z_r, rtol=1e-5, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        nb=st.sampled_from([(16, 8), (32, 16), (64, 16), (32, 32)]),
        h=st.sampled_from([8, 16, 24]),
        d=st.sampled_from([8, 16, 32]),
    )
    def test_hypothesis_sweep(self, seed, nb, h, d):
        n, block = nb
        x, params = _head_inputs(seed, n, h, d)
        z, _ = mlp_head_fwd_pallas(x, params, block)
        z_r = mlp_head_ref(x, params)
        np.testing.assert_allclose(z, z_r, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("seed", [0, 5, 9])
    def test_backward_kernel_matches_ref_autodiff(self, seed):
        """The hand-written Pallas backward vs jax.grad of the reference."""
        n, h, d, block = 32, 16, 16, 16
        x, params = _head_inputs(seed, n, h, d)

        def loss_pallas(x, params):
            return jnp.sum(jnp.tanh(mlp_head(x, params, block)))

        def loss_ref(x, params):
            return jnp.sum(jnp.tanh(mlp_head_ref(x, params)))

        gx_p, gp_p = jax.grad(loss_pallas, argnums=(0, 1))(x, params)
        gx_r, gp_r = jax.grad(loss_ref, argnums=(0, 1))(x, params)
        np.testing.assert_allclose(gx_p, gx_r, rtol=1e-4, atol=1e-4)
        for k in gp_r:
            np.testing.assert_allclose(
                gp_p[k], gp_r[k], rtol=1e-4, atol=1e-4, err_msg=k
            )

    def test_weight_grad_accumulates_across_tiles(self):
        """Weight grads must sum contributions from every node tile."""
        n, h, d, block = 64, 8, 8, 8  # 8 grid steps
        x, params = _head_inputs(2, n, h, d)

        def loss(params):
            return jnp.sum(mlp_head(x, params, block))

        g_many = jax.grad(loss)(params)

        def loss_one(params):
            return jnp.sum(mlp_head(x, params, n))  # single tile

        g_one = jax.grad(loss_one)(params)
        for k in g_many:
            np.testing.assert_allclose(
                g_many[k], g_one[k], rtol=1e-4, atol=1e-4, err_msg=k
            )


# ---------------------------------------------------------------------------
# rbf expansion
# ---------------------------------------------------------------------------

class TestRbf:
    def test_zero_distance_is_finite(self):
        out = rbf_expand(jnp.zeros(8), 16, 6.0)
        assert np.isfinite(np.asarray(out)).all()

    def test_beyond_cutoff_is_zero(self):
        out = rbf_expand(jnp.asarray([6.0, 7.5, 100.0]), 16, 6.0)
        np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)

    def test_shape(self):
        assert rbf_expand(jnp.zeros(12), 7, 5.0).shape == (12, 7)
