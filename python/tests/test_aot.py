"""AOT export tests: manifest consistency, determinism, HLO sanity."""

import json
import os

import jax
import pytest

from compile import aot, model
from compile.config import TINY


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.export_artifacts(TINY, str(out), quiet=True)
    return str(out), manifest


class TestManifest:
    def test_all_artifacts_written(self, exported):
        out, manifest = exported
        for name, entry in manifest["artifacts"].items():
            path = os.path.join(out, entry["file"])
            assert os.path.exists(path), name
            assert os.path.getsize(path) == entry["hlo_bytes"]

    def test_manifest_roundtrips_as_json(self, exported):
        out, manifest = exported
        with open(os.path.join(out, "manifest.json")) as f:
            loaded = json.load(f)
        assert loaded["config"] == manifest["config"]
        assert loaded["artifacts"].keys() == manifest["artifacts"].keys()

    def test_input_count_matches_flattened_pytrees(self, exported):
        _, manifest = exported
        params = model.init_params(jax.random.PRNGKey(0), TINY)
        batch = model.batch_spec(TINY)
        n_params = len(jax.tree_util.tree_leaves(params))
        n_batch = len(jax.tree_util.tree_leaves(batch))
        ts = manifest["artifacts"]["train_step"]
        assert len(ts["inputs"]) == n_params + n_batch

    def test_train_step_outputs_are_grads_plus_metrics(self, exported):
        _, manifest = exported
        ts = manifest["artifacts"]["train_step"]
        names = [o["name"] for o in ts["outputs"]]
        assert "loss" in names and "mae_e" in names and "mae_f" in names
        grads = [n for n in names if n.startswith("grads.")]
        assert len(grads) == len(manifest["params"])

    def test_grad_outputs_mirror_param_shapes(self, exported):
        _, manifest = exported
        ts = manifest["artifacts"]["train_step"]
        by_name = {o["name"]: o for o in ts["outputs"]}
        for p in manifest["params"]:
            g = by_name["grads." + p["name"]]
            assert g["shape"] == p["shape"]
            assert g["dtype"] == p["dtype"]

    def test_param_metadata_has_init_hints(self, exported):
        _, manifest = exported
        for p in manifest["params"]:
            leaf = p["name"].rsplit(".", 1)[-1]
            if leaf.startswith("w") and len(p["shape"]) == 2:
                assert p["init"]["kind"] == "lecun"
                assert p["init"]["fan_in"] == p["shape"][0]
            elif leaf.startswith("b"):
                assert p["init"]["kind"] == "zeros"

    def test_batch_field_order_is_sorted(self, exported):
        """Rust relies on dict-key sorted flatten order."""
        _, manifest = exported
        names = [b["name"] for b in manifest["batch"]]
        assert names == sorted(names)

    def test_encoder_params_prefix_of_names(self, exported):
        _, manifest = exported
        enc = {p["name"] for p in manifest["encoder_params"]}
        full = {p["name"] for p in manifest["params"]}
        assert {"encoder." + n for n in enc} <= full


class TestDeterminism:
    def test_export_is_deterministic(self, exported, tmp_path):
        out1, manifest1 = exported
        manifest2 = aot.export_artifacts(TINY, str(tmp_path), quiet=True)
        for name in manifest1["artifacts"]:
            assert (
                manifest1["artifacts"][name]["sha256"]
                == manifest2["artifacts"][name]["sha256"]
            ), name


class TestHloText:
    def test_hlo_is_text_parsable_header(self, exported):
        out, manifest = exported
        for entry in manifest["artifacts"].values():
            with open(os.path.join(out, entry["file"])) as f:
                head = f.read(200)
            assert head.startswith("HloModule"), entry["file"]

    def test_no_mosaic_custom_calls(self, exported):
        """interpret=True must have eliminated TPU-only custom calls."""
        out, manifest = exported
        for entry in manifest["artifacts"].values():
            with open(os.path.join(out, entry["file"])) as f:
                text = f.read()
            assert "tpu_custom_call" not in text, entry["file"]
            assert "mosaic" not in text.lower(), entry["file"]


class TestOverrides:
    def test_parse_overrides(self):
        out = aot.parse_overrides(["hidden=32", "cutoff=5.5"])
        assert out == {"hidden": 32, "cutoff": 5.5}

    def test_parse_overrides_rejects_unknown(self):
        with pytest.raises(SystemExit):
            aot.parse_overrides(["nope=1"])
