"""L2: HydraGNN-style JAX model — EGNN encoder + one two-level MTL branch.

The model follows the paper's architecture (Section 4.2 / Section 5):

  shared encoder : species embedding + ``num_layers`` EGNN message-passing
                   layers (invariant scalar channel ``h`` plus an equivariant
                   vector channel ``v`` used for force prediction);
  branch         : per-dataset trunk of 3 fully-connected layers (L1 Pallas
                   kernel) that splits into two sub-heads — energy-per-atom
                   (graph level) and atomic forces (node level, equivariant
                   via the vector channel).

Under multi-task parallelism each rust process executes the exported
``train_step`` with *its own* branch parameters, so a single artifact serves
all heads. Everything here is build-time Python: ``aot.py`` lowers these
functions once to HLO text.

Batches are statically shaped padded graph batches (see config.ModelConfig):
    species    i32[N]      0 = padding atom
    edge_src   i32[E]      source node per directed edge
    edge_dst   i32[E]      destination node per directed edge
    rel_hat    f32[E,3]    unit vector x_src - x_dst
    dist       f32[E]      edge length (Angstrom)
    node_mask  f32[N]      1 for real atoms
    edge_mask  f32[E]      1 for real edges
    node_graph i32[N]      graph id per node (padding -> max_graphs-1 slot ok)
    graph_mask f32[G]      1 for real structures
    inv_atoms  f32[G]      1 / natoms per structure (0 for padding)
    y_energy   f32[G]      target energy per atom
    y_forces   f32[N,3]    target forces
"""

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import egnn_message, mlp_head
from .kernels.ref import rbf_expand, silu

BATCH_FIELDS = (
    ("species", "i4", ("N",)),
    ("edge_src", "i4", ("E",)),
    ("edge_dst", "i4", ("E",)),
    ("rel_hat", "f4", ("E", 3)),
    ("dist", "f4", ("E",)),
    ("node_mask", "f4", ("N",)),
    ("edge_mask", "f4", ("E",)),
    ("node_graph", "i4", ("N",)),
    ("graph_mask", "f4", ("G",)),
    ("inv_atoms", "f4", ("G",)),
    ("y_energy", "f4", ("G",)),
    ("y_forces", "f4", ("N", 3)),
)


# ---------------------------------------------------------------------------
# initialization
# ---------------------------------------------------------------------------

def _dense_init(key, fan_in, fan_out, dtype=jnp.float32):
    """LeCun-normal weights, zero bias (matches the rust-side initializer)."""
    w = jax.random.normal(key, (fan_in, fan_out), dtype) / jnp.sqrt(
        jnp.asarray(fan_in, dtype)
    )
    return w, jnp.zeros((fan_out,), dtype)


def init_encoder(key, cfg: ModelConfig):
    keys = jax.random.split(key, 1 + cfg.num_layers)
    embed = (
        jax.random.normal(keys[0], (cfg.num_species, cfg.hidden), jnp.float32)
        * 0.5
    )
    layers = []
    for li in range(cfg.num_layers):
        k = jax.random.split(keys[1 + li], 5)
        ew1, eb1 = _dense_init(k[0], cfg.edge_in, cfg.hidden)
        ew2, eb2 = _dense_init(k[1], cfg.hidden, cfg.hidden)
        gw, gb = _dense_init(k[2], cfg.hidden, 1)
        nw1, nb1 = _dense_init(k[3], cfg.node_in, cfg.hidden)
        nw2, nb2 = _dense_init(k[4], cfg.hidden, cfg.hidden)
        layers.append(
            {
                "edge": {"w1": ew1, "b1": eb1, "w2": ew2, "b2": eb2,
                         "wg": gw, "bg": gb},
                "node": {"w1": nw1, "b1": nb1, "w2": nw2, "b2": nb2},
            }
        )
    return {"embed": embed, "layers": layers}


def init_branch(key, cfg: ModelConfig):
    k = jax.random.split(key, 5)
    tw1, tb1 = _dense_init(k[0], cfg.hidden, cfg.head_hidden)
    tw2, tb2 = _dense_init(k[1], cfg.head_hidden, cfg.head_hidden)
    tw3, tb3 = _dense_init(k[2], cfg.head_hidden, cfg.head_hidden)
    ew, eb = _dense_init(k[3], cfg.head_hidden, 1)
    fw, fb = _dense_init(k[4], cfg.head_hidden, 1)
    return {
        "trunk": {"w1": tw1, "b1": tb1, "w2": tw2, "b2": tb2,
                  "w3": tw3, "b3": tb3},
        "energy": {"w": ew, "b": eb},
        "force": {"w": fw, "b": fb},
    }


def init_params(key, cfg: ModelConfig):
    ke, kb = jax.random.split(key)
    return {"branch": init_branch(kb, cfg), "encoder": init_encoder(ke, cfg)}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def encoder_apply(enc, batch, cfg: ModelConfig):
    """Shared MPNN layers: returns (h [N,H] invariant, v [N,3] equivariant)."""
    node_mask = batch["node_mask"][:, None]
    emask = batch["edge_mask"][:, None]
    h = enc["embed"][batch["species"]] * node_mask
    v = jnp.zeros((cfg.max_nodes, 3), h.dtype)
    rbf = rbf_expand(batch["dist"], cfg.num_rbf, cfg.cutoff) * emask

    # Degree normalization: the kernel scatter-adds edge messages; dense
    # molecular graphs (20+ neighbours within the cutoff) would otherwise
    # grow |h| layer over layer and push pre-activations into overflow.
    deg = jnp.zeros(cfg.max_nodes, h.dtype).at[batch["edge_dst"]].add(
        batch["edge_mask"]
    )
    inv_deg = (1.0 / (1.0 + deg))[:, None]

    for layer in enc["layers"]:
        h_src = h[batch["edge_src"]]
        h_dst = h[batch["edge_dst"]]
        _, hagg, vagg = egnn_message(
            h_src, h_dst, rbf, batch["rel_hat"], batch["edge_dst"], emask,
            layer["edge"], cfg.max_nodes, cfg.block_edges,
        )
        hagg = hagg * inv_deg
        v = v + vagg * inv_deg * node_mask
        nin = jnp.concatenate([h, hagg], axis=1)
        upd = silu(nin @ layer["node"]["w1"] + layer["node"]["b1"])
        upd = upd @ layer["node"]["w2"] + layer["node"]["b2"]
        h = (h + upd) * node_mask
    return h, v


def branch_apply(branch, h, v, batch, cfg: ModelConfig):
    """One dataset branch: trunk MLP -> {energy-per-atom, forces}."""
    z = mlp_head(h, branch["trunk"], cfg.block_nodes)  # (N, D) pallas

    # Energy sub-head: per-node scalar, masked segment-sum per graph,
    # normalized to energy *per atom*.
    e_node = (z @ branch["energy"]["w"] + branch["energy"]["b"])[:, 0]
    e_node = e_node * batch["node_mask"]
    seg = (
        jnp.arange(cfg.max_graphs, dtype=jnp.int32)[:, None]
        == batch["node_graph"][None, :]
    ).astype(z.dtype) * batch["node_mask"][None, :]       # (G, N)
    e_pa = (seg @ e_node) * batch["inv_atoms"]            # (G,)

    # Force sub-head: scalar gate times the equivariant vector channel.
    gate = z @ branch["force"]["w"] + branch["force"]["b"]  # (N, 1)
    forces = gate * v * batch["node_mask"][:, None]
    return e_pa, forces


def forward(params, batch, cfg: ModelConfig):
    h, v = encoder_apply(params["encoder"], batch, cfg)
    return branch_apply(params["branch"], h, v, batch, cfg)


# ---------------------------------------------------------------------------
# loss / metrics / train step
# ---------------------------------------------------------------------------

def loss_and_metrics(params, batch, cfg: ModelConfig):
    e_pa, forces = forward(params, batch, cfg)
    gmask = batch["graph_mask"]
    nmask = batch["node_mask"]
    n_g = jnp.maximum(jnp.sum(gmask), 1.0)
    n_n = jnp.maximum(jnp.sum(nmask), 1.0)

    de = (e_pa - batch["y_energy"]) * gmask
    df = (forces - batch["y_forces"]) * nmask[:, None]

    mse_e = jnp.sum(de**2) / n_g
    mse_f = jnp.sum(df**2) / (3.0 * n_n)
    loss = cfg.energy_weight * mse_e + cfg.force_weight * mse_f

    mae_e = jnp.sum(jnp.abs(de)) / n_g
    mae_f = jnp.sum(jnp.abs(df)) / (3.0 * n_n)
    return loss, (mae_e, mae_f)


def make_train_step(cfg: ModelConfig):
    """Returns train_step(params, batch) -> {loss, mae_e, mae_f, grads}.

    The optimizer update runs in rust (L3) so the artifact stays a pure
    function: same inputs -> same outputs, no state.
    """

    def train_step(params, batch):
        (loss, (mae_e, mae_f)), grads = jax.value_and_grad(
            loss_and_metrics, has_aux=True
        )(params, batch, cfg)
        return {"loss": loss, "mae_e": mae_e, "mae_f": mae_f, "grads": grads}

    return train_step


def make_forward(cfg: ModelConfig):
    def fwd(params, batch):
        e_pa, forces = forward(params, batch, cfg)
        return {"energy": e_pa, "forces": forces}

    return fwd


def make_eval_step(cfg: ModelConfig):
    """Forward + metrics, no gradients: the evaluation hot path."""

    def eval_step(params, batch):
        loss, (mae_e, mae_f) = loss_and_metrics(params, batch, cfg)
        return {"loss": loss, "mae_e": mae_e, "mae_f": mae_f}

    return eval_step


def make_encoder_forward(cfg: ModelConfig):
    """Encoder-only forward (diagnostics / transfer-learning example)."""

    def enc_fwd(enc_params, batch):
        h, v = encoder_apply(enc_params, batch, cfg)
        return {"h": h, "v": v}

    return enc_fwd


# ---------------------------------------------------------------------------
# example inputs (shared by aot.py and tests)
# ---------------------------------------------------------------------------

def batch_spec(cfg: ModelConfig):
    """ShapeDtypeStruct pytree describing one padded batch."""
    dims = {"N": cfg.max_nodes, "E": cfg.max_edges, "G": cfg.max_graphs}
    out = {}
    for name, dt, shape in BATCH_FIELDS:
        shp = tuple(dims[s] if isinstance(s, str) else s for s in shape)
        dtype = jnp.int32 if dt == "i4" else jnp.float32
        out[name] = jax.ShapeDtypeStruct(shp, dtype)
    return out


def random_batch(key, cfg: ModelConfig, n_graphs=None):
    """A synthetic — but *internally consistent* — padded batch for tests."""
    import numpy as np

    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
    n_graphs = n_graphs or cfg.max_graphs
    species = np.zeros(cfg.max_nodes, np.int32)
    node_graph = np.full(cfg.max_nodes, cfg.max_graphs - 1, np.int32)
    node_mask = np.zeros(cfg.max_nodes, np.float32)
    inv_atoms = np.zeros(cfg.max_graphs, np.float32)
    graph_mask = np.zeros(cfg.max_graphs, np.float32)
    positions = rng.uniform(0, 8, (cfg.max_nodes, 3)).astype(np.float32)

    node = 0
    per_graph = max(2, cfg.max_nodes // max(n_graphs, 1) - 1)
    for g in range(n_graphs):
        take = min(per_graph, cfg.max_nodes - node)
        if take < 2:
            break
        species[node : node + take] = rng.integers(
            1, cfg.num_species, take, dtype=np.int32
        )
        node_graph[node : node + take] = g
        node_mask[node : node + take] = 1.0
        inv_atoms[g] = 1.0 / take
        graph_mask[g] = 1.0
        node += take

    # Edges: random pairs within each graph.
    src = np.zeros(cfg.max_edges, np.int32)
    dst = np.zeros(cfg.max_edges, np.int32)
    emask = np.zeros(cfg.max_edges, np.float32)
    real_nodes = np.where(node_mask > 0)[0]
    if len(real_nodes) >= 2:
        budget = min(cfg.max_edges, len(real_nodes) * 8)
        for e in range(budget):
            g = rng.integers(0, max(n_graphs, 1))
            members = np.where(node_graph == g)[0]
            if len(members) < 2:
                continue
            a, b = rng.choice(members, 2, replace=False)
            src[e], dst[e] = a, b
            emask[e] = 1.0
    rel = positions[src] - positions[dst]
    d = np.linalg.norm(rel, axis=1)
    d = np.where(emask > 0, np.maximum(d, 1e-3), 0.0)
    rel_hat = np.where(
        emask[:, None] > 0, rel / np.maximum(d, 1e-3)[:, None], 0.0
    ).astype(np.float32)

    return {
        "species": jnp.asarray(species),
        "edge_src": jnp.asarray(src),
        "edge_dst": jnp.asarray(dst),
        "rel_hat": jnp.asarray(rel_hat),
        "dist": jnp.asarray(d.astype(np.float32)),
        "node_mask": jnp.asarray(node_mask),
        "edge_mask": jnp.asarray(emask),
        "node_graph": jnp.asarray(node_graph),
        "graph_mask": jnp.asarray(graph_mask),
        "inv_atoms": jnp.asarray(inv_atoms),
        "y_energy": jnp.asarray(
            rng.normal(0, 1, cfg.max_graphs).astype(np.float32) * graph_mask
        ),
        "y_forces": jnp.asarray(
            rng.normal(0, 1, (cfg.max_nodes, 3)).astype(np.float32)
            * node_mask[:, None]
        ),
    }
