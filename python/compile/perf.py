"""L1/L2 performance analysis (build-time tooling).

interpret=True wallclock is CPU-numpy time, NOT a TPU proxy, so the L1
optimization loop is *structural*: given the kernels' BlockSpecs this module
computes, per grid step,

  - VMEM footprint (inputs + outputs + weights resident per step), checked
    against the ~16 MiB/core budget;
  - MXU utilization estimate: fraction of each matmul's (M, K, N) that fills
    the 128x128 systolic array, FLOPs-weighted;
  - HBM <-> VMEM traffic and arithmetic intensity (FLOPs/byte), placing each
    kernel on the roofline.

It also audits the lowered HLO artifacts (op histogram, fusion count) for
the L2 pass. Results are recorded in EXPERIMENTS.md §Perf.

Usage: python -m compile.perf [--set key=val ...]
"""

import argparse
import collections
import dataclasses
import os
import re

from .config import DEFAULT, ModelConfig

MXU = 128           # systolic array edge
VMEM_BYTES = 16 * 1024 * 1024
F32 = 4


@dataclasses.dataclass
class MatmulShape:
    name: str
    m: int
    k: int
    n: int

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.k * self.n

    @property
    def mxu_utilization(self) -> float:
        """Fraction of the systolic array the tile shapes fill.

        Each dimension pads up to the next multiple of MXU lanes (M, N) /
        8-deep sublanes (K is pipelined, near-free when >= 8).
        """
        def eff(dim, quantum):
            pad = -dim % quantum
            return dim / (dim + pad)

        return eff(self.m, 8) * eff(self.n, MXU) * eff(self.k, 8)


@dataclasses.dataclass
class KernelReport:
    name: str
    grid: int
    vmem_bytes: int
    matmuls: list
    hbm_bytes: float

    @property
    def flops(self) -> float:
        return self.grid * sum(m.flops for m in self.matmuls)

    @property
    def mxu_utilization(self) -> float:
        total = sum(m.flops for m in self.matmuls)
        return sum(m.flops * m.mxu_utilization for m in self.matmuls) / total

    @property
    def intensity(self) -> float:
        return self.flops / self.hbm_bytes

    def render(self) -> str:
        lines = [
            f"kernel {self.name}: grid={self.grid}",
            f"  VMEM/step: {self.vmem_bytes / 1024:.1f} KiB "
            f"({100 * self.vmem_bytes / VMEM_BYTES:.1f}% of 16 MiB budget)",
            f"  FLOPs: {self.flops / 1e6:.2f} M   "
            f"HBM traffic: {self.hbm_bytes / 1e6:.2f} MB   "
            f"intensity: {self.intensity:.1f} FLOP/B",
            f"  MXU utilization (FLOPs-weighted): {100 * self.mxu_utilization:.1f}%",
        ]
        for m in self.matmuls:
            lines.append(
                f"    {m.name:<28} ({m.m:>5} x {m.k:>4} x {m.n:>4})"
                f"  util {100 * m.mxu_utilization:.1f}%"
            )
        return "\n".join(lines)


def egnn_message_report(cfg: ModelConfig) -> KernelReport:
    """Structural model of kernels/egnn_message.py's pallas_call."""
    be = cfg.block_edges
    h = cfg.hidden
    r = cfg.num_rbf
    n = cfg.max_nodes
    grid = cfg.max_edges // be

    # Resident per grid step: edge tiles + full weights + node accumulators.
    vmem = F32 * (
        be * h * 2          # h_src, h_dst
        + be * r            # rbf
        + be * 3            # rel_hat
        + be                # dst (i32)
        + be                # emask
        + (2 * h + r) * h + h + h * h + h + h + 1   # weights
        + be * h            # m out tile
        + n * h             # hagg accumulator
        + n * 3             # vagg accumulator
        + n * be            # one-hot scatter matrix
    )
    matmuls = [
        MatmulShape("edge_mlp_1 (x @ w1)", be, 2 * h + r, h),
        MatmulShape("edge_mlp_2 (u @ w2)", be, h, h),
        MatmulShape("gate (m @ wg)", be, h, 1),
        MatmulShape("scatter_h (onehot @ m)", n, be, h),
        MatmulShape("scatter_v (onehot @ gv)", n, be, 3),
    ]
    # HBM: stream every edge tile once; weights once; node accums once.
    hbm = F32 * (
        cfg.max_edges * (2 * h + r + 3 + 1 + 1)
        + ((2 * h + r) * h + h * h + 2 * h + h + 1)
        + cfg.max_edges * h      # m written back
        + n * (h + 3)
    )
    return KernelReport("egnn_message", grid, vmem, matmuls, hbm)


def mlp_head_report(cfg: ModelConfig, backward: bool = False) -> KernelReport:
    """Structural model of kernels/mlp_head.py's pallas_calls."""
    bn = cfg.block_nodes
    h = cfg.hidden
    d = cfg.head_hidden
    n = cfg.max_nodes
    grid = n // bn

    weights = h * d + d + 2 * (d * d + d)
    if not backward:
        vmem = F32 * (bn * h + weights + 4 * bn * d)
        matmuls = [
            MatmulShape("trunk_1 (h @ w1)", bn, h, d),
            MatmulShape("trunk_2 (z1 @ w2)", bn, d, d),
            MatmulShape("trunk_3 (z2 @ w3)", bn, d, d),
        ]
        hbm = F32 * (n * h + weights + 4 * n * d)
        return KernelReport("mlp_head_fwd", grid, vmem, matmuls, hbm)

    vmem = F32 * (
        bn * h + 4 * bn * d        # h, a1..a3, dz tiles
        + (h * d + 2 * d * d)      # w1..w3
        + bn * h                   # dh tile
        + (h * d + d + 2 * (d * d + d))  # grad accumulators
    )
    matmuls = [
        MatmulShape("da2 (da3 @ w3^T)", bn, d, d),
        MatmulShape("da1 (da2 @ w2^T)", bn, d, d),
        MatmulShape("dh (da1 @ w1^T)", bn, d, h),
        MatmulShape("dw3 (z2^T @ da3)", d, bn, d),
        MatmulShape("dw2 (z1^T @ da2)", d, bn, d),
        MatmulShape("dw1 (h^T @ da1)", h, bn, d),
    ]
    hbm = F32 * (n * (h + 4 * d) + (h * d + 2 * d * d) + n * h + weights)
    return KernelReport("mlp_head_bwd", grid, vmem, matmuls, hbm)


def sweep_block_sizes(cfg: ModelConfig):
    """The L1 optimization loop: evaluate candidate tilings and pick the
    best (max MXU utilization subject to the VMEM budget)."""
    rows = []
    for be in (64, 128, 256, 512, 1024, 2048):
        if cfg.max_edges % be:
            continue
        c = dataclasses.replace(cfg, block_edges=be)
        r = egnn_message_report(c)
        rows.append((be, r.vmem_bytes, r.mxu_utilization, r.intensity,
                     r.vmem_bytes <= VMEM_BYTES))
    return rows


def hlo_histogram(path: str):
    """Count HLO opcodes + fusions in a lowered artifact (L2 audit)."""
    ops = collections.Counter()
    with open(path) as f:
        for line in f:
            m = re.search(r"=\s+\S+\s+([a-z0-9-]+)\(", line)
            if m:
                ops[m.group(1)] += 1
    return ops


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifacts", default="../artifacts")
    args = ap.parse_args()
    cfg = DEFAULT

    print("=== L1 structural performance analysis (TPU estimates) ===\n")
    for rep in (
        egnn_message_report(cfg),
        mlp_head_report(cfg, backward=False),
        mlp_head_report(cfg, backward=True),
    ):
        print(rep.render())
        print()

    print("=== block_edges sweep (egnn_message) ===")
    print(f"{'block':>6} {'VMEM KiB':>10} {'MXU util':>9} {'FLOP/B':>8} {'fits':>5}")
    best = None
    # Tie-break on utilization by preferring the LARGEST block that stays
    # under 25% of VMEM: fewer grid steps (less per-step overhead) while
    # leaving room for double-buffering the next tile's DMA.
    double_buffer_cap = VMEM_BYTES // 4
    for be, vmem, util, inten, fits in sweep_block_sizes(cfg):
        print(f"{be:>6} {vmem / 1024:>10.0f} {100 * util:>8.1f}% {inten:>8.1f} {str(fits):>5}")
        grid = cfg.max_edges // be
        # grid >= 2 keeps the DMA/compute pipeline alive; grid == 1 has
        # nothing to overlap with.
        if vmem <= double_buffer_cap and grid >= 2 and (best is None or util >= best[1]):
            best = (be, util)
    print(
        f"-> selected block_edges={best[0]} "
        f"(max MXU util, largest tile under the 25% double-buffer cap)\n"
    )

    print("=== paper-config projection (hidden=866, head=889) ===")
    from .config import ModelConfig as MC
    paper = MC(
        max_nodes=1024, max_edges=8192, max_graphs=32,
        hidden=866 + 6, num_layers=4, head_hidden=889 + 7,
        block_edges=512, block_nodes=128,
    )  # +pad to multiples of 8 for the tile math
    rep = egnn_message_report(paper)
    print(
        f"egnn_message at paper width: MXU util "
        f"{100 * rep.mxu_utilization:.1f}% "
        f"(vs {100 * egnn_message_report(cfg).mxu_utilization:.1f}% at CPU-test width 64)\n"
    )

    print("=== L2 HLO audit ===")
    for name in ("train_step", "eval_step", "fwd"):
        path = os.path.join(args.artifacts, f"{name}.hlo.txt")
        if not os.path.exists(path):
            continue
        ops = hlo_histogram(path)
        total = sum(ops.values())
        fusions = ops.get("fusion", 0)
        dots = ops.get("dot", 0)
        top = ", ".join(f"{k}:{v}" for k, v in ops.most_common(6))
        print(f"{name:<12} {total:>5} ops | dot {dots:>3} | fusion {fusions:>3} | {top}")


if __name__ == "__main__":
    main()
