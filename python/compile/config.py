"""Static model / batch configuration shared by kernels, model, AOT and tests.

Every shape in the exported HLO artifacts is fixed at lowering time; the rust
coordinator reads the same numbers back from ``artifacts/manifest.json`` and
pads every batch to them. The defaults are sized for the CPU PJRT client used
in tests; the paper configuration (4 layers, 866 hidden, 3x889 heads) is only
used analytically by the rust-side memory / scaling model.
"""

from dataclasses import dataclass, asdict, field


@dataclass(frozen=True)
class ModelConfig:
    """HydraGNN-style model dimensions (one shared encoder + one branch).

    The exported train-step artifact covers a *single* branch: under
    multi-task parallelism each process executes the artifact with its own
    branch's parameter values, so one executable serves every head.
    """

    # --- static batch geometry (padded) ---
    max_nodes: int = 256          # N: atoms per padded batch
    max_edges: int = 2048         # E: directed edges per padded batch
    max_graphs: int = 16          # G: structures per padded batch

    # --- encoder (shared MPNN layers) ---
    num_species: int = 96         # 0 is the padding species
    hidden: int = 64              # H: node feature width
    num_layers: int = 4           # EGNN message-passing layers (paper: 4)
    num_rbf: int = 16             # radial basis features per edge
    cutoff: float = 6.0           # radial cutoff (Angstrom) baked into RBF

    # --- per-dataset branch (two-level MTL: trunk -> {energy, force}) ---
    head_hidden: int = 64         # width of the 3 FC trunk layers (paper: 889)
    head_layers: int = 3          # paper: three fully-connected layers

    # --- loss weights ---
    # Energy-dominant weighting: per-atom energies carry the multi-fidelity
    # reference-shift signal the MTL heads must absorb (Tables 1-2); forces
    # are kept as a secondary task so the equivariant channel still trains.
    energy_weight: float = 10.0
    force_weight: float = 1.0

    # --- pallas block sizes (L1 tiling; see DESIGN.md section Hardware-Adaptation) ---
    # block_edges selected by the perf sweep (python -m compile.perf):
    # largest tile with grid >= 2 under the 25%-of-VMEM double-buffer cap —
    # identical MXU utilization to smaller tiles but 4x fewer grid steps.
    block_edges: int = 1024       # edges per VMEM tile in the message kernel
    block_nodes: int = 128        # nodes per VMEM tile in the head kernel

    def __post_init__(self) -> None:
        assert self.max_edges % self.block_edges == 0, "E must tile by block_edges"
        assert self.max_nodes % self.block_nodes == 0, "N must tile by block_nodes"
        assert self.hidden % 8 == 0, "hidden should be MXU-lane friendly"

    @property
    def edge_in(self) -> int:
        """Input width of the edge MLP: [h_src, h_dst, rbf(dist)]."""
        return 2 * self.hidden + self.num_rbf

    @property
    def node_in(self) -> int:
        """Input width of the node-update MLP: [h, aggregated message]."""
        return 2 * self.hidden

    def to_dict(self) -> dict:
        return asdict(self)


@dataclass(frozen=True)
class PaperConfig:
    """The paper's published configuration (Section 5), used by the rust
    scaling model for exact parameter counts — never lowered on CPU."""

    hidden: int = 866
    num_layers: int = 4
    head_hidden: int = 889
    head_layers: int = 3
    num_datasets: int = 5


DEFAULT = ModelConfig()

# A tiny config for fast unit tests (pytest + hypothesis sweeps).
TINY = ModelConfig(
    max_nodes=32,
    max_edges=64,
    max_graphs=4,
    hidden=16,
    num_layers=2,
    num_rbf=8,
    head_hidden=16,
    block_edges=32,
    block_nodes=16,
)
