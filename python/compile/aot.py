"""AOT lowering: jax model -> HLO *text* artifacts + manifest.json.

This is the only place Python touches the system: ``make artifacts`` runs it
once, and the rust coordinator consumes the outputs forever after.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/README.md.

Exported artifacts (all shapes static, one branch per executable — under
multi-task parallelism each process feeds its own branch parameters):

  train_step.hlo.txt   (params, batch) -> {grads, loss, mae_e, mae_f}
  eval_step.hlo.txt    (params, batch) -> {loss, mae_e, mae_f}
  fwd.hlo.txt          (params, batch) -> {energy, forces}
  encoder_fwd.hlo.txt  (enc_params, batch) -> {h, v}

manifest.json records the flattened input/output order (pytree flatten
order: dict keys sorted), every shape/dtype, and the initializer metadata the
rust side needs to build parameter tensors without jax.
"""

import argparse
import dataclasses
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .config import DEFAULT, ModelConfig


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def _leaf_meta(path, leaf):
    name = _path_str(path)
    shape = list(leaf.shape)
    dtype = jnp.dtype(leaf.dtype).name
    meta = {"name": name, "shape": shape, "dtype": dtype}
    # Initializer hint for the rust side (params only; harmless on batch).
    last = name.rsplit(".", 1)[-1]
    if last == "embed":
        meta["init"] = {"kind": "normal", "scale": 0.5}
    elif len(shape) == 2 and last.startswith("w"):
        meta["init"] = {"kind": "lecun", "fan_in": shape[0]}
    elif last.startswith("b"):
        meta["init"] = {"kind": "zeros"}
    return meta


def _flat_meta(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [_leaf_meta(path, leaf) for path, leaf in leaves]


def _spec_tree(tree):
    """Concrete pytree -> ShapeDtypeStruct pytree."""
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


def export_artifacts(cfg: ModelConfig, out_dir: str, quiet: bool = False):
    os.makedirs(out_dir, exist_ok=True)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key, cfg)
    param_spec = _spec_tree(params)
    batch = model.batch_spec(cfg)

    fns = {
        "train_step": (model.make_train_step(cfg), (param_spec, batch)),
        "eval_step": (model.make_eval_step(cfg), (param_spec, batch)),
        "fwd": (model.make_forward(cfg), (param_spec, batch)),
        "encoder_fwd": (
            model.make_encoder_forward(cfg),
            (param_spec["encoder"], batch),
        ),
    }

    manifest = {
        "version": 1,
        "config": cfg.to_dict(),
        "params": _flat_meta(params),
        "encoder_params": _flat_meta(params["encoder"]),
        "branch_params": _flat_meta(params["branch"]),
        "batch": _flat_meta(batch),
        "artifacts": {},
    }

    for name, (fn, args) in fns.items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_spec = jax.eval_shape(fn, *args)
        # jax DCEs unused flat inputs at lowering (e.g. fwd ignores the
        # label fields); the manifest must list only the *kept* parameters,
        # in order, or the rust marshaller supplies too many buffers.
        all_inputs = sum((_flat_meta(a) for a in args), [])
        kept = getattr(lowered._lowering, "compile_args", {}).get("kept_var_idx")
        if kept is not None:
            kept_inputs = [all_inputs[i] for i in sorted(kept)]
        else:
            kept_inputs = all_inputs
        entry = {
            "file": fname,
            "inputs": kept_inputs,
            "outputs": _flat_meta(out_spec),
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "hlo_bytes": len(text),
        }
        manifest["artifacts"][name] = entry
        if not quiet:
            print(
                f"wrote {fname}: {len(text)} chars, "
                f"{len(entry['inputs'])} inputs, {len(entry['outputs'])} outputs"
            )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    if not quiet:
        print(f"wrote manifest.json ({len(manifest['params'])} param leaves)")
    return manifest


def parse_overrides(pairs):
    out = {}
    if not pairs:
        return out
    fields = {f.name: f.type for f in dataclasses.fields(ModelConfig)}
    for pair in pairs:
        k, v = pair.split("=", 1)
        if k not in fields:
            raise SystemExit(f"unknown config field: {k}")
        typ = fields[k]
        out[k] = float(v) if typ is float else int(v)
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--set",
        nargs="*",
        metavar="KEY=VAL",
        help="override ModelConfig fields, e.g. --set hidden=32 max_nodes=128",
    )
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    overrides = parse_overrides(args.set)
    cfg = dataclasses.replace(DEFAULT, **overrides) if overrides else DEFAULT
    export_artifacts(cfg, args.out, quiet=args.quiet)


if __name__ == "__main__":
    main()
