"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal: pytest asserts the Pallas kernels
(interpret=True) match these references to tight tolerances across shape /
seed sweeps, and that custom-VJP gradients match jax.grad through these.
"""

import jax
import jax.numpy as jnp


def silu(x):
    """NaN-safe silu.

    Uses jax.nn.sigmoid (the XLA logistic primitive) rather than a
    hand-rolled `where(exp(...))` split: the where-trick leaves an
    overflowing exp in the unselected branch whose backward chain produces
    inf/inf = NaN that the select's zero cotangent cannot cancel (0 * NaN).
    """
    return x * jax.nn.sigmoid(x)


def dsilu(a):
    """Derivative of silu wrt its pre-activation."""
    s = jax.nn.sigmoid(a)
    return s * (1.0 + a * (1.0 - s))


def egnn_message_ref(h_src, h_dst, rbf, rel_hat, dst, emask, params, num_nodes):
    """Reference for the fused EGNN edge-message kernel.

    Args:
      h_src:   (E, H)  gathered source-node features
      h_dst:   (E, H)  gathered destination-node features
      rbf:     (E, R)  radial basis expansion of edge length
      rel_hat: (E, 3)  unit relative position vectors (src - dst)
      dst:     (E,)    destination node index of each edge (int32)
      emask:   (E, 1)  1.0 for real edges, 0.0 for padding
      params:  dict with w1 (2H+R, H), b1 (H,), w2 (H, H), b2 (H,),
               wg (H, 1), bg (1,)
      num_nodes: N, static

    Returns:
      m:    (E, H)  per-edge messages (masked)
      hagg: (N, H)  per-node scatter-add of messages
      vagg: (N, 3)  per-node equivariant vector aggregation
    """
    x = jnp.concatenate([h_src, h_dst, rbf], axis=1)
    u = silu(x @ params["w1"] + params["b1"])
    m = silu(u @ params["w2"] + params["b2"]) * emask
    gate = jnp.tanh(m @ params["wg"] + params["bg"])  # (E, 1)
    onehot = (
        jnp.arange(num_nodes, dtype=jnp.int32)[:, None] == dst[None, :]
    ).astype(h_src.dtype) * emask[:, 0][None, :]       # (N, E)
    hagg = onehot @ m
    vagg = onehot @ (rel_hat * gate * emask)
    return m, hagg, vagg


def mlp_head_ref(h, params):
    """Reference for the fused 3-layer branch-trunk MLP (per node).

    Args:
      h: (N, H)
      params: dict with w1 (H, D), b1 (D,), w2 (D, D), b2 (D,),
              w3 (D, D), b3 (D,)

    Returns: z (N, D)
    """
    z = silu(h @ params["w1"] + params["b1"])
    z = silu(z @ params["w2"] + params["b2"])
    z = silu(z @ params["w3"] + params["b3"])
    return z


def rbf_expand(dist, num_rbf, cutoff):
    """Gaussian radial basis expansion with a smooth cosine cutoff envelope.

    dist: (E,) -> (E, num_rbf). Padded edges carry dist=0 and are masked by
    the caller; the envelope also kills anything past the cutoff.
    """
    centers = jnp.linspace(0.0, cutoff, num_rbf, dtype=dist.dtype)
    gamma = (num_rbf / cutoff) ** 2
    g = jnp.exp(-gamma * (dist[:, None] - centers[None, :]) ** 2)
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(dist / cutoff, 0.0, 1.0)) + 1.0)
    return g * env[:, None]
