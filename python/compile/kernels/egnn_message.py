"""Fused EGNN edge-message Pallas kernel (L1 hot spot #1).

One pallas_call fuses, per tile of ``block_edges`` edges:

  1. the two-layer edge MLP on [h_src | h_dst | rbf(dist)],
  2. the tanh gate that scales the equivariant vector channel, and
  3. the scatter-add aggregation of both message and vector streams into
     per-node accumulators.

Hardware adaptation (see DESIGN.md): on GPU this scatter is an atomicAdd per
edge; on TPU we express it as a masked one-hot matmul
``(N, BLOCK_E) @ (BLOCK_E, H)`` so accumulation stays in VMEM and runs on the
MXU. The grid walks edge tiles; the two node-indexed outputs use a constant
index map so every grid step revisits (and accumulates into) the same block.

interpret=True is mandatory here: the CPU PJRT client cannot execute Mosaic
custom-calls. Correctness is asserted against kernels.ref.egnn_message_ref.

Autodiff: pallas_call has no VJP rule, so the public entry point is a
jax.custom_vjp whose forward runs this kernel and whose backward is the exact
closed-form pure-jnp adjoint (lowered into the same HLO artifact — Python is
still never on the request path).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .ref import silu, dsilu


def _kernel(
    h_src_ref, h_dst_ref, rbf_ref, rel_hat_ref, dst_ref, emask_ref,
    w1_ref, b1_ref, w2_ref, b2_ref, wg_ref, bg_ref,
    m_ref, hagg_ref, vagg_ref,
    *, num_nodes: int,
):
    """One grid step: process BLOCK_E edges, accumulate into N-node outputs."""
    h_src = h_src_ref[...]
    h_dst = h_dst_ref[...]
    rbf = rbf_ref[...]
    emask = emask_ref[...]                       # (BE, 1)

    # Edge MLP: two dense layers on the MXU.
    x = jnp.concatenate([h_src, h_dst, rbf], axis=1)
    u = silu(x @ w1_ref[...] + b1_ref[...])
    m = silu(u @ w2_ref[...] + b2_ref[...]) * emask

    # Equivariant gate.
    gate = jnp.tanh(m @ wg_ref[...] + bg_ref[...])        # (BE, 1)
    gv = rel_hat_ref[...] * gate * emask                  # (BE, 3)

    # Masked one-hot scatter: (N, BE) @ (BE, H) on the MXU.
    dst = dst_ref[...]                                    # (BE,)
    iota = jax.lax.broadcasted_iota(jnp.int32, (num_nodes, dst.shape[0]), 0)
    onehot = (iota == dst[None, :]).astype(m.dtype) * emask[:, 0][None, :]

    m_ref[...] = m

    @pl.when(pl.program_id(0) == 0)
    def _init():
        hagg_ref[...] = jnp.zeros_like(hagg_ref)
        vagg_ref[...] = jnp.zeros_like(vagg_ref)

    hagg_ref[...] += onehot @ m
    vagg_ref[...] += onehot @ gv


def egnn_message_fwd_pallas(h_src, h_dst, rbf, rel_hat, dst, emask, params,
                            num_nodes, block_edges):
    """Raw pallas_call wrapper (forward only)."""
    e, h = h_src.shape
    r = rbf.shape[1]
    assert e % block_edges == 0, (e, block_edges)
    grid = (e // block_edges,)
    w1, b1 = params["w1"], params["b1"]
    w2, b2 = params["w2"], params["b2"]
    wg, bg = params["wg"], params["bg"]

    full = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    edge2 = lambda width: pl.BlockSpec((block_edges, width), lambda i: (i, 0))

    m, hagg, vagg = pl.pallas_call(
        functools.partial(_kernel, num_nodes=num_nodes),
        grid=grid,
        in_specs=[
            edge2(h),                                  # h_src
            edge2(h),                                  # h_dst
            edge2(r),                                  # rbf
            edge2(3),                                  # rel_hat
            pl.BlockSpec((block_edges,), lambda i: (i,)),  # dst
            edge2(1),                                  # emask
            full(w1.shape), full(b1.shape),
            full(w2.shape), full(b2.shape),
            full(wg.shape), full(bg.shape),
        ],
        out_specs=[
            edge2(h),                                  # m (per-edge)
            pl.BlockSpec((num_nodes, h), lambda i: (0, 0)),   # hagg (accum)
            pl.BlockSpec((num_nodes, 3), lambda i: (0, 0)),   # vagg (accum)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((e, h), h_src.dtype),
            jax.ShapeDtypeStruct((num_nodes, h), h_src.dtype),
            jax.ShapeDtypeStruct((num_nodes, 3), h_src.dtype),
        ],
        interpret=True,
    )(h_src, h_dst, rbf, rel_hat, dst, emask, w1, b1, w2, b2, wg, bg)
    return m, hagg, vagg


# ---------------------------------------------------------------------------
# custom_vjp entry point
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8))
def egnn_message(h_src, h_dst, rbf, rel_hat, dst, emask, params,
                 num_nodes, block_edges):
    """Differentiable fused edge-message op. See module docstring."""
    return egnn_message_fwd_pallas(
        h_src, h_dst, rbf, rel_hat, dst, emask, params, num_nodes, block_edges
    )


def _fwd(h_src, h_dst, rbf, rel_hat, dst, emask, params, num_nodes, block_edges):
    out = egnn_message_fwd_pallas(
        h_src, h_dst, rbf, rel_hat, dst, emask, params, num_nodes, block_edges
    )
    res = (h_src, h_dst, rbf, rel_hat, dst, emask, params)
    return out, res


def _bwd(num_nodes, block_edges, res, cts):
    """Closed-form adjoint of the fused op (pure jnp, exact).

    Recomputes the cheap forward intermediates (rematerialization — the same
    trade a hand-written GPU backward kernel makes) and propagates:
      d(hagg), d(vagg), d(m) -> d(edge MLP inputs) + d(weights).
    """
    h_src, h_dst, rbf, rel_hat, dst, emask, params = res
    dm_out, dhagg, dvagg = cts
    w1, b1 = params["w1"], params["b1"]
    w2, b2 = params["w2"], params["b2"]
    wg, bg = params["wg"], params["bg"]

    # --- recompute forward intermediates ---
    x = jnp.concatenate([h_src, h_dst, rbf], axis=1)
    a1 = x @ w1 + b1
    u = silu(a1)
    a2 = u @ w2 + b2
    m = silu(a2) * emask
    ag = m @ wg + bg
    gate = jnp.tanh(ag)

    # --- scatter adjoints: gather the node cotangents back to edges ---
    # hagg = onehot @ m  =>  dm += onehot^T @ dhagg = dhagg[dst] (masked)
    dm = dm_out + dhagg[dst] * emask
    # vagg = onehot @ (rel_hat * gate * emask)
    dgv = dvagg[dst] * emask                              # (E, 3)
    dgate = jnp.sum(dgv * rel_hat, axis=1, keepdims=True) * emask
    # (rel_hat is input geometry — not differentiated; positions are fixed
    #  inputs in this architecture, forces come from the vector channel.)

    # --- gate adjoint ---
    dag = dgate * (1.0 - gate**2)
    dwg = m.T @ dag
    dbg = jnp.sum(dag, axis=0)
    dm = dm + dag @ wg.T

    # --- edge MLP adjoint ---
    da2 = dm * emask * dsilu(a2)
    dw2 = u.T @ da2
    db2 = jnp.sum(da2, axis=0)
    du = da2 @ w2.T
    da1 = du * dsilu(a1)
    dw1 = x.T @ da1
    db1 = jnp.sum(da1, axis=0)
    dx = da1 @ w1.T

    h = h_src.shape[1]
    dh_src = dx[:, :h]
    dh_dst = dx[:, h : 2 * h]
    drbf = dx[:, 2 * h :]

    dparams = {"w1": dw1, "b1": db1, "w2": dw2, "b2": db2, "wg": dwg, "bg": dbg}
    zeros_rel = jnp.zeros_like(rel_hat)
    zeros_emask = jnp.zeros_like(emask)
    # dst is integer-typed: its cotangent is the symbolic float0 zero.
    ddst = np.zeros(dst.shape, dtype=jax.dtypes.float0)
    return (dh_src, dh_dst, drbf, zeros_rel, ddst, zeros_emask, dparams)


egnn_message.defvjp(_fwd, _bwd)
