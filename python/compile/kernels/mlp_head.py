"""Fused 3-layer branch-trunk MLP Pallas kernels (L1 hot spot #2).

The per-dataset branch of the two-level MTL architecture applies three
fully-connected silu layers to every node embedding (paper: 3 x 889 units).
Both the forward and the backward pass are hand-written Pallas kernels:

  forward : grid over node tiles; three chained matmuls stay in VMEM, and
            the pre-activations are emitted as residuals for the backward.
  backward: grid over node tiles; per-tile weight-gradient contributions are
            accumulated across grid steps via constant-index-map outputs
            (the TPU analogue of a grid-stride atomicAdd reduction).

interpret=True is mandatory (CPU PJRT cannot run Mosaic custom-calls); the
numerics are asserted against kernels.ref.mlp_head_ref and jax.grad of it.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import silu, dsilu


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _fwd_kernel(h_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref,
                z_ref, a1_ref, a2_ref, a3_ref):
    h = h_ref[...]
    a1 = h @ w1_ref[...] + b1_ref[...]
    z1 = silu(a1)
    a2 = z1 @ w2_ref[...] + b2_ref[...]
    z2 = silu(a2)
    a3 = z2 @ w3_ref[...] + b3_ref[...]
    z_ref[...] = silu(a3)
    a1_ref[...] = a1
    a2_ref[...] = a2
    a3_ref[...] = a3


def mlp_head_fwd_pallas(h, params, block_nodes):
    n, hdim = h.shape
    d = params["w1"].shape[1]
    assert n % block_nodes == 0, (n, block_nodes)
    grid = (n // block_nodes,)
    full = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    tile = lambda width: pl.BlockSpec((block_nodes, width), lambda i: (i, 0))

    z, a1, a2, a3 = pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            tile(hdim),
            full(params["w1"].shape), full(params["b1"].shape),
            full(params["w2"].shape), full(params["b2"].shape),
            full(params["w3"].shape), full(params["b3"].shape),
        ],
        out_specs=[tile(d), tile(d), tile(d), tile(d)],
        out_shape=[jax.ShapeDtypeStruct((n, d), h.dtype) for _ in range(4)],
        interpret=True,
    )(h, params["w1"], params["b1"], params["w2"], params["b2"],
      params["w3"], params["b3"])
    return z, (a1, a2, a3)


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------

def _bwd_kernel(h_ref, a1_ref, a2_ref, a3_ref, dz_ref,
                w1_ref, w2_ref, w3_ref,
                dh_ref, dw1_ref, db1_ref, dw2_ref, db2_ref, dw3_ref, db3_ref):
    h = h_ref[...]
    a1, a2, a3 = a1_ref[...], a2_ref[...], a3_ref[...]
    z1, z2 = silu(a1), silu(a2)

    da3 = dz_ref[...] * dsilu(a3)
    da2 = (da3 @ w3_ref[...].T) * dsilu(a2)
    da1 = (da2 @ w2_ref[...].T) * dsilu(a1)
    dh_ref[...] = da1 @ w1_ref[...].T

    @pl.when(pl.program_id(0) == 0)
    def _init():
        dw1_ref[...] = jnp.zeros_like(dw1_ref)
        db1_ref[...] = jnp.zeros_like(db1_ref)
        dw2_ref[...] = jnp.zeros_like(dw2_ref)
        db2_ref[...] = jnp.zeros_like(db2_ref)
        dw3_ref[...] = jnp.zeros_like(dw3_ref)
        db3_ref[...] = jnp.zeros_like(db3_ref)

    dw3_ref[...] += z2.T @ da3
    db3_ref[...] += jnp.sum(da3, axis=0)
    dw2_ref[...] += z1.T @ da2
    db2_ref[...] += jnp.sum(da2, axis=0)
    dw1_ref[...] += h.T @ da1
    db1_ref[...] += jnp.sum(da1, axis=0)


def mlp_head_bwd_pallas(h, residuals, dz, params, block_nodes):
    a1, a2, a3 = residuals
    n, hdim = h.shape
    d = params["w1"].shape[1]
    grid = (n // block_nodes,)
    full = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    tile = lambda width: pl.BlockSpec((block_nodes, width), lambda i: (i, 0))

    outs = pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            tile(hdim), tile(d), tile(d), tile(d), tile(d),
            full(params["w1"].shape),
            full(params["w2"].shape),
            full(params["w3"].shape),
        ],
        out_specs=[
            tile(hdim),
            full(params["w1"].shape), full(params["b1"].shape),
            full(params["w2"].shape), full(params["b2"].shape),
            full(params["w3"].shape), full(params["b3"].shape),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, hdim), h.dtype),
            jax.ShapeDtypeStruct(params["w1"].shape, h.dtype),
            jax.ShapeDtypeStruct(params["b1"].shape, h.dtype),
            jax.ShapeDtypeStruct(params["w2"].shape, h.dtype),
            jax.ShapeDtypeStruct(params["b2"].shape, h.dtype),
            jax.ShapeDtypeStruct(params["w3"].shape, h.dtype),
            jax.ShapeDtypeStruct(params["b3"].shape, h.dtype),
        ],
        interpret=True,
    )(h, a1, a2, a3, dz, params["w1"], params["w2"], params["w3"])
    dh, dw1, db1, dw2, db2, dw3, db3 = outs
    dparams = {"w1": dw1, "b1": db1, "w2": dw2, "b2": db2, "w3": dw3, "b3": db3}
    return dh, dparams


# ---------------------------------------------------------------------------
# custom_vjp entry point
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def mlp_head(h, params, block_nodes):
    """Differentiable fused 3-layer trunk MLP. See module docstring."""
    z, _ = mlp_head_fwd_pallas(h, params, block_nodes)
    return z


def _fwd(h, params, block_nodes):
    z, residuals = mlp_head_fwd_pallas(h, params, block_nodes)
    return z, (h, residuals, params)


def _bwd(block_nodes, res, dz):
    h, residuals, params = res
    dh, dparams = mlp_head_bwd_pallas(h, residuals, dz, params, block_nodes)
    return dh, dparams


mlp_head.defvjp(_fwd, _bwd)
