"""L1 Pallas kernels (build-time only; lowered into the HLO artifacts)."""

from .egnn_message import egnn_message, egnn_message_fwd_pallas
from .mlp_head import mlp_head, mlp_head_fwd_pallas, mlp_head_bwd_pallas
from . import ref

__all__ = [
    "egnn_message",
    "egnn_message_fwd_pallas",
    "mlp_head",
    "mlp_head_fwd_pallas",
    "mlp_head_bwd_pallas",
    "ref",
]
