//! Featurize-once accounting, isolated in its own test binary: the
//! radius-graph call counter is process-global, and any other test running
//! concurrently in the same process would bump it. Keep this file to this
//! single test.

use hydra_mtp::data::batch::{BatchDims, BatchPool};
use hydra_mtp::data::featurized::FeaturizedStore;
use hydra_mtp::data::generators::{DatasetGenerator, GeneratorConfig};
use hydra_mtp::data::graph::radius_graph_call_count;
use hydra_mtp::data::structures::DatasetId;
use hydra_mtp::data::DDStore;

#[test]
fn warm_epoch_planning_performs_zero_radius_graph_calls() {
    let mut g = DatasetGenerator::new(
        DatasetId::Ani1x,
        11,
        GeneratorConfig { max_atoms: 12, ..Default::default() },
    );
    let ss = g.take(40);
    let n = ss.len() as u64;
    let store = DDStore::new(ss, 2);

    // Build featurizes every structure exactly once (across worker threads).
    let c0 = radius_graph_call_count();
    let fstore = FeaturizedStore::build(store, 6.0);
    let c1 = radius_graph_call_count();
    assert_eq!(c1 - c0, n, "featurize-once: exactly one graph per structure");

    // Every later epoch, on every rank, is pure shuffle + pack: the counter
    // must not move.
    let dims = BatchDims { max_nodes: 64, max_edges: 512, max_graphs: 8 };
    let mut pool = BatchPool::new();
    let mut planned = 0usize;
    for rank in 0..2 {
        for epoch in 0..3u64 {
            let batches =
                fstore.plan_epoch_batches(rank, 2, dims, 1_000 + epoch, &mut pool);
            planned += batches.iter().map(|b| b.n_graphs).sum::<usize>();
            pool.recycle(batches);
        }
    }
    assert_eq!(planned as u64, 3 * n, "every sample reaches a batch each epoch");
    assert_eq!(
        radius_graph_call_count(),
        c1,
        "warm epoch planning must never re-featurize"
    );
    assert!(pool.pooled() > 0, "epoch batches are recycled through the pool");
    let (local, remote) = fstore.stats();
    assert_eq!(local + remote, 3 * n, "every planned access is counted");
}
