//! Integration: the chaos harness — deterministic fault injection and the
//! recovery machinery it exercises.
//!
//! Four headline properties:
//!
//! 1. a rank panic surfaces on its peers as a **typed**
//!    [`CommError::RankFailure`] naming the dead rank, never a deadlock;
//! 2. a mid-training rank kill under [`Trainer::train_with_recovery`]
//!    resumes from the latest CRC-valid checkpoint (skipping a corrupted
//!    one) and finishes with parameters **bit-identical** to a fault-free
//!    run;
//! 3. an injected non-finite loss is skipped and counted
//!    (`skipped_batches`), and training still descends;
//! 4. an injected serve-worker panic answers every in-flight request with
//!    [`ServeError::Internal`] (no stranded waiters), the worker respawns,
//!    and subsequent requests stay bit-identical to `predict_one`.
//!
//! Fault plans are passed programmatically (`cfg.fault.spec` /
//! `FaultPlan::parse`), never via `HYDRA_MTP_FAULTS` — tests run in
//! parallel and must not race on process-wide env state. The env path is
//! exercised by the CI `chaos-release` job's CLI invocations.

use std::sync::Arc;
use std::time::Duration;

use hydra_mtp::comm::{Comm, CommError};
use hydra_mtp::config::{RunConfig, TrainMode};
use hydra_mtp::coordinator::{DataBundle, Heads, TrainedModel, Trainer};
use hydra_mtp::data::generators::{DatasetGenerator, GeneratorConfig};
use hydra_mtp::data::structures::DatasetId;
use hydra_mtp::fault::FaultPlan;
use hydra_mtp::model::params::ParamSet;
use hydra_mtp::runtime::{Engine, ManifestConfig, Precision};
use hydra_mtp::serve::loadtest::synthetic_model;
use hydra_mtp::serve::{ServeError, Server};
use hydra_mtp::session::Predictor;
use hydra_mtp::tensor::DType;

// ---------------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------------

fn engine() -> Arc<Engine> {
    use std::sync::OnceLock;
    static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            let e = Engine::load("artifacts").expect("engine loads on every machine");
            eprintln!("chaos tests run on the '{}' backend", e.backend_name());
            Arc::new(e)
        })
        .clone()
}

fn tiny_config(mode: TrainMode, epochs: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.mode = mode;
    cfg.parallel.replicas = 1;
    cfg.train.epochs = epochs;
    cfg.train.patience = 0;
    cfg.data.per_dataset = 40;
    cfg.data.max_atoms = 10;
    cfg
}

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("hydra_mtp_chaos_{}_{name}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn assert_params_bits_eq(a: &ParamSet, b: &ParamSet, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: leaf count");
    for ((na, ta), (nb, tb)) in a.iter().zip(b.iter()) {
        assert_eq!(na, nb, "{what}: leaf name");
        match ta.dtype() {
            DType::F32 => {
                let (xa, xb) = (ta.as_f32(), tb.as_f32());
                assert_eq!(xa.len(), xb.len(), "{what}: {na} numel");
                for (i, (x, y)) in xa.iter().zip(xb).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{what}: {na}[{i}]: {x} vs {y} (bitwise)"
                    );
                }
            }
            DType::I32 => assert_eq!(ta.as_i32(), tb.as_i32(), "{what}: {na}"),
        }
    }
}

fn assert_models_bits_eq(a: &TrainedModel, b: &TrainedModel) {
    assert_params_bits_eq(&a.encoder, &b.encoder, "encoder");
    match (&a.heads, &b.heads) {
        (Heads::Shared(x), Heads::Shared(y)) => assert_params_bits_eq(x, y, "shared head"),
        (Heads::PerDataset(x), Heads::PerDataset(y)) => {
            assert_eq!(x.len(), y.len(), "head count");
            for (d, bx) in x {
                assert_params_bits_eq(bx, &y[d], &format!("head {}", d.name()));
            }
        }
        _ => panic!("heads kind mismatch"),
    }
}

// ---------------------------------------------------------------------------
// 1. rank death surfaces as a typed error, never a deadlock
// ---------------------------------------------------------------------------

#[test]
fn rank_panic_surfaces_as_typed_rank_failure_on_peers() {
    // Three group members with a bounded collective timeout. Member 0
    // panics while holding a member guard; 1 and 2 sit in an allreduce.
    // The guard's drop poisons the group, so both peers must return
    // Err(RankFailure { rank: 0 }) promptly — not hang, not time out.
    let comms = Comm::group_with(3, Duration::from_secs(10), None);
    let results: Vec<Result<Result<(), CommError>, String>> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = comms
                .into_iter()
                .enumerate()
                .map(|(rank, c)| {
                    scope.spawn(move || {
                        let guard = c.member_guard();
                        if rank == 0 {
                            panic!("injected fault: rank 0 dies before the collective");
                        }
                        let mut data = vec![rank as f32; 64];
                        let out = c.allreduce_mean(&mut data);
                        if out.is_ok() {
                            guard.disarm();
                        }
                        out
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().map_err(|_| "panicked".to_string()))
                .collect()
        });

    assert!(results[0].is_err(), "rank 0 must have panicked");
    for (rank, r) in results.iter().enumerate().skip(1) {
        match r {
            Ok(Err(CommError::RankFailure { rank: dead })) => {
                assert_eq!(*dead, 0, "peer {rank} must name the dead rank");
            }
            other => panic!("peer {rank}: expected RankFailure {{ rank: 0 }}, got {other:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// 2. rank kill + corrupt checkpoint -> recovery, bit-identical to fault-free
// ---------------------------------------------------------------------------

#[test]
fn recovery_from_rank_kill_is_bit_identical_to_fault_free_run() {
    let e = engine();
    let datasets = [DatasetId::Ani1x];
    let epochs = 4;

    // Reference: fault-free, uninterrupted.
    let mut cfg_ref = tiny_config(TrainMode::Single(DatasetId::Ani1x), epochs);
    cfg_ref.parallel.replicas = 2;
    let data = DataBundle::generate(&cfg_ref.data, &datasets);
    let reference = Trainer::new(Arc::clone(&e), cfg_ref.clone()).train(&data).unwrap();

    // Chaos run: checkpoints every epoch; the file written after epoch 1
    // (epoch_0002.ckpt) is corrupted on disk, then rank 1 is killed at the
    // start of epoch 2. Recovery must warn-and-skip the corrupt file,
    // resume from epoch_0001.ckpt, and (fire-once faults) run clean to the
    // end. The final model must match the reference to the last bit.
    let dir = tmp_dir("recovery");
    let mut cfg = cfg_ref.clone();
    cfg.checkpoint.dir = Some(dir.to_string_lossy().into_owned());
    cfg.fault.spec =
        Some("corrupt-ckpt@epoch=2;rank-panic@rank=1,epoch=2,step=0".to_string());
    cfg.fault.max_restarts = 2;
    cfg.fault.comm_timeout_ms = 10_000;
    let recovered = Trainer::new(Arc::clone(&e), cfg).train_with_recovery(&data).unwrap();

    assert_models_bits_eq(&recovered.model, &reference.model);
    assert_eq!(recovered.log.epochs.len(), reference.log.epochs.len());
    for (ea, eb) in recovered.log.epochs.iter().zip(&reference.log.epochs) {
        assert_eq!(ea.steps, eb.steps, "epoch {}", ea.epoch);
        assert_eq!(
            ea.train_loss.to_bits(),
            eb.train_loss.to_bits(),
            "epoch {} train_loss",
            ea.epoch
        );
        assert_eq!(ea.val_loss.to_bits(), eb.val_loss.to_bits(), "epoch {}", ea.epoch);
        assert_eq!(ea.skipped_batches, 0, "no skips in either run");
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn recovery_gives_up_after_max_restarts_with_the_typed_cause() {
    // A panic re-injected on every attempt (one entry per attempt, all at
    // the same coordinates a restart-from-scratch replays) must exhaust
    // max_restarts and surface the rank failure, not loop forever.
    let e = engine();
    let mut cfg = tiny_config(TrainMode::Single(DatasetId::Qm7x), 2);
    cfg.parallel.replicas = 2;
    // No checkpoint dir: every retry is a cold restart, so epoch 0 step 0
    // is replayed each time and each entry fires on one attempt.
    cfg.fault.spec = Some(
        "rank-panic@rank=0,epoch=0,step=0;rank-panic@rank=0,epoch=0,step=0"
            .to_string(),
    );
    cfg.fault.max_restarts = 1;
    cfg.fault.comm_timeout_ms = 10_000;
    let data = DataBundle::generate(&cfg.data, &[DatasetId::Qm7x]);
    let err = Trainer::new(e, cfg).train_with_recovery(&data).unwrap_err();
    let failure = err.chain().find_map(|c| c.downcast_ref::<CommError>());
    match failure {
        Some(CommError::RankFailure { rank }) => assert_eq!(*rank, 0),
        other => panic!("expected RankFailure {{ rank: 0 }}, got {other:?}: {err:#}"),
    }
}

// ---------------------------------------------------------------------------
// 3. non-finite loss -> skip + count, training continues
// ---------------------------------------------------------------------------

#[test]
fn injected_nonfinite_loss_is_skipped_counted_and_training_descends() {
    let e = engine();
    let mut cfg = tiny_config(TrainMode::Single(DatasetId::Ani1x), 4);
    cfg.fault.spec = Some("nonfinite@epoch=1,batch=0".to_string());
    let data = DataBundle::generate(&cfg.data, &[DatasetId::Ani1x]);
    let out = Trainer::new(e, cfg).train(&data).unwrap();

    for ep in &out.log.epochs {
        let expect = if ep.epoch == 1 { 1 } else { 0 };
        assert_eq!(
            ep.skipped_batches, expect,
            "epoch {}: skipped_batches",
            ep.epoch
        );
        assert!(ep.train_loss.is_finite(), "epoch {}: loss finite", ep.epoch);
    }
    let first = out.log.epochs.first().unwrap().train_loss;
    let last = out.log.epochs.last().unwrap().train_loss;
    assert!(
        last < first,
        "training must still descend across the skipped batch: {first} -> {last}"
    );
}

#[test]
fn exhausted_skip_budget_aborts_instead_of_training_on_garbage() {
    let e = engine();
    let mut cfg = tiny_config(TrainMode::Single(DatasetId::Ani1x), 2);
    // Two injected NaN batches against a budget of one.
    cfg.fault.spec = Some("nonfinite@epoch=0,batch=0;nonfinite@epoch=0,batch=1".to_string());
    cfg.fault.skip_batch_budget = 1;
    let data = DataBundle::generate(&cfg.data, &[DatasetId::Ani1x]);
    let err = Trainer::new(e, cfg).train(&data).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("skip"), "expected a skip-budget error, got: {msg}");
}

// ---------------------------------------------------------------------------
// 4. serve-worker panic -> Internal answers, respawn, bit-identity restored
// ---------------------------------------------------------------------------

fn small_config() -> ManifestConfig {
    let mut c = ManifestConfig::default_native();
    c.max_nodes = 64;
    c.max_edges = 512;
    c.max_graphs = 8;
    c.hidden = 32;
    c.num_layers = 2;
    c.num_rbf = 8;
    c.head_hidden = 32;
    c
}

#[test]
fn serve_worker_panic_answers_inflight_then_respawns_bit_identical() {
    let e = Arc::new(Engine::native_with(small_config(), Precision::F64));
    let tasks = [DatasetId::Ani1x];
    let model = synthetic_model(&e, &tasks, 7);
    let gen_cfg = GeneratorConfig { max_atoms: 8, ..Default::default() };
    let ss = DatasetGenerator::new(DatasetId::Ani1x, 42, gen_cfg).take(6);

    let plan = Arc::new(FaultPlan::parse("serve-panic@batch=0").unwrap());
    let cfg = hydra_mtp::config::ServeConfig {
        workers: 1,
        queue_capacity: 64,
        enqueue_wait_ms: 5_000,
        latency_budget_ms: 1_000.0,
    };
    let server = Server::start_with_faults(Arc::clone(&e), model.clone(), cfg, plan).unwrap();

    // Sequential requests: the first lands in batch attempt 0, whose
    // worker panics — it must be ANSWERED with the typed internal error,
    // not left waiting on a dead worker's channel.
    match server.predict(&ss[0]) {
        Err(ServeError::Internal(msg)) => {
            assert!(msg.contains("injected fault"), "payload surfaced: {msg}")
        }
        other => panic!("expected Internal for the poisoned batch, got {other:?}"),
    }

    // The worker respawned: every later request succeeds and matches the
    // sequential predict_one path bit for bit.
    let mut seq = Predictor::new(Arc::clone(&e), model);
    for s in &ss[1..] {
        let got = server.predict(s).expect("post-respawn request served");
        let want = seq.predict_one(s).unwrap();
        assert_eq!(got.energy.to_bits(), want.energy.to_bits());
        assert_eq!(got.energy_per_atom.to_bits(), want.energy_per_atom.to_bits());
        assert_eq!(got.forces.len(), want.forces.len());
        for (fa, fb) in got.forces.iter().zip(&want.forces) {
            for k in 0..3 {
                assert_eq!(fa[k].to_bits(), fb[k].to_bits());
            }
        }
    }

    let stats = server.stats();
    server.shutdown();
    assert!(stats.respawned >= 1, "worker recovery counted: {stats:?}");
    assert!(stats.internal_errors >= 1, "internal answers counted: {stats:?}");
    assert_eq!(stats.served, (ss.len() - 1) as u64, "all later requests served");
}

// ---------------------------------------------------------------------------
// guard: a disabled plan changes nothing
// ---------------------------------------------------------------------------

#[test]
fn empty_fault_plan_is_bit_identical_to_no_fault_config() {
    let e = engine();
    let cfg_plain = tiny_config(TrainMode::Single(DatasetId::Qm7x), 2);
    let data = DataBundle::generate(&cfg_plain.data, &[DatasetId::Qm7x]);
    let plain = Trainer::new(Arc::clone(&e), cfg_plain.clone()).train(&data).unwrap();

    // Same run with the fault subsystem explicitly configured but empty:
    // recovery wrapper, empty spec, custom timeout. Zero behavior change.
    let mut cfg = cfg_plain;
    cfg.fault.spec = Some(String::new());
    cfg.fault.max_restarts = 3;
    cfg.fault.comm_timeout_ms = 30_000;
    let wrapped = Trainer::new(e, cfg).train_with_recovery(&data).unwrap();
    assert_models_bits_eq(&wrapped.model, &plain.model);
}
