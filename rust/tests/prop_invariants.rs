//! Property-based tests over the coordinator's substrates (seeded-case
//! harness in `util::prop` — the offline registry has no proptest).
//!
//! Each property runs over dozens of seeded random cases; a failure prints
//! the seed so the exact case replays deterministically.

use hydra_mtp::comm::{build_mesh, Comm, MeshShape};
use hydra_mtp::data::batch::{BatchBuilder, BatchDims};
use hydra_mtp::data::generators::{DatasetGenerator, GeneratorConfig};
use hydra_mtp::data::graph::{radius_graph_brute, radius_graph_positions};
use hydra_mtp::data::split::{Split, SplitSpec};
use hydra_mtp::data::structures::{AtomicStructure, ALL_DATASETS};
use hydra_mtp::data::DDStore;
use hydra_mtp::util::json::Json;
use hydra_mtp::util::prop::{check, forall};
use hydra_mtp::util::rng::Rng;

fn random_structures(rng: &mut Rng, n: usize) -> Vec<AtomicStructure> {
    let d = ALL_DATASETS[rng.below(5)];
    let mut g = DatasetGenerator::new(
        d,
        rng.next_u64(),
        GeneratorConfig { max_atoms: rng.int_range(4, 20), ..Default::default() },
    );
    g.take(n)
}

#[test]
fn prop_batching_conserves_everything() {
    forall(
        "batching conserves atoms/graphs and keeps masks consistent",
        25,
        |rng| {
            let n = rng.int_range(1, 30);
            let dims = BatchDims {
                max_nodes: rng.int_range(32, 128),
                max_edges: rng.int_range(256, 1024),
                max_graphs: rng.int_range(2, 12),
            };
            (random_structures(rng, n), dims)
        },
        |(structures, dims)| {
            let batches = BatchBuilder::build_all(*dims, 6.0, structures);
            let mut builder = BatchBuilder::new(*dims, 6.0);
            let mut skipped = 0usize;
            for s in structures {
                builder.push(s);
                skipped = builder.skipped;
            }
            let total_graphs: usize = batches.iter().map(|b| b.n_graphs).sum();
            check(
                total_graphs + skipped == structures.len(),
                format!("graphs {total_graphs} + skipped {skipped} != {}", structures.len()),
            )?;
            for b in &batches {
                check(b.n_nodes <= dims.max_nodes, "node budget")?;
                check(b.n_edges <= dims.max_edges, "edge budget")?;
                check(
                    b.node_mask.iter().sum::<f32>() as usize == b.n_nodes,
                    "node mask sum",
                )?;
                for e in 0..b.n_edges {
                    let (s, d) = (b.edge_src[e] as usize, b.edge_dst[e] as usize);
                    check(s < b.n_nodes && d < b.n_nodes, "edge endpoints real")?;
                    check(b.node_graph[s] == b.node_graph[d], "edges intra-graph")?;
                }
                // Padding slots must be inert.
                for n in b.n_nodes..dims.max_nodes {
                    check(b.species[n] == 0, "padding species zero")?;
                    check(b.node_mask[n] == 0.0, "padding node mask")?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cell_list_matches_brute_force() {
    forall(
        "cell-list radius graph == O(n^2) reference",
        30,
        |rng| {
            let n = rng.int_range(2, 60);
            let span = rng.range(2.0, 20.0);
            let cutoff = rng.range(1.5, 7.0);
            let pos: Vec<[f64; 3]> = (0..n)
                .map(|_| [rng.range(0.0, span), rng.range(0.0, span), rng.range(0.0, span)])
                .collect();
            (pos, cutoff)
        },
        |(pos, cutoff)| {
            let fast = radius_graph_positions(pos, *cutoff);
            let brute = radius_graph_brute(pos, *cutoff);
            check(fast == brute, format!("{} vs {} edges", fast.len(), brute.len()))
        },
    );
}

#[test]
fn prop_allreduce_mean_is_exact_average() {
    forall(
        "allreduce_mean == per-element average over any group size",
        12,
        |rng| {
            let group = rng.int_range(1, 6);
            let len = rng.int_range(1, 200);
            let data: Vec<Vec<f32>> = (0..group)
                .map(|_| (0..len).map(|_| rng.range(-5.0, 5.0) as f32).collect())
                .collect();
            data
        },
        |data| {
            let group = data.len();
            let comms = Comm::group(group);
            let data2 = data.clone();
            let results: Vec<Vec<f32>> = std::thread::scope(|s| {
                comms
                    .into_iter()
                    .zip(data2)
                    .map(|(c, mut d)| {
                        s.spawn(move || {
                            c.allreduce_mean(&mut d).unwrap();
                            d
                        })
                    })
                    .collect::<Vec<_>>()
                    .into_iter()
                    .map(|h| h.join().unwrap())
                    .collect()
            });
            let len = data[0].len();
            for i in 0..len {
                let expect: f64 =
                    data.iter().map(|d| d[i] as f64).sum::<f64>() / group as f64;
                for r in &results {
                    check(
                        (r[i] as f64 - expect).abs() < 1e-5,
                        format!("elem {i}: {} vs {expect}", r[i]),
                    )?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mesh_coords_bijective() {
    forall(
        "mesh rank <-> (head, replica) is a bijection",
        50,
        |rng| MeshShape {
            num_heads: rng.int_range(1, 8),
            replicas: rng.int_range(1, 8),
        },
        |shape| {
            let mut seen = std::collections::HashSet::new();
            for rank in 0..shape.world_size() {
                let (h, r) = shape.coords(rank);
                check(h < shape.num_heads && r < shape.replicas, "coords in range")?;
                check(shape.rank_of(h, r) == rank, "roundtrip")?;
                check(seen.insert((h, r)), "distinct coords")?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mesh_subgroup_reductions_are_isolated() {
    forall(
        "head sub-groups average independently of each other",
        6,
        |rng| MeshShape {
            num_heads: rng.int_range(2, 4),
            replicas: rng.int_range(1, 3),
        },
        |shape| {
            let ranks = build_mesh(*shape);
            let shape = *shape;
            let results: Vec<(usize, f32)> = std::thread::scope(|s| {
                ranks
                    .into_iter()
                    .map(|mr| {
                        s.spawn(move || {
                            let mut v = vec![(mr.head * 100 + mr.replica) as f32];
                            mr.head_group.allreduce_mean(&mut v).unwrap();
                            (mr.head, v[0])
                        })
                    })
                    .collect::<Vec<_>>()
                    .into_iter()
                    .map(|h| h.join().unwrap())
                    .collect()
            });
            for (head, mean) in results {
                let expect = (head * 100) as f32
                    + (0..shape.replicas).sum::<usize>() as f32 / shape.replicas as f32;
                check(
                    (mean - expect).abs() < 1e-4,
                    format!("head {head}: {mean} vs {expect}"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_split_partitions() {
    forall(
        "split is a deterministic partition with right fractions",
        20,
        |rng| (rng.int_range(100, 4000), rng.next_u64()),
        |&(n, seed)| {
            let spec = SplitSpec::default();
            let tr = spec.indices(n, seed, Split::Train).len();
            let va = spec.indices(n, seed, Split::Val).len();
            let te = spec.indices(n, seed, Split::Test).len();
            check(tr + va + te == n, "partition complete")?;
            check(
                (tr as f64 / n as f64 - 0.8).abs() < 0.08,
                format!("train fraction {}", tr as f64 / n as f64),
            )
        },
    );
}

#[test]
fn prop_ddstore_get_matches_source() {
    forall(
        "ddstore round-robin get returns the original sample",
        10,
        |rng| {
            let world = rng.int_range(1, 6);
            let n = rng.int_range(1, 40);
            (random_structures(rng, n), world)
        },
        |(samples, world)| {
            let store = DDStore::new(samples.clone(), *world);
            for (g, expect) in samples.iter().enumerate() {
                let got = store
                    .get(g % *world, g)
                    .ok_or_else(|| format!("missing sample {g}"))?;
                check(&got == expect, format!("sample {g} mismatch"))?;
            }
            check(store.get(0, samples.len()).is_none(), "oob is none")
        },
    );
}

#[test]
fn prop_gpack_roundtrip() {
    forall(
        "gpack write/read roundtrips arbitrary generated structures",
        8,
        |rng| {
            let n = rng.int_range(1, 25);
            (random_structures(rng, n), rng.next_u64())
        },
        |(samples, tag)| {
            let path = std::env::temp_dir()
                .join(format!("hydra_prop_{}_{tag}.gpack", std::process::id()));
            hydra_mtp::data::pack::write_all(&path, samples).map_err(|e| e.to_string())?;
            let mut r =
                hydra_mtp::data::pack::GPackReader::open(&path).map_err(|e| e.to_string())?;
            let back = r.read_all().map_err(|e| e.to_string())?;
            std::fs::remove_file(&path).ok();
            check(&back == samples, "roundtrip mismatch")
        },
    );
}

#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Int(rng.next_u64() as i64 / 1024),
            1 => Json::Float((rng.range(-1e6, 1e6) * 1e3).round() / 1e3),
            2 => Json::Bool(rng.bool_with(0.5)),
            3 => {
                let n = rng.int_range(0, 12);
                Json::Str((0..n).map(|_| (b'a' + rng.below(26) as u8) as char).collect())
            }
            4 => Json::Array(
                (0..rng.int_range(0, 5)).map(|_| random_json(rng, depth - 1)).collect(),
            ),
            _ => Json::Object(
                (0..rng.int_range(0, 5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall(
        "json serialize/parse roundtrips",
        60,
        |rng| random_json(rng, 3),
        |j| {
            let text = j.to_string();
            let back = Json::parse(&text).map_err(|e| e.to_string())?;
            check(&back == j, format!("roundtrip mismatch: {text}"))
        },
    );
}

#[test]
fn prop_generated_structures_always_valid_and_curated() {
    forall(
        "every generated structure is valid and within curation bounds",
        10,
        |rng| {
            let d = ALL_DATASETS[rng.below(5)];
            let seed = rng.next_u64();
            (d, seed)
        },
        |&(d, seed)| {
            let cfg = GeneratorConfig::default();
            let mut g = DatasetGenerator::new(d, seed, cfg.clone());
            for s in g.take(15) {
                s.validate().map_err(|e| e.to_string())?;
                check(
                    s.energy_per_atom().abs() <= cfg.max_energy_per_atom,
                    format!("energy outlier {}", s.energy_per_atom()),
                )?;
                for f in &s.forces {
                    for x in f {
                        check(x.abs() <= cfg.max_force, format!("force outlier {x}"))?;
                    }
                }
            }
            Ok(())
        },
    );
}
