//! Integration: graph-parallel (domain-decomposed) training with halo
//! exchange.
//!
//! Headline properties:
//!
//! 1. training one-structure-per-step with atoms partitioned across 2/4/8
//!    ranks is **bit-identical** to the single-rank run — final parameters
//!    and metric trajectories to the last bit (the fixed 8-segment
//!    decomposition + slotted f64 exchange make the fold order
//!    world-invariant);
//! 2. the graph-parallel path deliberately ignores the precision knob
//!    (pure f64 end to end): an engine loaded at MixedF32 produces the
//!    exact bits of the f64 engine;
//! 3. kill-at-k checkpoint resume parity holds under graph parallelism;
//! 4. a rank dying mid-step (between halo exchanges) surfaces as a typed
//!    rank failure on its peers — never a deadlock;
//! 5. a non-finite loss injected at ONE rank skips the batch on EVERY rank
//!    (the group shares one structure per step), keeping the run
//!    bit-identical to a single-rank run with the same injection;
//! 6. property: the segment partition + halo exchange delivers every
//!    cross-rank neighbor row exactly, on structures large enough for the
//!    cell-grid radius-graph path, which itself must match brute force;
//! 7. the analytic halo-traffic formula (`predicted_step_elems`) equals
//!    the measured per-step `Comm::stats` delta, element for element;
//! 8. the registered 1000-atom Supercell preset trains end to end under
//!    graph parallelism.

use std::sync::Arc;
use std::time::Duration;

use hydra_mtp::comm::{run_group, HaloPlan};
use hydra_mtp::config::{RunConfig, TrainMode};
use hydra_mtp::coordinator::{DataBundle, Heads, RunLog, TrainedModel, Trainer};
use hydra_mtp::data::featurized::compute_segments;
use hydra_mtp::data::generators::inorganic::build_crystal;
use hydra_mtp::data::graph::{
    radius_graph_positions, radius_graph_positions_reference, uses_grid_path,
};
use hydra_mtp::data::potential::energy_and_forces;
use hydra_mtp::data::structures::DatasetId;
use hydra_mtp::model::egnn::{BranchParams, EgnnDims, EncoderParams};
use hydra_mtp::model::graphpar::{self, GpPlan, GpStructure, GradLayout};
use hydra_mtp::model::params::ParamSet;
use hydra_mtp::runtime::{BackendKind, Engine, Manifest, ManifestConfig, Precision};
use hydra_mtp::tasks::{
    register_large_presets, FidelityProfile, GeneratorProfile, StructureKind,
    TaskRegistry, TaskSpec,
};
use hydra_mtp::tensor::DType;
use hydra_mtp::util::prop::{check, forall};
use hydra_mtp::util::rng::Rng;

// ---------------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------------

/// Shared f64 engine. The graph-parallel trainer path only consumes the
/// manifest (dims + parameter init), so any backend works identically.
fn engine() -> Arc<Engine> {
    use std::sync::OnceLock;
    static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            let e = Engine::load("artifacts").expect("engine loads on every machine");
            eprintln!("graph-parallel tests run on the '{}' backend", e.backend_name());
            Arc::new(e)
        })
        .clone()
}

/// Native mixed-f32 engine: the precision knob the graph-parallel path must
/// provably IGNORE (its math is pinned to f64).
fn engine_f32() -> Arc<Engine> {
    use std::sync::OnceLock;
    static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            let e = Engine::load_full("artifacts", BackendKind::Native, Precision::MixedF32)
                .expect("native engine loads on every machine");
            Arc::new(e)
        })
        .clone()
}

/// A test-sized bulk task: 5^3 = 125-atom supercells — the same generator
/// family as the registered 1000-atom preset, small enough that the
/// world-parity matrix stays fast. Registered once per process (the
/// registry is idempotent for identical specs).
fn bulk_task() -> DatasetId {
    TaskRegistry::global()
        .register(TaskSpec::new(
            "GpTest-Bulk",
            vec![12, 8, 11, 17],
            GeneratorProfile {
                kind: StructureKind::Supercell { reps: 5 },
                relax_steps: 0,
                relax_step_size: 0.05,
                perturb_factor: 0.2,
            },
            FidelityProfile {
                seed_tag: 53,
                shift_sigma: 0.25,
                scale_jitter: 0.01,
                force_scale_jitter: 0.005,
                energy_noise: 0.002,
                force_noise: 0.003,
                shift_offset: 0.0,
            },
        ))
        .expect("identical re-registration is idempotent")
}

fn gp_config(dataset: DatasetId, replicas: usize, epochs: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.mode = TrainMode::Single(dataset);
    cfg.parallel.replicas = replicas;
    cfg.parallel.graph_par = true;
    cfg.train.epochs = epochs;
    cfg.train.patience = 0;
    cfg.data.per_dataset = 5;
    cfg
}

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("hydra_mtp_graphpar_{}_{name}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn assert_params_bits_eq(a: &ParamSet, b: &ParamSet, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: leaf count");
    for ((na, ta), (nb, tb)) in a.iter().zip(b.iter()) {
        assert_eq!(na, nb, "{what}: leaf name");
        match ta.dtype() {
            DType::F32 => {
                let (xa, xb) = (ta.as_f32(), tb.as_f32());
                assert_eq!(xa.len(), xb.len(), "{what}: {na} numel");
                for (i, (x, y)) in xa.iter().zip(xb).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{what}: {na}[{i}]: {x} vs {y} (bitwise)"
                    );
                }
            }
            DType::I32 => assert_eq!(ta.as_i32(), tb.as_i32(), "{what}: {na}"),
        }
    }
}

fn assert_models_bits_eq(a: &TrainedModel, b: &TrainedModel) {
    assert_params_bits_eq(&a.encoder, &b.encoder, "encoder");
    match (&a.heads, &b.heads) {
        (Heads::Shared(x), Heads::Shared(y)) => assert_params_bits_eq(x, y, "shared head"),
        _ => panic!("graph-parallel modes train a shared head"),
    }
}

/// Trajectory equality ignoring wall-clock quantities (phase timings and
/// the `step_ms` coverage EMA legitimately differ between runs; everything
/// numeric must match to the last bit).
fn assert_logs_bits_eq(a: &RunLog, b: &RunLog) {
    assert_eq!(a.epochs.len(), b.epochs.len(), "epoch count");
    for (ea, eb) in a.epochs.iter().zip(&b.epochs) {
        assert_eq!(ea.epoch, eb.epoch);
        assert_eq!(ea.steps, eb.steps, "epoch {}", ea.epoch);
        assert_eq!(ea.skipped_batches, eb.skipped_batches, "epoch {}", ea.epoch);
        assert_eq!(
            ea.train_loss.to_bits(),
            eb.train_loss.to_bits(),
            "epoch {} train_loss {} vs {}",
            ea.epoch,
            ea.train_loss,
            eb.train_loss
        );
        assert_eq!(ea.mae_e.to_bits(), eb.mae_e.to_bits(), "epoch {}", ea.epoch);
        assert_eq!(ea.mae_f.to_bits(), eb.mae_f.to_bits(), "epoch {}", ea.epoch);
        assert_eq!(ea.val_loss.to_bits(), eb.val_loss.to_bits(), "epoch {}", ea.epoch);
    }
}

// ---------------------------------------------------------------------------
// 1. world-shape invariance: 2/4/8 ranks == 1 rank, bitwise
// ---------------------------------------------------------------------------

#[test]
fn graph_par_bit_identical_across_worlds() {
    let e = engine();
    let d = bulk_task();
    let cfg1 = gp_config(d, 1, 2);
    let data = DataBundle::generate(&cfg1.data, &[d]);
    let reference = Trainer::new(Arc::clone(&e), cfg1).train(&data).unwrap();
    assert!(reference.log.epochs.iter().all(|ep| ep.steps > 0), "must actually train");
    assert!(reference.log.epochs.iter().all(|ep| ep.train_loss.is_finite()));

    for replicas in [2usize, 4, 8] {
        let out = Trainer::new(Arc::clone(&e), gp_config(d, replicas, 2))
            .train(&data)
            .unwrap();
        assert_models_bits_eq(&out.model, &reference.model);
        assert_logs_bits_eq(&out.log, &reference.log);
    }
}

// ---------------------------------------------------------------------------
// 2. the precision knob is provably ignored (pure-f64 invariant)
// ---------------------------------------------------------------------------

#[test]
fn graph_par_ignores_the_precision_knob() {
    let d = bulk_task();
    let cfg = gp_config(d, 2, 2);
    let data = DataBundle::generate(&cfg.data, &[d]);
    let f64_out = Trainer::new(engine(), cfg.clone()).train(&data).unwrap();
    let f32_out = Trainer::new(engine_f32(), cfg).train(&data).unwrap();
    assert_models_bits_eq(&f32_out.model, &f64_out.model);
    assert_logs_bits_eq(&f32_out.log, &f64_out.log);
}

// ---------------------------------------------------------------------------
// 3. kill-at-k resume parity
// ---------------------------------------------------------------------------

#[test]
fn kill_at_k_resume_parity_graph_par() {
    let e = engine();
    let d = bulk_task();
    let epochs = 4;
    let k = 2;
    let cfg_full = gp_config(d, 2, epochs);
    let data = DataBundle::generate(&cfg_full.data, &[d]);
    let full = Trainer::new(Arc::clone(&e), cfg_full).train(&data).unwrap();

    let dir = tmp_dir("resume");
    let mut cfg_phase1 = gp_config(d, 2, k);
    cfg_phase1.checkpoint.dir = Some(dir.to_string_lossy().into_owned());
    Trainer::new(Arc::clone(&e), cfg_phase1).train(&data).unwrap();

    let mut cfg_phase2 = gp_config(d, 2, epochs);
    cfg_phase2.checkpoint.resume = Some(dir.to_string_lossy().into_owned());
    let resumed = Trainer::new(e, cfg_phase2).train(&data).unwrap();

    assert_models_bits_eq(&resumed.model, &full.model);
    assert_logs_bits_eq(&resumed.log, &full.log);
    std::fs::remove_dir_all(dir).ok();
}

// ---------------------------------------------------------------------------
// 4. chaos: rank death mid-step is typed, never a deadlock
// ---------------------------------------------------------------------------

#[test]
fn rank_death_mid_halo_is_typed_not_deadlock() {
    // A rank-panic fault fires before step 1 of epoch 0 on rank 1. The dead
    // rank leaves its peers inside the step's halo/loss/gradient collective
    // sequence; they must wake with a typed error naming rank 1 within the
    // comm timeout — not hang waiting for its slot deposits.
    let e = engine();
    let d = bulk_task();
    let mut cfg = gp_config(d, 2, 2);
    cfg.fault.spec = Some("rank-panic@rank=1,epoch=0,step=1".into());
    cfg.fault.comm_timeout_ms = 10_000;
    let data = DataBundle::generate(&cfg.data, &[d]);
    let t0 = std::time::Instant::now();
    let err = Trainer::new(e, cfg).train(&data).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("rank 1"), "expected a typed rank-1 failure, got: {msg}");
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "failure must surface promptly, took {:?}",
        t0.elapsed()
    );
}

// ---------------------------------------------------------------------------
// 5. a non-finite loss at one rank skips the batch on every rank
// ---------------------------------------------------------------------------

#[test]
fn nonfinite_injection_skips_the_whole_group() {
    // The group cooperates on ONE structure per step, so a poisoned batch
    // must be skipped group-uniformly: a world-2 run with the injection at
    // rank 1 lands on the exact bits of a world-1 run with the injection
    // at rank 0 (the only rank there is).
    let e = engine();
    let d = bulk_task();
    let mut cfg1 = gp_config(d, 1, 2);
    cfg1.fault.spec = Some("nonfinite@rank=0,epoch=0,batch=1".into());
    let data = DataBundle::generate(&cfg1.data, &[d]);
    let solo = Trainer::new(Arc::clone(&e), cfg1).train(&data).unwrap();
    assert!(
        solo.log.epochs[0].skipped_batches >= 1,
        "the injection must actually skip a batch"
    );

    let mut cfg2 = gp_config(d, 2, 2);
    cfg2.fault.spec = Some("nonfinite@rank=1,epoch=0,batch=1".into());
    let duo = Trainer::new(e, cfg2).train(&data).unwrap();
    assert_models_bits_eq(&duo.model, &solo.model);
    assert_logs_bits_eq(&duo.log, &solo.log);
}

// ---------------------------------------------------------------------------
// 6. property: partition + halo exchange reconstructs brute-force
//    neighborhoods (cell-grid-sized structures)
// ---------------------------------------------------------------------------

#[test]
fn large_structures_take_the_cell_grid_path() {
    // The dense O(n^2) scan cuts over to the cell grid at 48 atoms; every
    // bulk size the graph-parallel generators produce must sit strictly
    // above it (a silent fallback would make halo-plan builds quadratic).
    assert!(!uses_grid_path(48));
    assert!(uses_grid_path(49));
    for bulk in [125usize, 1000, 1200] {
        assert!(uses_grid_path(bulk), "{bulk}-atom bulk must use the cell grid");
    }
}

#[test]
fn prop_halo_exchange_reconstructs_brute_force_neighborhoods() {
    forall(
        "partition+halo delivers every cross-rank neighbor row",
        8,
        |rng| (rng.int_range(20, 120), rng.next_u64()),
        |&(natoms, seed)| {
            // Sizes straddle the 48-atom cutover: the cell-grid edge list
            // (the path every large structure takes) and the dense-scan
            // edge list must BOTH equal the brute-force reference — the
            // halo plan inherits any topology bug wholesale.
            let mut rng = Rng::new(seed);
            let (_, positions) = build_crystal(&mut rng, &[12, 8, 11, 17], natoms);
            let cutoff = 6.0;
            let edges = radius_graph_positions(&positions, cutoff);
            let brute = radius_graph_positions_reference(&positions, cutoff);
            let pairs = |es: &[hydra_mtp::data::graph::Edge]| {
                es.iter().map(|e| (e.src, e.dst)).collect::<Vec<_>>()
            };
            check(
                pairs(&edges) == pairs(&brute),
                format!("{natoms} atoms: cell-grid edges != brute force"),
            )?;

            let segments = compute_segments(&positions, cutoff);
            let width = 5usize;
            for &world in &[2usize, 4, 8] {
                let plan = HaloPlan::build(&segments, &edges, world);
                let results = run_group(world, |c| {
                    let rank = c.rank_in_group;
                    // Owned rows hold a known function of the atom index;
                    // everything remote starts as NaN poison.
                    let n = positions.len();
                    let mut data = vec![f64::NAN; n * width];
                    for a in 0..n {
                        if plan.owns(rank, a) {
                            for k in 0..width {
                                data[a * width + k] = (a * width + k) as f64 + 0.25;
                            }
                        }
                    }
                    plan.exchange_node_rows(&c, &mut data, width).unwrap();
                    // Post-exchange, this rank's edge work can read the src
                    // row of EVERY edge whose dst it owns — local or remote
                    // — with the owner's exact bits.
                    for e in &edges {
                        let (s, dst) = (e.src as usize, e.dst as usize);
                        if !plan.owns(rank, dst) {
                            continue;
                        }
                        for k in 0..width {
                            let got = data[s * width + k];
                            let want = (s * width + k) as f64 + 0.25;
                            if got.to_bits() != want.to_bits() {
                                return Err(format!(
                                    "rank {rank}: edge {s}->{dst} src row [{k}]: \
                                     {got} vs {want}"
                                ));
                            }
                        }
                    }
                    Ok(())
                });
                for (r, res) in results.into_iter().enumerate() {
                    res.map_err(|e| format!("world {world} rank {r}: {e}"))?
                        .map_err(|e| format!("world {world}: {e}"))?;
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// 7. analytic halo traffic == measured Comm::stats, exactly
// ---------------------------------------------------------------------------

#[test]
fn predicted_step_elems_matches_measured_comm_stats() {
    let m = Manifest::synthesize(ManifestConfig::default_native());
    let dims = EgnnDims::from_config(&m.config);
    let layout = GradLayout::new(&dims);
    let params = ParamSet::init(&m.params, 9);
    let mut rng = Rng::new(5);
    let (species, positions) = build_crystal(&mut rng, &[12, 8, 11, 17], 80);
    let (energy, forces) = energy_and_forces(&species, &positions);
    let y_epa = energy / positions.len() as f64;
    let edges = radius_graph_positions(&positions, m.config.cutoff);
    let segments = compute_segments(&positions, m.config.cutoff);

    for world in [1usize, 2, 4] {
        let plan = GpPlan::build(&segments, &edges, world);
        let predicted = plan.predicted_step_elems(dims.h, dims.l, layout.len);
        let results = run_group(world, |c| {
            let enc = EncoderParams::from_set(&dims, &params).unwrap();
            let br = BranchParams::from_set(&dims, &params).unwrap();
            let st = GpStructure {
                species: &species,
                edges: &edges,
                y_energy_per_atom: y_epa,
                y_forces: &forces,
            };
            let before = c.stats().elems;
            graphpar::train_step(&dims, &enc, &br, &st, &plan, &layout, &c).unwrap();
            c.stats().elems - before
        });
        for (r, res) in results.into_iter().enumerate() {
            let measured = res.unwrap_or_else(|e| panic!("world {world} rank {r}: {e}"));
            assert_eq!(
                measured, predicted,
                "world {world} rank {r}: the analytic halo-traffic model \
                 must match Comm::stats element for element"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// 8. the 1000-atom Supercell preset trains end to end
// ---------------------------------------------------------------------------

#[test]
fn supercell_preset_trains_graph_parallel() {
    let (supercell, _) = register_large_presets().unwrap();
    let e = engine();
    let mut cfg = gp_config(supercell, 2, 1);
    cfg.data.per_dataset = 2;
    let data = DataBundle::generate(&cfg.data, &[supercell]);
    // The preset really is beyond any single-rank batch budget.
    let n = data.train[&supercell]
        .first()
        .or_else(|| data.val[&supercell].first())
        .expect("preset generates structures")
        .natoms();
    assert_eq!(n, 1000, "Supercell preset is 10^3 atoms");

    let out = Trainer::new(e, cfg).train(&data).unwrap();
    assert!(out.log.epochs.iter().all(|ep| ep.train_loss.is_finite()));
    assert!(out.comm_elems.0 > 0, "halo + loss + gradient folds must be on record");
}
