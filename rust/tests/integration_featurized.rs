//! Integration: the featurize-once, zero-copy data pipeline must be
//! bit-identical to the seed path — same edges, same batches, same order —
//! across the grid/dense radius-graph rewrite, the cached epoch planner,
//! pooled batch reuse, and parallel data generation.

use hydra_mtp::config::RunConfig;
use hydra_mtp::coordinator::trainer::{plan_epoch_batches_reference, DataBundle};
use hydra_mtp::data::batch::{BatchDims, BatchPool};
use hydra_mtp::data::featurized::FeaturizedStore;
use hydra_mtp::data::generators::{DatasetGenerator, GeneratorConfig};
use hydra_mtp::data::graph::{
    radius_graph_brute, radius_graph_positions, radius_graph_positions_reference,
};
use hydra_mtp::data::structures::{AtomicStructure, DatasetId, ALL_DATASETS};
use hydra_mtp::data::DDStore;
use hydra_mtp::util::rng::Rng;

fn mixed_samples(n_per: usize, max_atoms: usize) -> Vec<AtomicStructure> {
    let mut out = Vec::new();
    for d in [DatasetId::Ani1x, DatasetId::MpTrj, DatasetId::Qm7x] {
        let mut g = DatasetGenerator::new(
            d,
            13,
            GeneratorConfig { max_atoms, ..Default::default() },
        );
        out.extend(g.take(n_per));
    }
    out
}

// ---------------------------------------------------------------------------
// radius graph: dense / flat-grid / hashed-fallback vs the oracles
// ---------------------------------------------------------------------------

#[test]
fn radius_graph_matches_brute_and_seed_on_random_clouds() {
    let mut rng = Rng::new(101);
    for trial in 0..25 {
        let n = rng.int_range(2, 180);
        let span = rng.range(2.0, 30.0);
        let cutoff = rng.range(1.5, 7.0);
        // Include negative coordinates: centre the cloud away from zero.
        let shift = rng.range(-50.0, 10.0);
        let pos: Vec<[f64; 3]> = (0..n)
            .map(|_| {
                [
                    shift + rng.range(0.0, span),
                    shift + rng.range(0.0, span),
                    shift + rng.range(0.0, span),
                ]
            })
            .collect();
        let fast = radius_graph_positions(&pos, cutoff);
        assert_eq!(fast, radius_graph_brute(&pos, cutoff), "brute, trial {trial} n={n}");
        assert_eq!(
            fast,
            radius_graph_positions_reference(&pos, cutoff),
            "seed reference, trial {trial} n={n}"
        );
    }
}

#[test]
fn radius_graph_handles_degenerate_geometry() {
    // All atoms coincident: every pair is under the self-overlap guard.
    for n in [2usize, 3, 49, 120] {
        let dup = vec![[-3.5, 0.25, 7.0]; n];
        assert!(radius_graph_positions(&dup, 4.0).is_empty(), "n={n}");
    }
    // Pairs at exactly the cutoff boundary and slightly inside/outside.
    let pos = vec![[0.0, 0.0, 0.0], [4.0, 0.0, 0.0], [8.1, 0.0, 0.0]];
    let edges = radius_graph_positions(&pos, 4.0);
    assert_eq!(edges, radius_graph_brute(&pos, 4.0));
    // Planar and collinear degenerate grids, above the dense cutover.
    let plane: Vec<[f64; 3]> = (0..120)
        .map(|i| [(i % 12) as f64 * 1.1, (i / 12) as f64 * 1.1, 0.0])
        .collect();
    assert_eq!(radius_graph_positions(&plane, 2.5), radius_graph_brute(&plane, 2.5));
    let line: Vec<[f64; 3]> = (0..90).map(|i| [0.0, 0.0, i as f64 * 0.8]).collect();
    assert_eq!(radius_graph_positions(&line, 2.0), radius_graph_brute(&line, 2.0));
}

#[test]
fn radius_graph_sparse_fallback_matches() {
    // Enormous bounding box vs tiny cutoff: the flat grid would need far
    // more cells than the cap allows, forcing the hashed fallback.
    let mut rng = Rng::new(55);
    let pos: Vec<[f64; 3]> = (0..120)
        .map(|_| {
            [
                rng.range(-4000.0, 4000.0),
                rng.range(-4000.0, 4000.0),
                rng.range(-4000.0, 4000.0),
            ]
        })
        .collect();
    let fast = radius_graph_positions(&pos, 1.8);
    assert_eq!(fast, radius_graph_brute(&pos, 1.8));
    assert_eq!(fast, radius_graph_positions_reference(&pos, 1.8));
}

// ---------------------------------------------------------------------------
// epoch planning: cached path vs seed refeaturize path
// ---------------------------------------------------------------------------

#[test]
fn featurized_epoch_batches_are_bit_identical_to_the_seed_planner() {
    let ss = mixed_samples(20, 12);
    let world = 3;
    let dims = BatchDims { max_nodes: 96, max_edges: 768, max_graphs: 6 };
    let cutoff = 6.0;
    let store = DDStore::new(ss, world);
    let fstore = FeaturizedStore::build(std::sync::Arc::clone(&store), cutoff);

    let mut pool = BatchPool::new();
    for rank in 0..world {
        for epoch_seed in [9u64, 777, 0xDEAD_BEEF] {
            let reference =
                plan_epoch_batches_reference(&store, rank, world, dims, cutoff, epoch_seed);
            let cached =
                fstore.plan_epoch_batches(rank, world, dims, epoch_seed, &mut pool);
            assert_eq!(
                cached, reference,
                "rank {rank} seed {epoch_seed}: cached planner must be bit-identical"
            );
            // Second pass through a now-dirty pool: reuse must not leak
            // state from the previous epoch into the next one's batches.
            let pooled_again =
                fstore.plan_epoch_batches(rank, world, dims, epoch_seed, &mut pool);
            assert_eq!(pooled_again, reference, "pooled reuse changed the batches");
            pool.recycle(cached);
            pool.recycle(pooled_again);
        }
    }
}

#[test]
fn featurized_planner_skips_oversized_structures_like_the_seed() {
    let ss = mixed_samples(15, 20);
    let store = DDStore::new(ss, 2);
    let fstore = FeaturizedStore::build(std::sync::Arc::clone(&store), 6.0);
    // Budget small enough that some crystals cannot fit at all.
    let dims = BatchDims { max_nodes: 10, max_edges: 80, max_graphs: 4 };
    let mut pool = BatchPool::new();
    for rank in 0..2 {
        let reference = plan_epoch_batches_reference(&store, rank, 2, dims, 6.0, 31);
        let cached = fstore.plan_epoch_batches(rank, 2, dims, 31, &mut pool);
        assert_eq!(cached, reference, "rank {rank}");
    }
}

// ---------------------------------------------------------------------------
// parallel data generation vs the serial seed path
// ---------------------------------------------------------------------------

#[test]
fn parallel_databundle_generation_is_bit_identical_to_serial() {
    let mut cfg = RunConfig::default().data;
    cfg.per_dataset = 40;
    cfg.max_atoms = 10;
    let parallel = DataBundle::generate(&cfg, &ALL_DATASETS);
    let serial = DataBundle::generate_serial(&cfg, &ALL_DATASETS);
    assert_eq!(parallel.train, serial.train, "train split diverged");
    assert_eq!(parallel.val, serial.val, "val split diverged");
    assert_eq!(parallel.test, serial.test, "test split diverged");
    // And the split actually contains data for every task.
    for d in ALL_DATASETS {
        assert!(!parallel.train[&d].is_empty(), "{}", d.name());
    }
}
