//! Gradient check for the native backend: the hand-written analytic
//! backward pass of `model::egnn` is validated entry-by-entry against
//! central finite differences of the loss, for EVERY parameter leaf
//! (encoder + one head) on a small random batch. Also pins the
//! `ArchDims::shared_params` / `head_params` closed forms to the actual
//! leaf numel of the synthesized manifest.
//!
//! The native engine computes in f64 internally, so the only quantization
//! is the f32 parameter storage — the finite-difference denominator uses
//! the *actually stored* perturbed values, which removes that error source
//! and keeps the check tight (max relative error < 1e-3 with a 1e-2
//! absolute floor for near-zero entries).

use hydra_mtp::data::batch::BatchBuilder;
use hydra_mtp::data::generators::{DatasetGenerator, GeneratorConfig};
use hydra_mtp::data::structures::DatasetId;
use hydra_mtp::model::params::ParamSet;
use hydra_mtp::runtime::{Engine, ManifestConfig};

/// A deliberately tiny model so the FD sweep (hundreds of forward passes)
/// stays fast while still exercising every code path: 2 EGNN layers,
/// multi-graph batch with real padding in all three dimensions.
fn tiny_config() -> ManifestConfig {
    let mut cfg = ManifestConfig::default_native();
    cfg.max_nodes = 24;
    cfg.max_edges = 160;
    cfg.max_graphs = 3;
    cfg.num_species = 16;
    cfg.hidden = 16;
    cfg.num_layers = 2;
    cfg.num_rbf = 8;
    cfg.head_hidden = 16;
    cfg.cutoff = 4.0;
    cfg
}

fn small_batch(engine: &Engine, seed: u64) -> hydra_mtp::data::batch::GraphBatch {
    let mut g = DatasetGenerator::new(
        DatasetId::Qm7x,
        seed,
        GeneratorConfig { max_atoms: 6, ..Default::default() },
    );
    let samples = g.take(2);
    let batches = BatchBuilder::build_all(
        engine.manifest.config.batch_dims(),
        engine.manifest.config.cutoff,
        &samples,
    );
    batches.into_iter().next().expect("at least one batch")
}

#[test]
fn arch_formulas_equal_actual_leaf_numel() {
    // Satellite assertion: the closed-form P_s / P_h formulas equal the
    // synthesized manifest's leaf numel exactly, at tiny AND default dims.
    for cfg in [tiny_config(), ManifestConfig::default_native()] {
        let e = Engine::native(cfg);
        let dims = e.manifest.config.arch_dims();
        let enc: usize = e.manifest.encoder_params.iter().map(|m| m.numel()).sum();
        let br: usize = e.manifest.branch_params.iter().map(|m| m.numel()).sum();
        assert_eq!(enc, dims.shared_params(), "P_s formula vs leaves");
        assert_eq!(br, dims.head_params(), "P_h formula vs leaves");
        let params = ParamSet::init(&e.manifest.params, 0);
        assert_eq!(params.total_params(), enc + br);
    }
}

#[test]
fn native_gradients_match_central_finite_differences() {
    let engine = Engine::native(tiny_config());
    assert!(engine.is_native());
    let batch = small_batch(&engine, 12345);
    assert!(batch.n_graphs >= 2, "need a multi-graph batch");
    assert!(batch.n_edges > 10, "need real edges");
    let params = ParamSet::init(&engine.manifest.params, 7);

    let analytic = engine.train_step(&params, &batch).unwrap().grads;

    let mut checked = 0usize;
    let mut max_rel: f64 = 0.0;
    let n_leaves = params.len();
    for li in 0..n_leaves {
        let name = params.metas()[li].name.clone();
        let numel = params.tensors[li].numel();
        // Probe up to 6 spread-out entries per leaf (every entry for small
        // leaves) — the full sweep would be quadratic in model size for no
        // extra signal.
        let probes: Vec<usize> = if numel <= 6 {
            (0..numel).collect()
        } else {
            (0..6).map(|j| j * (numel - 1) / 5).collect()
        };
        for &j in &probes {
            let theta = params.tensors[li].as_f32()[j];
            let eps = (5e-4 * (1.0 + theta.abs() as f64)) as f32;

            let mut plus = params.clone();
            plus.tensors[li].as_f32_mut()[j] = theta + eps;
            let stored_plus = plus.tensors[li].as_f32()[j] as f64;
            let loss_plus = engine.eval_step(&plus, &batch).unwrap().loss;

            let mut minus = params.clone();
            minus.tensors[li].as_f32_mut()[j] = theta - eps;
            let stored_minus = minus.tensors[li].as_f32()[j] as f64;
            let loss_minus = engine.eval_step(&minus, &batch).unwrap().loss;

            let fd = (loss_plus - loss_minus) / (stored_plus - stored_minus);
            let a = analytic.tensors[li].as_f32()[j] as f64;
            let denom = a.abs().max(fd.abs()).max(1e-2);
            let rel = (a - fd).abs() / denom;
            max_rel = max_rel.max(rel);
            assert!(
                rel < 1e-3,
                "{name}[{j}]: analytic {a} vs finite-difference {fd} (rel {rel:.2e})"
            );
            checked += 1;
        }
    }
    // Every leaf must have been probed, and the model must not be trivially
    // flat (an all-zero gradient would vacuously pass the comparison).
    assert!(checked >= 4 * n_leaves, "probed {checked} entries over {n_leaves} leaves");
    assert!(analytic.global_norm() > 1e-6, "gradient must be non-trivial");
    eprintln!("gradcheck: {checked} entries over {n_leaves} leaves, max rel err {max_rel:.2e}");
}

#[test]
fn train_and_eval_agree_and_loss_descends_at_tiny_dims() {
    // Cross-check the cached-forward (train) and plain-forward (eval) paths
    // bit-for-bit, then take a few SGD-ish steps along the analytic
    // gradient: the loss must descend — independent corroboration that the
    // gradient points downhill, not just that it matches FD.
    let engine = Engine::native(tiny_config());
    let batch = small_batch(&engine, 99);
    let mut params = ParamSet::init(&engine.manifest.params, 3);
    let tr = engine.train_step(&params, &batch).unwrap();
    let ev = engine.eval_step(&params, &batch).unwrap();
    assert_eq!(tr.loss, ev.loss, "train and eval forward must agree exactly");
    assert_eq!(tr.mae_e, ev.mae_e);
    assert_eq!(tr.mae_f, ev.mae_f);

    let mut last = tr.loss;
    for _ in 0..5 {
        let out = engine.train_step(&params, &batch).unwrap();
        let scale = 1e-2 / out.grads.global_norm().max(1e-12);
        for (p, g) in params.tensors.iter_mut().zip(&out.grads.tensors) {
            for (pv, gv) in p.as_f32_mut().iter_mut().zip(g.as_f32()) {
                *pv -= (scale * *gv as f64) as f32;
            }
        }
        last = out.loss;
    }
    let end = engine.eval_step(&params, &batch).unwrap().loss;
    assert!(
        end < last,
        "normalized gradient steps must reduce the loss: {last} -> {end}"
    );
}
