//! Gradient + precision harness for the native backend.
//!
//! Two oracles bound every parameter leaf (encoder + one head) on a small
//! random batch:
//!
//! * **finite differences** — the hand-written analytic backward pass is
//!   validated entry-by-entry against central finite differences of the
//!   loss at `Precision::F64` (pinned explicitly, so a CI-matrix
//!   `HYDRA_MTP_PRECISION=mixed-f32` leg cannot soften this check). The
//!   f64 engine computes in f64 internally, so the only quantization is
//!   the f32 parameter storage — the finite-difference denominator uses
//!   the *actually stored* perturbed values, which removes that error
//!   source and keeps the check tight (max relative error < 1e-3 with a
//!   1e-2 absolute floor for near-zero entries).
//! * **the f64 path itself** — the `MixedF32` analytic gradients (blocked
//!   f32 compute, f64 accumulation; `model::kernels`) are bounded against
//!   the f64 oracle for every leaf, at a documented tolerance.
//!
//! Also pins the `ArchDims::shared_params` / `head_params` closed forms to
//! the actual leaf numel of the synthesized manifest.

use hydra_mtp::data::batch::BatchBuilder;
use hydra_mtp::data::generators::{DatasetGenerator, GeneratorConfig};
use hydra_mtp::data::structures::DatasetId;
use hydra_mtp::model::params::ParamSet;
use hydra_mtp::runtime::{Engine, ManifestConfig, Precision};

/// A deliberately tiny model so the FD sweep (hundreds of forward passes)
/// stays fast while still exercising every code path: 2 EGNN layers,
/// multi-graph batch with real padding in all three dimensions.
fn tiny_config() -> ManifestConfig {
    let mut cfg = ManifestConfig::default_native();
    cfg.max_nodes = 24;
    cfg.max_edges = 160;
    cfg.max_graphs = 3;
    cfg.num_species = 16;
    cfg.hidden = 16;
    cfg.num_layers = 2;
    cfg.num_rbf = 8;
    cfg.head_hidden = 16;
    cfg.cutoff = 4.0;
    cfg
}

fn small_batch(engine: &Engine, seed: u64) -> hydra_mtp::data::batch::GraphBatch {
    let mut g = DatasetGenerator::new(
        DatasetId::Qm7x,
        seed,
        GeneratorConfig { max_atoms: 6, ..Default::default() },
    );
    let samples = g.take(2);
    let batches = BatchBuilder::build_all(
        engine.manifest.config.batch_dims(),
        engine.manifest.config.cutoff,
        &samples,
    );
    batches.into_iter().next().expect("at least one batch")
}

#[test]
fn arch_formulas_equal_actual_leaf_numel() {
    // Satellite assertion: the closed-form P_s / P_h formulas equal the
    // synthesized manifest's leaf numel exactly, at tiny AND default dims.
    for cfg in [tiny_config(), ManifestConfig::default_native()] {
        let e = Engine::native(cfg);
        let dims = e.manifest.config.arch_dims();
        let enc: usize = e.manifest.encoder_params.iter().map(|m| m.numel()).sum();
        let br: usize = e.manifest.branch_params.iter().map(|m| m.numel()).sum();
        assert_eq!(enc, dims.shared_params(), "P_s formula vs leaves");
        assert_eq!(br, dims.head_params(), "P_h formula vs leaves");
        let params = ParamSet::init(&e.manifest.params, 0);
        assert_eq!(params.total_params(), enc + br);
    }
}

#[test]
fn native_gradients_match_central_finite_differences() {
    // Pinned to the F64 oracle: this check must be unchanged by the
    // precision knob (and by any HYDRA_MTP_PRECISION override in the
    // environment, e.g. CI's mixed-f32 matrix leg).
    let engine = Engine::native_with(tiny_config(), Precision::F64);
    assert!(engine.is_native());
    assert_eq!(engine.precision(), Precision::F64);
    let batch = small_batch(&engine, 12345);
    assert!(batch.n_graphs >= 2, "need a multi-graph batch");
    assert!(batch.n_edges > 10, "need real edges");
    let params = ParamSet::init(&engine.manifest.params, 7);

    let analytic = engine.train_step(&params, &batch).unwrap().grads;

    let mut checked = 0usize;
    let mut max_rel: f64 = 0.0;
    let n_leaves = params.len();
    for li in 0..n_leaves {
        let name = params.metas()[li].name.clone();
        let numel = params.tensors[li].numel();
        // Probe up to 6 spread-out entries per leaf (every entry for small
        // leaves) — the full sweep would be quadratic in model size for no
        // extra signal.
        let probes: Vec<usize> = if numel <= 6 {
            (0..numel).collect()
        } else {
            (0..6).map(|j| j * (numel - 1) / 5).collect()
        };
        for &j in &probes {
            let theta = params.tensors[li].as_f32()[j];
            let eps = (5e-4 * (1.0 + theta.abs() as f64)) as f32;

            let mut plus = params.clone();
            plus.tensors[li].as_f32_mut()[j] = theta + eps;
            let stored_plus = plus.tensors[li].as_f32()[j] as f64;
            let loss_plus = engine.eval_step(&plus, &batch).unwrap().loss;

            let mut minus = params.clone();
            minus.tensors[li].as_f32_mut()[j] = theta - eps;
            let stored_minus = minus.tensors[li].as_f32()[j] as f64;
            let loss_minus = engine.eval_step(&minus, &batch).unwrap().loss;

            let fd = (loss_plus - loss_minus) / (stored_plus - stored_minus);
            let a = analytic.tensors[li].as_f32()[j] as f64;
            let denom = a.abs().max(fd.abs()).max(1e-2);
            let rel = (a - fd).abs() / denom;
            max_rel = max_rel.max(rel);
            assert!(
                rel < 1e-3,
                "{name}[{j}]: analytic {a} vs finite-difference {fd} (rel {rel:.2e})"
            );
            checked += 1;
        }
    }
    // Every leaf must have been probed, and the model must not be trivially
    // flat (an all-zero gradient would vacuously pass the comparison).
    assert!(checked >= 4 * n_leaves, "probed {checked} entries over {n_leaves} leaves");
    assert!(analytic.global_norm() > 1e-6, "gradient must be non-trivial");
    eprintln!("gradcheck: {checked} entries over {n_leaves} leaves, max rel err {max_rel:.2e}");
}

#[test]
fn mixed_f32_gradients_bounded_against_f64_oracle_for_every_leaf() {
    // The precision harness: same params, same batch, one engine per
    // precision; the MixedF32 analytic gradients must track the f64 oracle
    // for EVERY parameter leaf.
    //
    // Documented tolerance: per-leaf L2 drift <= 1e-3 x the oracle's leaf
    // norm + 1e-5 x the oracle's GLOBAL gradient norm (the absolute term
    // covers leaves whose entries cancel to near zero, where a pure ratio
    // would be ill-conditioned). Observed drift is ~1e-6..1e-5 relative:
    // f32 products under f64 accumulators quantize each multiply at ~6e-8
    // relative and the f64 reductions keep that from compounding, so the
    // bound has >=2 orders of magnitude of headroom while a genuinely
    // wrong kernel (drift ~ leaf norm) still fails it by far.
    let e64 = Engine::native_with(tiny_config(), Precision::F64);
    let e32 = Engine::native_with(tiny_config(), Precision::MixedF32);
    assert_eq!(e64.precision().name(), "f64");
    assert_eq!(e32.precision().name(), "mixed-f32");
    let batch = small_batch(&e64, 12345);
    let params = ParamSet::init(&e64.manifest.params, 7);

    let o64 = e64.train_step(&params, &batch).unwrap();
    let o32 = e32.train_step(&params, &batch).unwrap();

    // Forward metrics agree tightly: the loss reduction itself is f64 at
    // both precisions, so only the activations' f32 quantization shows.
    assert!(
        (o32.loss - o64.loss).abs() <= 1e-4 * o64.loss.abs().max(1.0),
        "loss: mixed {} vs f64 {}",
        o32.loss,
        o64.loss
    );
    assert!((o32.mae_e - o64.mae_e).abs() <= 1e-4 * o64.mae_e.abs().max(1.0));
    assert!((o32.mae_f - o64.mae_f).abs() <= 1e-4 * o64.mae_f.abs().max(1.0));

    let global = o64.grads.global_norm();
    assert!(global > 1e-6, "oracle gradient must be non-trivial");
    let mut total_diff = 0.0f64;
    let mut max_rel = 0.0f64;
    for li in 0..params.len() {
        let name = &o64.grads.metas()[li].name;
        let a = o64.grads.tensors[li].as_f32();
        let b = o32.grads.tensors[li].as_f32();
        assert_eq!(a.len(), b.len(), "{name}: leaf numel");
        let mut d2 = 0.0f64;
        let mut n2 = 0.0f64;
        for (&x, &y) in a.iter().zip(b) {
            let (x, y) = (x as f64, y as f64);
            d2 += (x - y) * (x - y);
            n2 += x * x;
        }
        let (diff, norm) = (d2.sqrt(), n2.sqrt());
        total_diff += diff;
        let bound = 1e-3 * norm + 1e-5 * global;
        max_rel = max_rel.max(diff / bound.max(f64::MIN_POSITIVE));
        assert!(
            diff <= bound,
            "{name}: MixedF32 grads drift {diff:.3e} vs oracle leaf norm {norm:.3e} \
             (bound {bound:.3e}, global {global:.3e})"
        );
    }
    // The knob must be live: bit-identical gradients across all leaves
    // would mean the MixedF32 path silently ran the f64 kernels.
    assert!(
        total_diff > 0.0,
        "MixedF32 gradients are bit-identical to f64 — precision knob inert?"
    );
    eprintln!(
        "precision harness: {} leaves, max bound utilization {max_rel:.2e}",
        params.len()
    );
}

#[test]
fn mixed_f32_is_deterministic_and_descends() {
    // Bit-determinism at fixed precision: two engines, same inputs, must
    // agree to the last bit (the mixed kernels chunk work over threads but
    // never reorder an accumulation). Then a few normalized gradient steps
    // must reduce the loss — the mixed gradients point downhill too.
    let ea = Engine::native_with(tiny_config(), Precision::MixedF32);
    let eb = Engine::native_with(tiny_config(), Precision::MixedF32);
    let batch = small_batch(&ea, 4242);
    let mut params = ParamSet::init(&ea.manifest.params, 11);
    let oa = ea.train_step(&params, &batch).unwrap();
    let ob = eb.train_step(&params, &batch).unwrap();
    assert_eq!(oa.loss.to_bits(), ob.loss.to_bits(), "mixed loss must be deterministic");
    for (ta, tb) in oa.grads.tensors.iter().zip(&ob.grads.tensors) {
        let (xa, xb) = (ta.as_f32(), tb.as_f32());
        for (x, y) in xa.iter().zip(xb) {
            assert_eq!(x.to_bits(), y.to_bits(), "mixed grads must be deterministic");
        }
    }

    let start = oa.loss;
    for _ in 0..5 {
        let out = ea.train_step(&params, &batch).unwrap();
        let scale = 1e-2 / out.grads.global_norm().max(1e-12);
        for (p, g) in params.tensors.iter_mut().zip(&out.grads.tensors) {
            for (pv, gv) in p.as_f32_mut().iter_mut().zip(g.as_f32()) {
                *pv -= (scale * *gv as f64) as f32;
            }
        }
    }
    let end = ea.eval_step(&params, &batch).unwrap().loss;
    assert!(end < start, "mixed-f32 gradient steps must reduce the loss: {start} -> {end}");
}

#[test]
fn train_and_eval_agree_and_loss_descends_at_tiny_dims() {
    // Cross-check the cached-forward (train) and plain-forward (eval) paths
    // bit-for-bit, then take a few SGD-ish steps along the analytic
    // gradient: the loss must descend — independent corroboration that the
    // gradient points downhill, not just that it matches FD.
    let engine = Engine::native(tiny_config());
    let batch = small_batch(&engine, 99);
    let mut params = ParamSet::init(&engine.manifest.params, 3);
    let tr = engine.train_step(&params, &batch).unwrap();
    let ev = engine.eval_step(&params, &batch).unwrap();
    assert_eq!(tr.loss, ev.loss, "train and eval forward must agree exactly");
    assert_eq!(tr.mae_e, ev.mae_e);
    assert_eq!(tr.mae_f, ev.mae_f);

    let mut last = tr.loss;
    for _ in 0..5 {
        let out = engine.train_step(&params, &batch).unwrap();
        let scale = 1e-2 / out.grads.global_norm().max(1e-12);
        for (p, g) in params.tensors.iter_mut().zip(&out.grads.tensors) {
            for (pv, gv) in p.as_f32_mut().iter_mut().zip(g.as_f32()) {
                *pv -= (scale * *gv as f64) as f32;
            }
        }
        last = out.loss;
    }
    let end = engine.eval_step(&params, &batch).unwrap().loss;
    assert!(
        end < last,
        "normalized gradient steps must reduce the loss: {last} -> {end}"
    );
}
