//! Integration: the full data pipeline — generators -> GPack files ->
//! reader -> DDStore -> padded batches — plus the multi-fidelity label
//! structure the Tables-1/2 reproduction depends on.

use hydra_mtp::data::batch::{BatchBuilder, BatchDims};
use hydra_mtp::data::fidelity::FidelityModel;
use hydra_mtp::data::generators::{generate_for, DatasetGenerator, GeneratorConfig};
use hydra_mtp::data::pack::{write_all, GPackReader};
use hydra_mtp::data::structures::{DatasetId, ALL_DATASETS};
use hydra_mtp::data::DDStore;
use hydra_mtp::util::rng::Rng;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("hydra_mtp_integration");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}_{}.gpack", std::process::id()))
}

#[test]
fn full_pipeline_generate_pack_load_batch() {
    // The path a real pre-training run takes, per dataset.
    let cfg = GeneratorConfig { max_atoms: 14, ..Default::default() };
    for (d, samples) in generate_for(&ALL_DATASETS, 77, 40, &cfg) {
        let path = tmp(&format!("pipeline_{}", d.index()));
        let n = write_all(&path, &samples).unwrap();
        assert_eq!(n, 40);

        let mut reader = GPackReader::open(&path).unwrap();
        let loaded = reader.read_all().unwrap();
        assert_eq!(loaded, samples, "{}", d.name());

        // DDStore over 4 ranks, then batch each rank's epoch slice.
        let store = DDStore::new(loaded, 4);
        let dims = BatchDims { max_nodes: 128, max_edges: 1024, max_graphs: 8 };
        let mut total_graphs = 0;
        for rank in 0..4 {
            let mut builder = BatchBuilder::new(dims, 6.0);
            let mut batches = Vec::new();
            for g in 0..store.len() {
                if g % 4 == rank {
                    let s = store.get(rank, g).unwrap();
                    if let Some(b) = builder.push(&s) {
                        batches.push(b);
                    }
                }
            }
            batches.extend(builder.finish());
            total_graphs += batches.iter().map(|b| b.n_graphs).sum::<usize>();
            assert_eq!(builder.skipped, 0, "nothing should be skipped at these dims");
        }
        assert_eq!(total_graphs, 40, "{}: every sample must reach a batch", d.name());
        std::fs::remove_file(path).ok();
    }
}

#[test]
fn multi_fidelity_conflict_has_the_papers_structure() {
    // The core data property behind Tables 1-2: the SAME physical structure
    // gets systematically different energy labels under different dataset
    // fidelities (per-element reference shifts), while forces barely move.
    let mut g = DatasetGenerator::new(
        DatasetId::Ani1x,
        5,
        GeneratorConfig { max_atoms: 10, ..Default::default() },
    );
    let probe = g.take(20);
    let ani = FidelityModel::for_dataset(DatasetId::Ani1x);
    let qm7 = FidelityModel::for_dataset(DatasetId::Qm7x);
    let mp = FidelityModel::for_dataset(DatasetId::MpTrj);
    let alex = FidelityModel::for_dataset(DatasetId::Alexandria);

    let mut organic_gap = 0.0;
    let mut inorganic_gap = 0.0;
    for s in &probe {
        organic_gap += ani.disagreement(&qm7, &s.species);
        inorganic_gap += alex.disagreement(&mp, &s.species);
    }
    organic_gap /= probe.len() as f64;
    inorganic_gap /= probe.len() as f64;
    assert!(
        organic_gap > 5.0 * inorganic_gap,
        "organic sources must conflict far more than the two PBE-family \
         inorganic sources: organic {organic_gap} vs inorganic {inorganic_gap}"
    );

    // Force labels: same structure relabeled by two fidelities stays close.
    let mut rng = Rng::new(9);
    let s = &probe[0];
    let (_, f_ani) = ani.apply(&s.species, 0.0, &s.forces, &mut rng);
    let (_, f_qm7) = qm7.apply(&s.species, 0.0, &s.forces, &mut rng);
    let mut max_rel = 0.0f64;
    for (a, b) in f_ani.iter().zip(&f_qm7) {
        for k in 0..3 {
            let denom = a[k].abs().max(1.0);
            max_rel = max_rel.max((a[k] - b[k]).abs() / denom);
        }
    }
    assert!(max_rel < 0.2, "force labels should nearly agree: {max_rel}");
}

#[test]
fn dataset_statistics_match_paper_profiles() {
    let cfg = GeneratorConfig::default();
    let all = generate_for(&ALL_DATASETS, 123, 60, &cfg);
    let stats: std::collections::BTreeMap<_, _> = all
        .iter()
        .map(|(d, ss)| {
            let mean_atoms =
                ss.iter().map(|s| s.natoms()).sum::<usize>() as f64 / ss.len() as f64;
            let h_frac = ss
                .iter()
                .flat_map(|s| s.species.iter())
                .filter(|&&z| z == 1)
                .count() as f64
                / ss.iter().map(|s| s.natoms()).sum::<usize>() as f64;
            (*d, (mean_atoms, h_frac))
        })
        .collect();

    // Sanity on all five datasets being distinct and populated.
    assert_eq!(stats.len(), ALL_DATASETS.len());
    // Organic datasets are hydrogen-rich; inorganic ones are not.
    assert!(stats[&DatasetId::Ani1x].1 > 0.3, "ANI1x H fraction");
    assert!(stats[&DatasetId::MpTrj].1 < 0.15, "MPTrj H fraction");
    assert!(stats[&DatasetId::Alexandria].1 < 0.15, "Alexandria H fraction");
}

#[test]
fn gpack_scales_to_many_samples() {
    // Mini stress test: 2k samples in one file, random access stays correct.
    let cfg = GeneratorConfig { max_atoms: 8, ..Default::default() };
    let mut g = DatasetGenerator::new(DatasetId::Qm7x, 31, cfg);
    let samples = g.take(2000);
    let path = tmp("stress");
    write_all(&path, &samples).unwrap();
    let mut r = GPackReader::open(&path).unwrap();
    assert_eq!(r.len(), 2000);
    let mut rng = Rng::new(4);
    for _ in 0..100 {
        let i = rng.below(2000);
        assert_eq!(r.read(i).unwrap(), samples[i], "sample {i}");
    }
    let size = std::fs::metadata(&path).unwrap().len();
    assert!(size > 100_000, "file should hold real data: {size} bytes");
    std::fs::remove_file(path).ok();
}

#[test]
fn ddstore_epoch_traffic_is_mostly_local_for_aligned_slices() {
    // When ranks iterate indices they own (the trainer's round-robin
    // slicing), DDStore reads are all local — the design goal.
    let cfg = GeneratorConfig { max_atoms: 8, ..Default::default() };
    let mut g = DatasetGenerator::new(DatasetId::Ani1x, 8, cfg);
    let store = DDStore::new(g.take(64), 4);
    for rank in 0..4 {
        for gidx in 0..64 {
            if store.owner(gidx) == rank {
                store.get(rank, gidx).unwrap();
            }
        }
    }
    let (local, remote) = store.stats();
    assert_eq!(local, 64);
    assert_eq!(remote, 0);
}
