//! hydra-lint integration tests: per-rule fixtures (a positive hit, an
//! annotated allow, a clean case), annotation hygiene, and the gate this
//! whole subsystem exists for — the real tree at HEAD must lint to zero
//! violations. The binary itself is exercised end to end via
//! `CARGO_BIN_EXE_hydra_lint` (exit 0 on HEAD, exit 1 on a violating
//! fixture tree, report JSON written either way).

use std::path::{Path, PathBuf};
use std::process::Command;

use hydra_mtp::lint;
use hydra_mtp::lint::env_registry::EnvVar;
use hydra_mtp::lint::rules;
use hydra_mtp::lint::scan::SourceFile;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hydra_mtp_lint_{}_{name}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Run every rule over one in-memory fixture file.
fn scan_one(rel_path: &str, src: &str) -> Vec<lint::Finding> {
    let f = SourceFile::parse(rel_path, src);
    lint::check_files(&[f])
}

fn violations(findings: &[lint::Finding]) -> Vec<&lint::Finding> {
    findings.iter().filter(|f| f.is_violation()).collect()
}

/// Whether any finding carries `rule` and a message containing `msg_part`.
fn has(findings: &[lint::Finding], rule: &str, msg_part: &str) -> bool {
    findings.iter().any(|f| f.rule == rule && f.message.contains(msg_part))
}

fn allowed_reasons(findings: &[lint::Finding]) -> Vec<&str> {
    findings.iter().filter_map(|f| f.allowed_reason.as_deref()).collect()
}

// ---------------------------------------------------------------------------
// R1: determinism
// ---------------------------------------------------------------------------

#[test]
fn r1_flags_nondeterminism_in_scope_only() {
    let hit = scan_one("data/graph.rs", "use std::collections::HashMap;\n");
    assert!(has(&hit, "nondeterministic", "HashMap"), "{hit:?}");
    assert_eq!(violations(&hit).len(), 1);

    let src = "pub fn f() { let _t = std::time::Instant::now(); }\n";
    let clock = scan_one("comm/collectives.rs", src);
    assert!(has(&clock, "nondeterministic", "Instant::now"), "{clock:?}");

    let clean = scan_one("data/graph.rs", "use std::collections::BTreeMap;\n");
    assert!(clean.is_empty(), "{clean:?}");

    // model/params.rs is outside the R1 scope: its HashMap keys a by-name
    // parameter lookup, never an iteration the numerics depend on.
    let out_of_scope = scan_one("model/params.rs", "use std::collections::HashMap;\n");
    assert!(out_of_scope.is_empty(), "{out_of_scope:?}");
}

#[test]
fn r1_annotated_allow_downgrades_the_finding() {
    let src = "// lint:allow(nondeterministic): fixture oracle\nuse std::collections::HashMap;\n";
    let got = scan_one("data/graph.rs", src);
    assert!(violations(&got).is_empty(), "{got:?}");
    assert_eq!(allowed_reasons(&got), vec!["fixture oracle"]);
}

// ---------------------------------------------------------------------------
// R2: panic safety
// ---------------------------------------------------------------------------

#[test]
fn r2_flags_panic_tokens_and_range_indexing_in_scope() {
    let src = r#"pub fn f(v: &[u8]) -> u8 {
    let a = v.first().unwrap();
    let s = &v[1..3];
    let ok = v.get(1..3);
    *a + s[0] + ok.map(|x| x[0]).unwrap_or(0)
}
"#;
    let got = scan_one("serve/queue.rs", src);
    let bad = violations(&got);
    assert_eq!(bad.len(), 2, "{got:?}");
    assert!(bad.iter().all(|f| f.rule == "panic"));
    assert!(has(&got, "panic", "unwrap"));
    assert!(has(&got, "panic", "range index"));
}

#[test]
fn r2_exempts_test_code_and_honors_annotations() {
    let in_test = "#[cfg(test)]\nmod tests {\n    fn g(x: Option<u8>) { x.unwrap(); }\n}\n";
    let got = scan_one("serve/queue.rs", in_test);
    assert!(got.is_empty(), "{got:?}");

    let annotated = "// lint:allow(panic): injected fault fixture\npanic!(\"boom\");\n";
    let got = scan_one("serve/mod.rs", annotated);
    assert!(violations(&got).is_empty(), "{got:?}");
    assert_eq!(allowed_reasons(&got), vec!["injected fault fixture"]);
}

#[test]
fn r2_range_leg_does_not_cover_the_trainer() {
    // The trainer's flatten/unflatten slices are bounds-proven by
    // construction; only the panic-token legs apply there.
    let src = "pub fn f(v: &[u8]) -> &[u8] { &v[1..3] }\n";
    let got = scan_one("coordinator/trainer.rs", src);
    assert!(got.is_empty(), "{got:?}");
}

// ---------------------------------------------------------------------------
// R3: collective safety
// ---------------------------------------------------------------------------

#[test]
fn r3_flags_unwrapped_or_discarded_collectives_anywhere() {
    let src = r#"fn f(c: &Comm, g: &Mesh, x: &mut [f32]) -> Result<(), E> {
    c.allreduce_mean(x).unwrap();
    g.global
        .broadcast(0, x)
        .expect("boom");
    let _ = c.barrier();
    c.allreduce_sum(x)?;
    Ok(())
}
"#;
    let got = scan_one("anywhere.rs", src);
    let coll: Vec<_> = got.iter().filter(|f| f.rule == "collective").collect();
    assert_eq!(coll.len(), 3, "{got:?}");
    assert!(coll.iter().all(|f| f.is_violation()));
    assert!(has(&got, "collective", "discarded"));
    assert!(has(&got, "collective", "unwrapped"));
}

// ---------------------------------------------------------------------------
// R4: config coverage
// ---------------------------------------------------------------------------

const R4_CLEAN: &str = r#"pub struct DataConfig {
    pub seed: u64,
}

pub struct RunConfig {
    pub mode: u32,
    pub artifacts_dir: String,
    pub data: DataConfig,
}

pub const FINGERPRINT_EXCLUDED: &[(&str, &str)] = &[
    ("artifacts_dir", "output location only"),
];

impl RunConfig {
    pub fn trajectory_fingerprint_resolved(&self) -> String {
        format!("mode={};data_seed={}", self.mode, self.data.seed)
    }
}
"#;

const R4_UNCOVERED: &str = r#"pub struct DataConfig {
    pub seed: u64,
}

pub struct RunConfig {
    pub mode: u32,
    pub extra: f64,
    pub artifacts_dir: String,
    pub data: DataConfig,
}

pub const FINGERPRINT_EXCLUDED: &[(&str, &str)] = &[
    ("artifacts_dir", "output location only"),
];

impl RunConfig {
    pub fn trajectory_fingerprint_resolved(&self) -> String {
        format!("mode={};data_seed={}", self.mode, self.data.seed)
    }
}
"#;

const R4_BOTH: &str = r#"pub struct DataConfig {
    pub seed: u64,
}

pub struct RunConfig {
    pub mode: u32,
    pub artifacts_dir: String,
    pub data: DataConfig,
}

pub const FINGERPRINT_EXCLUDED: &[(&str, &str)] = &[
    ("mode", "oops: it is also fingerprinted"),
    ("artifacts_dir", "output location only"),
];

impl RunConfig {
    pub fn trajectory_fingerprint_resolved(&self) -> String {
        format!("mode={};data_seed={}", self.mode, self.data.seed)
    }
}
"#;

const R4_STALE: &str = r#"pub struct DataConfig {
    pub seed: u64,
}

pub struct RunConfig {
    pub mode: u32,
    pub artifacts_dir: String,
    pub data: DataConfig,
}

pub const FINGERPRINT_EXCLUDED: &[(&str, &str)] = &[
    ("artifacts_dir", "output location only"),
    ("ghost.knob", "this field no longer exists"),
];

impl RunConfig {
    pub fn trajectory_fingerprint_resolved(&self) -> String {
        format!("mode={};data_seed={}", self.mode, self.data.seed)
    }
}
"#;

#[test]
fn r4_requires_every_leaf_fingerprinted_or_excluded() {
    let clean = scan_one("config.rs", R4_CLEAN);
    assert!(clean.is_empty(), "{clean:?}");

    let uncovered = scan_one("config.rs", R4_UNCOVERED);
    assert!(has(&uncovered, "config", "`extra`"), "{uncovered:?}");
    assert!(has(&uncovered, "config", "neither"), "{uncovered:?}");

    let both = scan_one("config.rs", R4_BOTH);
    assert!(has(&both, "config", "both fingerprinted"), "{both:?}");

    let stale = scan_one("config.rs", R4_STALE);
    assert!(has(&stale, "config", "stale FINGERPRINT_EXCLUDED"), "{stale:?}");
}

// ---------------------------------------------------------------------------
// R5: env-var registry
// ---------------------------------------------------------------------------

#[test]
fn r5_flags_unregistered_env_reads() {
    let src = "fn f() { let _ = std::env::var(\"HYDRA_MTP_BOGUS\"); }\n";
    let bad = scan_one("fault.rs", src);
    assert!(has(&bad, "env", "HYDRA_MTP_BOGUS"), "{bad:?}");

    let src = "fn f() { let _ = std::env::var(\"HYDRA_MTP_THREADS\"); }\n";
    let ok = scan_one("fault.rs", src);
    assert!(ok.is_empty(), "{ok:?}");
}

#[test]
fn r5_flags_stale_registry_entries_on_full_tree_scans() {
    let reg: &[EnvVar] = &[EnvVar {
        name: "HYDRA_MTP_GHOST",
        summary: "an entry no code reads",
        unset: "irrelevant",
    }];
    let fixture = SourceFile::parse("lint/env_registry.rs", "pub const REGISTRY: () = ();\n");
    let mut out = Vec::new();
    rules::r5_env_registry(&[fixture], reg, &mut out);
    assert!(has(&out, "env", "stale registry entry"), "{out:?}");
}

// ---------------------------------------------------------------------------
// annotation hygiene
// ---------------------------------------------------------------------------

#[test]
fn annotation_hygiene_is_enforced() {
    let unknown = scan_one("x.rs", "// lint:allow(bogus): reason\nlet x = 1;\n");
    assert!(has(&unknown, "annotation", "unknown rule"), "{unknown:?}");

    let no_reason = scan_one("x.rs", "// lint:allow(panic)\nlet x = 1;\n");
    assert!(has(&no_reason, "annotation", "without a reason"), "{no_reason:?}");

    let unused = scan_one("x.rs", "// lint:allow(panic): never used\nlet x = 1;\n");
    assert!(has(&unused, "annotation", "suppresses nothing"), "{unused:?}");
}

// ---------------------------------------------------------------------------
// the gate: HEAD lints clean
// ---------------------------------------------------------------------------

fn repo_src_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src")
}

#[test]
fn head_tree_is_clean() {
    let report = lint::run(&repo_src_root()).unwrap();
    assert!(report.files_scanned > 30, "only {} files scanned", report.files_scanned);
    let mut diag = String::new();
    for f in &report.violations {
        diag.push_str(&format!("{}:{} [{}] {}\n", f.file, f.line, f.rule, f.message));
    }
    assert!(report.violations.is_empty(), "HEAD must lint clean:\n{diag}");
    // The audited exception surface: the three collective deadlines, the
    // reference radius-graph oracle, and the two injected-fault panics.
    assert!(report.allowed.len() >= 4, "annotated allowances: {}", report.allowed.len());
}

// ---------------------------------------------------------------------------
// the binary, end to end
// ---------------------------------------------------------------------------

#[test]
fn binary_exits_zero_on_head_and_writes_the_report() {
    let dir = tmp_dir("bin_clean");
    let json = dir.join("LINT_report.json");
    let out = Command::new(env!("CARGO_BIN_EXE_hydra_lint"))
        .arg("--root")
        .arg(repo_src_root())
        .arg("--quiet")
        .arg("--json")
        .arg(&json)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let report = std::fs::read_to_string(&json).unwrap();
    assert!(report.contains("hydra-lint-report/v1"), "{report}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn binary_exits_one_on_a_violating_tree() {
    let dir = tmp_dir("bin_dirty");
    let root = dir.join("src");
    std::fs::create_dir_all(root.join("data")).unwrap();
    std::fs::write(root.join("data/graph.rs"), "use std::collections::HashMap;\n").unwrap();
    let json = dir.join("LINT_report.json");
    let out = Command::new(env!("CARGO_BIN_EXE_hydra_lint"))
        .arg("--root")
        .arg(&root)
        .arg("--json")
        .arg(&json)
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1), "{}", String::from_utf8_lossy(&out.stdout));
    let report = std::fs::read_to_string(&json).unwrap();
    let flagged_dirty = report.contains("\"clean\":false") || report.contains("\"clean\": false");
    assert!(flagged_dirty, "{report}");
    let _ = std::fs::remove_dir_all(&dir);
}
