//! Integration: the execution-engine contract. Runs the four hot-path
//! entry points (train/eval/forward/encoder_forward) plus optimizer
//! integration against whatever backend `Engine::load` resolves — native on
//! a clean machine, PJRT when `make artifacts` + the feature are present.
//! Only the artifact-marshalling specifics remain PJRT-gated.

use std::sync::Arc;

use hydra_mtp::data::batch::BatchBuilder;
use hydra_mtp::data::generators::{DatasetGenerator, GeneratorConfig};
use hydra_mtp::data::structures::DatasetId;
use hydra_mtp::model::optimizer::{AdamW, AdamWConfig};
use hydra_mtp::model::params::ParamSet;
use hydra_mtp::runtime::{BackendKind, Engine};

/// One engine per test binary (compiling PJRT artifacts is the slow part);
/// the native fallback means these tests never skip.
fn engine() -> Arc<Engine> {
    use std::sync::OnceLock;
    static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            let e = Engine::load("artifacts").expect("engine loads on every machine");
            eprintln!("runtime tests run on the '{}' backend", e.backend_name());
            Arc::new(e)
        })
        .clone()
}

/// PJRT-only engine, or `None` (with a skip message) on machines without
/// compiled artifacts / the `pjrt` feature. Only the artifact-specific
/// tests below use this.
fn pjrt_engine() -> Option<Arc<Engine>> {
    use std::sync::OnceLock;
    static ENGINE: OnceLock<Option<Arc<Engine>>> = OnceLock::new();
    ENGINE
        .get_or_init(|| match Engine::load_with("artifacts", BackendKind::Pjrt) {
            Ok(e) => Some(Arc::new(e)),
            Err(e) => {
                eprintln!(
                    "SKIP (pjrt-specific): artifacts unavailable ({e:#}); run \
                     `make artifacts` and enable the `pjrt` feature to cover the AOT bridge"
                );
                None
            }
        })
        .clone()
}

fn small_batch(engine: &Engine, seed: u64) -> hydra_mtp::data::batch::GraphBatch {
    let mut g = DatasetGenerator::new(
        DatasetId::Ani1x,
        seed,
        GeneratorConfig { max_atoms: 12, ..Default::default() },
    );
    let samples = g.take(8);
    let batches = BatchBuilder::build_all(
        engine.manifest.config.batch_dims(),
        engine.manifest.config.cutoff,
        &samples,
    );
    batches.into_iter().next().expect("at least one batch")
}

#[test]
fn manifest_loads_and_validates() {
    let e = engine();
    assert!(e.manifest.params.len() > 40);
    assert_eq!(e.manifest.batch_fields.len(), 12);
    e.manifest.validate().unwrap();
    assert!(e.platform().to_lowercase().contains("cpu") || !e.platform().is_empty());
}

#[test]
fn arch_formulas_match_manifest_counts() {
    // The closed-form P_s / P_h formulas must agree with the real artifact.
    let e = engine();
    let dims = e.manifest.config.arch_dims();
    let params = ParamSet::init(&e.manifest.params, 0);
    let enc = params.subset("encoder.").total_params();
    let br = params.subset("branch.").total_params();
    assert_eq!(enc, dims.shared_params(), "P_s formula");
    assert_eq!(br, dims.head_params(), "P_h formula");
    assert_eq!(enc + br, params.total_params());
}

#[test]
fn train_step_runs_and_is_deterministic() {
    let e = engine();
    let params = ParamSet::init(&e.manifest.params, 1);
    let batch = small_batch(&e, 2);
    let a = e.train_step(&params, &batch).unwrap();
    let b = e.train_step(&params, &batch).unwrap();
    assert!(a.loss.is_finite() && a.loss > 0.0);
    assert_eq!(a.loss, b.loss, "same inputs -> same loss");
    assert_eq!(a.mae_e, b.mae_e);
    // Gradients exist and are not all zero.
    assert!(a.grads.global_norm() > 0.0);
    assert_eq!(a.grads.len(), params.len());
}

#[test]
fn eval_step_matches_train_step_metrics() {
    let e = engine();
    let params = ParamSet::init(&e.manifest.params, 3);
    let batch = small_batch(&e, 4);
    let tr = e.train_step(&params, &batch).unwrap();
    let ev = e.eval_step(&params, &batch).unwrap();
    assert!((tr.loss - ev.loss).abs() < 1e-5 * (1.0 + tr.loss.abs()));
    assert!((tr.mae_e - ev.mae_e).abs() < 1e-5);
    assert!((tr.mae_f - ev.mae_f).abs() < 1e-5);
}

#[test]
fn forward_shapes_and_masking() {
    let e = engine();
    let params = ParamSet::init(&e.manifest.params, 5);
    let batch = small_batch(&e, 6);
    let (energy, forces) = e.forward(&params, &batch).unwrap();
    let dims = e.manifest.config.batch_dims();
    assert_eq!(energy.shape, vec![dims.max_graphs]);
    assert_eq!(forces.shape, vec![dims.max_nodes, 3]);
    // Padded graphs/nodes must predict exactly zero (masking).
    let ev = energy.as_f32();
    for g in batch.n_graphs..dims.max_graphs {
        assert_eq!(ev[g], 0.0, "padded graph {g}");
    }
    let fv = forces.as_f32();
    for n in batch.n_nodes..dims.max_nodes {
        assert_eq!(&fv[n * 3..n * 3 + 3], &[0.0, 0.0, 0.0], "padded node {n}");
    }
}

#[test]
fn gradients_point_downhill_with_adamw() {
    // Full L3 stack sanity: repeated engine steps + rust AdamW reduce loss.
    let e = engine();
    let mut params = ParamSet::init(&e.manifest.params, 7);
    let batch = small_batch(&e, 8);
    let mut opt = AdamW::new(
        AdamWConfig { lr: 3e-3, ..Default::default() },
        &params,
    );
    let first = e.train_step(&params, &batch).unwrap().loss;
    let mut last = first;
    for _ in 0..10 {
        let out = e.train_step(&params, &batch).unwrap();
        last = out.loss;
        opt.step(&mut params, &out.grads);
    }
    assert!(
        last < first,
        "loss should decrease under AdamW: {first} -> {last}"
    );
}

#[test]
fn branch_swap_changes_predictions_encoder_forward_does_not() {
    // The MTL split point: same encoder + different branch => different
    // predictions; encoder-only forward ignores branch values entirely.
    let e = engine();
    let p1 = ParamSet::init(&e.manifest.params, 11);
    let mut p2 = p1.clone();
    let other = ParamSet::init(&e.manifest.params, 99).subset("branch.");
    p2.copy_matching_from(&other);
    let batch = small_batch(&e, 12);

    let (e1, _) = e.forward(&p1, &batch).unwrap();
    let (e2, _) = e.forward(&p2, &batch).unwrap();
    let diff: f32 = e1
        .as_f32()
        .iter()
        .zip(e2.as_f32())
        .map(|(a, b)| (a - b).abs())
        .sum();
    assert!(diff > 1e-6, "branch change must alter head predictions");

    let enc1 = p1.subset("encoder.");
    let enc2 = p2.subset("encoder.");
    let (h1, v1) = e.encoder_forward(&enc1, &batch).unwrap();
    let (h2, v2) = e.encoder_forward(&enc2, &batch).unwrap();
    assert_eq!(h1.as_f32(), h2.as_f32(), "encoder output must not depend on branch");
    assert_eq!(v1.as_f32(), v2.as_f32());
}

#[test]
fn marshalling_rejects_wrong_input_count() {
    // PJRT-specific: the raw artifact surface checks input arity.
    let Some(e) = pjrt_engine() else { return };
    let err = e.run_raw("train_step", &[]);
    assert!(err.is_err());
}

#[test]
fn native_engine_names_missing_pjrt_surface() {
    // The artifact-marshalling surface does not exist on the native
    // backend; asking for it must produce a clear routing error, not a
    // panic or a silent no-op.
    let e = engine();
    if !e.is_native() {
        return; // covered by the pjrt-specific tests instead
    }
    let params = ParamSet::init(&e.manifest.params, 1);
    let batch = small_batch(&e, 2);
    let err = e.marshal("train_step", &params, &batch).unwrap_err();
    assert!(format!("{err}").contains("PJRT"), "{err}");
    assert!(e.run_raw("train_step", &[]).is_err());
    // And the manifest honestly reports its provenance.
    assert!(e.manifest.is_synthesized());
    assert_eq!(e.backend_name(), "native");
}

#[test]
fn one_artifact_serves_all_heads() {
    // Same executable, different branch values = different heads (the core
    // mechanism multi-task parallelism relies on).
    let e = engine();
    let batch = small_batch(&e, 20);
    let encoder = ParamSet::init(&e.manifest.params, 30).subset("encoder.");
    let mut losses = Vec::new();
    for head_seed in 0..3u64 {
        let mut full = ParamSet::init(&e.manifest.params, 40 + head_seed);
        full.copy_matching_from(&encoder);
        losses.push(e.train_step(&full, &batch).unwrap().loss);
    }
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(
        (losses[0] - losses[1]).abs() > 1e-9 || (losses[1] - losses[2]).abs() > 1e-9,
        "different heads should produce different losses: {losses:?}"
    );
}
