//! Integration: overlapped bucketed gradient reduction + elastic head
//! scheduling.
//!
//! Headline properties:
//!
//! 1. bucketed reduction over **arbitrary** bucket boundaries is bitwise
//!    identical to one monolithic `allreduce_mean` (property test at
//!    1/2/8 ranks — the determinism argument behind the whole feature);
//! 2. training with overlap on produces final parameters and metric
//!    trajectories **bit-identical** to the synchronous path in all three
//!    parallel modes (DDP, MTL-base, MTL-par), at both native precisions;
//! 3. kill-at-k resume parity holds with overlap enabled;
//! 4. a rank dying mid-bucket surfaces as a typed
//!    [`CommError::RankFailure`] on its peers — never a comm-thread
//!    deadlock — both at the reducer level and through the trainer's
//!    fault injection;
//! 5. the elastic scheduler demonstrably shifts head sub-group sizes
//!    under an imbalanced bundle;
//! 6. the scalesim overlap predictor, calibrated to this host's measured
//!    compute/comm split, confronts the measured win within a documented
//!    generous factor.

use std::sync::Arc;
use std::time::Duration;

use hydra_mtp::comm::{run_group, CommError, OverlapReducer, Segment};
use hydra_mtp::config::{RunConfig, TrainMode};
use hydra_mtp::coordinator::trainer::TrainOutcome;
use hydra_mtp::coordinator::{DataBundle, Heads, RunLog, TrainedModel, Trainer};
use hydra_mtp::data::structures::DatasetId;
use hydra_mtp::model::params::ParamSet;
use hydra_mtp::runtime::{BackendKind, Engine, Precision};
use hydra_mtp::scalesim::{
    predicted_overlap_win, MachineProfile, SimMode, Workload, OVERLAP_WINDOW_FRACTION,
};
use hydra_mtp::tensor::DType;
use hydra_mtp::util::prop::{check, forall};
use hydra_mtp::util::rng::Rng;

// ---------------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------------

/// Shared engine (f64 oracle precision): PJRT when artifacts + the feature
/// are available, the native pure-rust backend otherwise — never a skip.
fn engine() -> Arc<Engine> {
    use std::sync::OnceLock;
    static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            let e = Engine::load("artifacts").expect("engine loads on every machine");
            eprintln!("overlap tests run on the '{}' backend", e.backend_name());
            Arc::new(e)
        })
        .clone()
}

/// Native mixed-f32 engine: the blocked f32 microkernels, so the parity
/// suite covers BOTH precisions.
fn engine_f32() -> Arc<Engine> {
    use std::sync::OnceLock;
    static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            let e = Engine::load_full("artifacts", BackendKind::Native, Precision::MixedF32)
                .expect("native engine loads on every machine");
            Arc::new(e)
        })
        .clone()
}

fn tiny_config(mode: TrainMode, replicas: usize, epochs: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.mode = mode;
    cfg.parallel.replicas = replicas;
    cfg.train.epochs = epochs;
    cfg.train.patience = 0;
    cfg.data.per_dataset = 48;
    cfg.data.max_atoms = 10;
    cfg
}

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("hydra_mtp_overlap_{}_{name}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn assert_params_bits_eq(a: &ParamSet, b: &ParamSet, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: leaf count");
    for ((na, ta), (nb, tb)) in a.iter().zip(b.iter()) {
        assert_eq!(na, nb, "{what}: leaf name");
        match ta.dtype() {
            DType::F32 => {
                let (xa, xb) = (ta.as_f32(), tb.as_f32());
                assert_eq!(xa.len(), xb.len(), "{what}: {na} numel");
                for (i, (x, y)) in xa.iter().zip(xb).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{what}: {na}[{i}]: {x} vs {y} (bitwise)"
                    );
                }
            }
            DType::I32 => assert_eq!(ta.as_i32(), tb.as_i32(), "{what}: {na}"),
        }
    }
}

fn assert_models_bits_eq(a: &TrainedModel, b: &TrainedModel) {
    assert_params_bits_eq(&a.encoder, &b.encoder, "encoder");
    match (&a.heads, &b.heads) {
        (Heads::Shared(x), Heads::Shared(y)) => assert_params_bits_eq(x, y, "shared head"),
        (Heads::PerDataset(x), Heads::PerDataset(y)) => {
            assert_eq!(x.len(), y.len(), "head count");
            for (d, bx) in x {
                assert_params_bits_eq(bx, &y[d], &format!("head {}", d.name()));
            }
        }
        _ => panic!("heads kind mismatch"),
    }
}

/// Trajectory equality ignoring wall-clock quantities (phase timings and
/// the `step_ms` coverage EMA legitimately differ between runs; everything
/// numeric must match to the last bit).
fn assert_logs_bits_eq(a: &RunLog, b: &RunLog) {
    assert_eq!(a.epochs.len(), b.epochs.len(), "epoch count");
    for (ea, eb) in a.epochs.iter().zip(&b.epochs) {
        assert_eq!(ea.epoch, eb.epoch);
        assert_eq!(ea.steps, eb.steps, "epoch {}", ea.epoch);
        assert_eq!(ea.skipped_batches, eb.skipped_batches, "epoch {}", ea.epoch);
        assert_eq!(
            ea.train_loss.to_bits(),
            eb.train_loss.to_bits(),
            "epoch {} train_loss {} vs {}",
            ea.epoch,
            ea.train_loss,
            eb.train_loss
        );
        assert_eq!(ea.mae_e.to_bits(), eb.mae_e.to_bits(), "epoch {}", ea.epoch);
        assert_eq!(ea.mae_f.to_bits(), eb.mae_f.to_bits(), "epoch {}", ea.epoch);
        assert_eq!(ea.val_loss.to_bits(), eb.val_loss.to_bits(), "epoch {}", ea.epoch);
        assert_eq!(ea.coverage.len(), eb.coverage.len(), "epoch {}", ea.epoch);
        for (ca, cb) in ea.coverage.iter().zip(&eb.coverage) {
            assert_eq!(ca.dataset, cb.dataset, "epoch {}", ea.epoch);
            assert_eq!(ca.planned, cb.planned, "epoch {} {}", ea.epoch, ca.dataset);
            assert_eq!(ca.used, cb.used, "epoch {} {}", ea.epoch, ca.dataset);
        }
    }
}

// ---------------------------------------------------------------------------
// 1. property: bucketing never changes the reduced bits
// ---------------------------------------------------------------------------

#[test]
fn prop_bucketed_reduction_any_boundary_matches_monolithic() {
    forall(
        "bucketed allreduce over arbitrary boundaries == monolithic (bitwise)",
        10,
        |rng| {
            let len = rng.int_range(1, 300);
            let chunk = rng.int_range(1, len + 16);
            (len, chunk, rng.next_u64())
        },
        |&(len, chunk, seed)| {
            for &world in &[1usize, 2, 8] {
                let results = run_group(world, move |c| {
                    let mut rng = Rng::new(
                        seed ^ (c.rank_in_group as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    );
                    // Awkward bit patterns on purpose: exact negative zeros
                    // and denormals only survive an exactly-identical
                    // reduction order.
                    let src: Vec<f32> = (0..len)
                        .map(|i| match i % 7 {
                            0 => -0.0,
                            1 => 1e-40,
                            _ => rng.range(-3.0, 3.0) as f32,
                        })
                        .collect();
                    let mut mono = src.clone();
                    c.allreduce_mean(&mut mono).unwrap();

                    let mut red = OverlapReducer::new(c.clone(), c.clone());
                    red.submit_chunks(Segment::Encoder, 0, &src, chunk).unwrap();
                    let mut out = vec![0f32; len];
                    for rb in red.finish().unwrap() {
                        out[rb.offset..rb.offset + rb.data.len()].copy_from_slice(&rb.data);
                        red.recycle(rb.data);
                    }
                    (mono, out)
                });
                for (r, res) in results.into_iter().enumerate() {
                    let (mono, out) = res.map_err(|e| format!("rank {r}: {e}"))?;
                    for (i, (a, b)) in mono.iter().zip(&out).enumerate() {
                        check(
                            a.to_bits() == b.to_bits(),
                            format!("world={world} chunk={chunk} [{i}]: {a} vs {b}"),
                        )?;
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// 2. sync-vs-overlap bit parity in all three parallel modes
// ---------------------------------------------------------------------------

/// Train the same config twice — synchronous and overlapped — and demand
/// bit-identical final parameters, metric trajectories, and total traffic.
/// `bucket_elems` is deliberately small so real multi-bucket pipelining
/// happens even on the tiny test model.
fn sync_vs_overlap_case(
    e: Arc<Engine>,
    mode: TrainMode,
    replicas: usize,
    datasets: &[DatasetId],
) -> (TrainOutcome, TrainOutcome) {
    let cfg = tiny_config(mode, replicas, 2);
    let data = DataBundle::generate(&cfg.data, datasets);
    let sync = Trainer::new(Arc::clone(&e), cfg.clone()).train(&data).unwrap();
    assert_eq!(sync.overlapped_elems, 0, "sync path must not count overlapped traffic");

    let mut cfg_ov = cfg;
    cfg_ov.parallel.overlap = true;
    cfg_ov.parallel.bucket_elems = 96;
    let ov = Trainer::new(e, cfg_ov).train(&data).unwrap();
    assert!(ov.overlapped_elems > 0, "overlap path must actually engage");

    assert_models_bits_eq(&ov.model, &sync.model);
    assert_logs_bits_eq(&ov.log, &sync.log);
    assert_eq!(
        ov.comm_elems, sync.comm_elems,
        "overlap hides traffic, it must not change its volume"
    );
    (sync, ov)
}

#[test]
fn overlap_bit_identical_ddp() {
    sync_vs_overlap_case(
        engine(),
        TrainMode::Single(DatasetId::Ani1x),
        2,
        &[DatasetId::Ani1x],
    );
}

#[test]
fn overlap_bit_identical_mtl_base() {
    sync_vs_overlap_case(
        engine(),
        TrainMode::MtlBase,
        1,
        &[DatasetId::Ani1x, DatasetId::Qm7x, DatasetId::MpTrj],
    );
}

#[test]
fn overlap_bit_identical_mtl_par() {
    sync_vs_overlap_case(
        engine(),
        TrainMode::MtlPar,
        2,
        &[DatasetId::Ani1x, DatasetId::Qm7x, DatasetId::MpTrj],
    );
}

#[test]
fn overlap_bit_identical_mixed_f32() {
    // Same parity claim on the blocked mixed-f32 microkernels.
    sync_vs_overlap_case(
        engine_f32(),
        TrainMode::MtlPar,
        1,
        &[DatasetId::Ani1x, DatasetId::Qm7x],
    );
}

// ---------------------------------------------------------------------------
// 3. kill-at-k resume parity with overlap on
// ---------------------------------------------------------------------------

#[test]
fn kill_at_k_resume_parity_with_overlap() {
    let e = engine();
    let epochs = 4;
    let k = 2;
    let mk_cfg = |epochs: usize| {
        let mut cfg = tiny_config(TrainMode::MtlPar, 1, epochs);
        cfg.parallel.overlap = true;
        cfg.parallel.bucket_elems = 128;
        cfg
    };
    let datasets = [DatasetId::Ani1x, DatasetId::Qm7x, DatasetId::MpTrj];
    let cfg_full = mk_cfg(epochs);
    let data = DataBundle::generate(&cfg_full.data, &datasets);
    let full = Trainer::new(Arc::clone(&e), cfg_full).train(&data).unwrap();

    let dir = tmp_dir("resume");
    let mut cfg_phase1 = mk_cfg(k);
    cfg_phase1.checkpoint.dir = Some(dir.to_string_lossy().into_owned());
    Trainer::new(Arc::clone(&e), cfg_phase1).train(&data).unwrap();

    let mut cfg_phase2 = mk_cfg(epochs);
    cfg_phase2.checkpoint.resume = Some(dir.to_string_lossy().into_owned());
    let resumed = Trainer::new(e, cfg_phase2).train(&data).unwrap();

    assert_models_bits_eq(&resumed.model, &full.model);
    assert_logs_bits_eq(&resumed.log, &full.log);
    std::fs::remove_dir_all(dir).ok();
}

// ---------------------------------------------------------------------------
// 4. chaos: rank death mid-bucket is typed, never a deadlock
// ---------------------------------------------------------------------------

#[test]
fn reducer_peer_death_mid_bucket_is_typed_rank_failure() {
    // Rank 0 submits its first bucket, then dies before the second ever
    // arrives. Its unwinding reducer + member guard poison the group, so
    // the surviving ranks' comm threads must wake with a typed failure
    // naming rank 0 — not hang waiting for the missing bucket.
    let results = run_group(3, |c| {
        if c.rank_in_group == 0 {
            let mut red = OverlapReducer::new(c.clone(), c.clone());
            red.submit(Segment::Encoder, 0, 0, &[1.0, 2.0, 3.0]).unwrap();
            panic!("injected rank death mid-bucket");
        }
        let mut red = OverlapReducer::new(c.clone(), c.clone());
        let mut submit_err: Option<String> = None;
        for (k, chunk) in [[1.0f32, 2.0, 3.0], [4.0, 5.0, 6.0]].iter().enumerate() {
            if let Err(e) = red.submit(Segment::Encoder, 0, 3 * k, chunk) {
                submit_err = Some(format!("{e:#}"));
                break;
            }
        }
        match red.finish() {
            Ok(_) => submit_err.ok_or("peer never observed the failure".to_string()),
            Err(e) => Ok(format!("{e:#}")),
        }
    });
    assert!(
        matches!(results[0], Err(CommError::RankFailure { rank: 0 })),
        "rank 0's own slot must report its death: {:?}",
        results[0]
    );
    for (r, res) in results.iter().enumerate().skip(1) {
        let msg = res
            .as_ref()
            .unwrap_or_else(|e| panic!("rank {r} must not die itself: {e}"))
            .as_ref()
            .unwrap_or_else(|e| panic!("rank {r}: {e}"));
        assert!(
            msg.contains("rank 0"),
            "rank {r} must see a typed failure naming rank 0, got: {msg}"
        );
    }
}

#[test]
fn injected_rank_panic_with_overlap_on_is_typed_not_deadlock() {
    // Trainer-level chaos leg: a rank-panic fault fires while overlap is
    // on. The dying rank may hold in-flight buckets; the run must end with
    // a typed error naming the dead rank within the comm timeout.
    let e = engine();
    let mut cfg = tiny_config(TrainMode::Single(DatasetId::Qm7x), 2, 2);
    cfg.parallel.overlap = true;
    cfg.parallel.bucket_elems = 64;
    cfg.fault.spec = Some("rank-panic@rank=1,epoch=0,step=1".into());
    cfg.fault.comm_timeout_ms = 10_000;
    let data = DataBundle::generate(&cfg.data, &[DatasetId::Qm7x]);
    let t0 = std::time::Instant::now();
    let err = Trainer::new(e, cfg).train(&data).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("rank 1"), "expected a typed rank-1 failure, got: {msg}");
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "failure must surface promptly, took {:?}",
        t0.elapsed()
    );
}

// ---------------------------------------------------------------------------
// 5. elastic head scheduling shifts sub-group sizes under imbalance
// ---------------------------------------------------------------------------

#[test]
fn elastic_scheduler_shifts_subgroup_sizes_under_imbalance() {
    // 10:1 sample imbalance between two datasets. Epoch 0 has no cost
    // history, so the planner's fallback weights by planned steps — already
    // tilted toward the big dataset — and from epoch 1 the measured
    // step-cost EMA keeps ranks pulled toward the big dataset's head.
    let e = engine();
    let mut big_cfg = tiny_config(TrainMode::MtlPar, 3, 3);
    big_cfg.parallel.elastic = true;
    big_cfg.data.per_dataset = 160;
    let big = DataBundle::generate(&big_cfg.data, &[DatasetId::Ani1x]);
    let mut small_cfg = big_cfg.clone();
    small_cfg.data.per_dataset = 16;
    let small = DataBundle::generate(&small_cfg.data, &[DatasetId::Qm7x]);

    let mut train = big.train;
    train.extend(small.train);
    let mut val = big.val;
    val.extend(small.val);
    let mut test = big.test;
    test.extend(small.test);
    let data = DataBundle { train, val, test };

    let out = Trainer::new(e, big_cfg).train(&data).unwrap();
    let sizes = &out.final_head_sizes;
    assert_eq!(sizes.len(), 2, "one sub-group per head: {sizes:?}");
    assert_eq!(sizes.iter().sum::<usize>(), 6, "elastic must repartition, not resize");
    assert!(sizes.iter().all(|&s| s >= 1), "every head keeps at least one rank");
    // Head order == dataset order: ANI1x (big) first, QM7-X (small) second.
    assert!(
        sizes[0] > sizes[1],
        "the 10x-larger dataset must win ranks: {sizes:?}"
    );
    assert!(out.log.epochs.iter().all(|ep| ep.train_loss.is_finite()));
    // The per-dataset cost EMAs the replans consumed are on record.
    let last = out.log.epochs.last().unwrap();
    assert!(
        last.coverage.iter().any(|c| c.step_ms > 0.0),
        "replans must leave their measured step costs in the coverage log"
    );
}

// ---------------------------------------------------------------------------
// 6. scalesim confrontation: predicted vs measured overlap win
// ---------------------------------------------------------------------------

#[test]
fn scalesim_prediction_confronts_measured_overlap_win() {
    // Reuse the parity harness: one sync + one overlapped MTL-par run of
    // the same config on this host.
    let e = engine();
    let datasets = [DatasetId::Ani1x, DatasetId::Qm7x, DatasetId::MpTrj];
    let (sync, ov) = sync_vs_overlap_case(Arc::clone(&e), TrainMode::MtlPar, 2, &datasets);

    let split = |out: &TrainOutcome| {
        let (mut exec, mut comm, mut opt, mut steps) = (0.0f64, 0.0f64, 0.0f64, 0usize);
        for ep in &out.log.epochs {
            exec += ep.time_exec.as_secs_f64();
            comm += ep.time_comm.as_secs_f64();
            opt += ep.time_opt.as_secs_f64();
            steps += ep.steps;
        }
        let n = steps.max(1) as f64;
        (exec / n, comm / n, opt / n)
    };
    let (s_exec, s_comm, s_opt) = split(&sync);
    let (o_exec, o_comm, o_opt) = split(&ov);
    let sync_step = s_exec + s_comm + s_opt;
    let ov_step = o_exec + o_comm + o_opt;
    let measured_win = (sync_step - ov_step) / sync_step;

    // Calibrate a MachineProfile to THIS host from the measured sync
    // split: tflops such that the model's compute term reproduces the
    // measured exec time, link bandwidth such that its ring-allreduce term
    // reproduces the measured comm time (zero latency, zero noise).
    let n_heads = datasets.len();
    let world = 2 * n_heads;
    let sub = 2;
    let dims = e.manifest.config.arch_dims();
    let local_batch = e.manifest.config.max_graphs;
    let w = Workload {
        dims,
        n_heads,
        avg_nodes: 8.0,
        avg_edges: 40.0,
        efficiency: 1.0,
    };
    let per_sample = w.flops_encoder_per_sample() + w.flops_head_per_sample();
    let tflops = per_sample * local_batch as f64 / (s_exec.max(1e-9) * 1e12);
    let gib = 1024.0 * 1024.0 * 1024.0;
    let ring = |n: usize, bytes: f64| 2.0 * (n as f64 - 1.0) / n as f64 * bytes;
    let volume = ring(world, dims.shared_params() as f64 * 4.0)
        + ring(sub, dims.head_params() as f64 * 4.0);
    let link_gib_s = volume.max(1.0) / (s_comm.max(1e-9) * gib);
    let m = MachineProfile {
        name: "local",
        ranks_per_node: world,
        tflops,
        hbm_gib: 64.0,
        link_gib_s,
        latency_us: 0.0,
        noise_sigma: 0.0,
        max_gpus: world,
    };
    let predicted = predicted_overlap_win(&m, &w, SimMode::MtlPar, world, local_batch);

    // CONFRONTATION. Tiny in-process runs are noisy and the shared-memory
    // "fabric" is nothing like a real interconnect, so we demand sign
    // agreement within a documented generous band, not magnitude match:
    //  * the model never predicts a slowdown, so the measured run must
    //    not show one beyond the noise floor;
    //  * the measured win must not exceed FACTOR x prediction + noise —
    //    a larger win would mean the model's hideable-comm accounting
    //    (bounded by OVERLAP_WINDOW_FRACTION of compute) is wrong.
    const FACTOR: f64 = 8.0;
    const NOISE_FLOOR: f64 = 0.25;
    assert!((0.0..1.0).contains(&predicted), "predicted win {predicted} out of range");
    assert!(
        measured_win >= -NOISE_FLOOR,
        "overlap measured as a slowdown beyond noise: {measured_win:.3} \
         (sync {sync_step:.6}s vs overlapped {ov_step:.6}s per step)"
    );
    assert!(
        measured_win <= predicted * FACTOR + NOISE_FLOOR,
        "measured win {measured_win:.3} exceeds {FACTOR}x predicted {predicted:.3} \
         + {NOISE_FLOOR} noise floor (window fraction {OVERLAP_WINDOW_FRACTION})"
    );
}
