//! Integration: the 2D-parallel trainer. Exercises all four training modes
//! end to end with multi-rank meshes, and verifies the paper's
//! communication-pattern claims against the comm counters.
//!
//! These tests run on EVERY machine: `Engine::load` falls back to the
//! native pure-rust backend when no AOT artifacts / PJRT are available, so
//! nothing here skips on the default build.

use std::sync::Arc;

use hydra_mtp::config::{RunConfig, TrainMode};
use hydra_mtp::coordinator::{evaluate_model, DataBundle, Heads, Trainer};
use hydra_mtp::data::structures::{DatasetId, ALL_DATASETS};
use hydra_mtp::runtime::Engine;

/// Shared engine: PJRT when artifacts + the feature are available, the
/// native backend otherwise — never a skip.
fn engine() -> Arc<Engine> {
    use std::sync::OnceLock;
    static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            let e = Engine::load("artifacts").expect("engine loads on every machine");
            eprintln!("trainer tests run on the '{}' backend", e.backend_name());
            Arc::new(e)
        })
        .clone()
}

fn tiny_config(mode: TrainMode, replicas: usize, epochs: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.mode = mode;
    cfg.parallel.replicas = replicas;
    cfg.train.epochs = epochs;
    cfg.train.patience = 0;
    cfg.data.per_dataset = 48;
    cfg.data.max_atoms = 10;
    cfg
}

fn bundle(cfg: &RunConfig, datasets: &[DatasetId]) -> DataBundle {
    DataBundle::generate(&cfg.data, datasets)
}

#[test]
fn single_dataset_training_reduces_loss() {
    let e = engine();
    let cfg = tiny_config(TrainMode::Single(DatasetId::Ani1x), 1, 4);
    let data = bundle(&cfg, &[DatasetId::Ani1x]);
    let out = Trainer::new(e, cfg).train(&data).unwrap();
    let first = out.log.epochs.first().unwrap().train_loss;
    let last = out.log.epochs.last().unwrap().train_loss;
    assert!(last < first, "loss should fall: {first} -> {last}");
    assert!(matches!(out.model.heads, Heads::Shared(_)));
}

#[test]
fn ddp_replicas_match_single_rank_loss_trajectory() {
    // DDP invariant: with the same *global* sample pool, two replicas
    // averaging gradients behave like a larger-batch single rank — and the
    // encoder stays bit-synced (checked inside finalize).
    let e = engine();
    let cfg1 = tiny_config(TrainMode::Single(DatasetId::Qm7x), 2, 2);
    let data = bundle(&cfg1, &[DatasetId::Qm7x]);
    let out = Trainer::new(e, cfg1).train(&data).unwrap();
    assert!(out.log.epochs.iter().all(|e| e.train_loss.is_finite()));
    assert!(out.comm_elems.0 > 0, "DDP must communicate");
}

#[test]
fn mtl_par_trains_all_heads_on_mesh() {
    let e = engine();
    let cfg = tiny_config(TrainMode::MtlPar, 1, 2);
    let data = bundle(&cfg, &ALL_DATASETS);
    let out = Trainer::new(Arc::clone(&e), cfg).train(&data).unwrap();
    match &out.model.heads {
        Heads::PerDataset(m) => assert_eq!(m.len(), 5, "one branch per dataset"),
        _ => panic!("MTL-par must produce per-dataset heads"),
    }
    // Evaluate the trained model across every dataset: all finite.
    let scores = evaluate_model(&e, &out.model, &data.test).unwrap();
    assert_eq!(scores.len(), 5);
    for (d, (mae_e, mae_f)) in scores {
        assert!(mae_e.is_finite() && mae_f.is_finite(), "{}", d.name());
    }
}

#[test]
fn mtl_par_with_replicas_keeps_encoder_synced() {
    // 5 heads x 2 replicas = 10 rank threads; finalize asserts encoder sync.
    let e = engine();
    let cfg = tiny_config(TrainMode::MtlPar, 2, 1);
    let data = bundle(&cfg, &ALL_DATASETS);
    let out = Trainer::new(e, cfg).train(&data).unwrap();
    assert!(out.comm_elems.0 > 0 && out.comm_elems.1 > 0);
}

#[test]
fn mtl_base_trains_and_carries_all_heads_per_rank() {
    let e = engine();
    let cfg = tiny_config(TrainMode::MtlBase, 1, 2);
    let data = bundle(&cfg, &ALL_DATASETS);
    let out = Trainer::new(e, cfg).train(&data).unwrap();
    match &out.model.heads {
        Heads::PerDataset(m) => assert_eq!(m.len(), 5),
        _ => panic!("MTL-base must produce per-dataset heads"),
    }
    let first = out.log.epochs.first().unwrap().train_loss;
    let last = out.log.epochs.last().unwrap().train_loss;
    assert!(last < first * 1.5, "MTL-base should not diverge: {first} -> {last}");
}

#[test]
fn baseline_all_trains_one_head_on_mixed_stream() {
    let e = engine();
    let cfg = tiny_config(TrainMode::BaselineAll, 1, 2);
    let data = bundle(&cfg, &ALL_DATASETS);
    let out = Trainer::new(e, cfg).train(&data).unwrap();
    assert!(matches!(out.model.heads, Heads::Shared(_)));
    assert!(out.log.epochs.iter().all(|e| e.train_loss.is_finite()));
}

#[test]
fn comm_payloads_match_paper_claims() {
    // Paper Section 4.3 / 6: MTL-par replaces the global (P_s + N_h*P_h)
    // allreduce with a global P_s + per-subgroup P_h. Verify with counters.
    let e = engine();
    let dims = e.manifest.config.arch_dims();
    let ps = dims.shared_params() as u64;
    let ph = dims.head_params() as u64;

    let cfg_par = tiny_config(TrainMode::MtlPar, 1, 1);
    let data = bundle(&cfg_par, &ALL_DATASETS);
    let out_par = Trainer::new(Arc::clone(&e), cfg_par).train(&data).unwrap();

    let cfg_base = tiny_config(TrainMode::MtlBase, 1, 1);
    let out_base = Trainer::new(Arc::clone(&e), cfg_base).train(&data).unwrap();

    let steps_par = out_par.log.epochs.iter().map(|e| e.steps as u64).sum::<u64>();
    let steps_base = out_base.log.epochs.iter().map(|e| e.steps as u64).sum::<u64>();
    assert!(steps_par > 0 && steps_base > 0);

    // MTL-par global traffic = steps * P_s (+ small metric allgathers).
    let par_global_grad = steps_par * ps;
    assert!(
        out_par.comm_elems.0 >= par_global_grad
            && out_par.comm_elems.0 < par_global_grad + steps_par * ph / 4 + 10_000,
        "par global {} vs expected ~{par_global_grad}",
        out_par.comm_elems.0
    );
    // Head-group traffic = steps * P_h (exactly: no allgathers there).
    assert_eq!(out_par.comm_elems.1, steps_par * ph, "head-group payload");

    // MTL-base global traffic = steps * (P_s + 5*P_h) (+ allgathers).
    let base_global_grad = steps_base * (ps + 5 * ph);
    assert!(
        out_base.comm_elems.0 >= base_global_grad
            && out_base.comm_elems.0 < base_global_grad + steps_base * ph + 10_000,
        "base global {} vs expected ~{base_global_grad}",
        out_base.comm_elems.0
    );
    assert_eq!(out_base.comm_elems.1, 0, "MTL-base has no sub-groups");

    // Per step, MTL-par moves strictly less data through the global group.
    assert!(
        out_par.comm_elems.0 / steps_par < out_base.comm_elems.0 / steps_base,
        "MTL-par must shrink the global payload"
    );
}

#[test]
fn training_loss_and_mae_sequences_are_reproducible() {
    // The featurized pipeline's batch-level bit-identity to the seed planner
    // is proven engine-free in integration_featurized.rs; this closes the
    // loop end to end: identical config => bit-identical loss/MAE/val
    // sequences through real train/eval steps. Single-rank is the exactly
    // deterministic case (multi-rank reductions accumulate in thread-arrival
    // order, which the seed already only bounds to 1e-5 in encoder sync).
    let e = engine();
    let cfg = tiny_config(TrainMode::Single(DatasetId::Ani1x), 1, 3);
    let data = bundle(&cfg, &[DatasetId::Ani1x]);
    let a = Trainer::new(Arc::clone(&e), cfg.clone()).train(&data).unwrap();
    let b = Trainer::new(e, cfg).train(&data).unwrap();
    assert_eq!(a.log.epochs.len(), b.log.epochs.len());
    for (ea, eb) in a.log.epochs.iter().zip(&b.log.epochs) {
        assert_eq!(ea.steps, eb.steps, "epoch {}", ea.epoch);
        assert_eq!(ea.train_loss, eb.train_loss, "epoch {}", ea.epoch);
        assert_eq!(ea.mae_e, eb.mae_e, "epoch {}", ea.epoch);
        assert_eq!(ea.mae_f, eb.mae_f, "epoch {}", ea.epoch);
        assert_eq!(ea.val_loss, eb.val_loss, "epoch {}", ea.epoch);
    }
    assert_eq!(a.comm_elems, b.comm_elems, "communication pattern diverged");
}

#[test]
fn early_stopping_halts_before_epoch_budget() {
    let e = engine();
    let mut cfg = tiny_config(TrainMode::Single(DatasetId::MpTrj), 1, 30);
    cfg.train.patience = 2;
    cfg.train.lr = 1e-12; // effectively frozen: val loss cannot improve
    let data = bundle(&cfg, &[DatasetId::MpTrj]);
    let out = Trainer::new(e, cfg).train(&data).unwrap();
    assert!(
        out.log.epochs.len() <= 5,
        "frozen lr must trigger early stopping, ran {} epochs",
        out.log.epochs.len()
    );
}
