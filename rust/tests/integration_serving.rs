//! Integration: the serving subsystem (`Session::server` / `serve::*`).
//!
//! The load-bearing guarantee is bit-identity: N concurrent clients
//! through the coalescing server must receive exactly — to the last bit —
//! what N sequential `Predictor::predict_one` calls would return, at
//! either `Precision`. On top of that: the eval-only forward matches the
//! training-path forward bitwise (cached and uncached f32 views), the
//! admission budget refuses oversized structures with typed errors,
//! mixed task heads share one queue, shutdown refuses late work, and the
//! head cache stays bounded under eviction.
//!
//! Engines are pinned per precision via `Engine::native_with`, so these
//! tests are env-independent (`HYDRA_MTP_PRECISION` does not reach them).

use std::sync::Arc;

use hydra_mtp::config::ServeConfig;
use hydra_mtp::data::batch::BatchBuilder;
use hydra_mtp::data::generators::{DatasetGenerator, GeneratorConfig};
use hydra_mtp::data::structures::{AtomicStructure, DatasetId};
use hydra_mtp::model::egnn::{BranchParams, EgnnDims, EncoderParams, EvalWorkspace};
use hydra_mtp::model::params::ParamSet;
use hydra_mtp::runtime::{Engine, ManifestConfig, Precision};
use hydra_mtp::serve::loadtest::synthetic_model;
use hydra_mtp::serve::{ServeError, Server};
use hydra_mtp::session::{Prediction, Predictor};

// ---------------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------------

/// Small dims: multi-graph batches, both EGNN layers, fast in debug.
fn small_config() -> ManifestConfig {
    let mut c = ManifestConfig::default_native();
    c.max_nodes = 64;
    c.max_edges = 512;
    c.max_graphs = 8;
    c.hidden = 32;
    c.num_layers = 2;
    c.num_rbf = 8;
    c.head_hidden = 32;
    c
}

fn engine(p: Precision) -> Arc<Engine> {
    Arc::new(Engine::native_with(small_config(), p))
}

fn serve_cfg(workers: usize) -> ServeConfig {
    ServeConfig {
        workers,
        queue_capacity: 64,
        enqueue_wait_ms: 5_000,
        latency_budget_ms: 1_000.0,
    }
}

/// `n` structures per task, interleaved across tasks in round-robin order
/// (so consecutive requests mix heads).
fn structures(tasks: &[DatasetId], n: usize) -> Vec<AtomicStructure> {
    let cfg = GeneratorConfig { max_atoms: 8, ..Default::default() };
    let per: Vec<Vec<AtomicStructure>> = tasks
        .iter()
        .map(|&d| DatasetGenerator::new(d, 42, cfg.clone()).take(n))
        .collect();
    let mut out = Vec::with_capacity(tasks.len() * n);
    for i in 0..n {
        for s in &per {
            out.push(s[i].clone());
        }
    }
    out
}

fn assert_prediction_bits_eq(a: &Prediction, b: &Prediction, what: &str) {
    assert_eq!(a.dataset, b.dataset, "{what}: dataset");
    assert_eq!(
        a.energy.to_bits(),
        b.energy.to_bits(),
        "{what}: energy {} vs {}",
        a.energy,
        b.energy
    );
    assert_eq!(
        a.energy_per_atom.to_bits(),
        b.energy_per_atom.to_bits(),
        "{what}: energy/atom"
    );
    assert_eq!(a.forces.len(), b.forces.len(), "{what}: natoms");
    for (i, (fa, fb)) in a.forces.iter().zip(&b.forces).enumerate() {
        for k in 0..3 {
            assert_eq!(
                fa[k].to_bits(),
                fb[k].to_bits(),
                "{what}: force[{i}][{k}]: {} vs {}",
                fa[k],
                fb[k]
            );
        }
    }
}

/// Run every structure through `clients` concurrent threads against the
/// server (round-robin split), returning predictions in input order.
fn predict_concurrently(
    server: &Server,
    structures: &[AtomicStructure],
    clients: usize,
) -> Vec<Prediction> {
    let mut out: Vec<Option<Prediction>> = vec![None; structures.len()];
    let results: Vec<Vec<(usize, Prediction)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                scope.spawn(move || {
                    structures
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| i % clients == c)
                        .map(|(i, s)| (i, server.predict(s).expect("request served")))
                        .collect()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client panicked")).collect()
    });
    for r in results {
        for (i, p) in r {
            out[i] = Some(p);
        }
    }
    out.into_iter().map(|p| p.expect("every slot answered")).collect()
}

// ---------------------------------------------------------------------------
// bit-identity
// ---------------------------------------------------------------------------

#[test]
fn coalesced_server_matches_sequential_predict_one_bitwise() {
    // The tentpole guarantee, at both precisions: concurrent clients
    // through the coalescing queue == one-by-one predict_one, every bit.
    for p in [Precision::F64, Precision::MixedF32] {
        let e = engine(p);
        let tasks = [DatasetId::Ani1x, DatasetId::Qm7x];
        let model = synthetic_model(&e, &tasks, 7);
        let ss = structures(&tasks, 8); // 16 requests, interleaved tasks

        let mut seq = Predictor::new(Arc::clone(&e), model.clone());
        let expected: Vec<Prediction> =
            ss.iter().map(|s| seq.predict_one(s).unwrap()).collect();

        // One worker, one client per request: while the worker executes a
        // batch the remaining clients pile into the queue, so coalescing
        // must kick in.
        let server = Server::start(Arc::clone(&e), model, serve_cfg(1)).unwrap();
        let got = predict_concurrently(&server, &ss, ss.len());
        let stats = server.stats();
        server.shutdown();

        assert_eq!(stats.served, ss.len() as u64, "{}: all served", p.name());
        assert!(
            stats.batches < ss.len() as u64,
            "{}: requests coalesced ({} batches for {} requests)",
            p.name(),
            stats.batches,
            ss.len()
        );
        for (i, (a, b)) in expected.iter().zip(&got).enumerate() {
            assert_prediction_bits_eq(a, b, &format!("{} request {i}", p.name()));
        }
    }
}

#[test]
fn eval_workspace_matches_engine_forward_bitwise() {
    // The eval-only forward (serving path) vs the training-path forward
    // behind Engine::forward — cached f32 views and uncached — all bitwise.
    for p in [Precision::F64, Precision::MixedF32] {
        let e = engine(p);
        let mut g = DatasetGenerator::new(
            DatasetId::Qm7x,
            77,
            GeneratorConfig { max_atoms: 6, ..Default::default() },
        );
        let samples = g.take(4);
        let batch = BatchBuilder::build_all(
            e.manifest.config.batch_dims(),
            e.manifest.config.cutoff,
            &samples,
        )
        .into_iter()
        .next()
        .expect("at least one batch");
        let full = ParamSet::init(&e.manifest.params, 5);
        let (energy, forces) = e.forward(&full, &batch).unwrap();
        let (ev, fv) = (energy.as_f32(), forces.as_f32());

        let dims = EgnnDims::from_config_with(&e.manifest.config, p);
        let mut enc = EncoderParams::from_set(&dims, &full.subset("encoder.")).unwrap();
        let mut br = BranchParams::from_set(&dims, &full.subset("branch.")).unwrap();
        for cached in [false, true] {
            if cached {
                enc.cache_f32();
                br.cache_f32();
            }
            let mut ws = EvalWorkspace::new(&dims);
            ws.run(&dims, &enc, &br, &batch).unwrap();
            let tag = if cached { "cached" } else { "uncached" };
            for (i, (a, b)) in ev.iter().zip(ws.energy_per_atom()).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{} {tag}: e_pa[{i}]: {a} vs {b}",
                    p.name()
                );
            }
            for (i, (a, b)) in fv.iter().zip(ws.forces()).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{} {tag}: force[{i}]: {a} vs {b}",
                    p.name()
                );
            }
        }
    }
}

#[test]
fn predictor_cached_views_match_manual_engine_forward() {
    // The refactored Predictor (prepared params, cached f32 views, recycled
    // workspace) must reproduce the manual full_params + engine.forward
    // chain it replaced — per structure, both precisions.
    for p in [Precision::F64, Precision::MixedF32] {
        let e = engine(p);
        let tasks = [DatasetId::Ani1x];
        let model = synthetic_model(&e, &tasks, 11);
        let ss = structures(&tasks, 5);

        let full = model.full_params(&e, DatasetId::Ani1x).unwrap();
        let mut predictor = Predictor::new(Arc::clone(&e), model.clone());
        for (i, s) in ss.iter().enumerate() {
            let batch = BatchBuilder::build_all(
                e.manifest.config.batch_dims(),
                e.manifest.config.cutoff,
                std::slice::from_ref(s),
            )
            .into_iter()
            .next()
            .unwrap();
            let (energy, forces) = e.forward(&full, &batch).unwrap();
            let epa = energy.as_f32()[0] as f64;
            let got = predictor.predict_one(s).unwrap();
            assert_eq!(
                got.energy_per_atom.to_bits(),
                epa.to_bits(),
                "{} structure {i}: e/atom",
                p.name()
            );
            let fv = forces.as_f32();
            for (k, f) in got.forces.iter().enumerate() {
                for x in 0..3 {
                    assert_eq!(
                        f[x].to_bits(),
                        (fv[k * 3 + x] as f64).to_bits(),
                        "{} structure {i}: force[{k}][{x}]",
                        p.name()
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// admission budget + typed refusals
// ---------------------------------------------------------------------------

#[test]
fn oversized_and_unserved_requests_are_refused_typed() {
    let e = engine(Precision::F64);
    let tasks = [DatasetId::Ani1x];
    let model = synthetic_model(&e, &tasks, 3);
    let server = Server::start(Arc::clone(&e), model.clone(), serve_cfg(1)).unwrap();

    // A structure over the node budget even alone: typed TooLarge, counted
    // as a rejection, and the queue/workers never see it. Atoms sit far
    // apart so the edge list stays empty.
    let n = small_config().max_nodes + 1;
    let big = AtomicStructure {
        species: vec![1; n],
        positions: (0..n).map(|i| [i as f64 * 100.0, 0.0, 0.0]).collect(),
        energy: 0.0,
        forces: vec![[0.0; 3]; n],
        dataset: DatasetId::Ani1x,
    };
    match server.predict(&big) {
        Err(ServeError::TooLarge { natoms, nedges, .. }) => {
            assert_eq!(natoms, n);
            assert_eq!(nedges, 0);
        }
        other => panic!("expected TooLarge, got {other:?}"),
    }

    // No head for the task: typed NoHead, before featurization.
    let mut g = DatasetGenerator::new(
        DatasetId::Qm7x,
        9,
        GeneratorConfig { max_atoms: 6, ..Default::default() },
    );
    let unserved = g.take(1).pop().unwrap();
    match server.predict(&unserved) {
        Err(ServeError::NoHead { model: m, task }) => {
            assert_eq!(m, model.name);
            assert_eq!(task, DatasetId::Qm7x);
        }
        other => panic!("expected NoHead, got {other:?}"),
    }
    assert_eq!(server.stats().rejected, 2);
    assert_eq!(server.stats().served, 0);
    server.shutdown();

    // The Predictor path refuses the same structure with its (stable)
    // error string, and an empty predict is an empty vec, not an error.
    let mut predictor = Predictor::new(Arc::clone(&e), model);
    let err = predictor.predict_one(&big).unwrap_err();
    assert!(
        format!("{err}").contains("exceeds the compiled batch budget"),
        "unexpected error: {err}"
    );
    assert!(predictor.predict(&[]).unwrap().is_empty());
}

#[test]
fn mixed_task_heads_share_one_queue_and_coalesce_per_task() {
    // Interleaved requests for three different heads through one server:
    // every request routed to its own head, outputs bitwise equal to the
    // sequential baseline, and coalescing still kicks in (same-task
    // requests skip ahead past other-task neighbours in the queue).
    let e = engine(Precision::MixedF32);
    let tasks = [DatasetId::Ani1x, DatasetId::Qm7x, DatasetId::Transition1x];
    let model = synthetic_model(&e, &tasks, 19);
    let ss = structures(&tasks, 6); // 18 requests, strict task interleave

    let mut seq = Predictor::new(Arc::clone(&e), model.clone());
    let expected: Vec<Prediction> =
        ss.iter().map(|s| seq.predict_one(s).unwrap()).collect();

    let server = Server::start(Arc::clone(&e), model, serve_cfg(2)).unwrap();
    let got = predict_concurrently(&server, &ss, 6);
    let stats = server.stats();
    server.shutdown();

    assert_eq!(stats.served, ss.len() as u64);
    for ((s, a), b) in ss.iter().zip(&expected).zip(&got) {
        assert_eq!(b.dataset, s.dataset, "routed to the structure's own head");
        assert_prediction_bits_eq(a, b, "mixed-head request");
    }
}

// ---------------------------------------------------------------------------
// shutdown + bounded head cache
// ---------------------------------------------------------------------------

#[test]
fn shutdown_answers_inflight_then_refuses_new_work() {
    let e = engine(Precision::F64);
    let tasks = [DatasetId::Ani1x];
    let model = synthetic_model(&e, &tasks, 3);
    let ss = structures(&tasks, 6);

    let server = Server::start(Arc::clone(&e), model, serve_cfg(1)).unwrap();
    // Every in-flight request is answered...
    let got = predict_concurrently(&server, &ss, 3);
    assert_eq!(got.len(), ss.len());
    server.shutdown();
    // ...and post-shutdown submissions get the typed refusal. (Drain
    // semantics — queued jobs answered between shutdown() and worker exit —
    // are pinned down in serve::queue's unit tests.)
    match server.predict(&ss[0]) {
        Err(ServeError::ShuttingDown) => {}
        other => panic!("expected ShuttingDown, got {other:?}"),
    }
    // Idempotent.
    server.shutdown();
}

#[test]
fn head_cache_is_bounded_and_evicts_without_changing_outputs() {
    // Regression for the unbounded Predictor::full_cache: with a cap of 2
    // and three live heads, the cache never exceeds 2 entries and every
    // prediction still matches an uncapped predictor bitwise (eviction
    // only costs a rebuild, never correctness).
    let e = engine(Precision::MixedF32);
    let tasks = [DatasetId::Ani1x, DatasetId::Qm7x, DatasetId::Transition1x];
    let model = synthetic_model(&e, &tasks, 23);
    let ss = structures(&tasks, 4); // cycles through all three heads twice+

    let mut unbounded = Predictor::new(Arc::clone(&e), model.clone());
    let mut capped = Predictor::with_head_cap(Arc::clone(&e), model, 2);
    for (i, s) in ss.iter().enumerate() {
        let a = unbounded.predict_one(s).unwrap();
        let b = capped.predict_one(s).unwrap();
        assert_prediction_bits_eq(&a, &b, &format!("request {i}"));
        assert!(
            capped.cached_heads() <= 2,
            "head cache exceeded its cap: {}",
            capped.cached_heads()
        );
    }
    assert_eq!(unbounded.cached_heads(), 3, "uncapped predictor holds all heads");
    assert_eq!(capped.cached_heads(), 2, "capped predictor evicted down to 2");
}
