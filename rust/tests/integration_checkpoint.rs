//! Integration: the checkpoint/serialization subsystem and the
//! imbalanced-dataset training fixes.
//!
//! Engine-free tests cover the binary format (round-trip bit-identity, CRC
//! corruption rejection, bundle validation) and run everywhere, including
//! artifact-less CI. Engine-gated tests prove the headline property:
//! **resume-at-epoch-k is bit-identical to an uninterrupted run** across
//! all three training modes — same style as the featurized-pipeline
//! oracles of PR 2.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use hydra_mtp::checkpoint::{self, OptHeads, TrainCheckpoint};
use hydra_mtp::config::{RunConfig, TrainMode};
use hydra_mtp::coordinator::trainer::validate_bundle;
use hydra_mtp::coordinator::{DataBundle, Heads, RunLog, StepAccum, TrainedModel, Trainer};
use hydra_mtp::data::structures::{DatasetId, ALL_DATASETS};
use hydra_mtp::model::optimizer::AdamWState;
use hydra_mtp::model::params::{Init, LeafMeta, ParamSet};
use hydra_mtp::runtime::Engine;
use hydra_mtp::session::Session;
use hydra_mtp::tensor::{DType, Tensor};

// ---------------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------------

/// Shared engine: PJRT when artifacts + the feature are available, the
/// native pure-rust backend otherwise — the resume-parity tests run (for
/// real, training included) on every machine.
fn engine() -> Arc<Engine> {
    use std::sync::OnceLock;
    static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            let e = Engine::load("artifacts").expect("engine loads on every machine");
            eprintln!("checkpoint tests run on the '{}' backend", e.backend_name());
            Arc::new(e)
        })
        .clone()
}

fn tiny_config(mode: TrainMode, epochs: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.mode = mode;
    cfg.parallel.replicas = 1;
    cfg.train.epochs = epochs;
    cfg.train.patience = 0;
    cfg.data.per_dataset = 40;
    cfg.data.max_atoms = 10;
    cfg
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("hydra_mtp_ckpt_it_{}_{name}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn assert_params_bits_eq(a: &ParamSet, b: &ParamSet, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: leaf count");
    for ((na, ta), (nb, tb)) in a.iter().zip(b.iter()) {
        assert_eq!(na, nb, "{what}: leaf name");
        assert_eq!(ta.dtype(), tb.dtype(), "{what}: {na} dtype");
        assert_eq!(ta.shape, tb.shape, "{what}: {na} shape");
        match ta.dtype() {
            DType::F32 => {
                let (xa, xb) = (ta.as_f32(), tb.as_f32());
                assert_eq!(xa.len(), xb.len(), "{what}: {na} numel");
                for (i, (x, y)) in xa.iter().zip(xb).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{what}: {na}[{i}]: {x} vs {y} (bitwise)"
                    );
                }
            }
            DType::I32 => assert_eq!(ta.as_i32(), tb.as_i32(), "{what}: {na}"),
        }
    }
}

fn assert_models_bits_eq(a: &TrainedModel, b: &TrainedModel) {
    assert_params_bits_eq(&a.encoder, &b.encoder, "encoder");
    match (&a.heads, &b.heads) {
        (Heads::Shared(x), Heads::Shared(y)) => assert_params_bits_eq(x, y, "shared head"),
        (Heads::PerDataset(x), Heads::PerDataset(y)) => {
            assert_eq!(x.len(), y.len(), "head count");
            for (d, bx) in x {
                assert_params_bits_eq(bx, &y[d], &format!("head {}", d.name()));
            }
        }
        _ => panic!("heads kind mismatch"),
    }
}

/// Trajectory equality ignoring wall-clock timings (those legitimately
/// differ between runs; everything numeric must match to the last bit).
fn assert_logs_bits_eq(a: &RunLog, b: &RunLog) {
    assert_eq!(a.epochs.len(), b.epochs.len(), "epoch count");
    for (ea, eb) in a.epochs.iter().zip(&b.epochs) {
        assert_eq!(ea.epoch, eb.epoch);
        assert_eq!(ea.steps, eb.steps, "epoch {}", ea.epoch);
        assert_eq!(
            ea.train_loss.to_bits(),
            eb.train_loss.to_bits(),
            "epoch {} train_loss {} vs {}",
            ea.epoch,
            ea.train_loss,
            eb.train_loss
        );
        assert_eq!(ea.mae_e.to_bits(), eb.mae_e.to_bits(), "epoch {}", ea.epoch);
        assert_eq!(ea.mae_f.to_bits(), eb.mae_f.to_bits(), "epoch {}", ea.epoch);
        assert_eq!(
            ea.val_loss.to_bits(),
            eb.val_loss.to_bits(),
            "epoch {} val_loss",
            ea.epoch
        );
        // Coverage matches on the deterministic fields; step_ms is a
        // wall-clock EMA and legitimately differs between runs.
        assert_eq!(ea.coverage.len(), eb.coverage.len(), "epoch {} coverage", ea.epoch);
        for (ca, cb) in ea.coverage.iter().zip(&eb.coverage) {
            assert_eq!(ca.dataset, cb.dataset, "epoch {}", ea.epoch);
            assert_eq!(ca.planned, cb.planned, "epoch {} {}", ea.epoch, ca.dataset);
            assert_eq!(ca.used, cb.used, "epoch {} {}", ea.epoch, ca.dataset);
        }
    }
}

/// Synthetic parameter set with awkward bit patterns (-0.0, NaN, inf,
/// denormals) that only an exact binary encoding survives.
fn gnarly_params() -> ParamSet {
    let metas = vec![
        LeafMeta {
            name: "branch.trunk.w".into(),
            shape: vec![2, 3],
            dtype: DType::F32,
            init: Some(Init::Lecun { fan_in: 2 }),
        },
        LeafMeta {
            name: "encoder.embed".into(),
            shape: vec![4],
            dtype: DType::F32,
            init: Some(Init::Normal { scale: 0.5 }),
        },
        LeafMeta { name: "encoder.ids".into(), shape: vec![3], dtype: DType::I32, init: None },
    ];
    let tensors = vec![
        Tensor::from_f32(&[2, 3], vec![1.5, -0.0, f32::NAN, f32::INFINITY, 1e-42, -7.25]),
        Tensor::from_f32(&[4], vec![0.1, 0.2, 0.3, f32::NEG_INFINITY]),
        Tensor::from_i32(&[3], vec![-1, 0, i32::MAX]),
    ];
    ParamSet::from_parts(metas, tensors).unwrap()
}

fn synthetic_train_checkpoint() -> TrainCheckpoint {
    let p = gnarly_params();
    let mut log = RunLog::new("GFM-MTL-All (MTL-base)");
    let mut acc = StepAccum::default();
    acc.record_step(1.25, 0.5, 0.25);
    acc.data = std::time::Duration::new(3, 141_592_653);
    log.push(acc.into_epoch(0, std::time::Duration::new(7, 999_999_999), 2.5));
    let heads: BTreeMap<DatasetId, ParamSet> = [
        (DatasetId::Ani1x, p.subset("branch.")),
        (DatasetId::MpTrj, p.subset("branch.")),
    ]
    .into_iter()
    .collect();
    let opt = AdamWState {
        m: vec![vec![0.5, -0.0, 2.0e-40, 1.0, -1.0, 0.0]],
        v: vec![vec![0.25; 6]],
        step: 17,
    };
    TrainCheckpoint {
        mode: "GFM-MTL-All (MTL-base)".into(),
        train_seed: 7,
        config_fingerprint: "unit-test-fingerprint".into(),
        epochs_done: 1,
        stopped: false,
        stopper_best: 2.5,
        stopper_bad_epochs: 0,
        model: TrainedModel {
            name: "GFM-MTL-All (MTL-base)".into(),
            encoder: p.subset("encoder."),
            heads: Heads::PerDataset(heads),
        },
        opt_encoder: AdamWState {
            m: vec![vec![0.0, f32::NAN, 3.5, -0.0], vec![1.0, 2.0, 3.0]],
            v: vec![vec![0.5; 4], vec![0.25; 3]],
            step: 17,
        },
        opt_heads: OptHeads::PerDataset(vec![
            ("ANI1x".into(), opt.clone()),
            ("MPTrj".into(), opt),
        ]),
        log,
        comm_global: 123_456_789,
        comm_head: 42,
    }
}

// ---------------------------------------------------------------------------
// engine-free: format round-trip + corruption + validation
// ---------------------------------------------------------------------------

#[test]
fn train_checkpoint_roundtrips_every_field_bit_for_bit() {
    let ckpt = synthetic_train_checkpoint();
    let dir = tmp_dir("roundtrip");
    let path = dir.join("ck.ckpt");
    checkpoint::save_train(&ckpt, &path).unwrap();
    let back = checkpoint::load_train(&path).unwrap();

    assert_eq!(back.mode, ckpt.mode);
    assert_eq!(back.train_seed, ckpt.train_seed);
    assert_eq!(back.config_fingerprint, ckpt.config_fingerprint);
    assert_eq!(back.epochs_done, ckpt.epochs_done);
    assert_eq!(back.stopped, ckpt.stopped);
    assert_eq!(back.stopper_best.to_bits(), ckpt.stopper_best.to_bits());
    assert_eq!(back.stopper_bad_epochs, ckpt.stopper_bad_epochs);
    assert_models_bits_eq(&back.model, &ckpt.model);
    assert_eq!(back.opt_encoder.step, ckpt.opt_encoder.step);
    // Moment vectors bit-for-bit (NaN-bearing, so compare bit patterns).
    for (a, b) in back.opt_encoder.m.iter().zip(&ckpt.opt_encoder.m) {
        let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
        let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
        assert_eq!(ab, bb, "encoder first moments");
    }
    assert_eq!(back.opt_heads, ckpt.opt_heads);
    // Durations round-trip exactly (stored as secs + nanos, not float).
    assert_eq!(back.log.model_name, ckpt.log.model_name);
    assert_eq!(back.log.epochs[0].time_data, ckpt.log.epochs[0].time_data);
    assert_eq!(back.log.epochs[0].time_total, ckpt.log.epochs[0].time_total);
    assert_eq!(back.log.epochs[0].steps, ckpt.log.epochs[0].steps);
    assert_eq!(back.comm_global, ckpt.comm_global);
    assert_eq!(back.comm_head, ckpt.comm_head);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn corrupted_checkpoint_is_rejected_via_crc() {
    let ckpt = synthetic_train_checkpoint();
    let dir = tmp_dir("corrupt");
    let path = dir.join("ck.ckpt");
    checkpoint::save_train(&ckpt, &path).unwrap();
    let clean = std::fs::read(&path).unwrap();

    // Flip a single bit at several positions inside the payload; every one
    // must be rejected loudly, never silently loaded.
    for frac in [0.2, 0.5, 0.8] {
        let mut bytes = clean.clone();
        let pos = 17 + ((bytes.len() - 25) as f64 * frac) as usize;
        bytes[pos] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = checkpoint::load_train(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("checksum") || msg.contains("corrupt"),
            "flip at {pos}: expected a CRC error, got: {msg}"
        );
    }

    // Truncation is caught before the CRC even runs.
    std::fs::write(&path, &clean[..clean.len() / 2]).unwrap();
    assert!(checkpoint::load_train(&path).is_err());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn validate_for_catches_mode_seed_config_and_head_mismatches() {
    let ckpt = synthetic_train_checkpoint();
    let fp = "unit-test-fingerprint";
    ckpt.validate_for(
        "GFM-MTL-All (MTL-base)",
        7,
        fp,
        &[DatasetId::Ani1x, DatasetId::MpTrj],
    )
    .unwrap();
    let err = ckpt
        .validate_for("GFM-MTL-All (MTL-par)", 7, fp, &[DatasetId::Ani1x])
        .unwrap_err();
    assert!(format!("{err}").contains("mode"), "{err}");
    let err = ckpt
        .validate_for("GFM-MTL-All (MTL-base)", 8, fp, &[DatasetId::Ani1x])
        .unwrap_err();
    assert!(format!("{err}").contains("seed"), "{err}");
    // A changed trajectory knob (e.g. --replicas or --lr) changes the
    // fingerprint and must be refused, not silently diverge.
    let err = ckpt
        .validate_for("GFM-MTL-All (MTL-base)", 7, "other-config", &[DatasetId::Ani1x])
        .unwrap_err();
    assert!(format!("{err}").contains("trajectory config"), "{err}");
    let err = ckpt
        .validate_for("GFM-MTL-All (MTL-base)", 7, fp, &[DatasetId::Qm7x])
        .unwrap_err();
    assert!(format!("{err}").contains("no head"), "{err}");
}

#[test]
fn empty_bundle_is_a_config_error_not_a_panic() {
    // Regression: `train_ddp` used to panic via `&datasets[..1]` deep in a
    // rank thread when the bundle had no datasets.
    let empty = DataBundle {
        train: BTreeMap::new(),
        val: BTreeMap::new(),
        test: BTreeMap::new(),
    };
    let err = validate_bundle(TrainMode::BaselineAll, &empty).unwrap_err();
    assert!(format!("{err}").contains("no datasets"), "{err}");
    let err = validate_bundle(TrainMode::MtlPar, &empty).unwrap_err();
    assert!(format!("{err}").contains("no datasets"), "{err}");

    // A bundle that lacks the requested single dataset is also an error.
    let cfg = tiny_config(TrainMode::Single(DatasetId::Ani1x), 1);
    let data = DataBundle::generate(&cfg.data, &[DatasetId::Qm7x]);
    let err = validate_bundle(TrainMode::Single(DatasetId::Ani1x), &data).unwrap_err();
    assert!(format!("{err}").contains("ANI1x"), "{err}");
    validate_bundle(TrainMode::Single(DatasetId::Qm7x), &data).unwrap();
}

#[test]
fn writes_sample_checkpoint_artifact_for_ci() {
    // CI runs this test in release and uploads target/ckpt_ci/ as the
    // `sample_checkpoint` build artifact (see .github/workflows/ci.yml).
    let dir = std::path::Path::new("target/ckpt_ci");
    std::fs::create_dir_all(dir).unwrap();
    let ckpt = synthetic_train_checkpoint();
    let train_path = dir.join("sample_train.ckpt");
    checkpoint::save_train(&ckpt, &train_path).unwrap();
    let model_path = dir.join("sample_model.ckpt");
    checkpoint::save_model(&ckpt.model, &model_path).unwrap();

    let back = checkpoint::load_model(&model_path).unwrap();
    assert_models_bits_eq(&back, &ckpt.model);
    let back = checkpoint::load_train(&train_path).unwrap();
    assert_eq!(back.epochs_done, ckpt.epochs_done);
}

// ---------------------------------------------------------------------------
// engine-gated: resume parity across all three modes
// ---------------------------------------------------------------------------

/// Uninterrupted run of `epochs` vs "killed at epoch k": train k epochs
/// with checkpointing, then resume to `epochs` from the written file. The
/// final model and the full metrics trajectory must match to the last bit.
fn resume_parity_case(e: Arc<Engine>, mode: TrainMode, datasets: &[DatasetId], name: &str) {
    let epochs = 4;
    let k = 2;
    let cfg_full = tiny_config(mode, epochs);
    let data = DataBundle::generate(&cfg_full.data, datasets);

    let full = Trainer::new(Arc::clone(&e), cfg_full.clone()).train(&data).unwrap();

    let dir = tmp_dir(name);
    let mut cfg_phase1 = tiny_config(mode, k);
    cfg_phase1.checkpoint.dir = Some(dir.to_string_lossy().into_owned());
    Trainer::new(Arc::clone(&e), cfg_phase1).train(&data).unwrap();
    assert!(
        checkpoint::epoch_path(&dir, k).is_file(),
        "phase 1 must write epoch_{k:04}.ckpt"
    );

    let mut cfg_phase2 = tiny_config(mode, epochs);
    // Resume from the DIRECTORY: the newest epoch_*.ckpt (k) is picked up.
    cfg_phase2.checkpoint.resume = Some(dir.to_string_lossy().into_owned());
    let resumed = Trainer::new(Arc::clone(&e), cfg_phase2).train(&data).unwrap();

    assert_models_bits_eq(&resumed.model, &full.model);
    assert_logs_bits_eq(&resumed.log, &full.log);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn resume_parity_single_mode() {
    let e = engine();
    resume_parity_case(e, TrainMode::Single(DatasetId::Ani1x), &[DatasetId::Ani1x], "single");
}

#[test]
fn resume_parity_mtl_base() {
    let e = engine();
    resume_parity_case(
        e,
        TrainMode::MtlBase,
        &[DatasetId::Ani1x, DatasetId::Qm7x, DatasetId::MpTrj],
        "mtlbase",
    );
}

#[test]
fn resume_parity_mtl_par() {
    // The hard case: a 3-head mesh. Bit-parity here relies on the
    // rank-order-deterministic collectives (see comm::collectives).
    let e = engine();
    resume_parity_case(
        e,
        TrainMode::MtlPar,
        &[DatasetId::Ani1x, DatasetId::Qm7x, DatasetId::MpTrj],
        "mtlpar",
    );
}

#[test]
fn resume_refuses_a_corrupted_checkpoint() {
    let e = engine();
    let cfg = tiny_config(TrainMode::Single(DatasetId::Qm7x), 1);
    let data = DataBundle::generate(&cfg.data, &[DatasetId::Qm7x]);
    let dir = tmp_dir("refuse");
    let mut cfg1 = cfg.clone();
    cfg1.checkpoint.dir = Some(dir.to_string_lossy().into_owned());
    Trainer::new(Arc::clone(&e), cfg1).train(&data).unwrap();

    let path = checkpoint::epoch_path(&dir, 1);
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();

    let mut cfg2 = tiny_config(TrainMode::Single(DatasetId::Qm7x), 2);
    cfg2.checkpoint.resume = Some(path.to_string_lossy().into_owned());
    let err = Trainer::new(e, cfg2).train(&data).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("checksum") || msg.contains("corrupt"),
        "corrupted resume must fail via CRC, got: {msg}"
    );
    std::fs::remove_dir_all(dir).ok();
}

// ---------------------------------------------------------------------------
// engine-gated: imbalanced MTL-base coverage regression
// ---------------------------------------------------------------------------

#[test]
fn mtl_base_covers_the_largest_dataset_and_cycles_the_smallest() {
    // Regression for the min-batches truncation bug: a 240-vs-8 sample
    // imbalance used to cut every epoch to the SMALL dataset's batch
    // count, discarding most of the large source. Now the epoch runs to
    // the LARGEST count, the small dataset cycles modulo its length, and
    // the run log records per-dataset coverage.
    let e = engine();
    let mut big_cfg = tiny_config(TrainMode::MtlBase, 1);
    big_cfg.data.per_dataset = 240;
    let big = DataBundle::generate(&big_cfg.data, &[DatasetId::Ani1x]);
    let mut small_cfg = tiny_config(TrainMode::MtlBase, 1);
    small_cfg.data.per_dataset = 8;
    let small = DataBundle::generate(&small_cfg.data, &[DatasetId::Qm7x]);

    let mut train = big.train;
    train.extend(small.train);
    let mut val = big.val;
    val.extend(small.val);
    let mut test = big.test;
    test.extend(small.test);
    let data = DataBundle { train, val, test };

    let out = Trainer::new(e, big_cfg).train(&data).unwrap();
    let epoch = &out.log.epochs[0];
    let cov_big = epoch
        .coverage
        .iter()
        .find(|c| c.dataset == "ANI1x")
        .expect("coverage recorded for the big dataset");
    let cov_small = epoch
        .coverage
        .iter()
        .find(|c| c.dataset == "QM7-X")
        .expect("coverage recorded for the small dataset");

    assert!(
        cov_big.planned > cov_small.planned,
        "test needs real imbalance: {} vs {} batches",
        cov_big.planned,
        cov_small.planned
    );
    assert_eq!(
        cov_big.used, cov_big.planned,
        "the large dataset must be fully covered (seed truncated it to {})",
        cov_small.planned
    );
    assert!(
        cov_small.used > cov_small.planned,
        "the small dataset must cycle modulo its length"
    );
    assert_eq!(epoch.steps, cov_big.planned, "epoch runs to the max batch count");
}

// ---------------------------------------------------------------------------
// engine-gated: model save/load + warm-start fine-tuning
// ---------------------------------------------------------------------------

#[test]
fn saved_model_predicts_identically_after_reload() {
    let e = engine();
    let cfg = tiny_config(TrainMode::MtlPar, 2);
    let mut session = Session::builder()
        .engine(Arc::clone(&e))
        .config(cfg)
        .tasks(&ALL_DATASETS)
        .build()
        .unwrap();
    let out = session.train().unwrap();

    let dir = tmp_dir("model_io");
    let path = dir.join("model.ckpt");
    session.save_model(&out.model, &path).unwrap();
    let loaded = Session::load_model(&path).unwrap();
    assert_models_bits_eq(&loaded, &out.model);

    let samples = session.test_samples(3).unwrap();
    let mut pred_a = session.predictor(&out.model);
    let a = pred_a.predict(&samples).unwrap();
    let mut pred_b = session.predictor(&loaded);
    let b = pred_b.predict(&samples).unwrap();
    assert_eq!(a.len(), b.len());
    for (pa, pb) in a.iter().zip(&b) {
        assert_eq!(pa.energy.to_bits(), pb.energy.to_bits());
        assert_eq!(pa.energy_per_atom.to_bits(), pb.energy_per_atom.to_bits());
        assert_eq!(pa.forces.len(), pb.forces.len());
        for (fa, fb) in pa.forces.iter().zip(&pb.forces) {
            for i in 0..3 {
                assert_eq!(fa[i].to_bits(), fb[i].to_bits());
            }
        }
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn warm_start_fine_tunes_a_new_head_on_a_frozen_encoder() {
    use hydra_mtp::tasks::{
        FidelityProfile, GeneratorProfile, StructureKind, TaskRegistry, TaskSpec,
    };
    let e = engine();

    // Pre-train on the five presets...
    let cfg = tiny_config(TrainMode::MtlPar, 2);
    let mut session = Session::builder()
        .engine(Arc::clone(&e))
        .config(cfg)
        .build()
        .unwrap();
    let base = session.train().unwrap();

    // ...then register a brand-new task and fine-tune only its head.
    let seventh = TaskRegistry::global()
        .register(TaskSpec::new(
            "CkptWarmStart",
            vec![1, 6, 8],
            GeneratorProfile {
                kind: StructureKind::Molecule { min_atoms: 4, atoms_cap: 10 },
                relax_steps: 5,
                relax_step_size: 0.05,
                perturb_factor: 1.0,
            },
            FidelityProfile {
                seed_tag: 131,
                shift_sigma: 0.6,
                scale_jitter: 0.02,
                force_scale_jitter: 0.01,
                energy_noise: 0.002,
                force_noise: 0.004,
                shift_offset: 0.0,
            },
        ))
        .unwrap();

    let tuned = session.fine_tune(&base.model, seventh).unwrap();

    // The encoder is frozen: bit-identical to the pre-trained one.
    assert_params_bits_eq(&tuned.model.encoder, &base.model.encoder, "frozen encoder");
    match &tuned.model.heads {
        Heads::PerDataset(m) => {
            assert_eq!(m.len(), 1, "exactly the new head");
            assert!(m.contains_key(&seventh));
        }
        _ => panic!("fine-tune must produce a per-dataset head"),
    }
    assert!(tuned.log.epochs.iter().all(|ep| ep.train_loss.is_finite()));

    // The tuned model serves the new task end to end.
    let mut generator = hydra_mtp::data::generators::DatasetGenerator::new(
        seventh,
        3,
        hydra_mtp::data::generators::GeneratorConfig { max_atoms: 8, ..Default::default() },
    );
    let fresh = generator.take(2);
    let mut predictor = session.predictor(&tuned.model);
    for p in predictor.predict(&fresh).unwrap() {
        assert!(p.energy.is_finite());
        assert!(p.forces.iter().flatten().all(|x| x.is_finite()));
    }
}
