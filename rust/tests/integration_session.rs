//! Integration: the Session/Predictor facade and the runtime task registry.
//!
//! Two pillars of the API redesign:
//!  1. `Session` + `Predictor` reproduce the seed's manual call-chain
//!     (`DataBundle::generate` -> `Trainer` -> `evaluate_model` ->
//!     hand-rolled `BatchBuilder`/`full_params`/`engine.forward`)
//!     bit-for-bit at the same seed.
//!  2. Head count is data, not code: a registry-defined sixth task trains
//!     end-to-end under `mtl-par` with six head sub-groups.

use std::sync::Arc;

use hydra_mtp::config::{RunConfig, TrainMode};
use hydra_mtp::coordinator::{evaluate_model, DataBundle, Heads, Trainer};
use hydra_mtp::data::batch::BatchBuilder;
use hydra_mtp::data::structures::ALL_DATASETS;
use hydra_mtp::runtime::Engine;
use hydra_mtp::session::Session;
use hydra_mtp::tasks::{
    FidelityProfile, GeneratorProfile, StructureKind, TaskRegistry, TaskSpec,
};

/// Shared engine: PJRT when artifacts + the feature are available, the
/// native pure-rust backend otherwise — these tests never skip.
fn engine() -> Arc<Engine> {
    use std::sync::OnceLock;
    static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            let e = Engine::load("artifacts").expect("engine loads on every machine");
            eprintln!("session tests run on the '{}' backend", e.backend_name());
            Arc::new(e)
        })
        .clone()
}

fn tiny_config(mode: TrainMode) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.mode = mode;
    cfg.train.epochs = 2;
    cfg.train.patience = 0;
    cfg.data.per_dataset = 40;
    cfg.data.max_atoms = 10;
    cfg
}

#[test]
fn session_reproduces_manual_path_bit_for_bit() {
    let e = engine();
    let cfg = tiny_config(TrainMode::MtlPar);

    // --- the seed's manual five-step dance ---
    let data = DataBundle::generate(&cfg.data, &ALL_DATASETS);
    let manual =
        Trainer::new(Arc::clone(&e), cfg.clone()).train(&data).unwrap();
    let manual_scores = evaluate_model(&e, &manual.model, &data.test).unwrap();

    // --- the same lifecycle through the facade ---
    let mut session = Session::builder()
        .engine(Arc::clone(&e))
        .config(cfg.clone())
        .build()
        .unwrap();
    let out = session.train().unwrap();
    let scores = session.evaluate(&out.model).unwrap();

    // Training trajectories identical to the last bit.
    assert_eq!(out.log.epochs.len(), manual.log.epochs.len());
    for (a, b) in out.log.epochs.iter().zip(&manual.log.epochs) {
        assert_eq!(a.train_loss, b.train_loss, "epoch train loss");
        assert_eq!(a.val_loss, b.val_loss, "epoch val loss");
        assert_eq!(a.steps, b.steps);
    }
    assert_eq!(out.comm_elems, manual.comm_elems, "comm traffic");

    // Evaluation matrices identical.
    assert_eq!(scores.len(), manual_scores.len());
    for (d, (mae_e, mae_f)) in &scores {
        let (me, mf) = manual_scores[d];
        assert_eq!(*mae_e, me, "{} energy MAE", d.name());
        assert_eq!(*mae_f, mf, "{} force MAE", d.name());
    }

    // Predictor output == the manual forward-pass plumbing on the same
    // samples (the old quickstart step 5).
    let d = ALL_DATASETS[0];
    let samples: Vec<_> = data.test[&d].iter().take(4).cloned().collect();
    let batch = BatchBuilder::build_all(
        e.manifest.config.batch_dims(),
        e.manifest.config.cutoff,
        &samples,
    )
    .remove(0);
    let full = manual.model.full_params(&e, d).unwrap();
    let (energy, forces) = e.forward(&full, &batch).unwrap();

    let mut predictor = session.predictor(&out.model);
    let preds = predictor.predict(&samples).unwrap();
    assert_eq!(preds.len(), samples.len());
    let ev = energy.as_f32();
    let fv = forces.as_f32();
    let mut node_base = 0;
    for (g, (p, s)) in preds.iter().zip(&samples).enumerate() {
        assert_eq!(p.dataset, d);
        assert_eq!(p.energy_per_atom, ev[g] as f64, "structure {g} energy");
        assert_eq!(p.energy, ev[g] as f64 * s.natoms() as f64);
        assert_eq!(p.forces.len(), s.natoms());
        for (k, f) in p.forces.iter().enumerate() {
            let row = (node_base + k) * 3;
            assert_eq!(f[0], fv[row] as f64, "structure {g} atom {k} fx");
            assert_eq!(f[1], fv[row + 1] as f64);
            assert_eq!(f[2], fv[row + 2] as f64);
        }
        node_base += s.natoms();
    }
}

/// Register the sixth synthetic source used by the tests below. Idempotent.
fn sixth_task() -> hydra_mtp::DatasetId {
    TaskRegistry::global()
        .register(TaskSpec::new(
            "Synth6",
            vec![1, 6, 7, 8, 16],
            GeneratorProfile {
                kind: StructureKind::Molecule { min_atoms: 4, atoms_cap: 12 },
                relax_steps: 10,
                relax_step_size: 0.05,
                perturb_factor: 1.2,
            },
            FidelityProfile {
                seed_tag: 97,
                shift_sigma: 1.0,
                scale_jitter: 0.03,
                force_scale_jitter: 0.015,
                energy_noise: 0.002,
                force_noise: 0.004,
                shift_offset: 0.0,
            },
        ))
        .expect("valid sixth-task spec")
}

#[test]
fn registry_sixth_task_trains_mtl_par_with_six_heads() {
    let e = engine();
    let six = sixth_task();
    let tasks: Vec<_> = ALL_DATASETS.iter().copied().chain([six]).collect();

    let mut session = Session::builder()
        .engine(Arc::clone(&e))
        .config(tiny_config(TrainMode::MtlPar))
        .tasks(&tasks)
        .build()
        .unwrap();
    assert_eq!(session.tasks().len(), 6);

    let out = session.train().unwrap();
    match &out.model.heads {
        Heads::PerDataset(m) => {
            assert_eq!(m.len(), 6, "one branch per task — head count is data");
            assert!(m.contains_key(&six), "sixth head trained");
        }
        _ => panic!("mtl-par must produce per-task heads"),
    }
    assert!(out.log.epochs.iter().all(|e| e.train_loss.is_finite()));

    // The sixth task evaluates and serves like any preset.
    let scores = session.evaluate(&out.model).unwrap();
    assert_eq!(scores.len(), 6);
    let (mae_e, mae_f) = scores[&six];
    assert!(mae_e.is_finite() && mae_f.is_finite());

    let samples = session.test_samples(2).unwrap();
    assert!(samples.iter().any(|s| s.dataset == six));
    let mut predictor = session.predictor(&out.model);
    for p in predictor.predict(&samples).unwrap() {
        assert!(p.energy.is_finite());
        assert!(p.forces.iter().flatten().all(|x| x.is_finite()));
    }
}

#[test]
fn predictor_rejects_headless_task() {
    let e = engine();
    let six = sixth_task();
    // Train only on the five presets...
    let mut session = Session::builder()
        .engine(Arc::clone(&e))
        .config(tiny_config(TrainMode::MtlPar))
        .build()
        .unwrap();
    let out = session.train().unwrap();
    // ...then ask for a prediction on the unknown sixth task.
    let mut generator = hydra_mtp::data::generators::DatasetGenerator::new(
        six,
        1,
        hydra_mtp::data::generators::GeneratorConfig {
            max_atoms: 8,
            ..Default::default()
        },
    );
    let alien = generator.take(1);
    let mut predictor = session.predictor(&out.model);
    let err = predictor.predict(&alien).unwrap_err();
    assert!(
        format!("{err}").contains("no head"),
        "clear routing error, got: {err}"
    );
}
