//! Integration: the mixed-precision compute path of the native backend
//! (blocked f32 microkernels with f64 accumulation, `model::kernels`).
//!
//! Proves the four end-to-end properties of ISSUE 5 on real short training
//! runs:
//!
//! 1. training DESCENDS at `Precision::MixedF32`;
//! 2. the final MixedF32 loss tracks the f64 oracle within tolerance;
//! 3. checkpoint kill-at-k resume parity is bit-exact at EACH precision
//!    (the mixed kernels chunk work over threads but never reorder an
//!    accumulation, so fixed-precision bit-determinism holds);
//! 4. resuming across precisions is REFUSED with an error naming both
//!    (the resolved precision is part of the trajectory fingerprint).
//!
//! Engines are pinned per precision via `Engine::native_with`, so these
//! tests mean the same thing regardless of any `HYDRA_MTP_PRECISION`
//! override in the environment (CI's mixed-f32 matrix leg).

use std::path::PathBuf;
use std::sync::Arc;

use hydra_mtp::checkpoint;
use hydra_mtp::config::{RunConfig, TrainMode};
use hydra_mtp::coordinator::{DataBundle, Heads, RunLog, TrainedModel, Trainer};
use hydra_mtp::data::batch::BatchBuilder;
use hydra_mtp::data::generators::{DatasetGenerator, GeneratorConfig};
use hydra_mtp::data::structures::DatasetId;
use hydra_mtp::model::params::ParamSet;
use hydra_mtp::runtime::{Engine, ManifestConfig, Precision};

// ---------------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------------

/// Small model dims: big enough to exercise multi-graph padded batches and
/// both EGNN layers, small enough that a handful of epochs stays fast in
/// debug builds.
fn small_config() -> ManifestConfig {
    let mut c = ManifestConfig::default_native();
    c.max_nodes = 64;
    c.max_edges = 512;
    c.max_graphs = 8;
    c.hidden = 32;
    c.num_layers = 2;
    c.num_rbf = 8;
    c.head_hidden = 32;
    c
}

fn engine(p: Precision) -> Arc<Engine> {
    Arc::new(Engine::native_with(small_config(), p))
}

fn tiny_cfg(mode: TrainMode, epochs: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.mode = mode;
    cfg.parallel.replicas = 1;
    cfg.train.epochs = epochs;
    cfg.train.patience = 0;
    cfg.data.per_dataset = 24;
    cfg.data.max_atoms = 8;
    cfg
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("hydra_mtp_precision_it_{}_{name}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn assert_params_bits_eq(a: &ParamSet, b: &ParamSet, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: leaf count");
    for ((na, ta), (nb, tb)) in a.iter().zip(b.iter()) {
        assert_eq!(na, nb, "{what}: leaf name");
        let (xa, xb) = (ta.as_f32(), tb.as_f32());
        assert_eq!(xa.len(), xb.len(), "{what}: {na} numel");
        for (i, (x, y)) in xa.iter().zip(xb).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: {na}[{i}]: {x} vs {y} (bitwise)");
        }
    }
}

fn assert_models_bits_eq(a: &TrainedModel, b: &TrainedModel) {
    assert_params_bits_eq(&a.encoder, &b.encoder, "encoder");
    match (&a.heads, &b.heads) {
        (Heads::Shared(x), Heads::Shared(y)) => assert_params_bits_eq(x, y, "shared head"),
        (Heads::PerDataset(x), Heads::PerDataset(y)) => {
            assert_eq!(x.len(), y.len(), "head count");
            for (d, bx) in x {
                assert_params_bits_eq(bx, &y[d], &format!("head {}", d.name()));
            }
        }
        _ => panic!("heads kind mismatch"),
    }
}

fn assert_logs_bits_eq(a: &RunLog, b: &RunLog) {
    assert_eq!(a.epochs.len(), b.epochs.len(), "epoch count");
    for (ea, eb) in a.epochs.iter().zip(&b.epochs) {
        assert_eq!(ea.epoch, eb.epoch);
        assert_eq!(ea.steps, eb.steps, "epoch {}", ea.epoch);
        assert_eq!(
            ea.train_loss.to_bits(),
            eb.train_loss.to_bits(),
            "epoch {} train_loss {} vs {}",
            ea.epoch,
            ea.train_loss,
            eb.train_loss
        );
        assert_eq!(ea.mae_e.to_bits(), eb.mae_e.to_bits(), "epoch {}", ea.epoch);
        assert_eq!(ea.mae_f.to_bits(), eb.mae_f.to_bits(), "epoch {}", ea.epoch);
        assert_eq!(ea.val_loss.to_bits(), eb.val_loss.to_bits(), "epoch {} val", ea.epoch);
    }
}

// ---------------------------------------------------------------------------
// (1) + (2): descent and f64 tracking
// ---------------------------------------------------------------------------

#[test]
fn mixed_f32_training_descends_and_tracks_the_f64_oracle() {
    let cfg = tiny_cfg(TrainMode::Single(DatasetId::Ani1x), 3);
    let data = DataBundle::generate(&cfg.data, &[DatasetId::Ani1x]);

    let o64 = Trainer::new(engine(Precision::F64), cfg.clone()).train(&data).unwrap();
    let o32 = Trainer::new(engine(Precision::MixedF32), cfg).train(&data).unwrap();

    // (1) the loss decreases under MixedF32 training.
    let first32 = o32.log.epochs.first().unwrap().train_loss;
    let last32 = o32.log.epochs.last().unwrap().train_loss;
    assert!(
        last32 < first32,
        "MixedF32 training must reduce the loss: {first32} -> {last32}"
    );

    // (2) the final loss tracks the f64 oracle. Per-step drift is ~1e-6
    // relative (gradcheck bounds it per leaf); over a few epochs the
    // trajectories separate slowly, so 5% is a loose-but-meaningful band —
    // a broken mixed kernel lands orders of magnitude outside it.
    let last64 = o64.log.epochs.last().unwrap().train_loss;
    let rel = (last32 - last64).abs() / last64.abs().max(1e-9);
    assert!(
        rel <= 0.05,
        "final MixedF32 loss {last32} drifts {rel:.4} from the f64 oracle {last64}"
    );
    // Same epoch/step structure: precision changes numerics, not schedule.
    assert_eq!(o32.log.epochs.len(), o64.log.epochs.len());
    for (e32, e64) in o32.log.epochs.iter().zip(&o64.log.epochs) {
        assert_eq!(e32.steps, e64.steps, "epoch {}", e32.epoch);
    }
}

#[test]
fn mixed_train_and_eval_forward_agree_bitwise() {
    // The cached-forward (train) and plain-forward (eval) paths must agree
    // exactly at MixedF32, same as the f64 guarantee in gradcheck.
    let e = engine(Precision::MixedF32);
    let mut g = DatasetGenerator::new(
        DatasetId::Qm7x,
        77,
        GeneratorConfig { max_atoms: 6, ..Default::default() },
    );
    let samples = g.take(4);
    let batches = BatchBuilder::build_all(
        e.manifest.config.batch_dims(),
        e.manifest.config.cutoff,
        &samples,
    );
    let batch = batches.into_iter().next().expect("at least one batch");
    let params = ParamSet::init(&e.manifest.params, 5);
    let tr = e.train_step(&params, &batch).unwrap();
    let ev = e.eval_step(&params, &batch).unwrap();
    assert_eq!(tr.loss.to_bits(), ev.loss.to_bits(), "train/eval forward must agree");
    assert_eq!(tr.mae_e.to_bits(), ev.mae_e.to_bits());
    assert_eq!(tr.mae_f.to_bits(), ev.mae_f.to_bits());
}

// ---------------------------------------------------------------------------
// (3): kill-at-k checkpoint parity, per precision
// ---------------------------------------------------------------------------

fn kill_at_k_parity_case(p: Precision, name: &str) {
    let epochs = 3;
    let k = 1;
    let e = engine(p);
    let cfg_full = tiny_cfg(TrainMode::Single(DatasetId::Ani1x), epochs);
    let data = DataBundle::generate(&cfg_full.data, &[DatasetId::Ani1x]);

    let full = Trainer::new(Arc::clone(&e), cfg_full).train(&data).unwrap();

    let dir = tmp_dir(name);
    let mut cfg_phase1 = tiny_cfg(TrainMode::Single(DatasetId::Ani1x), k);
    cfg_phase1.checkpoint.dir = Some(dir.to_string_lossy().into_owned());
    Trainer::new(Arc::clone(&e), cfg_phase1).train(&data).unwrap();
    assert!(
        checkpoint::epoch_path(&dir, k).is_file(),
        "phase 1 must write epoch_{k:04}.ckpt"
    );

    let mut cfg_phase2 = tiny_cfg(TrainMode::Single(DatasetId::Ani1x), epochs);
    cfg_phase2.checkpoint.resume = Some(dir.to_string_lossy().into_owned());
    let resumed = Trainer::new(Arc::clone(&e), cfg_phase2).train(&data).unwrap();

    assert_models_bits_eq(&resumed.model, &full.model);
    assert_logs_bits_eq(&resumed.log, &full.log);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn kill_at_k_checkpoint_parity_holds_at_f64() {
    kill_at_k_parity_case(Precision::F64, "f64");
}

#[test]
fn kill_at_k_checkpoint_parity_holds_at_mixed_f32() {
    kill_at_k_parity_case(Precision::MixedF32, "mixedf32");
}

// ---------------------------------------------------------------------------
// (4): cross-precision resume refusal
// ---------------------------------------------------------------------------

#[test]
fn cross_precision_resume_is_refused_naming_both_precisions() {
    let cfg = tiny_cfg(TrainMode::Single(DatasetId::Ani1x), 1);
    let data = DataBundle::generate(&cfg.data, &[DatasetId::Ani1x]);

    let dir = tmp_dir("cross");
    let mut cfg_write = cfg.clone();
    cfg_write.checkpoint.dir = Some(dir.to_string_lossy().into_owned());
    Trainer::new(engine(Precision::F64), cfg_write).train(&data).unwrap();

    let mut cfg_resume = tiny_cfg(TrainMode::Single(DatasetId::Ani1x), 2);
    cfg_resume.checkpoint.resume = Some(dir.to_string_lossy().into_owned());
    let err = Trainer::new(engine(Precision::MixedF32), cfg_resume)
        .train(&data)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("precision=f64") && msg.contains("precision=mixed-f32"),
        "cross-precision refusal must name both the writer's and the \
         resumer's precision: {msg}"
    );
    std::fs::remove_dir_all(dir).ok();
}
