//! Minimal offline-vendored subset of the `anyhow` API.
//!
//! The real crate is unavailable in the offline build environment, so this
//! path dependency provides exactly the surface the repo uses: an opaque
//! boxed error, `Result<T>`, the `anyhow!` / `bail!` / `ensure!` macros and
//! the `Context` extension trait. Error sources chain through
//! `std::error::Error::source`, and the alternate formatter (`{:#}`) prints
//! the full cause chain like upstream anyhow does.

use std::error::Error as StdError;
use std::fmt;

/// An opaque error type, convertible from any `std::error::Error`.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

/// A message-only error used by `anyhow!` and `Context`.
struct Message {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl fmt::Debug for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Display for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl StdError for Message {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn StdError + 'static))
    }
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { inner: Box::new(Message { msg: msg.to_string(), source: None }) }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            inner: Box::new(Message { msg: context.to_string(), source: Some(self.inner) }),
        }
    }

    /// Iterate the cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &(dyn StdError + 'static)> {
        let mut next: Option<&(dyn StdError + 'static)> =
            Some(self.inner.as_ref() as &(dyn StdError + 'static));
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }

    /// The innermost error in the chain.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        self.chain().last().expect("chain is never empty")
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { inner: Box::new(e) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: full cause chain, colon-separated (anyhow-compatible).
            let mut first = true;
            for cause in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{cause}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.inner)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut causes = self.chain().skip(1).peekable();
        if causes.peek().is_some() {
            write!(f, "\n\nCaused by:")?;
            for cause in causes {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

/// `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors, like upstream anyhow's `Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*).into())
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: `", stringify!($cond), "`"));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42)
    }

    #[test]
    fn macros_and_chain() {
        let e = fails().unwrap_err().context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
        assert_eq!(e.chain().count(), 2);
        assert_eq!(e.root_cause().to_string(), "inner 42");
    }

    #[test]
    fn from_std_error() {
        let io = std::fs::read_to_string("/definitely/not/a/file/anywhere");
        let e: Error = io.unwrap_err().into();
        assert!(!format!("{e}").is_empty());
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(-1).is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(5).context("missing").unwrap(), 5);
    }
}
