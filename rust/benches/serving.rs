//! Serving load-test benchmark (harness = serve::loadtest; criterion is
//! unavailable offline). Run with `cargo bench --bench serving`.
//!
//! One process, both legs, both precisions: the same request stream goes
//! through sequential `Predictor::predict_one` and through N concurrent
//! clients against a `Server`, so the coalescing win is measured against a
//! baseline from the SAME run on the SAME machine. Writes
//! `BENCH_serving.json` (p50/p95/p99 latency, sustained structures/sec,
//! avg batch occupancy, speedup) — the machine-readable trajectory that
//! EXPERIMENTS.md §Serving tracks and CI uploads as an artifact.
//!
//! The bench is also an enforcement point: it asserts (a) the server's
//! outputs are bit-identical to the sequential baseline, (b) sustained
//! server throughput strictly exceeds the sequential baseline, and (c)
//! server p99 latency stays inside the explicit budget.

use std::sync::Arc;

use hydra_mtp::config::ServeConfig;
use hydra_mtp::data::generators::{DatasetGenerator, GeneratorConfig};
use hydra_mtp::data::structures::{AtomicStructure, DatasetId};
use hydra_mtp::runtime::{Engine, ManifestConfig, Precision};
use hydra_mtp::serve::loadtest::{run_loadtest, synthetic_model, LegReport};
use hydra_mtp::util::json::Json;

const BENCH_JSON: &str = "BENCH_serving.json";

/// Explicit latency budget the server leg is held to (p99, per request).
const LATENCY_BUDGET_MS: f64 = 250.0;

const REQUESTS: usize = 48;
const CLIENTS: usize = 8;

/// Small dims (the integration-test geometry): padded batches of up to 7
/// real structures, so coalescing has headroom while a single forward
/// stays cheap enough for tight CI boxes.
fn small_config() -> ManifestConfig {
    let mut c = ManifestConfig::default_native();
    c.max_nodes = 64;
    c.max_edges = 512;
    c.max_graphs = 8;
    c.hidden = 32;
    c.num_layers = 2;
    c.num_rbf = 8;
    c.head_hidden = 32;
    c
}

/// `REQUESTS` structures over two tasks, interleaved.
fn request_stream() -> Vec<AtomicStructure> {
    let cfg = GeneratorConfig { max_atoms: 8, ..Default::default() };
    let tasks = [DatasetId::Ani1x, DatasetId::Qm7x];
    let per: Vec<Vec<AtomicStructure>> = tasks
        .iter()
        .map(|&d| DatasetGenerator::new(d, 2025, cfg.clone()).take(REQUESTS / 2))
        .collect();
    let mut out = Vec::with_capacity(REQUESTS);
    for i in 0..REQUESTS / 2 {
        for s in &per {
            out.push(s[i].clone());
        }
    }
    out
}

fn leg_json(op: &str, leg: &LegReport) -> Json {
    Json::obj(vec![
        ("op", Json::str(op)),
        ("requests", Json::from(leg.requests)),
        ("clients", Json::from(leg.clients)),
        ("wall_secs", Json::from(leg.wall_secs)),
        ("p50_ns", Json::from(leg.p50_ns as i64)),
        ("p95_ns", Json::from(leg.p95_ns as i64)),
        ("p99_ns", Json::from(leg.p99_ns as i64)),
        ("throughput_per_sec", Json::from(leg.throughput_per_sec)),
        ("avg_batch", Json::from(leg.avg_batch)),
    ])
}

fn report_line(op: &str, leg: &LegReport) {
    println!(
        "{op:<24} p50 {:>9.3}ms  p95 {:>9.3}ms  p99 {:>9.3}ms  {:>9.1} structures/s  avg batch {:.2}",
        leg.p50_ns as f64 / 1e6,
        leg.p95_ns as f64 / 1e6,
        leg.p99_ns as f64 / 1e6,
        leg.throughput_per_sec,
        leg.avg_batch
    );
}

fn main() -> anyhow::Result<()> {
    println!("== hydra-mtp serving load test ==\n");
    let structures = request_stream();
    let mut results: Vec<Json> = Vec::new();

    for p in [Precision::F64, Precision::MixedF32] {
        // Pin the precision explicitly so both legs of both precisions run
        // in one process regardless of HYDRA_MTP_PRECISION.
        let engine = Arc::new(Engine::native_with(small_config(), p));
        let model =
            synthetic_model(&engine, &[DatasetId::Ani1x, DatasetId::Qm7x], 7);
        // One worker isolates the coalescing effect: the throughput gain
        // over sequential comes from batch occupancy, not extra compute
        // threads (kernels at these dims stay serial either way).
        let cfg = ServeConfig {
            workers: 1,
            queue_capacity: 128,
            enqueue_wait_ms: 10_000,
            latency_budget_ms: LATENCY_BUDGET_MS,
        };
        let report = run_loadtest(&engine, &model, &structures, CLIENTS, cfg)?;

        println!("-- precision {} --", p.name());
        report_line(&format!("sequential_{}", p.name()), &report.sequential);
        report_line(&format!("server_{}", p.name()), &report.server);
        println!(
            "speedup {:.2}x, bit-identical: {}\n",
            report.speedup(),
            report.bit_identical
        );

        anyhow::ensure!(
            report.bit_identical,
            "{}: server outputs diverged from the sequential baseline",
            p.name()
        );
        anyhow::ensure!(
            report.server.throughput_per_sec > report.sequential.throughput_per_sec,
            "{}: server throughput ({:.1}/s) did not beat the sequential baseline \
             ({:.1}/s) measured in the same run",
            p.name(),
            report.server.throughput_per_sec,
            report.sequential.throughput_per_sec
        );
        let p99_ms = report.server.p99_ns as f64 / 1e6;
        anyhow::ensure!(
            p99_ms <= LATENCY_BUDGET_MS,
            "{}: server p99 {:.3}ms exceeds the {:.0}ms latency budget",
            p.name(),
            p99_ms,
            LATENCY_BUDGET_MS
        );

        results.push(leg_json(&format!("sequential_{}", p.name()), &report.sequential));
        let mut server = leg_json(&format!("server_{}", p.name()), &report.server);
        if let Json::Object(pairs) = &mut server {
            pairs.insert("speedup".to_string(), Json::from(report.speedup()));
            pairs.insert("bit_identical".to_string(), Json::from(report.bit_identical));
        }
        results.push(server);
    }

    let doc = Json::obj(vec![
        ("suite", Json::str("serving")),
        ("latency_budget_ms", Json::from(LATENCY_BUDGET_MS)),
        ("results", Json::Array(results)),
    ]);
    std::fs::write(BENCH_JSON, format!("{doc}\n"))?;
    println!("wrote {BENCH_JSON} (4 ops, budget {LATENCY_BUDGET_MS:.0}ms p99)");
    Ok(())
}
