//! Micro-benchmarks of every L3 hot path (harness = util::timer; criterion
//! is unavailable offline). Run with `cargo bench --bench hot_paths`.
//!
//! Besides the stdout report, the run writes `BENCH_hot_paths.json`
//! (op name, ns/iter, throughput) — the machine-readable trajectory that
//! EXPERIMENTS.md §Perf tracks and CI uploads as an artifact. The data-path
//! AND native-backend sections need no AOT artifacts, so every CI run now
//! carries real train/eval step timings — at BOTH precisions: the
//! `native_f64 *` ops are the scalar oracle path, the `native_f32 *` ops
//! the blocked mixed-precision microkernels, recorded side by side in the
//! same run. Only the PJRT section still wants `make artifacts` +
//! `--features pjrt`. `*_seed` ops are the retained seed implementations,
//! benchmarked next to their replacements so every entry carries its own
//! before/after.

use std::sync::Arc;
use std::time::Duration;

use hydra_mtp::comm::Comm;
use hydra_mtp::coordinator::trainer::plan_epoch_batches_reference;
use hydra_mtp::data::batch::{BatchBuilder, BatchDims, BatchPool, GraphBatch};
use hydra_mtp::data::featurized::FeaturizedStore;
use hydra_mtp::data::generators::{DatasetGenerator, GeneratorConfig};
use hydra_mtp::data::graph::{
    radius_graph, radius_graph_positions, radius_graph_positions_reference,
};
use hydra_mtp::data::structures::{AtomicStructure, DatasetId};
use hydra_mtp::data::DDStore;
use hydra_mtp::model::optimizer::{AdamW, AdamWConfig};
use hydra_mtp::model::params::ParamSet;
use hydra_mtp::runtime::{BackendKind, Engine, Precision};
use hydra_mtp::util::rng::Rng;
use hydra_mtp::util::timer::{bench, bench_n, write_bench_json, BenchStats};

const BENCH_JSON: &str = "BENCH_hot_paths.json";

/// Batch geometry for the engine-free data-path benches (the compiled
/// manifest dims are used automatically for the engine section).
const DIMS: BatchDims = BatchDims { max_nodes: 256, max_edges: 4096, max_graphs: 16 };
const CUTOFF: f64 = 6.0;

fn samples(n: usize, max_atoms: usize) -> Vec<AtomicStructure> {
    let mut g = DatasetGenerator::new(
        DatasetId::Ani1x,
        2025,
        GeneratorConfig { max_atoms, ..Default::default() },
    );
    g.take(n)
}

fn record(results: &mut Vec<BenchStats>, s: BenchStats) {
    println!("{}", s.report());
    results.push(s);
}

fn finish(results: &[BenchStats]) -> anyhow::Result<()> {
    write_bench_json(BENCH_JSON, "hot_paths", results)?;
    println!("\nwrote {BENCH_JSON} ({} ops)", results.len());
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("== hydra-mtp hot-path benchmarks ==\n");
    let budget = Duration::from_millis(600);
    let mut results: Vec<BenchStats> = Vec::new();

    // --- radius graph: seed hashmap-cell-list vs dense/flat-grid paths ---
    let ss = samples(64, 16);
    record(&mut results, bench("radius_graph_seed (16-atom molecule)", 3, budget, || {
        std::hint::black_box(radius_graph_positions_reference(&ss[0].positions, CUTOFF));
    }));
    record(&mut results, bench("radius_graph (16-atom molecule)", 3, budget, || {
        std::hint::black_box(radius_graph(&ss[0], CUTOFF));
    }));
    let mut rng = Rng::new(7);
    let big: Vec<[f64; 3]> = (0..512)
        .map(|_| [rng.range(0.0, 14.0), rng.range(0.0, 14.0), rng.range(0.0, 14.0)])
        .collect();
    record(&mut results, bench("radius_graph_seed (512-atom box)", 3, budget, || {
        std::hint::black_box(radius_graph_positions_reference(&big, 4.5));
    }));
    record(&mut results, bench("radius_graph (512-atom box)", 3, budget, || {
        std::hint::black_box(radius_graph_positions(&big, 4.5));
    }));

    // --- batch assembly ---
    record(&mut results, bench("batch assembly (64 structures)", 2, budget, || {
        std::hint::black_box(BatchBuilder::build_all(DIMS, CUTOFF, &ss));
    }));

    // --- featurize-once epoch planning: seed refeaturize vs warm cache ---
    let store = DDStore::new(ss.clone(), 1);
    record(&mut results, bench("featurized store build (64 structures)", 2, budget, || {
        std::hint::black_box(FeaturizedStore::build(Arc::clone(&store), CUTOFF));
    }));
    let fstore = FeaturizedStore::build(Arc::clone(&store), CUTOFF);
    record(&mut results, bench("epoch planning seed (refeaturize)", 2, budget, || {
        std::hint::black_box(plan_epoch_batches_reference(&store, 0, 1, DIMS, CUTOFF, 42));
    }));
    let mut pool = BatchPool::new();
    record(&mut results, bench("epoch planning warm (cached edges, pooled)", 2, budget, || {
        let batches = fstore.plan_epoch_batches(0, 1, DIMS, 42, &mut pool);
        std::hint::black_box(&batches);
        pool.recycle(batches);
    }));

    // --- per-step batch-field marshalling: clone-to-Tensor vs in-place ---
    let batches = BatchBuilder::build_all(DIMS, CUTOFF, &ss);
    let batch: &GraphBatch = &batches[0];
    const FIELDS: [&str; 12] = [
        "species", "edge_src", "edge_dst", "rel_hat", "dist", "node_mask",
        "edge_mask", "node_graph", "graph_mask", "inv_atoms", "y_energy", "y_forces",
    ];
    record(&mut results, bench("marshal 12 fields seed (clone->Tensor->literal)", 3, budget, || {
        for f in FIELDS {
            std::hint::black_box(batch.field(f).to_literal().unwrap());
        }
    }));
    record(&mut results, bench("marshal 12 fields (field_literal, in place)", 3, budget, || {
        for f in FIELDS {
            std::hint::black_box(batch.field_literal(f).unwrap());
        }
    }));

    // --- gpack io ---
    let path = std::env::temp_dir().join(format!("hydra_bench_{}.gpack", std::process::id()));
    hydra_mtp::data::pack::write_all(&path, &ss)?;
    let mut reader = hydra_mtp::data::pack::GPackReader::open(&path)?;
    let mut i = 0usize;
    record(&mut results, bench("gpack random read", 5, budget, || {
        i = (i * 7 + 1) % reader.len();
        std::hint::black_box(reader.read(i).unwrap());
    }));
    std::fs::remove_file(&path).ok();

    // --- collectives across group sizes and payloads ---
    for group in [2usize, 4, 8] {
        for len in [10_000usize, 250_000] {
            let name = format!("allreduce_mean x{group} ({} Kf32)", len / 1000);
            let stats = bench_n(&name, 40, || {
                let comms = Comm::group(group);
                std::thread::scope(|s| {
                    for c in comms {
                        s.spawn(move || {
                            let mut data = vec![1.0f32; len];
                            c.allreduce_mean(&mut data).unwrap();
                            std::hint::black_box(&data);
                        });
                    }
                });
            });
            record(&mut results, stats);
        }
    }

    // --- native backend: the zero-artifact train/eval step hot path, at
    // BOTH precisions side by side. `native_f64` is the scalar oracle path
    // (the PR-4 baseline, renamed); `native_f32` the blocked f32-compute /
    // f64-accumulate microkernels of `model::kernels`. Each engine pins its
    // precision explicitly (no env dependence), so a single run — and
    // therefore a single CI `BENCH_hot_paths.json` artifact — carries the
    // f64-vs-f32 speedup. Runs everywhere (pure rust).
    for (tag, precision) in
        [("native_f64", Precision::F64), ("native_f32", Precision::MixedF32)]
    {
        let native = Engine::load_full("artifacts", BackendKind::Native, precision)?;
        let ndims = native.manifest.config.batch_dims();
        let ncut = native.manifest.config.cutoff;
        let nbatches = BatchBuilder::build_all(ndims, ncut, &ss);
        let nbatch: &GraphBatch = &nbatches[0];
        let nparams = ParamSet::init(&native.manifest.params, 1);
        let name = |op: &str| format!("{tag} {op}");
        record(&mut results, bench_n(&name("train_step (fwd+bwd, full batch)"), 12, || {
            std::hint::black_box(native.train_step(&nparams, nbatch).unwrap());
        }));
        record(&mut results, bench_n(&name("eval_step (fwd only)"), 20, || {
            std::hint::black_box(native.eval_step(&nparams, nbatch).unwrap());
        }));
        record(&mut results, bench_n(&name("forward (serving)"), 20, || {
            std::hint::black_box(native.forward(&nparams, nbatch).unwrap());
        }));
        println!("\n{tag} executions: {}", native.executions());
    }

    // --- PJRT path (needs compiled AOT artifacts + --features pjrt) ---
    let engine = match Engine::load_with("artifacts", BackendKind::Pjrt) {
        Ok(e) => Arc::new(e),
        Err(e) => {
            eprintln!(
                "SKIP pjrt section: AOT artifacts unavailable ({e:#}); run \
                 `make artifacts` and enable the `pjrt` feature (uncomment `xla` \
                 in Cargo.toml) for the PJRT engine benchmarks"
            );
            return finish(&results);
        }
    };
    let dims = engine.manifest.config.batch_dims();
    let cutoff = engine.manifest.config.cutoff;
    let ebatches = BatchBuilder::build_all(dims, cutoff, &ss);
    let ebatch: &GraphBatch = &ebatches[0];

    let params = ParamSet::init(&engine.manifest.params, 1);
    record(&mut results, bench_n("marshal train_step inputs", 200, || {
        std::hint::black_box(engine.marshal("train_step", &params, ebatch).unwrap());
    }));

    record(&mut results, bench_n("train_step (fwd+bwd, full batch)", 20, || {
        std::hint::black_box(engine.train_step(&params, ebatch).unwrap());
    }));

    record(&mut results, bench_n("eval_step (fwd only)", 30, || {
        std::hint::black_box(engine.eval_step(&params, ebatch).unwrap());
    }));

    // --- optimizer ---
    let grads = {
        let out = engine.train_step(&params, ebatch)?;
        out.grads
    };
    let mut opt_params = ParamSet::init(&engine.manifest.params, 2);
    let mut opt = AdamW::new(AdamWConfig::default(), &opt_params);
    record(&mut results, bench("adamw step (full model)", 3, budget, || {
        opt.step(&mut opt_params, &grads);
    }));

    // --- gradient sync prep: before/after the §Perf L3 iteration ---
    record(&mut results, bench("grad sync prep OLD subset+flatten", 3, budget, || {
        std::hint::black_box(grads.subset("encoder.").flatten());
    }));
    let mut flat_buf: Vec<f32> = Vec::new();
    record(&mut results, bench("grad sync prep NEW flatten_prefix", 3, budget, || {
        grads.flatten_prefix_into("encoder.", &mut flat_buf);
        std::hint::black_box(&flat_buf);
    }));

    println!("\ntotal executions against PJRT: {}", engine.executions());
    finish(&results)
}
