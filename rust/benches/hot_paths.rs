//! Micro-benchmarks of every L3 hot path (harness = util::timer; criterion
//! is unavailable offline). Run with `cargo bench --bench hot_paths`.
//! These numbers feed EXPERIMENTS.md §Perf.

use std::sync::Arc;
use std::time::Duration;

use hydra_mtp::comm::Comm;
use hydra_mtp::data::batch::{BatchBuilder, GraphBatch};
use hydra_mtp::data::generators::{DatasetGenerator, GeneratorConfig};
use hydra_mtp::data::graph::radius_graph;
use hydra_mtp::data::structures::{AtomicStructure, DatasetId};
use hydra_mtp::model::optimizer::{AdamW, AdamWConfig};
use hydra_mtp::model::params::ParamSet;
use hydra_mtp::runtime::Engine;
use hydra_mtp::util::timer::{bench, bench_n};

fn samples(n: usize, max_atoms: usize) -> Vec<AtomicStructure> {
    let mut g = DatasetGenerator::new(
        DatasetId::Ani1x,
        2025,
        GeneratorConfig { max_atoms, ..Default::default() },
    );
    g.take(n)
}

fn main() -> anyhow::Result<()> {
    println!("== hydra-mtp hot-path benchmarks ==\n");
    let budget = Duration::from_millis(600);

    // --- data path ---
    let ss = samples(64, 16);
    println!("{}", bench("radius_graph (16-atom molecule)", 3, budget, || {
        std::hint::black_box(radius_graph(&ss[0], 6.0));
    }).report());

    let engine = match Engine::load("artifacts") {
        Ok(e) => Arc::new(e),
        Err(e) => {
            eprintln!(
                "SKIP: AOT artifacts unavailable ({e:#}); run `make artifacts` and \
                 enable the `pjrt` feature (uncomment `xla` in Cargo.toml) for the engine benchmarks"
            );
            return Ok(());
        }
    };
    let dims = engine.manifest.config.batch_dims();
    let cutoff = engine.manifest.config.cutoff;
    println!("{}", bench("batch assembly (64 structures)", 2, budget, || {
        std::hint::black_box(BatchBuilder::build_all(dims, cutoff, &ss));
    }).report());

    let batches = BatchBuilder::build_all(dims, cutoff, &ss);
    let batch: &GraphBatch = &batches[0];

    // --- gpack io ---
    let path = std::env::temp_dir().join(format!("hydra_bench_{}.gpack", std::process::id()));
    hydra_mtp::data::pack::write_all(&path, &ss)?;
    let mut reader = hydra_mtp::data::pack::GPackReader::open(&path)?;
    let mut i = 0usize;
    println!("{}", bench("gpack random read", 5, budget, || {
        i = (i * 7 + 1) % reader.len();
        std::hint::black_box(reader.read(i).unwrap());
    }).report());
    std::fs::remove_file(&path).ok();

    // --- runtime path ---
    let params = ParamSet::init(&engine.manifest.params, 1);
    println!("{}", bench_n("marshal train_step inputs", 200, || {
        std::hint::black_box(engine.marshal("train_step", &params, batch).unwrap());
    }).report());

    println!("{}", bench_n("train_step (fwd+bwd, full batch)", 20, || {
        std::hint::black_box(engine.train_step(&params, batch).unwrap());
    }).report());

    println!("{}", bench_n("eval_step (fwd only)", 30, || {
        std::hint::black_box(engine.eval_step(&params, batch).unwrap());
    }).report());

    // --- optimizer ---
    let grads = {
        let out = engine.train_step(&params, batch)?;
        out.grads
    };
    let mut opt_params = ParamSet::init(&engine.manifest.params, 2);
    let mut opt = AdamW::new(AdamWConfig::default(), &opt_params);
    println!("{}", bench("adamw step (full model)", 3, budget, || {
        opt.step(&mut opt_params, &grads);
    }).report());

    // --- gradient sync prep: before/after the §Perf L3 iteration ---
    println!("{}", bench("grad sync prep OLD subset+flatten", 3, budget, || {
        std::hint::black_box(grads.subset("encoder.").flatten());
    }).report());
    let mut flat_buf: Vec<f32> = Vec::new();
    println!("{}", bench("grad sync prep NEW flatten_prefix", 3, budget, || {
        grads.flatten_prefix_into("encoder.", &mut flat_buf);
        std::hint::black_box(&flat_buf);
    }).report());

    // --- collectives across group sizes and payloads ---
    for group in [2usize, 4, 8] {
        for len in [10_000usize, 250_000] {
            let name = format!("allreduce_mean x{group} ({} Kf32)", len / 1000);
            let stats = bench_n(&name, 40, || {
                let comms = Comm::group(group);
                std::thread::scope(|s| {
                    for c in comms {
                        s.spawn(move || {
                            let mut data = vec![1.0f32; len];
                            c.allreduce_mean(&mut data);
                            std::hint::black_box(&data);
                        });
                    }
                });
            });
            println!("{}", stats.report());
        }
    }

    println!("\ntotal executions against PJRT: {}", engine.executions());
    Ok(())
}
