//! Graph-parallel (domain-decomposed) training benchmark. Run with
//! `cargo bench --bench graph_parallel`.
//!
//! Writes `BENCH_graph_parallel.json` — the artifact EXPERIMENTS.md §Graph
//! parallel quotes and CI uploads. Two sections:
//!
//! * step layer: one `graphpar::train_step` on crystal fragments of growing
//!   atom count across worlds 1/2/4, timed inside the rank group. The
//!   measured per-step [`Comm::stats`] delta is asserted EQUAL to
//!   `GpPlan::predicted_step_elems` — the analytic halo-traffic formula the
//!   scalesim quotes must match what the implementation actually moves,
//!   element for element;
//! * trainer layer: a Supercell (1000-atom bulk) graph-parallel training
//!   run at replicas 1 vs 2 through the full `Trainer` path, reporting the
//!   measured per-step time of each — plus a bit-identity check of every
//!   epoch loss, because domain decomposition that changes the numbers is
//!   a bug, not a speedup.
//!
//! All legs run on the native backend, so CI carries real measurements on
//! every run.

use std::sync::Arc;
use std::time::Duration;

use hydra_mtp::comm::run_group;
use hydra_mtp::config::{RunConfig, TrainMode};
use hydra_mtp::coordinator::trainer::TrainOutcome;
use hydra_mtp::coordinator::{DataBundle, Trainer};
use hydra_mtp::data::featurized::compute_segments;
use hydra_mtp::data::generators::inorganic::build_crystal;
use hydra_mtp::data::graph::radius_graph_positions;
use hydra_mtp::data::potential::energy_and_forces;
use hydra_mtp::model::egnn::{BranchParams, EgnnDims, EncoderParams};
use hydra_mtp::model::graphpar::{self, GpPlan, GpStructure, GradLayout};
use hydra_mtp::model::ParamSet;
use hydra_mtp::runtime::{BackendKind, Engine, Manifest, ManifestConfig, Precision};
use hydra_mtp::tasks::register_large_presets;
use hydra_mtp::util::rng::Rng;
use hydra_mtp::util::timer::{bench_n, write_bench_json, BenchStats};

const BENCH_JSON: &str = "BENCH_graph_parallel.json";
const STEP_ITERS: usize = 6;

/// Bench `train_step` on one structure at one world size; every rank runs
/// the same iterations in lockstep, rank 0's timings are reported. Returns
/// (stats, measured f64 elems per step, predicted f64 elems per step).
fn step_leg(m: &Manifest, natoms: usize, world: usize) -> (BenchStats, u64, u64) {
    let dims = EgnnDims::from_config(&m.config);
    let layout = GradLayout::new(&dims);
    let params = ParamSet::init(&m.params, 5);
    let mut rng = Rng::new(31);
    let (species, positions) = build_crystal(&mut rng, &[12, 8, 11, 17], natoms);
    let (energy, forces) = energy_and_forces(&species, &positions);
    let y_epa = energy / natoms as f64;
    let edges = radius_graph_positions(&positions, m.config.cutoff);
    let segments = compute_segments(&positions, m.config.cutoff);
    let plan = GpPlan::build(&segments, &edges, world);
    let predicted = plan.predicted_step_elems(dims.h, dims.l, layout.len);

    let name = format!("graph-par train_step {natoms} atoms world {world}");
    let results = run_group(world, |c| {
        let enc = EncoderParams::from_set(&dims, &params).unwrap();
        let br = BranchParams::from_set(&dims, &params).unwrap();
        let st = GpStructure {
            species: &species,
            edges: &edges,
            y_energy_per_atom: y_epa,
            y_forces: &forces,
        };
        let before = c.stats().elems;
        let stats = bench_n(&name, STEP_ITERS, || {
            graphpar::train_step(&dims, &enc, &br, &st, &plan, &layout, &c).unwrap();
        });
        let per_step = (c.stats().elems - before) / STEP_ITERS as u64;
        (stats, per_step)
    });
    let (stats, measured) = results
        .into_iter()
        .next()
        .expect("rank 0 ran")
        .expect("no rank failed in a healthy bench group");
    (stats, measured, predicted)
}

/// One graph-parallel training leg through the full Trainer path; returns
/// the outcome and its measured per-step time (exec + comm + opt over all
/// steps). Quantiles are per-epoch per-step means.
fn train_leg(
    engine: &Arc<Engine>,
    data: &DataBundle,
    supercell: hydra_mtp::DatasetId,
    name: &str,
    replicas: usize,
) -> (TrainOutcome, BenchStats) {
    let mut cfg = RunConfig::default();
    cfg.mode = TrainMode::Single(supercell);
    cfg.parallel.replicas = replicas;
    cfg.parallel.graph_par = true;
    cfg.train.epochs = 2;
    cfg.train.patience = 0;
    cfg.data.per_dataset = 6;
    let out = Trainer::new(Arc::clone(engine), cfg).train(data).expect("training runs");

    let mut samples: Vec<Duration> = Vec::new();
    let mut total = Duration::ZERO;
    let mut steps = 0usize;
    for ep in &out.log.epochs {
        let t = ep.time_exec + ep.time_comm + ep.time_opt;
        if ep.steps > 0 {
            samples.push(t / ep.steps as u32);
        }
        total += t;
        steps += ep.steps;
    }
    samples.sort_unstable();
    let n = samples.len().max(1);
    let mean = if steps > 0 { total / steps as u32 } else { Duration::ZERO };
    let stats = BenchStats {
        name: name.to_string(),
        iters: steps,
        mean,
        p50: samples.get(n / 2).copied().unwrap_or(mean),
        p95: samples.get((n * 95 / 100).min(n - 1)).copied().unwrap_or(mean),
        min: samples.first().copied().unwrap_or(mean),
    };
    (out, stats)
}

fn main() -> anyhow::Result<()> {
    println!("== hydra-mtp graph-parallel benchmarks ==\n");
    let mut results: Vec<BenchStats> = Vec::new();

    // --- step layer: train_step time + halo traffic vs atom count/world ---
    let m = Manifest::synthesize(ManifestConfig::default_native());
    for natoms in [120usize, 480, 1000] {
        for world in [1usize, 2, 4] {
            let (stats, measured, predicted) = step_leg(&m, natoms, world);
            println!("{}", stats.report());
            println!(
                "    halo traffic: {measured} f64 elems/step measured, \
                 {predicted} predicted ({:.1} KiB)",
                measured as f64 * 8.0 / 1024.0
            );
            assert_eq!(
                measured, predicted,
                "{natoms} atoms world {world}: the analytic halo-traffic \
                 model must match Comm::stats exactly"
            );
            results.push(stats);
        }
    }

    // --- trainer layer: full graph-par run, 1 vs 2 ranks, same data ---
    let (supercell, _) = register_large_presets()?;
    let engine = Arc::new(Engine::load_full(
        "artifacts",
        BackendKind::Native,
        Precision::F64,
    )?);
    let mut data_cfg = RunConfig::default();
    data_cfg.data.per_dataset = 6;
    let data = DataBundle::generate(&data_cfg.data, &[supercell]);

    let (solo, solo_stats) =
        train_leg(&engine, &data, supercell, "supercell graph-par step (1 rank)", 1);
    println!("{}", solo_stats.report());
    results.push(solo_stats.clone());

    let (duo, duo_stats) =
        train_leg(&engine, &data, supercell, "supercell graph-par step (2 ranks)", 2);
    println!("{}", duo_stats.report());
    results.push(duo_stats.clone());

    // Decomposition that changes the numbers is a bug: both legs must land
    // on the same losses to the last bit.
    assert_eq!(solo.log.epochs.len(), duo.log.epochs.len());
    for (a, b) in solo.log.epochs.iter().zip(&duo.log.epochs) {
        assert_eq!(
            a.train_loss.to_bits(),
            b.train_loss.to_bits(),
            "epoch {}: 2-rank leg diverged from single-rank",
            a.epoch
        );
        assert_eq!(a.val_loss.to_bits(), b.val_loss.to_bits(), "epoch {}", a.epoch);
    }
    println!(
        "\nbit-identical across worlds: yes; comm {:.1} Mf64 (2 ranks); \
         step time {:?} (1 rank) -> {:?} (2 ranks)",
        duo.comm_elems.0 as f64 / 1e6,
        solo_stats.mean,
        duo_stats.mean,
    );

    write_bench_json(BENCH_JSON, "graph_parallel", &results)?;
    println!("wrote {BENCH_JSON} ({} ops)", results.len());
    Ok(())
}
