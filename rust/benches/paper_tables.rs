//! Paper-evaluation bench harness: regenerates every table and figure of
//! the paper's evaluation section and reports the shape checks.
//!
//!   Table 1 — energy-per-atom MAE, 7 models x 5 datasets
//!   Table 2 — force MAE, same matrix (same training runs)
//!   Fig 1   — element-frequency heatmap of the aggregated data
//!   Fig 4   — weak/strong scaling, MTL-base vs MTL-par, 3 machines
//!
//! Run: cargo bench --bench paper_tables
//! Flags (after --): --per-dataset N --epochs N --quick
//!
//! Absolute MAE values differ from the paper (synthetic labels, scaled-down
//! model — see DESIGN.md §3); the claimed reproduction is the *shape*:
//! diagonal dominance of single-dataset models, catastrophic organic ->
//! inorganic transfer, GFM-Baseline-All in between, GFM-MTL-All best
//! overall, and MTL-par's communication advantage at scale.

use std::sync::Arc;

use hydra_mtp::config::RunConfig;
use hydra_mtp::coordinator::experiments;
use hydra_mtp::data::structures::{DatasetId, ALL_DATASETS};
use hydra_mtp::coordinator::DataBundle;
use hydra_mtp::runtime::Engine;
use hydra_mtp::scalesim::{self, Workload};
use hydra_mtp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    // `bench` is what cargo passes to harness=false bench binaries.
    args.ensure_known(
        "paper_tables",
        &["quick", "per-dataset", "max-atoms", "epochs", "lr", "bench"],
    )?;
    let quick = args.bool("quick");
    let mut cfg = RunConfig::default();
    cfg.data.per_dataset = args.usize("per-dataset", if quick { 96 } else { 600 });
    cfg.data.max_atoms = args.usize("max-atoms", 12);
    cfg.train.epochs = args.usize("epochs", if quick { 4 } else { 24 });
    cfg.train.lr = args.f64("lr", 2e-3);
    cfg.train.patience = 6;

    println!("== paper_tables bench: Tables 1-2 + Fig 1 + Fig 4 ==\n");

    // ---- Fig 1 ------------------------------------------------------------
    let t0 = std::time::Instant::now();
    let counts = experiments::fig1_histogram(cfg.data.seed, 300, 20);
    println!("{}", experiments::fig1_render(&counts));
    let covered = counts.iter().filter(|&&c| c > 0).count();
    println!(
        "[fig1] {covered}/94 elements covered, generated in {:?}\n",
        t0.elapsed()
    );

    // ---- Tables 1 & 2 -------------------------------------------------------
    let engine = match Engine::load(&cfg.artifacts_dir) {
        Ok(e) => Arc::new(e),
        Err(e) => {
            eprintln!(
                "SKIP: AOT artifacts unavailable ({e:#}); run `make artifacts` and \
                 enable the `pjrt` feature (uncomment `xla` in Cargo.toml) to regenerate Tables 1-2 / Fig 4"
            );
            return Ok(());
        }
    };
    let data = DataBundle::generate(&cfg.data, &ALL_DATASETS);
    let t1 = std::time::Instant::now();
    let matrix = experiments::run_tables(&engine, &cfg, &data, |line| {
        println!("  [train] {line}");
    })?;
    println!("\n{}", matrix.render(true));
    println!("{}", matrix.render(false));
    println!("[tables] 7 models trained + scored in {:?}\n", t1.elapsed());

    // Shape checks (the paper's qualitative claims).
    let idx = |name: &str| matrix.row(name).unwrap_or_else(|| panic!("row {name}"));
    let mtl = idx("GFM-MTL-All");
    let base = idx("GFM-Baseline-All");
    let col = |d: DatasetId| ALL_DATASETS.iter().position(|&x| x == d).unwrap();

    let mut checks: Vec<(String, bool)> = Vec::new();
    // 1. Single-dataset models: in-distribution beats their worst OOD column
    //    (paper: by 6x-80x; we require 2x at this scaled-down budget).
    for &d in &ALL_DATASETS {
        let r = idx(&format!("Model-{}", d.name()));
        let own = matrix.mae_e[r][col(d)];
        let worst = matrix.mae_e[r]
            .iter()
            .cloned()
            .fold(f64::MIN, f64::max);
        checks.push((
            format!("Model-{} diagonal << worst OOD ({own:.3} vs {worst:.3})", d.name()),
            own * 2.0 < worst,
        ));
    }
    // 1b. Column winners among single-dataset models: each dataset is
    //     predicted best by the model trained on it (paper Tables 1-2).
    for &d in &ALL_DATASETS {
        let r_own = idx(&format!("Model-{}", d.name()));
        let own = matrix.mae_e[r_own][col(d)];
        let best_other = ALL_DATASETS
            .iter()
            .filter(|&&o| o != d)
            .map(|o| matrix.mae_e[idx(&format!("Model-{}", o.name()))][col(d)])
            .fold(f64::MAX, f64::min);
        checks.push((
            format!(
                "{} column won by its own model ({own:.3} vs next {best_other:.3})",
                d.name()
            ),
            own < best_other * 1.1,
        ));
    }
    // 2. Organic-only models transfer poorly to inorganic columns.
    let ani = idx("Model-ANI1x");
    checks.push((
        "organic model fails on MPTrj/Alexandria".into(),
        matrix.mae_e[ani][col(DatasetId::MpTrj)]
            > 3.0 * matrix.mae_e[ani][col(DatasetId::Ani1x)],
    ));
    // 3. MTL-All mean beats Baseline-All mean (energy).
    checks.push((
        format!(
            "GFM-MTL-All mean energy MAE < GFM-Baseline-All ({:.3} vs {:.3})",
            matrix.row_mean(mtl, true),
            matrix.row_mean(base, true)
        ),
        matrix.row_mean(mtl, true) < matrix.row_mean(base, true),
    ));
    // 4. MTL-All is best-or-near-best everywhere: within 2x of column min.
    let mut near_best = true;
    for c in 0..ALL_DATASETS.len() {
        let colmin = (0..matrix.model_names.len())
            .map(|r| matrix.mae_e[r][c])
            .fold(f64::MAX, f64::min);
        if matrix.mae_e[mtl][c] > 3.0 * colmin {
            near_best = false;
        }
    }
    checks.push(("GFM-MTL-All within 3x of best in every column".into(), near_best));

    println!("shape checks (paper's qualitative claims):");
    let mut failures = 0;
    for (name, ok) in &checks {
        println!("  [{}] {name}", if *ok { "PASS" } else { "FAIL" });
        failures += usize::from(!ok);
    }

    // ---- Fig 4 -------------------------------------------------------------
    let t2 = std::time::Instant::now();
    let w = Workload::paper(5);
    let rows = scalesim::fig4_all(&w, cfg.data.seed);
    for m in ["Frontier", "Perlmutter", "Aurora"] {
        println!("\n{}", scalesim::render_panel(&rows, m, "weak"));
        println!("{}", scalesim::render_panel(&rows, m, "strong"));
    }
    println!("[fig4] {} points simulated in {:?}", rows.len(), t2.elapsed());
    std::fs::write("fig4.csv", scalesim::to_csv(&rows))?;
    std::fs::write("table1.csv", matrix.to_csv(true))?;
    std::fs::write("table2.csv", matrix.to_csv(false))?;
    println!("\nwrote table1.csv, table2.csv, fig4.csv");

    if failures > 0 {
        println!("\nWARNING: {failures} shape check(s) failed at this budget — rerun without --quick / with more --epochs.");
    }
    Ok(())
}
