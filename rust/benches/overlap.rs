//! Sync-vs-overlapped gradient reduction benchmark. Run with
//! `cargo bench --bench overlap`.
//!
//! Writes `BENCH_overlap.json` — the artifact EXPERIMENTS.md §Overlap
//! quotes and CI uploads. Two sections, all legs recorded in the SAME run
//! so the comparison is apples to apples:
//!
//! * comm layer: one monolithic `allreduce_mean` of a 1M-f32 payload over
//!   4 ranks vs the same payload streamed through the overlapped bucketed
//!   reducer (the raw cost of chunking + the comm thread);
//! * trainer: an MTL-par training run with the synchronous reduction path
//!   vs the identical config with overlap on, reporting the measured
//!   per-step time of each — plus a bit-identity check of the final
//!   training losses, because a perf win that changes the numbers is a
//!   bug, not a win.
//!
//! The trainer legs run on the native backend (no artifacts needed), so CI
//! carries real sync-vs-overlapped step timings on every run.

use std::sync::Arc;
use std::time::Duration;

use hydra_mtp::comm::{run_group, OverlapReducer, Segment};
use hydra_mtp::config::{RunConfig, TrainMode};
use hydra_mtp::coordinator::trainer::TrainOutcome;
use hydra_mtp::coordinator::{DataBundle, Trainer};
use hydra_mtp::data::structures::DatasetId;
use hydra_mtp::runtime::{BackendKind, Engine, Precision};
use hydra_mtp::util::timer::{bench_n, write_bench_json, BenchStats};

const BENCH_JSON: &str = "BENCH_overlap.json";

const ELEMS: usize = 1 << 20;
const RANKS: usize = 4;
const COMM_ITERS: usize = 12;

/// Bench one reduction flavor on a fresh 4-rank group; every rank runs the
/// same iterations in lockstep, rank 0's timings are reported.
fn comm_leg(name: &'static str, bucket_elems: usize) -> BenchStats {
    let results = run_group(RANKS, move |c| {
        let mut data: Vec<f32> = (0..ELEMS).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut red = OverlapReducer::new(c.clone(), c.clone());
        bench_n(name, COMM_ITERS, || {
            if bucket_elems >= ELEMS {
                c.allreduce_mean(&mut data).unwrap();
            } else {
                red.submit_chunks(Segment::Encoder, 0, &data, bucket_elems).unwrap();
                for rb in red.finish().unwrap() {
                    data[rb.offset..rb.offset + rb.data.len()].copy_from_slice(&rb.data);
                    red.recycle(rb.data);
                }
            }
        })
    });
    results
        .into_iter()
        .next()
        .expect("rank 0 ran")
        .expect("no rank failed in a healthy bench group")
}

/// One MTL-par training leg; returns the outcome and its measured per-step
/// time (exec + comm + opt over all steps) as a BenchStats row. Quantiles
/// are per-epoch per-step means.
fn train_leg(
    engine: &Arc<Engine>,
    data: &DataBundle,
    name: &str,
    overlap: bool,
) -> (TrainOutcome, BenchStats) {
    let mut cfg = RunConfig::default();
    cfg.mode = TrainMode::MtlPar;
    cfg.parallel.replicas = 2;
    cfg.parallel.overlap = overlap;
    cfg.parallel.bucket_elems = 1 << 14;
    cfg.train.epochs = 3;
    cfg.train.patience = 0;
    cfg.data.per_dataset = 96;
    cfg.data.max_atoms = 12;
    let out = Trainer::new(Arc::clone(engine), cfg).train(data).expect("training runs");

    let mut samples: Vec<Duration> = Vec::new();
    let mut total = Duration::ZERO;
    let mut steps = 0usize;
    for ep in &out.log.epochs {
        let t = ep.time_exec + ep.time_comm + ep.time_opt;
        if ep.steps > 0 {
            samples.push(t / ep.steps as u32);
        }
        total += t;
        steps += ep.steps;
    }
    samples.sort_unstable();
    let n = samples.len().max(1);
    let mean = if steps > 0 { total / steps as u32 } else { Duration::ZERO };
    let stats = BenchStats {
        name: name.to_string(),
        iters: steps,
        mean,
        p50: samples.get(n / 2).copied().unwrap_or(mean),
        p95: samples.get((n * 95 / 100).min(n - 1)).copied().unwrap_or(mean),
        min: samples.first().copied().unwrap_or(mean),
    };
    (out, stats)
}

fn main() -> anyhow::Result<()> {
    println!("== hydra-mtp overlapped-reduction benchmarks ==\n");
    let mut results: Vec<BenchStats> = Vec::new();

    // --- comm layer: monolithic vs bucketed-overlapped, same payload ---
    for (name, bucket) in [
        ("allreduce_mean 4x1M f32 (monolithic)", ELEMS),
        ("overlapped bucketed reduce 4x1M f32 (256k buckets)", 1 << 18),
        ("overlapped bucketed reduce 4x1M f32 (64k buckets)", 1 << 16),
    ] {
        let s = comm_leg(name, bucket);
        println!("{}", s.report());
        results.push(s);
    }

    // --- trainer: sync vs overlapped step time, same config + data ---
    let engine = Arc::new(Engine::load_full(
        "artifacts",
        BackendKind::Native,
        Precision::F64,
    )?);
    let mut data_cfg = RunConfig::default();
    data_cfg.data.per_dataset = 96;
    data_cfg.data.max_atoms = 12;
    let data = DataBundle::generate(
        &data_cfg.data,
        &[DatasetId::Ani1x, DatasetId::Qm7x, DatasetId::MpTrj],
    );

    let (sync_out, sync_stats) =
        train_leg(&engine, &data, "mtl-par train step (sync reduction)", false);
    println!("{}", sync_stats.report());
    results.push(sync_stats.clone());

    let (ov_out, ov_stats) =
        train_leg(&engine, &data, "mtl-par train step (overlapped reduction)", true);
    println!("{}", ov_stats.report());
    results.push(ov_stats.clone());

    // A perf win that changes the numbers is a bug: the two legs must end
    // at the same training losses to the last bit.
    for (a, b) in sync_out.log.epochs.iter().zip(&ov_out.log.epochs) {
        assert_eq!(
            a.train_loss.to_bits(),
            b.train_loss.to_bits(),
            "epoch {}: overlapped leg diverged from sync",
            a.epoch
        );
        assert_eq!(a.val_loss.to_bits(), b.val_loss.to_bits(), "epoch {}", a.epoch);
    }
    assert!(ov_out.overlapped_elems > 0, "overlap leg must engage the comm thread");
    println!(
        "\nbit-identical: yes; overlapped traffic {:.1} Mf32; step time {:?} -> {:?} ({:+.1}%)",
        ov_out.overlapped_elems as f64 / 1e6,
        sync_stats.mean,
        ov_stats.mean,
        (ov_stats.mean_secs() / sync_stats.mean_secs() - 1.0) * 100.0
    );

    write_bench_json(BENCH_JSON, "overlap", &results)?;
    println!("wrote {BENCH_JSON} ({} ops)", results.len());
    Ok(())
}
