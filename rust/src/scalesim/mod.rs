//! Scaling simulator: calibrated machine profiles of Frontier / Perlmutter /
//! Aurora, an analytic step-time model with the paper's exact collective
//! payloads, and the Figure-4 weak/strong sweep driver.

pub mod machines;
pub mod perfmodel;
pub mod sweep;

pub use machines::{machine_by_name, MachineProfile, ALL_MACHINES, AURORA, FRONTIER, PERLMUTTER};
pub use perfmodel::{
    graph_par_boundary_fraction, graph_par_step_comm_time, graph_par_step_elems,
    predicted_overlap_win, step_time_overlapped, step_time_sync, SimMode, Workload,
    OVERLAP_WINDOW_FRACTION,
};
pub use sweep::{fig4_all, render_panel, strong_scaling, to_csv, weak_scaling, SweepRow};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn fig4_covers_six_panels() {
        let rows = fig4_all(&Workload::paper(5), 1);
        for m in ["Frontier", "Perlmutter", "Aurora"] {
            for regime in ["weak", "strong"] {
                assert!(
                    rows.iter().any(|r| r.machine == m && r.regime == regime),
                    "missing panel {m}/{regime}"
                );
            }
        }
        // Aurora reaches 1920 GPUs, the others stop at 640.
        assert!(rows.iter().any(|r| r.machine == "Aurora" && r.n_gpus == 1920));
        assert!(rows.iter().all(|r| r.machine == "Aurora" || r.n_gpus <= 640));
    }

    #[test]
    fn strong_scaling_mtl_par_wins_at_scale() {
        // Fig 4's headline shape: at the largest GPU count MTL-par's epoch
        // time is lower than MTL-base's for the same effective batch.
        let w = Workload::paper(5);
        let rows = strong_scaling(&FRONTIER, &w, &[10240], 1_000_000, 3);
        let at = |mode: &str, gpus: usize| {
            rows.iter()
                .find(|r| r.mode == mode && r.n_gpus == gpus)
                .unwrap()
                .epoch_time_s
        };
        assert!(at("MTL-par", 640) < at("MTL-base", 640));
    }

    #[test]
    fn weak_scaling_grows_slowly() {
        // Weak scaling epoch time should rise with GPU count (comm overhead)
        // but far less than proportionally.
        let w = Workload::paper(5);
        let rows = weak_scaling(&PERLMUTTER, &w, &[320], 100, 5);
        let series: Vec<f64> = rows
            .iter()
            .filter(|r| r.mode == "MTL-par")
            .map(|r| r.epoch_time_s)
            .collect();
        let first = series.first().unwrap();
        let last = series.last().unwrap();
        assert!(last >= &(first * 0.8), "should not collapse");
        assert!(last < &(first * 3.0), "should not explode: {first} -> {last}");
    }

    #[test]
    fn csv_and_panels_render() {
        let w = Workload::paper(5);
        let rows = weak_scaling(&FRONTIER, &w, &[160], 10, 1);
        let csv = to_csv(&rows);
        assert!(csv.lines().count() > rows.len());
        let panel = render_panel(&rows, "Frontier", "weak");
        assert!(panel.contains("MTL-par b=160"));
        assert!(panel.contains("MTL-base b=160"));
    }

    #[test]
    fn ideal_line_reference() {
        // Strong-scaling ideal: time ~ 1/n. Verify our model approaches the
        // ideal at small scale where comm is negligible on Frontier.
        let w = Workload::paper(5);
        let mut rng = Rng::new(0);
        let mut t = |g: usize| {
            perfmodel::epoch_time(
                &FRONTIER,
                &w,
                SimMode::MtlPar,
                perfmodel::ScalePoint { n_gpus: g, local_batch: 20480 / g, steps: 10 },
                &mut rng,
            )
        };
        let t40 = t(40);
        let t80 = t(80);
        let speedup = t40 / t80;
        assert!(speedup > 1.5 && speedup < 2.5, "speedup 40->80 = {speedup}");
    }
}
