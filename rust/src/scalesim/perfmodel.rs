//! Analytic performance model reproducing Figure 4 (weak + strong scaling
//! of MTL-base vs MTL-par on Frontier / Perlmutter / Aurora).
//!
//! Epoch time = steps * (compute + gradient-sync) + per-epoch data cost.
//!
//! * compute: per-sample FLOPs from the exact architecture formulas times
//!   the local batch, over the rank's sustained throughput. MTL-base runs
//!   every head on every rank, MTL-par one head per rank — with the same
//!   *per-dataset* sample budget, both do the same per-sample encoder work;
//!   MTL-base additionally pays all-heads head work per rank.
//! * gradient sync: ring-allreduce cost  2*(n-1)/n * bytes / bw +
//!   2*(n-1)*latency, with the paper's payloads —
//!     MTL-base: one global allreduce of (P_s + N_h*P_h);
//!     MTL-par : global P_s over n ranks + per-subgroup P_h over n/N_h.
//! * noise: multiplicative lognormal-ish jitter per machine (Aurora high).
//!
//! The same collective payload accounting is validated against the real
//! trainer's comm counters in the integration tests, so the simulated and
//! executed systems share their communication structure.

use crate::model::arch::ArchDims;
use crate::scalesim::machines::MachineProfile;
use crate::util::rng::Rng;

/// Scaling-run description (one point of a Fig-4 panel).
#[derive(Debug, Clone, Copy)]
pub struct ScalePoint {
    pub n_gpus: usize,
    /// Samples per GPU per step.
    pub local_batch: usize,
    /// Steps per epoch (derived from the scaling regime).
    pub steps: usize,
}

/// Parallelization mode of Figure 4.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimMode {
    MtlBase,
    MtlPar,
}

impl SimMode {
    pub fn label(&self) -> &'static str {
        match self {
            SimMode::MtlBase => "MTL-base",
            SimMode::MtlPar => "MTL-par",
        }
    }
}

/// Workload constants shared by a sweep.
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    pub dims: ArchDims,
    pub n_heads: usize,
    /// Mean atoms / edges per structure (from the generators' statistics).
    pub avg_nodes: f64,
    pub avg_edges: f64,
    /// Fraction of peak the GNN sustains (sparse gathers hurt).
    pub efficiency: f64,
}

impl Workload {
    pub fn paper(n_heads: usize) -> Workload {
        Workload {
            dims: ArchDims::paper(),
            n_heads,
            avg_nodes: 16.0,
            avg_edges: 120.0,
            efficiency: 0.25,
        }
    }

    /// FLOPs for one structure through encoder (+backward ~ 2x forward).
    pub fn flops_encoder_per_sample(&self) -> f64 {
        let h = self.dims.hidden as f64;
        let r = self.dims.num_rbf as f64;
        let l = self.dims.num_layers as f64;
        // Edge MLP: E * ((2H+R)*H + H*H + H), node MLP: N * (2H*H + H*H),
        // message scatter ~ E*H; x2 mults, x3 fwd+bwd.
        let edge = self.avg_edges * ((2.0 * h + r) * h + h * h + h);
        let node = self.avg_nodes * (2.0 * h * h + h * h);
        let scatter = self.avg_edges * h;
        6.0 * l * (edge + node + scatter)
    }

    /// FLOPs for one structure through ONE branch head.
    pub fn flops_head_per_sample(&self) -> f64 {
        let h = self.dims.hidden as f64;
        let d = self.dims.head_hidden as f64;
        let trunk = self.avg_nodes * (h * d + 2.0 * d * d);
        6.0 * trunk
    }
}

/// Ring allreduce time (seconds) for `bytes` over `n` ranks.
pub fn ring_allreduce_time(m: &MachineProfile, n: usize, bytes: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let steps = 2 * (n - 1);
    let volume = 2.0 * (n as f64 - 1.0) / n as f64 * bytes;
    volume / (m.link_gib_s * 1024.0 * 1024.0 * 1024.0) + steps as f64 * m.latency_us * 1e-6
}

/// Per-step compute time (seconds) on one rank.
pub fn step_compute_time(m: &MachineProfile, w: &Workload, mode: SimMode, local_batch: usize) -> f64 {
    let enc = w.flops_encoder_per_sample();
    let head = w.flops_head_per_sample();
    // MTL-base: each rank runs all N_h heads on N_h per-dataset batches per
    // step (encoder too). MTL-par: one head, one batch.
    let per_sample = match mode {
        SimMode::MtlBase => (enc + head) * w.n_heads as f64,
        SimMode::MtlPar => enc + head,
    };
    per_sample * local_batch as f64 / (m.tflops * 1e12 * w.efficiency)
}

/// Per-step gradient synchronization time (seconds).
pub fn step_comm_time(m: &MachineProfile, w: &Workload, mode: SimMode, n_gpus: usize) -> f64 {
    let ps_bytes = w.dims.shared_params() as f64 * 4.0;
    let ph_bytes = w.dims.head_params() as f64 * 4.0;
    match mode {
        SimMode::MtlBase => {
            ring_allreduce_time(m, n_gpus, ps_bytes + w.n_heads as f64 * ph_bytes)
        }
        SimMode::MtlPar => {
            let sub = (n_gpus / w.n_heads).max(1);
            ring_allreduce_time(m, n_gpus, ps_bytes) + ring_allreduce_time(m, sub, ph_bytes)
        }
    }
}

/// Fraction of a step's compute during which bucket reductions can hide:
/// backward is ~2/3 of the fwd+bwd FLOPs and the bucket plan streams blocks
/// out as backward completes them (trunk/heads first, embedding last), so
/// roughly the backward window is available to the comm thread.
pub const OVERLAP_WINDOW_FRACTION: f64 = 2.0 / 3.0;

/// Per-step time (seconds) on the synchronous path: compute, then the full
/// gradient allreduce on the critical path.
pub fn step_time_sync(
    m: &MachineProfile,
    w: &Workload,
    mode: SimMode,
    n_gpus: usize,
    local_batch: usize,
) -> f64 {
    step_compute_time(m, w, mode, local_batch)
        + step_comm_time(m, w, mode, n_gpus)
        + step_data_time(w, local_batch)
}

/// Per-step time (seconds) with overlapped bucketed reduction: only the
/// communication that does not fit inside the backward window stays on the
/// critical path. Compute is unchanged — overlap hides traffic, it never
/// removes it.
pub fn step_time_overlapped(
    m: &MachineProfile,
    w: &Workload,
    mode: SimMode,
    n_gpus: usize,
    local_batch: usize,
) -> f64 {
    let compute = step_compute_time(m, w, mode, local_batch);
    let comm = step_comm_time(m, w, mode, n_gpus);
    let exposed = (comm - OVERLAP_WINDOW_FRACTION * compute).max(0.0);
    compute + exposed + step_data_time(w, local_batch)
}

/// Predicted fractional step-time win of overlap over sync, in [0, 1).
/// Approaches `comm / (compute + comm)` when the backward window swallows
/// the whole reduction, and 0 when compute dominates so completely that
/// there is nothing worth hiding. `rust/tests/integration_overlap.rs`
/// confronts the sign of this prediction with the measured win.
pub fn predicted_overlap_win(
    m: &MachineProfile,
    w: &Workload,
    mode: SimMode,
    n_gpus: usize,
    local_batch: usize,
) -> f64 {
    let sync = step_time_sync(m, w, mode, n_gpus, local_batch);
    if sync <= 0.0 {
        return 0.0;
    }
    (sync - step_time_overlapped(m, w, mode, n_gpus, local_batch)) / sync
}

/// Per-epoch data-pipeline time: DDStore batch fetch + padding, overlapped
/// except for a small per-step residue; grows slowly with scale (metadata).
pub fn step_data_time(w: &Workload, local_batch: usize) -> f64 {
    // ~1.5 us per structure of batch assembly left on the critical path.
    1.5e-6 * local_batch as f64 * (w.avg_nodes / 16.0)
}

/// Average epoch time for one scaling point.
pub fn epoch_time(
    m: &MachineProfile,
    w: &Workload,
    mode: SimMode,
    p: ScalePoint,
    rng: &mut Rng,
) -> f64 {
    let per_step = step_compute_time(m, w, mode, p.local_batch)
        + step_comm_time(m, w, mode, p.n_gpus)
        + step_data_time(w, p.local_batch);
    let base = per_step * p.steps as f64;
    // Multiplicative noise, clamped positive.
    let noisy = base * (1.0 + rng.normal_scaled(0.0, m.noise_sigma)).max(0.2);
    noisy
}

/// Exact f64 elements ONE graph-parallel training step moves through the
/// collectives — the closed form of
/// [`crate::comm::halo::HaloPlan::predicted_step_elems`], usable before a
/// plan exists: `layers` forward node exchanges (boundary atoms x hidden),
/// `layers` reverse edge exchanges (boundary edges x hidden), the 24-slot
/// segment-folded loss reduce, and the `8 x P` segmented gradient fold.
/// World 1 has empty boundaries but still folds loss + gradients, so the
/// formula holds at every world in {1, 2, 4, 8}. Confronted against both
/// the plan's prediction and the measured [`crate::comm::Comm`] stats delta
/// in `rust/tests/integration_graph_parallel.rs` and the
/// `graph_parallel` bench.
pub fn graph_par_step_elems(
    boundary_atoms: usize,
    boundary_edges: usize,
    hidden: usize,
    layers: usize,
    param_len: usize,
) -> u64 {
    let halo = (boundary_atoms + boundary_edges) * hidden * layers;
    (halo + crate::comm::halo::LOSS_SLOTS + crate::comm::halo::SEGMENTS * param_len) as u64
}

/// Estimated fraction of a structure's atoms on segment boundaries under
/// the 8-segment cell-sorted decomposition: cuts are (roughly) planar, so
/// the boundary scales with the surface-to-volume ratio `n^(2/3) / n`.
/// A coarse planning estimate for sizing halo traffic before featurizing —
/// the exact count comes from `HaloPlan::build`.
pub fn graph_par_boundary_fraction(natoms: usize, world: usize) -> f64 {
    if world <= 1 || natoms == 0 {
        return 0.0;
    }
    // (world - 1) cut planes, each intersecting ~n^(2/3) atoms of an
    // isotropic structure; clamp to 1 for tiny structures where every atom
    // borders a cut.
    let n = natoms as f64;
    ((world - 1) as f64 * n.powf(2.0 / 3.0) / n).min(1.0)
}

/// Predicted per-step wall-clock (seconds) of the graph-parallel exchanges
/// on `m`: every collective in the step is an allreduce over the full
/// `world`, so one ring transfer covers the summed f64 payload.
pub fn graph_par_step_comm_time(m: &MachineProfile, step_elems: u64, world: usize) -> f64 {
    ring_allreduce_time(m, world, step_elems as f64 * 8.0)
}

/// Check the per-GPU parameter memory fits the machine's HBM (the paper's
/// motivation for MTP: MTL-base replicates every head).
pub fn fits_memory(m: &MachineProfile, w: &Workload, mode: SimMode) -> bool {
    let params = match mode {
        SimMode::MtlBase => w.dims.total_params(w.n_heads),
        SimMode::MtlPar => w.dims.shared_params() + w.dims.head_params(),
    };
    let bytes = params * crate::model::arch::TRAIN_BYTES_PER_PARAM;
    (bytes as f64) < m.hbm_gib * 0.9 * 1024.0 * 1024.0 * 1024.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scalesim::machines::{AURORA, FRONTIER, PERLMUTTER};

    fn w() -> Workload {
        Workload::paper(5)
    }

    #[test]
    fn ring_allreduce_scales_with_bytes_and_ranks() {
        let t1 = ring_allreduce_time(&FRONTIER, 8, 1e6);
        let t2 = ring_allreduce_time(&FRONTIER, 8, 1e8);
        assert!(t2 > t1 * 10.0);
        let t3 = ring_allreduce_time(&FRONTIER, 640, 1e6);
        assert!(t3 > t1, "latency term grows with ranks");
        assert_eq!(ring_allreduce_time(&FRONTIER, 1, 1e9), 0.0);
    }

    #[test]
    fn mtl_par_reduces_comm_at_scale() {
        // The paper's core scaling claim: the MTL-par payload (P_s global +
        // P_h subgroup) beats MTL-base (P_s + N_h*P_h global) at scale.
        for m in [&FRONTIER, &PERLMUTTER, &AURORA] {
            let base = step_comm_time(m, &w(), SimMode::MtlBase, 640);
            let par = step_comm_time(m, &w(), SimMode::MtlPar, 640);
            assert!(par < base, "{}: par={par} base={base}", m.name);
        }
    }

    #[test]
    fn mtl_base_computes_more_per_rank() {
        let base = step_compute_time(&FRONTIER, &w(), SimMode::MtlBase, 128);
        let par = step_compute_time(&FRONTIER, &w(), SimMode::MtlPar, 128);
        assert!(base > par * 3.0, "base runs all 5 heads per rank");
    }

    #[test]
    fn memory_model_prefers_mtp_for_many_heads() {
        // With enough heads, MTL-base no longer fits but MTL-par does.
        let mut big = w();
        big.n_heads = 120;
        big.dims.head_hidden = 4096;
        assert!(!fits_memory(&PERLMUTTER, &big, SimMode::MtlBase));
        assert!(fits_memory(&PERLMUTTER, &big, SimMode::MtlPar));
    }

    #[test]
    fn overlap_never_slower_and_wins_when_comm_bound() {
        for m in [&FRONTIER, &PERLMUTTER, &AURORA] {
            for mode in [SimMode::MtlBase, SimMode::MtlPar] {
                for (n, b) in [(8usize, 4usize), (640, 16), (640, 1024)] {
                    let sync = step_time_sync(m, &w(), mode, n, b);
                    let ov = step_time_overlapped(m, &w(), mode, n, b);
                    assert!(ov <= sync + 1e-15, "{} {:?}: ov={ov} sync={sync}", m.name, mode);
                    let win = predicted_overlap_win(m, &w(), mode, n, b);
                    assert!((0.0..1.0).contains(&win));
                }
            }
        }
        // Comm-bound point (many ranks, tiny local batch): overlap must win.
        let win = predicted_overlap_win(&AURORA, &w(), SimMode::MtlBase, 640, 1);
        assert!(win > 0.1, "comm-bound win = {win}");
        // Compute-bound point: the window swallows everything, win ~ comm share.
        let big = predicted_overlap_win(&FRONTIER, &w(), SimMode::MtlBase, 8, 4096);
        let sync = step_time_sync(&FRONTIER, &w(), SimMode::MtlBase, 8, 4096);
        let comm = step_comm_time(&FRONTIER, &w(), SimMode::MtlBase, 8);
        assert!((big - comm / sync).abs() < 1e-12, "fully hidden: win equals comm share");
    }

    #[test]
    fn graph_par_elems_match_a_real_halo_plan() {
        use crate::comm::halo::HaloPlan;
        use crate::data::featurized::compute_segments;
        use crate::data::generators::inorganic::build_crystal;
        use crate::data::graph::radius_graph_positions;
        use crate::util::rng::Rng;

        let (_, positions) = build_crystal(&mut Rng::new(11), &[12, 8, 11, 17], 60);
        let edges = radius_graph_positions(&positions, 6.0);
        let segments = compute_segments(&positions, 6.0);
        let (hidden, layers, p) = (16usize, 4usize, 12_345usize);
        for world in [1usize, 2, 4, 8] {
            let plan = HaloPlan::build(&segments, &edges, world);
            assert_eq!(
                graph_par_step_elems(
                    plan.boundary_atoms().len(),
                    plan.boundary_edges().len(),
                    hidden,
                    layers,
                    p
                ),
                plan.predicted_step_elems(hidden, layers, p),
                "world {world}: the closed form must equal the plan's prediction"
            );
        }
        // World 1 has no boundary: only the loss + gradient folds remain.
        let w1 = HaloPlan::build(&segments, &edges, 1);
        assert!(w1.boundary_atoms().is_empty());
        assert_eq!(
            w1.predicted_step_elems(hidden, layers, p),
            (crate::comm::halo::LOSS_SLOTS + crate::comm::halo::SEGMENTS * p) as u64
        );
    }

    #[test]
    fn graph_par_boundary_fraction_shrinks_with_size() {
        assert_eq!(graph_par_boundary_fraction(1000, 1), 0.0);
        assert_eq!(graph_par_boundary_fraction(0, 8), 0.0);
        let small = graph_par_boundary_fraction(100, 8);
        let large = graph_par_boundary_fraction(100_000, 8);
        assert!(large < small, "surface-to-volume: {large} < {small}");
        assert!((0.0..=1.0).contains(&small) && (0.0..=1.0).contains(&large));
        // More ranks cut more planes.
        assert!(
            graph_par_boundary_fraction(10_000, 8) > graph_par_boundary_fraction(10_000, 2)
        );
    }

    #[test]
    fn graph_par_comm_time_scales_with_payload_and_vanishes_alone() {
        assert_eq!(graph_par_step_comm_time(&FRONTIER, 1 << 20, 1), 0.0);
        let t2 = graph_par_step_comm_time(&FRONTIER, 1 << 20, 2);
        let t2_big = graph_par_step_comm_time(&FRONTIER, 1 << 27, 2);
        assert!(t2 > 0.0 && t2_big > t2 * 10.0);
    }

    #[test]
    fn epoch_time_is_positive_and_noisy() {
        let mut rng = Rng::new(1);
        let p = ScalePoint { n_gpus: 40, local_batch: 160, steps: 100 };
        let a = epoch_time(&AURORA, &w(), SimMode::MtlPar, p, &mut rng);
        let b = epoch_time(&AURORA, &w(), SimMode::MtlPar, p, &mut rng);
        assert!(a > 0.0 && b > 0.0);
        assert!((a - b).abs() > 0.0, "noise should differ draw to draw");
    }

    #[test]
    fn strong_scaling_decreases_epoch_time() {
        // Fixed effective batch: more GPUs -> fewer samples per GPU.
        let mut rng = Rng::new(2);
        let effective = 10240;
        let steps = 50;
        let t40 = epoch_time(
            &FRONTIER,
            &w(),
            SimMode::MtlPar,
            ScalePoint { n_gpus: 40, local_batch: effective / 40, steps },
            &mut rng,
        );
        let t640 = epoch_time(
            &FRONTIER,
            &w(),
            SimMode::MtlPar,
            ScalePoint { n_gpus: 640, local_batch: effective / 640, steps },
            &mut rng,
        );
        assert!(t640 < t40 / 4.0, "t40={t40} t640={t640}");
    }
}
