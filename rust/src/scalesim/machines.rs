//! Machine profiles of the three DOE systems the paper evaluates on
//! (Section 5): NERSC-Perlmutter, OLCF-Frontier, ALCF-Aurora.
//!
//! Numbers are public architecture figures (per-"GPU" = the scheduling unit
//! the paper maps one rank to: an A100, an MI250X *GCD*, a PVC *tile*).
//! They parameterize the analytic performance model in `perfmodel`; only
//! ratios matter for reproducing Figure 4's shape.

/// One supercomputer's per-rank and fabric characteristics.
#[derive(Debug, Clone, Copy)]
pub struct MachineProfile {
    pub name: &'static str,
    /// Ranks (GPUs/GCDs/tiles) per node.
    pub ranks_per_node: usize,
    /// Dense f32-equivalent throughput per rank, TFLOP/s (sustained for
    /// GNN-style mixed dense/sparse work — a fraction of peak).
    pub tflops: f64,
    /// HBM capacity per rank, GiB.
    pub hbm_gib: f64,
    /// Injection bandwidth per rank onto the fabric, GiB/s.
    pub link_gib_s: f64,
    /// Per-message fabric latency, microseconds.
    pub latency_us: f64,
    /// Run-to-run performance noise (relative sigma). The paper observes
    /// "higher variability on Aurora"; we model it explicitly.
    pub noise_sigma: f64,
    /// Largest GPU count used in the paper's plots for this machine.
    pub max_gpus: usize,
}

/// OLCF-Frontier: AMD MI250X, 8 GCDs/node, Slingshot-11.
pub const FRONTIER: MachineProfile = MachineProfile {
    name: "Frontier",
    ranks_per_node: 8,
    tflops: 12.0,
    hbm_gib: 64.0,
    link_gib_s: 25.0,
    latency_us: 2.0,
    noise_sigma: 0.02,
    max_gpus: 640,
};

/// NERSC-Perlmutter: NVIDIA A100, 4 GPUs/node, Slingshot-11.
pub const PERLMUTTER: MachineProfile = MachineProfile {
    name: "Perlmutter",
    ranks_per_node: 4,
    tflops: 10.0,
    hbm_gib: 40.0,
    link_gib_s: 25.0,
    latency_us: 2.0,
    noise_sigma: 0.02,
    max_gpus: 640,
};

/// ALCF-Aurora: Intel Data Center GPU Max (PVC), 12 tiles/node, Slingshot.
pub const AURORA: MachineProfile = MachineProfile {
    name: "Aurora",
    ranks_per_node: 12,
    tflops: 9.0,
    hbm_gib: 64.0,
    link_gib_s: 19.0,
    latency_us: 3.0,
    noise_sigma: 0.08,
    max_gpus: 1920,
};

pub const ALL_MACHINES: [MachineProfile; 3] = [FRONTIER, PERLMUTTER, AURORA];

pub fn machine_by_name(name: &str) -> Option<MachineProfile> {
    ALL_MACHINES
        .iter()
        .find(|m| m.name.eq_ignore_ascii_case(name))
        .copied()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert_eq!(machine_by_name("frontier").unwrap().name, "Frontier");
        assert_eq!(machine_by_name("AURORA").unwrap().max_gpus, 1920);
        assert!(machine_by_name("summit").is_none());
    }

    #[test]
    fn paper_scale_limits() {
        assert_eq!(FRONTIER.max_gpus, 640);
        assert_eq!(PERLMUTTER.max_gpus, 640);
        assert_eq!(AURORA.max_gpus, 1920);
    }

    #[test]
    fn aurora_is_noisiest() {
        assert!(AURORA.noise_sigma > FRONTIER.noise_sigma);
        assert!(AURORA.noise_sigma > PERLMUTTER.noise_sigma);
    }
}
