//! Figure 4 sweep driver: weak + strong scaling series for MTL-base vs
//! MTL-par on each machine, emitted as CSV rows matching the paper's six
//! panels (2 regimes x 3 machines, several batch sizes each).

use crate::scalesim::machines::{MachineProfile, ALL_MACHINES};
use crate::scalesim::perfmodel::{epoch_time, ScalePoint, SimMode, Workload};
use crate::util::rng::Rng;

/// Seed tags separating the weak / strong noise streams.
const WEAK_TAG: u64 = 0x0EA4;
const STRONG_TAG: u64 = 0x57_0126;

#[derive(Debug, Clone)]
pub struct SweepRow {
    pub machine: &'static str,
    pub regime: &'static str, // "weak" | "strong"
    pub mode: &'static str,   // "MTL-base" | "MTL-par"
    pub batch: usize,         // local batch (weak) or effective batch (strong)
    pub n_gpus: usize,
    pub epoch_time_s: f64,
}

/// GPU counts for a machine's panel (paper: 40..640 on Frontier/Perlmutter,
/// 120..1920 on Aurora; both sweeps double each step).
pub fn gpu_counts(m: &MachineProfile) -> Vec<usize> {
    let start = match m.name {
        "Aurora" => 120,
        _ => 40,
    };
    let mut out = Vec::new();
    let mut g = start;
    while g <= m.max_gpus {
        out.push(g);
        g *= 2;
    }
    out
}

/// Weak-scaling panel: fixed local batch per GPU.
pub fn weak_scaling(
    m: &MachineProfile,
    w: &Workload,
    local_batches: &[usize],
    steps: usize,
    seed: u64,
) -> Vec<SweepRow> {
    let mut rng = Rng::new(seed ^ WEAK_TAG);
    let mut rows = Vec::new();
    for &lb in local_batches {
        for mode in [SimMode::MtlBase, SimMode::MtlPar] {
            for &g in &gpu_counts(m) {
                let p = ScalePoint { n_gpus: g, local_batch: lb, steps };
                rows.push(SweepRow {
                    machine: m.name,
                    regime: "weak",
                    mode: mode.label(),
                    batch: lb,
                    n_gpus: g,
                    epoch_time_s: epoch_time(m, w, mode, p, &mut rng),
                });
            }
        }
    }
    rows
}

/// Strong-scaling panel: fixed effective batch across all GPUs.
pub fn strong_scaling(
    m: &MachineProfile,
    w: &Workload,
    effective_batches: &[usize],
    total_samples: usize,
    seed: u64,
) -> Vec<SweepRow> {
    let mut rng = Rng::new(seed ^ STRONG_TAG);
    let mut rows = Vec::new();
    for &eb in effective_batches {
        for mode in [SimMode::MtlBase, SimMode::MtlPar] {
            for &g in &gpu_counts(m) {
                let local = (eb / g).max(1);
                let steps = (total_samples / eb).max(1);
                let p = ScalePoint { n_gpus: g, local_batch: local, steps };
                rows.push(SweepRow {
                    machine: m.name,
                    regime: "strong",
                    mode: mode.label(),
                    batch: eb,
                    n_gpus: g,
                    epoch_time_s: epoch_time(m, w, mode, p, &mut rng),
                });
            }
        }
    }
    rows
}

/// All six panels of Figure 4 with the paper's batch settings.
pub fn fig4_all(w: &Workload, seed: u64) -> Vec<SweepRow> {
    let mut rows = Vec::new();
    for m in &ALL_MACHINES {
        rows.extend(weak_scaling(m, w, &[160, 320, 640], 100, seed));
        rows.extend(strong_scaling(m, w, &[10240, 20480], 1_000_000, seed));
    }
    rows
}

pub fn to_csv(rows: &[SweepRow]) -> String {
    let mut out = String::from("machine,regime,mode,batch,n_gpus,epoch_time_s\n");
    for r in rows {
        out.push_str(&format!(
            "{},{},{},{},{},{:.6}\n",
            r.machine, r.regime, r.mode, r.batch, r.n_gpus, r.epoch_time_s
        ));
    }
    out
}

/// Render one panel as an aligned text table (series per (mode, batch)).
pub fn render_panel(rows: &[SweepRow], machine: &str, regime: &str) -> String {
    let panel: Vec<&SweepRow> =
        rows.iter().filter(|r| r.machine == machine && r.regime == regime).collect();
    let mut gpus: Vec<usize> = panel.iter().map(|r| r.n_gpus).collect();
    gpus.sort_unstable();
    gpus.dedup();
    let mut series: Vec<(&str, usize)> =
        panel.iter().map(|r| (r.mode, r.batch)).collect();
    series.sort();
    series.dedup();

    let mut out = format!("-- {machine} / {regime} scaling: epoch time (s) --\n");
    out.push_str(&format!("{:<22}", "series \\ gpus"));
    for g in &gpus {
        out.push_str(&format!("{g:>10}"));
    }
    out.push('\n');
    for (mode, batch) in series {
        out.push_str(&format!("{:<22}", format!("{mode} b={batch}")));
        for g in &gpus {
            let v = panel
                .iter()
                .find(|r| r.mode == mode && r.batch == batch && r.n_gpus == *g)
                .map(|r| r.epoch_time_s)
                .unwrap_or(f64::NAN);
            out.push_str(&format!("{v:>10.3}"));
        }
        out.push('\n');
    }
    out
}
