//! The 2D-parallel training coordinator — the paper's system contribution.
//!
//! Three execution modes (Section 5.1's seven models reduce to these):
//!
//! * `Single(d)` / `BaselineAll` — one branch, plain DDP: every rank holds
//!   encoder + the branch; gradients allreduce over the global group.
//! * `MtlBase` — two-level MTL with DDP only: every rank holds encoder +
//!   ALL `N_h` branches, processes one batch per dataset per step, and
//!   allreduces the full `P_s + N_h*P_h` gradient payload globally.
//! * `MtlPar` — **multi-task parallelism** x DDP (the contribution): the
//!   mesh is `N_h` head sub-groups x `M` replicas; each rank holds encoder
//!   + exactly ONE branch, works only on its head's dataset, allreduces
//!   branch gradients within its sub-group (`P_h` payload) and encoder
//!   gradients globally (`P_s` payload).
//!
//! Ranks are OS threads sharing the PJRT engine; collectives are the
//! `comm` module's rendezvous groups, so the communication *pattern* is
//! exactly the paper's Figure 3 even though transport is shared memory.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::checkpoint::{self, OptHeads, TrainCheckpoint};
use crate::comm::overlap::{BucketPlan, OverlapReducer, OverlapSink, Segment};
use crate::comm::{
    build_mesh_with_timeout, build_ragged_mesh_with_timeout, Comm, CommError, MeshRank,
    MeshShape, RaggedMeshRank, RaggedShape,
};
use crate::config::{RunConfig, TrainMode};
use crate::fault::{self, FaultPlan};
use crate::coordinator::metrics::{Coverage, EpochMetrics, RunLog, StepAccum};
use crate::coordinator::scheduler::{plan_head_groups_with_fallback, EarlyStopper};
use crate::data::batch::{BatchBuilder, BatchPool, GraphBatch};
use crate::data::featurized::FeaturizedStore;
use crate::data::split::{Split, SplitSpec};
use crate::data::structures::{AtomicStructure, DatasetId};
use crate::data::DDStore;
use crate::model::egnn::{BranchParams, EgnnDims, EncoderParams};
use crate::model::graphpar::{self, GpPlan, GpStructure, GradLayout};
use crate::model::optimizer::{AdamW, AdamWConfig, AdamWState};
use crate::model::params::ParamSet;
use crate::runtime::Engine;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// data bundle
// ---------------------------------------------------------------------------

/// Per-dataset train/val/test structure lists.
pub struct DataBundle {
    pub train: BTreeMap<DatasetId, Arc<Vec<AtomicStructure>>>,
    pub val: BTreeMap<DatasetId, Arc<Vec<AtomicStructure>>>,
    pub test: BTreeMap<DatasetId, Arc<Vec<AtomicStructure>>>,
}

impl DataBundle {
    /// Generate synthetic data for `datasets` per the run config, one scoped
    /// thread per dataset. Generation is embarrassingly parallel: every
    /// dataset's RNG stream is seeded only by `(cfg.seed, dataset)`, so the
    /// output is bit-identical to [`DataBundle::generate_serial`] (proven in
    /// `rust/tests/integration_featurized.rs`).
    pub fn generate(cfg: &crate::config::DataConfig, datasets: &[DatasetId]) -> DataBundle {
        let parts: Vec<_> = std::thread::scope(|scope| {
            let handles: Vec<_> = datasets
                .iter()
                .map(|&d| scope.spawn(move || generate_one(cfg, d)))
                .collect();
            handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(part) => part,
                    // A generator panic is a bug in deterministic, input-free
                    // code; re-raise it on the caller thread with its original
                    // payload rather than minting a second panic here.
                    Err(payload) => std::panic::resume_unwind(payload),
                })
                .collect()
        });
        Self::assemble(datasets, parts)
    }

    /// Serial reference generator (the seed code path), kept as the
    /// bit-identity oracle for the parallel [`DataBundle::generate`].
    pub fn generate_serial(
        cfg: &crate::config::DataConfig,
        datasets: &[DatasetId],
    ) -> DataBundle {
        let parts = datasets.iter().map(|&d| generate_one(cfg, d)).collect();
        Self::assemble(datasets, parts)
    }

    fn assemble(datasets: &[DatasetId], parts: Vec<DatasetSplits>) -> DataBundle {
        let mut train = BTreeMap::new();
        let mut val = BTreeMap::new();
        let mut test = BTreeMap::new();
        for (&d, (tr, va, te)) in datasets.iter().zip(parts) {
            train.insert(d, Arc::new(tr));
            val.insert(d, Arc::new(va));
            test.insert(d, Arc::new(te));
        }
        DataBundle { train, val, test }
    }

    pub fn datasets(&self) -> Vec<DatasetId> {
        self.train.keys().copied().collect()
    }
}

/// (train, val, test) structure lists for one dataset.
type DatasetSplits = (Vec<AtomicStructure>, Vec<AtomicStructure>, Vec<AtomicStructure>);

/// Generate and split one dataset (deterministic in `(cfg, d)` alone).
fn generate_one(cfg: &crate::config::DataConfig, d: DatasetId) -> DatasetSplits {
    use crate::data::generators::{DatasetGenerator, GeneratorConfig};
    let spec = SplitSpec { train: cfg.train_frac, val: cfg.val_frac };
    let mut g = DatasetGenerator::new(
        d,
        cfg.seed,
        GeneratorConfig { max_atoms: cfg.max_atoms, ..Default::default() },
    );
    let samples = g.take(cfg.per_dataset);
    let mut tr = Vec::new();
    let mut va = Vec::new();
    let mut te = Vec::new();
    for (i, s) in samples.into_iter().enumerate() {
        match spec.of(i, cfg.seed ^ d.index() as u64) {
            Split::Train => tr.push(s),
            Split::Val => va.push(s),
            Split::Test => te.push(s),
        }
    }
    (tr, va, te)
}

// ---------------------------------------------------------------------------
// trained model
// ---------------------------------------------------------------------------

/// Final parameters of a training run.
#[derive(Clone)]
pub struct TrainedModel {
    pub name: String,
    /// Encoder leaves ("encoder.*").
    pub encoder: ParamSet,
    /// Branch leaves ("branch.*"): one shared branch, or one per dataset.
    pub heads: Heads,
}

#[derive(Clone)]
pub enum Heads {
    Shared(ParamSet),
    PerDataset(BTreeMap<DatasetId, ParamSet>),
}

impl TrainedModel {
    /// The branch used to predict data from `d`, if the model has one.
    pub fn try_branch_for(&self, d: DatasetId) -> Option<&ParamSet> {
        match &self.heads {
            Heads::Shared(b) => Some(b),
            Heads::PerDataset(m) => m.get(&d),
        }
    }

    /// Full engine-callable parameter set for dataset `d`. Errors (naming
    /// the task) when the model carries no head for it — the seed panicked
    /// here via `branch_for`, which took down serving threads on a routing
    /// mistake instead of surfacing a typed error.
    pub fn full_params(&self, engine: &Engine, d: DatasetId) -> anyhow::Result<ParamSet> {
        let branch = self.try_branch_for(d).ok_or_else(|| {
            anyhow::anyhow!(
                "model '{}' has no trained head for task {}",
                self.name,
                d.name()
            )
        })?;
        let mut full = ParamSet::zeros_like(&engine.manifest.params);
        full.copy_matching_from(&self.encoder);
        full.copy_matching_from(branch);
        Ok(full)
    }
}

// ---------------------------------------------------------------------------
// trainer
// ---------------------------------------------------------------------------

pub struct Trainer {
    pub engine: Arc<Engine>,
    pub cfg: RunConfig,
}

/// Outcome of a training run: final model + rank-0 metrics log + comm stats.
pub struct TrainOutcome {
    pub model: TrainedModel,
    pub log: RunLog,
    /// (global allreduced f32 elements, head-group allreduced f32 elements).
    pub comm_elems: (u64, u64),
    /// f32 elements reduced while backward still ran (the overlapped path's
    /// traffic; 0 on the synchronous path). Max over ranks, global +
    /// head-group combined.
    pub overlapped_elems: u64,
    /// Per-head sub-group sizes of the last trained epoch under elastic
    /// MTL-par scheduling; empty for every other mode/configuration.
    pub final_head_sizes: Vec<usize>,
}

impl Trainer {
    pub fn new(engine: Arc<Engine>, cfg: RunConfig) -> Trainer {
        Trainer { engine, cfg }
    }

    /// Run the configured training mode on `data`.
    ///
    /// When `cfg.checkpoint.dir` is set, rank 0 writes a CRC-guarded
    /// checkpoint (`crate::checkpoint`) at every `cfg.checkpoint.every`-th
    /// epoch boundary (plus the final / early-stop epoch). When
    /// `cfg.checkpoint.resume` is set, training restarts from that file and
    /// the resumed run is bit-identical to an uninterrupted one (proven in
    /// `rust/tests/integration_checkpoint.rs`).
    pub fn train(&self, data: &DataBundle) -> anyhow::Result<TrainOutcome> {
        let plan = Arc::new(self.cfg.fault.plan()?);
        self.train_with_plan(data, &plan)
    }

    /// [`Trainer::train`] with an explicit fault-injection plan (the plan's
    /// fired-once state must be shared across recovery attempts, so
    /// [`Trainer::train_with_recovery`] builds it once and passes it here).
    pub fn train_with_plan(
        &self,
        data: &DataBundle,
        plan: &Arc<FaultPlan>,
    ) -> anyhow::Result<TrainOutcome> {
        validate_bundle(self.cfg.mode, data)?;
        let resume = self.load_resume(data)?;
        let graph_par = self.cfg.parallel.graph_par;
        match self.cfg.mode {
            TrainMode::Single(d) if graph_par => {
                self.train_graph_par(data, vec![d], resume, plan)
            }
            TrainMode::BaselineAll if graph_par => {
                let datasets = data.datasets();
                self.train_graph_par(data, datasets, resume, plan)
            }
            _ if graph_par => anyhow::bail!(
                "parallel.graph_par applies to the single-branch modes only \
                 (a dataset name or baseline-all); got mode '{}'",
                self.cfg.mode.name()
            ),
            TrainMode::Single(d) => self.train_ddp(data, vec![d], resume, plan),
            TrainMode::BaselineAll => {
                let datasets = data.datasets();
                self.train_ddp(data, datasets, resume, plan)
            }
            TrainMode::MtlBase => self.train_mtl_base(data, resume, plan),
            TrainMode::MtlPar => self.train_mtl_par(data, resume, plan),
        }
    }

    /// [`Trainer::train`] under rank-failure supervision: a run that dies
    /// with a typed [`CommError`] anywhere in its error chain (a rank
    /// panicked, exited early, or a collective timed out) is restarted from
    /// the latest **CRC-valid** checkpoint in `cfg.checkpoint.dir` (corrupt
    /// or truncated files are warned about and skipped; none valid means a
    /// cold restart), up to `cfg.fault.max_restarts` times. Resume is
    /// bit-identical and injected faults fire at most once, so the
    /// recovered run's final parameters equal a fault-free run's bit for
    /// bit (`rust/tests/integration_chaos.rs`). Non-communication errors
    /// (bad config, exhausted skip budget) are never retried.
    pub fn train_with_recovery(&self, data: &DataBundle) -> anyhow::Result<TrainOutcome> {
        let plan = Arc::new(self.cfg.fault.plan()?);
        let max_restarts = self.cfg.fault.max_restarts;
        let mut cfg = self.cfg.clone();
        // A `loop` + explicit counter instead of `for 0..=max_restarts`: every
        // exit is a `return` inside the body, so no unreachable fall-through
        // arm is needed after the loop (hydra-lint R2 keeps this supervision
        // path free of panicking constructs).
        let mut attempt = 0;
        loop {
            let t = Trainer { engine: Arc::clone(&self.engine), cfg: cfg.clone() };
            let err = match t.train_with_plan(data, &plan) {
                Ok(out) => return Ok(out),
                Err(e) => e,
            };
            let rank_failure =
                err.chain().any(|c| c.downcast_ref::<CommError>().is_some());
            if !rank_failure || attempt == max_restarts {
                return Err(err);
            }
            let resume = match &cfg.checkpoint.dir {
                Some(dir) => checkpoint::latest_valid_in_dir(dir)?
                    .map(|p| p.display().to_string()),
                None => None,
            };
            eprintln!(
                "rank failure on attempt {}/{}: {err:#}; restarting {}",
                attempt + 1,
                max_restarts + 1,
                match &resume {
                    Some(p) => format!("from checkpoint {p}"),
                    None => "from scratch (no valid checkpoint found)".to_string(),
                }
            );
            cfg.checkpoint.resume = resume;
            attempt += 1;
        }
    }

    /// Load + validate the checkpoint named by `cfg.checkpoint.resume`.
    fn load_resume(
        &self,
        data: &DataBundle,
    ) -> anyhow::Result<Option<Arc<TrainCheckpoint>>> {
        let Some(spec) = &self.cfg.checkpoint.resume else {
            return Ok(None);
        };
        // `--resume latest`: scan the checkpoint dir for the newest
        // CRC-valid file, warning about and skipping corrupt or truncated
        // ones — the same scan rank-failure recovery uses.
        let path = if spec == "latest" {
            let dir = self.cfg.checkpoint.dir.as_ref().ok_or_else(|| {
                anyhow::anyhow!(
                    "resume spec 'latest' requires a checkpoint dir (--checkpoint-dir)"
                )
            })?;
            checkpoint::latest_valid_in_dir(dir)?.ok_or_else(|| {
                anyhow::anyhow!("resume spec 'latest': no valid checkpoint in {dir}")
            })?
        } else {
            checkpoint::resolve_resume_path(spec)?
        };
        let ckpt = checkpoint::load_train(&path)?;
        let datasets = match self.cfg.mode {
            TrainMode::Single(d) => vec![d],
            _ => data.datasets(),
        };
        // Fingerprint with the RESOLVED backend + precision: `auto` (or a
        // HYDRA_MTP_PRECISION override) can resolve differently on the
        // writing and resuming machines, and native/PJRT or f64/mixed-f32
        // numerics must never be silently mixed mid-run.
        ckpt.validate_for(
            &self.cfg.mode.name(),
            self.cfg.train.seed,
            &self.cfg.trajectory_fingerprint_resolved(
                self.engine.backend_name(),
                self.engine.precision().name(),
            ),
            &datasets,
        )?;
        // Structural compatibility with the engine this run is about to use
        // (a clear error here beats an unflatten panic inside a rank loop).
        let template = ParamSet::zeros_like(&self.engine.manifest.params);
        anyhow::ensure!(
            ckpt.model.encoder.same_structure(&template.subset("encoder.")),
            "{}: checkpoint encoder structure does not match the loaded artifacts",
            path.display()
        );
        let branch_template = template.subset("branch.");
        let branches: Vec<&ParamSet> = match &ckpt.model.heads {
            Heads::Shared(b) => vec![b],
            Heads::PerDataset(m) => m.values().collect(),
        };
        for b in branches {
            anyhow::ensure!(
                b.same_structure(&branch_template),
                "{}: checkpoint branch structure does not match the loaded artifacts",
                path.display()
            );
        }
        eprintln!(
            "resuming {} from {} ({} epochs done)",
            self.cfg.mode.name(),
            path.display(),
            ckpt.epochs_done
        );
        Ok(Some(Arc::new(ckpt)))
    }

    // -- mode: single-branch DDP (Single / BaselineAll) ---------------------

    /// One branch, `replicas` DDP ranks. For BaselineAll the stream mixes
    /// every dataset through the same head (the paper's GFM-Baseline-All).
    fn train_ddp(
        &self,
        data: &DataBundle,
        datasets: Vec<DatasetId>,
        resume: Option<Arc<TrainCheckpoint>>,
        plan: &Arc<FaultPlan>,
    ) -> anyhow::Result<TrainOutcome> {
        let replicas = self.cfg.parallel.replicas;
        let shape = MeshShape { num_heads: 1, replicas };
        let mesh = build_mesh_with_timeout(shape, self.cfg.fault.comm_timeout());
        let engine = &self.engine;
        let cfg = &self.cfg;
        let plan = &**plan;

        // Mixed stream: concatenate (dataset-tagged) training samples.
        // Featurize once, up front: warm epochs only shuffle and pack.
        let cutoff = engine.manifest.config.cutoff;
        let mixed: Vec<AtomicStructure> = datasets
            .iter()
            .flat_map(|d| data.train[d].iter().cloned())
            .collect();
        let store = FeaturizedStore::build(DDStore::new(mixed, replicas), cutoff);
        let val_mixed: Vec<AtomicStructure> = datasets
            .iter()
            .flat_map(|d| data.val[d].iter().cloned())
            .collect();
        let val_store = FeaturizedStore::build(DDStore::new(val_mixed, replicas), cutoff);

        let results = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for mr in mesh {
                let store = Arc::clone(&store);
                let val_store = Arc::clone(&val_store);
                let datasets = datasets.clone();
                let resume = resume.clone();
                handles.push(scope.spawn(move || {
                    let guards = (mr.global.member_guard(), mr.head_group.member_guard());
                    let out = rank_loop_single_branch(
                        engine, cfg, mr, store, val_store, &datasets, resume, plan,
                    );
                    if out.is_ok() {
                        guards.0.disarm();
                        guards.1.disarm();
                    }
                    out
                }));
            }
            join_ranks(handles)
        })?;

        let name = self.cfg.mode.name();
        finalize_shared(name, results, datasets)
    }

    // -- mode: graph-parallel single branch ----------------------------------

    /// One branch, `replicas` ranks cooperating on every structure: each
    /// structure's atoms are domain-decomposed into 8 spatial segments
    /// (`FeaturizedStore::segments`), ranks own contiguous segment ranges
    /// and exchange boundary (halo) activations per EGNN block instead of
    /// replicating the whole graph. The per-structure loss and the folded
    /// gradient are bit-identical on every world size in {1, 2, 4, 8}
    /// (`model::graphpar`, proven in
    /// `rust/tests/integration_graph_parallel.rs`), so the trained model is
    /// bit-for-bit the single-rank model while the per-rank working set
    /// shrinks with the world — the path to structures too large for one
    /// rank's memory.
    fn train_graph_par(
        &self,
        data: &DataBundle,
        datasets: Vec<DatasetId>,
        resume: Option<Arc<TrainCheckpoint>>,
        plan: &Arc<FaultPlan>,
    ) -> anyhow::Result<TrainOutcome> {
        let replicas = self.cfg.parallel.replicas;
        anyhow::ensure!(
            matches!(replicas, 1 | 2 | 4 | 8),
            "graph-parallel training requires replicas in {{1, 2, 4, 8}} (the 8-segment \
             decomposition must split evenly across ranks); got {replicas}"
        );
        let shape = MeshShape { num_heads: 1, replicas };
        let mesh = build_mesh_with_timeout(shape, self.cfg.fault.comm_timeout());
        let engine = &self.engine;
        let cfg = &self.cfg;
        let plan = &**plan;

        // Graph parallelism splits ATOMS across ranks, not structures:
        // every rank steps the same structure, so the store is built with
        // world 1 (no round-robin sample sharding).
        let cutoff = engine.manifest.config.cutoff;
        let mixed: Vec<AtomicStructure> =
            datasets.iter().flat_map(|d| data.train[d].iter().cloned()).collect();
        let store = FeaturizedStore::build(DDStore::new(mixed, 1), cutoff);
        let val_mixed: Vec<AtomicStructure> =
            datasets.iter().flat_map(|d| data.val[d].iter().cloned()).collect();
        let val_store = FeaturizedStore::build(DDStore::new(val_mixed, 1), cutoff);

        let results = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for mr in mesh {
                let store = Arc::clone(&store);
                let val_store = Arc::clone(&val_store);
                let datasets = datasets.clone();
                let resume = resume.clone();
                handles.push(scope.spawn(move || {
                    let guards = (mr.global.member_guard(), mr.head_group.member_guard());
                    let out = rank_loop_graph_par(
                        engine, cfg, mr, store, val_store, &datasets, resume, plan,
                    );
                    if out.is_ok() {
                        guards.0.disarm();
                        guards.1.disarm();
                    }
                    out
                }));
            }
            join_ranks(handles)
        })?;

        let name = self.cfg.mode.name();
        finalize_shared(name, results, datasets)
    }

    // -- mode: MTL-base (all heads everywhere, DDP only) ---------------------

    fn train_mtl_base(
        &self,
        data: &DataBundle,
        resume: Option<Arc<TrainCheckpoint>>,
        plan: &Arc<FaultPlan>,
    ) -> anyhow::Result<TrainOutcome> {
        let replicas = self.cfg.parallel.replicas;
        let shape = MeshShape { num_heads: 1, replicas };
        let mesh = build_mesh_with_timeout(shape, self.cfg.fault.comm_timeout());
        let engine = &self.engine;
        let cfg = &self.cfg;
        let plan = &**plan;
        let datasets = data.datasets();

        let cutoff = engine.manifest.config.cutoff;
        let stores: BTreeMap<DatasetId, Arc<FeaturizedStore>> = datasets
            .iter()
            .map(|&d| {
                (d, FeaturizedStore::build(DDStore::new(data.train[&d].to_vec(), replicas), cutoff))
            })
            .collect();
        let val_stores: BTreeMap<DatasetId, Arc<FeaturizedStore>> = datasets
            .iter()
            .map(|&d| {
                (d, FeaturizedStore::build(DDStore::new(data.val[&d].to_vec(), replicas), cutoff))
            })
            .collect();

        let results = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for mr in mesh {
                let stores = stores.clone();
                let val_stores = val_stores.clone();
                let datasets = datasets.clone();
                let resume = resume.clone();
                handles.push(scope.spawn(move || {
                    let guards = (mr.global.member_guard(), mr.head_group.member_guard());
                    let out = rank_loop_mtl_base(
                        engine, cfg, mr, stores, val_stores, &datasets, resume, plan,
                    );
                    if out.is_ok() {
                        guards.0.disarm();
                        guards.1.disarm();
                    }
                    out
                }));
            }
            join_ranks(handles)
        })?;

        finalize_per_dataset("GFM-MTL-All (MTL-base)".to_string(), results, &datasets)
    }

    // -- mode: MTL-par (multi-task parallelism x DDP) ------------------------

    fn train_mtl_par(
        &self,
        data: &DataBundle,
        resume: Option<Arc<TrainCheckpoint>>,
        plan: &Arc<FaultPlan>,
    ) -> anyhow::Result<TrainOutcome> {
        if self.cfg.parallel.elastic {
            return self.train_mtl_par_elastic(data, resume, plan);
        }
        let datasets = data.datasets();
        let replicas = self.cfg.parallel.replicas;
        let shape = MeshShape { num_heads: datasets.len(), replicas };
        let mesh = build_mesh_with_timeout(shape, self.cfg.fault.comm_timeout());
        let engine = &self.engine;
        let cfg = &self.cfg;
        let plan = &**plan;

        // One store per head sub-group: world = replicas.
        let cutoff = engine.manifest.config.cutoff;
        let stores: Vec<Arc<FeaturizedStore>> = datasets
            .iter()
            .map(|d| FeaturizedStore::build(DDStore::new(data.train[d].to_vec(), replicas), cutoff))
            .collect();
        let val_stores: Vec<Arc<FeaturizedStore>> = datasets
            .iter()
            .map(|d| FeaturizedStore::build(DDStore::new(data.val[d].to_vec(), replicas), cutoff))
            .collect();

        let results = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            let datasets = &datasets;
            for mr in mesh {
                let store = Arc::clone(&stores[mr.head]);
                let val_store = Arc::clone(&val_stores[mr.head]);
                let resume = resume.clone();
                handles.push(scope.spawn(move || {
                    let guards = (mr.global.member_guard(), mr.head_group.member_guard());
                    let out = rank_loop_mtl_par(
                        engine, cfg, mr, store, val_store, datasets, resume, plan,
                    );
                    if out.is_ok() {
                        guards.0.disarm();
                        guards.1.disarm();
                    }
                    out
                }));
            }
            join_ranks(handles)
        })?;

        finalize_per_dataset("GFM-MTL-All (MTL-par)".to_string(), results, &datasets)
    }

    /// Elastic MTL-par: the mesh is static within an epoch but re-planned at
    /// every epoch boundary. Each head's sub-group size comes from its
    /// measured cost — the per-step wall-time EMA ([`Coverage::step_ms`],
    /// persisted in checkpoints so a resumed run replans from the same
    /// history) times its dataset size. Ranks are re-spawned per epoch over
    /// a [`RaggedShape`] mesh; the driver carries encoder, branches, and
    /// optimizer state across the boundary and writes the checkpoints
    /// itself (it already holds every head — no gather collective needed).
    fn train_mtl_par_elastic(
        &self,
        data: &DataBundle,
        resume: Option<Arc<TrainCheckpoint>>,
        plan: &Arc<FaultPlan>,
    ) -> anyhow::Result<TrainOutcome> {
        let engine = &self.engine;
        let cfg = &self.cfg;
        let plan = &**plan;
        let datasets = data.datasets();
        let nh = datasets.len();
        let world = nh * cfg.parallel.replicas;
        let cutoff = engine.manifest.config.cutoff;

        // Start-of-run state, carried by the driver between epochs.
        let (init_encoder, init_branches) = init_rank_params(engine, cfg, &datasets);
        let mut encoder = init_encoder;
        let mut opt_enc_state = AdamW::new(adamw_cfg(cfg), &encoder).export_state();
        let mut heads: Vec<ElasticHead> = init_branches
            .into_iter()
            .map(|(dataset, branch)| {
                let opt = AdamW::new(adamw_cfg(cfg), &branch).export_state();
                ElasticHead { dataset, branch, opt, step_ms: 0.0 }
            })
            .collect();

        let mut log = RunLog::new("GFM-MTL-All (MTL-par)");
        let mut stopper = restore_stopper(cfg, resume.as_deref());
        let (start_epoch, end_epoch) = epoch_range(cfg, resume.as_deref());
        let mut base_cg = 0u64;
        let mut base_ch = 0u64;
        let mut overlapped = 0u64;
        if let Some(ckpt) = &resume {
            encoder = ckpt.model.encoder.clone();
            let saved_heads = match &ckpt.model.heads {
                Heads::PerDataset(m) => m,
                Heads::Shared(_) => anyhow::bail!(
                    "checkpoint is shared-head but mode mtl-par is per-dataset"
                ),
            };
            for h in heads.iter_mut() {
                h.branch = saved_heads
                    .get(&h.dataset)
                    .ok_or_else(|| {
                        anyhow::anyhow!("checkpoint has no head for {}", h.dataset.name())
                    })?
                    .clone();
                h.opt = ckpt.opt_for(h.dataset)?.clone();
            }
            opt_enc_state = ckpt.opt_encoder.clone();
            log = ckpt.log.clone();
            base_cg = ckpt.comm_global;
            base_ch = ckpt.comm_head;
            // Re-seed the cost EMAs from the last persisted coverage so the
            // resumed replan matches the uninterrupted run's.
            if let Some(last) = log.epochs.last() {
                for c in &last.coverage {
                    if let Some(h) =
                        heads.iter_mut().find(|h| h.dataset.name() == c.dataset)
                    {
                        h.step_ms = c.step_ms;
                    }
                }
            }
        }

        let mut final_sizes: Vec<usize> = vec![cfg.parallel.replicas; nh];
        for epoch in start_epoch..end_epoch {
            // Cost of head h ~ (per-step time EMA) x (dataset size): the
            // serial work its sub-group must absorb this epoch. Heads with
            // no EMA yet (first epoch, or a coverage row that never seeded)
            // fall back to dataset-size weighting instead of being starved
            // at the 1-rank floor by a zero weight.
            let costs: Vec<f64> = heads
                .iter()
                .map(|h| h.step_ms * data.train[&h.dataset].len() as f64)
                .collect();
            let planned: Vec<usize> =
                heads.iter().map(|h| data.train[&h.dataset].len()).collect();
            let sizes = plan_head_groups_with_fallback(&costs, &planned, world)?;
            let shape = RaggedShape::new(sizes)?;
            final_sizes = shape.head_sizes().to_vec();
            let mesh = build_ragged_mesh_with_timeout(&shape, cfg.fault.comm_timeout());
            // Stores are sharded at THIS epoch's sub-group sizes.
            let stores: Vec<Arc<FeaturizedStore>> = datasets
                .iter()
                .enumerate()
                .map(|(h, d)| {
                    FeaturizedStore::build(
                        DDStore::new(data.train[d].to_vec(), shape.head_size(h)),
                        cutoff,
                    )
                })
                .collect();
            let val_stores: Vec<Arc<FeaturizedStore>> = datasets
                .iter()
                .enumerate()
                .map(|(h, d)| {
                    FeaturizedStore::build(
                        DDStore::new(data.val[d].to_vec(), shape.head_size(h)),
                        cutoff,
                    )
                })
                .collect();

            let mut results = std::thread::scope(|scope| {
                let mut handles = Vec::new();
                let heads_ref = &heads;
                let encoder_ref = &encoder;
                let opt_enc_ref = &opt_enc_state;
                for mr in mesh {
                    let store = Arc::clone(&stores[mr.head]);
                    let val_store = Arc::clone(&val_stores[mr.head]);
                    handles.push(scope.spawn(move || {
                        let guards =
                            (mr.global.member_guard(), mr.head_group.member_guard());
                        let head = &heads_ref[mr.head];
                        let out = rank_epoch_mtl_par_elastic(
                            engine,
                            cfg,
                            mr,
                            epoch,
                            store,
                            val_store,
                            encoder_ref,
                            opt_enc_ref,
                            head,
                            plan,
                        );
                        if out.is_ok() {
                            guards.0.disarm();
                            guards.1.disarm();
                        }
                        out
                    }));
                }
                join_ranks(handles)
            })?;
            results.sort_by_key(|r| r.rank);
            let pairs: Vec<(usize, &ParamSet)> =
                results.iter().map(|r| (r.rank, &r.encoder)).collect();
            check_encoder_pairs(&pairs)?;
            let r0 = results
                .first()
                .ok_or_else(|| anyhow::anyhow!("no rank results"))?;
            let mut em = r0.metrics.clone();
            let val_loss = em.val_loss;
            encoder = r0.encoder.clone();
            opt_enc_state = r0.opt_enc.clone();
            base_cg += results.iter().map(|r| r.comm_global).max().unwrap_or(0);
            base_ch += results.iter().map(|r| r.comm_head).max().unwrap_or(0);
            overlapped += results.iter().map(|r| r.comm_overlapped).max().unwrap_or(0);
            for r in &results {
                if r.replica == 0 {
                    heads[r.head].branch = r.branch.clone();
                    heads[r.head].opt = r.opt_br.clone();
                }
            }
            // Full per-head coverage row (dataset order) from each head's
            // root rank; fold the fresh EMAs back into the driver state —
            // these are next epoch's replan inputs.
            let mut coverage = Vec::with_capacity(nh);
            for h in 0..nh {
                let root = shape.head_root(h);
                let c = results
                    .iter()
                    .find(|r| r.rank == root)
                    .and_then(|r| r.metrics.coverage.first())
                    .cloned()
                    .ok_or_else(|| {
                        anyhow::anyhow!("head {h} root rank {root} returned no coverage")
                    })?;
                heads[h].step_ms = c.step_ms;
                coverage.push(c);
            }
            em.coverage = coverage;
            log.push(em);
            let stop = stopper.update(val_loss);
            if save_after_epoch(cfg, epoch, end_epoch, stop) {
                let model = TrainedModel {
                    name: cfg.mode.name(),
                    encoder: encoder.clone(),
                    heads: Heads::PerDataset(
                        heads.iter().map(|h| (h.dataset, h.branch.clone())).collect(),
                    ),
                };
                let opts = OptHeads::PerDataset(
                    heads.iter().map(|h| (h.dataset.name(), h.opt.clone())).collect(),
                );
                let saved = save_checkpoint_rank0(
                    engine,
                    cfg,
                    epoch + 1,
                    stop,
                    &stopper,
                    model,
                    opt_enc_state.clone(),
                    opts,
                    &log,
                    base_cg,
                    base_ch,
                );
                warn_save_failure(epoch + 1, saved);
                inject_checkpoint_corruption(plan, cfg, epoch + 1);
            }
            if stop {
                break;
            }
        }

        let model = TrainedModel {
            name: "GFM-MTL-All (MTL-par)".to_string(),
            encoder,
            heads: Heads::PerDataset(
                heads.into_iter().map(|h| (h.dataset, h.branch)).collect(),
            ),
        };
        Ok(TrainOutcome {
            model,
            log,
            comm_elems: (base_cg, base_ch),
            overlapped_elems: overlapped,
            final_head_sizes: final_sizes,
        })
    }

    // -- warm-start fine-tuning ---------------------------------------------

    /// Warm-start fine-tuning: adopt a pre-trained `encoder`, freeze it,
    /// and train ONLY the branch of `dataset` on that dataset's data (DDP
    /// over `cfg.parallel.replicas` ranks, branch gradients only). This is
    /// how a new task registered at runtime via `TaskRegistry` rides on a
    /// checkpointed foundation model without re-running pre-training.
    pub fn fine_tune_head(
        &self,
        data: &DataBundle,
        encoder: &ParamSet,
        dataset: DatasetId,
    ) -> anyhow::Result<TrainOutcome> {
        anyhow::ensure!(
            data.train.contains_key(&dataset)
                && data.val.contains_key(&dataset)
                && data.test.contains_key(&dataset),
            "fine-tune bundle has no splits for {}",
            dataset.name()
        );
        let template =
            ParamSet::zeros_like(&self.engine.manifest.params).subset("encoder.");
        anyhow::ensure!(
            encoder.same_structure(&template),
            "pre-trained encoder structure does not match the loaded artifacts \
             ({} leaves vs {})",
            encoder.len(),
            template.len()
        );
        let replicas = self.cfg.parallel.replicas;
        let shape = MeshShape { num_heads: 1, replicas };
        let mesh = build_mesh_with_timeout(shape, self.cfg.fault.comm_timeout());
        let engine = &self.engine;
        let cfg = &self.cfg;
        let plan_arc = Arc::new(self.cfg.fault.plan()?);
        let plan = &*plan_arc;
        let cutoff = engine.manifest.config.cutoff;
        let store =
            FeaturizedStore::build(DDStore::new(data.train[&dataset].to_vec(), replicas), cutoff);
        let val_store =
            FeaturizedStore::build(DDStore::new(data.val[&dataset].to_vec(), replicas), cutoff);

        let results = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for mr in mesh {
                let store = Arc::clone(&store);
                let val_store = Arc::clone(&val_store);
                handles.push(scope.spawn(move || {
                    let guards = (mr.global.member_guard(), mr.head_group.member_guard());
                    let out = rank_loop_fine_tune(
                        engine, cfg, mr, store, val_store, encoder, dataset, plan,
                    );
                    if out.is_ok() {
                        guards.0.disarm();
                        guards.1.disarm();
                    }
                    out
                }));
            }
            join_ranks(handles)
        })?;

        finalize_per_dataset(
            format!("WarmStart-{}", dataset.name()),
            results,
            &[dataset],
        )
    }
}

// ---------------------------------------------------------------------------
// per-rank state and loops
// ---------------------------------------------------------------------------

/// What each rank thread returns.
struct RankResult {
    rank: usize,
    #[allow(dead_code)]
    head: usize,
    replica: usize,
    encoder: ParamSet,
    /// (dataset, branch) pairs this rank owns.
    branches: Vec<(DatasetId, ParamSet)>,
    log: RunLog,
    comm_global: u64,
    comm_head: u64,
    /// f32 elements this rank reduced through the overlapped path.
    comm_overlapped: u64,
}

/// Join every rank thread and collapse their outcomes. Handles are in rank
/// order (the mesh iterates ranks in order). Error priority:
///
/// 1. a **panicked** rank — the root cause; its peers' typed
///    `CommError::RankFailure` results are symptoms. Reported as a
///    [`CommError::RankFailure`] naming the rank, so
///    [`Trainer::train_with_recovery`] treats an in-process rank crash
///    exactly like a failed collective;
/// 2. a rank's own non-communication error (bad checkpoint, exhausted skip
///    budget) — again the cause, never retried by recovery;
/// 3. a communication error (the remaining symptom case).
fn join_ranks<T>(
    handles: Vec<std::thread::ScopedJoinHandle<'_, anyhow::Result<T>>>,
) -> anyhow::Result<Vec<T>> {
    let joined: Vec<std::thread::Result<anyhow::Result<T>>> =
        handles.into_iter().map(|h| h.join()).collect();
    for (rank, j) in joined.iter().enumerate() {
        if let Err(p) = j {
            return Err(anyhow::Error::from(CommError::RankFailure { rank }).context(
                format!("rank {rank} panicked: {}", fault::panic_message(p.as_ref())),
            ));
        }
    }
    let mut out = Vec::with_capacity(joined.len());
    let mut comm_err: Option<anyhow::Error> = None;
    let mut other_err: Option<anyhow::Error> = None;
    // The panic pass above returned on any `Err`, so flattening here visits
    // exactly the `Ok` results — no `expect` needed on this supervision path.
    for j in joined.into_iter().flatten() {
        match j {
            Ok(r) => out.push(r),
            Err(e) => {
                let is_comm =
                    e.chain().any(|c| c.downcast_ref::<CommError>().is_some());
                let slot = if is_comm { &mut comm_err } else { &mut other_err };
                if slot.is_none() {
                    *slot = Some(e);
                }
            }
        }
    }
    if let Some(e) = other_err {
        return Err(e);
    }
    if let Some(e) = comm_err {
        return Err(e);
    }
    Ok(out)
}

fn adamw_cfg(cfg: &RunConfig) -> AdamWConfig {
    AdamWConfig {
        lr: cfg.train.lr,
        beta1: cfg.train.beta1,
        beta2: cfg.train.beta2,
        eps: cfg.train.eps,
        weight_decay: cfg.train.weight_decay,
        grad_clip: cfg.train.grad_clip,
    }
}

/// Initialize rank-local parameters. All ranks use the same seeds so DDP
/// replicas start identical (and stay identical: collectives are exact).
fn init_rank_params(
    engine: &Engine,
    cfg: &RunConfig,
    datasets: &[DatasetId],
) -> (ParamSet, Vec<(DatasetId, ParamSet)>) {
    let full = ParamSet::init(&engine.manifest.params, cfg.train.seed);
    let encoder = full.subset("encoder.");
    let branches = datasets
        .iter()
        .map(|&d| {
            // Salt comes from the task spec (presets resolve to the seed
            // repo's exact constants, so trajectories are unchanged).
            let seed = cfg.train.seed ^ d.branch_init_salt();
            let b = ParamSet::init(&engine.manifest.params, seed).subset("branch.");
            (d, b)
        })
        .collect();
    (encoder, branches)
}

/// The seed epoch planner: clones every sample out of the `DDStore` and
/// re-runs `radius_graph` on it, every epoch, every rank. The production
/// path is `FeaturizedStore::plan_epoch_batches` (shuffle + pack cached
/// edges); this snapshot is kept as the bit-identity oracle for tests and
/// the "before" baseline in `BENCH_hot_paths.json`.
pub fn plan_epoch_batches_reference(
    store: &DDStore,
    rank_in_group: usize,
    group_size: usize,
    dims: crate::data::batch::BatchDims,
    cutoff: f64,
    epoch_seed: u64,
) -> Vec<GraphBatch> {
    let n = store.len();
    let mut indices: Vec<usize> = (0..n).collect();
    let mut rng = Rng::new(epoch_seed);
    rng.shuffle(&mut indices);
    let my: Vec<usize> =
        indices.into_iter().skip(rank_in_group).step_by(group_size).collect();
    let mut builder = BatchBuilder::new(dims, cutoff);
    let mut batches = Vec::new();
    for idx in my {
        if let Some(s) = store.get(rank_in_group, idx) {
            if let Some(b) = builder.push(&s) {
                batches.push(b);
            }
        }
    }
    batches.extend(builder.finish());
    batches
}

/// Assemble the full engine-callable ParamSet from encoder + branch.
fn assemble_full(scratch: &mut ParamSet, encoder: &ParamSet, branch: &ParamSet) {
    scratch.copy_matching_from(encoder);
    scratch.copy_matching_from(branch);
}

/// Mean validation loss across `comm`'s group (same value on every rank).
fn distributed_val_loss(
    engine: &Engine,
    comm: &Comm,
    full: &ParamSet,
    val_batches: &[GraphBatch],
) -> anyhow::Result<f64> {
    let mut local = 0.0;
    let mut count = 0.0;
    for b in val_batches {
        let out = engine.eval_step(full, b)?;
        local += out.loss * b.n_graphs as f64;
        count += b.n_graphs as f64;
    }
    let sums = comm.allgather_f64(local)?;
    let counts = comm.allgather_f64(count)?;
    let total: f64 = sums.iter().sum();
    let n: f64 = counts.iter().sum();
    if n > 0.0 {
        Ok(total / n)
    } else {
        // Zero val batches across the whole group: say so instead of
        // silently handing the early stopper a NaN to choke on (the
        // stopper itself is NaN-safe now, but the condition deserves a
        // visible warning — it usually means the val split is too small
        // for the replica count).
        if comm.rank_in_group == 0 {
            eprintln!(
                "warning: validation split produced zero batches across the whole \
                 group; val_loss is NaN and early stopping skips this epoch"
            );
        }
        Ok(f64::NAN)
    }
}

/// Shared epoch-count agreement: every rank must run the same number of
/// steps or the collectives deadlock; take the global min of planned counts.
fn agree_steps(comm: &Comm, planned: usize) -> Result<usize, CommError> {
    let counts = comm.allgather_f64(planned as f64)?;
    Ok(counts.into_iter().fold(f64::INFINITY, f64::min) as usize)
}

/// Mean per-step working time (exec + comm + opt) in milliseconds — the
/// sample the elastic scheduler's `Coverage::step_ms` EMA folds in.
fn measured_step_ms(acc: &StepAccum, steps: usize) -> f64 {
    if steps == 0 {
        return 0.0;
    }
    (acc.exec + acc.comm + acc.opt).as_secs_f64() * 1e3 / steps as f64
}

/// Build this rank's overlap sink when the overlapped path is on:
/// encoder buckets reduce on `enc_comm`, branch buckets on `br_comm`.
fn build_overlap_sink(
    engine: &Engine,
    cfg: &RunConfig,
    enc_comm: &Comm,
    br_comm: &Comm,
) -> anyhow::Result<Option<OverlapSink>> {
    if !cfg.parallel.overlap_resolved() {
        return Ok(None);
    }
    let plan = BucketPlan::new(
        &engine.manifest.params,
        engine.manifest.config.num_layers,
        cfg.parallel.bucket_elems,
    )?;
    Ok(Some(OverlapSink::new(plan, enc_comm.clone(), br_comm.clone())))
}

// ---------------------------------------------------------------------------
// checkpoint / resume plumbing shared by the rank loops
// ---------------------------------------------------------------------------

/// Pre-flight check that `data` can serve `mode`: a non-empty dataset list
/// with every split present. The seed panicked deep inside a rank loop
/// (`&datasets[..1]` on an empty list) instead of returning a config error.
pub fn validate_bundle(mode: TrainMode, data: &DataBundle) -> anyhow::Result<()> {
    let datasets = data.datasets();
    anyhow::ensure!(
        !datasets.is_empty(),
        "training bundle contains no datasets; generate data for at least one task \
         before calling train"
    );
    for d in &datasets {
        anyhow::ensure!(
            data.val.contains_key(d) && data.test.contains_key(d),
            "training bundle is missing the val/test split for {}",
            d.name()
        );
    }
    if let TrainMode::Single(d) = mode {
        anyhow::ensure!(
            data.train.contains_key(&d),
            "mode {} but the bundle has no data for {}",
            mode.name(),
            d.name()
        );
    }
    Ok(())
}

/// `(start_epoch, end_epoch)` for this run. A checkpoint that had already
/// early-stopped runs zero further epochs (the stop decision was final).
fn epoch_range(cfg: &RunConfig, resume: Option<&TrainCheckpoint>) -> (usize, usize) {
    match resume {
        Some(c) if c.stopped => (c.epochs_done, c.epochs_done),
        Some(c) => (c.epochs_done, cfg.train.epochs.max(c.epochs_done)),
        None => (0, cfg.train.epochs),
    }
}

/// The stopper a rank starts with: fresh, or the persisted mid-run cursor
/// so a resumed run makes the same stop decisions an uninterrupted one
/// would.
fn restore_stopper(cfg: &RunConfig, resume: Option<&TrainCheckpoint>) -> EarlyStopper {
    match resume {
        Some(c) => {
            EarlyStopper::restore(cfg.train.patience, c.stopper_best, c.stopper_bad_epochs)
        }
        None => EarlyStopper::new(cfg.train.patience),
    }
}

/// Whether ranks checkpoint after completing `epoch`. Must be a pure
/// function of group-uniform values: the MTL-par save path includes a
/// gather collective that every rank joins.
fn save_after_epoch(cfg: &RunConfig, epoch: usize, end_epoch: usize, stop: bool) -> bool {
    cfg.checkpoint.dir.is_some()
        && (stop || epoch + 1 == end_epoch || (epoch + 1) % cfg.checkpoint.every == 0)
}

/// Restore a parameter set at a rank with the payload broadcast from group
/// rank 0 — the traffic pattern of a real restore (one rank reads the
/// file, the rest receive over the interconnect), and what makes
/// `Comm::broadcast` traffic observable in the comm counters. Only the
/// root's `saved` values are read; every other rank genuinely receives the
/// broadcast bytes (the f32 -> f64 -> f32 relay is exact).
fn restore_params_broadcast(
    comm: &Comm,
    params: &mut ParamSet,
    saved: &ParamSet,
) -> Result<(), CommError> {
    let mut flat = if comm.rank_in_group == 0 {
        params.copy_matching_from(saved);
        params.flatten()
    } else {
        vec![0.0f32; params.total_params()]
    };
    comm.broadcast(0, &mut flat)?;
    params.unflatten_from(&flat);
    Ok(())
}

/// Build + write a checkpoint after `epochs_done` completed epochs (called
/// on rank 0 only; `cfg.checkpoint.dir` must be set).
///
/// Callers must NOT propagate a save error out of the rank loop with `?`:
/// on a multi-rank mesh only rank 0 writes, so an early return from rank 0
/// alone would leave its peers blocked forever in the next epoch's
/// collectives. Use [`warn_save_failure`] and keep training.
#[allow(clippy::too_many_arguments)]
fn save_checkpoint_rank0(
    engine: &Engine,
    cfg: &RunConfig,
    epochs_done: usize,
    stopped: bool,
    stopper: &EarlyStopper,
    model: TrainedModel,
    opt_encoder: AdamWState,
    opt_heads: OptHeads,
    log: &RunLog,
    comm_global: u64,
    comm_head: u64,
) -> anyhow::Result<()> {
    // `save_after_epoch` gates every call on `dir.is_some()`; treat a bare
    // call without a directory as a no-op save rather than killing rank 0
    // mid-training over a bookkeeping slip.
    let Some(dir) = cfg.checkpoint.dir.as_ref() else {
        return Ok(());
    };
    let (stopper_best, stopper_bad_epochs) = stopper.state();
    let ckpt = TrainCheckpoint {
        mode: cfg.mode.name(),
        train_seed: cfg.train.seed,
        // The RESOLVED backend + precision: `auto` (or an env precision
        // override) must not fingerprint-match across machines whose
        // resolution differs — the numerics differ.
        config_fingerprint: cfg
            .trajectory_fingerprint_resolved(engine.backend_name(), engine.precision().name()),
        epochs_done,
        stopped,
        stopper_best,
        stopper_bad_epochs,
        model,
        opt_encoder,
        opt_heads,
        log: log.clone(),
        comm_global,
        comm_head,
    };
    checkpoint::save_train(&ckpt, checkpoint::epoch_path(dir, epochs_done))
}

/// A failed checkpoint write is a loud warning, never a training failure:
/// losing fault tolerance beats deadlocking the mesh (rank 0 erroring out
/// of its loop while peers wait in collectives) or killing a multi-day run
/// over a transient disk condition.
fn warn_save_failure(epochs_done: usize, result: anyhow::Result<()>) {
    if let Err(e) = result {
        eprintln!(
            "warning: failed to write checkpoint after epoch {epochs_done}: {e:#}; \
             training continues WITHOUT this checkpoint"
        );
    }
}

/// Pack per-leaf moment vectors into one contiguous slice (same leaf order
/// as the parameter set they belong to).
fn write_moments(mv: &[Vec<f32>], out: &mut [f32]) {
    let mut off = 0;
    for m in mv {
        out[off..off + m.len()].copy_from_slice(m);
        off += m.len();
    }
    debug_assert_eq!(off, out.len());
}

/// Inverse of [`write_moments`]: split a flat slice along `template`'s
/// leaf boundaries.
fn split_moments(template: &ParamSet, flat: &[f32]) -> Vec<Vec<f32>> {
    let mut out = Vec::with_capacity(template.len());
    let mut off = 0;
    for t in &template.tensors {
        let n = t.numel();
        out.push(flat[off..off + n].to_vec());
        off += n;
    }
    debug_assert_eq!(off, flat.len());
    out
}

// ---------------------------------------------------------------------------
// fault-injection hooks shared by the rank loops
// ---------------------------------------------------------------------------

/// Apply rank-kill / collective-stall faults scheduled for this exact
/// `(rank, epoch, step)`. A no-op on the empty plan.
fn inject_rank_faults(plan: &FaultPlan, rank: usize, epoch: usize, step: usize) {
    if plan.panic_at(rank, epoch, step) {
        // lint:allow(panic): deliberate fault injection — the chaos harness's rank-kill primitive
        panic!("injected fault: rank {rank} panics at epoch {epoch} step {step}");
    }
    if let Some(ms) = plan.stall_ms(rank, epoch, step) {
        eprintln!("injected fault: rank {rank} stalls {ms} ms at epoch {epoch} step {step}");
        std::thread::sleep(std::time::Duration::from_millis(ms));
    }
}

/// Account one skipped non-finite-loss batch against the per-epoch budget.
fn skip_batch(
    cfg: &RunConfig,
    acc: &mut StepAccum,
    rank: usize,
    epoch: usize,
    step: usize,
) -> anyhow::Result<()> {
    acc.skipped += 1;
    eprintln!(
        "warning: rank {rank}: non-finite loss at epoch {epoch} step {step}; \
         skipping batch ({} of {} budget)",
        acc.skipped, cfg.fault.skip_batch_budget
    );
    anyhow::ensure!(
        acc.skipped <= cfg.fault.skip_batch_budget,
        "rank {rank}: {} non-finite-loss batches in epoch {epoch} exceed the skip \
         budget of {}; the model is diverging, not hitting a transient bad batch",
        acc.skipped,
        cfg.fault.skip_batch_budget
    );
    Ok(())
}

/// Size a flat gradient buffer and zero it (the skipped-batch contribution).
fn zero_flat(flat: &mut Vec<f32>, n: usize) {
    flat.clear();
    flat.resize(n, 0.0);
}

/// Apply a scheduled checkpoint-corruption fault to the file just written
/// after `epochs_done` epochs (called on the writing rank only).
fn inject_checkpoint_corruption(plan: &FaultPlan, cfg: &RunConfig, epochs_done: usize) {
    if !plan.corrupt_after(epochs_done) {
        return;
    }
    let Some(dir) = &cfg.checkpoint.dir else { return };
    let path = checkpoint::epoch_path(dir, epochs_done);
    match fault::corrupt_file(&path) {
        Ok(()) => eprintln!("injected fault: corrupted checkpoint {}", path.display()),
        Err(e) => eprintln!(
            "warning: fault injection failed to corrupt {}: {e}",
            path.display()
        ),
    }
}

// -- single-branch DDP loop (Single / BaselineAll) ---------------------------

#[allow(clippy::too_many_arguments)]
fn rank_loop_single_branch(
    engine: &Engine,
    cfg: &RunConfig,
    mr: MeshRank,
    store: Arc<FeaturizedStore>,
    val_store: Arc<FeaturizedStore>,
    datasets: &[DatasetId],
    resume: Option<Arc<TrainCheckpoint>>,
    plan: &FaultPlan,
) -> anyhow::Result<RankResult> {
    let dims = engine.manifest.config.batch_dims();
    let (encoder, mut branches) = init_rank_params(engine, cfg, &datasets[..1]);
    let mut encoder = encoder;
    let branch_dataset = branches[0].0;
    let mut branch = branches.remove(0).1;

    let mut full = ParamSet::zeros_like(&engine.manifest.params);
    let mut opt_enc = AdamW::new(adamw_cfg(cfg), &encoder);
    let mut opt_br = AdamW::new(adamw_cfg(cfg), &branch);
    let mut log = RunLog::new(cfg.mode.name());
    let mut stopper = restore_stopper(cfg, resume.as_deref());
    // Reused gradient-sync scratch (no per-step allocation).
    let mut enc_g = ParamSet::zeros_like(&engine.manifest.params).subset("encoder.");
    let mut br_g = ParamSet::zeros_like(&engine.manifest.params).subset("branch.");
    let mut enc_flat: Vec<f32> = Vec::new();
    let mut br_flat: Vec<f32> = Vec::new();
    // Per-rank batch pool: epoch N+1 reuses epoch N's buffers.
    let mut pool = BatchPool::default();
    // Overlapped path: plain DDP has no sub-groups, so encoder and branch
    // buckets both reduce on the global group.
    let mut sink = build_overlap_sink(engine, cfg, &mr.global, &mr.global)?;
    let mut step_ms_ema = 0.0f64;

    let (start_epoch, end_epoch) = epoch_range(cfg, resume.as_deref());
    let mut base_cg = 0u64;
    if let Some(ckpt) = &resume {
        // Rank 0 holds the checkpoint values; everyone else receives them
        // over a broadcast (the real restore traffic pattern).
        restore_params_broadcast(&mr.global, &mut encoder, &ckpt.model.encoder)?;
        let saved_branch = match &ckpt.model.heads {
            Heads::Shared(b) => b,
            Heads::PerDataset(_) => anyhow::bail!(
                "checkpoint is per-dataset but mode {} uses a shared head",
                cfg.mode.name()
            ),
        };
        restore_params_broadcast(&mr.global, &mut branch, saved_branch)?;
        opt_enc.load_state(&ckpt.opt_encoder)?;
        let saved_opt = match &ckpt.opt_heads {
            OptHeads::Shared(s) => s,
            OptHeads::PerDataset(_) => anyhow::bail!(
                "checkpoint optimizer state is per-dataset but mode {} is shared",
                cfg.mode.name()
            ),
        };
        opt_br.load_state(saved_opt)?;
        if mr.rank == 0 {
            log = ckpt.log.clone();
        }
        base_cg = ckpt.comm_global;
    }

    let stream_label = if datasets.len() == 1 {
        datasets[0].name()
    } else {
        format!("mixed({} tasks)", datasets.len())
    };

    let val_batches = val_store.plan_epoch_batches(
        mr.replica,
        mr.shape.replicas,
        dims,
        cfg.train.seed ^ VAL_SEED,
        &mut pool,
    );

    for epoch in start_epoch..end_epoch {
        let t_epoch = Instant::now();
        let mut acc = StepAccum::default();

        let t0 = Instant::now();
        let batches = store.plan_epoch_batches(
            mr.replica,
            mr.shape.replicas,
            dims,
            cfg.train.seed.wrapping_add(epoch as u64 * 7_777_777),
            &mut pool,
        );
        acc.data += t0.elapsed();
        let planned = batches.len();
        let steps = agree_steps(&mr.global, batches.len())?;

        for step in 0..steps {
            inject_rank_faults(plan, mr.rank, epoch, step);
            let batch = &batches[step % batches.len().max(1)];
            assemble_full(&mut full, &encoder, &branch);

            let t1 = Instant::now();
            if let Some(sink) = sink.as_mut() {
                // Overlapped DDP: backward streams ready buckets to the comm
                // thread; by the time finish_step returns, enc_flat/br_flat
                // hold exactly what the synchronous collectives in the other
                // arm would have produced (bit-identical by construction).
                sink.begin_step(plan.nonfinite_at(mr.rank, epoch, step));
                let out = engine.train_step_observed_unchecked(&full, batch, sink)?;
                acc.exec += t1.elapsed();
                let t2 = Instant::now();
                if sink.zeroed() {
                    skip_batch(cfg, &mut acc, mr.rank, epoch, step)?;
                } else {
                    acc.record_step(out.loss, out.mae_e, out.mae_f);
                }
                sink.finish_step(&mut enc_flat, &mut br_flat)?;
                enc_g.unflatten_from(&enc_flat);
                br_g.unflatten_from(&br_flat);
                acc.comm += t2.elapsed();
            } else {
                let mut out = engine.train_step_unchecked(&full, batch)?;
                if plan.nonfinite_at(mr.rank, epoch, step) {
                    out.loss = f64::NAN;
                }
                acc.exec += t1.elapsed();

                // Plain DDP: allreduce the complete gradient payload globally.
                // A non-finite loss skips the batch: this rank contributes a
                // zero gradient but still joins every collective and optimizer
                // step, so the group stays step-synchronized.
                let t2 = Instant::now();
                if out.loss.is_finite() {
                    acc.record_step(out.loss, out.mae_e, out.mae_f);
                    out.grads.flatten_prefix_into("encoder.", &mut enc_flat);
                    out.grads.flatten_prefix_into("branch.", &mut br_flat);
                } else {
                    skip_batch(cfg, &mut acc, mr.rank, epoch, step)?;
                    zero_flat(&mut enc_flat, enc_g.total_params());
                    zero_flat(&mut br_flat, br_g.total_params());
                }
                mr.global.allreduce_mean(&mut enc_flat)?;
                mr.global.allreduce_mean(&mut br_flat)?;
                enc_g.unflatten_from(&enc_flat);
                br_g.unflatten_from(&br_flat);
                acc.comm += t2.elapsed();
            }

            let t3 = Instant::now();
            opt_enc.step(&mut encoder, &enc_g);
            opt_br.step(&mut branch, &br_g);
            acc.opt += t3.elapsed();
        }
        pool.recycle(batches);

        assemble_full(&mut full, &encoder, &branch);
        let val_loss = distributed_val_loss(engine, &mr.global, &full, &val_batches)?;
        let mut cov = Coverage {
            dataset: stream_label.clone(),
            planned,
            used: steps,
            step_ms: step_ms_ema,
        };
        cov.observe_step_ms(measured_step_ms(&acc, steps));
        step_ms_ema = cov.step_ms;
        log.push(acc.into_epoch(epoch, t_epoch.elapsed(), val_loss).with_coverage(vec![cov]));
        let stop = stopper.update(val_loss);
        if save_after_epoch(cfg, epoch, end_epoch, stop) && mr.rank == 0 {
            let saved = save_checkpoint_rank0(
                engine,
                cfg,
                epoch + 1,
                stop,
                &stopper,
                TrainedModel {
                    name: cfg.mode.name(),
                    encoder: encoder.clone(),
                    heads: Heads::Shared(branch.clone()),
                },
                opt_enc.export_state(),
                OptHeads::Shared(opt_br.export_state()),
                &log,
                base_cg + mr.global.stats().elems,
                0,
            );
            warn_save_failure(epoch + 1, saved);
            inject_checkpoint_corruption(plan, cfg, epoch + 1);
        }
        if stop {
            break;
        }
    }

    let st = mr.global.stats();
    Ok(RankResult {
        rank: mr.rank,
        head: mr.head,
        replica: mr.replica,
        encoder,
        branches: vec![(branch_dataset, branch)],
        log,
        comm_global: base_cg + st.elems,
        comm_head: 0,
        comm_overlapped: st.overlapped_elems,
    })
}

// -- graph-parallel single-branch loop ----------------------------------------

#[allow(clippy::too_many_arguments)]
fn rank_loop_graph_par(
    engine: &Engine,
    cfg: &RunConfig,
    mr: MeshRank,
    store: Arc<FeaturizedStore>,
    val_store: Arc<FeaturizedStore>,
    datasets: &[DatasetId],
    resume: Option<Arc<TrainCheckpoint>>,
    plan: &FaultPlan,
) -> anyhow::Result<RankResult> {
    // Graph-parallel math is pure f64 end to end regardless of the
    // configured precision: halo-exchanged activations feed the next
    // block's matmuls directly, so a blocked-f32 variant would make
    // results depend on the world size. `EgnnDims::from_config` pins the
    // oracle precision (model::graphpar documents the invariant).
    let dims = EgnnDims::from_config(&engine.manifest.config);
    let layout = GradLayout::new(&dims);
    let world = mr.shape.replicas;

    let (encoder, mut branches) = init_rank_params(engine, cfg, &datasets[..1]);
    let mut encoder = encoder;
    let branch_dataset = branches[0].0;
    let mut branch = branches.remove(0).1;
    let mut opt_enc = AdamW::new(adamw_cfg(cfg), &encoder);
    let mut opt_br = AdamW::new(adamw_cfg(cfg), &branch);
    let mut log = RunLog::new(cfg.mode.name());
    let mut stopper = restore_stopper(cfg, resume.as_deref());
    // Full-set gradient image: `GradLayout::write_into` addresses every
    // named leaf; the optimizers consume the encoder/branch subsets.
    let mut g_full = ParamSet::zeros_like(&engine.manifest.params);
    let mut enc_g = ParamSet::zeros_like(&engine.manifest.params).subset("encoder.");
    let mut br_g = ParamSet::zeros_like(&engine.manifest.params).subset("branch.");
    let mut zeros: Vec<f32> = Vec::new();
    // Per-structure work plans, built on first touch and reused across
    // epochs (the partition is a pure function of positions + world).
    let mut plans: Vec<Option<GpPlan>> = (0..store.len()).map(|_| None).collect();
    let mut val_plans: Vec<Option<GpPlan>> =
        (0..val_store.len()).map(|_| None).collect();
    let mut step_ms_ema = 0.0f64;

    let (start_epoch, end_epoch) = epoch_range(cfg, resume.as_deref());
    let mut base_cg = 0u64;
    if let Some(ckpt) = &resume {
        restore_params_broadcast(&mr.global, &mut encoder, &ckpt.model.encoder)?;
        let saved_branch = match &ckpt.model.heads {
            Heads::Shared(b) => b,
            Heads::PerDataset(_) => anyhow::bail!(
                "checkpoint is per-dataset but mode {} uses a shared head",
                cfg.mode.name()
            ),
        };
        restore_params_broadcast(&mr.global, &mut branch, saved_branch)?;
        opt_enc.load_state(&ckpt.opt_encoder)?;
        let saved_opt = match &ckpt.opt_heads {
            OptHeads::Shared(s) => s,
            OptHeads::PerDataset(_) => anyhow::bail!(
                "checkpoint optimizer state is per-dataset but mode {} is shared",
                cfg.mode.name()
            ),
        };
        opt_br.load_state(saved_opt)?;
        if mr.rank == 0 {
            log = ckpt.log.clone();
        }
        base_cg = ckpt.comm_global;
    }

    let stream_label = if datasets.len() == 1 {
        datasets[0].name()
    } else {
        format!("mixed({} tasks)", datasets.len())
    };

    for epoch in start_epoch..end_epoch {
        let t_epoch = Instant::now();
        let mut acc = StepAccum::default();

        // Identical shuffle on every rank — NO rank sharding: the whole
        // group cooperates on one structure per step instead of splitting
        // the epoch's list (same epoch-seed recipe as the DDP planner).
        let t0 = Instant::now();
        let mut order: Vec<usize> = (0..store.len()).collect();
        let mut rng = Rng::new(cfg.train.seed.wrapping_add(epoch as u64 * 7_777_777));
        rng.shuffle(&mut order);
        acc.data += t0.elapsed();
        let planned = order.len();
        let steps = agree_steps(&mr.global, order.len())?;

        for step in 0..steps {
            inject_rank_faults(plan, mr.rank, epoch, step);
            let idx = order[step % order.len().max(1)];
            let gp = plans[idx].get_or_insert_with(|| {
                GpPlan::build(store.segments(idx), store.edges(idx), world)
            });
            let st = GpStructure {
                species: store.species(idx),
                edges: store.edges(idx),
                y_energy_per_atom: store.energy_per_atom(idx),
                y_forces: store.forces(idx),
            };

            let t1 = Instant::now();
            let enc_p = EncoderParams::from_set(&dims, &encoder)?;
            let br_p = BranchParams::from_set(&dims, &branch)?;
            let (mut out, flat) =
                graphpar::train_step(&dims, &enc_p, &br_p, &st, gp, &layout, &mr.global)?;
            acc.exec += t1.elapsed();

            // A non-finite injection is keyed per rank, but one shared
            // structure per step means a poisoned batch poisons the whole
            // group: agree with a 1-element sum so every rank skips (or
            // none) — a per-rank skip would diverge the cooperatively
            // computed update. Zero cost on the fault-free path.
            if !plan.is_empty() {
                let mine = plan.nonfinite_at(mr.rank, epoch, step);
                let mut poisoned = [if mine { 1.0f64 } else { 0.0 }];
                mr.global.allreduce_sum_f64(&mut poisoned)?;
                if poisoned[0] != 0.0 {
                    out.loss = f64::NAN;
                }
            }

            let t2 = Instant::now();
            if out.loss.is_finite() {
                acc.record_step(out.loss, out.mae_e, out.mae_f);
                // `flat` is already the group-folded gradient (bit-identical
                // on every rank): no DDP allreduce follows, only the
                // downcast into the optimizer's named leaves.
                layout.write_into(&flat, &mut g_full)?;
                enc_g.copy_matching_from(&g_full);
                br_g.copy_matching_from(&g_full);
            } else {
                skip_batch(cfg, &mut acc, mr.rank, epoch, step)?;
                zero_flat(&mut zeros, enc_g.total_params());
                enc_g.unflatten_from(&zeros);
                zero_flat(&mut zeros, br_g.total_params());
                br_g.unflatten_from(&zeros);
            }
            acc.comm += t2.elapsed();

            let t3 = Instant::now();
            opt_enc.step(&mut encoder, &enc_g);
            opt_br.step(&mut branch, &br_g);
            acc.opt += t3.elapsed();
        }

        // Validation: mean per-structure loss over the shared val list.
        // Each `eval_step` loss is already identical on every rank, so the
        // mean is too — no extra reduction needed.
        let enc_p = EncoderParams::from_set(&dims, &encoder)?;
        let br_p = BranchParams::from_set(&dims, &branch)?;
        let mut val_sum = 0.0;
        for i in 0..val_store.len() {
            let gp = val_plans[i].get_or_insert_with(|| {
                GpPlan::build(val_store.segments(i), val_store.edges(i), world)
            });
            let st = GpStructure {
                species: val_store.species(i),
                edges: val_store.edges(i),
                y_energy_per_atom: val_store.energy_per_atom(i),
                y_forces: val_store.forces(i),
            };
            val_sum +=
                graphpar::eval_step(&dims, &enc_p, &br_p, &st, gp, &mr.global)?.loss;
        }
        let val_loss = val_sum / val_store.len().max(1) as f64;

        let mut cov = Coverage {
            dataset: stream_label.clone(),
            planned,
            used: steps,
            step_ms: step_ms_ema,
        };
        cov.observe_step_ms(measured_step_ms(&acc, steps));
        step_ms_ema = cov.step_ms;
        log.push(acc.into_epoch(epoch, t_epoch.elapsed(), val_loss).with_coverage(vec![cov]));
        let stop = stopper.update(val_loss);
        if save_after_epoch(cfg, epoch, end_epoch, stop) && mr.rank == 0 {
            let saved = save_checkpoint_rank0(
                engine,
                cfg,
                epoch + 1,
                stop,
                &stopper,
                TrainedModel {
                    name: cfg.mode.name(),
                    encoder: encoder.clone(),
                    heads: Heads::Shared(branch.clone()),
                },
                opt_enc.export_state(),
                OptHeads::Shared(opt_br.export_state()),
                &log,
                base_cg + mr.global.stats().elems,
                0,
            );
            warn_save_failure(epoch + 1, saved);
            inject_checkpoint_corruption(plan, cfg, epoch + 1);
        }
        if stop {
            break;
        }
    }

    let st = mr.global.stats();
    Ok(RankResult {
        rank: mr.rank,
        head: mr.head,
        replica: mr.replica,
        encoder,
        branches: vec![(branch_dataset, branch)],
        log,
        comm_global: base_cg + st.elems,
        comm_head: 0,
        comm_overlapped: st.overlapped_elems,
    })
}

// -- MTL-base loop ------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn rank_loop_mtl_base(
    engine: &Engine,
    cfg: &RunConfig,
    mr: MeshRank,
    stores: BTreeMap<DatasetId, Arc<FeaturizedStore>>,
    val_stores: BTreeMap<DatasetId, Arc<FeaturizedStore>>,
    datasets: &[DatasetId],
    resume: Option<Arc<TrainCheckpoint>>,
    plan: &FaultPlan,
) -> anyhow::Result<RankResult> {
    let dims = engine.manifest.config.batch_dims();
    let (mut encoder, mut branches) = init_rank_params(engine, cfg, datasets);
    let mut full = ParamSet::zeros_like(&engine.manifest.params);
    let mut opt_enc = AdamW::new(adamw_cfg(cfg), &encoder);
    let mut opt_brs: Vec<AdamW> =
        branches.iter().map(|(_, b)| AdamW::new(adamw_cfg(cfg), b)).collect();
    let mut log = RunLog::new("GFM-MTL-All (MTL-base)");
    let mut stopper = restore_stopper(cfg, resume.as_deref());
    // Per-rank batch pool shared across datasets and epochs.
    let mut pool = BatchPool::default();
    let nd = datasets.len();
    let br_len = branches_scratch_branch(engine).total_params();
    // Overlapped path: each dataset's branch-gradient chunks go to the comm
    // thread as soon as that dataset's backward finishes, hiding their
    // reduction behind the NEXT dataset's forward/backward. The shared
    // encoder mean can only be formed after every dataset contributed, so
    // its chunks go out last. Chunked reduction never changes what is
    // reduced, only when — values stay bit-identical to the monolithic
    // concatenated-payload allreduce of the synchronous arm.
    let mut reducer = if cfg.parallel.overlap_resolved() {
        Some(OverlapReducer::new(mr.global.clone(), mr.global.clone()))
    } else {
        None
    };
    let mut br_flats: Vec<Vec<f32>> = vec![Vec::new(); nd];
    let mut br_g_scratch: Vec<ParamSet> = if reducer.is_some() {
        (0..nd).map(|_| branches_scratch_branch(engine)).collect()
    } else {
        Vec::new()
    };
    let mut step_ms_emas = vec![0.0f64; nd];

    let (start_epoch, end_epoch) = epoch_range(cfg, resume.as_deref());
    let mut base_cg = 0u64;
    if let Some(ckpt) = &resume {
        restore_params_broadcast(&mr.global, &mut encoder, &ckpt.model.encoder)?;
        let saved_heads = match &ckpt.model.heads {
            Heads::PerDataset(m) => m,
            Heads::Shared(_) => anyhow::bail!(
                "checkpoint is shared-head but mode mtl-base is per-dataset"
            ),
        };
        for (k, (d, b)) in branches.iter_mut().enumerate() {
            let d = *d;
            let saved = saved_heads
                .get(&d)
                .ok_or_else(|| anyhow::anyhow!("checkpoint has no head for {}", d.name()))?;
            restore_params_broadcast(&mr.global, b, saved)?;
            opt_brs[k].load_state(ckpt.opt_for(d)?)?;
        }
        opt_enc.load_state(&ckpt.opt_encoder)?;
        if mr.rank == 0 {
            log = ckpt.log.clone();
        }
        base_cg = ckpt.comm_global;
    }

    // Group-uniform active-dataset count: a dataset is active iff it has
    // any samples at all. The featurized stores are shared by every rank,
    // so this is identical on every rank with zero communication, and it is
    // epoch-invariant (shard emptiness depends on store size, not on the
    // epoch shuffle). Every rank must use the SAME normalizer — a per-rank
    // count would make ranks with and without a tiny dataset's shard divide
    // their encoder-grad sums differently before the cross-rank mean,
    // silently reweighting the shared encoder update.
    let active =
        datasets.iter().filter(|&&d| !stores[&d].is_empty()).count().max(1) as f64;
    // A dataset with no samples at all never produces a gradient on any
    // rank. Its optimizer step must be skipped too (uniformly — store
    // emptiness is identical on every rank): AdamW's decoupled weight
    // decay moves parameters even under all-zero gradients, which would
    // silently decay a head that was never trained.
    let globally_empty: Vec<bool> =
        datasets.iter().map(|d| stores[d].is_empty()).collect();

    // Validation: every dataset's shard through its own branch.
    let val_batches: Vec<(usize, Vec<GraphBatch>)> = datasets
        .iter()
        .enumerate()
        .map(|(k, d)| {
            (
                k,
                val_stores[d].plan_epoch_batches(
                    mr.replica,
                    mr.shape.replicas,
                    dims,
                    cfg.train.seed ^ VAL_SEED,
                    &mut pool,
                ),
            )
        })
        .collect();

    for epoch in start_epoch..end_epoch {
        let t_epoch = Instant::now();
        let mut acc = StepAccum::default();

        let t0 = Instant::now();
        let per_ds_batches: Vec<Vec<GraphBatch>> = datasets
            .iter()
            .map(|d| {
                stores[d].plan_epoch_batches(
                    mr.replica,
                    mr.shape.replicas,
                    dims,
                    cfg.train.seed.wrapping_add(epoch as u64 * 7_777_777)
                        ^ d.index() as u64,
                    &mut pool,
                )
            })
            .collect();
        acc.data += t0.elapsed();
        // Run up to the LARGEST dataset's batch count; smaller datasets
        // cycle modulo their length (the `step % len` wrap below). The seed
        // truncated every epoch to the SMALLEST dataset's count, silently
        // discarding most of every larger source — exactly the imbalance
        // failure mode the multi-fidelity setting is about. Coverage is
        // recorded in the run log so truncation can never be silent again.
        let max_batches = per_ds_batches.iter().map(|b| b.len()).max().unwrap_or(0);
        let steps = agree_steps(&mr.global, max_batches)?;
        let mut ds_exec: Vec<Duration> = vec![Duration::ZERO; nd];

        for step in 0..steps {
            inject_rank_faults(plan, mr.rank, epoch, step);
            // A non-finite injection at (rank, epoch, step) hits the first
            // dataset processed this step (deterministic: dataset order is
            // the BTreeMap's).
            let mut inject_nan = plan.nonfinite_at(mr.rank, epoch, step);
            // One batch per dataset through its branch; encoder grads mean.
            let mut enc_gsum: Option<Vec<f32>> = None;
            let mut br_grads: Vec<ParamSet> = Vec::with_capacity(datasets.len());
            let mut loss_sum = 0.0;
            let mut mae_e_sum = 0.0;
            let mut mae_f_sum = 0.0;
            for (k, _) in datasets.iter().enumerate() {
                if per_ds_batches[k].is_empty() {
                    // No local shard: contribute zero branch grads so the
                    // global collective payload stays structurally uniform.
                    if let Some(red) = reducer.as_mut() {
                        zero_flat(&mut br_flats[k], br_len);
                        red.submit_chunks(
                            Segment::Branch,
                            k,
                            &br_flats[k],
                            cfg.parallel.bucket_elems,
                        )?;
                    } else {
                        br_grads.push(branches_scratch_branch(engine));
                    }
                    continue;
                }
                let batch = &per_ds_batches[k][step % per_ds_batches[k].len()];
                assemble_full(&mut full, &encoder, &branches[k].1);
                let t1 = Instant::now();
                let mut out = engine.train_step_unchecked(&full, batch)?;
                if std::mem::take(&mut inject_nan) {
                    out.loss = f64::NAN;
                }
                if !out.loss.is_finite() {
                    // Skip this dataset's batch: zero branch grads, no
                    // encoder contribution; the collective payload below
                    // stays structurally uniform so the group never skews.
                    let dt = t1.elapsed();
                    acc.exec += dt;
                    ds_exec[k] += dt;
                    skip_batch(cfg, &mut acc, mr.rank, epoch, step)?;
                    if let Some(red) = reducer.as_mut() {
                        zero_flat(&mut br_flats[k], br_len);
                        red.submit_chunks(
                            Segment::Branch,
                            k,
                            &br_flats[k],
                            cfg.parallel.bucket_elems,
                        )?;
                    } else {
                        br_grads.push(branches_scratch_branch(engine));
                    }
                    continue;
                }
                let dt = t1.elapsed();
                acc.exec += dt;
                ds_exec[k] += dt;
                loss_sum += out.loss;
                mae_e_sum += out.mae_e;
                mae_f_sum += out.mae_f;
                let enc_flat = out.grads.subset("encoder.").flatten();
                match &mut enc_gsum {
                    None => enc_gsum = Some(enc_flat),
                    Some(acc_flat) => {
                        for (a, g) in acc_flat.iter_mut().zip(enc_flat) {
                            *a += g;
                        }
                    }
                }
                if let Some(red) = reducer.as_mut() {
                    out.grads.flatten_prefix_into("branch.", &mut br_flats[k]);
                    red.submit_chunks(
                        Segment::Branch,
                        k,
                        &br_flats[k],
                        cfg.parallel.bucket_elems,
                    )?;
                } else {
                    br_grads.push(out.grads.subset("branch."));
                }
            }
            let nh = active;
            acc.record_step(loss_sum / nh, mae_e_sum / nh, mae_f_sum / nh);

            let t2 = Instant::now();
            // None only when every local batch this step was skipped as
            // non-finite: contribute a zero encoder gradient.
            let mut enc_flat =
                enc_gsum.unwrap_or_else(|| vec![0.0f32; encoder.total_params()]);
            for g in enc_flat.iter_mut() {
                *g /= nh as f32;
            }
            if let Some(red) = reducer.as_mut() {
                // Overlapped: the branch chunks are already in flight (or
                // reduced); send the encoder mean and drain everything.
                red.submit_chunks(Segment::Encoder, 0, &enc_flat, cfg.parallel.bucket_elems)?;
                for rb in red.finish()? {
                    let dst = match rb.seg {
                        Segment::Encoder => &mut enc_flat,
                        Segment::Branch => &mut br_flats[rb.dest],
                    };
                    dst[rb.offset..rb.offset + rb.data.len()].copy_from_slice(&rb.data);
                    red.recycle(rb.data);
                }
                acc.comm += t2.elapsed();

                let t3 = Instant::now();
                let mut enc_g = branches_scratch_encoder(engine);
                enc_g.unflatten_from(&enc_flat);
                opt_enc.step(&mut encoder, &enc_g);
                for k in 0..nd {
                    if !globally_empty[k] {
                        br_g_scratch[k].unflatten_from(&br_flats[k]);
                        opt_brs[k].step(&mut branches[k].1, &br_g_scratch[k]);
                    }
                }
                acc.opt += t3.elapsed();
            } else {
                // ONE global allreduce over P_s + N_h * P_h (the paper's
                // MTL-base payload): concatenate encoder mean + all branches.
                let enc_len = enc_flat.len();
                let mut payload = enc_flat;
                let mut br_lens = Vec::with_capacity(br_grads.len());
                for bg in &br_grads {
                    let f = bg.flatten();
                    br_lens.push(f.len());
                    payload.extend(f);
                }
                mr.global.allreduce_mean(&mut payload)?;
                acc.comm += t2.elapsed();

                let t3 = Instant::now();
                let mut enc_g = branches_scratch_encoder(engine);
                enc_g.unflatten_from(&payload[..enc_len]);
                opt_enc.step(&mut encoder, &enc_g);
                let mut off = enc_len;
                for (k, bg) in br_grads.iter_mut().enumerate() {
                    bg.unflatten_from(&payload[off..off + br_lens[k]]);
                    off += br_lens[k];
                    if !globally_empty[k] {
                        opt_brs[k].step(&mut branches[k].1, bg);
                    }
                }
                acc.opt += t3.elapsed();
            }
        }
        let coverage: Vec<Coverage> = datasets
            .iter()
            .enumerate()
            .map(|(k, d)| {
                let mut c = Coverage {
                    dataset: d.name(),
                    planned: per_ds_batches[k].len(),
                    used: if per_ds_batches[k].is_empty() { 0 } else { steps },
                    step_ms: step_ms_emas[k],
                };
                if steps > 0 {
                    c.observe_step_ms(ds_exec[k].as_secs_f64() * 1e3 / steps as f64);
                }
                step_ms_emas[k] = c.step_ms;
                c
            })
            .collect();
        for b in per_ds_batches {
            pool.recycle(b);
        }

        // Validation across every head.
        let mut val_local = 0.0;
        let mut val_count = 0.0;
        for (k, batches) in &val_batches {
            assemble_full(&mut full, &encoder, &branches[*k].1);
            for b in batches {
                let out = engine.eval_step(&full, b)?;
                val_local += out.loss * b.n_graphs as f64;
                val_count += b.n_graphs as f64;
            }
        }
        let sums = mr.global.allgather_f64(val_local)?;
        let counts = mr.global.allgather_f64(val_count)?;
        let n: f64 = counts.iter().sum();
        let val_loss = if n > 0.0 {
            sums.iter().sum::<f64>() / n
        } else {
            // The seed divided by max(1.0), reporting a fake 0.0 val loss
            // that immediately became the early stopper's "best".
            if mr.rank == 0 {
                eprintln!(
                    "warning: epoch {epoch}: no validation batches on any rank; \
                     val_loss is NaN and early stopping skips this epoch"
                );
            }
            f64::NAN
        };
        log.push(acc.into_epoch(epoch, t_epoch.elapsed(), val_loss).with_coverage(coverage));
        let stop = stopper.update(val_loss);
        if save_after_epoch(cfg, epoch, end_epoch, stop) && mr.rank == 0 {
            let saved = save_checkpoint_rank0(
                engine,
                cfg,
                epoch + 1,
                stop,
                &stopper,
                TrainedModel {
                    name: cfg.mode.name(),
                    encoder: encoder.clone(),
                    heads: Heads::PerDataset(
                        branches.iter().map(|(d, b)| (*d, b.clone())).collect(),
                    ),
                },
                opt_enc.export_state(),
                OptHeads::PerDataset(
                    branches
                        .iter()
                        .zip(&opt_brs)
                        .map(|((d, _), o)| (d.name(), o.export_state()))
                        .collect(),
                ),
                &log,
                base_cg + mr.global.stats().elems,
                0,
            );
            warn_save_failure(epoch + 1, saved);
            inject_checkpoint_corruption(plan, cfg, epoch + 1);
        }
        if stop {
            break;
        }
    }

    let st = mr.global.stats();
    Ok(RankResult {
        rank: mr.rank,
        head: mr.head,
        replica: mr.replica,
        encoder,
        branches,
        log,
        comm_global: base_cg + st.elems,
        comm_head: 0,
        comm_overlapped: st.overlapped_elems,
    })
}

/// Encoder-gradient scratch with full names ("encoder.*").
fn branches_scratch_encoder(engine: &Engine) -> ParamSet {
    ParamSet::zeros_like(&engine.manifest.params).subset("encoder.")
}

/// Branch scratch with full names ("branch.*"): zero gradients for a
/// dataset with no local shard, and the decode template for the MTL-par
/// checkpoint gather.
fn branches_scratch_branch(engine: &Engine) -> ParamSet {
    ParamSet::zeros_like(&engine.manifest.params).subset("branch.")
}

// -- MTL-par loop --------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn rank_loop_mtl_par(
    engine: &Engine,
    cfg: &RunConfig,
    mr: MeshRank,
    store: Arc<FeaturizedStore>,
    val_store: Arc<FeaturizedStore>,
    datasets: &[DatasetId],
    resume: Option<Arc<TrainCheckpoint>>,
    plan: &FaultPlan,
) -> anyhow::Result<RankResult> {
    let dataset = datasets[mr.head];
    let dims = engine.manifest.config.batch_dims();
    let (mut encoder, mut branches) = init_rank_params(engine, cfg, &[dataset]);
    let mut branch = branches.remove(0).1;
    let mut full = ParamSet::zeros_like(&engine.manifest.params);
    let mut opt_enc = AdamW::new(adamw_cfg(cfg), &encoder);
    let mut opt_br = AdamW::new(adamw_cfg(cfg), &branch);
    let mut log = RunLog::new(format!("MTL-par head {}", dataset.name()));
    let mut stopper = restore_stopper(cfg, resume.as_deref());
    // Reused gradient-sync scratch (no per-step allocation).
    let mut enc_g = ParamSet::zeros_like(&engine.manifest.params).subset("encoder.");
    let mut br_g = ParamSet::zeros_like(&engine.manifest.params).subset("branch.");
    let mut enc_flat: Vec<f32> = Vec::new();
    let mut br_flat: Vec<f32> = Vec::new();
    // Per-rank batch pool: epoch N+1 reuses epoch N's buffers.
    let mut pool = BatchPool::default();
    // Overlapped path: encoder buckets reduce on the GLOBAL group, branch
    // buckets on this head's sub-group — Figure 3's two-level pattern,
    // pipelined behind backward.
    let mut sink = build_overlap_sink(engine, cfg, &mr.global, &mr.head_group)?;
    let mut step_ms_ema = 0.0f64;

    let (start_epoch, end_epoch) = epoch_range(cfg, resume.as_deref());
    let mut base_cg = 0u64;
    let mut base_ch = 0u64;
    if let Some(ckpt) = &resume {
        // Encoder arrives over the global broadcast from rank 0; each
        // head's branch over its sub-group broadcast from replica 0 —
        // Figure 3's two-level pattern, applied to restore traffic.
        restore_params_broadcast(&mr.global, &mut encoder, &ckpt.model.encoder)?;
        let saved_branch = match &ckpt.model.heads {
            Heads::PerDataset(m) => m.get(&dataset).ok_or_else(|| {
                anyhow::anyhow!("checkpoint has no head for {}", dataset.name())
            })?,
            Heads::Shared(_) => anyhow::bail!(
                "checkpoint is shared-head but mode mtl-par is per-dataset"
            ),
        };
        restore_params_broadcast(&mr.head_group, &mut branch, saved_branch)?;
        opt_enc.load_state(&ckpt.opt_encoder)?;
        opt_br.load_state(ckpt.opt_for(dataset)?)?;
        if mr.rank == 0 {
            log = ckpt.log.clone();
        }
        base_cg = ckpt.comm_global;
        base_ch = ckpt.comm_head;
    }

    let val_batches = val_store.plan_epoch_batches(
        mr.replica,
        mr.shape.replicas,
        dims,
        cfg.train.seed ^ VAL_SEED,
        &mut pool,
    );

    for epoch in start_epoch..end_epoch {
        let t_epoch = Instant::now();
        let mut acc = StepAccum::default();

        let t0 = Instant::now();
        let batches = store.plan_epoch_batches(
            mr.replica,
            mr.shape.replicas,
            dims,
            cfg.train.seed.wrapping_add(epoch as u64 * 7_777_777) ^ dataset.index() as u64,
            &mut pool,
        );
        acc.data += t0.elapsed();
        let planned = batches.len();
        let steps = agree_steps(&mr.global, batches.len())?;

        for step in 0..steps {
            inject_rank_faults(plan, mr.rank, epoch, step);
            let batch = &batches[step % batches.len().max(1)];
            assemble_full(&mut full, &encoder, &branch);

            let t1 = Instant::now();
            if let Some(sink) = sink.as_mut() {
                // Overlapped two-level reduction: branch buckets reach the
                // sub-group while the encoder's backward still runs, then
                // encoder buckets reach the global group layer by layer.
                // finish_step leaves enc_flat/br_flat bit-identical to the
                // synchronous arm's collectives.
                sink.begin_step(plan.nonfinite_at(mr.rank, epoch, step));
                let out = engine.train_step_observed_unchecked(&full, batch, sink)?;
                acc.exec += t1.elapsed();
                let t2 = Instant::now();
                if sink.zeroed() {
                    skip_batch(cfg, &mut acc, mr.rank, epoch, step)?;
                } else {
                    acc.record_step(out.loss, out.mae_e, out.mae_f);
                }
                sink.finish_step(&mut enc_flat, &mut br_flat)?;
                enc_g.unflatten_from(&enc_flat);
                br_g.unflatten_from(&br_flat);
                acc.comm += t2.elapsed();
            } else {
                let mut out = engine.train_step_unchecked(&full, batch)?;
                if plan.nonfinite_at(mr.rank, epoch, step) {
                    out.loss = f64::NAN;
                }
                acc.exec += t1.elapsed();

                // Multi-task parallelism: encoder grads allreduce GLOBALLY
                // (P_s payload); branch grads only within the head sub-group
                // (P_h payload) — Figure 3's two-level DDP. A skipped
                // non-finite batch still joins both collectives with zeros.
                let t2 = Instant::now();
                if out.loss.is_finite() {
                    acc.record_step(out.loss, out.mae_e, out.mae_f);
                    out.grads.flatten_prefix_into("encoder.", &mut enc_flat);
                    out.grads.flatten_prefix_into("branch.", &mut br_flat);
                } else {
                    skip_batch(cfg, &mut acc, mr.rank, epoch, step)?;
                    zero_flat(&mut enc_flat, enc_g.total_params());
                    zero_flat(&mut br_flat, br_g.total_params());
                }
                mr.global.allreduce_mean(&mut enc_flat)?;
                mr.head_group.allreduce_mean(&mut br_flat)?;
                enc_g.unflatten_from(&enc_flat);
                br_g.unflatten_from(&br_flat);
                acc.comm += t2.elapsed();
            }

            let t3 = Instant::now();
            opt_enc.step(&mut encoder, &enc_g);
            opt_br.step(&mut branch, &br_g);
            acc.opt += t3.elapsed();
        }
        pool.recycle(batches);

        assemble_full(&mut full, &encoder, &branch);
        let val_loss = distributed_val_loss(engine, &mr.global, &full, &val_batches)?;
        let mut cov =
            Coverage { dataset: dataset.name(), planned, used: steps, step_ms: step_ms_ema };
        cov.observe_step_ms(measured_step_ms(&acc, steps));
        step_ms_ema = cov.step_ms;
        log.push(acc.into_epoch(epoch, t_epoch.elapsed(), val_loss).with_coverage(vec![cov]));
        let stop = stopper.update(val_loss);
        if save_after_epoch(cfg, epoch, end_epoch, stop) {
            // Under multi-task parallelism no single rank holds every head,
            // so rank 0 cannot write the checkpoint alone. Each head's
            // replica-0 rank broadcasts its (branch, m, v) block over the
            // global group — bit-exact relay (f32 -> f64 -> f32 preserves
            // every value including -0.0, which a zero-padded sum would
            // flip to +0.0 and break the bit-identity guarantee), and the
            // checkpoint-gather traffic shows up in the comm counters the
            // way it would on a real fabric.
            let ph = branch.total_params();
            let mut head_blocks: Vec<Vec<f32>> = Vec::with_capacity(datasets.len());
            for h in 0..datasets.len() {
                let root = mr.shape.rank_of(h, 0);
                let mut block = vec![0.0f32; ph * 3];
                if mr.rank == root {
                    block[..ph].copy_from_slice(&branch.flatten());
                    let st = opt_br.export_state();
                    write_moments(&st.m, &mut block[ph..2 * ph]);
                    write_moments(&st.v, &mut block[2 * ph..]);
                }
                mr.global.broadcast(root, &mut block)?;
                head_blocks.push(block);
            }
            if mr.rank == 0 {
                let mut heads = BTreeMap::new();
                let mut opts = Vec::with_capacity(datasets.len());
                // Step counts are group-uniform: every rank runs the same
                // agreed step count each epoch.
                let step_count = opt_br.step_count();
                for (h, &d) in datasets.iter().enumerate() {
                    let block = &head_blocks[h];
                    let mut b = branches_scratch_branch(engine);
                    b.unflatten_from(&block[..ph]);
                    let m = split_moments(&b, &block[ph..2 * ph]);
                    let v = split_moments(&b, &block[2 * ph..]);
                    heads.insert(d, b);
                    opts.push((d.name(), AdamWState { m, v, step: step_count }));
                }
                let saved = save_checkpoint_rank0(
                    engine,
                    cfg,
                    epoch + 1,
                    stop,
                    &stopper,
                    TrainedModel {
                        name: cfg.mode.name(),
                        encoder: encoder.clone(),
                        heads: Heads::PerDataset(heads),
                    },
                    opt_enc.export_state(),
                    OptHeads::PerDataset(opts),
                    &log,
                    base_cg + mr.global.stats().elems,
                    base_ch + mr.head_group.stats().elems,
                );
                warn_save_failure(epoch + 1, saved);
                inject_checkpoint_corruption(plan, cfg, epoch + 1);
            }
        }
        if stop {
            break;
        }
    }

    let sg = mr.global.stats();
    let sh = mr.head_group.stats();
    Ok(RankResult {
        rank: mr.rank,
        head: mr.head,
        replica: mr.replica,
        encoder,
        branches: vec![(dataset, branch)],
        log,
        comm_global: base_cg + sg.elems,
        comm_head: base_ch + sh.elems,
        comm_overlapped: sg.overlapped_elems + sh.overlapped_elems,
    })
}

// -- elastic MTL-par epoch loop -----------------------------------------------

/// One head's state carried by the elastic driver between epochs.
struct ElasticHead {
    dataset: DatasetId,
    branch: ParamSet,
    opt: AdamWState,
    /// Per-step wall-time EMA in ms ([`Coverage::step_ms`]) — the replan's
    /// cost signal, fed from each head's root-rank coverage.
    step_ms: f64,
}

/// What one rank of one elastic epoch returns to the driver.
struct ElasticRankOut {
    rank: usize,
    head: usize,
    replica: usize,
    encoder: ParamSet,
    branch: ParamSet,
    opt_enc: AdamWState,
    opt_br: AdamWState,
    metrics: EpochMetrics,
    comm_global: u64,
    comm_head: u64,
    comm_overlapped: u64,
}

/// One epoch of one rank under elastic MTL-par: identical step semantics to
/// [`rank_loop_mtl_par`] (including the overlapped path), but parameterized
/// on a ragged mesh rank and driver-held start-of-epoch state, because the
/// mesh may be rebuilt with different sub-group sizes next epoch.
#[allow(clippy::too_many_arguments)]
fn rank_epoch_mtl_par_elastic(
    engine: &Engine,
    cfg: &RunConfig,
    mr: RaggedMeshRank,
    epoch: usize,
    store: Arc<FeaturizedStore>,
    val_store: Arc<FeaturizedStore>,
    encoder_init: &ParamSet,
    opt_enc_state: &AdamWState,
    head: &ElasticHead,
    plan: &FaultPlan,
) -> anyhow::Result<ElasticRankOut> {
    let dataset = head.dataset;
    let dims = engine.manifest.config.batch_dims();
    let group = mr.shape.head_size(mr.head);
    let mut encoder = encoder_init.clone();
    let mut branch = head.branch.clone();
    let mut full = ParamSet::zeros_like(&engine.manifest.params);
    let mut opt_enc = AdamW::new(adamw_cfg(cfg), &encoder);
    opt_enc.load_state(opt_enc_state)?;
    let mut opt_br = AdamW::new(adamw_cfg(cfg), &branch);
    opt_br.load_state(&head.opt)?;
    let mut enc_g = ParamSet::zeros_like(&engine.manifest.params).subset("encoder.");
    let mut br_g = ParamSet::zeros_like(&engine.manifest.params).subset("branch.");
    let mut enc_flat: Vec<f32> = Vec::new();
    let mut br_flat: Vec<f32> = Vec::new();
    let mut pool = BatchPool::default();
    let mut sink = build_overlap_sink(engine, cfg, &mr.global, &mr.head_group)?;

    let val_batches =
        val_store.plan_epoch_batches(mr.replica, group, dims, cfg.train.seed ^ VAL_SEED, &mut pool);

    let t_epoch = Instant::now();
    let mut acc = StepAccum::default();
    let t0 = Instant::now();
    let batches = store.plan_epoch_batches(
        mr.replica,
        group,
        dims,
        cfg.train.seed.wrapping_add(epoch as u64 * 7_777_777) ^ dataset.index() as u64,
        &mut pool,
    );
    acc.data += t0.elapsed();
    let planned = batches.len();
    let steps = agree_steps(&mr.global, batches.len())?;

    for step in 0..steps {
        inject_rank_faults(plan, mr.rank, epoch, step);
        let batch = &batches[step % batches.len().max(1)];
        assemble_full(&mut full, &encoder, &branch);

        let t1 = Instant::now();
        if let Some(sink) = sink.as_mut() {
            sink.begin_step(plan.nonfinite_at(mr.rank, epoch, step));
            let out = engine.train_step_observed_unchecked(&full, batch, sink)?;
            acc.exec += t1.elapsed();
            let t2 = Instant::now();
            if sink.zeroed() {
                skip_batch(cfg, &mut acc, mr.rank, epoch, step)?;
            } else {
                acc.record_step(out.loss, out.mae_e, out.mae_f);
            }
            sink.finish_step(&mut enc_flat, &mut br_flat)?;
            enc_g.unflatten_from(&enc_flat);
            br_g.unflatten_from(&br_flat);
            acc.comm += t2.elapsed();
        } else {
            let mut out = engine.train_step_unchecked(&full, batch)?;
            if plan.nonfinite_at(mr.rank, epoch, step) {
                out.loss = f64::NAN;
            }
            acc.exec += t1.elapsed();

            let t2 = Instant::now();
            if out.loss.is_finite() {
                acc.record_step(out.loss, out.mae_e, out.mae_f);
                out.grads.flatten_prefix_into("encoder.", &mut enc_flat);
                out.grads.flatten_prefix_into("branch.", &mut br_flat);
            } else {
                skip_batch(cfg, &mut acc, mr.rank, epoch, step)?;
                zero_flat(&mut enc_flat, enc_g.total_params());
                zero_flat(&mut br_flat, br_g.total_params());
            }
            mr.global.allreduce_mean(&mut enc_flat)?;
            mr.head_group.allreduce_mean(&mut br_flat)?;
            enc_g.unflatten_from(&enc_flat);
            br_g.unflatten_from(&br_flat);
            acc.comm += t2.elapsed();
        }

        let t3 = Instant::now();
        opt_enc.step(&mut encoder, &enc_g);
        opt_br.step(&mut branch, &br_g);
        acc.opt += t3.elapsed();
    }
    pool.recycle(batches);

    assemble_full(&mut full, &encoder, &branch);
    let val_loss = distributed_val_loss(engine, &mr.global, &full, &val_batches)?;
    let mut cov =
        Coverage { dataset: dataset.name(), planned, used: steps, step_ms: head.step_ms };
    cov.observe_step_ms(measured_step_ms(&acc, steps));
    let metrics =
        acc.into_epoch(epoch, t_epoch.elapsed(), val_loss).with_coverage(vec![cov]);
    let sg = mr.global.stats();
    let sh = mr.head_group.stats();
    Ok(ElasticRankOut {
        rank: mr.rank,
        head: mr.head,
        replica: mr.replica,
        encoder,
        branch,
        opt_enc: opt_enc.export_state(),
        opt_br: opt_br.export_state(),
        metrics,
        comm_global: sg.elems,
        comm_head: sh.elems,
        comm_overlapped: sg.overlapped_elems + sh.overlapped_elems,
    })
}

// -- warm-start fine-tune loop ------------------------------------------------

/// Branch-only training against a frozen, pre-trained encoder. DDP over
/// the global group (one head), branch gradients only — the encoder is
/// used exactly as given and never updated.
///
/// Deliberately synchronous even when `parallel.overlap` is on: the branch
/// payload is the FIRST block backward completes, so there is no later
/// compute to hide its reduction behind — an overlap sink would add comm-
/// thread hops for zero pipelining win.
#[allow(clippy::too_many_arguments)]
fn rank_loop_fine_tune(
    engine: &Engine,
    cfg: &RunConfig,
    mr: MeshRank,
    store: Arc<FeaturizedStore>,
    val_store: Arc<FeaturizedStore>,
    encoder: &ParamSet,
    dataset: DatasetId,
    plan: &FaultPlan,
) -> anyhow::Result<RankResult> {
    let dims = engine.manifest.config.batch_dims();
    let (_, mut branches) = init_rank_params(engine, cfg, &[dataset]);
    let mut branch = branches.remove(0).1;
    let mut full = ParamSet::zeros_like(&engine.manifest.params);
    let mut opt_br = AdamW::new(adamw_cfg(cfg), &branch);
    let mut log = RunLog::new(format!("WarmStart-{}", dataset.name()));
    let mut stopper = EarlyStopper::new(cfg.train.patience);
    let mut br_g = ParamSet::zeros_like(&engine.manifest.params).subset("branch.");
    let mut br_flat: Vec<f32> = Vec::new();
    let mut pool = BatchPool::default();
    let mut step_ms_ema = 0.0f64;

    let val_batches = val_store.plan_epoch_batches(
        mr.replica,
        mr.shape.replicas,
        dims,
        cfg.train.seed ^ VAL_SEED,
        &mut pool,
    );

    for epoch in 0..cfg.train.epochs {
        let t_epoch = Instant::now();
        let mut acc = StepAccum::default();

        let t0 = Instant::now();
        let batches = store.plan_epoch_batches(
            mr.replica,
            mr.shape.replicas,
            dims,
            cfg.train.seed.wrapping_add(epoch as u64 * 7_777_777) ^ dataset.index() as u64,
            &mut pool,
        );
        acc.data += t0.elapsed();
        let planned = batches.len();
        let steps = agree_steps(&mr.global, batches.len())?;

        for step in 0..steps {
            inject_rank_faults(plan, mr.rank, epoch, step);
            let batch = &batches[step % batches.len().max(1)];
            assemble_full(&mut full, encoder, &branch);

            let t1 = Instant::now();
            let mut out = engine.train_step_unchecked(&full, batch)?;
            if plan.nonfinite_at(mr.rank, epoch, step) {
                out.loss = f64::NAN;
            }
            acc.exec += t1.elapsed();

            // Branch gradients only; the frozen encoder's grads are dropped.
            let t2 = Instant::now();
            if out.loss.is_finite() {
                acc.record_step(out.loss, out.mae_e, out.mae_f);
                out.grads.flatten_prefix_into("branch.", &mut br_flat);
            } else {
                skip_batch(cfg, &mut acc, mr.rank, epoch, step)?;
                zero_flat(&mut br_flat, br_g.total_params());
            }
            mr.global.allreduce_mean(&mut br_flat)?;
            br_g.unflatten_from(&br_flat);
            acc.comm += t2.elapsed();

            let t3 = Instant::now();
            opt_br.step(&mut branch, &br_g);
            acc.opt += t3.elapsed();
        }
        pool.recycle(batches);

        assemble_full(&mut full, encoder, &branch);
        let val_loss = distributed_val_loss(engine, &mr.global, &full, &val_batches)?;
        let mut cov =
            Coverage { dataset: dataset.name(), planned, used: steps, step_ms: step_ms_ema };
        cov.observe_step_ms(measured_step_ms(&acc, steps));
        step_ms_ema = cov.step_ms;
        log.push(acc.into_epoch(epoch, t_epoch.elapsed(), val_loss).with_coverage(vec![cov]));
        if stopper.update(val_loss) {
            break;
        }
    }

    let st = mr.global.stats();
    Ok(RankResult {
        rank: mr.rank,
        head: mr.head,
        replica: mr.replica,
        encoder: encoder.clone(),
        branches: vec![(dataset, branch)],
        log,
        comm_global: st.elems,
        comm_head: 0,
        comm_overlapped: st.overlapped_elems,
    })
}

/// Validation-batch shuffle seed tag.
const VAL_SEED: u64 = 0x5EED_FACE;

// ---------------------------------------------------------------------------
// finalization
// ---------------------------------------------------------------------------

/// Collapse rank results for single-branch modes: the shared branch from
/// rank 0 (all replicas are in sync), log from rank 0.
fn finalize_shared(
    name: String,
    mut results: Vec<RankResult>,
    _datasets: Vec<DatasetId>,
) -> anyhow::Result<TrainOutcome> {
    results.sort_by_key(|r| r.rank);
    check_encoder_sync(&results)?;
    let comm_elems = (
        results.iter().map(|r| r.comm_global).max().unwrap_or(0),
        results.iter().map(|r| r.comm_head).max().unwrap_or(0),
    );
    let overlapped_elems = results.iter().map(|r| r.comm_overlapped).max().unwrap_or(0);
    let r0 = results
        .into_iter()
        .next()
        .ok_or_else(|| anyhow::anyhow!("no rank results"))?;
    let branch = r0
        .branches
        .into_iter()
        .next()
        .ok_or_else(|| anyhow::anyhow!("rank 0 returned no branch"))?
        .1;
    Ok(TrainOutcome {
        model: TrainedModel { name: r0.log.model_name.clone(), encoder: r0.encoder, heads: Heads::Shared(branch) }
            .with_name(name),
        log: r0.log,
        comm_elems,
        overlapped_elems,
        final_head_sizes: Vec::new(),
    })
}

/// Collapse rank results for per-dataset-head modes: encoder from rank 0,
/// each dataset's branch from replica 0 of its head sub-group.
/// DDP invariant: every rank's encoder must end bit-identically in sync
/// (same init, exact collectives, deterministic optimizer).
fn check_encoder_sync(results: &[RankResult]) -> anyhow::Result<()> {
    let pairs: Vec<(usize, &ParamSet)> =
        results.iter().map(|r| (r.rank, &r.encoder)).collect();
    check_encoder_pairs(&pairs)
}

/// The rank-agnostic core of [`check_encoder_sync`], shared with the
/// elastic driver (whose per-epoch results are not `RankResult`s).
fn check_encoder_pairs(pairs: &[(usize, &ParamSet)]) -> anyhow::Result<()> {
    let Some((_, e0)) = pairs.first() else {
        return Ok(());
    };
    for (rank, e) in &pairs[1..] {
        for ((name, a), (_, b)) in e0.iter().zip(e.iter()) {
            let (av, bv) = (a.as_f32(), b.as_f32());
            for i in 0..av.len() {
                anyhow::ensure!(
                    (av[i] - bv[i]).abs() <= 1e-5 * (1.0 + av[i].abs()),
                    "encoder desync: rank {rank} vs 0 at {name}[{i}]: {} vs {}",
                    bv[i],
                    av[i]
                );
            }
        }
    }
    Ok(())
}

fn finalize_per_dataset(
    name: String,
    mut results: Vec<RankResult>,
    datasets: &[DatasetId],
) -> anyhow::Result<TrainOutcome> {
    results.sort_by_key(|r| r.rank);
    check_encoder_sync(&results)?;
    let comm_elems = (
        results.iter().map(|r| r.comm_global).max().unwrap_or(0),
        results.iter().map(|r| r.comm_head).max().unwrap_or(0),
    );
    let overlapped_elems = results.iter().map(|r| r.comm_overlapped).max().unwrap_or(0);
    let mut heads: BTreeMap<DatasetId, ParamSet> = BTreeMap::new();
    for r in &results {
        if r.replica == 0 {
            for (d, b) in &r.branches {
                heads.insert(*d, b.clone());
            }
        }
    }
    for d in datasets {
        anyhow::ensure!(heads.contains_key(d), "missing trained branch for {}", d.name());
    }
    let r0 = results
        .into_iter()
        .next()
        .ok_or_else(|| anyhow::anyhow!("no rank results"))?;
    Ok(TrainOutcome {
        model: TrainedModel { name, encoder: r0.encoder, heads: Heads::PerDataset(heads) },
        log: r0.log,
        comm_elems,
        overlapped_elems,
        final_head_sizes: Vec::new(),
    })
}

impl TrainedModel {
    fn with_name(mut self, name: String) -> TrainedModel {
        self.name = name;
        self
    }
}
