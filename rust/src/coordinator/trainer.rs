//! The 2D-parallel training coordinator — the paper's system contribution.
//!
//! Three execution modes (Section 5.1's seven models reduce to these):
//!
//! * `Single(d)` / `BaselineAll` — one branch, plain DDP: every rank holds
//!   encoder + the branch; gradients allreduce over the global group.
//! * `MtlBase` — two-level MTL with DDP only: every rank holds encoder +
//!   ALL `N_h` branches, processes one batch per dataset per step, and
//!   allreduces the full `P_s + N_h*P_h` gradient payload globally.
//! * `MtlPar` — **multi-task parallelism** x DDP (the contribution): the
//!   mesh is `N_h` head sub-groups x `M` replicas; each rank holds encoder
//!   + exactly ONE branch, works only on its head's dataset, allreduces
//!   branch gradients within its sub-group (`P_h` payload) and encoder
//!   gradients globally (`P_s` payload).
//!
//! Ranks are OS threads sharing the PJRT engine; collectives are the
//! `comm` module's rendezvous groups, so the communication *pattern* is
//! exactly the paper's Figure 3 even though transport is shared memory.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use crate::comm::{build_mesh, MeshRank, MeshShape};
use crate::config::{RunConfig, TrainMode};
use crate::coordinator::metrics::{RunLog, StepAccum};
use crate::coordinator::scheduler::EarlyStopper;
use crate::data::batch::{BatchBuilder, BatchPool, GraphBatch};
use crate::data::featurized::FeaturizedStore;
use crate::data::split::{Split, SplitSpec};
use crate::data::structures::{AtomicStructure, DatasetId};
use crate::data::DDStore;
use crate::model::optimizer::{AdamW, AdamWConfig};
use crate::model::params::ParamSet;
use crate::runtime::Engine;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// data bundle
// ---------------------------------------------------------------------------

/// Per-dataset train/val/test structure lists.
pub struct DataBundle {
    pub train: BTreeMap<DatasetId, Arc<Vec<AtomicStructure>>>,
    pub val: BTreeMap<DatasetId, Arc<Vec<AtomicStructure>>>,
    pub test: BTreeMap<DatasetId, Arc<Vec<AtomicStructure>>>,
}

impl DataBundle {
    /// Generate synthetic data for `datasets` per the run config, one scoped
    /// thread per dataset. Generation is embarrassingly parallel: every
    /// dataset's RNG stream is seeded only by `(cfg.seed, dataset)`, so the
    /// output is bit-identical to [`DataBundle::generate_serial`] (proven in
    /// `rust/tests/integration_featurized.rs`).
    pub fn generate(cfg: &crate::config::DataConfig, datasets: &[DatasetId]) -> DataBundle {
        let parts: Vec<_> = std::thread::scope(|scope| {
            let handles: Vec<_> = datasets
                .iter()
                .map(|&d| scope.spawn(move || generate_one(cfg, d)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("dataset generation thread panicked"))
                .collect()
        });
        Self::assemble(datasets, parts)
    }

    /// Serial reference generator (the seed code path), kept as the
    /// bit-identity oracle for the parallel [`DataBundle::generate`].
    pub fn generate_serial(
        cfg: &crate::config::DataConfig,
        datasets: &[DatasetId],
    ) -> DataBundle {
        let parts = datasets.iter().map(|&d| generate_one(cfg, d)).collect();
        Self::assemble(datasets, parts)
    }

    fn assemble(datasets: &[DatasetId], parts: Vec<DatasetSplits>) -> DataBundle {
        let mut train = BTreeMap::new();
        let mut val = BTreeMap::new();
        let mut test = BTreeMap::new();
        for (&d, (tr, va, te)) in datasets.iter().zip(parts) {
            train.insert(d, Arc::new(tr));
            val.insert(d, Arc::new(va));
            test.insert(d, Arc::new(te));
        }
        DataBundle { train, val, test }
    }

    pub fn datasets(&self) -> Vec<DatasetId> {
        self.train.keys().copied().collect()
    }
}

/// (train, val, test) structure lists for one dataset.
type DatasetSplits = (Vec<AtomicStructure>, Vec<AtomicStructure>, Vec<AtomicStructure>);

/// Generate and split one dataset (deterministic in `(cfg, d)` alone).
fn generate_one(cfg: &crate::config::DataConfig, d: DatasetId) -> DatasetSplits {
    use crate::data::generators::{DatasetGenerator, GeneratorConfig};
    let spec = SplitSpec { train: cfg.train_frac, val: cfg.val_frac };
    let mut g = DatasetGenerator::new(
        d,
        cfg.seed,
        GeneratorConfig { max_atoms: cfg.max_atoms, ..Default::default() },
    );
    let samples = g.take(cfg.per_dataset);
    let mut tr = Vec::new();
    let mut va = Vec::new();
    let mut te = Vec::new();
    for (i, s) in samples.into_iter().enumerate() {
        match spec.of(i, cfg.seed ^ d.index() as u64) {
            Split::Train => tr.push(s),
            Split::Val => va.push(s),
            Split::Test => te.push(s),
        }
    }
    (tr, va, te)
}

// ---------------------------------------------------------------------------
// trained model
// ---------------------------------------------------------------------------

/// Final parameters of a training run.
#[derive(Clone)]
pub struct TrainedModel {
    pub name: String,
    /// Encoder leaves ("encoder.*").
    pub encoder: ParamSet,
    /// Branch leaves ("branch.*"): one shared branch, or one per dataset.
    pub heads: Heads,
}

#[derive(Clone)]
pub enum Heads {
    Shared(ParamSet),
    PerDataset(BTreeMap<DatasetId, ParamSet>),
}

impl TrainedModel {
    /// The branch used to predict data from `d`, if the model has one.
    pub fn try_branch_for(&self, d: DatasetId) -> Option<&ParamSet> {
        match &self.heads {
            Heads::Shared(b) => Some(b),
            Heads::PerDataset(m) => m.get(&d),
        }
    }

    /// The branch used to predict data from `d`.
    pub fn branch_for(&self, d: DatasetId) -> &ParamSet {
        self.try_branch_for(d)
            .unwrap_or_else(|| panic!("{}: no branch for {}", self.name, d.name()))
    }

    /// Full engine-callable parameter set for dataset `d`.
    pub fn full_params(&self, engine: &Engine, d: DatasetId) -> ParamSet {
        let mut full = ParamSet::zeros_like(&engine.manifest.params);
        full.copy_matching_from(&self.encoder);
        full.copy_matching_from(self.branch_for(d));
        full
    }
}

// ---------------------------------------------------------------------------
// trainer
// ---------------------------------------------------------------------------

pub struct Trainer {
    pub engine: Arc<Engine>,
    pub cfg: RunConfig,
}

/// Outcome of a training run: final model + rank-0 metrics log + comm stats.
pub struct TrainOutcome {
    pub model: TrainedModel,
    pub log: RunLog,
    /// (global allreduced f32 elements, head-group allreduced f32 elements).
    pub comm_elems: (u64, u64),
}

impl Trainer {
    pub fn new(engine: Arc<Engine>, cfg: RunConfig) -> Trainer {
        Trainer { engine, cfg }
    }

    /// Run the configured training mode on `data`.
    pub fn train(&self, data: &DataBundle) -> anyhow::Result<TrainOutcome> {
        match self.cfg.mode {
            TrainMode::Single(d) => self.train_ddp(data, vec![d], false),
            TrainMode::BaselineAll => {
                self.train_ddp(data, data.datasets(), false)
            }
            TrainMode::MtlBase => self.train_mtl_base(data),
            TrainMode::MtlPar => self.train_mtl_par(data),
        }
    }

    // -- mode: single-branch DDP (Single / BaselineAll) ---------------------

    /// One branch, `replicas` DDP ranks. For BaselineAll the stream mixes
    /// every dataset through the same head (the paper's GFM-Baseline-All).
    fn train_ddp(
        &self,
        data: &DataBundle,
        datasets: Vec<DatasetId>,
        _reserved: bool,
    ) -> anyhow::Result<TrainOutcome> {
        let replicas = self.cfg.parallel.replicas;
        let shape = MeshShape { num_heads: 1, replicas };
        let mesh = build_mesh(shape);
        let engine = &self.engine;
        let cfg = &self.cfg;

        // Mixed stream: concatenate (dataset-tagged) training samples.
        // Featurize once, up front: warm epochs only shuffle and pack.
        let cutoff = engine.manifest.config.cutoff;
        let mixed: Vec<AtomicStructure> = datasets
            .iter()
            .flat_map(|d| data.train[d].iter().cloned())
            .collect();
        let store = FeaturizedStore::build(DDStore::new(mixed, replicas), cutoff);
        let val_mixed: Vec<AtomicStructure> = datasets
            .iter()
            .flat_map(|d| data.val[d].iter().cloned())
            .collect();
        let val_store = FeaturizedStore::build(DDStore::new(val_mixed, replicas), cutoff);

        let results = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for mr in mesh {
                let store = Arc::clone(&store);
                let val_store = Arc::clone(&val_store);
                let datasets = datasets.clone();
                handles.push(scope.spawn(move || {
                    rank_loop_single_branch(engine, cfg, mr, store, val_store, &datasets)
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("rank thread panicked"))
                .collect::<anyhow::Result<Vec<_>>>()
        })?;

        let name = self.cfg.mode.name();
        finalize_shared(name, results, datasets)
    }

    // -- mode: MTL-base (all heads everywhere, DDP only) ---------------------

    fn train_mtl_base(&self, data: &DataBundle) -> anyhow::Result<TrainOutcome> {
        let replicas = self.cfg.parallel.replicas;
        let shape = MeshShape { num_heads: 1, replicas };
        let mesh = build_mesh(shape);
        let engine = &self.engine;
        let cfg = &self.cfg;
        let datasets = data.datasets();

        let cutoff = engine.manifest.config.cutoff;
        let stores: BTreeMap<DatasetId, Arc<FeaturizedStore>> = datasets
            .iter()
            .map(|&d| {
                (d, FeaturizedStore::build(DDStore::new(data.train[&d].to_vec(), replicas), cutoff))
            })
            .collect();
        let val_stores: BTreeMap<DatasetId, Arc<FeaturizedStore>> = datasets
            .iter()
            .map(|&d| {
                (d, FeaturizedStore::build(DDStore::new(data.val[&d].to_vec(), replicas), cutoff))
            })
            .collect();

        let results = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for mr in mesh {
                let stores = stores.clone();
                let val_stores = val_stores.clone();
                let datasets = datasets.clone();
                handles.push(scope.spawn(move || {
                    rank_loop_mtl_base(engine, cfg, mr, stores, val_stores, &datasets)
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("rank thread panicked"))
                .collect::<anyhow::Result<Vec<_>>>()
        })?;

        finalize_per_dataset("GFM-MTL-All (MTL-base)".to_string(), results, &datasets)
    }

    // -- mode: MTL-par (multi-task parallelism x DDP) ------------------------

    fn train_mtl_par(&self, data: &DataBundle) -> anyhow::Result<TrainOutcome> {
        let datasets = data.datasets();
        let replicas = self.cfg.parallel.replicas;
        let shape = MeshShape { num_heads: datasets.len(), replicas };
        let mesh = build_mesh(shape);
        let engine = &self.engine;
        let cfg = &self.cfg;

        // One store per head sub-group: world = replicas.
        let cutoff = engine.manifest.config.cutoff;
        let stores: Vec<Arc<FeaturizedStore>> = datasets
            .iter()
            .map(|d| FeaturizedStore::build(DDStore::new(data.train[d].to_vec(), replicas), cutoff))
            .collect();
        let val_stores: Vec<Arc<FeaturizedStore>> = datasets
            .iter()
            .map(|d| FeaturizedStore::build(DDStore::new(data.val[d].to_vec(), replicas), cutoff))
            .collect();

        let results = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for mr in mesh {
                let store = Arc::clone(&stores[mr.head]);
                let val_store = Arc::clone(&val_stores[mr.head]);
                let dataset = datasets[mr.head];
                handles.push(scope.spawn(move || {
                    rank_loop_mtl_par(engine, cfg, mr, store, val_store, dataset)
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("rank thread panicked"))
                .collect::<anyhow::Result<Vec<_>>>()
        })?;

        finalize_per_dataset("GFM-MTL-All (MTL-par)".to_string(), results, &datasets)
    }
}

// ---------------------------------------------------------------------------
// per-rank state and loops
// ---------------------------------------------------------------------------

/// What each rank thread returns.
struct RankResult {
    rank: usize,
    #[allow(dead_code)]
    head: usize,
    replica: usize,
    encoder: ParamSet,
    /// (dataset, branch) pairs this rank owns.
    branches: Vec<(DatasetId, ParamSet)>,
    log: RunLog,
    comm_global: u64,
    comm_head: u64,
}

fn adamw_cfg(cfg: &RunConfig) -> AdamWConfig {
    AdamWConfig {
        lr: cfg.train.lr,
        beta1: cfg.train.beta1,
        beta2: cfg.train.beta2,
        eps: cfg.train.eps,
        weight_decay: cfg.train.weight_decay,
        grad_clip: cfg.train.grad_clip,
    }
}

/// Initialize rank-local parameters. All ranks use the same seeds so DDP
/// replicas start identical (and stay identical: collectives are exact).
fn init_rank_params(
    engine: &Engine,
    cfg: &RunConfig,
    datasets: &[DatasetId],
) -> (ParamSet, Vec<(DatasetId, ParamSet)>) {
    let full = ParamSet::init(&engine.manifest.params, cfg.train.seed);
    let encoder = full.subset("encoder.");
    let branches = datasets
        .iter()
        .map(|&d| {
            // Salt comes from the task spec (presets resolve to the seed
            // repo's exact constants, so trajectories are unchanged).
            let seed = cfg.train.seed ^ d.branch_init_salt();
            let b = ParamSet::init(&engine.manifest.params, seed).subset("branch.");
            (d, b)
        })
        .collect();
    (encoder, branches)
}

/// The seed epoch planner: clones every sample out of the `DDStore` and
/// re-runs `radius_graph` on it, every epoch, every rank. The production
/// path is `FeaturizedStore::plan_epoch_batches` (shuffle + pack cached
/// edges); this snapshot is kept as the bit-identity oracle for tests and
/// the "before" baseline in `BENCH_hot_paths.json`.
pub fn plan_epoch_batches_reference(
    store: &DDStore,
    rank_in_group: usize,
    group_size: usize,
    dims: crate::data::batch::BatchDims,
    cutoff: f64,
    epoch_seed: u64,
) -> Vec<GraphBatch> {
    let n = store.len();
    let mut indices: Vec<usize> = (0..n).collect();
    let mut rng = Rng::new(epoch_seed);
    rng.shuffle(&mut indices);
    let my: Vec<usize> =
        indices.into_iter().skip(rank_in_group).step_by(group_size).collect();
    let mut builder = BatchBuilder::new(dims, cutoff);
    let mut batches = Vec::new();
    for idx in my {
        if let Some(s) = store.get(rank_in_group, idx) {
            if let Some(b) = builder.push(&s) {
                batches.push(b);
            }
        }
    }
    batches.extend(builder.finish());
    batches
}

/// Assemble the full engine-callable ParamSet from encoder + branch.
fn assemble_full(scratch: &mut ParamSet, encoder: &ParamSet, branch: &ParamSet) {
    scratch.copy_matching_from(encoder);
    scratch.copy_matching_from(branch);
}

/// Mean validation loss across the group (same value on every rank).
fn distributed_val_loss(
    engine: &Engine,
    mr: &MeshRank,
    full: &ParamSet,
    val_batches: &[GraphBatch],
) -> anyhow::Result<f64> {
    let mut local = 0.0;
    let mut count = 0.0;
    for b in val_batches {
        let out = engine.eval_step(full, b)?;
        local += out.loss * b.n_graphs as f64;
        count += b.n_graphs as f64;
    }
    let sums = mr.global.allgather_f64(local);
    let counts = mr.global.allgather_f64(count);
    let total: f64 = sums.iter().sum();
    let n: f64 = counts.iter().sum();
    Ok(if n > 0.0 { total / n } else { f64::NAN })
}

/// Shared epoch-count agreement: every rank must run the same number of
/// steps or the collectives deadlock; take the global min of planned counts.
fn agree_steps(mr: &MeshRank, planned: usize) -> usize {
    let counts = mr.global.allgather_f64(planned as f64);
    counts.into_iter().fold(f64::INFINITY, f64::min) as usize
}

// -- single-branch DDP loop (Single / BaselineAll) ---------------------------

fn rank_loop_single_branch(
    engine: &Engine,
    cfg: &RunConfig,
    mr: MeshRank,
    store: Arc<FeaturizedStore>,
    val_store: Arc<FeaturizedStore>,
    datasets: &[DatasetId],
) -> anyhow::Result<RankResult> {
    let dims = engine.manifest.config.batch_dims();
    let (encoder, mut branches) = init_rank_params(engine, cfg, &datasets[..1]);
    let mut encoder = encoder;
    let branch_dataset = branches[0].0;
    let mut branch = branches.remove(0).1;

    let mut full = ParamSet::zeros_like(&engine.manifest.params);
    let mut opt_enc = AdamW::new(adamw_cfg(cfg), &encoder);
    let mut opt_br = AdamW::new(adamw_cfg(cfg), &branch);
    let mut log = RunLog::new(cfg.mode.name());
    let mut stopper = EarlyStopper::new(cfg.train.patience);
    // Reused gradient-sync scratch (no per-step allocation).
    let mut enc_g = ParamSet::zeros_like(&engine.manifest.params).subset("encoder.");
    let mut br_g = ParamSet::zeros_like(&engine.manifest.params).subset("branch.");
    let mut enc_flat: Vec<f32> = Vec::new();
    let mut br_flat: Vec<f32> = Vec::new();
    // Per-rank batch pool: epoch N+1 reuses epoch N's buffers.
    let mut pool = BatchPool::default();

    let val_batches = val_store.plan_epoch_batches(
        mr.replica,
        mr.shape.replicas,
        dims,
        cfg.train.seed ^ VAL_SEED,
        &mut pool,
    );

    for epoch in 0..cfg.train.epochs {
        let t_epoch = Instant::now();
        let mut acc = StepAccum::default();

        let t0 = Instant::now();
        let batches = store.plan_epoch_batches(
            mr.replica,
            mr.shape.replicas,
            dims,
            cfg.train.seed.wrapping_add(epoch as u64 * 7_777_777),
            &mut pool,
        );
        acc.data += t0.elapsed();
        let steps = agree_steps(&mr, batches.len());

        for step in 0..steps {
            let batch = &batches[step % batches.len().max(1)];
            assemble_full(&mut full, &encoder, &branch);

            let t1 = Instant::now();
            let out = engine.train_step(&full, batch)?;
            acc.exec += t1.elapsed();
            acc.record_step(out.loss, out.mae_e, out.mae_f);

            // Plain DDP: allreduce the complete gradient payload globally.
            let t2 = Instant::now();
            out.grads.flatten_prefix_into("encoder.", &mut enc_flat);
            out.grads.flatten_prefix_into("branch.", &mut br_flat);
            mr.global.allreduce_mean(&mut enc_flat);
            mr.global.allreduce_mean(&mut br_flat);
            enc_g.unflatten_from(&enc_flat);
            br_g.unflatten_from(&br_flat);
            acc.comm += t2.elapsed();

            let t3 = Instant::now();
            opt_enc.step(&mut encoder, &enc_g);
            opt_br.step(&mut branch, &br_g);
            acc.opt += t3.elapsed();
        }
        pool.recycle(batches);

        assemble_full(&mut full, &encoder, &branch);
        let val_loss = distributed_val_loss(engine, &mr, &full, &val_batches)?;
        log.push(acc.into_epoch(epoch, t_epoch.elapsed(), val_loss));
        if stopper.update(val_loss) {
            break;
        }
    }

    let (cg, _) = mr.global.stats();
    Ok(RankResult {
        rank: mr.rank,
        head: mr.head,
        replica: mr.replica,
        encoder,
        branches: vec![(branch_dataset, branch)],
        log,
        comm_global: cg,
        comm_head: 0,
    })
}

// -- MTL-base loop ------------------------------------------------------------

fn rank_loop_mtl_base(
    engine: &Engine,
    cfg: &RunConfig,
    mr: MeshRank,
    stores: BTreeMap<DatasetId, Arc<FeaturizedStore>>,
    val_stores: BTreeMap<DatasetId, Arc<FeaturizedStore>>,
    datasets: &[DatasetId],
) -> anyhow::Result<RankResult> {
    let dims = engine.manifest.config.batch_dims();
    let (mut encoder, mut branches) = init_rank_params(engine, cfg, datasets);
    let mut full = ParamSet::zeros_like(&engine.manifest.params);
    let mut opt_enc = AdamW::new(adamw_cfg(cfg), &encoder);
    let mut opt_brs: Vec<AdamW> =
        branches.iter().map(|(_, b)| AdamW::new(adamw_cfg(cfg), b)).collect();
    let mut log = RunLog::new("GFM-MTL-All (MTL-base)");
    let mut stopper = EarlyStopper::new(cfg.train.patience);
    // Per-rank batch pool shared across datasets and epochs.
    let mut pool = BatchPool::default();

    // Validation: every dataset's shard through its own branch.
    let val_batches: Vec<(usize, Vec<GraphBatch>)> = datasets
        .iter()
        .enumerate()
        .map(|(k, d)| {
            (
                k,
                val_stores[d].plan_epoch_batches(
                    mr.replica,
                    mr.shape.replicas,
                    dims,
                    cfg.train.seed ^ VAL_SEED,
                    &mut pool,
                ),
            )
        })
        .collect();

    for epoch in 0..cfg.train.epochs {
        let t_epoch = Instant::now();
        let mut acc = StepAccum::default();

        let t0 = Instant::now();
        let per_ds_batches: Vec<Vec<GraphBatch>> = datasets
            .iter()
            .map(|d| {
                stores[d].plan_epoch_batches(
                    mr.replica,
                    mr.shape.replicas,
                    dims,
                    cfg.train.seed.wrapping_add(epoch as u64 * 7_777_777)
                        ^ d.index() as u64,
                    &mut pool,
                )
            })
            .collect();
        acc.data += t0.elapsed();
        let min_batches = per_ds_batches.iter().map(|b| b.len()).min().unwrap_or(0);
        let steps = agree_steps(&mr, min_batches);

        for step in 0..steps {
            // One batch per dataset through its branch; encoder grads mean.
            let mut enc_gsum: Option<Vec<f32>> = None;
            let mut br_grads: Vec<ParamSet> = Vec::with_capacity(datasets.len());
            let mut loss_sum = 0.0;
            let mut mae_e_sum = 0.0;
            let mut mae_f_sum = 0.0;
            for (k, _) in datasets.iter().enumerate() {
                let batch = &per_ds_batches[k][step % per_ds_batches[k].len().max(1)];
                assemble_full(&mut full, &encoder, &branches[k].1);
                let t1 = Instant::now();
                let out = engine.train_step(&full, batch)?;
                acc.exec += t1.elapsed();
                loss_sum += out.loss;
                mae_e_sum += out.mae_e;
                mae_f_sum += out.mae_f;
                let enc_flat = out.grads.subset("encoder.").flatten();
                match &mut enc_gsum {
                    None => enc_gsum = Some(enc_flat),
                    Some(acc_flat) => {
                        for (a, g) in acc_flat.iter_mut().zip(enc_flat) {
                            *a += g;
                        }
                    }
                }
                br_grads.push(out.grads.subset("branch."));
            }
            let nh = datasets.len() as f64;
            acc.record_step(loss_sum / nh, mae_e_sum / nh, mae_f_sum / nh);

            // ONE global allreduce over P_s + N_h * P_h (the paper's
            // MTL-base payload): concatenate encoder mean + all branches.
            let t2 = Instant::now();
            let mut enc_flat = enc_gsum.unwrap();
            for g in enc_flat.iter_mut() {
                *g /= nh as f32;
            }
            let enc_len = enc_flat.len();
            let mut payload = enc_flat;
            let mut br_lens = Vec::with_capacity(br_grads.len());
            for bg in &br_grads {
                let f = bg.flatten();
                br_lens.push(f.len());
                payload.extend(f);
            }
            mr.global.allreduce_mean(&mut payload);
            acc.comm += t2.elapsed();

            let t3 = Instant::now();
            let mut enc_g = branches_scratch_encoder(engine);
            enc_g.unflatten_from(&payload[..enc_len]);
            opt_enc.step(&mut encoder, &enc_g);
            let mut off = enc_len;
            for (k, bg) in br_grads.iter_mut().enumerate() {
                bg.unflatten_from(&payload[off..off + br_lens[k]]);
                off += br_lens[k];
                opt_brs[k].step(&mut branches[k].1, bg);
            }
            acc.opt += t3.elapsed();
        }
        for b in per_ds_batches {
            pool.recycle(b);
        }

        // Validation across every head.
        let mut val_local = 0.0;
        let mut val_count = 0.0;
        for (k, batches) in &val_batches {
            assemble_full(&mut full, &encoder, &branches[*k].1);
            for b in batches {
                let out = engine.eval_step(&full, b)?;
                val_local += out.loss * b.n_graphs as f64;
                val_count += b.n_graphs as f64;
            }
        }
        let sums = mr.global.allgather_f64(val_local);
        let counts = mr.global.allgather_f64(val_count);
        let val_loss = sums.iter().sum::<f64>() / counts.iter().sum::<f64>().max(1.0);
        log.push(acc.into_epoch(epoch, t_epoch.elapsed(), val_loss));
        if stopper.update(val_loss) {
            break;
        }
    }

    let (cg, _) = mr.global.stats();
    Ok(RankResult {
        rank: mr.rank,
        head: mr.head,
        replica: mr.replica,
        encoder,
        branches,
        log,
        comm_global: cg,
        comm_head: 0,
    })
}

/// Encoder-gradient scratch with full names ("encoder.*").
fn branches_scratch_encoder(engine: &Engine) -> ParamSet {
    ParamSet::zeros_like(&engine.manifest.params).subset("encoder.")
}

// -- MTL-par loop --------------------------------------------------------------

fn rank_loop_mtl_par(
    engine: &Engine,
    cfg: &RunConfig,
    mr: MeshRank,
    store: Arc<FeaturizedStore>,
    val_store: Arc<FeaturizedStore>,
    dataset: DatasetId,
) -> anyhow::Result<RankResult> {
    let dims = engine.manifest.config.batch_dims();
    let (mut encoder, mut branches) = init_rank_params(engine, cfg, &[dataset]);
    let mut branch = branches.remove(0).1;
    let mut full = ParamSet::zeros_like(&engine.manifest.params);
    let mut opt_enc = AdamW::new(adamw_cfg(cfg), &encoder);
    let mut opt_br = AdamW::new(adamw_cfg(cfg), &branch);
    let mut log = RunLog::new(format!("MTL-par head {}", dataset.name()));
    let mut stopper = EarlyStopper::new(cfg.train.patience);
    // Reused gradient-sync scratch (no per-step allocation).
    let mut enc_g = ParamSet::zeros_like(&engine.manifest.params).subset("encoder.");
    let mut br_g = ParamSet::zeros_like(&engine.manifest.params).subset("branch.");
    let mut enc_flat: Vec<f32> = Vec::new();
    let mut br_flat: Vec<f32> = Vec::new();
    // Per-rank batch pool: epoch N+1 reuses epoch N's buffers.
    let mut pool = BatchPool::default();

    let val_batches = val_store.plan_epoch_batches(
        mr.replica,
        mr.shape.replicas,
        dims,
        cfg.train.seed ^ VAL_SEED,
        &mut pool,
    );

    for epoch in 0..cfg.train.epochs {
        let t_epoch = Instant::now();
        let mut acc = StepAccum::default();

        let t0 = Instant::now();
        let batches = store.plan_epoch_batches(
            mr.replica,
            mr.shape.replicas,
            dims,
            cfg.train.seed.wrapping_add(epoch as u64 * 7_777_777) ^ dataset.index() as u64,
            &mut pool,
        );
        acc.data += t0.elapsed();
        let steps = agree_steps(&mr, batches.len());

        for step in 0..steps {
            let batch = &batches[step % batches.len().max(1)];
            assemble_full(&mut full, &encoder, &branch);

            let t1 = Instant::now();
            let out = engine.train_step(&full, batch)?;
            acc.exec += t1.elapsed();
            acc.record_step(out.loss, out.mae_e, out.mae_f);

            // Multi-task parallelism: encoder grads allreduce GLOBALLY
            // (P_s payload); branch grads only within the head sub-group
            // (P_h payload) — Figure 3's two-level DDP.
            let t2 = Instant::now();
            out.grads.flatten_prefix_into("encoder.", &mut enc_flat);
            out.grads.flatten_prefix_into("branch.", &mut br_flat);
            mr.global.allreduce_mean(&mut enc_flat);
            mr.head_group.allreduce_mean(&mut br_flat);
            enc_g.unflatten_from(&enc_flat);
            br_g.unflatten_from(&br_flat);
            acc.comm += t2.elapsed();

            let t3 = Instant::now();
            opt_enc.step(&mut encoder, &enc_g);
            opt_br.step(&mut branch, &br_g);
            acc.opt += t3.elapsed();
        }
        pool.recycle(batches);

        assemble_full(&mut full, &encoder, &branch);
        let val_loss = distributed_val_loss(engine, &mr, &full, &val_batches)?;
        log.push(acc.into_epoch(epoch, t_epoch.elapsed(), val_loss));
        if stopper.update(val_loss) {
            break;
        }
    }

    let (cg, _) = mr.global.stats();
    let (ch, _) = mr.head_group.stats();
    Ok(RankResult {
        rank: mr.rank,
        head: mr.head,
        replica: mr.replica,
        encoder,
        branches: vec![(dataset, branch)],
        log,
        comm_global: cg,
        comm_head: ch,
    })
}

/// Validation-batch shuffle seed tag.
const VAL_SEED: u64 = 0x5EED_FACE;

// ---------------------------------------------------------------------------
// finalization
// ---------------------------------------------------------------------------

/// Collapse rank results for single-branch modes: the shared branch from
/// rank 0 (all replicas are in sync), log from rank 0.
fn finalize_shared(
    name: String,
    mut results: Vec<RankResult>,
    _datasets: Vec<DatasetId>,
) -> anyhow::Result<TrainOutcome> {
    results.sort_by_key(|r| r.rank);
    check_encoder_sync(&results)?;
    let comm_elems = (
        results.iter().map(|r| r.comm_global).max().unwrap_or(0),
        results.iter().map(|r| r.comm_head).max().unwrap_or(0),
    );
    let r0 = results
        .into_iter()
        .next()
        .ok_or_else(|| anyhow::anyhow!("no rank results"))?;
    let branch = r0
        .branches
        .into_iter()
        .next()
        .ok_or_else(|| anyhow::anyhow!("rank 0 returned no branch"))?
        .1;
    Ok(TrainOutcome {
        model: TrainedModel { name: r0.log.model_name.clone(), encoder: r0.encoder, heads: Heads::Shared(branch) }
            .with_name(name),
        log: r0.log,
        comm_elems,
    })
}

/// Collapse rank results for per-dataset-head modes: encoder from rank 0,
/// each dataset's branch from replica 0 of its head sub-group.
/// DDP invariant: every rank's encoder must end bit-identically in sync
/// (same init, exact collectives, deterministic optimizer).
fn check_encoder_sync(results: &[RankResult]) -> anyhow::Result<()> {
    let r0 = &results[0];
    for r in &results[1..] {
        for ((name, a), (_, b)) in r0.encoder.iter().zip(r.encoder.iter()) {
            let (av, bv) = (a.as_f32(), b.as_f32());
            for i in 0..av.len() {
                anyhow::ensure!(
                    (av[i] - bv[i]).abs() <= 1e-5 * (1.0 + av[i].abs()),
                    "encoder desync: rank {} vs 0 at {name}[{i}]: {} vs {}",
                    r.rank,
                    bv[i],
                    av[i]
                );
            }
        }
    }
    Ok(())
}

fn finalize_per_dataset(
    name: String,
    mut results: Vec<RankResult>,
    datasets: &[DatasetId],
) -> anyhow::Result<TrainOutcome> {
    results.sort_by_key(|r| r.rank);
    check_encoder_sync(&results)?;
    let comm_elems = (
        results.iter().map(|r| r.comm_global).max().unwrap_or(0),
        results.iter().map(|r| r.comm_head).max().unwrap_or(0),
    );
    let mut heads: BTreeMap<DatasetId, ParamSet> = BTreeMap::new();
    for r in &results {
        if r.replica == 0 {
            for (d, b) in &r.branches {
                heads.insert(*d, b.clone());
            }
        }
    }
    for d in datasets {
        anyhow::ensure!(heads.contains_key(d), "missing trained branch for {}", d.name());
    }
    let r0 = results
        .into_iter()
        .next()
        .ok_or_else(|| anyhow::anyhow!("no rank results"))?;
    Ok(TrainOutcome {
        model: TrainedModel { name, encoder: r0.encoder, heads: Heads::PerDataset(heads) },
        log: r0.log,
        comm_elems,
    })
}

impl TrainedModel {
    fn with_name(mut self, name: String) -> TrainedModel {
        self.name = name;
        self
    }
}
