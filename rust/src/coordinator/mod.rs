//! L3 coordinator: the 2D-parallel trainer (multi-task parallelism x DDP),
//! cross-dataset evaluation, experiment drivers for the paper's tables and
//! figures, metrics, and schedules.

pub mod evaluate;
pub mod experiments;
pub mod metrics;
pub mod scheduler;
pub mod trainer;

pub use evaluate::{evaluate_model, EvalMatrix};
pub use metrics::{Coverage, EpochMetrics, RunLog, StepAccum};
pub use scheduler::{EarlyStopper, LrSchedule};
pub use trainer::{DataBundle, Heads, TrainOutcome, TrainedModel, Trainer};
