//! Training metrics: per-epoch aggregates with phase timing (data loading,
//! forward+backward execution, gradient communication, optimizer), matching
//! the decomposition the paper's Figure 4 reports ("average total training
//! time per epoch, including data loading, forward, and backward passes").

use std::time::Duration;

use crate::util::json::Json;

/// Per-dataset batch coverage of one epoch on one rank: how many batches
/// the dataset's shard planned and how many batch-slots the epoch actually
/// consumed. `used > planned` means the dataset wrapped modulo its length
/// (smaller source cycled to keep up with a larger one); `used < planned`
/// means batches were dropped. The MTL-base loop used to silently truncate
/// every epoch to the *smallest* dataset — recording coverage in the run
/// log makes any such truncation visible forever.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Coverage {
    pub dataset: String,
    pub planned: usize,
    pub used: usize,
    /// EMA of this dataset's measured per-step wall time in milliseconds
    /// (0.0 until the first measurement). The elastic head scheduler sizes
    /// MTL-par sub-groups from this estimate at epoch boundaries; it is
    /// persisted in checkpoints so a resumed run replans from the same
    /// history an uninterrupted one would.
    pub step_ms: f64,
}

/// EMA decay for [`Coverage::step_ms`]: heavy enough on the newest epoch to
/// track load shifts, smooth enough to ignore one noisy epoch.
pub const STEP_MS_EMA_ALPHA: f64 = 0.5;

impl Coverage {
    /// Fold one epoch's measured mean step wall time into the EMA. The
    /// first observation seeds the estimate directly; non-finite or
    /// non-positive samples are ignored.
    pub fn observe_step_ms(&mut self, measured_ms: f64) {
        if !measured_ms.is_finite() || measured_ms <= 0.0 {
            return;
        }
        self.step_ms = if self.step_ms > 0.0 {
            STEP_MS_EMA_ALPHA * measured_ms + (1.0 - STEP_MS_EMA_ALPHA) * self.step_ms
        } else {
            measured_ms
        };
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::str(self.dataset.clone())),
            ("planned", Json::from(self.planned)),
            ("used", Json::from(self.used)),
            ("step_ms", Json::from(self.step_ms)),
        ])
    }
}

#[derive(Debug, Clone, Default, PartialEq)]
pub struct EpochMetrics {
    pub epoch: usize,
    pub steps: usize,
    pub train_loss: f64,
    pub mae_e: f64,
    pub mae_f: f64,
    pub val_loss: f64,
    /// Batches whose loss came back non-finite and were skipped by the
    /// trainer's supervision (zero gradient contribution, optimizer still
    /// stepped with the peers' mean) instead of aborting the run. Always 0
    /// on a healthy run; bounded by the configured skip budget.
    pub skipped_batches: usize,
    pub time_total: Duration,
    pub time_data: Duration,
    pub time_exec: Duration,
    pub time_comm: Duration,
    pub time_opt: Duration,
    /// Per-dataset batch coverage (see [`Coverage`]).
    pub coverage: Vec<Coverage>,
}

impl EpochMetrics {
    /// Attach per-dataset coverage (builder-style, used right after
    /// [`StepAccum::into_epoch`]).
    pub fn with_coverage(mut self, coverage: Vec<Coverage>) -> EpochMetrics {
        self.coverage = coverage;
        self
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("epoch", Json::from(self.epoch)),
            ("steps", Json::from(self.steps)),
            ("train_loss", Json::from(self.train_loss)),
            ("mae_e", Json::from(self.mae_e)),
            ("mae_f", Json::from(self.mae_f)),
            ("val_loss", Json::from(self.val_loss)),
            ("skipped_batches", Json::from(self.skipped_batches)),
            ("time_total_s", Json::from(self.time_total.as_secs_f64())),
            ("time_data_s", Json::from(self.time_data.as_secs_f64())),
            ("time_exec_s", Json::from(self.time_exec.as_secs_f64())),
            ("time_comm_s", Json::from(self.time_comm.as_secs_f64())),
            ("time_opt_s", Json::from(self.time_opt.as_secs_f64())),
            (
                "coverage",
                Json::Array(self.coverage.iter().map(|c| c.to_json()).collect()),
            ),
        ])
    }

    pub fn summary(&self) -> String {
        format!(
            "epoch {:>3}  loss {:>10.5}  mae_e {:>9.5}  mae_f {:>9.5}  val {:>10.5}  \
             [{:>7.2?} total | data {:.0?} exec {:.0?} comm {:.0?} opt {:.0?}]",
            self.epoch,
            self.train_loss,
            self.mae_e,
            self.mae_f,
            self.val_loss,
            self.time_total,
            self.time_data,
            self.time_exec,
            self.time_comm,
            self.time_opt
        )
    }
}

/// Step-level accumulator a rank carries through an epoch.
#[derive(Debug, Default, Clone)]
pub struct StepAccum {
    pub steps: usize,
    pub loss_sum: f64,
    pub mae_e_sum: f64,
    pub mae_f_sum: f64,
    /// Non-finite-loss batches skipped this epoch (not counted in `steps`).
    pub skipped: usize,
    pub data: Duration,
    pub exec: Duration,
    pub comm: Duration,
    pub opt: Duration,
}

impl StepAccum {
    pub fn record_step(&mut self, loss: f64, mae_e: f64, mae_f: f64) {
        self.steps += 1;
        self.loss_sum += loss;
        self.mae_e_sum += mae_e;
        self.mae_f_sum += mae_f;
    }

    pub fn mean_loss(&self) -> f64 {
        if self.steps == 0 {
            f64::NAN
        } else {
            self.loss_sum / self.steps as f64
        }
    }

    pub fn into_epoch(self, epoch: usize, total: Duration, val_loss: f64) -> EpochMetrics {
        let n = self.steps.max(1) as f64;
        EpochMetrics {
            epoch,
            steps: self.steps,
            train_loss: self.loss_sum / n,
            mae_e: self.mae_e_sum / n,
            mae_f: self.mae_f_sum / n,
            val_loss,
            skipped_batches: self.skipped,
            time_total: total,
            time_data: self.data,
            time_exec: self.exec,
            time_comm: self.comm,
            time_opt: self.opt,
            coverage: Vec::new(),
        }
    }
}

/// Full run log with CSV/JSON export (EXPERIMENTS.md quotes these).
#[derive(Debug, Default, Clone, PartialEq)]
pub struct RunLog {
    pub model_name: String,
    pub epochs: Vec<EpochMetrics>,
}

impl RunLog {
    pub fn new(model_name: impl Into<String>) -> RunLog {
        RunLog { model_name: model_name.into(), epochs: Vec::new() }
    }

    pub fn push(&mut self, m: EpochMetrics) {
        self.epochs.push(m);
    }

    pub fn best_val(&self) -> Option<f64> {
        self.epochs.iter().map(|e| e.val_loss).fold(None, |acc, v| match acc {
            None => Some(v),
            Some(best) => Some(best.min(v)),
        })
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "epoch,steps,train_loss,mae_e,mae_f,val_loss,skipped,total_s,data_s,exec_s,\
             comm_s,opt_s,step_ms,step_ms_unseeded\n",
        );
        for e in &self.epochs {
            // The flat CSV gets the mean of the per-dataset step-time EMAs
            // over SEEDED (> 0) entries only: an EMA is 0.0 until its first
            // measurement, and in MTL-par a rank only ever observes its own
            // head's datasets — folding those zeros in dragged the reported
            // mean toward zero in early epochs. The count of still-unseeded
            // datasets rides along so the flat row stays honest about how
            // much of the fleet the mean covers; the per-dataset breakdown
            // lives in the JSON coverage array.
            let seeded = e.coverage.iter().filter(|c| c.step_ms > 0.0).count();
            let step_ms = if seeded == 0 {
                0.0
            } else {
                e.coverage.iter().map(|c| c.step_ms).sum::<f64>() / seeded as f64
            };
            let unseeded = e.coverage.len() - seeded;
            out.push_str(&format!(
                "{},{},{:.6},{:.6},{:.6},{:.6},{},{:.4},{:.4},{:.4},{:.4},{:.4},{:.4},{}\n",
                e.epoch,
                e.steps,
                e.train_loss,
                e.mae_e,
                e.mae_f,
                e.val_loss,
                e.skipped_batches,
                e.time_total.as_secs_f64(),
                e.time_data.as_secs_f64(),
                e.time_exec.as_secs_f64(),
                e.time_comm.as_secs_f64(),
                e.time_opt.as_secs_f64(),
                step_ms,
                unseeded,
            ));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(self.model_name.clone())),
            ("epochs", Json::Array(self.epochs.iter().map(|e| e.to_json()).collect())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accum_averages() {
        let mut a = StepAccum::default();
        a.record_step(2.0, 0.5, 0.1);
        a.record_step(4.0, 1.5, 0.3);
        assert_eq!(a.mean_loss(), 3.0);
        let e = a.into_epoch(1, Duration::from_secs(2), 3.5);
        assert_eq!(e.train_loss, 3.0);
        assert_eq!(e.mae_e, 1.0);
        assert_eq!(e.val_loss, 3.5);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut log = RunLog::new("test");
        log.push(StepAccum::default().into_epoch(0, Duration::ZERO, 1.0));
        let csv = log.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("epoch,"));
    }

    #[test]
    fn coverage_rides_along_into_json() {
        let mut a = StepAccum::default();
        a.record_step(1.0, 0.0, 0.0);
        let e = a.into_epoch(0, Duration::ZERO, 1.0).with_coverage(vec![
            Coverage { dataset: "big".into(), planned: 10, used: 10, step_ms: 0.0 },
            Coverage { dataset: "small".into(), planned: 2, used: 10, step_ms: 1.25 },
        ]);
        assert_eq!(e.coverage.len(), 2);
        let j = e.to_json();
        let cov = j.get("coverage");
        assert_eq!(cov.idx(1).get("dataset").as_str(), Some("small"));
        assert_eq!(cov.idx(1).get("used").as_i64(), Some(10));
        assert_eq!(cov.idx(1).get("planned").as_i64(), Some(2));
        assert_eq!(cov.idx(1).get("step_ms").as_f64(), Some(1.25));
    }

    #[test]
    fn step_ms_ema_seeds_then_smooths() {
        let mut c = Coverage { dataset: "d".into(), ..Default::default() };
        c.observe_step_ms(f64::NAN); // ignored
        c.observe_step_ms(-3.0); // ignored
        assert_eq!(c.step_ms, 0.0);
        c.observe_step_ms(10.0); // first sample seeds directly
        assert_eq!(c.step_ms, 10.0);
        c.observe_step_ms(20.0);
        assert_eq!(c.step_ms, STEP_MS_EMA_ALPHA * 20.0 + (1.0 - STEP_MS_EMA_ALPHA) * 10.0);
    }

    #[test]
    fn csv_step_ms_averages_seeded_emas_only() {
        // One dataset has never been timed (EMA still 0.0); its zero must not
        // drag the flat-CSV mean down, and the unseeded count must ride along
        // in the final column.
        let mut a = StepAccum::default();
        a.record_step(1.0, 0.0, 0.0);
        let e = a.into_epoch(0, Duration::ZERO, 1.0).with_coverage(vec![
            Coverage { dataset: "unseeded".into(), planned: 4, used: 0, step_ms: 0.0 },
            Coverage { dataset: "fast".into(), planned: 4, used: 4, step_ms: 1.25 },
            Coverage { dataset: "slow".into(), planned: 4, used: 4, step_ms: 2.75 },
        ]);
        let mut log = RunLog::new("t");
        log.push(e);
        let csv = log.to_csv();
        let header = csv.lines().next().unwrap();
        assert!(header.ends_with(",step_ms,step_ms_unseeded"));
        let row = csv.lines().nth(1).unwrap();
        let cols: Vec<&str> = row.split(',').collect();
        assert_eq!(cols.len(), header.split(',').count());
        // Mean of {1.25, 2.75}, not of {0.0, 1.25, 2.75}.
        assert_eq!(cols[cols.len() - 2], "2.0000");
        assert_eq!(cols[cols.len() - 1], "1");
    }

    #[test]
    fn csv_step_ms_is_zero_when_nothing_is_seeded() {
        let mut a = StepAccum::default();
        a.record_step(1.0, 0.0, 0.0);
        let e = a.into_epoch(0, Duration::ZERO, 1.0).with_coverage(vec![
            Coverage { dataset: "a".into(), planned: 2, used: 0, step_ms: 0.0 },
            Coverage { dataset: "b".into(), planned: 2, used: 0, step_ms: 0.0 },
        ]);
        let mut log = RunLog::new("t");
        log.push(e);
        let row = log.to_csv().lines().nth(1).unwrap().to_string();
        let cols: Vec<&str> = row.split(',').collect();
        assert_eq!(cols[cols.len() - 2], "0.0000");
        assert_eq!(cols[cols.len() - 1], "2");
    }

    #[test]
    fn skipped_batches_flow_into_epoch_json_and_csv() {
        let mut a = StepAccum::default();
        a.record_step(1.0, 0.0, 0.0);
        a.skipped = 2;
        let e = a.into_epoch(0, Duration::ZERO, 1.0);
        assert_eq!(e.skipped_batches, 2);
        assert_eq!(e.to_json().get("skipped_batches").as_i64(), Some(2));
        let mut log = RunLog::new("t");
        log.push(e);
        let csv = log.to_csv();
        assert!(csv.lines().next().unwrap().contains(",skipped,"));
        assert!(csv.lines().nth(1).unwrap().contains(",2,"));
    }

    #[test]
    fn best_val_tracks_minimum() {
        let mut log = RunLog::new("t");
        for (i, v) in [3.0, 1.5, 2.0].iter().enumerate() {
            let mut a = StepAccum::default();
            a.record_step(1.0, 0.0, 0.0);
            log.push(a.into_epoch(i, Duration::ZERO, *v));
        }
        assert_eq!(log.best_val(), Some(1.5));
    }
}
