//! Cross-dataset evaluation: the MAE matrices of Tables 1 and 2.
//!
//! Every trained model is evaluated on every dataset's held-out test split;
//! MTL models route each dataset through its own branch, single-branch
//! models use their only head everywhere (exactly how the paper scores the
//! seven models).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::coordinator::trainer::TrainedModel;
use crate::data::batch::BatchBuilder;
use crate::data::structures::{AtomicStructure, DatasetId};
use crate::runtime::Engine;

/// Per-dataset (energy MAE, force MAE), node/graph weighted.
pub fn evaluate_model(
    engine: &Engine,
    model: &TrainedModel,
    test: &BTreeMap<DatasetId, Arc<Vec<AtomicStructure>>>,
) -> anyhow::Result<BTreeMap<DatasetId, (f64, f64)>> {
    let dims = engine.manifest.config.batch_dims();
    let cutoff = engine.manifest.config.cutoff;
    let mut out = BTreeMap::new();
    for (&d, samples) in test {
        // Errors (naming the task) instead of the seed's branch_for panic
        // when a model is scored on a dataset it has no head for.
        let full = model.full_params(engine, d)?;
        let batches = BatchBuilder::build_all(dims, cutoff, samples);
        let mut e_sum = 0.0;
        let mut e_w = 0.0;
        let mut f_sum = 0.0;
        let mut f_w = 0.0;
        for b in &batches {
            let r = engine.eval_step(&full, b)?;
            e_sum += r.mae_e * b.n_graphs as f64;
            e_w += b.n_graphs as f64;
            f_sum += r.mae_f * b.n_nodes as f64;
            f_w += b.n_nodes as f64;
        }
        out.insert(d, (e_sum / e_w.max(1.0), f_sum / f_w.max(1.0)));
    }
    Ok(out)
}

/// The 7-model x 5-dataset result matrix (Tables 1-2).
pub struct EvalMatrix {
    pub model_names: Vec<String>,
    pub datasets: Vec<DatasetId>,
    /// mae_e[model][dataset]
    pub mae_e: Vec<Vec<f64>>,
    pub mae_f: Vec<Vec<f64>>,
}

impl EvalMatrix {
    pub fn new(datasets: Vec<DatasetId>) -> EvalMatrix {
        EvalMatrix { model_names: Vec::new(), datasets, mae_e: Vec::new(), mae_f: Vec::new() }
    }

    pub fn push_row(
        &mut self,
        name: impl Into<String>,
        per_dataset: &BTreeMap<DatasetId, (f64, f64)>,
    ) {
        self.model_names.push(name.into());
        self.mae_e.push(self.datasets.iter().map(|d| per_dataset[d].0).collect());
        self.mae_f.push(self.datasets.iter().map(|d| per_dataset[d].1).collect());
    }

    /// Paper-style text table. `which` selects energy ("Table 1") or force
    /// ("Table 2") MAEs; the two best per column are marked with '*'.
    pub fn render(&self, energy: bool) -> String {
        let vals = if energy { &self.mae_e } else { &self.mae_f };
        let title = if energy {
            "MAE in energy-per-atom predictions (Table 1 analogue)"
        } else {
            "MAE in force predictions (Table 2 analogue)"
        };
        let mut out = format!("{title}\n");
        out.push_str(&format!("{:<28}", "model"));
        for d in &self.datasets {
            out.push_str(&format!("{:>14}", d.name()));
        }
        out.push('\n');
        // Two best per column.
        let mut best: Vec<Vec<usize>> = Vec::new();
        for c in 0..self.datasets.len() {
            let mut order: Vec<usize> = (0..vals.len()).collect();
            order.sort_by(|&a, &b| vals[a][c].partial_cmp(&vals[b][c]).unwrap());
            best.push(order.into_iter().take(2).collect());
        }
        for (r, name) in self.model_names.iter().enumerate() {
            out.push_str(&format!("{name:<28}"));
            for c in 0..self.datasets.len() {
                let marker = if best[c].contains(&r) { "*" } else { " " };
                out.push_str(&format!("{:>13.4}{marker}", vals[r][c]));
            }
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self, energy: bool) -> String {
        let vals = if energy { &self.mae_e } else { &self.mae_f };
        let mut out = String::from("model");
        for d in &self.datasets {
            out.push_str(&format!(",{}", d.name()));
        }
        out.push('\n');
        for (r, name) in self.model_names.iter().enumerate() {
            out.push_str(name);
            for c in 0..self.datasets.len() {
                out.push_str(&format!(",{:.6}", vals[r][c]));
            }
            out.push('\n');
        }
        out
    }

    pub fn row(&self, name: &str) -> Option<usize> {
        self.model_names.iter().position(|n| n == name)
    }

    /// Mean MAE of a model's row (transferability summary).
    pub fn row_mean(&self, r: usize, energy: bool) -> f64 {
        let vals = if energy { &self.mae_e } else { &self.mae_f };
        vals[r].iter().sum::<f64>() / vals[r].len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::structures::ALL_DATASETS;

    #[test]
    fn matrix_render_marks_best() {
        let mut m = EvalMatrix::new(ALL_DATASETS.to_vec());
        let mk = |v: f64| -> BTreeMap<DatasetId, (f64, f64)> {
            ALL_DATASETS.iter().map(|&d| (d, (v, v * 2.0))).collect()
        };
        m.push_row("good", &mk(0.1));
        m.push_row("bad", &mk(5.0));
        m.push_row("mid", &mk(1.0));
        let text = m.render(true);
        // 'good' and 'mid' are the two best everywhere.
        let good_line = text.lines().find(|l| l.starts_with("good")).unwrap();
        assert!(good_line.contains('*'));
        let bad_line = text.lines().find(|l| l.starts_with("bad")).unwrap();
        assert!(!bad_line.contains('*'));
    }

    #[test]
    fn csv_roundtrips_dimensions() {
        let mut m = EvalMatrix::new(ALL_DATASETS.to_vec());
        let row: BTreeMap<DatasetId, (f64, f64)> =
            ALL_DATASETS.iter().map(|&d| (d, (0.5, 0.25))).collect();
        m.push_row("m1", &row);
        let csv = m.to_csv(false);
        assert_eq!(csv.lines().count(), 2);
        assert_eq!(csv.lines().next().unwrap().split(',').count(), 6);
        assert!(csv.contains("0.250000"));
    }

    #[test]
    fn row_mean() {
        let mut m = EvalMatrix::new(vec![DatasetId::Ani1x, DatasetId::Qm7x]);
        let mut row = BTreeMap::new();
        row.insert(DatasetId::Ani1x, (1.0, 0.0));
        row.insert(DatasetId::Qm7x, (3.0, 0.0));
        m.push_row("m", &row);
        assert_eq!(m.row_mean(0, true), 2.0);
    }
}
