//! Experiment drivers regenerating the paper's tables and figures:
//!
//! * `run_tables`  — Tables 1 & 2: train the seven models (five
//!   single-dataset, GFM-Baseline-All, GFM-MTL-All) and score the 7x5 MAE
//!   matrices for energies and forces.
//! * `fig1`        — the element-frequency heatmap over the aggregated data.
//!
//! Figure 4 (scaling) lives in `scalesim` since it sweeps simulated
//! machines; `examples/pretrain_e2e.rs` covers the Section 5.1 convergence
//! claim end to end.

use std::sync::Arc;

use crate::config::{RunConfig, TrainMode};
use crate::coordinator::evaluate::{evaluate_model, EvalMatrix};
use crate::coordinator::trainer::{DataBundle, TrainOutcome};
use crate::data::generators::{element_histogram, DatasetGenerator, GeneratorConfig};
use crate::data::structures::ALL_DATASETS;
use crate::elements;
use crate::runtime::Engine;
use crate::session::Session;

/// Train one model in the given mode (shared data bundle) and return it
/// along with its metrics log. Routed through the [`Session`] facade, so
/// every paper mode exercises the public API.
pub fn train_mode(
    engine: &Arc<Engine>,
    base: &RunConfig,
    data: &DataBundle,
    mode: TrainMode,
) -> anyhow::Result<TrainOutcome> {
    let mut cfg = base.clone();
    cfg.mode = mode;
    let session = Session::builder().config(cfg).engine(Arc::clone(engine)).build()?;
    session.train_on(data)
}

/// The seven models of Section 5.1, in paper order.
pub fn paper_model_modes() -> Vec<TrainMode> {
    let mut modes: Vec<TrainMode> =
        ALL_DATASETS.iter().map(|&d| TrainMode::Single(d)).collect();
    modes.push(TrainMode::BaselineAll);
    modes.push(TrainMode::MtlPar);
    modes
}

/// Train all seven models and evaluate the full cross-dataset matrix.
/// `progress` receives one line per finished model.
pub fn run_tables(
    engine: &Arc<Engine>,
    base: &RunConfig,
    data: &DataBundle,
    mut progress: impl FnMut(&str),
) -> anyhow::Result<EvalMatrix> {
    let mut matrix = EvalMatrix::new(data.datasets());
    for mode in paper_model_modes() {
        let t0 = std::time::Instant::now();
        let outcome = train_mode(engine, base, data, mode)?;
        let scores = evaluate_model(engine, &outcome.model, &data.test)?;
        progress(&format!(
            "{:<28} trained in {:>7.1?} ({} epochs, best val {:.5})",
            outcome.model.name,
            t0.elapsed(),
            outcome.log.epochs.len(),
            outcome.log.best_val().unwrap_or(f64::NAN),
        ));
        // Use the paper's row label (GFM-MTL-All for the MTL model).
        let label = match mode {
            TrainMode::MtlPar | TrainMode::MtlBase => "GFM-MTL-All".to_string(),
            _ => outcome.model.name.clone(),
        };
        matrix.push_row(label, &scores);
    }
    Ok(matrix)
}

// ---------------------------------------------------------------------------
// Fig 1: element frequency heatmap
// ---------------------------------------------------------------------------

/// Element occurrence counts over freshly generated aggregated data.
pub fn fig1_histogram(seed: u64, per_dataset: usize, max_atoms: usize) -> Vec<u64> {
    let mut counts = vec![0u64; elements::MAX_Z + 1];
    for &d in &ALL_DATASETS {
        let mut g = DatasetGenerator::new(
            d,
            seed,
            GeneratorConfig { max_atoms, ..Default::default() },
        );
        let hist = element_histogram(&g.take(per_dataset));
        for (z, c) in hist.iter().enumerate() {
            counts[z] += c;
        }
    }
    counts
}

/// Render the histogram as a periodic-table-shaped text heatmap (the Fig 1
/// analogue) plus a CSV appendix.
pub fn fig1_render(counts: &[u64]) -> String {
    let max = *counts.iter().max().unwrap_or(&1) as f64;
    let shade = |c: u64| -> char {
        if c == 0 {
            '.'
        } else {
            // log-scaled 5-level shading.
            let t = ((c as f64).ln_1p() / max.ln_1p() * 4.0).round() as usize;
            [':', '-', '=', '#', '@'][t.min(4)]
        }
    };
    let mut out = String::from(
        "Element frequency across aggregated ANI1x+QM7-X+Transition1x+MPTrj+Alexandria\n\
         (periodic-table layout; shade = log frequency: . 0  : low ... @ high)\n\n",
    );
    // 7 periods x 18 groups; f-block printed separately.
    for period in 1..=7u8 {
        let mut row = vec!["   ".to_string(); 18];
        for z in 1..=elements::MAX_Z {
            let e = elements::element(z);
            if e.period == period && e.group >= 1 {
                row[(e.group - 1) as usize] = format!("{}{} ", shade(counts[z]), e.symbol);
            }
        }
        out.push_str(&format!("P{period} "));
        for cell in row {
            out.push_str(&format!("{cell:<4}"));
        }
        out.push('\n');
    }
    out.push_str("f-block: ");
    for z in 1..=elements::MAX_Z {
        let e = elements::element(z);
        if e.group == 0 {
            out.push_str(&format!("{}{} ", shade(counts[z]), e.symbol));
        }
    }
    out.push_str("\n\nCSV: Z,symbol,count\n");
    for z in 1..=elements::MAX_Z {
        if counts[z] > 0 {
            out.push_str(&format!("{z},{},{}\n", elements::symbol(z), counts[z]));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_has_seven_models() {
        assert_eq!(paper_model_modes().len(), 7);
    }

    #[test]
    fn fig1_histogram_covers_organic_and_inorganic() {
        let counts = fig1_histogram(1, 30, 16);
        // H and C dominate (three organic datasets).
        assert!(counts[1] > 0 && counts[6] > 0);
        assert!(counts[1] >= counts[26], "H should outnumber Fe");
        // Inorganic coverage: some transition metal must appear.
        let tm: u64 = (21..=30).map(|z| counts[z]).sum();
        assert!(tm > 0, "no transition metals generated");
        // Coverage target: paper says two-thirds of natural elements.
        let covered = counts.iter().filter(|&&c| c > 0).count();
        assert!(covered > 40, "only {covered} elements covered");
    }

    #[test]
    fn fig1_render_contains_table_and_csv() {
        let counts = fig1_histogram(2, 20, 16);
        let text = fig1_render(&counts);
        assert!(text.contains("P1"));
        assert!(text.contains("P7"));
        assert!(text.contains("CSV: Z,symbol,count"));
        assert!(text.contains("H "));
    }
}
