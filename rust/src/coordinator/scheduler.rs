//! Training schedule helpers: early stopping (paper Section 5.1: "early
//! stopping was applied to avoid redundant computations"), learning-rate
//! schedules, and the elastic head-group planner that sizes MTL-par
//! sub-groups from measured per-head step costs.

/// Size each head's sub-group proportionally to its measured cost (elastic
/// MTL-par). `costs[h]` is head `h`'s total serial-work estimate for the
/// coming epoch (per-step wall-time EMA x planned batches); `world` ranks
/// are split so every head keeps at least one rank, with the spare ranks
/// apportioned by largest remainder over the cost weights (ties to the
/// lower head index). A pure function of its arguments — every rank replans
/// at an epoch boundary from identical inputs and must agree bit-for-bit on
/// the resulting mesh.
///
/// Heads with no measurement yet (cost `<= 0` or non-finite, e.g. the first
/// epoch) weigh zero; when NO head has a measurement the split is as even
/// as possible, matching the static mesh for a uniform bundle.
pub fn plan_head_groups(costs: &[f64], world: usize) -> anyhow::Result<Vec<usize>> {
    let n = costs.len();
    anyhow::ensure!(n >= 1, "elastic plan needs at least one head");
    anyhow::ensure!(
        world >= n,
        "world size {world} cannot give each of {n} heads a rank"
    );
    let sane: Vec<f64> = costs
        .iter()
        .map(|&c| if c.is_finite() && c > 0.0 { c } else { 0.0 })
        .collect();
    let total: f64 = sane.iter().sum();
    if total <= 0.0 {
        let (base, extra) = (world / n, world % n);
        return Ok((0..n).map(|h| base + usize::from(h < extra)).collect());
    }
    // Every head starts with one rank; the spare ranks follow the weights.
    let spare = (world - n) as f64;
    let quota: Vec<f64> = sane.iter().map(|&c| c / total * spare).collect();
    let mut sizes: Vec<usize> = quota.iter().map(|&q| 1 + q.floor() as usize).collect();
    let assigned: usize = sizes.iter().sum();
    let mut by_rem: Vec<(usize, f64)> = quota
        .iter()
        .enumerate()
        .map(|(h, &q)| (h, q - q.floor()))
        .collect();
    by_rem.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(&b.0))
    });
    // Fewer leftovers than heads by construction (fractional parts < 1).
    for &(h, _) in by_rem.iter().take(world - assigned) {
        sizes[h] += 1;
    }
    Ok(sizes)
}

/// [`plan_head_groups`] with a planned-steps fallback for unseeded heads.
///
/// The bare planner gives a head with no cost measurement yet (cost `<= 0`
/// or non-finite) weight 0.0, so in a PARTIALLY measured epoch — e.g. right
/// after a new head joins, or on resume when only some coverage rows carried
/// an EMA — the unseeded head is starved down to its 1-rank floor no matter
/// how much work it has planned. Here an unseeded head is instead imputed
/// the cost `mean measured cost per planned step x its planned steps`
/// (`planned[h]` is head `h`'s batch count for the coming epoch); when no
/// head is measured at all, that degenerates to pure planned-steps
/// weighting. Still a pure function of its arguments, so every rank replans
/// to the same mesh.
pub fn plan_head_groups_with_fallback(
    costs: &[f64],
    planned: &[usize],
    world: usize,
) -> anyhow::Result<Vec<usize>> {
    anyhow::ensure!(
        costs.len() == planned.len(),
        "cost vector ({}) and planned-steps vector ({}) disagree on head count",
        costs.len(),
        planned.len()
    );
    let seeded: Vec<Option<f64>> = costs
        .iter()
        .map(|&c| if c.is_finite() && c > 0.0 { Some(c) } else { None })
        .collect();
    // Scale that makes an imputed cost commensurate with the measured ones:
    // mean measured cost per planned step across the seeded heads.
    let (cost_sum, steps_sum) = seeded
        .iter()
        .zip(planned)
        .filter_map(|(c, &p)| c.map(|c| (c, p)))
        .fold((0.0f64, 0usize), |(cs, ps), (c, p)| (cs + c, ps + p));
    let per_step = if steps_sum > 0 { cost_sum / steps_sum as f64 } else { 1.0 };
    let imputed: Vec<f64> = seeded
        .iter()
        .zip(planned)
        .map(|(c, &p)| c.unwrap_or(per_step * p as f64))
        .collect();
    plan_head_groups(&imputed, world)
}

/// Early stopping on validation loss with a patience window.
#[derive(Debug, Clone)]
pub struct EarlyStopper {
    pub patience: usize,
    best: f64,
    bad_epochs: usize,
    /// Relative improvement below which an epoch counts as "no progress".
    pub min_delta: f64,
}

impl EarlyStopper {
    pub fn new(patience: usize) -> EarlyStopper {
        EarlyStopper { patience, best: f64::INFINITY, bad_epochs: 0, min_delta: 1e-4 }
    }

    /// Record a validation loss; returns true if training should stop.
    ///
    /// NaN losses (the trainer's sentinel for "no validation batches this
    /// epoch") are skipped entirely: they neither update `best` nor count
    /// against patience. Previously a NaN poisoned `best` —
    /// `!best.is_finite()` then held forever, so the bad-epoch counter was
    /// reset on every update and early stopping was silently disabled for
    /// the rest of the run. Infinite losses are NOT skipped: +inf is a
    /// real, measured divergence and counts as a bad epoch like any other
    /// non-improving value.
    pub fn update(&mut self, val_loss: f64) -> bool {
        if self.patience == 0 || val_loss.is_nan() {
            return false;
        }
        if val_loss < self.best * (1.0 - self.min_delta) {
            self.best = val_loss;
            self.bad_epochs = 0;
            false
        } else {
            self.bad_epochs += 1;
            self.bad_epochs >= self.patience
        }
    }

    pub fn best(&self) -> f64 {
        self.best
    }

    /// `(best, bad_epochs)` — persisted by the checkpoint subsystem.
    pub fn state(&self) -> (f64, usize) {
        (self.best, self.bad_epochs)
    }

    /// Rebuild a stopper mid-run (checkpoint resume): a resumed run makes
    /// the exact same stop decisions an uninterrupted one would. Built via
    /// [`EarlyStopper::new`] so the two construction paths share one
    /// `min_delta` and cannot drift.
    pub fn restore(patience: usize, best: f64, bad_epochs: usize) -> EarlyStopper {
        EarlyStopper { best, bad_epochs, ..EarlyStopper::new(patience) }
    }
}

/// Learning-rate schedule.
#[derive(Debug, Clone, Copy)]
pub enum LrSchedule {
    Constant(f64),
    /// Linear warmup to `peak` over `warmup` steps, cosine decay to
    /// `peak*floor_frac` at `total` steps.
    WarmupCosine { peak: f64, warmup: usize, total: usize, floor_frac: f64 },
}

impl LrSchedule {
    pub fn at(&self, step: usize) -> f64 {
        match *self {
            LrSchedule::Constant(lr) => lr,
            LrSchedule::WarmupCosine { peak, warmup, total, floor_frac } => {
                if step < warmup {
                    peak * (step + 1) as f64 / warmup as f64
                } else {
                    let t = ((step - warmup) as f64
                        / (total.saturating_sub(warmup)).max(1) as f64)
                        .min(1.0);
                    let floor = peak * floor_frac;
                    floor + 0.5 * (peak - floor) * (1.0 + (std::f64::consts::PI * t).cos())
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stops_after_patience_bad_epochs() {
        let mut es = EarlyStopper::new(2);
        assert!(!es.update(1.0));
        assert!(!es.update(0.5)); // improvement
        assert!(!es.update(0.6)); // bad 1
        assert!(es.update(0.55)); // bad 2 -> stop
        assert_eq!(es.best(), 0.5);
    }

    #[test]
    fn improvement_resets_counter() {
        let mut es = EarlyStopper::new(2);
        es.update(1.0);
        es.update(1.1); // bad 1
        assert!(!es.update(0.8)); // improvement resets
        assert!(!es.update(0.9)); // bad 1
        assert!(es.update(0.9)); // bad 2
    }

    #[test]
    fn nan_updates_are_skipped_not_poisonous() {
        let mut es = EarlyStopper::new(2);
        assert!(!es.update(1.0));
        // NaN neither improves, counts as bad, nor becomes the new best.
        assert!(!es.update(f64::NAN));
        assert_eq!(es.best(), 1.0, "NaN must not replace best");
        // The seed's bug: after a NaN, best stayed NaN and bad_epochs was
        // reset on every later update, so this sequence never stopped.
        assert!(!es.update(2.0)); // bad 1
        assert!(es.update(2.0), "must still stop after patience bad epochs");
    }

    #[test]
    fn infinite_loss_counts_as_bad_epoch() {
        // A diverged run (val_loss -> +inf) must still stop after patience:
        // inf is a real measured value, unlike the NaN no-val sentinel.
        let mut es = EarlyStopper::new(2);
        assert!(!es.update(1.0));
        assert!(!es.update(f64::INFINITY)); // bad 1
        assert!(es.update(f64::INFINITY), "divergence must trigger the stop");
        assert_eq!(es.best(), 1.0);
    }

    #[test]
    fn state_roundtrip_resumes_mid_window() {
        let mut es = EarlyStopper::new(3);
        es.update(1.0);
        es.update(1.5); // bad 1
        let (best, bad) = es.state();
        let mut resumed = EarlyStopper::restore(3, best, bad);
        // Both continue identically.
        assert_eq!(es.update(1.4), resumed.update(1.4)); // bad 2
        assert_eq!(es.update(1.4), resumed.update(1.4)); // bad 3 -> stop
        assert_eq!(es.best(), resumed.best());
    }

    #[test]
    fn zero_patience_never_stops() {
        let mut es = EarlyStopper::new(0);
        for _ in 0..100 {
            assert!(!es.update(5.0));
        }
    }

    #[test]
    fn warmup_cosine_shape() {
        let s = LrSchedule::WarmupCosine { peak: 1.0, warmup: 10, total: 110, floor_frac: 0.1 };
        assert!(s.at(0) < s.at(5));
        assert!((s.at(9) - 1.0).abs() < 0.11);
        assert!(s.at(60) < 1.0);
        assert!((s.at(1000) - 0.1).abs() < 1e-9, "floor: {}", s.at(1000));
    }

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::Constant(0.01);
        assert_eq!(s.at(0), 0.01);
        assert_eq!(s.at(9999), 0.01);
    }

    #[test]
    fn elastic_plan_shifts_ranks_toward_expensive_heads() {
        let sizes = plan_head_groups(&[9.0, 1.0], 10).unwrap();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes[0] > sizes[1], "9x cost head must get more ranks: {sizes:?}");
        assert!(sizes[1] >= 1);
        // Extreme skew still leaves every head at least one rank.
        assert_eq!(plan_head_groups(&[1000.0, 0.001, 0.001], 4).unwrap(), vec![2, 1, 1]);
    }

    #[test]
    fn elastic_plan_without_measurements_splits_evenly() {
        assert_eq!(plan_head_groups(&[0.0, 0.0], 5).unwrap(), vec![3, 2]);
        assert_eq!(plan_head_groups(&[f64::NAN, -1.0, 0.0], 6).unwrap(), vec![2, 2, 2]);
    }

    #[test]
    fn fallback_plan_does_not_starve_unseeded_heads() {
        // Regression: with the bare planner a partially measured cost vector
        // zero-weights the unseeded head, pinning it to the 1-rank floor.
        assert_eq!(plan_head_groups(&[f64::NAN, 4.0], 4).unwrap(), vec![1, 3]);
        // The fallback imputes it the seeded heads' per-step cost (4.0 / 10
        // per step x 10 planned = 4.0), so equal workloads split evenly.
        assert_eq!(
            plan_head_groups_with_fallback(&[f64::NAN, 4.0], &[10, 10], 4).unwrap(),
            vec![2, 2]
        );
        // An unseeded head with 3x the planned steps wins ranks accordingly.
        assert_eq!(
            plan_head_groups_with_fallback(&[0.0, 2.0], &[30, 10], 6).unwrap(),
            vec![4, 2]
        );
    }

    #[test]
    fn fallback_plan_weights_by_planned_steps_when_nothing_is_measured() {
        // No measurements at all: pure planned-steps weighting...
        assert_eq!(
            plan_head_groups_with_fallback(&[0.0, 0.0], &[9, 1], 10).unwrap(),
            vec![8, 2]
        );
        // ...which for equal workloads is the familiar even split.
        assert_eq!(
            plan_head_groups_with_fallback(&[0.0, 0.0], &[5, 5], 5).unwrap(),
            vec![3, 2]
        );
        assert_eq!(
            plan_head_groups_with_fallback(&[f64::NAN, -1.0, 0.0], &[4, 4, 4], 6).unwrap(),
            vec![2, 2, 2]
        );
        // Degenerate all-zero planned steps: falls through to the bare
        // planner's even split rather than dividing by zero.
        assert_eq!(
            plan_head_groups_with_fallback(&[0.0, 0.0], &[0, 0], 5).unwrap(),
            vec![3, 2]
        );
        // Fully measured vectors are untouched by the fallback.
        assert_eq!(
            plan_head_groups_with_fallback(&[9.0, 1.0], &[1, 99], 10).unwrap(),
            plan_head_groups(&[9.0, 1.0], 10).unwrap()
        );
        // Mismatched head counts are a hard error.
        assert!(plan_head_groups_with_fallback(&[1.0], &[1, 2], 3).is_err());
    }

    #[test]
    fn elastic_plan_is_total_and_minimal_worlds_work() {
        assert_eq!(plan_head_groups(&[5.0, 1.0, 1.0], 3).unwrap(), vec![1, 1, 1]);
        assert!(plan_head_groups(&[1.0, 1.0], 1).is_err(), "world < heads rejected");
        assert!(plan_head_groups(&[], 1).is_err());
        // Deterministic: identical inputs replan to identical sizes.
        let a = plan_head_groups(&[3.0, 2.0, 2.0, 1.0], 11).unwrap();
        let b = plan_head_groups(&[3.0, 2.0, 2.0, 1.0], 11).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.iter().sum::<usize>(), 11);
    }
}
