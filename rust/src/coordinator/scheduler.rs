//! Training schedule helpers: early stopping (paper Section 5.1: "early
//! stopping was applied to avoid redundant computations") and learning-rate
//! schedules.

/// Early stopping on validation loss with a patience window.
#[derive(Debug, Clone)]
pub struct EarlyStopper {
    pub patience: usize,
    best: f64,
    bad_epochs: usize,
    /// Relative improvement below which an epoch counts as "no progress".
    pub min_delta: f64,
}

impl EarlyStopper {
    pub fn new(patience: usize) -> EarlyStopper {
        EarlyStopper { patience, best: f64::INFINITY, bad_epochs: 0, min_delta: 1e-4 }
    }

    /// Record a validation loss; returns true if training should stop.
    ///
    /// NaN losses (the trainer's sentinel for "no validation batches this
    /// epoch") are skipped entirely: they neither update `best` nor count
    /// against patience. Previously a NaN poisoned `best` —
    /// `!best.is_finite()` then held forever, so the bad-epoch counter was
    /// reset on every update and early stopping was silently disabled for
    /// the rest of the run. Infinite losses are NOT skipped: +inf is a
    /// real, measured divergence and counts as a bad epoch like any other
    /// non-improving value.
    pub fn update(&mut self, val_loss: f64) -> bool {
        if self.patience == 0 || val_loss.is_nan() {
            return false;
        }
        if val_loss < self.best * (1.0 - self.min_delta) {
            self.best = val_loss;
            self.bad_epochs = 0;
            false
        } else {
            self.bad_epochs += 1;
            self.bad_epochs >= self.patience
        }
    }

    pub fn best(&self) -> f64 {
        self.best
    }

    /// `(best, bad_epochs)` — persisted by the checkpoint subsystem.
    pub fn state(&self) -> (f64, usize) {
        (self.best, self.bad_epochs)
    }

    /// Rebuild a stopper mid-run (checkpoint resume): a resumed run makes
    /// the exact same stop decisions an uninterrupted one would. Built via
    /// [`EarlyStopper::new`] so the two construction paths share one
    /// `min_delta` and cannot drift.
    pub fn restore(patience: usize, best: f64, bad_epochs: usize) -> EarlyStopper {
        EarlyStopper { best, bad_epochs, ..EarlyStopper::new(patience) }
    }
}

/// Learning-rate schedule.
#[derive(Debug, Clone, Copy)]
pub enum LrSchedule {
    Constant(f64),
    /// Linear warmup to `peak` over `warmup` steps, cosine decay to
    /// `peak*floor_frac` at `total` steps.
    WarmupCosine { peak: f64, warmup: usize, total: usize, floor_frac: f64 },
}

impl LrSchedule {
    pub fn at(&self, step: usize) -> f64 {
        match *self {
            LrSchedule::Constant(lr) => lr,
            LrSchedule::WarmupCosine { peak, warmup, total, floor_frac } => {
                if step < warmup {
                    peak * (step + 1) as f64 / warmup as f64
                } else {
                    let t = ((step - warmup) as f64
                        / (total.saturating_sub(warmup)).max(1) as f64)
                        .min(1.0);
                    let floor = peak * floor_frac;
                    floor + 0.5 * (peak - floor) * (1.0 + (std::f64::consts::PI * t).cos())
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stops_after_patience_bad_epochs() {
        let mut es = EarlyStopper::new(2);
        assert!(!es.update(1.0));
        assert!(!es.update(0.5)); // improvement
        assert!(!es.update(0.6)); // bad 1
        assert!(es.update(0.55)); // bad 2 -> stop
        assert_eq!(es.best(), 0.5);
    }

    #[test]
    fn improvement_resets_counter() {
        let mut es = EarlyStopper::new(2);
        es.update(1.0);
        es.update(1.1); // bad 1
        assert!(!es.update(0.8)); // improvement resets
        assert!(!es.update(0.9)); // bad 1
        assert!(es.update(0.9)); // bad 2
    }

    #[test]
    fn nan_updates_are_skipped_not_poisonous() {
        let mut es = EarlyStopper::new(2);
        assert!(!es.update(1.0));
        // NaN neither improves, counts as bad, nor becomes the new best.
        assert!(!es.update(f64::NAN));
        assert_eq!(es.best(), 1.0, "NaN must not replace best");
        // The seed's bug: after a NaN, best stayed NaN and bad_epochs was
        // reset on every later update, so this sequence never stopped.
        assert!(!es.update(2.0)); // bad 1
        assert!(es.update(2.0), "must still stop after patience bad epochs");
    }

    #[test]
    fn infinite_loss_counts_as_bad_epoch() {
        // A diverged run (val_loss -> +inf) must still stop after patience:
        // inf is a real measured value, unlike the NaN no-val sentinel.
        let mut es = EarlyStopper::new(2);
        assert!(!es.update(1.0));
        assert!(!es.update(f64::INFINITY)); // bad 1
        assert!(es.update(f64::INFINITY), "divergence must trigger the stop");
        assert_eq!(es.best(), 1.0);
    }

    #[test]
    fn state_roundtrip_resumes_mid_window() {
        let mut es = EarlyStopper::new(3);
        es.update(1.0);
        es.update(1.5); // bad 1
        let (best, bad) = es.state();
        let mut resumed = EarlyStopper::restore(3, best, bad);
        // Both continue identically.
        assert_eq!(es.update(1.4), resumed.update(1.4)); // bad 2
        assert_eq!(es.update(1.4), resumed.update(1.4)); // bad 3 -> stop
        assert_eq!(es.best(), resumed.best());
    }

    #[test]
    fn zero_patience_never_stops() {
        let mut es = EarlyStopper::new(0);
        for _ in 0..100 {
            assert!(!es.update(5.0));
        }
    }

    #[test]
    fn warmup_cosine_shape() {
        let s = LrSchedule::WarmupCosine { peak: 1.0, warmup: 10, total: 110, floor_frac: 0.1 };
        assert!(s.at(0) < s.at(5));
        assert!((s.at(9) - 1.0).abs() < 0.11);
        assert!(s.at(60) < 1.0);
        assert!((s.at(1000) - 0.1).abs() < 1e-9, "floor: {}", s.at(1000));
    }

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::Constant(0.01);
        assert_eq!(s.at(0), 0.01);
        assert_eq!(s.at(9999), 0.01);
    }
}
