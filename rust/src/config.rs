//! Run configuration: typed config structs with JSON load/save and presets
//! mirroring the paper's experimental setups (Section 5).

use std::path::Path;

use crate::data::structures::DatasetId;
use crate::runtime::backend::{BackendKind, Precision};
use crate::util::json::Json;

/// How the model is trained (the seven models of Tables 1-2 plus modes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrainMode {
    /// One dataset, one branch (the five `Model-<dataset>` baselines).
    Single(DatasetId),
    /// All datasets mixed through ONE shared branch (`GFM-Baseline-All`).
    BaselineAll,
    /// Two-level MTL, one branch per dataset, plain DDP (`MTL-base`):
    /// every rank holds all heads.
    MtlBase,
    /// Two-level MTL with multi-task parallelism (`MTL-par`): each rank
    /// holds the shared encoder + exactly one head; 2D mesh DDP.
    MtlPar,
}

impl TrainMode {
    pub fn name(&self) -> String {
        match self {
            TrainMode::Single(d) => format!("Model-{}", d.name()),
            TrainMode::BaselineAll => "GFM-Baseline-All".to_string(),
            TrainMode::MtlBase => "GFM-MTL-All (MTL-base)".to_string(),
            TrainMode::MtlPar => "GFM-MTL-All (MTL-par)".to_string(),
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<TrainMode> {
        match s.to_ascii_lowercase().as_str() {
            "baseline-all" | "baseline" => Ok(TrainMode::BaselineAll),
            "mtl-base" | "mtlbase" => Ok(TrainMode::MtlBase),
            "mtl-par" | "mtlpar" => Ok(TrainMode::MtlPar),
            other => DatasetId::from_name(other)
                .map(TrainMode::Single)
                .ok_or_else(|| anyhow::anyhow!("unknown train mode '{s}'")),
        }
    }
}

/// Data generation / loading settings.
#[derive(Debug, Clone)]
pub struct DataConfig {
    pub seed: u64,
    /// Samples generated per source dataset.
    pub per_dataset: usize,
    pub max_atoms: usize,
    /// Graph cutoff; must match the cutoff baked into the artifacts' RBF.
    pub cutoff: f64,
    pub train_frac: f64,
    pub val_frac: f64,
}

impl Default for DataConfig {
    fn default() -> Self {
        DataConfig {
            seed: 2025,
            per_dataset: 256,
            max_atoms: 24,
            cutoff: 6.0,
            train_frac: 0.8,
            val_frac: 0.1,
        }
    }
}

/// Optimizer / schedule settings (paper: AdamW, lr 1e-3, local batch 128).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub lr: f64,
    pub weight_decay: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub grad_clip: f64,
    pub epochs: usize,
    /// Early stopping patience in epochs (0 disables).
    pub patience: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            lr: 1e-3,
            weight_decay: 1e-4,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            grad_clip: 10.0,
            epochs: 10,
            patience: 3,
            seed: 7,
        }
    }
}

/// Mesh geometry for the parallel modes.
#[derive(Debug, Clone, Copy)]
pub struct ParallelConfig {
    /// Replicas per head sub-group (M in Figure 3). Head count comes from
    /// the number of datasets in play.
    pub replicas: usize,
    /// Overlap gradient communication with the backward pass: bucketed
    /// reductions stream on a per-rank comm thread as blocks complete
    /// (`comm::overlap`). BIT-identical to the synchronous path — a pure
    /// scheduling change — so it is excluded from the trajectory
    /// fingerprint. The `HYDRA_MTP_OVERLAP` env var overrides it at
    /// train time (see [`ParallelConfig::overlap_resolved`]).
    pub overlap: bool,
    /// Bucket payload bound in f32 elements for the overlapped path (>= 1).
    /// Smaller buckets overlap earlier but pay more per-round latency;
    /// reduced values are identical at any size.
    pub bucket_elems: usize,
    /// Elastic head scheduling for MTL-par: re-size each head's sub-group
    /// at epoch boundaries from its dataset's measured per-step cost
    /// (`Coverage::step_ms` EMA x planned batches). Changes which ranks
    /// average which head's gradients, hence the trajectory — fingerprinted.
    pub elastic: bool,
    /// Graph parallelism for single-branch modes: instead of replicating
    /// every structure on every rank (DDP), each structure's atoms are
    /// domain-decomposed into 8 spatial segments and ranks own contiguous
    /// segment ranges, exchanging boundary (halo) activations per EGNN
    /// block (`comm::halo`, `model::graphpar`). Changes the data path —
    /// every rank steps the SAME structure each step — hence the
    /// trajectory: fingerprinted. Requires `replicas` in {1, 2, 4, 8}.
    pub graph_par: bool,
}

impl Default for ParallelConfig {
    fn default() -> Self {
        ParallelConfig {
            replicas: 1,
            overlap: false,
            bucket_elems: 8192,
            elastic: false,
            graph_par: false,
        }
    }
}

impl ParallelConfig {
    /// Whether to run the overlapped reduction path: `HYDRA_MTP_OVERLAP`
    /// (when set non-empty: `1`/`true`/`on` enable, `0`/`false`/`off`
    /// disable, anything else warns and falls back to the config) overrides
    /// the configured flag — the CI matrix flips the whole suite this way.
    pub fn overlap_resolved(&self) -> bool {
        if let Ok(env) = std::env::var("HYDRA_MTP_OVERLAP") {
            let v = env.trim().to_ascii_lowercase();
            match v.as_str() {
                "" => {}
                "1" | "true" | "on" => return true,
                "0" | "false" | "off" => return false,
                other => {
                    eprintln!(
                        "warning: HYDRA_MTP_OVERLAP ignored: expected 1|true|on|0|false|off, \
                         got '{other}'"
                    );
                }
            }
        }
        self.overlap
    }
}

/// Fault tolerance: periodic checkpointing + resume (see `crate::checkpoint`).
#[derive(Debug, Clone)]
pub struct CheckpointConfig {
    /// Directory for per-epoch `epoch_*.ckpt` files (None disables saving).
    pub dir: Option<String>,
    /// Save every N epochs (>= 1). The final epoch and an early-stop epoch
    /// are always saved when `dir` is set, regardless of cadence.
    pub every: usize,
    /// Checkpoint file — or directory holding `epoch_*.ckpt` files, in
    /// which case the highest epoch wins — to resume training from.
    pub resume: Option<String>,
}

impl Default for CheckpointConfig {
    fn default() -> Self {
        CheckpointConfig { dir: None, every: 1, resume: None }
    }
}

/// Serving knobs for [`crate::serve::Server`] (`Session::server`). None of
/// these affect a training trajectory, so they are deliberately excluded
/// from [`RunConfig::trajectory_fingerprint`].
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Worker threads in the serving pool; `0` means "use the kernel
    /// thread cap" (`HYDRA_MTP_THREADS`, default 8).
    pub workers: usize,
    /// Maximum queued (not yet batched) requests before backpressure.
    pub queue_capacity: usize,
    /// How long a submit waits for queue space before failing with
    /// `Overloaded` (the bounded-backpressure contract).
    pub enqueue_wait_ms: u64,
    /// Latency budget the load-test bench reports against (p99 target).
    pub latency_budget_ms: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 0,
            queue_capacity: 256,
            enqueue_wait_ms: 100,
            latency_budget_ms: 250.0,
        }
    }
}

/// Chaos / recovery knobs (see `crate::fault` and
/// `Trainer::train_with_recovery`). Like [`ServeConfig`], none of these
/// affect a healthy training trajectory, so they are excluded from
/// [`RunConfig::trajectory_fingerprint`] — a recovered run must be able to
/// resume checkpoints written before the faults were configured.
#[derive(Debug, Clone)]
pub struct FaultConfig {
    /// Fault-injection spec (see `fault::FaultPlan::parse` for the
    /// grammar); `None` or empty means no injected faults. The
    /// `HYDRA_MTP_FAULTS` env var overrides this at plan build.
    pub spec: Option<String>,
    /// Restart attempts `train_with_recovery` makes after a rank failure
    /// (each rescanning the checkpoint dir for the latest CRC-valid file).
    pub max_restarts: usize,
    /// Collective timeout in milliseconds: a rank that stalls past this in
    /// a collective surfaces as `CommError::Timeout` instead of a hang.
    pub comm_timeout_ms: u64,
    /// Non-finite-loss batches a rank may skip per epoch before the run
    /// aborts anyway (a model that keeps producing NaN is not recoverable
    /// by skipping).
    pub skip_batch_budget: usize,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            spec: None,
            max_restarts: 2,
            comm_timeout_ms: 60_000,
            skip_batch_budget: 8,
        }
    }
}

impl FaultConfig {
    /// Build the fault plan: `HYDRA_MTP_FAULTS` (when set non-empty)
    /// overrides the configured spec; an absent spec yields the no-op plan.
    pub fn plan(&self) -> anyhow::Result<crate::fault::FaultPlan> {
        if let Ok(env) = std::env::var("HYDRA_MTP_FAULTS") {
            if !env.trim().is_empty() {
                return crate::fault::FaultPlan::parse(&env);
            }
        }
        match &self.spec {
            Some(s) => crate::fault::FaultPlan::parse(s),
            None => Ok(crate::fault::FaultPlan::none()),
        }
    }

    /// The collective timeout as a `Duration`.
    pub fn comm_timeout(&self) -> std::time::Duration {
        std::time::Duration::from_millis(self.comm_timeout_ms)
    }
}

#[derive(Debug, Clone)]
pub struct RunConfig {
    pub artifacts_dir: String,
    /// Execution backend: native (default everywhere), pjrt (AOT artifacts
    /// + `--features pjrt`), or auto (pjrt when available, else native).
    pub backend: BackendKind,
    /// Native-backend compute precision: `F64` (default, the gradcheck
    /// oracle) or `MixedF32` (blocked f32 kernels, f64 accumulation). The
    /// `HYDRA_MTP_PRECISION` env var overrides this at engine load; PJRT
    /// ignores it.
    pub precision: Precision,
    pub mode: TrainMode,
    pub data: DataConfig,
    pub train: TrainConfig,
    pub parallel: ParallelConfig,
    pub checkpoint: CheckpointConfig,
    pub serve: ServeConfig,
    pub fault: FaultConfig,
}

/// `RunConfig` leaves deliberately **excluded** from
/// [`RunConfig::trajectory_fingerprint_resolved`], each with the reason it
/// cannot change a training trajectory. hydra-lint rule R4 checks this
/// table against the struct: every leaf must be fingerprinted or listed
/// here (never both, never neither), so adding a field forces an explicit
/// trajectory-relevance decision instead of silently skipping the resume
/// guard — the manual-exclusion failure mode PR 6/7 worked around.
pub const FINGERPRINT_EXCLUDED: &[(&str, &str)] = &[
    ("artifacts_dir", "output location only; no effect on computed values"),
    ("train.epochs", "resume may extend a run; epochs are progress, not trajectory shape"),
    ("checkpoint.dir", "where snapshots land, not what they contain"),
    ("checkpoint.every", "snapshot cadence; the saved states themselves are unchanged"),
    ("checkpoint.resume", "names the snapshot being validated; cannot fingerprint itself"),
    ("serve.workers", "serving-only; inference never mutates trained state"),
    ("serve.queue_capacity", "serving-only admission bound"),
    ("serve.enqueue_wait_ms", "serving-only backpressure wait"),
    ("serve.latency_budget_ms", "serving-only reporting target"),
    ("fault.spec", "faults fire once; recovery restores the fault-free trajectory"),
    ("fault.max_restarts", "recovery attempt bound; resumes are bit-identical"),
    ("fault.comm_timeout_ms", "failure-detection deadline; healthy runs never hit it"),
    ("fault.skip_batch_budget", "abort bound; healthy runs never hit it"),
    (
        "parallel.overlap",
        "pure comm scheduling; overlapped reduction is bit-identical to sync by construction",
    ),
    (
        "parallel.bucket_elems",
        "bucket sizing only changes when elements reduce, never what they reduce to",
    ),
];

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts_dir: "artifacts".to_string(),
            backend: BackendKind::Auto,
            precision: Precision::F64,
            mode: TrainMode::MtlPar,
            data: DataConfig::default(),
            train: TrainConfig::default(),
            parallel: ParallelConfig::default(),
            checkpoint: CheckpointConfig::default(),
            serve: ServeConfig::default(),
            fault: FaultConfig::default(),
        }
    }
}

impl RunConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.train.lr > 0.0, "lr must be positive");
        anyhow::ensure!(self.train.epochs > 0, "epochs must be positive");
        anyhow::ensure!(self.parallel.replicas > 0, "replicas must be positive");
        anyhow::ensure!(
            self.parallel.bucket_elems >= 1,
            "parallel.bucket_elems must be >= 1 (got {})",
            self.parallel.bucket_elems
        );
        if self.parallel.graph_par {
            anyhow::ensure!(
                matches!(self.parallel.replicas, 1 | 2 | 4 | 8),
                "parallel.graph_par requires replicas in {{1, 2, 4, 8}} (the 8-segment \
                 domain decomposition must split evenly across ranks); got {}",
                self.parallel.replicas
            );
            anyhow::ensure!(
                matches!(self.mode, TrainMode::Single(_) | TrainMode::BaselineAll),
                "parallel.graph_par applies to the single-branch modes only \
                 (a dataset name or baseline-all); got mode '{}'",
                self.mode.name()
            );
        }
        anyhow::ensure!(self.data.per_dataset > 0, "per_dataset must be positive");
        anyhow::ensure!(
            self.data.train_frac + self.data.val_frac < 1.0 + 1e-12,
            "train+val fractions exceed 1"
        );
        anyhow::ensure!(
            self.checkpoint.every >= 1,
            "checkpoint.every must be >= 1 (got {})",
            self.checkpoint.every
        );
        anyhow::ensure!(
            self.serve.queue_capacity >= 1,
            "serve.queue_capacity must be >= 1 (got {})",
            self.serve.queue_capacity
        );
        anyhow::ensure!(
            self.serve.latency_budget_ms > 0.0,
            "serve.latency_budget_ms must be positive"
        );
        anyhow::ensure!(
            self.fault.comm_timeout_ms >= 1,
            "fault.comm_timeout_ms must be >= 1 (got {})",
            self.fault.comm_timeout_ms
        );
        if let Some(spec) = &self.fault.spec {
            // Fail at config time, not mid-run.
            crate::fault::FaultPlan::parse(spec)?;
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mode = match self.mode {
            TrainMode::Single(d) => d.name(),
            TrainMode::BaselineAll => "baseline-all".to_string(),
            TrainMode::MtlBase => "mtl-base".to_string(),
            TrainMode::MtlPar => "mtl-par".to_string(),
        };
        Json::obj(vec![
            ("artifacts_dir", Json::str(self.artifacts_dir.clone())),
            ("backend", Json::str(self.backend.name())),
            ("precision", Json::str(self.precision.name())),
            ("mode", Json::str(mode)),
            (
                "data",
                Json::obj(vec![
                    ("seed", Json::from(self.data.seed as i64)),
                    ("per_dataset", Json::from(self.data.per_dataset)),
                    ("max_atoms", Json::from(self.data.max_atoms)),
                    ("cutoff", Json::from(self.data.cutoff)),
                    ("train_frac", Json::from(self.data.train_frac)),
                    ("val_frac", Json::from(self.data.val_frac)),
                ]),
            ),
            (
                "train",
                Json::obj(vec![
                    ("lr", Json::from(self.train.lr)),
                    ("weight_decay", Json::from(self.train.weight_decay)),
                    ("beta1", Json::from(self.train.beta1)),
                    ("beta2", Json::from(self.train.beta2)),
                    ("eps", Json::from(self.train.eps)),
                    ("grad_clip", Json::from(self.train.grad_clip)),
                    ("epochs", Json::from(self.train.epochs)),
                    ("patience", Json::from(self.train.patience)),
                    ("seed", Json::from(self.train.seed as i64)),
                ]),
            ),
            (
                "parallel",
                Json::obj(vec![
                    ("replicas", Json::from(self.parallel.replicas)),
                    ("overlap", Json::from(self.parallel.overlap)),
                    ("bucket_elems", Json::from(self.parallel.bucket_elems)),
                    ("elastic", Json::from(self.parallel.elastic)),
                    ("graph_par", Json::from(self.parallel.graph_par)),
                ]),
            ),
            (
                "checkpoint",
                Json::obj(vec![
                    (
                        "dir",
                        match &self.checkpoint.dir {
                            Some(d) => Json::str(d.clone()),
                            None => Json::Null,
                        },
                    ),
                    ("every", Json::from(self.checkpoint.every)),
                    (
                        "resume",
                        match &self.checkpoint.resume {
                            Some(r) => Json::str(r.clone()),
                            None => Json::Null,
                        },
                    ),
                ]),
            ),
            (
                "serve",
                Json::obj(vec![
                    ("workers", Json::from(self.serve.workers)),
                    ("queue_capacity", Json::from(self.serve.queue_capacity)),
                    ("enqueue_wait_ms", Json::from(self.serve.enqueue_wait_ms as i64)),
                    ("latency_budget_ms", Json::from(self.serve.latency_budget_ms)),
                ]),
            ),
            (
                "fault",
                Json::obj(vec![
                    (
                        "spec",
                        match &self.fault.spec {
                            Some(s) => Json::str(s.clone()),
                            None => Json::Null,
                        },
                    ),
                    ("max_restarts", Json::from(self.fault.max_restarts)),
                    ("comm_timeout_ms", Json::from(self.fault.comm_timeout_ms as i64)),
                    ("skip_batch_budget", Json::from(self.fault.skip_batch_budget)),
                ]),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<RunConfig> {
        let mut cfg = RunConfig::default();
        if let Some(s) = j.get("artifacts_dir").as_str() {
            cfg.artifacts_dir = s.to_string();
        }
        if let Some(s) = j.get("backend").as_str() {
            cfg.backend = BackendKind::parse(s)?;
        }
        if let Some(s) = j.get("precision").as_str() {
            cfg.precision = Precision::parse(s)?;
        }
        if let Some(s) = j.get("mode").as_str() {
            cfg.mode = TrainMode::parse(s)?;
        }
        let d = j.get("data");
        if let Some(v) = d.get("seed").as_i64() {
            cfg.data.seed = v as u64;
        }
        if let Some(v) = d.get("per_dataset").as_i64() {
            cfg.data.per_dataset = v as usize;
        }
        if let Some(v) = d.get("max_atoms").as_i64() {
            cfg.data.max_atoms = v as usize;
        }
        if let Some(v) = d.get("cutoff").as_f64() {
            cfg.data.cutoff = v;
        }
        if let Some(v) = d.get("train_frac").as_f64() {
            cfg.data.train_frac = v;
        }
        if let Some(v) = d.get("val_frac").as_f64() {
            cfg.data.val_frac = v;
        }
        let t = j.get("train");
        if let Some(v) = t.get("lr").as_f64() {
            cfg.train.lr = v;
        }
        if let Some(v) = t.get("weight_decay").as_f64() {
            cfg.train.weight_decay = v;
        }
        if let Some(v) = t.get("beta1").as_f64() {
            cfg.train.beta1 = v;
        }
        if let Some(v) = t.get("beta2").as_f64() {
            cfg.train.beta2 = v;
        }
        if let Some(v) = t.get("eps").as_f64() {
            cfg.train.eps = v;
        }
        if let Some(v) = t.get("grad_clip").as_f64() {
            cfg.train.grad_clip = v;
        }
        if let Some(v) = t.get("epochs").as_i64() {
            cfg.train.epochs = v as usize;
        }
        if let Some(v) = t.get("patience").as_i64() {
            cfg.train.patience = v as usize;
        }
        if let Some(v) = t.get("seed").as_i64() {
            cfg.train.seed = v as u64;
        }
        let p = j.get("parallel");
        if let Some(v) = p.get("replicas").as_i64() {
            cfg.parallel.replicas = v as usize;
        }
        if let Some(v) = p.get("overlap").as_bool() {
            cfg.parallel.overlap = v;
        }
        if let Some(v) = p.get("bucket_elems").as_i64() {
            cfg.parallel.bucket_elems = v as usize;
        }
        if let Some(v) = p.get("elastic").as_bool() {
            cfg.parallel.elastic = v;
        }
        if let Some(v) = p.get("graph_par").as_bool() {
            cfg.parallel.graph_par = v;
        }
        let c = j.get("checkpoint");
        if let Some(s) = c.get("dir").as_str() {
            cfg.checkpoint.dir = Some(s.to_string());
        }
        if let Some(v) = c.get("every").as_i64() {
            cfg.checkpoint.every = v as usize;
        }
        if let Some(s) = c.get("resume").as_str() {
            cfg.checkpoint.resume = Some(s.to_string());
        }
        let s = j.get("serve");
        if let Some(v) = s.get("workers").as_i64() {
            cfg.serve.workers = v as usize;
        }
        if let Some(v) = s.get("queue_capacity").as_i64() {
            cfg.serve.queue_capacity = v as usize;
        }
        if let Some(v) = s.get("enqueue_wait_ms").as_i64() {
            cfg.serve.enqueue_wait_ms = v as u64;
        }
        if let Some(v) = s.get("latency_budget_ms").as_f64() {
            cfg.serve.latency_budget_ms = v;
        }
        let f = j.get("fault");
        if let Some(s) = f.get("spec").as_str() {
            cfg.fault.spec = Some(s.to_string());
        }
        if let Some(v) = f.get("max_restarts").as_i64() {
            cfg.fault.max_restarts = v as usize;
        }
        if let Some(v) = f.get("comm_timeout_ms").as_i64() {
            cfg.fault.comm_timeout_ms = v as u64;
        }
        if let Some(v) = f.get("skip_batch_budget").as_i64() {
            cfg.fault.skip_batch_budget = v as usize;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Canonical string over every trajectory-determining knob (mode, both
    /// seeds, data sizes/splits, optimizer hyper-parameters, patience,
    /// replicas). Two runs with equal fingerprints replay the same
    /// trajectory epoch-for-epoch; the checkpoint subsystem refuses to
    /// resume across differing fingerprints, because mode + seed alone
    /// would let e.g. a changed `--replicas` or `--lr` silently diverge
    /// from the run that wrote the file. `epochs` is deliberately
    /// excluded — extending a finished run IS the resume use case — as are
    /// the artifacts dir and the checkpoint paths themselves. Floats are
    /// rendered by bit pattern so the comparison is exact. The backend and
    /// the compute precision are included: native/PJRT and f64/mixed-f32
    /// numerics differ, so resuming a run on a different backend OR at a
    /// different precision must be refused, not silently diverge. This
    /// variant records the *configured* kind and precision; the trainer
    /// fingerprints checkpoints with [`Self::trajectory_fingerprint_resolved`]
    /// and the engine's actual backend + precision, so `auto` (or a
    /// `HYDRA_MTP_PRECISION` override) resolving differently on the
    /// writing and resuming machines is still caught.
    pub fn trajectory_fingerprint(&self) -> String {
        self.trajectory_fingerprint_resolved(self.backend.name(), self.precision.name())
    }

    /// [`Self::trajectory_fingerprint`] with explicit backend + precision
    /// tokens — pass the RESOLVED values (`engine.backend_name()`,
    /// `engine.precision().name()`) when writing or validating checkpoints.
    pub fn trajectory_fingerprint_resolved(&self, backend: &str, precision: &str) -> String {
        let f = |x: f64| format!("{:016x}", x.to_bits());
        format!(
            "backend={};precision={};mode={};train_seed={};data_seed={};per_dataset={};max_atoms={};\
             cutoff={};train_frac={};val_frac={};lr={};weight_decay={};beta1={};\
             beta2={};eps={};grad_clip={};patience={};replicas={};elastic={};graph_par={}",
            backend,
            precision,
            self.mode.name(),
            self.train.seed,
            self.data.seed,
            self.data.per_dataset,
            self.data.max_atoms,
            f(self.data.cutoff),
            f(self.data.train_frac),
            f(self.data.val_frac),
            f(self.train.lr),
            f(self.train.weight_decay),
            f(self.train.beta1),
            f(self.train.beta2),
            f(self.train.eps),
            f(self.train.grad_clip),
            self.train.patience,
            self.parallel.replicas,
            self.parallel.elastic,
            self.parallel.graph_par,
        )
    }

    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<RunConfig> {
        let text = std::fs::read_to_string(path)?;
        RunConfig::from_json(&Json::parse(&text)?)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let mut cfg = RunConfig::default();
        cfg.mode = TrainMode::Single(DatasetId::MpTrj);
        cfg.backend = BackendKind::Native;
        cfg.precision = Precision::MixedF32;
        cfg.train.lr = 0.005;
        cfg.parallel.replicas = 4;
        cfg.parallel.overlap = true;
        cfg.parallel.bucket_elems = 1024;
        cfg.parallel.elastic = true;
        cfg.parallel.graph_par = true;
        cfg.checkpoint.dir = Some("ckpts".to_string());
        cfg.checkpoint.every = 3;
        cfg.serve.workers = 2;
        cfg.serve.queue_capacity = 32;
        cfg.serve.enqueue_wait_ms = 17;
        cfg.serve.latency_budget_ms = 75.0;
        cfg.fault.spec = Some("nonfinite@epoch=1,batch=0".to_string());
        cfg.fault.max_restarts = 5;
        cfg.fault.comm_timeout_ms = 2500;
        cfg.fault.skip_batch_budget = 3;
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.mode, cfg.mode);
        assert_eq!(back.backend, BackendKind::Native);
        assert_eq!(back.precision, Precision::MixedF32);
        assert_eq!(back.train.lr, 0.005);
        assert_eq!(back.parallel.replicas, 4);
        assert!(back.parallel.overlap);
        assert_eq!(back.parallel.bucket_elems, 1024);
        assert!(back.parallel.elastic);
        assert!(back.parallel.graph_par);
        assert_eq!(back.checkpoint.dir.as_deref(), Some("ckpts"));
        assert_eq!(back.checkpoint.every, 3);
        assert!(back.checkpoint.resume.is_none());
        assert_eq!(back.serve.workers, 2);
        assert_eq!(back.serve.queue_capacity, 32);
        assert_eq!(back.serve.enqueue_wait_ms, 17);
        assert_eq!(back.serve.latency_budget_ms, 75.0);
        assert_eq!(back.fault.spec.as_deref(), Some("nonfinite@epoch=1,batch=0"));
        assert_eq!(back.fault.max_restarts, 5);
        assert_eq!(back.fault.comm_timeout_ms, 2500);
        assert_eq!(back.fault.skip_batch_budget, 3);
    }

    #[test]
    fn trajectory_fingerprint_tracks_trajectory_knobs_only() {
        let a = RunConfig::default();
        let mut b = RunConfig::default();
        // Non-trajectory knobs: fingerprint unchanged.
        b.train.epochs += 5;
        b.artifacts_dir = "elsewhere".into();
        b.checkpoint.dir = Some("ckpts".into());
        b.serve.workers = 3;
        b.serve.queue_capacity = 7;
        b.fault.spec = Some("rank-panic@rank=0,epoch=1,step=0".into());
        b.fault.max_restarts = 9;
        b.fault.comm_timeout_ms = 123;
        b.fault.skip_batch_budget = 99;
        // Overlapped reduction is bit-identical to sync, and bucket sizing
        // only reschedules it — neither may invalidate a resume.
        b.parallel.overlap = true;
        b.parallel.bucket_elems = 17;
        assert_eq!(a.trajectory_fingerprint(), b.trajectory_fingerprint());
        // Every trajectory knob changes it.
        for mutate in [
            (|c: &mut RunConfig| c.parallel.replicas = 4) as fn(&mut RunConfig),
            |c| c.train.lr = 2e-3,
            |c| c.train.seed = 8,
            |c| c.data.per_dataset = 13,
            |c| c.mode = TrainMode::MtlBase,
            |c| c.train.patience = 9,
            |c| c.backend = BackendKind::Native,
            |c| c.precision = Precision::MixedF32,
            |c| c.parallel.elastic = true,
            |c| c.parallel.graph_par = true,
        ] {
            let mut c = RunConfig::default();
            mutate(&mut c);
            assert_ne!(
                a.trajectory_fingerprint(),
                c.trajectory_fingerprint(),
                "trajectory knob change must change the fingerprint"
            );
        }
    }

    #[test]
    fn resolved_fingerprint_names_backend_and_precision() {
        // The resume-refusal error prints both fingerprints, so these
        // tokens are what names the writer's and the resumer's precision
        // (asserted end-to-end in rust/tests/integration_precision.rs).
        let cfg = RunConfig::default();
        let fp = cfg.trajectory_fingerprint_resolved("native", "mixed-f32");
        assert!(fp.starts_with("backend=native;precision=mixed-f32;"), "{fp}");
        assert_ne!(
            fp,
            cfg.trajectory_fingerprint_resolved("native", "f64"),
            "precision must be a trajectory knob"
        );
    }

    #[test]
    fn checkpoint_every_zero_is_rejected() {
        let mut cfg = RunConfig::default();
        cfg.checkpoint.every = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn mode_parsing() {
        assert_eq!(TrainMode::parse("mtl-par").unwrap(), TrainMode::MtlPar);
        assert_eq!(TrainMode::parse("baseline-all").unwrap(), TrainMode::BaselineAll);
        assert_eq!(
            TrainMode::parse("ANI1x").unwrap(),
            TrainMode::Single(DatasetId::Ani1x)
        );
        assert!(TrainMode::parse("bogus").is_err());
    }

    #[test]
    fn validation_rejects_nonsense() {
        let mut cfg = RunConfig::default();
        cfg.train.lr = -1.0;
        assert!(cfg.validate().is_err());
        let mut cfg = RunConfig::default();
        cfg.parallel.replicas = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = RunConfig::default();
        cfg.parallel.bucket_elems = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = RunConfig::default();
        cfg.serve.queue_capacity = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = RunConfig::default();
        cfg.serve.latency_budget_ms = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = RunConfig::default();
        cfg.fault.comm_timeout_ms = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = RunConfig::default();
        cfg.fault.spec = Some("bogus-fault@x=1".into());
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn graph_par_validation() {
        // Accepted: single-branch mode with a world that divides 8 segments.
        let mut cfg = RunConfig::default();
        cfg.mode = TrainMode::Single(DatasetId::MpTrj);
        cfg.parallel.graph_par = true;
        for replicas in [1, 2, 4, 8] {
            cfg.parallel.replicas = replicas;
            assert!(cfg.validate().is_ok(), "replicas={replicas}");
        }
        // Rejected: worlds that cannot split 8 contiguous segments evenly.
        for replicas in [3, 5, 6, 7, 16] {
            cfg.parallel.replicas = replicas;
            assert!(cfg.validate().is_err(), "replicas={replicas}");
        }
        // Rejected: multi-head modes (graph-par is a single-branch data path).
        cfg.parallel.replicas = 2;
        for mode in [TrainMode::MtlBase, TrainMode::MtlPar] {
            cfg.mode = mode;
            assert!(cfg.validate().is_err(), "mode={}", cfg.mode.name());
        }
        cfg.mode = TrainMode::BaselineAll;
        assert!(cfg.validate().is_ok(), "baseline-all is single-branch");
    }

    #[test]
    fn file_roundtrip() {
        let path = std::env::temp_dir()
            .join(format!("hydra_mtp_cfg_{}.json", std::process::id()));
        let cfg = RunConfig::default();
        cfg.save(&path).unwrap();
        let back = RunConfig::load(&path).unwrap();
        assert_eq!(back.mode, cfg.mode);
        std::fs::remove_file(path).ok();
    }
}
