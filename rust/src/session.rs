//! The `Session` facade: one entry point for the full lifecycle.
//!
//! The seed's public API forced callers through a six-step manual dance
//! (`Engine::load` -> `RunConfig` -> `DataBundle::generate` ->
//! `Trainer::new(..).train(..)` -> `evaluate_model` -> hand-rolled
//! `BatchBuilder` / `full_params` / `engine.forward` for inference). A
//! [`Session`] owns `Engine + TaskRegistry + RunConfig` and exposes that
//! lifecycle as `generate_data()` / `train()` / `evaluate()` /
//! `predictor()`; [`Predictor`] is the batched-inference entry point that
//! routes each structure to the correct MTL head, packs/pads into the
//! compiled batch dims, and returns typed [`Prediction`] values — the crate's
//! serving story.
//!
//! Every method is deterministic given the config: `Session` reproduces the
//! manual call-chain bit-for-bit (see `rust/tests/integration_session.rs`).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::config::{RunConfig, ServeConfig, TrainMode};
use crate::coordinator::evaluate::evaluate_model;
use crate::coordinator::trainer::{DataBundle, TrainOutcome, TrainedModel, Trainer};
use crate::data::batch::{BatchDims, GraphBatch};
use crate::data::graph::radius_graph;
use crate::data::structures::{AtomicStructure, DatasetId};
use crate::runtime::Engine;
use crate::serve::prepared::{PreparedModel, Workspace, DEFAULT_HEAD_CAP};
use crate::tasks::TaskRegistry;

// ---------------------------------------------------------------------------
// builder
// ---------------------------------------------------------------------------

/// Builder for [`Session`]. Field setters mirror the `RunConfig` knobs the
/// CLI exposes; `config()` replaces the whole config for full control.
#[derive(Default)]
pub struct SessionBuilder {
    config: RunConfig,
    engine: Option<Arc<Engine>>,
    tasks: Option<Vec<DatasetId>>,
}

impl SessionBuilder {
    /// Directory holding the AOT artifacts (`manifest.json`, `*.hlo.txt`).
    /// Only consulted by the pjrt/auto backends; the native backend runs
    /// without it (it synthesizes the manifest when the directory is
    /// absent, or adopts its dims when present).
    pub fn artifacts(mut self, dir: impl Into<String>) -> Self {
        self.config.artifacts_dir = dir.into();
        self
    }

    /// Execution backend (native / pjrt / auto). Default: auto — PJRT when
    /// compiled + artifacts exist, the native pure-rust engine otherwise.
    pub fn backend(mut self, kind: crate::runtime::BackendKind) -> Self {
        self.config.backend = kind;
        self
    }

    /// Native-backend compute precision: `F64` (default; the gradcheck
    /// oracle) or `MixedF32` (blocked f32 microkernels with f64
    /// accumulation — faster, bounded against the oracle in
    /// `rust/tests/gradcheck.rs`). Ignored by PJRT. The
    /// `HYDRA_MTP_PRECISION` env var overrides this at engine load, and
    /// the resolved value is part of the checkpoint trajectory
    /// fingerprint, so resuming across precisions is refused.
    pub fn precision(mut self, precision: crate::runtime::Precision) -> Self {
        self.config.precision = precision;
        self
    }

    /// Training mode (one of the paper's seven models / modes).
    pub fn mode(mut self, mode: TrainMode) -> Self {
        self.config.mode = mode;
        self
    }

    /// Replicas per head sub-group (M in the paper's Figure 3).
    pub fn replicas(mut self, replicas: usize) -> Self {
        self.config.parallel.replicas = replicas;
        self
    }

    /// Overlapped bucketed gradient reduction: reduce gradient buckets on a
    /// per-rank comm thread while backward still runs (see
    /// [`crate::comm::overlap`]). Bit-identical to the synchronous path;
    /// the `HYDRA_MTP_OVERLAP` env var overrides this at run time.
    pub fn overlap(mut self, on: bool) -> Self {
        self.config.parallel.overlap = on;
        self
    }

    /// Gradient bucket size in f32 elements for the overlapped path
    /// (excluded from the trajectory fingerprint — it never changes the
    /// reduced values, only when they are reduced).
    pub fn bucket_elems(mut self, elems: usize) -> Self {
        self.config.parallel.bucket_elems = elems;
        self
    }

    /// Elastic head scheduling for MTL-par: size each head's sub-group from
    /// its dataset's measured per-step cost (the [`Coverage::step_ms`] EMA),
    /// re-planned at epoch boundaries. The mesh is static within an epoch.
    ///
    /// [`Coverage::step_ms`]: crate::coordinator::metrics::Coverage
    pub fn elastic(mut self, on: bool) -> Self {
        self.config.parallel.elastic = on;
        self
    }

    /// Graph-parallel training for single-branch modes: domain-decompose
    /// each structure's atoms across ranks with per-EGNN-block halo
    /// exchange (`crate::comm::halo`, `crate::model::graphpar`) instead of
    /// replicating whole graphs. Requires `replicas` in {1, 2, 4, 8};
    /// results are bit-identical to the single-rank run at every world.
    pub fn graph_par(mut self, on: bool) -> Self {
        self.config.parallel.graph_par = on;
        self
    }

    pub fn epochs(mut self, epochs: usize) -> Self {
        self.config.train.epochs = epochs;
        self
    }

    pub fn patience(mut self, patience: usize) -> Self {
        self.config.train.patience = patience;
        self
    }

    pub fn lr(mut self, lr: f64) -> Self {
        self.config.train.lr = lr;
        self
    }

    /// Samples generated per task.
    pub fn per_dataset(mut self, n: usize) -> Self {
        self.config.data.per_dataset = n;
        self
    }

    pub fn max_atoms(mut self, n: usize) -> Self {
        self.config.data.max_atoms = n;
        self
    }

    /// Data-generation seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.config.data.seed = seed;
        self
    }

    /// Directory for per-epoch checkpoints (rank 0 writes
    /// `DIR/epoch_NNNN.ckpt`; see `crate::checkpoint`).
    pub fn checkpoint_dir(mut self, dir: impl Into<String>) -> Self {
        self.config.checkpoint.dir = Some(dir.into());
        self
    }

    /// Checkpoint cadence in epochs (the final / early-stop epoch is
    /// always saved).
    pub fn checkpoint_every(mut self, every: usize) -> Self {
        self.config.checkpoint.every = every;
        self
    }

    /// Resume training from a checkpoint file or directory (see
    /// [`Session::resume`] for the one-shot equivalent).
    pub fn resume_from(mut self, path: impl Into<String>) -> Self {
        self.config.checkpoint.resume = Some(path.into());
        self
    }

    /// Replace the entire run config (setters applied afterwards still win).
    pub fn config(mut self, config: RunConfig) -> Self {
        self.config = config;
        self
    }

    /// Reuse an already-loaded engine instead of loading
    /// `config.artifacts_dir` (artifact compilation is the slow part; tests
    /// and multi-run experiments share one engine this way).
    pub fn engine(mut self, engine: Arc<Engine>) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Explicit task list. Defaults to the mode's dataset for
    /// `TrainMode::Single` and the registry's five built-ins otherwise;
    /// pass more handles (e.g. a registered sixth task) to widen the run —
    /// under `mtl-par` the mesh grows one head sub-group per task.
    pub fn tasks(mut self, tasks: &[DatasetId]) -> Self {
        self.tasks = Some(tasks.to_vec());
        self
    }

    /// Validate the config, load (or adopt) the engine and resolve the task
    /// list.
    pub fn build(self) -> anyhow::Result<Session> {
        let SessionBuilder { config, engine, tasks } = self;
        config.validate()?;
        let registry = TaskRegistry::global();
        let tasks = match tasks {
            Some(t) => {
                anyhow::ensure!(!t.is_empty(), "session task list must be non-empty");
                for &d in &t {
                    anyhow::ensure!(
                        registry.try_spec(d).is_some(),
                        "task index {} is not registered",
                        d.index()
                    );
                }
                if let TrainMode::Single(d) = config.mode {
                    anyhow::ensure!(
                        t.contains(&d),
                        "mode Model-{} but task list omits it",
                        d.name()
                    );
                }
                t
            }
            None => match config.mode {
                TrainMode::Single(d) => vec![d],
                _ => registry.builtin().to_vec(),
            },
        };
        let engine = match engine {
            Some(e) => e,
            None => Arc::new(Engine::load_full(
                &config.artifacts_dir,
                config.backend,
                config.precision.resolve(),
            )?),
        };
        Ok(Session { engine, registry, config, tasks, data: None })
    }
}

// ---------------------------------------------------------------------------
// session
// ---------------------------------------------------------------------------

/// Owns `Engine + TaskRegistry + RunConfig` and exposes the full
/// generate / train / evaluate / predict lifecycle. See the crate docs and
/// `examples/quickstart.rs`.
pub struct Session {
    engine: Arc<Engine>,
    registry: TaskRegistry,
    config: RunConfig,
    tasks: Vec<DatasetId>,
    data: Option<DataBundle>,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    pub fn config(&self) -> &RunConfig {
        &self.config
    }

    pub fn registry(&self) -> &TaskRegistry {
        &self.registry
    }

    /// Tasks this session generates/trains over, in head order.
    pub fn tasks(&self) -> &[DatasetId] {
        &self.tasks
    }

    /// Generate (once) and return the session's data bundle. Deterministic
    /// in `config.data` and the task list.
    pub fn generate_data(&mut self) -> &DataBundle {
        if self.data.is_none() {
            self.data = Some(DataBundle::generate(&self.config.data, &self.tasks));
        }
        self.data.as_ref().unwrap()
    }

    /// The bundle, if already generated.
    pub fn data(&self) -> Option<&DataBundle> {
        self.data.as_ref()
    }

    /// Train the configured mode on the session's data (generated lazily).
    pub fn train(&mut self) -> anyhow::Result<TrainOutcome> {
        self.generate_data();
        let data = self.data.as_ref().unwrap();
        Trainer::new(Arc::clone(&self.engine), self.config.clone()).train(data)
    }

    /// Train on an external bundle (multi-run experiments share one bundle
    /// across modes this way; `experiments::run_tables` uses it).
    pub fn train_on(&self, data: &DataBundle) -> anyhow::Result<TrainOutcome> {
        Trainer::new(Arc::clone(&self.engine), self.config.clone()).train(data)
    }

    /// [`Session::train`] under rank-failure supervision: a run that dies
    /// with a typed communication error restarts from the latest CRC-valid
    /// checkpoint in `config.checkpoint.dir`, up to
    /// `config.fault.max_restarts` times (see
    /// `Trainer::train_with_recovery`). The CLI's `hydra-mtp train` routes
    /// through this, so an injected or real rank failure self-heals.
    pub fn train_with_recovery(&mut self) -> anyhow::Result<TrainOutcome> {
        self.generate_data();
        let data = self.data.as_ref().unwrap();
        Trainer::new(Arc::clone(&self.engine), self.config.clone())
            .train_with_recovery(data)
    }

    /// Resume an interrupted run from `path` — a checkpoint file, or a
    /// directory of `epoch_*.ckpt` files (highest epoch wins). Restores
    /// parameters, optimizer moments, the metrics log, and the
    /// early-stopper cursor; the resumed run is bit-identical to an
    /// uninterrupted one (see `rust/tests/integration_checkpoint.rs`).
    pub fn resume(&mut self, path: impl Into<String>) -> anyhow::Result<TrainOutcome> {
        let prev = self.config.checkpoint.resume.replace(path.into());
        let out = self.train();
        self.config.checkpoint.resume = prev;
        out
    }

    /// Persist a trained model (encoder + heads) as a CRC-guarded
    /// checkpoint file; load it back with [`Session::load_model`].
    pub fn save_model(
        &self,
        model: &TrainedModel,
        path: impl AsRef<std::path::Path>,
    ) -> anyhow::Result<()> {
        crate::checkpoint::save_model(model, path)
    }

    /// Load a model saved with [`Session::save_model`] (an associated
    /// function: no engine or session needed — useful for offline
    /// inspection; pair with an engine-holding session for serving).
    pub fn load_model(path: impl AsRef<std::path::Path>) -> anyhow::Result<TrainedModel> {
        crate::checkpoint::load_model(path)
    }

    /// Warm-start fine-tuning: adopt `base`'s pre-trained encoder, freeze
    /// it, and train ONLY a new head for `task` on that task's generated
    /// data (config-driven: epochs/lr/replicas come from this session).
    /// `task` must be registered — typically a custom task added via
    /// `TaskRegistry::global().register(..)` after pre-training on the
    /// presets. Returns a model whose single per-dataset head serves
    /// `task` through [`Session::predictor`].
    pub fn fine_tune(
        &self,
        base: &TrainedModel,
        task: DatasetId,
    ) -> anyhow::Result<TrainOutcome> {
        anyhow::ensure!(
            self.registry.try_spec(task).is_some(),
            "task index {} is not registered",
            task.index()
        );
        let data = DataBundle::generate(&self.config.data, &[task]);
        Trainer::new(Arc::clone(&self.engine), self.config.clone())
            .fine_tune_head(&data, &base.encoder, task)
    }

    /// Per-task (energy MAE, force MAE) on the held-out test split.
    pub fn evaluate(
        &mut self,
        model: &TrainedModel,
    ) -> anyhow::Result<BTreeMap<DatasetId, (f64, f64)>> {
        self.generate_data();
        evaluate_model(&self.engine, model, &self.data.as_ref().unwrap().test)
    }

    /// Batched-inference entry point over the trained model.
    pub fn predictor(&self, model: &TrainedModel) -> Predictor {
        Predictor::new(Arc::clone(&self.engine), model.clone())
    }

    /// Start an always-on batched-inference server over `model`: a
    /// persistent worker pool behind a coalescing request queue, tuned by
    /// `config.serve` (see [`crate::serve`] for the protocol and
    /// guarantees). Concurrent single-structure requests coalesce into
    /// shared padded batches with outputs bit-identical to sequential
    /// [`Predictor::predict_one`] calls.
    pub fn server(&self, model: &TrainedModel) -> anyhow::Result<crate::serve::Server> {
        self.server_with(model, self.config.serve)
    }

    /// As [`Session::server`] with explicit serving knobs.
    pub fn server_with(
        &self,
        model: &TrainedModel,
        cfg: ServeConfig,
    ) -> anyhow::Result<crate::serve::Server> {
        crate::serve::Server::start(Arc::clone(&self.engine), model.clone(), cfg)
    }

    /// Up to `n` held-out test structures per task, concatenated in head
    /// order — handy fresh inputs for [`Predictor`].
    pub fn test_samples(&mut self, n: usize) -> anyhow::Result<Vec<AtomicStructure>> {
        self.generate_data();
        let data = self.data.as_ref().unwrap();
        let mut out = Vec::new();
        for d in &self.tasks {
            let split = data
                .test
                .get(d)
                .ok_or_else(|| anyhow::anyhow!("no test split for {}", d.name()))?;
            out.extend(split.iter().take(n).cloned());
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// predictor
// ---------------------------------------------------------------------------

/// Typed output of [`Predictor`]: labeled-scale energies and forces for one
/// structure, produced by the head of the structure's source task.
#[derive(Debug, Clone)]
pub struct Prediction {
    /// Task whose head produced the prediction.
    pub dataset: DatasetId,
    /// Predicted total energy (energy-per-atom x natoms).
    pub energy: f64,
    /// Predicted energy per atom (the model's native target).
    pub energy_per_atom: f64,
    /// Predicted per-atom forces.
    pub forces: Vec<[f64; 3]>,
}

/// Batched inference over a [`TrainedModel`]: routes each structure to the
/// correct MTL head, auto-packs/pads groups into the compiled batch dims,
/// and unpads the outputs back into per-structure [`Prediction`]s. Replaces
/// the seed's manual `BatchBuilder` + `full_params` + `engine.forward`
/// plumbing.
///
/// Execution goes through the same [`PreparedModel`] the serving subsystem
/// uses: parameters are marshalled into typed structs once (f32 weight
/// views cached at the same time), activations live in one recycled
/// workspace, and the packing batch is recycled via `GraphBatch::clear` —
/// so repeated calls pay no per-call parameter marshal, weight downcast,
/// or buffer allocation. Materialized heads are held in a small bounded
/// LRU (see [`Predictor::with_head_cap`]), not an ever-growing map.
pub struct Predictor {
    prepared: PreparedModel,
    dims: BatchDims,
    cutoff: f64,
    /// Recycled packing batch (cleared, never reallocated).
    batch: GraphBatch,
    /// Recycled activation workspace / output buffers.
    ws: Workspace,
}

impl Predictor {
    pub fn new(engine: Arc<Engine>, model: TrainedModel) -> Predictor {
        Self::with_head_cap(engine, model, DEFAULT_HEAD_CAP)
    }

    /// As [`Predictor::new`] with an explicit bound on cached head
    /// materializations (least-recently-used head evicted beyond `cap`).
    pub fn with_head_cap(engine: Arc<Engine>, model: TrainedModel, cap: usize) -> Predictor {
        let dims = engine.manifest.config.batch_dims();
        let cutoff = engine.manifest.config.cutoff;
        let prepared = PreparedModel::with_head_cap(engine, model, cap);
        let batch = GraphBatch::empty(dims);
        let ws = prepared.workspace();
        Predictor { prepared, dims, cutoff, batch, ws }
    }

    pub fn model_name(&self) -> &str {
        self.prepared.name()
    }

    /// Heads currently materialized (bounded; see
    /// [`Predictor::with_head_cap`]).
    pub fn cached_heads(&self) -> usize {
        self.prepared.cached_heads()
    }

    /// Predict energies and forces for every structure, each through the
    /// head of its source task, preserving input order. Structures from the
    /// same task are packed together into as few padded batches as fit the
    /// compiled dims.
    pub fn predict(
        &mut self,
        structures: &[AtomicStructure],
    ) -> anyhow::Result<Vec<Prediction>> {
        let mut by_task: BTreeMap<DatasetId, Vec<usize>> = BTreeMap::new();
        for (i, s) in structures.iter().enumerate() {
            by_task.entry(s.dataset).or_default().push(i);
        }
        let mut out: Vec<Option<Prediction>> =
            structures.iter().map(|_| None).collect();
        for (d, idxs) in by_task {
            self.predict_group(d, &idxs, structures, &mut out)?;
        }
        Ok(out
            .into_iter()
            .map(|p| p.expect("every structure receives a prediction"))
            .collect())
    }

    /// Convenience for a single structure.
    pub fn predict_one(&mut self, s: &AtomicStructure) -> anyhow::Result<Prediction> {
        let mut v = self.predict(std::slice::from_ref(s))?;
        Ok(v.remove(0))
    }

    fn predict_group(
        &mut self,
        d: DatasetId,
        idxs: &[usize],
        structures: &[AtomicStructure],
        out: &mut [Option<Prediction>],
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.prepared.has_head(d),
            "model '{}' has no head for task {}",
            self.prepared.name(),
            d.name()
        );
        self.batch.clear();
        let mut slots: Vec<usize> = Vec::new();
        for &i in idxs {
            let s = &structures[i];
            let edges = radius_graph(s, self.cutoff);
            anyhow::ensure!(
                s.natoms() <= self.dims.max_nodes && edges.len() <= self.dims.max_edges,
                "structure {i} ({} atoms / {} edges) exceeds the compiled batch \
                 budget {:?}",
                s.natoms(),
                edges.len(),
                self.dims
            );
            if !self.batch.fits(s.natoms(), edges.len()) {
                self.flush(d, &slots, structures, out)?;
                self.batch.clear();
                slots.clear();
            }
            self.batch
                .push(s, &edges)
                .map_err(|e| anyhow::anyhow!("batch pack failed: {e}"))?;
            slots.push(i);
        }
        if !slots.is_empty() {
            self.flush(d, &slots, structures, out)?;
        }
        Ok(())
    }

    /// Run the recycled packed batch through the prepared model and scatter
    /// the unpadded outputs back to their structures.
    fn flush(
        &mut self,
        d: DatasetId,
        slots: &[usize],
        structures: &[AtomicStructure],
        out: &mut [Option<Prediction>],
    ) -> anyhow::Result<()> {
        self.prepared.run(d, &self.batch, &mut self.ws)?;
        let ev = self.ws.energy_per_atom();
        let fv = self.ws.forces();
        let mut node_base = 0usize;
        for (g, &i) in slots.iter().enumerate() {
            let s = &structures[i];
            let n = s.natoms();
            let epa = ev[g] as f64;
            let mut fs = Vec::with_capacity(n);
            for k in 0..n {
                let row = (node_base + k) * 3;
                fs.push([fv[row] as f64, fv[row + 1] as f64, fv[row + 2] as f64]);
            }
            node_base += n;
            out[i] = Some(Prediction {
                dataset: d,
                energy: epa * n as f64,
                energy_per_atom: epa,
                forces: fs,
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_resolves_default_tasks_per_mode() {
        // No engine available in unit tests; exercise the task resolution by
        // checking the validation errors fire before engine loading.
        let err = Session::builder()
            .mode(TrainMode::MtlPar)
            .tasks(&[])
            .build()
            .unwrap_err();
        assert!(format!("{err}").contains("non-empty"), "{err}");

        let err = Session::builder()
            .mode(TrainMode::Single(DatasetId::Qm7x))
            .tasks(&[DatasetId::Ani1x])
            .build()
            .unwrap_err();
        assert!(format!("{err}").contains("omits"), "{err}");
    }

    #[test]
    fn builder_rejects_invalid_config_before_loading_engine() {
        let mut cfg = crate::config::RunConfig::default();
        cfg.train.epochs = 0;
        assert!(Session::builder().config(cfg).build().is_err());
    }
}
