//! Featurize-once data pipeline (the HydraGNN-at-exascale lesson: keep every
//! rank's data path cheap enough that the accelerator heads stay busy).
//!
//! The seed training loop re-ran `radius_graph` for every structure on every
//! rank in every epoch. A [`FeaturizedStore`] runs it exactly once per
//! structure at bundle-build time — in parallel across shards with scoped
//! threads — and caches `(edges, species, forces, energy)` in flat
//! contiguous arrays. Warm-epoch planning then only shuffles indices and
//! packs cached slices into pooled batches ([`crate::data::batch::BatchPool`]),
//! performing **zero** graph constructions (asserted against
//! [`crate::data::graph::radius_graph_call_count`] in tests).
//!
//! Output parity: epoch batches are bit-identical to the seed
//! re-featurize-every-epoch path (kept as
//! `coordinator::trainer::plan_epoch_batches_reference`), proven in
//! `rust/tests/integration_featurized.rs`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::data::batch::{BatchDims, BatchPool, GraphBatch};
use crate::data::ddstore::DDStore;
use crate::data::graph::{radius_graph, Edge};
use crate::util::rng::Rng;

/// Immutable edge/field cache built from a [`DDStore`] once per training
/// run and shared by every rank thread. The source store is NOT retained:
/// only the round-robin world size (ownership arithmetic) and the flat
/// caches survive, so the caller can drop the `DDStore` — and the sample
/// copy inside it — as soon as `build` returns.
pub struct FeaturizedStore {
    /// Round-robin world size of the source store (owner = index % world).
    world: usize,
    cutoff: f64,
    /// Edges of structure `i` live at `edges[edge_off[i]..edge_off[i+1]]`.
    edge_off: Vec<usize>,
    edges: Vec<Edge>,
    /// Nodes of structure `i` live at `node_off[i]..node_off[i+1]` in
    /// `species` / `forces`.
    node_off: Vec<usize>,
    species: Vec<u8>,
    forces: Vec<[f64; 3]>,
    /// Graph-parallel domain decomposition: segment 0..8 per atom (flat,
    /// aligned with `species`). Atoms are sorted by spatial cell (the same
    /// cutoff-sized cells `radius_graph` bins into) and split into 8
    /// balanced contiguous chunks of that order, so segments are spatially
    /// compact — boundary (halo) sets stay small — and a pure function of
    /// positions. Rank `r` of a graph-parallel world `W in {1,2,4,8}` owns
    /// segments `r*8/W..(r+1)*8/W` (see `comm::halo`).
    segments: Vec<u8>,
    /// Labeled total energy per structure.
    energy: Vec<f64>,
    /// Planned-access locality counters — the in-process analogue of
    /// DDStore's one-sided-get stats, kept here because the cache serves
    /// epoch reads without touching the samples.
    local_gets: AtomicU64,
    remote_gets: AtomicU64,
}

impl FeaturizedStore {
    /// Featurize every sample of `store` exactly once, fanning the
    /// `radius_graph` work out over scoped worker threads. Workers produce
    /// per-structure edge lists in index order, so the flat layout (and
    /// everything downstream) is deterministic regardless of thread count.
    pub fn build(store: Arc<DDStore>, cutoff: f64) -> Arc<FeaturizedStore> {
        let n = store.len();
        let workers = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .clamp(1, n.max(1));
        let chunk = n.div_ceil(workers);
        let per: Vec<Vec<Edge>> = std::thread::scope(|scope| {
            let store = &store;
            let mut handles = Vec::with_capacity(workers);
            for w in 0..workers {
                let start = w * chunk;
                let end = (start + chunk).min(n);
                handles.push(scope.spawn(move || {
                    (start..end)
                        .map(|g| {
                            let s = store.peek(g).expect("global index in range");
                            radius_graph(s, cutoff)
                        })
                        .collect::<Vec<_>>()
                }));
            }
            let mut all = Vec::with_capacity(n);
            for h in handles {
                all.extend(h.join().expect("featurize worker panicked"));
            }
            all
        });

        let total_edges: usize = per.iter().map(|e| e.len()).sum();
        let mut edge_off = Vec::with_capacity(n + 1);
        let mut node_off = Vec::with_capacity(n + 1);
        let mut edges = Vec::with_capacity(total_edges);
        let mut species = Vec::new();
        let mut forces = Vec::new();
        let mut energy = Vec::with_capacity(n);
        edge_off.push(0);
        node_off.push(0);
        let mut segments = Vec::new();
        for (g, es) in per.into_iter().enumerate() {
            let s = store.peek(g).expect("global index in range");
            edges.extend(es);
            edge_off.push(edges.len());
            species.extend_from_slice(&s.species);
            forces.extend_from_slice(&s.forces);
            segments.extend(compute_segments(&s.positions, cutoff));
            node_off.push(species.len());
            energy.push(s.energy);
        }
        Arc::new(FeaturizedStore {
            world: store.world(),
            cutoff,
            edge_off,
            edges,
            node_off,
            species,
            forces,
            segments,
            energy,
            local_gets: AtomicU64::new(0),
            remote_gets: AtomicU64::new(0),
        })
    }

    pub fn len(&self) -> usize {
        self.energy.len()
    }

    pub fn is_empty(&self) -> bool {
        self.energy.is_empty()
    }

    /// The cutoff the cached graphs were built with.
    pub fn cutoff(&self) -> f64 {
        self.cutoff
    }

    /// (local, remote) planned-access counters since construction.
    pub fn stats(&self) -> (u64, u64) {
        (self.local_gets.load(Ordering::Relaxed), self.remote_gets.load(Ordering::Relaxed))
    }

    pub fn natoms(&self, i: usize) -> usize {
        self.node_off[i + 1] - self.node_off[i]
    }

    pub fn nedges(&self, i: usize) -> usize {
        self.edge_off[i + 1] - self.edge_off[i]
    }

    pub fn edges(&self, i: usize) -> &[Edge] {
        &self.edges[self.edge_off[i]..self.edge_off[i + 1]]
    }

    pub fn species(&self, i: usize) -> &[u8] {
        &self.species[self.node_off[i]..self.node_off[i + 1]]
    }

    pub fn forces(&self, i: usize) -> &[[f64; 3]] {
        &self.forces[self.node_off[i]..self.node_off[i + 1]]
    }

    /// Graph-parallel segment (0..8) of every atom of structure `i`; see
    /// the field docs for the ownership rule.
    pub fn segments(&self, i: usize) -> &[u8] {
        &self.segments[self.node_off[i]..self.node_off[i + 1]]
    }

    /// Labeled total energy of structure `i` (graph-parallel training fits
    /// the per-structure energy directly rather than the batched per-atom
    /// view).
    pub fn energy(&self, i: usize) -> f64 {
        self.energy[i]
    }

    /// Same value the seed path computed via
    /// [`crate::data::structures::AtomicStructure::energy_per_atom`].
    pub fn energy_per_atom(&self, i: usize) -> f64 {
        self.energy[i] / self.natoms(i) as f64
    }

    /// Plan one rank's padded batches for an epoch from its slice of the
    /// shuffled global index list (identical shuffle on every rank, same as
    /// the seed planner) — but packing cached edge/field slices into pooled
    /// batches instead of re-featurizing every structure. Zero
    /// `radius_graph` calls; locality is still recorded on [`Self::stats`]
    /// so the access pattern stays observable to the scaling model.
    pub fn plan_epoch_batches(
        &self,
        rank_in_group: usize,
        group_size: usize,
        dims: BatchDims,
        epoch_seed: u64,
        pool: &mut BatchPool,
    ) -> Vec<GraphBatch> {
        let n = self.len();
        let mut indices: Vec<usize> = (0..n).collect();
        let mut rng = Rng::new(epoch_seed);
        rng.shuffle(&mut indices);
        let mut batches = Vec::new();
        let mut current = pool.acquire(dims);
        for idx in indices.into_iter().skip(rank_in_group).step_by(group_size) {
            if idx % self.world == rank_in_group {
                self.local_gets.fetch_add(1, Ordering::Relaxed);
            } else {
                self.remote_gets.fetch_add(1, Ordering::Relaxed);
            }
            let natoms = self.natoms(idx);
            let nedges = self.nedges(idx);
            if natoms > dims.max_nodes || nedges > dims.max_edges {
                // Same skip rule as the seed BatchBuilder: structures that
                // can never fit are dropped from the epoch.
                continue;
            }
            if !current.fits(natoms, nedges) {
                batches.push(std::mem::replace(&mut current, pool.acquire(dims)));
            }
            current
                .push_raw(
                    self.species(idx),
                    self.forces(idx),
                    self.energy_per_atom(idx),
                    self.edges(idx),
                )
                .expect("fits() checked");
        }
        if current.n_graphs > 0 {
            batches.push(current);
        } else {
            pool.recycle([current]);
        }
        batches
    }
}

/// Contiguous-by-sorted-cell partition of one structure's atoms into 8
/// balanced segments: sort atoms by their `cutoff`-sized spatial cell
/// (lexicographic, ties broken by atom index — fully deterministic), then
/// chunk the sorted order evenly. Exposed for the graph-parallel property
/// tests; production access goes through [`FeaturizedStore::segments`].
pub fn compute_segments(positions: &[[f64; 3]], cutoff: f64) -> Vec<u8> {
    let n = positions.len();
    if n == 0 {
        return Vec::new();
    }
    let mut lo = [f64::INFINITY; 3];
    for p in positions {
        for k in 0..3 {
            lo[k] = lo[k].min(p[k]);
        }
    }
    let cells: Vec<[i64; 3]> = positions
        .iter()
        .map(|p| {
            [
                ((p[0] - lo[0]) / cutoff) as i64,
                ((p[1] - lo[1]) / cutoff) as i64,
                ((p[2] - lo[2]) / cutoff) as i64,
            ]
        })
        .collect();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| (cells[i], i));
    let mut seg = vec![0u8; n];
    for (pos, &atom) in order.iter().enumerate() {
        seg[atom] = (pos * 8 / n) as u8;
    }
    seg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{DatasetGenerator, GeneratorConfig};
    use crate::data::structures::{AtomicStructure, DatasetId};

    fn samples(n: usize) -> Vec<AtomicStructure> {
        let mut g = DatasetGenerator::new(
            DatasetId::Qm7x,
            21,
            GeneratorConfig { max_atoms: 12, ..Default::default() },
        );
        g.take(n)
    }

    #[test]
    fn cached_fields_match_the_source_samples() {
        let ss = samples(17);
        let store = DDStore::new(ss.clone(), 3);
        let fs = FeaturizedStore::build(store, 6.0);
        assert_eq!(fs.len(), ss.len());
        for (i, s) in ss.iter().enumerate() {
            assert_eq!(fs.natoms(i), s.natoms(), "sample {i}");
            assert_eq!(fs.species(i), &s.species[..], "sample {i}");
            assert_eq!(fs.forces(i), &s.forces[..], "sample {i}");
            assert_eq!(fs.energy_per_atom(i), s.energy_per_atom(), "sample {i}");
            assert_eq!(fs.edges(i), &radius_graph(s, 6.0)[..], "sample {i}");
        }
    }

    #[test]
    fn segments_are_balanced_deterministic_and_spatially_sorted() {
        let ss = samples(6);
        let store = DDStore::new(ss.clone(), 2);
        let fs = FeaturizedStore::build(store, 6.0);
        for (i, s) in ss.iter().enumerate() {
            let seg = fs.segments(i);
            assert_eq!(seg.len(), s.natoms());
            assert!(seg.iter().all(|&x| x < 8), "segment ids are 0..8");
            // Pure function of positions: rebuilding yields identical bits.
            assert_eq!(seg, &compute_segments(&s.positions, 6.0)[..], "sample {i}");
            // Balanced: chunk sizes of the sorted order differ by <= 1.
            let mut counts = [0usize; 8];
            for &x in seg {
                counts[x as usize] += 1;
            }
            let n = s.natoms();
            for (c, &count) in counts.iter().enumerate() {
                let expect = (c + 1) * n / 8 - c * n / 8;
                assert_eq!(count, expect, "sample {i} segment {c}");
            }
        }
    }

    #[test]
    fn empty_store_plans_no_batches() {
        let fs = FeaturizedStore::build(DDStore::new(Vec::new(), 2), 6.0);
        assert!(fs.is_empty());
        let dims = BatchDims { max_nodes: 16, max_edges: 128, max_graphs: 4 };
        let mut pool = BatchPool::new();
        assert!(fs.plan_epoch_batches(0, 2, dims, 1, &mut pool).is_empty());
        assert_eq!(pool.pooled(), 1, "the unused scratch batch is recycled");
    }

    #[test]
    fn oversized_structures_are_skipped_like_the_seed_builder() {
        let ss = samples(12);
        let fs = FeaturizedStore::build(DDStore::new(ss.clone(), 1), 6.0);
        let tiny = BatchDims { max_nodes: 4, max_edges: 8, max_graphs: 2 };
        let mut pool = BatchPool::new();
        let batches = fs.plan_epoch_batches(0, 1, tiny, 5, &mut pool);
        let packed: usize = batches.iter().map(|b| b.n_graphs).sum();
        let fitting = ss
            .iter()
            .filter(|s| s.natoms() <= 4 && radius_graph(s, 6.0).len() <= 8)
            .count();
        assert_eq!(packed, fitting);
    }
}
