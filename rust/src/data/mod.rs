//! Data substrate: structures, ground-truth potential, fidelity transforms,
//! the five synthetic dataset generators, radius graphs, padded batching,
//! the GPack packed file format (ADIOS substitute), the DDStore distributed
//! sample store, the featurize-once `FeaturizedStore` cache that warm
//! epochs plan from, and deterministic splits.

pub mod batch;
pub mod ddstore;
pub mod featurized;
pub mod fidelity;
pub mod generators;
pub mod graph;
pub mod pack;
pub mod potential;
pub mod split;
pub mod structures;

pub use batch::{BatchBuilder, BatchDims, BatchPool, GraphBatch};
pub use ddstore::DDStore;
pub use featurized::FeaturizedStore;
pub use structures::{AtomicStructure, DatasetId, ALL_DATASETS};
