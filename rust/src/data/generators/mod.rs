//! Synthetic dataset generators reproducing the statistical profile of the
//! registered tasks — the paper's five presets (Section 4.1: ANI1x, QM7-X,
//! Transition1x, MPTrj, Alexandria) plus any task added to the
//! [`crate::tasks::TaskRegistry`] at runtime.
//!
//! Each generator produces `AtomicStructure`s whose
//!   - element palette,
//!   - atom-count distribution,
//!   - geometry class (molecular vs crystalline), and
//!   - equilibrium character (relaxed vs perturbed vs reaction-path)
//! come from the task's [`crate::tasks::GeneratorProfile`], with labels
//! from the shared ground-truth potential passed through the task's
//! fidelity transform. See DESIGN.md Section 3 for why this preserves the
//! behaviour the paper studies.

pub mod inorganic;
pub mod large;
pub mod organic;

use std::sync::Arc;

use crate::data::fidelity::FidelityModel;
use crate::data::potential;
use crate::data::structures::{AtomicStructure, DatasetId};
use crate::tasks::{StructureKind, TaskSpec};
use crate::util::rng::Rng;

/// Generation knobs shared by all dataset profiles.
#[derive(Debug, Clone)]
pub struct GeneratorConfig {
    /// Maximum atoms per structure (keeps structures inside batch budgets).
    pub max_atoms: usize,
    /// Scale perturbation applied to off-equilibrium samples (Angstrom).
    pub perturbation: f64,
    /// Curation filter: reject samples whose max |force component| exceeds
    /// this (eV/A). Real datasets (ANI1x & co.) apply the same filter —
    /// near-overlapping atoms produce unphysical labels that destabilize
    /// training.
    pub max_force: f64,
    /// Curation filter: reject |energy per atom| above this.
    pub max_energy_per_atom: f64,
}

impl Default for GeneratorConfig {
    fn default() -> Self {
        GeneratorConfig {
            max_atoms: 24,
            perturbation: 0.25,
            max_force: 30.0,
            max_energy_per_atom: 15.0,
        }
    }
}

/// A generator for one registered task.
pub struct DatasetGenerator {
    pub dataset: DatasetId,
    pub config: GeneratorConfig,
    spec: Arc<TaskSpec>,
    fidelity: FidelityModel,
    rng: Rng,
}

impl DatasetGenerator {
    pub fn new(dataset: DatasetId, seed: u64, config: GeneratorConfig) -> Self {
        // NB: `+` binds tighter than `^` — parens keep the seed repo's exact
        // stream (seed ^ (tag + index)).
        let mut root = Rng::new(seed ^ (0xDA7A_5E7 + dataset.index() as u64));
        let rng = root.fork(dataset.index() as u64);
        DatasetGenerator {
            dataset,
            config,
            spec: dataset.spec(),
            fidelity: FidelityModel::for_dataset(dataset),
            rng,
        }
    }

    /// The task spec driving this generator.
    pub fn spec(&self) -> &TaskSpec {
        &self.spec
    }

    /// Generate one labeled structure passing the curation filters.
    pub fn sample(&mut self) -> AtomicStructure {
        // Rejection loop with progressively damped perturbation: mirrors how
        // curated datasets drop unphysical outliers rather than keep them.
        let base_perturbation = self.config.perturbation;
        for attempt in 0..16 {
            let s = self.sample_unfiltered();
            let ok = s.energy_per_atom().abs() <= self.config.max_energy_per_atom
                && s.forces.iter().flat_map(|f| f.iter()).all(|x| x.abs() <= self.config.max_force);
            if ok {
                self.config.perturbation = base_perturbation;
                return s;
            }
            // Damp the displacement scale and retry.
            self.config.perturbation *= 0.7;
            let _ = attempt;
        }
        self.config.perturbation = base_perturbation;
        // Final fallback: unperturbed relaxed structure (always physical).
        let saved = self.config.perturbation;
        self.config.perturbation = 0.0;
        let s = self.sample_unfiltered();
        self.config.perturbation = saved;
        s
    }

    /// Generate one labeled structure without curation filters. Entirely
    /// driven by the task's [`crate::tasks::GeneratorProfile`]; size ranges
    /// of the organic presets deliberately overlap so a single-head baseline
    /// cannot infer the source from structure size alone (the label
    /// conflict, not geometry, is what MTL absorbs).
    fn sample_unfiltered(&mut self) -> AtomicStructure {
        let profile = &self.spec.generator;
        let (species, mut positions) = match profile.kind {
            StructureKind::Molecule { min_atoms, atoms_cap } => {
                let natoms =
                    self.rng.int_range(min_atoms, self.config.max_atoms.min(atoms_cap));
                organic::build_molecule(&mut self.rng, &self.spec.palette, natoms)
            }
            StructureKind::MoleculeHeavyLimited { min_heavy, max_heavy } => {
                let heavy = self.rng.int_range(min_heavy, max_heavy);
                organic::build_molecule_heavy_limited(
                    &mut self.rng,
                    &self.spec.palette,
                    heavy,
                    self.config.max_atoms,
                )
            }
            StructureKind::Crystal { min_atoms } => {
                let natoms = self.rng.int_range(min_atoms, self.config.max_atoms);
                inorganic::build_crystal(&mut self.rng, &self.spec.palette, natoms)
            }
            // Bulk kinds deliberately ignore `config.max_atoms`: the whole
            // point is structures too large for one rank's batch budget
            // (graph-parallel training partitions them across ranks).
            StructureKind::Supercell { reps } => {
                large::build_supercell(&mut self.rng, &self.spec.palette, reps)
            }
            StructureKind::AmorphousBox { natoms } => {
                large::build_amorphous_box(&mut self.rng, &self.spec.palette, natoms)
            }
        };

        // Equilibrium character: optional relaxation (rng-free), then a
        // profile-scaled jitter. relax=0 + factor>1 models reaction paths.
        if profile.relax_steps > 0 {
            potential::relax(
                &species,
                &mut positions,
                profile.relax_steps,
                profile.relax_step_size,
            );
        }
        let perturb = profile.perturb_factor * self.config.perturbation;
        for pos in positions.iter_mut() {
            for x in pos.iter_mut() {
                *x += self.rng.normal_scaled(0.0, perturb);
            }
        }

        let (true_e, true_f) = potential::energy_and_forces(&species, &positions);
        let (energy, forces) =
            self.fidelity.apply(&species, true_e, &true_f, &mut self.rng);

        let s = AtomicStructure { species, positions, energy, forces, dataset: self.dataset };
        debug_assert!(s.validate().is_ok());
        s
    }

    /// Generate `n` structures.
    pub fn take(&mut self, n: usize) -> Vec<AtomicStructure> {
        (0..n).map(|_| self.sample()).collect()
    }
}

/// Convenience: generate `per_dataset` samples for every *registered* task
/// — the five presets plus anything added to the registry at runtime. For
/// the paper's fixed five-source aggregation, pass
/// [`crate::data::structures::ALL_DATASETS`] to [`generate_for`] instead.
pub fn generate_all(
    seed: u64,
    per_dataset: usize,
    config: &GeneratorConfig,
) -> Vec<(DatasetId, Vec<AtomicStructure>)> {
    generate_for(&crate::tasks::TaskRegistry::global().all(), seed, per_dataset, config)
}

/// Generate `per_dataset` samples for each listed task.
pub fn generate_for(
    datasets: &[DatasetId],
    seed: u64,
    per_dataset: usize,
    config: &GeneratorConfig,
) -> Vec<(DatasetId, Vec<AtomicStructure>)> {
    datasets
        .iter()
        .map(|&d| {
            let mut g = DatasetGenerator::new(d, seed, config.clone());
            (d, g.take(per_dataset))
        })
        .collect()
}

/// Element frequency histogram over a set of structures (Fig 1 input).
pub fn element_histogram(structures: &[AtomicStructure]) -> Vec<u64> {
    let mut counts = vec![0u64; crate::elements::MAX_Z + 1];
    for s in structures {
        for &z in &s.species {
            counts[z as usize] += 1;
        }
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::structures::ALL_DATASETS;

    #[test]
    fn all_generators_produce_valid_structures() {
        for d in ALL_DATASETS {
            let mut g = DatasetGenerator::new(d, 42, GeneratorConfig::default());
            for _ in 0..20 {
                let s = g.sample();
                s.validate().unwrap_or_else(|e| panic!("{d:?}: {e}"));
                assert_eq!(s.dataset, d);
                assert!(s.natoms() <= g.config.max_atoms + 8, "{d:?}");
            }
        }
    }

    #[test]
    fn palettes_respected() {
        for d in ALL_DATASETS {
            let palette = d.palette();
            let mut g = DatasetGenerator::new(d, 7, GeneratorConfig::default());
            for _ in 0..10 {
                let s = g.sample();
                for &z in &s.species {
                    assert!(palette.contains(&(z as usize)), "{d:?} produced Z={z}");
                }
            }
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let mut a = DatasetGenerator::new(DatasetId::Qm7x, 3, GeneratorConfig::default());
        let mut b = DatasetGenerator::new(DatasetId::Qm7x, 3, GeneratorConfig::default());
        for _ in 0..5 {
            assert_eq!(a.sample(), b.sample());
        }
    }

    #[test]
    fn qm7x_heavy_atom_limit() {
        let mut g = DatasetGenerator::new(DatasetId::Qm7x, 9, GeneratorConfig::default());
        for _ in 0..30 {
            let s = g.sample();
            let heavy = s.species.iter().filter(|&&z| z != 1).count();
            assert!(heavy <= 7, "QM7-X must have <=7 heavy atoms, got {heavy}");
        }
    }

    #[test]
    fn inorganic_more_diverse_than_organic() {
        let cfg = GeneratorConfig::default();
        // generate_for, not generate_all: other tests in this binary mutate
        // the global registry, and this test's claim is about the presets.
        let all = generate_for(&ALL_DATASETS, 5, 50, &cfg);
        let hist_of = |d: DatasetId| {
            let s = &all.iter().find(|(id, _)| *id == d).unwrap().1;
            element_histogram(s).iter().filter(|&&c| c > 0).count()
        };
        assert!(hist_of(DatasetId::Alexandria) > hist_of(DatasetId::Ani1x));
        assert!(hist_of(DatasetId::MpTrj) > hist_of(DatasetId::Qm7x));
    }

    #[test]
    fn custom_registered_task_generates_valid_structures() {
        use crate::tasks::{
            FidelityProfile, GeneratorProfile, StructureKind, TaskRegistry, TaskSpec,
        };
        let palette = vec![1usize, 6, 8, 14];
        let id = TaskRegistry::global()
            .register(TaskSpec::new(
                "GenTest-Organo",
                palette.clone(),
                GeneratorProfile {
                    kind: StructureKind::Molecule { min_atoms: 4, atoms_cap: 12 },
                    relax_steps: 5,
                    relax_step_size: 0.05,
                    perturb_factor: 1.0,
                },
                FidelityProfile {
                    seed_tag: 71,
                    shift_sigma: 0.6,
                    scale_jitter: 0.02,
                    force_scale_jitter: 0.01,
                    energy_noise: 0.002,
                    force_noise: 0.004,
                    shift_offset: 0.0,
                },
            ))
            .unwrap();
        let mut g = DatasetGenerator::new(id, 3, GeneratorConfig::default());
        let mut a = DatasetGenerator::new(id, 3, GeneratorConfig::default());
        for _ in 0..10 {
            let s = g.sample();
            s.validate().unwrap();
            assert_eq!(s.dataset, id);
            for &z in &s.species {
                assert!(palette.contains(&(z as usize)), "Z={z} outside palette");
            }
            assert_eq!(s, a.sample(), "custom-task generation must be deterministic");
        }
    }

    #[test]
    fn bulk_kinds_generate_valid_structures_beyond_the_batch_cap() {
        use crate::tasks::{
            FidelityProfile, GeneratorProfile, StructureKind, TaskRegistry, TaskSpec,
        };
        let fid = FidelityProfile {
            seed_tag: 77,
            shift_sigma: 0.25,
            scale_jitter: 0.01,
            force_scale_jitter: 0.005,
            energy_noise: 0.002,
            force_noise: 0.003,
            shift_offset: 0.0,
        };
        let prof = |kind| GeneratorProfile {
            kind,
            relax_steps: 0,
            relax_step_size: 0.05,
            perturb_factor: 0.2,
        };
        let reg = TaskRegistry::global();
        let sc = reg
            .register(TaskSpec::new(
                "GenTest-Supercell",
                vec![12, 8, 11, 17],
                prof(StructureKind::Supercell { reps: 4 }),
                fid.clone(),
            ))
            .unwrap();
        let ab = reg
            .register(TaskSpec::new(
                "GenTest-Amorphous",
                vec![12, 8, 11, 17],
                prof(StructureKind::AmorphousBox { natoms: 100 }),
                fid,
            ))
            .unwrap();
        let cfg = GeneratorConfig::default();
        let mut g = DatasetGenerator::new(sc, 13, cfg.clone());
        let s = g.sample();
        s.validate().unwrap();
        assert_eq!(s.natoms(), 64, "supercell size is exact (reps^3)");
        assert!(s.natoms() > cfg.max_atoms, "bulk kinds ignore the batch cap");
        // Bulk near-equilibrium lattices pass the curation filters as-is.
        assert!(s.energy_per_atom().abs() <= cfg.max_energy_per_atom);
        let mut g = DatasetGenerator::new(ab, 13, cfg.clone());
        let s = g.sample();
        s.validate().unwrap();
        assert_eq!(s.natoms(), 100, "amorphous box size is exact");
        // Determinism across generator instances, like every other kind.
        let mut a = DatasetGenerator::new(ab, 17, cfg.clone());
        let mut b = DatasetGenerator::new(ab, 17, cfg);
        assert_eq!(a.sample(), b.sample());
    }

    #[test]
    fn transition1x_is_most_off_equilibrium() {
        // Mean |F| should be largest for the reaction-path dataset among the
        // organic sources (forces grow with displacement from equilibrium).
        let cfg = GeneratorConfig::default();
        let mean_force = |d: DatasetId| {
            let mut g = DatasetGenerator::new(d, 11, cfg.clone());
            let mut total = 0.0;
            let mut n = 0usize;
            for _ in 0..30 {
                let s = g.sample();
                for f in &s.forces {
                    total += (f[0] * f[0] + f[1] * f[1] + f[2] * f[2]).sqrt();
                    n += 1;
                }
            }
            total / n as f64
        };
        assert!(mean_force(DatasetId::Transition1x) > mean_force(DatasetId::MpTrj));
    }
}
