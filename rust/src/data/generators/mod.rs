//! Synthetic dataset generators reproducing the statistical profile of the
//! five datasets the paper aggregates (Section 4.1): ANI1x, QM7-X,
//! Transition1x, MPTrj, Alexandria.
//!
//! Each generator produces `AtomicStructure`s whose
//!   - element palette,
//!   - atom-count distribution,
//!   - geometry class (molecular vs crystalline), and
//!   - equilibrium character (relaxed vs perturbed vs reaction-path)
//! match the corresponding source, with labels from the shared ground-truth
//! potential passed through the dataset's fidelity transform. See DESIGN.md
//! Section 3 for why this preserves the behaviour the paper studies.

pub mod inorganic;
pub mod organic;

use crate::data::fidelity::FidelityModel;
use crate::data::potential;
use crate::data::structures::{AtomicStructure, DatasetId};
use crate::util::rng::Rng;

/// Generation knobs shared by all dataset profiles.
#[derive(Debug, Clone)]
pub struct GeneratorConfig {
    /// Maximum atoms per structure (keeps structures inside batch budgets).
    pub max_atoms: usize,
    /// Scale perturbation applied to off-equilibrium samples (Angstrom).
    pub perturbation: f64,
    /// Curation filter: reject samples whose max |force component| exceeds
    /// this (eV/A). Real datasets (ANI1x & co.) apply the same filter —
    /// near-overlapping atoms produce unphysical labels that destabilize
    /// training.
    pub max_force: f64,
    /// Curation filter: reject |energy per atom| above this.
    pub max_energy_per_atom: f64,
}

impl Default for GeneratorConfig {
    fn default() -> Self {
        GeneratorConfig {
            max_atoms: 24,
            perturbation: 0.25,
            max_force: 30.0,
            max_energy_per_atom: 15.0,
        }
    }
}

/// A generator for one source dataset.
pub struct DatasetGenerator {
    pub dataset: DatasetId,
    pub config: GeneratorConfig,
    fidelity: FidelityModel,
    rng: Rng,
}

impl DatasetGenerator {
    pub fn new(dataset: DatasetId, seed: u64, config: GeneratorConfig) -> Self {
        let mut root = Rng::new(seed ^ 0xDA7A_5E7 + dataset.index() as u64);
        let rng = root.fork(dataset.index() as u64);
        DatasetGenerator { dataset, config, fidelity: FidelityModel::for_dataset(dataset), rng }
    }

    /// Generate one labeled structure passing the curation filters.
    pub fn sample(&mut self) -> AtomicStructure {
        // Rejection loop with progressively damped perturbation: mirrors how
        // curated datasets drop unphysical outliers rather than keep them.
        let base_perturbation = self.config.perturbation;
        for attempt in 0..16 {
            let s = self.sample_unfiltered();
            let ok = s.energy_per_atom().abs() <= self.config.max_energy_per_atom
                && s.forces.iter().flat_map(|f| f.iter()).all(|x| x.abs() <= self.config.max_force);
            if ok {
                self.config.perturbation = base_perturbation;
                return s;
            }
            // Damp the displacement scale and retry.
            self.config.perturbation *= 0.7;
            let _ = attempt;
        }
        self.config.perturbation = base_perturbation;
        // Final fallback: unperturbed relaxed structure (always physical).
        let saved = self.config.perturbation;
        self.config.perturbation = 0.0;
        let s = self.sample_unfiltered();
        self.config.perturbation = saved;
        s
    }

    /// Generate one labeled structure without curation filters.
    fn sample_unfiltered(&mut self) -> AtomicStructure {
        let (species, mut positions) = match self.dataset {
            DatasetId::Ani1x => {
                // 57k distinct molecular configurations, equilibrium and
                // perturbed: small CHNO molecules, moderate displacement.
                // Size range overlaps QM7-X/Transition1x so a single-head
                // baseline cannot infer the source from structure size alone
                // (the label conflict, not geometry, is what MTL absorbs).
                let natoms = self.rng.int_range(4, self.config.max_atoms.min(14));
                let (s, p) = organic::build_molecule(
                    &mut self.rng,
                    &self.dataset.palette(),
                    natoms,
                );
                (s, p)
            }
            DatasetId::Qm7x => {
                // Up to 7 non-hydrogen atoms: smallest structures.
                let heavy = self.rng.int_range(2, 7);
                let (s, p) = organic::build_molecule_heavy_limited(
                    &mut self.rng,
                    &self.dataset.palette(),
                    heavy,
                    self.config.max_atoms,
                );
                (s, p)
            }
            DatasetId::Transition1x => {
                // Reaction pathways: strongly off-equilibrium organics.
                let natoms = self.rng.int_range(4, self.config.max_atoms.min(16));
                let (s, p) = organic::build_molecule(
                    &mut self.rng,
                    &self.dataset.palette(),
                    natoms,
                );
                (s, p)
            }
            DatasetId::MpTrj | DatasetId::Alexandria => {
                let natoms = self.rng.int_range(4, self.config.max_atoms);
                inorganic::build_crystal(&mut self.rng, &self.dataset.palette(), natoms)
            }
        };

        // Equilibrium character.
        let perturb = match self.dataset {
            // Near-equilibrium (relax, then tiny jitter).
            DatasetId::MpTrj | DatasetId::Alexandria => {
                potential::relax(&species, &mut positions, 20, 0.05);
                0.3 * self.config.perturbation
            }
            // Equilibrium + non-equilibrium mix.
            DatasetId::Ani1x | DatasetId::Qm7x => {
                potential::relax(&species, &mut positions, 10, 0.05);
                self.config.perturbation
            }
            // On/around reaction pathways: largest displacements.
            DatasetId::Transition1x => 2.0 * self.config.perturbation,
        };
        for pos in positions.iter_mut() {
            for x in pos.iter_mut() {
                *x += self.rng.normal_scaled(0.0, perturb);
            }
        }

        let (true_e, true_f) = potential::energy_and_forces(&species, &positions);
        let (energy, forces) =
            self.fidelity.apply(&species, true_e, &true_f, &mut self.rng);

        let s = AtomicStructure { species, positions, energy, forces, dataset: self.dataset };
        debug_assert!(s.validate().is_ok());
        s
    }

    /// Generate `n` structures.
    pub fn take(&mut self, n: usize) -> Vec<AtomicStructure> {
        (0..n).map(|_| self.sample()).collect()
    }
}

/// Convenience: generate `per_dataset` samples for every source dataset.
pub fn generate_all(
    seed: u64,
    per_dataset: usize,
    config: &GeneratorConfig,
) -> Vec<(DatasetId, Vec<AtomicStructure>)> {
    crate::data::structures::ALL_DATASETS
        .iter()
        .map(|&d| {
            let mut g = DatasetGenerator::new(d, seed, config.clone());
            (d, g.take(per_dataset))
        })
        .collect()
}

/// Element frequency histogram over a set of structures (Fig 1 input).
pub fn element_histogram(structures: &[AtomicStructure]) -> Vec<u64> {
    let mut counts = vec![0u64; crate::elements::MAX_Z + 1];
    for s in structures {
        for &z in &s.species {
            counts[z as usize] += 1;
        }
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::structures::ALL_DATASETS;

    #[test]
    fn all_generators_produce_valid_structures() {
        for d in ALL_DATASETS {
            let mut g = DatasetGenerator::new(d, 42, GeneratorConfig::default());
            for _ in 0..20 {
                let s = g.sample();
                s.validate().unwrap_or_else(|e| panic!("{d:?}: {e}"));
                assert_eq!(s.dataset, d);
                assert!(s.natoms() <= g.config.max_atoms + 8, "{d:?}");
            }
        }
    }

    #[test]
    fn palettes_respected() {
        for d in ALL_DATASETS {
            let palette = d.palette();
            let mut g = DatasetGenerator::new(d, 7, GeneratorConfig::default());
            for _ in 0..10 {
                let s = g.sample();
                for &z in &s.species {
                    assert!(palette.contains(&(z as usize)), "{d:?} produced Z={z}");
                }
            }
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let mut a = DatasetGenerator::new(DatasetId::Qm7x, 3, GeneratorConfig::default());
        let mut b = DatasetGenerator::new(DatasetId::Qm7x, 3, GeneratorConfig::default());
        for _ in 0..5 {
            assert_eq!(a.sample(), b.sample());
        }
    }

    #[test]
    fn qm7x_heavy_atom_limit() {
        let mut g = DatasetGenerator::new(DatasetId::Qm7x, 9, GeneratorConfig::default());
        for _ in 0..30 {
            let s = g.sample();
            let heavy = s.species.iter().filter(|&&z| z != 1).count();
            assert!(heavy <= 7, "QM7-X must have <=7 heavy atoms, got {heavy}");
        }
    }

    #[test]
    fn inorganic_more_diverse_than_organic() {
        let cfg = GeneratorConfig::default();
        let all = generate_all(5, 50, &cfg);
        let hist_of = |d: DatasetId| {
            let s = &all.iter().find(|(id, _)| *id == d).unwrap().1;
            element_histogram(s).iter().filter(|&&c| c > 0).count()
        };
        assert!(hist_of(DatasetId::Alexandria) > hist_of(DatasetId::Ani1x));
        assert!(hist_of(DatasetId::MpTrj) > hist_of(DatasetId::Qm7x));
    }

    #[test]
    fn transition1x_is_most_off_equilibrium() {
        // Mean |F| should be largest for the reaction-path dataset among the
        // organic sources (forces grow with displacement from equilibrium).
        let cfg = GeneratorConfig::default();
        let mean_force = |d: DatasetId| {
            let mut g = DatasetGenerator::new(d, 11, cfg.clone());
            let mut total = 0.0;
            let mut n = 0usize;
            for _ in 0..30 {
                let s = g.sample();
                for f in &s.forces {
                    total += (f[0] * f[0] + f[1] * f[1] + f[2] * f[2]).sqrt();
                    n += 1;
                }
            }
            total / n as f64
        };
        assert!(mean_force(DatasetId::Transition1x) > mean_force(DatasetId::MpTrj));
    }
}
