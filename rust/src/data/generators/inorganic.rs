//! Crystalline fragment builder for the inorganic datasets (MPTrj,
//! Alexandria): carves a finite cluster out of a jittered rock-salt-like
//! lattice populated by 1-4 element species — the "periodic crystal"
//! geometry class, approximated as clusters since the model (like HydraGNN
//! on these datasets) sees a radius graph either way.

use crate::data::potential::pair_params;
use crate::util::rng::Rng;

/// Build a crystal fragment of `natoms` atoms over up to 4 species drawn
/// from `palette`. Returns (species, positions).
pub fn build_crystal(
    rng: &mut Rng,
    palette: &[usize],
    natoms: usize,
) -> (Vec<u8>, Vec<[f64; 3]>) {
    assert!(natoms >= 2);
    // Composition: 1-4 distinct elements, like typical MP entries.
    // `Rng::int_range` is INCLUSIVE on both ends, so this draws the
    // documented maximum of 4 (the `four_species_structures_occur` test
    // below pins that the upper bound is reachable).
    let n_species = rng.int_range(1, 4.min(natoms));
    let chosen: Vec<usize> =
        rng.choose_k(palette.len(), n_species).into_iter().map(|i| palette[i]).collect();

    // Lattice constant from the mean pair equilibrium distance of the
    // chosen composition, so the relaxed fragment is near equilibrium.
    let mut r0_sum = 0.0;
    let mut count = 0.0;
    for &a in &chosen {
        for &b in &chosen {
            r0_sum += pair_params(a, b).r0;
            count += 1.0;
        }
    }
    let spacing = (r0_sum / count) * rng.range(0.98, 1.06);

    // Fill a cube of lattice sites large enough for natoms, alternating
    // species rock-salt style (checkerboard by site parity).
    let side = (natoms as f64).cbrt().ceil() as usize + 1;
    let mut sites: Vec<([f64; 3], usize)> = Vec::new();
    for ix in 0..side {
        for iy in 0..side {
            for iz in 0..side {
                let parity = (ix + iy + iz) % chosen.len().max(1);
                sites.push((
                    [ix as f64 * spacing, iy as f64 * spacing, iz as f64 * spacing],
                    parity,
                ));
            }
        }
    }
    // Keep the natoms sites closest to the cube center: a compact cluster.
    let c = (side - 1) as f64 * spacing / 2.0;
    sites.sort_by(|a, b| {
        let da = (a.0[0] - c).powi(2) + (a.0[1] - c).powi(2) + (a.0[2] - c).powi(2);
        let db = (b.0[0] - c).powi(2) + (b.0[1] - c).powi(2) + (b.0[2] - c).powi(2);
        da.partial_cmp(&db).unwrap()
    });
    sites.truncate(natoms);

    let mut species = Vec::with_capacity(natoms);
    let mut positions = Vec::with_capacity(natoms);
    for (pos, parity) in sites {
        species.push(chosen[parity % chosen.len()] as u8);
        // Thermal jitter.
        positions.push([
            pos[0] + rng.normal_scaled(0.0, 0.03 * spacing),
            pos[1] + rng.normal_scaled(0.0, 0.03 * spacing),
            pos[2] + rng.normal_scaled(0.0, 0.03 * spacing),
        ]);
    }
    (species, positions)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elements::mptrj_palette;

    #[test]
    fn builds_requested_size() {
        let mut rng = Rng::new(1);
        for natoms in [2, 5, 12, 30] {
            let (s, p) = build_crystal(&mut rng, &mptrj_palette(), natoms);
            assert_eq!(s.len(), natoms);
            assert_eq!(p.len(), natoms);
        }
    }

    #[test]
    fn at_most_four_species() {
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let (s, _) = build_crystal(&mut rng, &mptrj_palette(), 16);
            let mut uniq: Vec<u8> = s.clone();
            uniq.sort_unstable();
            uniq.dedup();
            assert!(uniq.len() <= 4);
        }
    }

    #[test]
    fn four_species_structures_occur() {
        // Regression guard for the composition draw's upper bound:
        // `int_range(1, 4)` is inclusive, so over a seeded sweep the full
        // 4-species compositions must actually appear (they would not if
        // the bound were exclusive).
        let mut rng = Rng::new(0xC0FFEE);
        let mut max_seen = 0usize;
        for _ in 0..200 {
            let (s, _) = build_crystal(&mut rng, &mptrj_palette(), 24);
            let mut uniq: Vec<u8> = s.clone();
            uniq.sort_unstable();
            uniq.dedup();
            max_seen = max_seen.max(uniq.len());
        }
        assert_eq!(
            max_seen, 4,
            "4-species structures must occur over a seeded sweep (saw max {max_seen})"
        );
    }

    #[test]
    fn cluster_is_compact() {
        // Max pairwise distance should be bounded by a few lattice spacings.
        let mut rng = Rng::new(3);
        let (_, p) = build_crystal(&mut rng, &mptrj_palette(), 27);
        let mut max_d2: f64 = 0.0;
        for i in 0..p.len() {
            for j in (i + 1)..p.len() {
                let d2 = (p[i][0] - p[j][0]).powi(2)
                    + (p[i][1] - p[j][1]).powi(2)
                    + (p[i][2] - p[j][2]).powi(2);
                max_d2 = max_d2.max(d2);
            }
        }
        assert!(max_d2.sqrt() < 30.0, "cluster too spread: {}", max_d2.sqrt());
    }

    #[test]
    fn no_overlapping_sites() {
        let mut rng = Rng::new(4);
        let (_, p) = build_crystal(&mut rng, &mptrj_palette(), 20);
        for i in 0..p.len() {
            for j in (i + 1)..p.len() {
                let d2 = (p[i][0] - p[j][0]).powi(2)
                    + (p[i][1] - p[j][1]).powi(2)
                    + (p[i][2] - p[j][2]).powi(2);
                assert!(d2 > 0.25, "sites {i},{j} overlap");
            }
        }
    }
}
