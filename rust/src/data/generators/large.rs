//! Bulk-structure builders for the large-structure task family (Supercell,
//! AmorphousBox): thousands-of-atom periodic-style slabs that do not fit a
//! single rank's batch budget and exist to exercise graph-parallel
//! (domain-decomposed) training. Unlike the cluster builder in
//! `inorganic.rs`, these fill a full cubic grid instead of carving a compact
//! cluster, so the atom count is exact and the geometry has genuine bulk
//! interior (most atoms see no surface within the model cutoff).

use crate::data::potential::pair_params;
use crate::util::rng::Rng;

/// Rock-salt style supercell: `reps^3` sites on a cubic grid, two palette
/// species interleaved by site parity, spacing set to the species pair's
/// Morse equilibrium distance (slightly randomized) so the lattice is
/// near-equilibrium without any relaxation pass. A small positional jitter
/// breaks exact symmetry so forces are non-trivial.
pub fn build_supercell(
    rng: &mut Rng,
    palette: &[usize],
    reps: usize,
) -> (Vec<u8>, Vec<[f64; 3]>) {
    assert!(reps >= 2, "supercell needs reps >= 2");
    let (za, zb) = if palette.len() >= 2 {
        let picks = rng.choose_k(palette.len(), 2);
        (palette[picks[0]], palette[picks[1]])
    } else {
        (palette[0], palette[0])
    };
    let spacing = pair_params(za, zb).r0 * rng.range(0.98, 1.04);
    let n = reps * reps * reps;
    let mut species: Vec<u8> = Vec::with_capacity(n);
    let mut positions: Vec<[f64; 3]> = Vec::with_capacity(n);
    let j = 0.02 * spacing;
    for ix in 0..reps {
        for iy in 0..reps {
            for iz in 0..reps {
                let z = if (ix + iy + iz) % 2 == 0 { za } else { zb };
                species.push(z as u8);
                positions.push([
                    spacing * ix as f64 + rng.range(-j, j),
                    spacing * iy as f64 + rng.range(-j, j),
                    spacing * iz as f64 + rng.range(-j, j),
                ]);
            }
        }
    }
    (species, positions)
}

/// Amorphous (glass-like) box: `natoms` atoms of random palette species on
/// a strongly jittered cubic grid. The jitter bound (10% of the grid
/// spacing per coordinate) keeps every pair separated by at least
/// ~0.65 x spacing, so the structure is disordered but overlap-free by
/// construction — no rejection sampling, which matters at this size.
pub fn build_amorphous_box(
    rng: &mut Rng,
    palette: &[usize],
    natoms: usize,
) -> (Vec<u8>, Vec<[f64; 3]>) {
    assert!(natoms >= 2, "amorphous box needs >= 2 atoms");
    let r0_mean =
        palette.iter().map(|&z| pair_params(z, z).r0).sum::<f64>() / palette.len() as f64;
    // Slightly open lattice (1.12 x mean like-pair equilibrium): amorphous
    // packings are less dense than crystals and the slack absorbs jitter.
    let spacing = r0_mean * 1.12 * rng.range(0.98, 1.04);
    let side = (natoms as f64).cbrt().ceil() as usize;
    let mut species: Vec<u8> = Vec::with_capacity(natoms);
    let mut positions: Vec<[f64; 3]> = Vec::with_capacity(natoms);
    let j = 0.10 * spacing;
    'fill: for ix in 0..side {
        for iy in 0..side {
            for iz in 0..side {
                if species.len() == natoms {
                    break 'fill;
                }
                species.push(palette[rng.below(palette.len())] as u8);
                positions.push([
                    spacing * ix as f64 + rng.range(-j, j),
                    spacing * iy as f64 + rng.range(-j, j),
                    spacing * iz as f64 + rng.range(-j, j),
                ]);
            }
        }
    }
    (species, positions)
}

#[cfg(test)]
mod tests {
    use super::*;

    const PALETTE: [usize; 5] = [12, 8, 11, 17, 22];

    fn min_pair_dist(positions: &[[f64; 3]]) -> f64 {
        let mut best = f64::INFINITY;
        for i in 0..positions.len() {
            for k in (i + 1)..positions.len() {
                let d2 = (positions[i][0] - positions[k][0]).powi(2)
                    + (positions[i][1] - positions[k][1]).powi(2)
                    + (positions[i][2] - positions[k][2]).powi(2);
                best = best.min(d2.sqrt());
            }
        }
        best
    }

    #[test]
    fn supercell_exact_count_and_two_species() {
        let mut rng = Rng::new(1);
        let (s, p) = build_supercell(&mut rng, &PALETTE, 5);
        assert_eq!(s.len(), 125);
        assert_eq!(p.len(), 125);
        let mut kinds: Vec<u8> = s.clone();
        kinds.sort_unstable();
        kinds.dedup();
        assert!(kinds.len() <= 2, "rock-salt motif uses at most two species");
        assert!(kinds.iter().all(|&z| PALETTE.contains(&(z as usize))));
    }

    #[test]
    fn supercell_no_overlaps() {
        let mut rng = Rng::new(2);
        let (_, p) = build_supercell(&mut rng, &PALETTE, 4);
        assert!(min_pair_dist(&p) > 1.0, "lattice sites must stay separated");
    }

    #[test]
    fn amorphous_exact_count_and_no_overlaps() {
        let mut rng = Rng::new(3);
        let (s, p) = build_amorphous_box(&mut rng, &PALETTE, 200);
        assert_eq!(s.len(), 200);
        assert_eq!(p.len(), 200);
        assert!(min_pair_dist(&p) > 1.0, "jitter bound must prevent overlaps");
        assert!(s.iter().all(|&z| PALETTE.contains(&(z as usize))));
    }

    #[test]
    fn builders_are_deterministic() {
        let (sa, pa) = build_supercell(&mut Rng::new(7), &PALETTE, 4);
        let (sb, pb) = build_supercell(&mut Rng::new(7), &PALETTE, 4);
        assert_eq!(sa, sb);
        assert_eq!(pa, pb);
        let (sa, pa) = build_amorphous_box(&mut Rng::new(8), &PALETTE, 100);
        let (sb, pb) = build_amorphous_box(&mut Rng::new(8), &PALETTE, 100);
        assert_eq!(sa, sb);
        assert_eq!(pa, pb);
    }

    #[test]
    fn amorphous_mixes_species() {
        let mut rng = Rng::new(9);
        let (s, _) = build_amorphous_box(&mut rng, &PALETTE, 300);
        let mut kinds: Vec<u8> = s;
        kinds.sort_unstable();
        kinds.dedup();
        assert!(kinds.len() >= 3, "300 draws over 5 species must mix");
    }
}
