//! Molecular structure builder for the organic datasets (ANI1x, QM7-X,
//! Transition1x): grows a random bonded tree with element-pair equilibrium
//! bond lengths, then decorates with hydrogens — producing the "many small
//! molecules" geometry class that dominates those sources.

use crate::data::potential::pair_params;
use crate::util::rng::Rng;

/// Minimum allowed distance between non-bonded atoms (Angstrom) during
/// placement; prevents pathological overlaps that would blow up the
/// ground-truth potential.
const MIN_SEP: f64 = 0.75;

/// Build a molecule with exactly `natoms` atoms drawn from `palette`
/// (hydrogen-biased like real organic chemistry).
pub fn build_molecule(
    rng: &mut Rng,
    palette: &[usize],
    natoms: usize,
) -> (Vec<u8>, Vec<[f64; 3]>) {
    assert!(natoms >= 2);
    // Weight hydrogen ~2x the heavy elements combined, like typical organics.
    let weights: Vec<f64> =
        palette.iter().map(|&z| if z == 1 { 2.0 * palette.len() as f64 } else { 1.0 }).collect();

    let mut species: Vec<u8> = Vec::with_capacity(natoms);
    // First atom must be heavy so the tree has a backbone.
    let heavy: Vec<usize> = palette.iter().copied().filter(|&z| z != 1).collect();
    species.push(heavy[rng.below(heavy.len())] as u8);
    for _ in 1..natoms {
        species.push(palette[rng.weighted(&weights)] as u8);
    }
    // Hydrogens bond to heavy atoms only; put them at the end so every H
    // can attach to an already-placed heavy atom.
    species.sort_by_key(|&z| if z == 1 { 1 } else { 0 });

    let positions = grow_tree(rng, &species);
    (species, positions)
}

/// QM7-X style: limit the number of *non-hydrogen* atoms to `max_heavy`,
/// then saturate with hydrogens up to `max_atoms`.
pub fn build_molecule_heavy_limited(
    rng: &mut Rng,
    palette: &[usize],
    max_heavy: usize,
    max_atoms: usize,
) -> (Vec<u8>, Vec<[f64; 3]>) {
    let heavy_palette: Vec<usize> = palette.iter().copied().filter(|&z| z != 1).collect();
    // Audited alongside inorganic.rs's composition draw: `Rng::int_range`
    // is INCLUSIVE on both ends, so `max_heavy` heavy atoms do occur (the
    // `heavy_limit_is_reachable` test below pins it).
    let n_heavy = rng.int_range(1, max_heavy).max(1);
    let n_h = rng.int_range(1, (2 * n_heavy + 2).min(max_atoms.saturating_sub(n_heavy)).max(1));

    let mut species: Vec<u8> = Vec::new();
    for _ in 0..n_heavy {
        species.push(heavy_palette[rng.below(heavy_palette.len())] as u8);
    }
    for _ in 0..n_h {
        species.push(1);
    }
    let positions = grow_tree(rng, &species);
    (species, positions)
}

/// Place atoms one at a time: each new atom bonds to a random previously
/// placed non-hydrogen atom at the pair's Morse equilibrium distance, in a
/// random direction, with overlap rejection.
fn grow_tree(rng: &mut Rng, species: &[u8]) -> Vec<[f64; 3]> {
    let n = species.len();
    let mut positions: Vec<[f64; 3]> = Vec::with_capacity(n);
    positions.push([0.0, 0.0, 0.0]);

    for i in 1..n {
        // Candidate anchors: heavy atoms already placed (or any if none).
        let anchors: Vec<usize> = (0..i).filter(|&j| species[j] != 1).collect();
        let anchor = if anchors.is_empty() { rng.below(i) } else { anchors[rng.below(anchors.len())] };
        let r0 = pair_params(species[anchor] as usize, species[i] as usize).r0;

        let mut placed = None;
        for attempt in 0..64 {
            let dir = rng.unit3();
            // Allow slight bond-length variation; relax later anyway.
            let bond = r0 * rng.range(0.95, 1.10);
            let cand = [
                positions[anchor][0] + bond * dir[0],
                positions[anchor][1] + bond * dir[1],
                positions[anchor][2] + bond * dir[2],
            ];
            let min_sep = if attempt < 48 { MIN_SEP } else { MIN_SEP * 0.8 };
            let ok = positions.iter().enumerate().all(|(j, p)| {
                if j == anchor {
                    return true;
                }
                let d2 = (p[0] - cand[0]).powi(2)
                    + (p[1] - cand[1]).powi(2)
                    + (p[2] - cand[2]).powi(2);
                d2 > min_sep * min_sep
            });
            if ok {
                placed = Some(cand);
                break;
            }
        }
        // Fall back to a slightly longer bond if crowded.
        positions.push(placed.unwrap_or_else(|| {
            let dir = rng.unit3();
            [
                positions[anchor][0] + 1.6 * r0 * dir[0],
                positions[anchor][1] + 1.6 * r0 * dir[1],
                positions[anchor][2] + 1.6 * r0 * dir[2],
            ]
        }));
    }
    positions
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elements::ani1x_palette;

    #[test]
    fn builds_requested_size() {
        let mut rng = Rng::new(1);
        let (s, p) = build_molecule(&mut rng, &ani1x_palette(), 10);
        assert_eq!(s.len(), 10);
        assert_eq!(p.len(), 10);
    }

    #[test]
    fn first_atom_is_heavy() {
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let (s, _) = build_molecule(&mut rng, &ani1x_palette(), 6);
            assert_ne!(s[0], 1, "backbone must start with a heavy atom");
        }
    }

    #[test]
    fn atoms_not_on_top_of_each_other() {
        let mut rng = Rng::new(3);
        for _ in 0..10 {
            let (_, p) = build_molecule(&mut rng, &ani1x_palette(), 12);
            for i in 0..p.len() {
                for j in (i + 1)..p.len() {
                    let d2 = (p[i][0] - p[j][0]).powi(2)
                        + (p[i][1] - p[j][1]).powi(2)
                        + (p[i][2] - p[j][2]).powi(2);
                    assert!(d2 > 0.2, "atoms {i},{j} overlap: d^2={d2}");
                }
            }
        }
    }

    #[test]
    fn molecule_is_connected_within_cutoff() {
        // Union-find over pairs within the potential cutoff: one component.
        let mut rng = Rng::new(4);
        let (s, p) = build_molecule(&mut rng, &ani1x_palette(), 12);
        let n = s.len();
        let mut parent: Vec<usize> = (0..n).collect();
        fn find(parent: &mut Vec<usize>, i: usize) -> usize {
            if parent[i] != i {
                let r = find(parent, parent[i]);
                parent[i] = r;
            }
            parent[i]
        }
        for i in 0..n {
            for j in (i + 1)..n {
                let d2 = (p[i][0] - p[j][0]).powi(2)
                    + (p[i][1] - p[j][1]).powi(2)
                    + (p[i][2] - p[j][2]).powi(2);
                if d2 < 36.0 {
                    let (ri, rj) = (find(&mut parent, i), find(&mut parent, j));
                    parent[ri] = rj;
                }
            }
        }
        let root = find(&mut parent, 0);
        for i in 1..n {
            assert_eq!(find(&mut parent, i), root, "atom {i} disconnected");
        }
    }

    #[test]
    fn heavy_limit_is_reachable() {
        // The inclusive `int_range(1, max_heavy)` draw must actually reach
        // the documented maximum over a seeded sweep (regression guard for
        // an exclusive-upper-bound off-by-one).
        let mut rng = Rng::new(0xBEEF);
        let mut max_seen = 0usize;
        for _ in 0..100 {
            let (s, _) = build_molecule_heavy_limited(
                &mut rng,
                &crate::elements::qm7x_palette(),
                7,
                24,
            );
            max_seen = max_seen.max(s.iter().filter(|&&z| z != 1).count());
        }
        assert_eq!(max_seen, 7, "7-heavy molecules must occur (saw max {max_seen})");
    }

    #[test]
    fn heavy_limited_respects_limit() {
        let mut rng = Rng::new(5);
        for _ in 0..30 {
            let (s, _) = build_molecule_heavy_limited(
                &mut rng,
                &crate::elements::qm7x_palette(),
                7,
                24,
            );
            assert!(s.iter().filter(|&&z| z != 1).count() <= 7);
            assert!(s.iter().any(|&z| z == 1), "must contain hydrogens");
        }
    }
}
