//! Radius-graph construction: turns an `AtomicStructure` into the directed
//! edge list the EGNN encoder consumes (both directions of every pair within
//! the cutoff). Uses a cell-list spatial hash so batch assembly stays O(n)
//! per structure — this sits on the data hot path of every training step.

use crate::data::structures::AtomicStructure;

/// One directed edge with precomputed geometry (the L2 model takes geometry
/// as inputs rather than raw positions; see python/compile/model.py).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Edge {
    pub src: u32,
    pub dst: u32,
    /// Unit vector x_src - x_dst.
    pub rel_hat: [f32; 3],
    /// Edge length, Angstrom.
    pub dist: f32,
}

/// Radius graph over a structure. Edges are emitted in both directions.
pub fn radius_graph(structure: &AtomicStructure, cutoff: f64) -> Vec<Edge> {
    radius_graph_positions(&structure.positions, cutoff)
}

/// Radius graph over raw positions.
pub fn radius_graph_positions(positions: &[[f64; 3]], cutoff: f64) -> Vec<Edge> {
    let n = positions.len();
    if n < 2 {
        return Vec::new();
    }
    // Cell list with cell size = cutoff: each atom only checks 27 cells.
    let mut lo = [f64::INFINITY; 3];
    for p in positions {
        for k in 0..3 {
            lo[k] = lo[k].min(p[k]);
        }
    }
    let cell_of = |p: &[f64; 3]| -> (i64, i64, i64) {
        (
            ((p[0] - lo[0]) / cutoff) as i64,
            ((p[1] - lo[1]) / cutoff) as i64,
            ((p[2] - lo[2]) / cutoff) as i64,
        )
    };
    let mut cells: std::collections::HashMap<(i64, i64, i64), Vec<usize>> =
        std::collections::HashMap::new();
    for (i, p) in positions.iter().enumerate() {
        cells.entry(cell_of(p)).or_default().push(i);
    }

    let c2 = cutoff * cutoff;
    let mut edges = Vec::new();
    for (i, pi) in positions.iter().enumerate() {
        let (cx, cy, cz) = cell_of(pi);
        for dx in -1..=1 {
            for dy in -1..=1 {
                for dz in -1..=1 {
                    let Some(neighbors) = cells.get(&(cx + dx, cy + dy, cz + dz)) else {
                        continue;
                    };
                    for &j in neighbors {
                        if j == i {
                            continue;
                        }
                        let pj = &positions[j];
                        let rx = pi[0] - pj[0];
                        let ry = pi[1] - pj[1];
                        let rz = pi[2] - pj[2];
                        let d2 = rx * rx + ry * ry + rz * rz;
                        if d2 > c2 || d2 < 1e-12 {
                            continue;
                        }
                        let d = d2.sqrt();
                        edges.push(Edge {
                            src: i as u32,
                            dst: j as u32,
                            rel_hat: [(rx / d) as f32, (ry / d) as f32, (rz / d) as f32],
                            dist: d as f32,
                        });
                    }
                }
            }
        }
    }
    // Deterministic order regardless of hash iteration: sort by (src, dst).
    edges.sort_unstable_by_key(|e| (e.src, e.dst));
    edges
}

/// Brute-force O(n^2) reference used by tests to validate the cell list.
pub fn radius_graph_brute(positions: &[[f64; 3]], cutoff: f64) -> Vec<Edge> {
    let c2 = cutoff * cutoff;
    let mut edges = Vec::new();
    for i in 0..positions.len() {
        for j in 0..positions.len() {
            if i == j {
                continue;
            }
            let rx = positions[i][0] - positions[j][0];
            let ry = positions[i][1] - positions[j][1];
            let rz = positions[i][2] - positions[j][2];
            let d2 = rx * rx + ry * ry + rz * rz;
            if d2 > c2 || d2 < 1e-12 {
                continue;
            }
            let d = d2.sqrt();
            edges.push(Edge {
                src: i as u32,
                dst: j as u32,
                rel_hat: [(rx / d) as f32, (ry / d) as f32, (rz / d) as f32],
                dist: d as f32,
            });
        }
    }
    edges.sort_unstable_by_key(|e| (e.src, e.dst));
    edges
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_positions(rng: &mut Rng, n: usize, span: f64) -> Vec<[f64; 3]> {
        (0..n)
            .map(|_| [rng.range(0.0, span), rng.range(0.0, span), rng.range(0.0, span)])
            .collect()
    }

    #[test]
    fn matches_brute_force() {
        let mut rng = Rng::new(1);
        for trial in 0..20 {
            let n = rng.int_range(2, 40);
            let span = rng.range(3.0, 15.0);
            let pos = random_positions(&mut rng, n, span);
            let fast = radius_graph_positions(&pos, 4.5);
            let brute = radius_graph_brute(&pos, 4.5);
            assert_eq!(fast, brute, "trial {trial} n={n} span={span}");
        }
    }

    #[test]
    fn edges_are_bidirectional() {
        let mut rng = Rng::new(2);
        let pos = random_positions(&mut rng, 20, 6.0);
        let edges = radius_graph_positions(&pos, 5.0);
        for e in &edges {
            assert!(
                edges.iter().any(|r| r.src == e.dst && r.dst == e.src),
                "missing reverse of {e:?}"
            );
        }
    }

    #[test]
    fn rel_hat_is_unit_and_antisymmetric() {
        let mut rng = Rng::new(3);
        let pos = random_positions(&mut rng, 15, 5.0);
        let edges = radius_graph_positions(&pos, 6.0);
        for e in &edges {
            let n = (e.rel_hat[0].powi(2) + e.rel_hat[1].powi(2) + e.rel_hat[2].powi(2)).sqrt();
            assert!((n - 1.0).abs() < 1e-5);
            let rev = edges.iter().find(|r| r.src == e.dst && r.dst == e.src).unwrap();
            for k in 0..3 {
                assert!((e.rel_hat[k] + rev.rel_hat[k]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn no_self_edges_and_within_cutoff() {
        let mut rng = Rng::new(4);
        let pos = random_positions(&mut rng, 30, 8.0);
        for e in radius_graph_positions(&pos, 4.0) {
            assert_ne!(e.src, e.dst);
            assert!(e.dist <= 4.0 + 1e-6);
            assert!(e.dist > 0.0);
        }
    }

    #[test]
    fn empty_and_single_atom() {
        assert!(radius_graph_positions(&[], 5.0).is_empty());
        assert!(radius_graph_positions(&[[0.0; 3]], 5.0).is_empty());
    }
}
