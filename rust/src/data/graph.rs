//! Radius-graph construction: turns an `AtomicStructure` into the directed
//! edge list the EGNN encoder consumes (both directions of every pair within
//! the cutoff). Two paths, both bit-identical to the seed implementation:
//! a direct O(n^2) scan for small molecules and a flat bucketed cell grid
//! (counting-sort layout) for larger systems. Edges are emitted already
//! sorted by `(src, dst)` — sources ascend by construction and each source's
//! neighbor set is sorted in place — so the seed's global
//! `sort_unstable_by_key` is reduced to a verify-only debug assertion.
//!
//! The featurize-once pipeline (`data::featurized`) calls this exactly once
//! per structure; the process-wide [`radius_graph_call_count`] counter lets
//! tests prove warm-epoch planning performs zero graph constructions.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::data::structures::AtomicStructure;

/// One directed edge with precomputed geometry (the L2 model takes geometry
/// as inputs rather than raw positions; see python/compile/model.py).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Edge {
    pub src: u32,
    pub dst: u32,
    /// Unit vector x_src - x_dst.
    pub rel_hat: [f32; 3],
    /// Edge length, Angstrom.
    pub dist: f32,
}

/// Process-wide count of radius-graph constructions.
static RADIUS_GRAPH_CALLS: AtomicU64 = AtomicU64::new(0);

/// Number of radius-graph constructions performed by this process. The
/// featurized store builds every graph exactly once up front; tests assert
/// warm-cache epoch planning leaves this counter untouched.
pub fn radius_graph_call_count() -> u64 {
    RADIUS_GRAPH_CALLS.load(Ordering::Relaxed)
}

/// Below this atom count a direct O(n^2) scan beats any spatial index
/// (typical molecular samples are 10-30 atoms; hashing/bucketing overhead
/// dominates there — see BENCH_hot_paths.json).
const DENSE_CUTOVER: usize = 48;

/// Whether a structure of `n` atoms takes the cell-grid path rather than
/// the dense O(n^2) scan. Exposed so the graph-parallel suite can assert
/// that the large-structure generators land strictly above the cutover —
/// a bulk structure silently falling back to the dense scan would hide a
/// quadratic blowup in the halo-plan build.
pub fn uses_grid_path(n: usize) -> bool {
    n > DENSE_CUTOVER
}

/// Radius graph over a structure. Edges are emitted in both directions.
pub fn radius_graph(structure: &AtomicStructure, cutoff: f64) -> Vec<Edge> {
    radius_graph_positions(&structure.positions, cutoff)
}

/// Radius graph over raw positions, sorted by `(src, dst)`.
pub fn radius_graph_positions(positions: &[[f64; 3]], cutoff: f64) -> Vec<Edge> {
    RADIUS_GRAPH_CALLS.fetch_add(1, Ordering::Relaxed);
    let n = positions.len();
    if n < 2 {
        return Vec::new();
    }
    let edges = if n <= DENSE_CUTOVER {
        dense_scan(positions, cutoff)
    } else {
        grid_scan(positions, cutoff)
    };
    debug_assert!(
        edges.windows(2).all(|w| (w[0].src, w[0].dst) < (w[1].src, w[1].dst)),
        "edges must come out strictly (src, dst)-sorted"
    );
    edges
}

/// Emit the `i -> j` edge if the pair is inside the cutoff. The float
/// operations (and their order) match the seed implementation exactly.
#[inline]
fn push_edge_if_close(
    edges: &mut Vec<Edge>,
    i: usize,
    j: usize,
    pi: &[f64; 3],
    pj: &[f64; 3],
    c2: f64,
) {
    let rx = pi[0] - pj[0];
    let ry = pi[1] - pj[1];
    let rz = pi[2] - pj[2];
    let d2 = rx * rx + ry * ry + rz * rz;
    if d2 > c2 || d2 < 1e-12 {
        return;
    }
    let d = d2.sqrt();
    edges.push(Edge {
        src: i as u32,
        dst: j as u32,
        rel_hat: [(rx / d) as f32, (ry / d) as f32, (rz / d) as f32],
        dist: d as f32,
    });
}

/// Direct pairwise scan: naturally emits in (src, dst) order.
fn dense_scan(positions: &[[f64; 3]], cutoff: f64) -> Vec<Edge> {
    let c2 = cutoff * cutoff;
    let mut edges = Vec::new();
    for (i, pi) in positions.iter().enumerate() {
        for (j, pj) in positions.iter().enumerate() {
            if i == j {
                continue;
            }
            push_edge_if_close(&mut edges, i, j, pi, pj, c2);
        }
    }
    edges
}

/// Cell binning: a flat counting-sort grid over the bounding box when the
/// box is dense enough to materialize, sorted-key buckets otherwise (sparse
/// or elongated systems). Either way the per-cell membership is identical to
/// the seed's `HashMap<(i64,i64,i64), Vec<usize>>` — but the sparse arm uses
/// a `BTreeMap` so iteration order (and any future traversal of the index)
/// is a pure function of the coordinates, never of `RandomState`.
enum CellIndex {
    Flat { dims: [i64; 3], start: Vec<u32>, items: Vec<u32> },
    Hashed(std::collections::BTreeMap<[i64; 3], Vec<u32>>),
}

impl CellIndex {
    fn build(coords: &[[i64; 3]], dims: [i64; 3]) -> CellIndex {
        let n = coords.len();
        let ncells = dims[0].checked_mul(dims[1]).and_then(|a| a.checked_mul(dims[2]));
        match ncells {
            // Memory cap: the flat grid spends 4 bytes per cell; fall back
            // to hashing when the box is overwhelmingly empty.
            Some(nc) if nc > 0 && (nc as u128) <= 64 * n as u128 + 1024 => {
                let nc = nc as usize;
                let id = |c: &[i64; 3]| ((c[0] * dims[1] + c[1]) * dims[2] + c[2]) as usize;
                let mut start = vec![0u32; nc + 1];
                for c in coords {
                    start[id(c) + 1] += 1;
                }
                for k in 1..=nc {
                    start[k] += start[k - 1];
                }
                // Stable placement: atoms within a cell stay in index order.
                let mut items = vec![0u32; n];
                let mut cursor = start.clone();
                for (i, c) in coords.iter().enumerate() {
                    let cell = id(c);
                    items[cursor[cell] as usize] = i as u32;
                    cursor[cell] += 1;
                }
                CellIndex::Flat { dims, start, items }
            }
            _ => {
                let mut map: std::collections::BTreeMap<[i64; 3], Vec<u32>> =
                    std::collections::BTreeMap::new();
                for (i, c) in coords.iter().enumerate() {
                    map.entry(*c).or_default().push(i as u32);
                }
                CellIndex::Hashed(map)
            }
        }
    }

    /// Append every atom in cell `c` to `out`.
    #[inline]
    fn extend_cell(&self, c: [i64; 3], out: &mut Vec<u32>) {
        match self {
            CellIndex::Flat { dims, start, items } => {
                if c.iter().zip(dims).any(|(&x, &d)| !(0..d).contains(&x)) {
                    return;
                }
                let id = ((c[0] * dims[1] + c[1]) * dims[2] + c[2]) as usize;
                out.extend_from_slice(&items[start[id] as usize..start[id + 1] as usize]);
            }
            CellIndex::Hashed(map) => {
                if let Some(v) = map.get(&c) {
                    out.extend_from_slice(v);
                }
            }
        }
    }
}

fn grid_scan(positions: &[[f64; 3]], cutoff: f64) -> Vec<Edge> {
    let mut lo = [f64::INFINITY; 3];
    for p in positions {
        for k in 0..3 {
            lo[k] = lo[k].min(p[k]);
        }
    }
    // Identical cell assignment to the seed: floor((p - lo) / cutoff). The
    // exact expression matters — the 27-cell sweep is correct either way,
    // but candidate sets (hence float-op order) must match the seed's.
    let coords: Vec<[i64; 3]> = positions
        .iter()
        .map(|p| {
            [
                ((p[0] - lo[0]) / cutoff) as i64,
                ((p[1] - lo[1]) / cutoff) as i64,
                ((p[2] - lo[2]) / cutoff) as i64,
            ]
        })
        .collect();
    let mut dims = [1i64; 3];
    for c in &coords {
        for k in 0..3 {
            dims[k] = dims[k].max(c[k].saturating_add(1));
        }
    }
    let index = CellIndex::build(&coords, dims);

    let c2 = cutoff * cutoff;
    let mut edges = Vec::new();
    let mut cellbuf: Vec<u32> = Vec::new();
    let mut neigh: Vec<(u32, f64)> = Vec::new();
    for (i, pi) in positions.iter().enumerate() {
        let c = coords[i];
        cellbuf.clear();
        for dx in -1..=1 {
            for dy in -1..=1 {
                for dz in -1..=1 {
                    index.extend_cell([c[0] + dx, c[1] + dy, c[2] + dz], &mut cellbuf);
                }
            }
        }
        neigh.clear();
        for &j in &cellbuf {
            if j as usize == i {
                continue;
            }
            let pj = &positions[j as usize];
            let rx = pi[0] - pj[0];
            let ry = pi[1] - pj[1];
            let rz = pi[2] - pj[2];
            let d2 = rx * rx + ry * ry + rz * rz;
            if d2 > c2 || d2 < 1e-12 {
                continue;
            }
            neigh.push((j, d2.sqrt()));
        }
        // Tiny per-atom sort replaces the seed's global edge sort.
        neigh.sort_unstable_by_key(|&(j, _)| j);
        for &(j, d) in &neigh {
            let pj = &positions[j as usize];
            let rx = pi[0] - pj[0];
            let ry = pi[1] - pj[1];
            let rz = pi[2] - pj[2];
            edges.push(Edge {
                src: i as u32,
                dst: j,
                rel_hat: [(rx / d) as f32, (ry / d) as f32, (rz / d) as f32],
                dist: d as f32,
            });
        }
    }
    edges
}

/// The seed implementation (hash-map cell list + global edge sort), kept as
/// the before/after baseline for `BENCH_hot_paths.json` and as a
/// differential-testing oracle. Not on any hot path.
pub fn radius_graph_positions_reference(positions: &[[f64; 3]], cutoff: f64) -> Vec<Edge> {
    let n = positions.len();
    if n < 2 {
        return Vec::new();
    }
    let mut lo = [f64::INFINITY; 3];
    for p in positions {
        for k in 0..3 {
            lo[k] = lo[k].min(p[k]);
        }
    }
    let cell_of = |p: &[f64; 3]| -> (i64, i64, i64) {
        (
            ((p[0] - lo[0]) / cutoff) as i64,
            ((p[1] - lo[1]) / cutoff) as i64,
            ((p[2] - lo[2]) / cutoff) as i64,
        )
    };
    // lint:allow(nondeterministic): test oracle off the hot path; edges globally sorted below
    let mut cells: std::collections::HashMap<(i64, i64, i64), Vec<usize>> =
        std::collections::HashMap::new();
    for (i, p) in positions.iter().enumerate() {
        cells.entry(cell_of(p)).or_default().push(i);
    }

    let c2 = cutoff * cutoff;
    let mut edges = Vec::new();
    for (i, pi) in positions.iter().enumerate() {
        let (cx, cy, cz) = cell_of(pi);
        for dx in -1..=1 {
            for dy in -1..=1 {
                for dz in -1..=1 {
                    let Some(neighbors) = cells.get(&(cx + dx, cy + dy, cz + dz)) else {
                        continue;
                    };
                    for &j in neighbors {
                        if j == i {
                            continue;
                        }
                        push_edge_if_close(&mut edges, i, j, pi, &positions[j], c2);
                    }
                }
            }
        }
    }
    edges.sort_unstable_by_key(|e| (e.src, e.dst));
    edges
}

/// Brute-force O(n^2) reference used by tests to validate the cell list.
pub fn radius_graph_brute(positions: &[[f64; 3]], cutoff: f64) -> Vec<Edge> {
    let c2 = cutoff * cutoff;
    let mut edges = Vec::new();
    for i in 0..positions.len() {
        for j in 0..positions.len() {
            if i == j {
                continue;
            }
            push_edge_if_close(&mut edges, i, j, &positions[i], &positions[j], c2);
        }
    }
    edges.sort_unstable_by_key(|e| (e.src, e.dst));
    edges
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_positions(rng: &mut Rng, n: usize, span: f64) -> Vec<[f64; 3]> {
        (0..n)
            .map(|_| [rng.range(0.0, span), rng.range(0.0, span), rng.range(0.0, span)])
            .collect()
    }

    #[test]
    fn matches_brute_force() {
        let mut rng = Rng::new(1);
        for trial in 0..20 {
            let n = rng.int_range(2, 40);
            let span = rng.range(3.0, 15.0);
            let pos = random_positions(&mut rng, n, span);
            let fast = radius_graph_positions(&pos, 4.5);
            let brute = radius_graph_brute(&pos, 4.5);
            assert_eq!(fast, brute, "trial {trial} n={n} span={span}");
        }
    }

    #[test]
    fn grid_path_matches_brute_and_reference() {
        // n > DENSE_CUTOVER exercises the flat counting-sort grid.
        let mut rng = Rng::new(6);
        for trial in 0..8 {
            let n = rng.int_range(DENSE_CUTOVER + 1, 220);
            let span = rng.range(4.0, 25.0);
            let pos = random_positions(&mut rng, n, span);
            let fast = radius_graph_positions(&pos, 4.5);
            assert_eq!(fast, radius_graph_brute(&pos, 4.5), "brute, trial {trial}");
            assert_eq!(
                fast,
                radius_graph_positions_reference(&pos, 4.5),
                "seed reference, trial {trial}"
            );
        }
    }

    #[test]
    fn negative_coordinates_are_handled() {
        let mut rng = Rng::new(7);
        for _ in 0..6 {
            let n = rng.int_range(2, 120);
            let pos: Vec<[f64; 3]> = (0..n)
                .map(|_| {
                    [rng.range(-12.0, 5.0), rng.range(-30.0, -10.0), rng.range(-1.0, 1.0)]
                })
                .collect();
            assert_eq!(radius_graph_positions(&pos, 3.5), radius_graph_brute(&pos, 3.5));
        }
    }

    #[test]
    fn degenerate_and_sparse_layouts() {
        // Coincident atoms: filtered by the d2 < 1e-12 guard, never NaN.
        let dup = vec![[1.0, 2.0, 3.0]; 60];
        assert!(radius_graph_positions(&dup, 5.0).is_empty());

        // Collinear chain: grid degenerates to 1x1xN.
        let chain: Vec<[f64; 3]> = (0..100).map(|i| [i as f64 * 0.9, 0.0, 0.0]).collect();
        assert_eq!(radius_graph_positions(&chain, 2.0), radius_graph_brute(&chain, 2.0));

        // Huge sparse span: the flat grid would explode, forcing the hashed
        // fallback; output must stay identical.
        let mut rng = Rng::new(8);
        let sparse: Vec<[f64; 3]> = (0..80)
            .map(|_| [rng.range(0.0, 900.0), rng.range(0.0, 900.0), rng.range(0.0, 900.0)])
            .collect();
        assert_eq!(radius_graph_positions(&sparse, 2.0), radius_graph_brute(&sparse, 2.0));
    }

    #[test]
    fn call_counter_increments() {
        let pos = vec![[0.0, 0.0, 0.0], [1.0, 0.0, 0.0]];
        let before = radius_graph_call_count();
        radius_graph_positions(&pos, 5.0);
        assert!(radius_graph_call_count() > before);
    }

    #[test]
    fn edges_are_bidirectional() {
        let mut rng = Rng::new(2);
        let pos = random_positions(&mut rng, 20, 6.0);
        let edges = radius_graph_positions(&pos, 5.0);
        for e in &edges {
            assert!(
                edges.iter().any(|r| r.src == e.dst && r.dst == e.src),
                "missing reverse of {e:?}"
            );
        }
    }

    #[test]
    fn rel_hat_is_unit_and_antisymmetric() {
        let mut rng = Rng::new(3);
        let pos = random_positions(&mut rng, 15, 5.0);
        let edges = radius_graph_positions(&pos, 6.0);
        for e in &edges {
            let n = (e.rel_hat[0].powi(2) + e.rel_hat[1].powi(2) + e.rel_hat[2].powi(2)).sqrt();
            assert!((n - 1.0).abs() < 1e-5);
            let rev = edges.iter().find(|r| r.src == e.dst && r.dst == e.src).unwrap();
            for k in 0..3 {
                assert!((e.rel_hat[k] + rev.rel_hat[k]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn no_self_edges_and_within_cutoff() {
        let mut rng = Rng::new(4);
        let pos = random_positions(&mut rng, 30, 8.0);
        for e in radius_graph_positions(&pos, 4.0) {
            assert_ne!(e.src, e.dst);
            assert!(e.dist <= 4.0 + 1e-6);
            assert!(e.dist > 0.0);
        }
    }

    #[test]
    fn empty_and_single_atom() {
        assert!(radius_graph_positions(&[], 5.0).is_empty());
        assert!(radius_graph_positions(&[[0.0; 3]], 5.0).is_empty());
    }
}
