//! Padded static-shape graph batching.
//!
//! The AOT artifacts have fixed input shapes (max_nodes / max_edges /
//! max_graphs); this module packs a list of structures into one padded
//! batch whose field set matches `manifest.json["batch"]` exactly, and a
//! greedy planner that splits a stream of structures into batches without
//! overflowing any budget. This is the L3 side of the data hot path:
//! batches come out of a [`BatchPool`] (buffer reuse via
//! [`GraphBatch::clear`], no per-batch reallocation) and are marshalled to
//! the runtime through [`GraphBatch::field_literal`], which reads the batch
//! buffers in place instead of cloning them into intermediate tensors.

use crate::data::graph::{radius_graph, Edge};
use crate::data::structures::AtomicStructure;
use crate::runtime::pjrt as xla;
use crate::tensor::Tensor;

/// Static batch geometry (mirrors python ModelConfig / manifest "config").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchDims {
    pub max_nodes: usize,
    pub max_edges: usize,
    pub max_graphs: usize,
}

impl BatchDims {
    /// Whether a single structure of `natoms`/`nedges` can ever be packed
    /// (the serving admission check: budget is nodes/edges, not requests).
    pub fn admits(&self, natoms: usize, nedges: usize) -> bool {
        natoms <= self.max_nodes && nedges <= self.max_edges
    }
}

/// One padded batch, laid out exactly as the artifacts expect.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphBatch {
    pub dims: BatchDims,
    pub species: Vec<i32>,      // [N]
    pub edge_src: Vec<i32>,     // [E]
    pub edge_dst: Vec<i32>,     // [E]
    pub rel_hat: Vec<f32>,      // [E*3]
    pub dist: Vec<f32>,         // [E]
    pub node_mask: Vec<f32>,    // [N]
    pub edge_mask: Vec<f32>,    // [E]
    pub node_graph: Vec<i32>,   // [N]
    pub graph_mask: Vec<f32>,   // [G]
    pub inv_atoms: Vec<f32>,    // [G]
    pub y_energy: Vec<f32>,     // [G] energy per atom
    pub y_forces: Vec<f32>,     // [N*3]
    /// Real (unpadded) counts.
    pub n_nodes: usize,
    pub n_edges: usize,
    pub n_graphs: usize,
}

#[derive(Debug)]
pub enum BatchError {
    TooLarge { natoms: usize, nedges: usize, dims: BatchDims },
    Full,
}

impl std::fmt::Display for BatchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BatchError::TooLarge { natoms, nedges, dims } => write!(
                f,
                "structure does not fit: {natoms} atoms / {nedges} edges vs budget {dims:?}"
            ),
            BatchError::Full => write!(f, "batch is full"),
        }
    }
}

impl std::error::Error for BatchError {}

impl GraphBatch {
    pub fn empty(dims: BatchDims) -> GraphBatch {
        GraphBatch {
            dims,
            species: vec![0; dims.max_nodes],
            edge_src: vec![0; dims.max_edges],
            edge_dst: vec![0; dims.max_edges],
            rel_hat: vec![0.0; dims.max_edges * 3],
            dist: vec![0.0; dims.max_edges],
            node_mask: vec![0.0; dims.max_nodes],
            edge_mask: vec![0.0; dims.max_edges],
            // Padding nodes point at the last (always padded-if-any-padding)
            // graph slot; masked out everywhere.
            node_graph: vec![(dims.max_graphs - 1) as i32; dims.max_nodes],
            graph_mask: vec![0.0; dims.max_graphs],
            inv_atoms: vec![0.0; dims.max_graphs],
            y_energy: vec![0.0; dims.max_graphs],
            y_forces: vec![0.0; dims.max_nodes * 3],
            n_nodes: 0,
            n_edges: 0,
            n_graphs: 0,
        }
    }

    /// Reset to empty without reallocating (hot-loop reuse).
    pub fn clear(&mut self) {
        self.species[..self.n_nodes].fill(0);
        self.node_mask[..self.n_nodes].fill(0.0);
        self.node_graph[..self.n_nodes].fill((self.dims.max_graphs - 1) as i32);
        self.y_forces[..self.n_nodes * 3].fill(0.0);
        self.edge_src[..self.n_edges].fill(0);
        self.edge_dst[..self.n_edges].fill(0);
        self.rel_hat[..self.n_edges * 3].fill(0.0);
        self.dist[..self.n_edges].fill(0.0);
        self.edge_mask[..self.n_edges].fill(0.0);
        self.graph_mask[..self.n_graphs].fill(0.0);
        self.inv_atoms[..self.n_graphs].fill(0.0);
        self.y_energy[..self.n_graphs].fill(0.0);
        self.n_nodes = 0;
        self.n_edges = 0;
        self.n_graphs = 0;
    }

    /// Whether a structure with `natoms`/`nedges` fits in the remaining room.
    pub fn fits(&self, natoms: usize, nedges: usize) -> bool {
        self.n_nodes + natoms <= self.dims.max_nodes
            && self.n_edges + nedges <= self.dims.max_edges
            && self.n_graphs + 1 <= self.dims.max_graphs
    }

    /// Append one structure (with its precomputed edges).
    pub fn push(
        &mut self,
        s: &AtomicStructure,
        edges: &[Edge],
    ) -> Result<(), BatchError> {
        self.push_raw(&s.species, &s.forces, s.energy_per_atom(), edges)
    }

    /// Append one structure from raw field slices — the featurized-store
    /// path, which packs cached flat arrays without materializing an
    /// `AtomicStructure`. Float conversions are identical to [`Self::push`].
    pub fn push_raw(
        &mut self,
        species: &[u8],
        forces: &[[f64; 3]],
        energy_per_atom: f64,
        edges: &[Edge],
    ) -> Result<(), BatchError> {
        let natoms = species.len();
        if natoms > self.dims.max_nodes || edges.len() > self.dims.max_edges {
            return Err(BatchError::TooLarge {
                natoms,
                nedges: edges.len(),
                dims: self.dims,
            });
        }
        if !self.fits(natoms, edges.len()) {
            return Err(BatchError::Full);
        }
        let base = self.n_nodes;
        let g = self.n_graphs;
        for (i, (&z, f)) in species.iter().zip(forces).enumerate() {
            let n = base + i;
            self.species[n] = z as i32;
            self.node_mask[n] = 1.0;
            self.node_graph[n] = g as i32;
            self.y_forces[n * 3] = f[0] as f32;
            self.y_forces[n * 3 + 1] = f[1] as f32;
            self.y_forces[n * 3 + 2] = f[2] as f32;
        }
        for (k, e) in edges.iter().enumerate() {
            let idx = self.n_edges + k;
            self.edge_src[idx] = (base + e.src as usize) as i32;
            self.edge_dst[idx] = (base + e.dst as usize) as i32;
            self.rel_hat[idx * 3] = e.rel_hat[0];
            self.rel_hat[idx * 3 + 1] = e.rel_hat[1];
            self.rel_hat[idx * 3 + 2] = e.rel_hat[2];
            self.dist[idx] = e.dist;
            self.edge_mask[idx] = 1.0;
        }
        self.graph_mask[g] = 1.0;
        self.inv_atoms[g] = 1.0 / natoms as f32;
        self.y_energy[g] = energy_per_atom as f32;
        self.n_nodes += natoms;
        self.n_edges += edges.len();
        self.n_graphs += 1;
        Ok(())
    }

    /// Append one structure for inference: identical to [`Self::push_raw`]
    /// except that no labels are written — `y_energy`/`y_forces` keep the
    /// zeros a cleared batch already holds. The forward pass never reads
    /// labels, so a batch packed this way produces bit-identical
    /// predictions to one packed with [`Self::push`].
    pub fn push_inference(&mut self, species: &[u8], edges: &[Edge]) -> Result<(), BatchError> {
        let natoms = species.len();
        if natoms > self.dims.max_nodes || edges.len() > self.dims.max_edges {
            return Err(BatchError::TooLarge {
                natoms,
                nedges: edges.len(),
                dims: self.dims,
            });
        }
        if !self.fits(natoms, edges.len()) {
            return Err(BatchError::Full);
        }
        let base = self.n_nodes;
        let g = self.n_graphs;
        for (i, &z) in species.iter().enumerate() {
            let n = base + i;
            self.species[n] = z as i32;
            self.node_mask[n] = 1.0;
            self.node_graph[n] = g as i32;
        }
        for (k, e) in edges.iter().enumerate() {
            let idx = self.n_edges + k;
            self.edge_src[idx] = (base + e.src as usize) as i32;
            self.edge_dst[idx] = (base + e.dst as usize) as i32;
            self.rel_hat[idx * 3] = e.rel_hat[0];
            self.rel_hat[idx * 3 + 1] = e.rel_hat[1];
            self.rel_hat[idx * 3 + 2] = e.rel_hat[2];
            self.dist[idx] = e.dist;
            self.edge_mask[idx] = 1.0;
        }
        self.graph_mask[g] = 1.0;
        self.inv_atoms[g] = 1.0 / natoms as f32;
        self.n_nodes += natoms;
        self.n_edges += edges.len();
        self.n_graphs += 1;
        Ok(())
    }

    /// Tensor for a batch field by its manifest name (owning copy; tests and
    /// cold paths). The marshalling hot path uses [`Self::field_literal`].
    pub fn field(&self, name: &str) -> Tensor {
        let d = self.dims;
        match name {
            "species" => Tensor::from_i32(&[d.max_nodes], self.species.clone()),
            "edge_src" => Tensor::from_i32(&[d.max_edges], self.edge_src.clone()),
            "edge_dst" => Tensor::from_i32(&[d.max_edges], self.edge_dst.clone()),
            "rel_hat" => Tensor::from_f32(&[d.max_edges, 3], self.rel_hat.clone()),
            "dist" => Tensor::from_f32(&[d.max_edges], self.dist.clone()),
            "node_mask" => Tensor::from_f32(&[d.max_nodes], self.node_mask.clone()),
            "edge_mask" => Tensor::from_f32(&[d.max_edges], self.edge_mask.clone()),
            "node_graph" => Tensor::from_i32(&[d.max_nodes], self.node_graph.clone()),
            "graph_mask" => Tensor::from_f32(&[d.max_graphs], self.graph_mask.clone()),
            "inv_atoms" => Tensor::from_f32(&[d.max_graphs], self.inv_atoms.clone()),
            "y_energy" => Tensor::from_f32(&[d.max_graphs], self.y_energy.clone()),
            "y_forces" => Tensor::from_f32(&[d.max_nodes, 3], self.y_forces.clone()),
            other => panic!("unknown batch field '{other}'"),
        }
    }

    /// PJRT literal for a batch field by its manifest name, built straight
    /// from the batch buffer — no intermediate `Tensor` clone. This is the
    /// per-step marshal path (`Engine::marshal`).
    pub fn field_literal(&self, name: &str) -> anyhow::Result<xla::Literal> {
        let d = self.dims;
        match name {
            "species" => Tensor::literal_i32(&[d.max_nodes], &self.species),
            "edge_src" => Tensor::literal_i32(&[d.max_edges], &self.edge_src),
            "edge_dst" => Tensor::literal_i32(&[d.max_edges], &self.edge_dst),
            "rel_hat" => Tensor::literal_f32(&[d.max_edges, 3], &self.rel_hat),
            "dist" => Tensor::literal_f32(&[d.max_edges], &self.dist),
            "node_mask" => Tensor::literal_f32(&[d.max_nodes], &self.node_mask),
            "edge_mask" => Tensor::literal_f32(&[d.max_edges], &self.edge_mask),
            "node_graph" => Tensor::literal_i32(&[d.max_nodes], &self.node_graph),
            "graph_mask" => Tensor::literal_f32(&[d.max_graphs], &self.graph_mask),
            "inv_atoms" => Tensor::literal_f32(&[d.max_graphs], &self.inv_atoms),
            "y_energy" => Tensor::literal_f32(&[d.max_graphs], &self.y_energy),
            "y_forces" => Tensor::literal_f32(&[d.max_nodes, 3], &self.y_forces),
            other => anyhow::bail!("unknown batch field '{other}'"),
        }
    }
}

/// Recycles [`GraphBatch`] allocations through [`GraphBatch::clear`] so hot
/// loops reuse batch buffers instead of paying `GraphBatch::empty`'s twelve
/// allocations per batch. Batches are cleared on acquire (recycling is a
/// plain move); acquiring from an empty pool falls back to a fresh batch,
/// so pooled and unpooled paths produce identical contents.
#[derive(Debug, Default)]
pub struct BatchPool {
    free: Vec<GraphBatch>,
}

impl BatchPool {
    pub fn new() -> BatchPool {
        BatchPool::default()
    }

    /// A cleared batch with the requested dims: recycled when available,
    /// freshly allocated otherwise.
    pub fn acquire(&mut self, dims: BatchDims) -> GraphBatch {
        match self.free.iter().position(|b| b.dims == dims) {
            Some(i) => {
                let mut b = self.free.swap_remove(i);
                b.clear();
                b
            }
            None => GraphBatch::empty(dims),
        }
    }

    /// Return batches to the pool for later reuse.
    pub fn recycle(&mut self, batches: impl IntoIterator<Item = GraphBatch>) {
        self.free.extend(batches);
    }

    /// Number of idle batches held.
    pub fn pooled(&self) -> usize {
        self.free.len()
    }
}

/// Greedy batch planner: converts a stream of structures into padded batches.
/// Structures that would never fit (bigger than the whole budget) are
/// reported in `skipped` rather than silently dropped. Completed batches the
/// caller is done with can be fed back via [`BatchBuilder::recycle`].
pub struct BatchBuilder {
    pub dims: BatchDims,
    pub cutoff: f64,
    pub skipped: usize,
    current: GraphBatch,
    pool: BatchPool,
}

impl BatchBuilder {
    pub fn new(dims: BatchDims, cutoff: f64) -> BatchBuilder {
        BatchBuilder::with_pool(dims, cutoff, BatchPool::default())
    }

    /// Build with a pre-seeded pool of recycled batches (hot-loop reuse
    /// across epochs / datasets).
    pub fn with_pool(dims: BatchDims, cutoff: f64, mut pool: BatchPool) -> BatchBuilder {
        let current = pool.acquire(dims);
        BatchBuilder { dims, cutoff, skipped: 0, current, pool }
    }

    /// Feed finished batches back for buffer reuse.
    pub fn recycle(&mut self, batches: impl IntoIterator<Item = GraphBatch>) {
        self.pool.recycle(batches);
    }

    /// Add a structure; returns a completed batch when the current one
    /// overflows and a fresh one was started.
    pub fn push(&mut self, s: &AtomicStructure) -> Option<GraphBatch> {
        let edges = radius_graph(s, self.cutoff);
        if s.natoms() > self.dims.max_nodes || edges.len() > self.dims.max_edges {
            self.skipped += 1;
            return None;
        }
        if self.current.fits(s.natoms(), edges.len()) {
            self.current.push(s, &edges).expect("fits() checked");
            None
        } else {
            let full = std::mem::replace(&mut self.current, self.pool.acquire(self.dims));
            self.current.push(s, &edges).expect("fresh batch must fit");
            Some(full)
        }
    }

    /// Flush the in-progress batch if it contains anything.
    pub fn finish(&mut self) -> Option<GraphBatch> {
        if self.current.n_graphs == 0 {
            return None;
        }
        Some(std::mem::replace(&mut self.current, self.pool.acquire(self.dims)))
    }

    /// Batch an entire slice of structures.
    pub fn build_all(dims: BatchDims, cutoff: f64, structures: &[AtomicStructure]) -> Vec<GraphBatch> {
        let mut b = BatchBuilder::new(dims, cutoff);
        let mut out = Vec::new();
        for s in structures {
            if let Some(batch) = b.push(s) {
                out.push(batch);
            }
        }
        out.extend(b.finish());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{DatasetGenerator, GeneratorConfig};
    use crate::data::structures::DatasetId;

    fn dims() -> BatchDims {
        BatchDims { max_nodes: 64, max_edges: 512, max_graphs: 8 }
    }

    fn structures(n: usize) -> Vec<AtomicStructure> {
        let mut g = DatasetGenerator::new(
            DatasetId::Ani1x,
            1,
            GeneratorConfig { max_atoms: 12, ..Default::default() },
        );
        g.take(n)
    }

    #[test]
    fn batches_respect_budgets() {
        let batches = BatchBuilder::build_all(dims(), 6.0, &structures(30));
        assert!(!batches.is_empty());
        for b in &batches {
            assert!(b.n_nodes <= b.dims.max_nodes);
            assert!(b.n_edges <= b.dims.max_edges);
            assert!(b.n_graphs <= b.dims.max_graphs);
            assert!(b.n_graphs > 0);
        }
    }

    #[test]
    fn all_structures_accounted_for() {
        let ss = structures(25);
        let batches = BatchBuilder::build_all(dims(), 6.0, &ss);
        let total: usize = batches.iter().map(|b| b.n_graphs).sum();
        assert_eq!(total, ss.len());
        let total_atoms: usize = batches.iter().map(|b| b.n_nodes).sum();
        assert_eq!(total_atoms, ss.iter().map(|s| s.natoms()).sum::<usize>());
    }

    #[test]
    fn masks_are_consistent() {
        let batches = BatchBuilder::build_all(dims(), 6.0, &structures(10));
        for b in &batches {
            let nm: f32 = b.node_mask.iter().sum();
            assert_eq!(nm as usize, b.n_nodes);
            let em: f32 = b.edge_mask.iter().sum();
            assert_eq!(em as usize, b.n_edges);
            let gm: f32 = b.graph_mask.iter().sum();
            assert_eq!(gm as usize, b.n_graphs);
            // Every real node's graph id must be a real graph.
            for n in 0..b.n_nodes {
                assert!((b.node_graph[n] as usize) < b.n_graphs);
            }
            // Edge endpoints must be real nodes of the same graph.
            for e in 0..b.n_edges {
                let (s, d) = (b.edge_src[e] as usize, b.edge_dst[e] as usize);
                assert!(s < b.n_nodes && d < b.n_nodes);
                assert_eq!(b.node_graph[s], b.node_graph[d]);
            }
        }
    }

    #[test]
    fn energy_targets_are_per_atom() {
        let ss = structures(3);
        let mut batch = GraphBatch::empty(dims());
        for s in &ss {
            let edges = radius_graph(s, 6.0);
            batch.push(s, &edges).unwrap();
        }
        for (g, s) in ss.iter().enumerate() {
            assert!((batch.y_energy[g] as f64 - s.energy_per_atom()).abs() < 1e-4);
            assert!((batch.inv_atoms[g] as f64 - 1.0 / s.natoms() as f64).abs() < 1e-7);
        }
    }

    #[test]
    fn clear_resets_for_reuse() {
        let ss = structures(5);
        let mut batch = GraphBatch::empty(dims());
        for s in &ss {
            let edges = radius_graph(s, 6.0);
            if batch.fits(s.natoms(), edges.len()) {
                batch.push(s, &edges).unwrap();
            }
        }
        batch.clear();
        let empty = GraphBatch::empty(dims());
        assert_eq!(batch, empty, "clear() must fully restore the empty state");
    }

    #[test]
    fn pooled_builder_matches_fresh_allocation() {
        let ss = structures(30);
        let fresh = BatchBuilder::build_all(dims(), 6.0, &ss);

        // Dirty pool: recycle a first pass's batches, then rebuild through
        // the pooled path — contents must be bit-identical.
        let mut pool = BatchPool::new();
        pool.recycle(BatchBuilder::build_all(dims(), 6.0, &ss));
        assert!(pool.pooled() > 0);
        let mut builder = BatchBuilder::with_pool(dims(), 6.0, pool);
        let mut pooled = Vec::new();
        for s in &ss {
            if let Some(b) = builder.push(s) {
                pooled.push(b);
            }
        }
        pooled.extend(builder.finish());
        assert_eq!(pooled, fresh);
    }

    #[test]
    fn pool_reuses_matching_dims_only() {
        let mut pool = BatchPool::new();
        pool.recycle([GraphBatch::empty(dims())]);
        let other = BatchDims { max_nodes: 16, max_edges: 64, max_graphs: 2 };
        let b = pool.acquire(other);
        assert_eq!(b.dims, other);
        assert_eq!(pool.pooled(), 1, "mismatched dims stay pooled");
        let b2 = pool.acquire(dims());
        assert_eq!(b2.dims, dims());
        assert_eq!(pool.pooled(), 0);
    }

    #[test]
    fn oversized_structure_is_skipped_not_dropped_silently() {
        let mut g =
            DatasetGenerator::new(DatasetId::MpTrj, 2, GeneratorConfig { max_atoms: 40, ..Default::default() });
        let small_dims = BatchDims { max_nodes: 8, max_edges: 64, max_graphs: 4 };
        let mut builder = BatchBuilder::new(small_dims, 6.0);
        let mut pushed = 0;
        for s in g.take(10) {
            builder.push(&s);
            pushed += 1;
        }
        assert_eq!(pushed, 10);
        assert!(builder.skipped > 0, "oversized structures must be counted");
    }

    #[test]
    fn admits_is_the_single_structure_budget() {
        let d = dims();
        assert!(d.admits(64, 512));
        assert!(!d.admits(65, 0));
        assert!(!d.admits(0, 513));
        assert!(d.admits(0, 0));
    }

    #[test]
    fn push_inference_matches_push_modulo_labels() {
        let ss = structures(4);
        let mut labeled = GraphBatch::empty(dims());
        let mut inference = GraphBatch::empty(dims());
        for s in &ss {
            let edges = radius_graph(s, 6.0);
            labeled.push(s, &edges).unwrap();
            inference.push_inference(&s.species, &edges).unwrap();
        }
        // Strip labels from the labeled batch: everything else must match
        // bit-for-bit.
        let mut stripped = labeled.clone();
        stripped.y_energy.fill(0.0);
        stripped.y_forces.fill(0.0);
        assert_eq!(stripped, inference);
        assert!(inference.y_energy.iter().all(|&x| x == 0.0));
        assert!(inference.y_forces.iter().all(|&x| x == 0.0));
        // And the same errors apply.
        let big_species = vec![1u8; dims().max_nodes + 1];
        assert!(matches!(
            inference.push_inference(&big_species, &[]),
            Err(BatchError::TooLarge { .. })
        ));
    }

    #[test]
    fn field_tensors_have_manifest_shapes() {
        let batches = BatchBuilder::build_all(dims(), 6.0, &structures(5));
        let b = &batches[0];
        assert_eq!(b.field("species").shape, vec![64]);
        assert_eq!(b.field("rel_hat").shape, vec![512, 3]);
        assert_eq!(b.field("y_forces").shape, vec![64, 3]);
        assert_eq!(b.field("graph_mask").shape, vec![8]);
    }

    #[test]
    fn field_literal_matches_field_tensor_route() {
        let batches = BatchBuilder::build_all(dims(), 6.0, &structures(5));
        let b = &batches[0];
        for name in [
            "species", "edge_src", "edge_dst", "rel_hat", "dist", "node_mask",
            "edge_mask", "node_graph", "graph_mask", "inv_atoms", "y_energy", "y_forces",
        ] {
            let via_tensor = b.field(name).to_literal().unwrap();
            let direct = b.field_literal(name).unwrap();
            let (sa, sb) = (via_tensor.array_shape().unwrap(), direct.array_shape().unwrap());
            assert_eq!(sa.dims(), sb.dims(), "{name}: dims");
            assert_eq!(sa.ty(), sb.ty(), "{name}: dtype");
            match sa.ty() {
                xla::ElementType::F32 => assert_eq!(
                    via_tensor.to_vec::<f32>().unwrap(),
                    direct.to_vec::<f32>().unwrap(),
                    "{name}: payload"
                ),
                _ => assert_eq!(
                    via_tensor.to_vec::<i32>().unwrap(),
                    direct.to_vec::<i32>().unwrap(),
                    "{name}: payload"
                ),
            }
        }
        assert!(b.field_literal("nope").is_err());
    }
}
