//! Core atomistic data type: a structure (one data sample).
//!
//! The identity of the source dataset used to live here as a closed
//! five-variant enum; it is now a lightweight handle into the runtime
//! [`crate::tasks::TaskRegistry`] (re-exported below for compatibility), so
//! the set of tasks is data, not code.

use crate::elements;

pub use crate::tasks::{DatasetId, ALL_DATASETS};

/// One atomistic structure: the unit data sample for GFM pre-training.
///
/// `energy` / `forces` hold the *labeled* values after the dataset's fidelity
/// transform (what a DFT code with that dataset's settings would report) —
/// the ground-truth values before the transform are not stored, mirroring
/// real multi-source data where the "true" functional is unknown.
#[derive(Debug, Clone, PartialEq)]
pub struct AtomicStructure {
    /// Atomic numbers (1-based; never 0 — 0 is the padding species).
    pub species: Vec<u8>,
    /// Cartesian coordinates, Angstrom.
    pub positions: Vec<[f64; 3]>,
    /// Labeled total energy (dataset-fidelity units).
    pub energy: f64,
    /// Labeled per-atom forces.
    pub forces: Vec<[f64; 3]>,
    /// Source task handle.
    pub dataset: DatasetId,
}

impl AtomicStructure {
    pub fn natoms(&self) -> usize {
        self.species.len()
    }

    pub fn energy_per_atom(&self) -> f64 {
        self.energy / self.natoms() as f64
    }

    /// Sanity check used by generators, the pack reader and tests.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.species.is_empty(), "empty structure");
        anyhow::ensure!(
            self.positions.len() == self.species.len(),
            "positions/species length mismatch"
        );
        anyhow::ensure!(
            self.forces.len() == self.species.len(),
            "forces/species length mismatch"
        );
        for &z in &self.species {
            anyhow::ensure!(
                (1..=elements::MAX_Z as u8).contains(&z),
                "invalid species {z}"
            );
        }
        anyhow::ensure!(self.energy.is_finite(), "non-finite energy");
        for f in &self.forces {
            anyhow::ensure!(
                f.iter().all(|x| x.is_finite()),
                "non-finite force component"
            );
        }
        for p in &self.positions {
            anyhow::ensure!(p.iter().all(|x| x.is_finite()), "non-finite position");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> AtomicStructure {
        AtomicStructure {
            species: vec![6, 1, 1, 1, 1],
            positions: vec![
                [0.0, 0.0, 0.0],
                [0.63, 0.63, 0.63],
                [-0.63, -0.63, 0.63],
                [-0.63, 0.63, -0.63],
                [0.63, -0.63, -0.63],
            ],
            energy: -5.0,
            forces: vec![[0.0; 3]; 5],
            dataset: DatasetId::Ani1x,
        }
    }

    #[test]
    fn validates_good_structure() {
        sample().validate().unwrap();
        assert_eq!(sample().natoms(), 5);
        assert!((sample().energy_per_atom() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_structures() {
        let mut s = sample();
        s.species[0] = 0;
        assert!(s.validate().is_err());

        let mut s = sample();
        s.forces.pop();
        assert!(s.validate().is_err());

        let mut s = sample();
        s.energy = f64::NAN;
        assert!(s.validate().is_err());
    }

    #[test]
    fn dataset_ids_roundtrip() {
        for d in ALL_DATASETS {
            assert_eq!(DatasetId::from_index(d.index()), d);
            assert_eq!(DatasetId::from_name(&d.name()), Some(d));
        }
        assert_eq!(DatasetId::from_name("qm7x"), Some(DatasetId::Qm7x));
        assert!(DatasetId::from_name("nope").is_none());
    }
}
