//! Core atomistic data types: a structure (one data sample) and the identity
//! of the five source datasets it may come from.

use crate::elements;

/// The five open-source datasets aggregated in the paper (Section 4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DatasetId {
    Ani1x,
    Qm7x,
    Transition1x,
    MpTrj,
    Alexandria,
}

pub const ALL_DATASETS: [DatasetId; 5] = [
    DatasetId::Ani1x,
    DatasetId::Qm7x,
    DatasetId::Transition1x,
    DatasetId::MpTrj,
    DatasetId::Alexandria,
];

impl DatasetId {
    pub fn name(&self) -> &'static str {
        match self {
            DatasetId::Ani1x => "ANI1x",
            DatasetId::Qm7x => "QM7-X",
            DatasetId::Transition1x => "Transition1x",
            DatasetId::MpTrj => "MPTrj",
            DatasetId::Alexandria => "Alexandria",
        }
    }

    pub fn index(&self) -> usize {
        ALL_DATASETS.iter().position(|d| d == self).unwrap()
    }

    pub fn from_index(i: usize) -> DatasetId {
        ALL_DATASETS[i]
    }

    pub fn from_name(name: &str) -> Option<DatasetId> {
        let lower = name.to_ascii_lowercase();
        ALL_DATASETS
            .iter()
            .find(|d| d.name().to_ascii_lowercase().replace('-', "") == lower.replace('-', ""))
            .copied()
    }

    /// Whether the dataset contains inorganic (periodic crystal) compounds.
    pub fn is_inorganic(&self) -> bool {
        matches!(self, DatasetId::MpTrj | DatasetId::Alexandria)
    }

    /// Element palette of the dataset (paper Section 4.1).
    pub fn palette(&self) -> Vec<usize> {
        match self {
            DatasetId::Ani1x => elements::ani1x_palette(),
            DatasetId::Qm7x => elements::qm7x_palette(),
            DatasetId::Transition1x => elements::transition1x_palette(),
            DatasetId::MpTrj => elements::mptrj_palette(),
            DatasetId::Alexandria => elements::alexandria_palette(),
        }
    }
}

/// One atomistic structure: the unit data sample for GFM pre-training.
///
/// `energy` / `forces` hold the *labeled* values after the dataset's fidelity
/// transform (what a DFT code with that dataset's settings would report) —
/// the ground-truth values before the transform are not stored, mirroring
/// real multi-source data where the "true" functional is unknown.
#[derive(Debug, Clone, PartialEq)]
pub struct AtomicStructure {
    /// Atomic numbers (1-based; never 0 — 0 is the padding species).
    pub species: Vec<u8>,
    /// Cartesian coordinates, Angstrom.
    pub positions: Vec<[f64; 3]>,
    /// Labeled total energy (dataset-fidelity units).
    pub energy: f64,
    /// Labeled per-atom forces.
    pub forces: Vec<[f64; 3]>,
    /// Source dataset.
    pub dataset: DatasetId,
}

impl AtomicStructure {
    pub fn natoms(&self) -> usize {
        self.species.len()
    }

    pub fn energy_per_atom(&self) -> f64 {
        self.energy / self.natoms() as f64
    }

    /// Sanity check used by generators, the pack reader and tests.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.species.is_empty(), "empty structure");
        anyhow::ensure!(
            self.positions.len() == self.species.len(),
            "positions/species length mismatch"
        );
        anyhow::ensure!(
            self.forces.len() == self.species.len(),
            "forces/species length mismatch"
        );
        for &z in &self.species {
            anyhow::ensure!(
                (1..=elements::MAX_Z as u8).contains(&z),
                "invalid species {z}"
            );
        }
        anyhow::ensure!(self.energy.is_finite(), "non-finite energy");
        for f in &self.forces {
            anyhow::ensure!(
                f.iter().all(|x| x.is_finite()),
                "non-finite force component"
            );
        }
        for p in &self.positions {
            anyhow::ensure!(p.iter().all(|x| x.is_finite()), "non-finite position");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> AtomicStructure {
        AtomicStructure {
            species: vec![6, 1, 1, 1, 1],
            positions: vec![
                [0.0, 0.0, 0.0],
                [0.63, 0.63, 0.63],
                [-0.63, -0.63, 0.63],
                [-0.63, 0.63, -0.63],
                [0.63, -0.63, -0.63],
            ],
            energy: -5.0,
            forces: vec![[0.0; 3]; 5],
            dataset: DatasetId::Ani1x,
        }
    }

    #[test]
    fn validates_good_structure() {
        sample().validate().unwrap();
        assert_eq!(sample().natoms(), 5);
        assert!((sample().energy_per_atom() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_structures() {
        let mut s = sample();
        s.species[0] = 0;
        assert!(s.validate().is_err());

        let mut s = sample();
        s.forces.pop();
        assert!(s.validate().is_err());

        let mut s = sample();
        s.energy = f64::NAN;
        assert!(s.validate().is_err());
    }

    #[test]
    fn dataset_ids_roundtrip() {
        for d in ALL_DATASETS {
            assert_eq!(DatasetId::from_index(d.index()), d);
            assert_eq!(DatasetId::from_name(d.name()), Some(d));
        }
        assert_eq!(DatasetId::from_name("qm7x"), Some(DatasetId::Qm7x));
        assert!(DatasetId::from_name("nope").is_none());
    }

    #[test]
    fn inorganic_flags_match_paper() {
        assert!(!DatasetId::Ani1x.is_inorganic());
        assert!(!DatasetId::Transition1x.is_inorganic());
        assert!(DatasetId::MpTrj.is_inorganic());
        assert!(DatasetId::Alexandria.is_inorganic());
    }
}
