//! DDStore: the distributed in-memory sample store (paper Section 3).
//!
//! In HydraGNN, DDStore keeps every sample resident in the aggregate memory
//! of all MPI processes and serves remote batches with one-sided MPI gets so
//! epochs never touch the filesystem. Here the "processes" are the trainer's
//! rank threads; ownership is round-robin by global index. [`DDStore::with`]
//! borrows the owner's shard directly on local hits (truly free) and pays
//! the RMA-style clone only on remote hits; [`DDStore::get`] is the
//! clone-always compatibility path. Both count local/remote traffic so the
//! scaling model and tests can observe the access pattern.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::data::structures::AtomicStructure;

/// Immutable, shareable store built once before training.
pub struct DDStore {
    /// shards[rank] = samples owned by that rank (global index % world == rank).
    shards: Vec<Arc<Vec<AtomicStructure>>>,
    total: usize,
    local_gets: AtomicU64,
    remote_gets: AtomicU64,
}

impl DDStore {
    /// Distribute `samples` across `world` ranks round-robin (matches
    /// DDStore's block-cyclic default).
    pub fn new(samples: Vec<AtomicStructure>, world: usize) -> Arc<DDStore> {
        assert!(world > 0);
        let total = samples.len();
        let mut shards: Vec<Vec<AtomicStructure>> = (0..world).map(|_| Vec::new()).collect();
        for (i, s) in samples.into_iter().enumerate() {
            shards[i % world].push(s);
        }
        Arc::new(DDStore {
            shards: shards.into_iter().map(Arc::new).collect(),
            total,
            local_gets: AtomicU64::new(0),
            remote_gets: AtomicU64::new(0),
        })
    }

    pub fn world(&self) -> usize {
        self.shards.len()
    }

    pub fn len(&self) -> usize {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Owner rank of a global index.
    pub fn owner(&self, global: usize) -> usize {
        global % self.shards.len()
    }

    /// Number of samples owned by `rank`.
    pub fn local_len(&self, rank: usize) -> usize {
        self.shards[rank].len()
    }

    /// Fetch a sample by global index from the perspective of `rank`,
    /// always returning an owned clone. The training hot path avoids
    /// per-sample access entirely (`FeaturizedStore` serves epoch planning
    /// from flat caches); callers that do need samples without paying the
    /// local-hit clone should use [`DDStore::with`] instead.
    pub fn get(&self, rank: usize, global: usize) -> Option<AtomicStructure> {
        let owner = self.owner(global);
        let slot = global / self.shards.len();
        let sample = self.shards[owner].get(slot)?;
        self.note_access(rank, global);
        Some(sample.clone())
    }

    /// Visit a sample by global index from the perspective of `rank`
    /// without paying the RMA-style clone on local hits: the owner's shard
    /// is borrowed directly. Remote hits still clone first (the in-process
    /// analogue of a one-sided MPI get), so only remote traffic pays.
    pub fn with<R>(
        &self,
        rank: usize,
        global: usize,
        f: impl FnOnce(&AtomicStructure) -> R,
    ) -> Option<R> {
        let owner = self.owner(global);
        let sample = self.shards[owner].get(global / self.shards.len())?;
        if owner == rank {
            self.local_gets.fetch_add(1, Ordering::Relaxed);
            Some(f(sample))
        } else {
            self.remote_gets.fetch_add(1, Ordering::Relaxed);
            let transferred = sample.clone();
            Some(f(&transferred))
        }
    }

    /// Uncounted borrow by global index: the build-time featurization pass
    /// (`FeaturizedStore::build`), which is not epoch traffic.
    pub fn peek(&self, global: usize) -> Option<&AtomicStructure> {
        self.shards[self.owner(global)].get(global / self.shards.len())
    }

    /// Count one access without materializing the sample.
    fn note_access(&self, rank: usize, global: usize) {
        if self.owner(global) == rank {
            self.local_gets.fetch_add(1, Ordering::Relaxed);
        } else {
            self.remote_gets.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Zero-copy access to a rank's own shard (epoch iteration fast path).
    pub fn local_shard(&self, rank: usize) -> Arc<Vec<AtomicStructure>> {
        Arc::clone(&self.shards[rank])
    }

    /// (local, remote) one-sided get counters since construction.
    pub fn stats(&self) -> (u64, u64) {
        (self.local_gets.load(Ordering::Relaxed), self.remote_gets.load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{DatasetGenerator, GeneratorConfig};
    use crate::data::structures::DatasetId;

    fn samples(n: usize) -> Vec<AtomicStructure> {
        let mut g = DatasetGenerator::new(DatasetId::Ani1x, 9, GeneratorConfig::default());
        g.take(n)
    }

    #[test]
    fn round_robin_ownership() {
        let store = DDStore::new(samples(10), 3);
        assert_eq!(store.local_len(0), 4); // 0,3,6,9
        assert_eq!(store.local_len(1), 3); // 1,4,7
        assert_eq!(store.local_len(2), 3); // 2,5,8
        for g in 0..10 {
            assert_eq!(store.owner(g), g % 3);
        }
    }

    #[test]
    fn get_returns_the_right_sample() {
        let ss = samples(8);
        let store = DDStore::new(ss.clone(), 4);
        for (g, expected) in ss.iter().enumerate() {
            let got = store.get(0, g).unwrap();
            assert_eq!(&got, expected, "global index {g}");
        }
    }

    #[test]
    fn counts_local_vs_remote() {
        let store = DDStore::new(samples(12), 4);
        // Rank 1 reads everything: 3 locals (1,5,9), 9 remotes.
        for g in 0..12 {
            store.get(1, g).unwrap();
        }
        let (local, remote) = store.stats();
        assert_eq!(local, 3);
        assert_eq!(remote, 9);
    }

    #[test]
    fn out_of_range_returns_none() {
        let store = DDStore::new(samples(5), 2);
        assert!(store.get(0, 5).is_none());
        assert!(store.get(0, 4).is_some());
    }

    #[test]
    fn with_borrows_local_hits_and_clones_remote() {
        let ss = samples(9);
        let store = DDStore::new(ss.clone(), 3);
        for (g, expect) in ss.iter().enumerate() {
            let owner = store.owner(g);
            // Local access: the closure sees the shard's sample in place.
            let shard_ptr = store.peek(g).unwrap() as *const AtomicStructure as usize;
            let seen_ptr = store
                .with(owner, g, |s| {
                    assert_eq!(s, expect);
                    s as *const AtomicStructure as usize
                })
                .unwrap();
            assert_eq!(seen_ptr, shard_ptr, "local hit must borrow, not clone");
            // Remote access: a transferred copy, same contents.
            let remote_rank = (owner + 1) % 3;
            let remote_ptr = store
                .with(remote_rank, g, |s| {
                    assert_eq!(s, expect);
                    s as *const AtomicStructure as usize
                })
                .unwrap();
            assert_ne!(remote_ptr, shard_ptr, "remote hit pays the RMA-style clone");
        }
        let (local, remote) = store.stats();
        assert_eq!(local, 9);
        assert_eq!(remote, 9);
        assert!(store.with(0, ss.len(), |_| ()).is_none());
    }

    #[test]
    fn peek_is_uncounted() {
        let store = DDStore::new(samples(5), 2);
        for g in 0..5 {
            assert!(store.peek(g).is_some());
        }
        assert!(store.peek(5).is_none());
        assert_eq!(store.stats(), (0, 0), "peek must not count as traffic");
    }

    #[test]
    fn single_rank_world_is_all_local() {
        let store = DDStore::new(samples(6), 1);
        for g in 0..6 {
            store.get(0, g).unwrap();
        }
        let (local, remote) = store.stats();
        assert_eq!((local, remote), (6, 0));
    }
}
