//! Ground-truth classical potential used to label the synthetic datasets.
//!
//! The paper trains on DFT/CCSD labels we cannot regenerate; the substitution
//! (DESIGN.md Section 3) is a smooth element-pair Morse potential whose pair
//! parameters derive from covalent radii and electronegativities. What
//! matters for reproducing the paper's *learning* behaviour is that labels
//! are (a) a smooth function of geometry, (b) element-specific, and
//! (c) shared across datasets **before** the per-dataset fidelity transform —
//! so the multi-fidelity inconsistency is purely the transform, exactly like
//! differing DFT settings on the same physical system.

use crate::elements::element;

/// Pairwise interaction cutoff (Angstrom). Matches the model's graph cutoff
/// so the GNN sees every interacting pair.
pub const CUTOFF: f64 = 6.0;

/// Morse parameters for an element pair.
#[derive(Debug, Clone, Copy)]
pub struct PairParams {
    /// Well depth (eV-ish scale).
    pub d_e: f64,
    /// Width parameter (1/Angstrom).
    pub a: f64,
    /// Equilibrium distance (Angstrom).
    pub r0: f64,
}

/// Derive pair parameters from element data. Deterministic and smooth in the
/// element properties, so chemically similar pairs get similar labels.
pub fn pair_params(zi: usize, zj: usize) -> PairParams {
    let ei = element(zi);
    let ej = element(zj);
    let r0 = ei.radius + ej.radius;
    // Stronger wells for electronegativity contrast (ionic character) plus a
    // covalent base that grows with the geometric mean of chi.
    let chi_gm = (ei.chi.max(0.5) * ej.chi.max(0.5)).sqrt();
    let d_e = 0.35 + 0.18 * chi_gm + 0.10 * (ei.chi - ej.chi).abs();
    let a = 1.8 / r0.max(0.5);
    PairParams { d_e, a, r0 }
}

/// Morse pair energy at distance `d` (shifted so u(CUTOFF-ish) ~ 0 tail).
#[inline]
pub fn pair_energy(p: PairParams, d: f64) -> f64 {
    let x = (-p.a * (d - p.r0)).exp();
    p.d_e * (x * x - 2.0 * x)
}

/// d(pair_energy)/dd (used by tests; the force loop inlines this).
#[inline]
pub fn pair_energy_deriv(p: PairParams, d: f64) -> f64 {
    let x = (-p.a * (d - p.r0)).exp();
    // d/dd [ d_e*(x^2 - 2x) ] with dx/dd = -a*x.
    p.d_e * (-2.0 * p.a * x * x + 2.0 * p.a * x)
}

/// Total energy + analytic forces for a set of atoms (open boundary).
///
/// O(n^2) pair loop — fine for the <= few-hundred-atom structures the
/// paper's datasets contain (atomistic data is many *small* graphs).
pub fn energy_and_forces(
    species: &[u8],
    positions: &[[f64; 3]],
) -> (f64, Vec<[f64; 3]>) {
    let n = species.len();
    assert_eq!(positions.len(), n);
    let mut energy = 0.0;
    let mut forces = vec![[0.0f64; 3]; n];
    for i in 0..n {
        for j in (i + 1)..n {
            let dx = [
                positions[i][0] - positions[j][0],
                positions[i][1] - positions[j][1],
                positions[i][2] - positions[j][2],
            ];
            let d2 = dx[0] * dx[0] + dx[1] * dx[1] + dx[2] * dx[2];
            if d2 > CUTOFF * CUTOFF || d2 < 1e-12 {
                continue;
            }
            let d = d2.sqrt();
            let p = pair_params(species[i] as usize, species[j] as usize);
            let x = (-p.a * (d - p.r0)).exp();
            energy += p.d_e * (x * x - 2.0 * x);
            // du/dd; force on i is -du/dd * dhat, on j the opposite.
            let dudd = p.d_e * (-2.0 * p.a * x * x + 2.0 * p.a * x);
            let f = -dudd / d;
            for k in 0..3 {
                forces[i][k] += f * dx[k];
                forces[j][k] -= f * dx[k];
            }
        }
    }
    (energy, forces)
}

/// Equilibrium-ish relaxation: a few damped steepest-descent steps. Used by
/// the generators to produce near-equilibrium structures (MPTrj/Alexandria
/// style) from random initial placements.
pub fn relax(species: &[u8], positions: &mut [[f64; 3]], steps: usize, step_size: f64) {
    for _ in 0..steps {
        let (_, forces) = energy_and_forces(species, positions);
        let max_f = forces
            .iter()
            .flat_map(|f| f.iter().map(|x| x.abs()))
            .fold(0.0f64, f64::max);
        if max_f < 1e-3 {
            break;
        }
        let scale = step_size / max_f.max(1.0);
        for (pos, f) in positions.iter_mut().zip(&forces) {
            for k in 0..3 {
                pos[k] += scale * f[k];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pair_params_symmetric() {
        let a = pair_params(6, 8);
        let b = pair_params(8, 6);
        assert!((a.d_e - b.d_e).abs() < 1e-12);
        assert!((a.r0 - b.r0).abs() < 1e-12);
    }

    #[test]
    fn minimum_is_at_r0() {
        let p = pair_params(6, 6);
        let at_r0 = pair_energy(p, p.r0);
        assert!(at_r0 < pair_energy(p, p.r0 * 0.9));
        assert!(at_r0 < pair_energy(p, p.r0 * 1.1));
        assert!((at_r0 + p.d_e).abs() < 1e-9, "well depth at r0");
    }

    #[test]
    fn forces_are_negative_gradient() {
        // Finite-difference check of the analytic forces.
        let species = [6u8, 8, 1];
        let positions = [[0.0, 0.0, 0.0], [1.3, 0.1, -0.2], [-0.4, 0.9, 0.3]];
        let (_, forces) = energy_and_forces(&species, &positions);
        let h = 1e-6;
        for atom in 0..3 {
            for k in 0..3 {
                let mut plus = positions;
                plus[atom][k] += h;
                let mut minus = positions;
                minus[atom][k] -= h;
                let (ep, _) = energy_and_forces(&species, &plus);
                let (em, _) = energy_and_forces(&species, &minus);
                let fd = -(ep - em) / (2.0 * h);
                assert!(
                    (fd - forces[atom][k]).abs() < 1e-5,
                    "atom {atom} comp {k}: fd={fd} analytic={}",
                    forces[atom][k]
                );
            }
        }
    }

    #[test]
    fn forces_sum_to_zero() {
        let species = [26u8, 8, 8, 1];
        let positions =
            [[0.0, 0.0, 0.0], [1.8, 0.0, 0.0], [0.0, 1.9, 0.0], [0.5, 0.5, 1.2]];
        let (_, forces) = energy_and_forces(&species, &positions);
        for k in 0..3 {
            let total: f64 = forces.iter().map(|f| f[k]).sum();
            assert!(total.abs() < 1e-10, "momentum conservation, axis {k}");
        }
    }

    #[test]
    fn relax_reduces_energy() {
        let species = [6u8, 6];
        let mut positions = [[0.0, 0.0, 0.0], [1.0, 0.0, 0.0]]; // compressed
        let (e0, _) = energy_and_forces(&species, &positions);
        relax(&species, &mut positions, 50, 0.05);
        let (e1, _) = energy_and_forces(&species, &positions);
        assert!(e1 < e0, "{e1} < {e0}");
        // Should approach the Morse minimum r0 = 2 * r_C = 1.52.
        let d = (positions[0][0] - positions[1][0]).abs();
        let r0 = pair_params(6, 6).r0;
        assert!((d - r0).abs() < 0.2, "d={d} r0={r0}");
    }

    #[test]
    fn beyond_cutoff_no_interaction() {
        let species = [1u8, 1];
        let positions = [[0.0, 0.0, 0.0], [CUTOFF + 1.0, 0.0, 0.0]];
        let (e, forces) = energy_and_forces(&species, &positions);
        assert_eq!(e, 0.0);
        assert_eq!(forces[0], [0.0; 3]);
    }
}
