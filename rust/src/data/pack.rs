//! GPack: the packed binary dataset format (the repo's ADIOS substitute).
//!
//! Role in the system (paper Section 3): serialize millions of variable-size
//! graph samples once during data preparation, then give training processes
//! O(1) random access to any sample without touching a Python stack. Layout:
//!
//! ```text
//! "GPAK" | u32 version
//! repeated sample records:
//!     u32 natoms | u8 dataset | species u8*natoms
//!     positions f64*3*natoms | energy f64 | forces f64*3*natoms
//! footer:
//!     u64 offsets[count]                (byte offset of each record)
//!     u64 count | u64 index_offset | u32 crc32(index bytes) | "KAPG"
//! ```
//!
//! Everything is little-endian. The trailing index makes the writer purely
//! append-only (streamable) while readers can mmap-style seek per sample.
//!
//! The one-byte `dataset` field is a *task registry index*: the five paper
//! presets (0..=4) are stable, but custom tasks are numbered in
//! registration order, so a reader process must register the same custom
//! tasks in the same order the writer did — otherwise samples would be
//! attributed to whichever task occupies that index (the reader can only
//! reject indices with no registered task at all). The v1 record format
//! stores no task names; treat cross-process GPack files with custom tasks
//! as valid only alongside their registration recipe.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use crate::data::structures::{AtomicStructure, DatasetId};

const MAGIC: &[u8; 4] = b"GPAK";
const MAGIC_END: &[u8; 4] = b"KAPG";
const VERSION: u32 = 1;

#[derive(Debug)]
pub enum PackError {
    Io(std::io::Error),
    BadMagic,
    BadVersion(u32),
    BadChecksum,
    Corrupt(u64),
    OutOfRange(usize, usize),
    /// Task registry index does not fit the v1 one-byte record field.
    TaskIndexOverflow(usize),
}

impl std::fmt::Display for PackError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PackError::Io(e) => write!(f, "io: {e}"),
            PackError::BadMagic => write!(f, "not a GPack file (bad magic)"),
            PackError::BadVersion(v) => write!(f, "unsupported version {v}"),
            PackError::BadChecksum => write!(f, "index checksum mismatch"),
            PackError::Corrupt(off) => write!(f, "corrupt record at offset {off}"),
            PackError::OutOfRange(i, n) => {
                write!(f, "sample index {i} out of range ({n} samples)")
            }
            PackError::TaskIndexOverflow(i) => {
                write!(f, "task index {i} exceeds the GPack v1 one-byte limit (255)")
            }
        }
    }
}

impl std::error::Error for PackError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            PackError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for PackError {
    fn from(e: std::io::Error) -> PackError {
        PackError::Io(e)
    }
}

// ---------------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------------

pub struct GPackWriter {
    out: BufWriter<File>,
    offsets: Vec<u64>,
    pos: u64,
}

impl GPackWriter {
    pub fn create(path: impl AsRef<Path>) -> Result<GPackWriter, PackError> {
        let mut out = BufWriter::new(File::create(path)?);
        out.write_all(MAGIC)?;
        out.write_all(&VERSION.to_le_bytes())?;
        Ok(GPackWriter { out, offsets: Vec::new(), pos: 8 })
    }

    pub fn write(&mut self, s: &AtomicStructure) -> Result<(), PackError> {
        // The v1 record format stores the task handle as one byte.
        if s.dataset.index() > u8::MAX as usize {
            return Err(PackError::TaskIndexOverflow(s.dataset.index()));
        }
        self.offsets.push(self.pos);
        let mut buf = Vec::with_capacity(16 + s.natoms() * 49);
        buf.extend_from_slice(&(s.natoms() as u32).to_le_bytes());
        buf.push(s.dataset.index() as u8);
        buf.extend_from_slice(&s.species);
        for p in &s.positions {
            for &x in p {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        buf.extend_from_slice(&s.energy.to_le_bytes());
        for f in &s.forces {
            for &x in f {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        self.out.write_all(&buf)?;
        self.pos += buf.len() as u64;
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.offsets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.offsets.is_empty()
    }

    /// Write the footer index and flush. Consumes the writer.
    pub fn finish(mut self) -> Result<usize, PackError> {
        let index_offset = self.pos;
        let mut index = Vec::with_capacity(self.offsets.len() * 8);
        for off in &self.offsets {
            index.extend_from_slice(&off.to_le_bytes());
        }
        let crc = crate::util::crc32::hash(&index);
        self.out.write_all(&index)?;
        self.out.write_all(&(self.offsets.len() as u64).to_le_bytes())?;
        self.out.write_all(&index_offset.to_le_bytes())?;
        self.out.write_all(&crc.to_le_bytes())?;
        self.out.write_all(MAGIC_END)?;
        self.out.flush()?;
        Ok(self.offsets.len())
    }
}

/// Convenience: pack a slice of structures into `path`.
pub fn write_all(path: impl AsRef<Path>, structures: &[AtomicStructure]) -> Result<usize, PackError> {
    let mut w = GPackWriter::create(path)?;
    for s in structures {
        w.write(s)?;
    }
    w.finish()
}

/// Convenience: read every structure from `path` (the write_all twin; the
/// `serve`/`loadtest` CLI's `--data` path).
pub fn read_all(path: impl AsRef<Path>) -> Result<Vec<AtomicStructure>, PackError> {
    GPackReader::open(path)?.read_all()
}

// ---------------------------------------------------------------------------
// reader
// ---------------------------------------------------------------------------

pub struct GPackReader {
    file: BufReader<File>,
    offsets: Vec<u64>,
}

impl GPackReader {
    pub fn open(path: impl AsRef<Path>) -> Result<GPackReader, PackError> {
        let mut file = BufReader::new(File::open(path)?);
        let mut head = [0u8; 8];
        file.read_exact(&mut head)?;
        if &head[..4] != MAGIC {
            return Err(PackError::BadMagic);
        }
        let version = u32::from_le_bytes(head[4..8].try_into().unwrap());
        if version != VERSION {
            return Err(PackError::BadVersion(version));
        }

        // Tail: count u64 | index_offset u64 | crc u32 | magic 4 = 24 bytes.
        let end = file.seek(SeekFrom::End(0))?;
        if end < 32 {
            return Err(PackError::BadMagic);
        }
        file.seek(SeekFrom::End(-24))?;
        let mut tail = [0u8; 24];
        file.read_exact(&mut tail)?;
        if &tail[20..24] != MAGIC_END {
            return Err(PackError::BadMagic);
        }
        let count = u64::from_le_bytes(tail[0..8].try_into().unwrap()) as usize;
        let index_offset = u64::from_le_bytes(tail[8..16].try_into().unwrap());
        let crc_stored = u32::from_le_bytes(tail[16..20].try_into().unwrap());

        file.seek(SeekFrom::Start(index_offset))?;
        let mut index = vec![0u8; count * 8];
        file.read_exact(&mut index)?;
        if crate::util::crc32::hash(&index) != crc_stored {
            return Err(PackError::BadChecksum);
        }
        let offsets = index
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(GPackReader { file, offsets })
    }

    pub fn len(&self) -> usize {
        self.offsets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.offsets.is_empty()
    }

    /// Random-access read of sample `i`.
    pub fn read(&mut self, i: usize) -> Result<AtomicStructure, PackError> {
        let off = *self
            .offsets
            .get(i)
            .ok_or(PackError::OutOfRange(i, self.offsets.len()))?;
        self.file.seek(SeekFrom::Start(off))?;
        let mut head = [0u8; 5];
        self.file.read_exact(&mut head)?;
        let natoms = u32::from_le_bytes(head[0..4].try_into().unwrap()) as usize;
        if natoms == 0 || natoms > 1_000_000 {
            return Err(PackError::Corrupt(off));
        }
        let dataset_idx = head[4] as usize;
        // Valid iff a task is registered at that index (readers must
        // register the same custom tasks the writer used).
        if dataset_idx >= crate::tasks::TaskRegistry::global().len() {
            return Err(PackError::Corrupt(off));
        }

        let mut species = vec![0u8; natoms];
        self.file.read_exact(&mut species)?;
        let mut body = vec![0u8; natoms * 24 + 8 + natoms * 24];
        self.file.read_exact(&mut body)?;

        let mut pos_iter = body.chunks_exact(8);
        let mut next_f64 =
            || f64::from_le_bytes(pos_iter.next().unwrap().try_into().unwrap());
        let positions: Vec<[f64; 3]> =
            (0..natoms).map(|_| [next_f64(), next_f64(), next_f64()]).collect();
        let energy = next_f64();
        let forces: Vec<[f64; 3]> =
            (0..natoms).map(|_| [next_f64(), next_f64(), next_f64()]).collect();

        Ok(AtomicStructure {
            species,
            positions,
            energy,
            forces,
            dataset: DatasetId::from_index(dataset_idx),
        })
    }

    /// Read every sample (tests / small files).
    pub fn read_all(&mut self) -> Result<Vec<AtomicStructure>, PackError> {
        (0..self.len()).map(|i| self.read(i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{DatasetGenerator, GeneratorConfig};

    fn samples(n: usize) -> Vec<AtomicStructure> {
        let mut g =
            DatasetGenerator::new(DatasetId::Transition1x, 5, GeneratorConfig::default());
        g.take(n)
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("hydra_mtp_pack_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}_{}.gpack", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let path = tmp("roundtrip");
        let ss = samples(20);
        let n = write_all(&path, &ss).unwrap();
        assert_eq!(n, 20);
        let mut r = GPackReader::open(&path).unwrap();
        assert_eq!(r.len(), 20);
        let back = r.read_all().unwrap();
        assert_eq!(ss, back);
        // The module-level convenience is the same read.
        assert_eq!(read_all(&path).unwrap(), ss);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn random_access_matches_sequential() {
        let path = tmp("random_access");
        let ss = samples(10);
        write_all(&path, &ss).unwrap();
        let mut r = GPackReader::open(&path).unwrap();
        // Read out of order.
        for &i in &[7usize, 0, 9, 3, 3, 1] {
            assert_eq!(r.read(i).unwrap(), ss[i], "sample {i}");
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn out_of_range_is_error() {
        let path = tmp("oob");
        write_all(&path, &samples(3)).unwrap();
        let mut r = GPackReader::open(&path).unwrap();
        assert!(matches!(r.read(3), Err(PackError::OutOfRange(3, 3))));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn detects_corrupted_index() {
        let path = tmp("corrupt");
        write_all(&path, &samples(5)).unwrap();
        // Flip a byte inside the index region (near the end, before tail).
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 30] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        match GPackReader::open(&path) {
            Err(PackError::BadChecksum) | Err(PackError::BadMagic) => {}
            Err(other) => panic!("expected checksum error, got {other:?}"),
            Ok(_) => panic!("expected checksum error, got Ok"),
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_non_gpack_files() {
        let path = tmp("notgpack");
        std::fs::write(&path, b"definitely not a gpack file, but long enough to have a tail........").unwrap();
        assert!(matches!(GPackReader::open(&path), Err(PackError::BadMagic)));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn empty_file_roundtrip() {
        let path = tmp("empty");
        let w = GPackWriter::create(&path).unwrap();
        w.finish().unwrap();
        let r = GPackReader::open(&path).unwrap();
        assert_eq!(r.len(), 0);
        std::fs::remove_file(path).ok();
    }
}
