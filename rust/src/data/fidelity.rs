//! Per-dataset fidelity transforms: the multi-source, multi-fidelity
//! inconsistency the paper's MTL approach exists to absorb.
//!
//! Real datasets disagree because they use different approximation theories
//! (DFT vs CCSD) and parameterizations (exchange-correlation functional,
//! basis set). The dominant, well-documented effect is a **per-element
//! atomic reference-energy shift** — precisely what "total-energy alignment"
//! schemes (Shiota et al.) try to remove, and what per-dataset MTL heads
//! learn implicitly. We model a labeled energy as
//!
//!   E_label = scale_d * E_true + sum_atoms shift_d[z] + noise
//!   F_label = scale_d * F_true + noise
//!
//! with all constants a deterministic function of the dataset id, so the
//! conflict between datasets is reproducible run-to-run.

use crate::data::structures::DatasetId;
use crate::elements::MAX_Z;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct FidelityModel {
    pub dataset: DatasetId,
    /// Per-element reference energy shift, indexed by Z (0 unused).
    pub ref_shift: Vec<f64>,
    /// Multiplicative fidelity scale on the true energy / forces.
    pub energy_scale: f64,
    pub force_scale: f64,
    /// Label noise floors (sigma).
    pub energy_noise: f64,
    pub force_noise: f64,
}

/// Per-dataset magnitudes. Organic datasets (different functionals over the
/// same CHNO chemistry) get *large, conflicting* reference shifts — that is
/// the instability source the paper highlights; the two inorganic datasets
/// use nearly identical settings (PBE-family), so their shifts are close,
/// mirroring how the paper's Model-MPTrj and Model-Alexandria transfer to
/// each other far better than the organic models do to either.
fn profile(dataset: DatasetId) -> (u64, f64, f64, f64, f64, f64) {
    // (seed_tag, shift_sigma, scale_jitter, force_scale_jitter, e_noise, f_noise)
    match dataset {
        DatasetId::Ani1x => (11, 0.90, 0.02, 0.01, 0.002, 0.004),
        DatasetId::Qm7x => (23, 1.40, 0.05, 0.02, 0.002, 0.004),
        DatasetId::Transition1x => (37, 0.70, 0.03, 0.015, 0.003, 0.006),
        // MPTrj / Alexandria: deliberately the *same* seed tag with small
        // sigma, so inorganic labels nearly agree (see doc comment).
        DatasetId::MpTrj => (53, 0.25, 0.01, 0.005, 0.002, 0.003),
        DatasetId::Alexandria => (53, 0.25, 0.01, 0.005, 0.002, 0.003),
    }
}

impl FidelityModel {
    /// Deterministically build the fidelity model for a dataset.
    pub fn for_dataset(dataset: DatasetId) -> FidelityModel {
        let (tag, shift_sigma, scale_j, fscale_j, e_noise, f_noise) = profile(dataset);
        let mut rng = Rng::new(fidelity_seed(tag));
        let mut ref_shift = vec![0.0; MAX_Z + 1];
        for z in 1..=MAX_Z {
            ref_shift[z] = rng.normal_scaled(0.0, shift_sigma);
        }
        // Alexandria differs from MPTrj by a small constant offset on top of
        // the shared shifts (same functional family, different code/settings).
        if dataset == DatasetId::Alexandria {
            for z in 1..=MAX_Z {
                ref_shift[z] += 0.05;
            }
        }
        let energy_scale = 1.0 + rng.normal_scaled(0.0, scale_j);
        let force_scale = 1.0 + rng.normal_scaled(0.0, fscale_j);
        FidelityModel {
            dataset,
            ref_shift,
            energy_scale,
            force_scale,
            energy_noise: e_noise,
            force_noise: f_noise,
        }
    }

    /// Transform ground-truth labels into this dataset's labeled values.
    pub fn apply(
        &self,
        species: &[u8],
        true_energy: f64,
        true_forces: &[[f64; 3]],
        rng: &mut Rng,
    ) -> (f64, Vec<[f64; 3]>) {
        let shift: f64 = species.iter().map(|&z| self.ref_shift[z as usize]).sum();
        let energy = self.energy_scale * true_energy
            + shift
            + rng.normal_scaled(0.0, self.energy_noise) * species.len() as f64;
        let forces = true_forces
            .iter()
            .map(|f| {
                [
                    self.force_scale * f[0] + rng.normal_scaled(0.0, self.force_noise),
                    self.force_scale * f[1] + rng.normal_scaled(0.0, self.force_noise),
                    self.force_scale * f[2] + rng.normal_scaled(0.0, self.force_noise),
                ]
            })
            .collect();
        (energy, forces)
    }

    /// Mean absolute per-atom label disagreement with another fidelity model
    /// over a given species composition — used by the multi_fidelity_inspect
    /// example and the data tests to quantify the cross-dataset conflict.
    pub fn disagreement(&self, other: &FidelityModel, species: &[u8]) -> f64 {
        let a: f64 = species.iter().map(|&z| self.ref_shift[z as usize]).sum();
        let b: f64 = species.iter().map(|&z| other.ref_shift[z as usize]).sum();
        (a - b).abs() / species.len() as f64
    }
}

/// Seed helper kept separate so the constant reads as intent, not magic.
#[inline]
fn fidelity_seed(tag: u64) -> u64 {
    0xF1DE_1171u64 ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::structures::ALL_DATASETS;

    #[test]
    fn deterministic_per_dataset() {
        for d in ALL_DATASETS {
            let a = FidelityModel::for_dataset(d);
            let b = FidelityModel::for_dataset(d);
            assert_eq!(a.ref_shift, b.ref_shift, "{d:?}");
            assert_eq!(a.energy_scale, b.energy_scale);
        }
    }

    #[test]
    fn organic_datasets_conflict_on_chno() {
        let ani = FidelityModel::for_dataset(DatasetId::Ani1x);
        let qm7 = FidelityModel::for_dataset(DatasetId::Qm7x);
        // CH4-like composition: per-atom disagreement should be substantial.
        let species = [6u8, 1, 1, 1, 1];
        assert!(
            ani.disagreement(&qm7, &species) > 0.05,
            "organic sources must disagree: {}",
            ani.disagreement(&qm7, &species)
        );
    }

    #[test]
    fn inorganic_datasets_nearly_agree() {
        let mp = FidelityModel::for_dataset(DatasetId::MpTrj);
        let alex = FidelityModel::for_dataset(DatasetId::Alexandria);
        let species = [26u8, 8, 8, 22]; // FeTiO2-ish
        // Same seed tag -> shifts differ only by the constant 0.05 offset.
        assert!(
            (alex.disagreement(&mp, &species) - 0.05).abs() < 1e-9,
            "got {}",
            alex.disagreement(&mp, &species)
        );
    }

    #[test]
    fn apply_shifts_energy_by_composition() {
        let m = FidelityModel::for_dataset(DatasetId::Ani1x);
        let species = [6u8, 1, 1];
        let forces = vec![[0.1, -0.2, 0.3]; 3];
        let mut rng = Rng::new(1);
        let (e, f) = m.apply(&species, -3.0, &forces, &mut rng);
        let expected_shift: f64 =
            species.iter().map(|&z| m.ref_shift[z as usize]).sum();
        // Noise sigma is small; check we are near scale*E + shift.
        assert!((e - (m.energy_scale * -3.0 + expected_shift)).abs() < 0.1);
        assert_eq!(f.len(), 3);
    }

    #[test]
    fn forces_unaffected_by_ref_shift() {
        // Reference shifts move energies, not forces: the paper's Table 2
        // shows inorganic models agreeing on forces even across datasets.
        let m = FidelityModel::for_dataset(DatasetId::Qm7x);
        let species = [6u8];
        let forces = vec![[1.0, 0.0, 0.0]];
        let mut rng = Rng::new(2);
        let (_, f) = m.apply(&species, 0.0, &forces, &mut rng);
        assert!((f[0][0] - m.force_scale).abs() < 0.05);
    }
}
