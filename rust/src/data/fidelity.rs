//! Per-task fidelity transforms: the multi-source, multi-fidelity
//! inconsistency the paper's MTL approach exists to absorb.
//!
//! Real datasets disagree because they use different approximation theories
//! (DFT vs CCSD) and parameterizations (exchange-correlation functional,
//! basis set). The dominant, well-documented effect is a **per-element
//! atomic reference-energy shift** — precisely what "total-energy alignment"
//! schemes (Shiota et al.) try to remove, and what per-dataset MTL heads
//! learn implicitly. We model a labeled energy as
//!
//!   E_label = scale_d * E_true + sum_atoms shift_d[z] + noise
//!   F_label = scale_d * F_true + noise
//!
//! with all constants coming from the task's [`FidelityProfile`] in the
//! registry (deterministic per seed tag), so the conflict between datasets
//! is reproducible run-to-run. The five presets carry the seed repo's exact
//! constants: organic sources get large, conflicting shifts; the two
//! inorganic sources share a seed tag (same PBE family) and nearly agree,
//! mirroring the paper's Tables 1-2 transfer structure.

use crate::data::structures::DatasetId;
use crate::elements::MAX_Z;
use crate::tasks::FidelityProfile;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct FidelityModel {
    pub dataset: DatasetId,
    /// Per-element reference energy shift, indexed by Z (0 unused).
    pub ref_shift: Vec<f64>,
    /// Multiplicative fidelity scale on the true energy / forces.
    pub energy_scale: f64,
    pub force_scale: f64,
    /// Label noise floors (sigma).
    pub energy_noise: f64,
    pub force_noise: f64,
}

impl FidelityModel {
    /// Deterministically build the fidelity model for a registered task.
    pub fn for_dataset(dataset: DatasetId) -> FidelityModel {
        FidelityModel::from_profile(dataset, &dataset.spec().fidelity)
    }

    /// Deterministically expand a [`FidelityProfile`] into per-element
    /// shifts and scales. The RNG stream depends only on the seed tag, so
    /// two tasks sharing a tag (MPTrj/Alexandria) produce the same base
    /// shifts, differing only by `shift_offset`.
    pub fn from_profile(dataset: DatasetId, p: &FidelityProfile) -> FidelityModel {
        let mut rng = Rng::new(fidelity_seed(p.seed_tag));
        let mut ref_shift = vec![0.0; MAX_Z + 1];
        for z in 1..=MAX_Z {
            ref_shift[z] = rng.normal_scaled(0.0, p.shift_sigma);
        }
        if p.shift_offset != 0.0 {
            for z in 1..=MAX_Z {
                ref_shift[z] += p.shift_offset;
            }
        }
        let energy_scale = 1.0 + rng.normal_scaled(0.0, p.scale_jitter);
        let force_scale = 1.0 + rng.normal_scaled(0.0, p.force_scale_jitter);
        FidelityModel {
            dataset,
            ref_shift,
            energy_scale,
            force_scale,
            energy_noise: p.energy_noise,
            force_noise: p.force_noise,
        }
    }

    /// Transform ground-truth labels into this dataset's labeled values.
    pub fn apply(
        &self,
        species: &[u8],
        true_energy: f64,
        true_forces: &[[f64; 3]],
        rng: &mut Rng,
    ) -> (f64, Vec<[f64; 3]>) {
        let shift: f64 = species.iter().map(|&z| self.ref_shift[z as usize]).sum();
        let energy = self.energy_scale * true_energy
            + shift
            + rng.normal_scaled(0.0, self.energy_noise) * species.len() as f64;
        let forces = true_forces
            .iter()
            .map(|f| {
                [
                    self.force_scale * f[0] + rng.normal_scaled(0.0, self.force_noise),
                    self.force_scale * f[1] + rng.normal_scaled(0.0, self.force_noise),
                    self.force_scale * f[2] + rng.normal_scaled(0.0, self.force_noise),
                ]
            })
            .collect();
        (energy, forces)
    }

    /// Mean absolute per-atom label disagreement with another fidelity model
    /// over a given species composition — used by the multi_fidelity_inspect
    /// example and the data tests to quantify the cross-dataset conflict.
    pub fn disagreement(&self, other: &FidelityModel, species: &[u8]) -> f64 {
        let a: f64 = species.iter().map(|&z| self.ref_shift[z as usize]).sum();
        let b: f64 = species.iter().map(|&z| other.ref_shift[z as usize]).sum();
        (a - b).abs() / species.len() as f64
    }
}

/// Seed helper kept separate so the constant reads as intent, not magic.
#[inline]
fn fidelity_seed(tag: u64) -> u64 {
    0xF1DE_1171u64 ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::structures::ALL_DATASETS;

    #[test]
    fn deterministic_per_dataset() {
        for d in ALL_DATASETS {
            let a = FidelityModel::for_dataset(d);
            let b = FidelityModel::for_dataset(d);
            assert_eq!(a.ref_shift, b.ref_shift, "{d:?}");
            assert_eq!(a.energy_scale, b.energy_scale);
        }
    }

    #[test]
    fn organic_datasets_conflict_on_chno() {
        let ani = FidelityModel::for_dataset(DatasetId::Ani1x);
        let qm7 = FidelityModel::for_dataset(DatasetId::Qm7x);
        // CH4-like composition: per-atom disagreement should be substantial.
        let species = [6u8, 1, 1, 1, 1];
        assert!(
            ani.disagreement(&qm7, &species) > 0.05,
            "organic sources must disagree: {}",
            ani.disagreement(&qm7, &species)
        );
    }

    #[test]
    fn inorganic_datasets_nearly_agree() {
        let mp = FidelityModel::for_dataset(DatasetId::MpTrj);
        let alex = FidelityModel::for_dataset(DatasetId::Alexandria);
        let species = [26u8, 8, 8, 22]; // FeTiO2-ish
        // Same seed tag -> shifts differ only by the constant 0.05 offset.
        assert!(
            (alex.disagreement(&mp, &species) - 0.05).abs() < 1e-9,
            "got {}",
            alex.disagreement(&mp, &species)
        );
    }

    #[test]
    fn apply_shifts_energy_by_composition() {
        let m = FidelityModel::for_dataset(DatasetId::Ani1x);
        let species = [6u8, 1, 1];
        let forces = vec![[0.1, -0.2, 0.3]; 3];
        let mut rng = Rng::new(1);
        let (e, f) = m.apply(&species, -3.0, &forces, &mut rng);
        let expected_shift: f64 =
            species.iter().map(|&z| m.ref_shift[z as usize]).sum();
        // Noise sigma is small; check we are near scale*E + shift.
        assert!((e - (m.energy_scale * -3.0 + expected_shift)).abs() < 0.1);
        assert_eq!(f.len(), 3);
    }

    #[test]
    fn forces_unaffected_by_ref_shift() {
        // Reference shifts move energies, not forces: the paper's Table 2
        // shows inorganic models agreeing on forces even across datasets.
        let m = FidelityModel::for_dataset(DatasetId::Qm7x);
        let species = [6u8];
        let forces = vec![[1.0, 0.0, 0.0]];
        let mut rng = Rng::new(2);
        let (_, f) = m.apply(&species, 0.0, &forces, &mut rng);
        assert!((f[0][0] - m.force_scale).abs() < 0.05);
    }

    #[test]
    fn custom_profile_expands_deterministically() {
        let p = FidelityProfile {
            seed_tag: 77,
            shift_sigma: 0.4,
            scale_jitter: 0.02,
            force_scale_jitter: 0.01,
            energy_noise: 0.001,
            force_noise: 0.002,
            shift_offset: 0.1,
        };
        let a = FidelityModel::from_profile(DatasetId::Ani1x, &p);
        let b = FidelityModel::from_profile(DatasetId::Ani1x, &p);
        assert_eq!(a.ref_shift, b.ref_shift);
        // Offset shifts every element by the same constant relative to the
        // zero-offset expansion of the same tag.
        let mut p0 = p.clone();
        p0.shift_offset = 0.0;
        let base = FidelityModel::from_profile(DatasetId::Ani1x, &p0);
        for z in 1..=crate::elements::MAX_Z {
            assert!((a.ref_shift[z] - base.ref_shift[z] - 0.1).abs() < 1e-12);
        }
    }
}
