//! Deterministic train/validation/test splitting.
//!
//! Splits are a pure function of the sample's global index via a hash, so
//! every rank derives identical splits without communication, and the split
//! is stable as files are re-read or shards move between ranks.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
    Test,
}

/// Fractions for (train, val); test is the remainder.
#[derive(Debug, Clone, Copy)]
pub struct SplitSpec {
    pub train: f64,
    pub val: f64,
}

impl Default for SplitSpec {
    fn default() -> Self {
        // Matches the common 0.8 / 0.1 / 0.1 convention used by HydraGNN.
        SplitSpec { train: 0.8, val: 0.1 }
    }
}

impl SplitSpec {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.train > 0.0 && self.val >= 0.0, "bad split fractions");
        anyhow::ensure!(self.train + self.val < 1.0 + 1e-12, "train+val must be <= 1");
        Ok(())
    }

    /// Split assignment for a global sample index.
    pub fn of(&self, index: usize, seed: u64) -> Split {
        let h = hash_index(index as u64, seed);
        let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        if u < self.train {
            Split::Train
        } else if u < self.train + self.val {
            Split::Val
        } else {
            Split::Test
        }
    }

    /// Indices of a split among 0..n.
    pub fn indices(&self, n: usize, seed: u64, which: Split) -> Vec<usize> {
        (0..n).filter(|&i| self.of(i, seed) == which).collect()
    }
}

#[inline]
fn hash_index(i: u64, seed: u64) -> u64 {
    let mut z = i.wrapping_add(seed).wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_is_deterministic() {
        let spec = SplitSpec::default();
        for i in 0..100 {
            assert_eq!(spec.of(i, 7), spec.of(i, 7));
        }
    }

    #[test]
    fn partitions_are_disjoint_and_complete() {
        let spec = SplitSpec::default();
        let n = 5000;
        let train = spec.indices(n, 1, Split::Train);
        let val = spec.indices(n, 1, Split::Val);
        let test = spec.indices(n, 1, Split::Test);
        assert_eq!(train.len() + val.len() + test.len(), n);
        let mut all: Vec<usize> = train.iter().chain(&val).chain(&test).copied().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n);
    }

    #[test]
    fn fractions_approximately_respected() {
        let spec = SplitSpec { train: 0.8, val: 0.1 };
        let n = 20000;
        let train = spec.indices(n, 3, Split::Train).len() as f64 / n as f64;
        let val = spec.indices(n, 3, Split::Val).len() as f64 / n as f64;
        assert!((train - 0.8).abs() < 0.02, "train={train}");
        assert!((val - 0.1).abs() < 0.01, "val={val}");
    }

    #[test]
    fn different_seeds_differ() {
        let spec = SplitSpec::default();
        let n = 1000;
        let a = spec.indices(n, 1, Split::Test);
        let b = spec.indices(n, 2, Split::Test);
        assert_ne!(a, b);
    }

    #[test]
    fn validates_fractions() {
        assert!(SplitSpec { train: 0.9, val: 0.2 }.validate().is_err());
        assert!(SplitSpec { train: 0.7, val: 0.1 }.validate().is_ok());
    }
}
