//! Runtime task registry: the paper's five datasets as *data*, not code.
//!
//! The seed hard-wired a closed five-variant `DatasetId` enum that every
//! layer matched on — palette in `elements`, fidelity constants in
//! `fidelity`, geometry class in `generators`, head-init salt in the
//! trainer. This module inverts that: a [`TaskSpec`] bundles dataset
//! identity, element palette, fidelity transform, generator family and head
//! configuration as runtime values, and [`DatasetId`] becomes a lightweight
//! handle (an index) into the process-global [`TaskRegistry`].
//!
//! The paper's five datasets (Section 4.1) are registered as built-in
//! presets at indices 0..=4, so all seed behaviour — RNG streams, split
//! seeds, head-init salts, `BTreeMap` orderings — is bit-for-bit preserved.
//! Arbitrary additional tasks (e.g. a sixth synthetic dataset) register at
//! runtime and flow through generation, training (`mtl-par` grows a sixth
//! head sub-group), evaluation and serving without code changes.
//!
//! Registration is process-global and append-only: handles stored inside
//! `AtomicStructure`s or GPack files stay valid for the process lifetime.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::{Arc, OnceLock, RwLock};

use crate::elements;

// ---------------------------------------------------------------------------
// handle
// ---------------------------------------------------------------------------

/// Lightweight handle to a registered task (index into the registry).
///
/// Replaces the seed's closed enum; the five paper datasets are the
/// associated constants below. `Ord` is registration order, which for the
/// presets equals the old enum-variant order, so `BTreeMap` iteration and
/// the mesh head assignment are unchanged.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DatasetId(u16);

#[allow(non_upper_case_globals)]
impl DatasetId {
    pub const Ani1x: DatasetId = DatasetId(0);
    pub const Qm7x: DatasetId = DatasetId(1);
    pub const Transition1x: DatasetId = DatasetId(2);
    pub const MpTrj: DatasetId = DatasetId(3);
    pub const Alexandria: DatasetId = DatasetId(4);
}

/// The five built-in datasets the paper aggregates (Section 4.1), in paper
/// order. Custom tasks are *not* listed here; use `TaskRegistry::all()`.
pub const ALL_DATASETS: [DatasetId; 5] = [
    DatasetId::Ani1x,
    DatasetId::Qm7x,
    DatasetId::Transition1x,
    DatasetId::MpTrj,
    DatasetId::Alexandria,
];

impl DatasetId {
    /// O(1) registry index (the seed's linear `position()` scan is gone).
    #[inline]
    pub fn index(&self) -> usize {
        self.0 as usize
    }

    /// Handle for a registry index; panics if no task is registered there.
    pub fn from_index(i: usize) -> DatasetId {
        let n = TaskRegistry::global().len();
        assert!(i < n, "task index {i} out of range ({n} registered)");
        DatasetId(i as u16)
    }

    /// Case/hyphen-insensitive name lookup across every registered task.
    pub fn from_name(name: &str) -> Option<DatasetId> {
        TaskRegistry::global().find(name)
    }

    /// Display name from the task spec.
    pub fn name(&self) -> String {
        match TaskRegistry::global().try_spec(*self) {
            Some(spec) => spec.name.clone(),
            None => format!("task#{}", self.0),
        }
    }

    /// Full spec of this task.
    pub fn spec(&self) -> Arc<TaskSpec> {
        TaskRegistry::global().spec(*self)
    }

    /// Whether the task generates inorganic (crystalline / bulk) structures.
    pub fn is_inorganic(&self) -> bool {
        matches!(
            self.spec().generator.kind,
            StructureKind::Crystal { .. }
                | StructureKind::Supercell { .. }
                | StructureKind::AmorphousBox { .. }
        )
    }

    /// Element palette of the task (atomic numbers).
    pub fn palette(&self) -> Vec<usize> {
        self.spec().palette.clone()
    }

    /// Salt mixed into the branch-parameter init seed for this task's head.
    /// Presets resolve to the seed repo's exact constants so checkpoints and
    /// training trajectories are unchanged.
    pub fn branch_init_salt(&self) -> u64 {
        self.spec()
            .head
            .init_salt
            .unwrap_or(0xB4A9 + self.index() as u64 * 7919)
    }
}

impl fmt::Debug for DatasetId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "DatasetId({})", self.name())
    }
}

// ---------------------------------------------------------------------------
// spec
// ---------------------------------------------------------------------------

/// Geometry class a task's generator produces.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StructureKind {
    /// Organic molecule with `min_atoms..min(config.max_atoms, atoms_cap)`
    /// atoms (bonded-tree builder).
    Molecule { min_atoms: usize, atoms_cap: usize },
    /// QM7-X style: `min_heavy..max_heavy` non-hydrogen atoms, hydrogen
    /// saturated up to the config atom budget.
    MoleculeHeavyLimited { min_heavy: usize, max_heavy: usize },
    /// Crystalline cluster with `min_atoms..config.max_atoms` atoms.
    Crystal { min_atoms: usize },
    /// Bulk crystalline supercell: `reps^3` lattice sites on a cubic grid,
    /// two palette species interleaved rock-salt style. Deliberately ignores
    /// `GeneratorConfig::max_atoms` — thousands-of-atom structures are the
    /// point (graph-parallel training splits them across ranks).
    Supercell { reps: usize },
    /// Amorphous bulk: `natoms` atoms of random palette species on a
    /// strongly jittered cubic grid (glass-like disorder, overlap-free by
    /// construction). Also ignores `GeneratorConfig::max_atoms`.
    AmorphousBox { natoms: usize },
}

/// How a task's structures are generated (geometry + equilibrium character).
#[derive(Debug, Clone, PartialEq)]
pub struct GeneratorProfile {
    pub kind: StructureKind,
    /// Steepest-descent relaxation iterations before perturbation (0 = none,
    /// i.e. reaction-pathway data stays off-equilibrium).
    pub relax_steps: usize,
    /// Relaxation step size (Angstrom).
    pub relax_step_size: f64,
    /// Multiplier on `GeneratorConfig::perturbation` for the final jitter:
    /// near-equilibrium datasets use < 1, reaction pathways > 1.
    pub perturb_factor: f64,
}

/// Constants of a task's label fidelity transform (see `data::fidelity` for
/// the model: `E_label = scale * E_true + sum_z shift[z] + noise`).
#[derive(Debug, Clone, PartialEq)]
pub struct FidelityProfile {
    /// Seed tag for the deterministic per-element shift stream. Two tasks
    /// sharing a tag model the same theory level (MPTrj/Alexandria).
    pub seed_tag: u64,
    /// Std-dev of the per-element reference-energy shifts.
    pub shift_sigma: f64,
    /// Jitter of the multiplicative energy scale around 1.
    pub scale_jitter: f64,
    /// Jitter of the multiplicative force scale around 1.
    pub force_scale_jitter: f64,
    /// Label noise floors (sigma).
    pub energy_noise: f64,
    pub force_noise: f64,
    /// Constant added to every element's shift on top of the seeded stream
    /// (how Alexandria differs from MPTrj within the same PBE family).
    pub shift_offset: f64,
}

/// Head / loss configuration of a task's MTL branch.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HeadConfig {
    /// Override for the branch-parameter init-seed salt. `None` resolves to
    /// the registry-index-derived default (`DatasetId::branch_init_salt`).
    pub init_salt: Option<u64>,
}

/// Everything that defines one pre-training task, as runtime values.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskSpec {
    /// Display name (e.g. "ANI1x"). Lookup is case/hyphen-insensitive.
    pub name: String,
    /// Element palette: atomic numbers the generator may draw.
    pub palette: Vec<usize>,
    pub generator: GeneratorProfile,
    pub fidelity: FidelityProfile,
    pub head: HeadConfig,
}

impl TaskSpec {
    pub fn new(
        name: impl Into<String>,
        palette: Vec<usize>,
        generator: GeneratorProfile,
        fidelity: FidelityProfile,
    ) -> TaskSpec {
        TaskSpec {
            name: name.into(),
            palette,
            generator,
            fidelity,
            head: HeadConfig::default(),
        }
    }

    pub fn with_head(mut self, head: HeadConfig) -> TaskSpec {
        self.head = head;
        self
    }

    fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.name.trim().is_empty(), "task name must be non-empty");
        anyhow::ensure!(!self.palette.is_empty(), "task '{}': empty palette", self.name);
        for &z in &self.palette {
            anyhow::ensure!(
                (1..=elements::MAX_Z).contains(&z),
                "task '{}': palette element Z={z} outside 1..={}",
                self.name,
                elements::MAX_Z
            );
        }
        match self.generator.kind {
            StructureKind::Molecule { min_atoms, atoms_cap } => {
                anyhow::ensure!(
                    min_atoms >= 2 && atoms_cap >= min_atoms,
                    "task '{}': bad molecule size range",
                    self.name
                );
                anyhow::ensure!(
                    self.palette.iter().any(|&z| z != 1),
                    "task '{}': molecular palette needs a heavy element",
                    self.name
                );
            }
            StructureKind::MoleculeHeavyLimited { min_heavy, max_heavy } => {
                anyhow::ensure!(
                    min_heavy >= 1 && max_heavy >= min_heavy,
                    "task '{}': bad heavy-atom range",
                    self.name
                );
                anyhow::ensure!(
                    self.palette.iter().any(|&z| z != 1),
                    "task '{}': molecular palette needs a heavy element",
                    self.name
                );
            }
            StructureKind::Crystal { min_atoms } => {
                anyhow::ensure!(
                    min_atoms >= 2,
                    "task '{}': crystals need at least 2 atoms",
                    self.name
                );
            }
            StructureKind::Supercell { reps } => {
                // reps^3 atoms: cap at 32^3 so the O(n^2) ground-truth
                // labeler stays tractable.
                anyhow::ensure!(
                    (2..=32).contains(&reps),
                    "task '{}': supercell reps must be in 2..=32, got {reps}",
                    self.name
                );
            }
            StructureKind::AmorphousBox { natoms } => {
                anyhow::ensure!(
                    (2..=32_768).contains(&natoms),
                    "task '{}': amorphous box needs 2..=32768 atoms, got {natoms}",
                    self.name
                );
            }
        }
        // All sigmas finite and non-negative (a NaN here would silently
        // poison every label the task generates), offset finite.
        for (field, v) in [
            ("shift_sigma", self.fidelity.shift_sigma),
            ("scale_jitter", self.fidelity.scale_jitter),
            ("force_scale_jitter", self.fidelity.force_scale_jitter),
            ("energy_noise", self.fidelity.energy_noise),
            ("force_noise", self.fidelity.force_noise),
        ] {
            anyhow::ensure!(
                v.is_finite() && v >= 0.0,
                "task '{}': fidelity {field} must be finite and non-negative, got {v}",
                self.name
            );
        }
        anyhow::ensure!(
            self.fidelity.shift_offset.is_finite(),
            "task '{}': shift_offset must be finite",
            self.name
        );
        anyhow::ensure!(
            self.generator.perturb_factor.is_finite() && self.generator.perturb_factor >= 0.0,
            "task '{}': perturb_factor must be finite and non-negative",
            self.name
        );
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// registry
// ---------------------------------------------------------------------------

/// Name normalization shared by registration and lookup: lowercase with
/// hyphens removed, so "qm7x" finds "QM7-X" (seed `from_name` behaviour).
fn normalize(name: &str) -> String {
    name.to_ascii_lowercase().replace('-', "")
}

struct Table {
    specs: Vec<Arc<TaskSpec>>,
    by_name: BTreeMap<String, u16>,
}

static TABLE: OnceLock<RwLock<Table>> = OnceLock::new();

fn table() -> &'static RwLock<Table> {
    TABLE.get_or_init(|| {
        let specs: Vec<Arc<TaskSpec>> =
            builtin_specs().into_iter().map(Arc::new).collect();
        let by_name = specs
            .iter()
            .enumerate()
            .map(|(i, s)| (normalize(&s.name), i as u16))
            .collect();
        RwLock::new(Table { specs, by_name })
    })
}

/// Handle to the process-global task table. Cheap to copy around; `Session`
/// owns one so the facade's dependencies are explicit.
#[derive(Clone, Copy, Default)]
pub struct TaskRegistry {
    _priv: (),
}

impl TaskRegistry {
    /// The process-global registry (five paper presets pre-registered).
    pub fn global() -> TaskRegistry {
        TaskRegistry { _priv: () }
    }

    /// Number of registered tasks (>= 5).
    pub fn len(&self) -> usize {
        table().read().unwrap().specs.len()
    }

    /// Never true — the five presets are always registered.
    pub fn is_empty(&self) -> bool {
        false
    }

    /// Handles of every registered task, in registration order.
    pub fn all(&self) -> Vec<DatasetId> {
        (0..self.len()).map(|i| DatasetId(i as u16)).collect()
    }

    /// The five paper presets.
    pub fn builtin(&self) -> [DatasetId; 5] {
        ALL_DATASETS
    }

    /// Spec for a handle; panics on a dangling handle (only possible by
    /// fabricating an index).
    pub fn spec(&self, id: DatasetId) -> Arc<TaskSpec> {
        self.try_spec(id)
            .unwrap_or_else(|| panic!("no task registered at index {}", id.0))
    }

    pub fn try_spec(&self, id: DatasetId) -> Option<Arc<TaskSpec>> {
        table().read().unwrap().specs.get(id.index()).cloned()
    }

    /// Case/hyphen-insensitive lookup by name.
    pub fn find(&self, name: &str) -> Option<DatasetId> {
        table().read().unwrap().by_name.get(&normalize(name)).map(|&i| DatasetId(i))
    }

    /// Register a task and return its handle. Re-registering an identical
    /// spec is idempotent (returns the existing handle, so test binaries
    /// and long-lived services can re-register safely); re-registering a
    /// name with a *different* spec is an error rather than a silent
    /// discard — specs are append-only and immutable once registered.
    pub fn register(&self, spec: TaskSpec) -> anyhow::Result<DatasetId> {
        spec.validate()?;
        let key = normalize(&spec.name);
        let mut t = table().write().unwrap();
        if let Some(&i) = t.by_name.get(&key) {
            anyhow::ensure!(
                *t.specs[i as usize] == spec,
                "task '{}' is already registered with a different spec \
                 (specs are immutable; pick a new name)",
                spec.name
            );
            return Ok(DatasetId(i));
        }
        anyhow::ensure!(
            t.specs.len() < u16::MAX as usize,
            "task registry full ({} tasks)",
            t.specs.len()
        );
        let id = t.specs.len() as u16;
        t.specs.push(Arc::new(spec));
        t.by_name.insert(key, id);
        Ok(DatasetId(id))
    }
}

// ---------------------------------------------------------------------------
// built-in presets (paper Section 4.1; constants identical to the seed)
// ---------------------------------------------------------------------------

fn organic_profile(min_atoms: usize, atoms_cap: usize, relax_steps: usize, perturb: f64) -> GeneratorProfile {
    GeneratorProfile {
        kind: StructureKind::Molecule { min_atoms, atoms_cap },
        relax_steps,
        relax_step_size: 0.05,
        perturb_factor: perturb,
    }
}

fn builtin_specs() -> Vec<TaskSpec> {
    vec![
        // ANI1x: small CHNO organics, equilibrium + perturbed.
        TaskSpec::new(
            "ANI1x",
            elements::ani1x_palette(),
            organic_profile(4, 14, 10, 1.0),
            FidelityProfile {
                seed_tag: 11,
                shift_sigma: 0.90,
                scale_jitter: 0.02,
                force_scale_jitter: 0.01,
                energy_noise: 0.002,
                force_noise: 0.004,
                shift_offset: 0.0,
            },
        ),
        // QM7-X: up to 7 heavy atoms — the smallest structures.
        TaskSpec::new(
            "QM7-X",
            elements::qm7x_palette(),
            GeneratorProfile {
                kind: StructureKind::MoleculeHeavyLimited { min_heavy: 2, max_heavy: 7 },
                relax_steps: 10,
                relax_step_size: 0.05,
                perturb_factor: 1.0,
            },
            FidelityProfile {
                seed_tag: 23,
                shift_sigma: 1.40,
                scale_jitter: 0.05,
                force_scale_jitter: 0.02,
                energy_noise: 0.002,
                force_noise: 0.004,
                shift_offset: 0.0,
            },
        ),
        // Transition1x: reaction pathways — no relaxation, large jitter.
        TaskSpec::new(
            "Transition1x",
            elements::transition1x_palette(),
            organic_profile(4, 16, 0, 2.0),
            FidelityProfile {
                seed_tag: 37,
                shift_sigma: 0.70,
                scale_jitter: 0.03,
                force_scale_jitter: 0.015,
                energy_noise: 0.003,
                force_noise: 0.006,
                shift_offset: 0.0,
            },
        ),
        // MPTrj / Alexandria: near-equilibrium crystals; deliberately the
        // SAME fidelity seed tag with small sigma so the two PBE-family
        // inorganic sources nearly agree (paper Tables 1-2 block structure).
        TaskSpec::new(
            "MPTrj",
            elements::mptrj_palette(),
            GeneratorProfile {
                kind: StructureKind::Crystal { min_atoms: 4 },
                relax_steps: 20,
                relax_step_size: 0.05,
                perturb_factor: 0.3,
            },
            FidelityProfile {
                seed_tag: 53,
                shift_sigma: 0.25,
                scale_jitter: 0.01,
                force_scale_jitter: 0.005,
                energy_noise: 0.002,
                force_noise: 0.003,
                shift_offset: 0.0,
            },
        ),
        TaskSpec::new(
            "Alexandria",
            elements::alexandria_palette(),
            GeneratorProfile {
                kind: StructureKind::Crystal { min_atoms: 4 },
                relax_steps: 20,
                relax_step_size: 0.05,
                perturb_factor: 0.3,
            },
            FidelityProfile {
                seed_tag: 53,
                shift_sigma: 0.25,
                scale_jitter: 0.01,
                force_scale_jitter: 0.005,
                energy_noise: 0.002,
                force_noise: 0.003,
                shift_offset: 0.05,
            },
        ),
    ]
}

// ---------------------------------------------------------------------------
// large-structure presets (graph-parallel training)
// ---------------------------------------------------------------------------

/// Register the two large-structure presets used by graph-parallel training:
/// "Supercell" (rock-salt bulk, `10^3 = 1000` atoms) and "AmorphousBox"
/// (glass-like bulk, 1200 atoms). They are NOT built in — single-rank batch
/// training cannot hold them — so every entry point that wants them (the CLI
/// before `TrainMode::parse`, tests, benches) calls this. Idempotent:
/// re-registration of the identical specs returns the existing handles.
pub fn register_large_presets() -> anyhow::Result<(DatasetId, DatasetId)> {
    let reg = TaskRegistry::global();
    // Small inorganic palette (Mg, O, Na, Cl, Ti, Si, Al, Fe, S): the
    // supercell builder picks two species per structure, the amorphous
    // builder mixes them all.
    let palette: Vec<usize> = vec![12, 8, 11, 17, 22, 14, 13, 26, 16];
    let supercell = reg.register(TaskSpec::new(
        "Supercell",
        palette.clone(),
        GeneratorProfile {
            kind: StructureKind::Supercell { reps: 10 },
            // No steepest-descent relaxation: the lattice is built at the
            // Morse equilibrium spacing and the O(n^2) potential makes
            // per-step relaxation of 1000-atom cells needlessly expensive.
            relax_steps: 0,
            relax_step_size: 0.05,
            perturb_factor: 0.2,
        },
        FidelityProfile {
            // Same PBE-family tag as MPTrj/Alexandria: bulk supercells model
            // the same theory level as the inorganic sources.
            seed_tag: 53,
            shift_sigma: 0.25,
            scale_jitter: 0.01,
            force_scale_jitter: 0.005,
            energy_noise: 0.002,
            force_noise: 0.003,
            shift_offset: 0.0,
        },
    ))?;
    let amorphous = reg.register(TaskSpec::new(
        "AmorphousBox",
        palette,
        GeneratorProfile {
            kind: StructureKind::AmorphousBox { natoms: 1200 },
            relax_steps: 0,
            relax_step_size: 0.05,
            perturb_factor: 0.2,
        },
        FidelityProfile {
            seed_tag: 61,
            shift_sigma: 0.40,
            scale_jitter: 0.02,
            force_scale_jitter: 0.01,
            energy_noise: 0.003,
            force_noise: 0.005,
            shift_offset: 0.0,
        },
    ))?;
    Ok((supercell, amorphous))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_registered_in_paper_order() {
        let reg = TaskRegistry::global();
        assert!(reg.len() >= 5);
        let names: Vec<String> = ALL_DATASETS.iter().map(|d| d.name()).collect();
        assert_eq!(
            names,
            vec!["ANI1x", "QM7-X", "Transition1x", "MPTrj", "Alexandria"]
        );
        for (i, d) in ALL_DATASETS.iter().enumerate() {
            assert_eq!(d.index(), i);
            assert_eq!(DatasetId::from_index(i), *d);
        }
    }

    #[test]
    fn name_lookup_is_fuzzy_like_the_seed() {
        for d in ALL_DATASETS {
            assert_eq!(DatasetId::from_name(&d.name()), Some(d));
        }
        assert_eq!(DatasetId::from_name("qm7x"), Some(DatasetId::Qm7x));
        assert_eq!(DatasetId::from_name("MPTRJ"), Some(DatasetId::MpTrj));
        assert!(DatasetId::from_name("nope").is_none());
    }

    #[test]
    fn inorganic_flags_match_paper() {
        assert!(!DatasetId::Ani1x.is_inorganic());
        assert!(!DatasetId::Qm7x.is_inorganic());
        assert!(!DatasetId::Transition1x.is_inorganic());
        assert!(DatasetId::MpTrj.is_inorganic());
        assert!(DatasetId::Alexandria.is_inorganic());
    }

    #[test]
    fn branch_init_salt_matches_seed_formula() {
        for d in ALL_DATASETS {
            assert_eq!(d.branch_init_salt(), 0xB4A9 + d.index() as u64 * 7919);
        }
    }

    #[test]
    fn register_custom_task_and_find_it() {
        let reg = TaskRegistry::global();
        let spec = TaskSpec::new(
            "RegTest-A",
            vec![1, 6, 14],
            GeneratorProfile {
                kind: StructureKind::Molecule { min_atoms: 4, atoms_cap: 12 },
                relax_steps: 5,
                relax_step_size: 0.05,
                perturb_factor: 1.0,
            },
            FidelityProfile {
                seed_tag: 99,
                shift_sigma: 0.5,
                scale_jitter: 0.02,
                force_scale_jitter: 0.01,
                energy_noise: 0.002,
                force_noise: 0.004,
                shift_offset: 0.0,
            },
        );
        let id = reg.register(spec.clone()).unwrap();
        assert!(id.index() >= 5, "custom tasks append after the presets");
        assert_eq!(DatasetId::from_name("regtest-a"), Some(id));
        assert_eq!(id.name(), "RegTest-A");
        assert!(!id.is_inorganic());
        assert_eq!(id.palette(), vec![1, 6, 14]);
        // Idempotent: identical spec returns the same handle.
        assert_eq!(reg.register(spec.clone()).unwrap(), id);
        assert!(reg.all().contains(&id));

        // A *different* spec under the same name is rejected loudly, not
        // silently discarded.
        let mut conflicting = spec;
        conflicting.fidelity.shift_sigma = 2.0;
        let err = reg.register(conflicting).unwrap_err();
        assert!(
            format!("{err}").contains("different spec"),
            "expected immutability error, got: {err}"
        );
    }

    #[test]
    fn register_rejects_bad_specs() {
        let reg = TaskRegistry::global();
        let base = |name: &str| {
            TaskSpec::new(
                name,
                vec![1, 8],
                GeneratorProfile {
                    kind: StructureKind::Crystal { min_atoms: 4 },
                    relax_steps: 0,
                    relax_step_size: 0.05,
                    perturb_factor: 1.0,
                },
                FidelityProfile {
                    seed_tag: 1,
                    shift_sigma: 0.1,
                    scale_jitter: 0.0,
                    force_scale_jitter: 0.0,
                    energy_noise: 0.0,
                    force_noise: 0.0,
                    shift_offset: 0.0,
                },
            )
        };
        assert!(reg.register(base("")).is_err(), "empty name");
        let mut s = base("BadPalette");
        s.palette = vec![0];
        assert!(reg.register(s).is_err(), "Z=0 palette");
        let mut s = base("HOnly");
        s.palette = vec![1];
        s.generator.kind = StructureKind::Molecule { min_atoms: 4, atoms_cap: 8 };
        assert!(reg.register(s).is_err(), "molecule needs a heavy element");
    }

    #[test]
    fn debug_prints_task_name() {
        assert_eq!(format!("{:?}", DatasetId::Ani1x), "DatasetId(ANI1x)");
    }

    #[test]
    fn large_presets_register_idempotently() {
        let (sc, ab) = register_large_presets().unwrap();
        assert_eq!(register_large_presets().unwrap(), (sc, ab));
        assert_eq!(DatasetId::from_name("supercell"), Some(sc));
        assert_eq!(DatasetId::from_name("amorphousbox"), Some(ab));
        assert!(sc.is_inorganic() && ab.is_inorganic());
        assert!(matches!(
            sc.spec().generator.kind,
            StructureKind::Supercell { reps: 10 }
        ));
        assert!(matches!(
            ab.spec().generator.kind,
            StructureKind::AmorphousBox { natoms: 1200 }
        ));
    }

    #[test]
    fn large_kind_validation_bounds() {
        let reg = TaskRegistry::global();
        let mk = |name: &str, kind: StructureKind| {
            TaskSpec::new(
                name,
                vec![12, 8],
                GeneratorProfile {
                    kind,
                    relax_steps: 0,
                    relax_step_size: 0.05,
                    perturb_factor: 0.2,
                },
                FidelityProfile {
                    seed_tag: 1,
                    shift_sigma: 0.1,
                    scale_jitter: 0.0,
                    force_scale_jitter: 0.0,
                    energy_noise: 0.0,
                    force_noise: 0.0,
                    shift_offset: 0.0,
                },
            )
        };
        assert!(reg.register(mk("ScBad1", StructureKind::Supercell { reps: 1 })).is_err());
        assert!(reg.register(mk("ScBad2", StructureKind::Supercell { reps: 33 })).is_err());
        assert!(reg
            .register(mk("AbBad1", StructureKind::AmorphousBox { natoms: 1 }))
            .is_err());
        assert!(reg
            .register(mk("AbBad2", StructureKind::AmorphousBox { natoms: 40_000 }))
            .is_err());
    }
}
