//! The execution engine: a thin dispatcher over pluggable [`Backend`]s.
//!
//! `Engine` owns the [`Manifest`] (the single source of truth for model
//! dims, parameter leaves and batch fields) and routes the four hot-path
//! entry points — `train_step`, `eval_step`, `forward`, `encoder_forward` —
//! to one of two backends:
//!
//! * **native** ([`crate::runtime::native::NativeBackend`]) — the pure-rust
//!   EGNN engine. Needs no artifacts and no PJRT: when no artifact
//!   directory exists, the manifest is synthesized from `ArchDims` +
//!   `BatchDims` defaults, so `ParamSet` init, checkpointing, the trainer
//!   and serving all run end-to-end on any machine. This is the default.
//! * **pjrt** ([`PjrtBackend`]) — loads the HLO-text artifacts, compiles
//!   them once on the CPU PJRT client, and marshals name-driven literals.
//!   Requires `--features pjrt` plus `make artifacts`; this is the
//!   accelerated option, not a prerequisite.
//!
//! Selection: `Engine::load` honors the `HYDRA_MTP_BACKEND` env var, then
//! auto-detects (PJRT if it loads, else native); `Engine::load_with` takes
//! an explicit [`BackendKind`] from `RunConfig`/CLI `--backend`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::runtime::pjrt as xla;

use crate::data::batch::GraphBatch;
use crate::model::kernels::Precision;
use crate::model::params::ParamSet;
use crate::runtime::backend::{Backend, BackendKind};
use crate::runtime::manifest::{Manifest, ManifestConfig};
use crate::runtime::native::NativeBackend;
use crate::tensor::Tensor;

/// Outputs of one train_step execution.
pub struct StepOut {
    pub loss: f64,
    pub mae_e: f64,
    pub mae_f: f64,
    pub grads: ParamSet,
}

/// Outputs of one eval_step execution.
#[derive(Debug, Clone, Copy)]
pub struct EvalOut {
    pub loss: f64,
    pub mae_e: f64,
    pub mae_f: f64,
}

enum BackendImpl {
    Native(NativeBackend),
    Pjrt(PjrtBackend),
}

/// A requested mixed-f32 precision must never be DROPPED silently: the
/// PJRT backend's numerics are fixed by the compiled artifacts, so when
/// backend resolution lands on PJRT the knob is ignored — loudly.
fn warn_pjrt_ignores_precision(precision: Precision) {
    if precision == Precision::MixedF32 {
        eprintln!(
            "warning: the PJRT backend ignores the requested mixed-f32 precision \
             (artifact numerics are fixed); running — and fingerprinting — as f64"
        );
    }
}

pub struct Engine {
    pub manifest: Manifest,
    backend: BackendImpl,
    /// Compute precision of the native kernels (PJRT engines always report
    /// [`Precision::F64`]: their numerics are fixed by the artifacts).
    precision: Precision,
    exec_count: AtomicU64,
}

impl Engine {
    /// Load an engine for `dir` with auto backend selection (see
    /// [`Engine::load_full`]); never fails on a machine without artifacts —
    /// the native backend is the universal fallback.
    pub fn load(dir: impl AsRef<std::path::Path>) -> anyhow::Result<Engine> {
        Self::load_with(dir, BackendKind::Auto)
    }

    /// Load an engine with an explicit backend choice and the default
    /// precision (f64, unless `HYDRA_MTP_PRECISION` overrides it).
    pub fn load_with(
        dir: impl AsRef<std::path::Path>,
        kind: BackendKind,
    ) -> anyhow::Result<Engine> {
        Self::load_full(dir, kind, Precision::default().resolve())
    }

    /// Load an engine with explicit backend and precision choices. `Auto`
    /// resolves the `HYDRA_MTP_BACKEND` env override first, then prefers
    /// PJRT when the feature + artifacts are available and falls back to
    /// native. `precision` is used exactly as given and only affects the
    /// native backend — callers resolving it from a config should apply
    /// the `HYDRA_MTP_PRECISION` override first via [`Precision::resolve`]
    /// (the `Session` builder does).
    pub fn load_full(
        dir: impl AsRef<std::path::Path>,
        kind: BackendKind,
        precision: Precision,
    ) -> anyhow::Result<Engine> {
        let dir = dir.as_ref();
        let kind = if kind == BackendKind::Auto { BackendKind::from_env() } else { kind };
        match kind {
            BackendKind::Pjrt => {
                warn_pjrt_ignores_precision(precision);
                Self::load_pjrt(dir, None)
            }
            BackendKind::Native => Ok(Self::load_native(dir, precision)),
            BackendKind::Auto => match Self::load_pjrt(dir, None) {
                Ok(e) => {
                    warn_pjrt_ignores_precision(precision);
                    Ok(e)
                }
                Err(err) => {
                    // Fall back to native — but never silently when an
                    // artifact directory is PRESENT: broken artifacts would
                    // otherwise degrade to a (possibly different-dims)
                    // native model with zero indication.
                    if dir.join("manifest.json").exists() {
                        eprintln!(
                            "warning: PJRT backend unavailable for {dir:?} ({err:#}); \
                             falling back to the native backend"
                        );
                    }
                    Ok(Self::load_native(dir, precision))
                }
            },
        }
    }

    /// PJRT engine: load + compile every artifact in `dir`'s manifest.
    pub fn load_pjrt(
        dir: impl AsRef<std::path::Path>,
        names: Option<&[&str]>,
    ) -> anyhow::Result<Engine> {
        let manifest = Manifest::load(dir)?;
        manifest.validate()?;
        anyhow::ensure!(
            !manifest.is_synthesized(),
            "manifest lists no artifacts; the PJRT backend needs compiled HLO (run `make artifacts`)"
        );
        let backend = PjrtBackend::compile(&manifest, names)?;
        Ok(Engine {
            manifest,
            backend: BackendImpl::Pjrt(backend),
            precision: Precision::F64,
            exec_count: AtomicU64::new(0),
        })
    }

    /// PJRT engine compiling only the named artifacts (focused tests).
    pub fn load_only(
        dir: impl AsRef<std::path::Path>,
        names: &[&str],
    ) -> anyhow::Result<Engine> {
        Self::load_pjrt(dir, Some(names))
    }

    /// Native engine for `dir`: adopt the artifact manifest's config when
    /// one is present (so dims match any compiled artifacts), otherwise
    /// synthesize the default configuration. Infallible by design — but an
    /// unreadable manifest that EXISTS is warned about, since the engine
    /// will run different (default) dims than the user compiled.
    fn load_native(dir: &std::path::Path, precision: Precision) -> Engine {
        let config = match Manifest::load(dir) {
            Ok(m) => m.config,
            Err(err) => {
                if dir.join("manifest.json").exists() {
                    eprintln!(
                        "warning: ignoring unreadable manifest in {dir:?} ({err:#}); \
                         the native backend uses the default model dims"
                    );
                }
                ManifestConfig::default_native()
            }
        };
        Self::native_with(config, precision)
    }

    /// Native engine with an explicit model configuration at the default
    /// precision (f64, unless `HYDRA_MTP_PRECISION` overrides it).
    /// Custom-dims experiments build tiny engines this way.
    pub fn native(config: ManifestConfig) -> Engine {
        Self::native_with(config, Precision::default().resolve())
    }

    /// Native engine with explicit model configuration AND compute
    /// precision, ignoring any environment override — the gradcheck
    /// oracle, the precision harness, and the side-by-side hot-path bench
    /// pin their engines this way.
    pub fn native_with(config: ManifestConfig, precision: Precision) -> Engine {
        Engine {
            manifest: Manifest::synthesize(config),
            backend: BackendImpl::Native(NativeBackend::new(precision)),
            precision,
            exec_count: AtomicU64::new(0),
        }
    }

    fn backend(&self) -> &dyn Backend {
        match &self.backend {
            BackendImpl::Native(b) => b,
            BackendImpl::Pjrt(b) => b,
        }
    }

    /// Stable backend identifier ("native" or "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.backend().name()
    }

    /// Compute precision this engine runs at. Recorded (resolved) in every
    /// checkpoint's trajectory fingerprint, so cross-precision resume is
    /// refused like cross-backend resume.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    pub fn is_native(&self) -> bool {
        matches!(self.backend, BackendImpl::Native(_))
    }

    pub fn platform(&self) -> String {
        self.backend().platform()
    }

    /// Number of executions performed (metrics).
    pub fn executions(&self) -> u64 {
        self.exec_count.load(Ordering::Relaxed)
    }

    fn count(&self) {
        self.exec_count.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one execution performed outside the engine's own dispatch —
    /// the serving fast path runs the native eval forward directly against
    /// cached parameter views, but its executions must still show up in
    /// [`Engine::executions`] metrics.
    pub(crate) fn record_execution(&self) {
        self.count();
    }

    /// One forward+backward pass: returns loss, MAEs, and named gradients.
    /// A non-finite loss is an error here; the trainer's skip-batch
    /// supervision uses [`Engine::train_step_unchecked`] and judges the
    /// raw loss itself.
    pub fn train_step(
        &self,
        params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<StepOut> {
        let out = self.train_step_unchecked(params, batch)?;
        anyhow::ensure!(out.loss.is_finite(), "train_step produced non-finite loss");
        Ok(out)
    }

    /// As [`Engine::train_step`] but a non-finite loss is returned, not an
    /// error — callers that can *recover* (the trainer skips the batch
    /// within a bounded budget) inspect `out.loss` themselves.
    pub fn train_step_unchecked(
        &self,
        params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<StepOut> {
        let out = self.backend().train_step(&self.manifest, params, batch)?;
        self.count();
        Ok(out)
    }

    /// As [`Engine::train_step_unchecked`], signaling gradient-block
    /// completion through `obs` while backward runs (native backend) or by
    /// replay after the step (other backends). See
    /// [`crate::runtime::backend::GradObserver`] for the contract; the
    /// overlapped trainer path feeds a `comm::overlap::OverlapSink` here.
    pub fn train_step_observed_unchecked(
        &self,
        params: &ParamSet,
        batch: &GraphBatch,
        obs: &mut dyn crate::runtime::backend::GradObserver,
    ) -> anyhow::Result<StepOut> {
        let out = self
            .backend()
            .train_step_observed(&self.manifest, params, batch, obs)?;
        self.count();
        Ok(out)
    }

    /// Metrics-only evaluation pass.
    pub fn eval_step(
        &self,
        params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<EvalOut> {
        let out = self.backend().eval_step(&self.manifest, params, batch)?;
        self.count();
        Ok(out)
    }

    /// Inference: (energy_per_atom [G], forces [N,3]).
    pub fn forward(
        &self,
        params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<(Tensor, Tensor)> {
        let out = self.backend().forward(&self.manifest, params, batch)?;
        self.count();
        Ok(out)
    }

    /// Encoder-only forward: (h [N,H], v [N,3]). Takes encoder params only
    /// (either `encoder.*` or bare leaf names).
    pub fn encoder_forward(
        &self,
        encoder_params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<(Tensor, Tensor)> {
        let out = self
            .backend()
            .encoder_forward(&self.manifest, encoder_params, batch)?;
        self.count();
        Ok(out)
    }

    // -- PJRT-specific surface (artifact marshalling) ------------------------

    fn pjrt(&self) -> anyhow::Result<&PjrtBackend> {
        match &self.backend {
            BackendImpl::Pjrt(b) => Ok(b),
            BackendImpl::Native(_) => anyhow::bail!(
                "the '{}' backend has no PJRT artifact surface; run_raw/marshal need \
                 `--features pjrt` plus compiled artifacts",
                self.backend_name()
            ),
        }
    }

    /// Execute an artifact on pre-marshalled literals; returns output
    /// tensors in manifest output order. PJRT backend only.
    pub fn run_raw(
        &self,
        name: &str,
        inputs: &[xla::Literal],
    ) -> anyhow::Result<Vec<Tensor>> {
        let out = self.pjrt()?.run_raw(&self.manifest, name, inputs)?;
        self.count();
        Ok(out)
    }

    /// Assemble the input literal list for an artifact from a parameter set
    /// plus a padded batch (name-driven; order from the manifest). PJRT
    /// backend only.
    pub fn marshal(
        &self,
        name: &str,
        params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<Vec<xla::Literal>> {
        self.pjrt()?.marshal(&self.manifest, name, params, batch)
    }
}

// ---------------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------------

/// The AOT-artifact backend: compiled PJRT executables, one per artifact.
/// Marshalling is name-driven: each artifact's manifest entry lists its
/// flattened inputs/outputs; parameters are looked up in the `ParamSet`,
/// everything else is a batch field. One compiled executable serves every
/// MTL head — under multi-task parallelism each rank feeds its own branch
/// parameter values (the head identity is data, not code).
pub struct PjrtBackend {
    client: xla::PjRtClient,
    executables: BTreeMap<String, Mutex<xla::PjRtLoadedExecutable>>,
}

// The PJRT CPU client is internally synchronized; executions are further
// serialized per-executable by the Mutex above. The raw pointers inside the
// xla wrappers are what block the auto-impl.
unsafe impl Send for PjrtBackend {}
unsafe impl Sync for PjrtBackend {}

impl PjrtBackend {
    fn compile(manifest: &Manifest, names: Option<&[&str]>) -> anyhow::Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu()?;
        let mut executables = BTreeMap::new();
        for (name, art) in &manifest.artifacts {
            if let Some(filter) = names {
                if !filter.contains(&name.as_str()) {
                    continue;
                }
            }
            let proto = xla::HloModuleProto::from_text_file(&art.file)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            executables.insert(name.clone(), Mutex::new(exe));
        }
        Ok(PjrtBackend { client, executables })
    }

    fn run_raw(
        &self,
        manifest: &Manifest,
        name: &str,
        inputs: &[xla::Literal],
    ) -> anyhow::Result<Vec<Tensor>> {
        let art = manifest.artifact(name)?;
        anyhow::ensure!(
            inputs.len() == art.inputs.len(),
            "artifact {name}: {} inputs supplied, {} expected",
            inputs.len(),
            art.inputs.len()
        );
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not compiled"))?
            .lock()
            .unwrap();
        let result = exe.execute::<xla::Literal>(inputs)?;
        // Artifacts are lowered with return_tuple=True: one tuple output.
        let root = result[0][0].to_literal_sync()?;
        let parts = root.to_tuple()?;
        anyhow::ensure!(
            parts.len() == art.outputs.len(),
            "artifact {name}: {} outputs, {} expected",
            parts.len(),
            art.outputs.len()
        );
        parts.iter().map(Tensor::from_literal).collect()
    }

    /// Batch fields are marshalled in place via `GraphBatch::field_literal`
    /// — no per-step buffer clones into intermediate tensors.
    fn marshal(
        &self,
        manifest: &Manifest,
        name: &str,
        params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let art = manifest.artifact(name)?;
        let mut out = Vec::with_capacity(art.inputs.len());
        for meta in &art.inputs {
            let lit = if let Some(t) = params.get(&meta.name) {
                debug_assert_eq!(t.shape, meta.shape, "{}", meta.name);
                t.to_literal()?
            } else {
                batch.field_literal(&meta.name)?
            };
            out.push(lit);
        }
        Ok(out)
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn train_step(
        &self,
        manifest: &Manifest,
        params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<StepOut> {
        let inputs = self.marshal(manifest, "train_step", params, batch)?;
        let outputs = self.run_raw(manifest, "train_step", &inputs)?;
        let art = manifest.artifact("train_step")?;

        let mut loss = f64::NAN;
        let mut mae_e = f64::NAN;
        let mut mae_f = f64::NAN;
        let mut grads = ParamSet::zeros_like(&manifest.params);
        for (meta, tensor) in art.outputs.iter().zip(outputs) {
            match meta.name.as_str() {
                "loss" => loss = tensor.item(),
                "mae_e" => mae_e = tensor.item(),
                "mae_f" => mae_f = tensor.item(),
                name => {
                    let pname = name
                        .strip_prefix("grads.")
                        .ok_or_else(|| anyhow::anyhow!("unexpected output {name}"))?;
                    let slot = grads
                        .get_mut(pname)
                        .ok_or_else(|| anyhow::anyhow!("gradient for unknown param {pname}"))?;
                    *slot = tensor;
                }
            }
        }
        Ok(StepOut { loss, mae_e, mae_f, grads })
    }

    fn eval_step(
        &self,
        manifest: &Manifest,
        params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<EvalOut> {
        let inputs = self.marshal(manifest, "eval_step", params, batch)?;
        let outputs = self.run_raw(manifest, "eval_step", &inputs)?;
        let art = manifest.artifact("eval_step")?;
        let mut out = EvalOut { loss: f64::NAN, mae_e: f64::NAN, mae_f: f64::NAN };
        for (meta, tensor) in art.outputs.iter().zip(outputs) {
            match meta.name.as_str() {
                "loss" => out.loss = tensor.item(),
                "mae_e" => out.mae_e = tensor.item(),
                "mae_f" => out.mae_f = tensor.item(),
                other => anyhow::bail!("unexpected eval output {other}"),
            }
        }
        Ok(out)
    }

    fn forward(
        &self,
        manifest: &Manifest,
        params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<(Tensor, Tensor)> {
        let inputs = self.marshal(manifest, "fwd", params, batch)?;
        let outputs = self.run_raw(manifest, "fwd", &inputs)?;
        let art = manifest.artifact("fwd")?;
        let mut energy = None;
        let mut forces = None;
        for (meta, tensor) in art.outputs.iter().zip(outputs) {
            match meta.name.as_str() {
                "energy" => energy = Some(tensor),
                "forces" => forces = Some(tensor),
                other => anyhow::bail!("unexpected fwd output {other}"),
            }
        }
        Ok((
            energy.ok_or_else(|| anyhow::anyhow!("fwd missing energy"))?,
            forces.ok_or_else(|| anyhow::anyhow!("fwd missing forces"))?,
        ))
    }

    fn encoder_forward(
        &self,
        manifest: &Manifest,
        encoder_params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<(Tensor, Tensor)> {
        let art = manifest.artifact("encoder_fwd")?;
        let mut inputs = Vec::with_capacity(art.inputs.len());
        for meta in &art.inputs {
            // encoder_fwd inputs use encoder-local names (no "encoder."
            // prefix); fall back through both spellings, else batch.
            let lit = if let Some(t) = encoder_params.get(&meta.name) {
                t.to_literal()?
            } else if let Some(t) =
                encoder_params.get(&format!("encoder.{}", meta.name))
            {
                t.to_literal()?
            } else {
                batch.field_literal(&meta.name)?
            };
            inputs.push(lit);
        }
        let outputs = self.run_raw(manifest, "encoder_fwd", &inputs)?;
        let mut h = None;
        let mut v = None;
        for (meta, tensor) in art.outputs.iter().zip(outputs) {
            match meta.name.as_str() {
                "h" => h = Some(tensor),
                "v" => v = Some(tensor),
                other => anyhow::bail!("unexpected encoder output {other}"),
            }
        }
        Ok((
            h.ok_or_else(|| anyhow::anyhow!("missing h"))?,
            v.ok_or_else(|| anyhow::anyhow!("missing v"))?,
        ))
    }
}
