//! PJRT execution engine: loads the HLO-text artifacts, compiles them once
//! on the CPU PJRT client, and exposes typed entry points for the training
//! hot path. This is the only place the `xla` crate is touched.
//!
//! Marshalling is name-driven: each artifact's manifest entry lists its
//! flattened inputs/outputs; parameters are looked up in the `ParamSet`,
//! everything else is a batch field. One compiled executable serves every
//! MTL head — under multi-task parallelism each rank feeds its own branch
//! parameter values (the head identity is data, not code).

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::runtime::pjrt as xla;

use crate::data::batch::GraphBatch;
use crate::model::params::ParamSet;
use crate::runtime::manifest::{ArtifactMeta, Manifest};
use crate::tensor::Tensor;

pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    executables: BTreeMap<String, Mutex<xla::PjRtLoadedExecutable>>,
    exec_count: std::sync::atomic::AtomicU64,
}

// The PJRT CPU client is internally synchronized; executions are further
// serialized per-executable by the Mutex above. The raw pointers inside the
// xla wrappers are what block the auto-impl.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

/// Outputs of one train_step execution.
pub struct StepOut {
    pub loss: f64,
    pub mae_e: f64,
    pub mae_f: f64,
    pub grads: ParamSet,
}

/// Outputs of one eval_step execution.
#[derive(Debug, Clone, Copy)]
pub struct EvalOut {
    pub loss: f64,
    pub mae_e: f64,
    pub mae_f: f64,
}

impl Engine {
    /// Load + compile every artifact in the manifest.
    pub fn load(dir: impl AsRef<std::path::Path>) -> anyhow::Result<Engine> {
        Self::load_subset(dir, None)
    }

    /// Load + compile only the named artifacts (faster for focused tests).
    pub fn load_only(
        dir: impl AsRef<std::path::Path>,
        names: &[&str],
    ) -> anyhow::Result<Engine> {
        Self::load_subset(dir, Some(names))
    }

    fn load_subset(
        dir: impl AsRef<std::path::Path>,
        names: Option<&[&str]>,
    ) -> anyhow::Result<Engine> {
        let manifest = Manifest::load(dir)?;
        manifest.validate()?;
        let client = xla::PjRtClient::cpu()?;
        let mut executables = BTreeMap::new();
        for (name, art) in &manifest.artifacts {
            if let Some(filter) = names {
                if !filter.contains(&name.as_str()) {
                    continue;
                }
            }
            let proto = xla::HloModuleProto::from_text_file(&art.file)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            executables.insert(name.clone(), Mutex::new(exe));
        }
        Ok(Engine {
            client,
            manifest,
            executables,
            exec_count: std::sync::atomic::AtomicU64::new(0),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of executions performed (metrics).
    pub fn executions(&self) -> u64 {
        self.exec_count.load(std::sync::atomic::Ordering::Relaxed)
    }

    fn artifact(&self, name: &str) -> anyhow::Result<&ArtifactMeta> {
        self.manifest.artifact(name)
    }

    /// Execute an artifact on pre-marshalled literals; returns output
    /// tensors in manifest output order.
    pub fn run_raw(
        &self,
        name: &str,
        inputs: &[xla::Literal],
    ) -> anyhow::Result<Vec<Tensor>> {
        let art = self.artifact(name)?;
        anyhow::ensure!(
            inputs.len() == art.inputs.len(),
            "artifact {name}: {} inputs supplied, {} expected",
            inputs.len(),
            art.inputs.len()
        );
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not compiled"))?
            .lock()
            .unwrap();
        let result = exe.execute::<xla::Literal>(inputs)?;
        self.exec_count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // Artifacts are lowered with return_tuple=True: one tuple output.
        let root = result[0][0].to_literal_sync()?;
        let parts = root.to_tuple()?;
        anyhow::ensure!(
            parts.len() == art.outputs.len(),
            "artifact {name}: {} outputs, {} expected",
            parts.len(),
            art.outputs.len()
        );
        parts.iter().map(Tensor::from_literal).collect()
    }

    /// Assemble the input literal list for an artifact from a parameter set
    /// plus a padded batch (name-driven; order from the manifest). Batch
    /// fields are marshalled in place via `GraphBatch::field_literal` — no
    /// per-step buffer clones into intermediate tensors.
    pub fn marshal(
        &self,
        name: &str,
        params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let art = self.artifact(name)?;
        let mut out = Vec::with_capacity(art.inputs.len());
        for meta in &art.inputs {
            let lit = if let Some(t) = params.get(&meta.name) {
                debug_assert_eq!(t.shape, meta.shape, "{}", meta.name);
                t.to_literal()?
            } else {
                batch.field_literal(&meta.name)?
            };
            out.push(lit);
        }
        Ok(out)
    }

    /// One forward+backward pass: returns loss, MAEs, and named gradients.
    pub fn train_step(
        &self,
        params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<StepOut> {
        let inputs = self.marshal("train_step", params, batch)?;
        let outputs = self.run_raw("train_step", &inputs)?;
        let art = self.artifact("train_step")?;

        let mut loss = f64::NAN;
        let mut mae_e = f64::NAN;
        let mut mae_f = f64::NAN;
        let mut grads = ParamSet::zeros_like(&self.manifest.params);
        for (meta, tensor) in art.outputs.iter().zip(outputs) {
            match meta.name.as_str() {
                "loss" => loss = tensor.item(),
                "mae_e" => mae_e = tensor.item(),
                "mae_f" => mae_f = tensor.item(),
                name => {
                    let pname = name
                        .strip_prefix("grads.")
                        .ok_or_else(|| anyhow::anyhow!("unexpected output {name}"))?;
                    let slot = grads
                        .get_mut(pname)
                        .ok_or_else(|| anyhow::anyhow!("gradient for unknown param {pname}"))?;
                    *slot = tensor;
                }
            }
        }
        anyhow::ensure!(loss.is_finite(), "train_step produced non-finite loss");
        Ok(StepOut { loss, mae_e, mae_f, grads })
    }

    /// Metrics-only evaluation pass.
    pub fn eval_step(
        &self,
        params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<EvalOut> {
        let inputs = self.marshal("eval_step", params, batch)?;
        let outputs = self.run_raw("eval_step", &inputs)?;
        let art = self.artifact("eval_step")?;
        let mut out = EvalOut { loss: f64::NAN, mae_e: f64::NAN, mae_f: f64::NAN };
        for (meta, tensor) in art.outputs.iter().zip(outputs) {
            match meta.name.as_str() {
                "loss" => out.loss = tensor.item(),
                "mae_e" => out.mae_e = tensor.item(),
                "mae_f" => out.mae_f = tensor.item(),
                other => anyhow::bail!("unexpected eval output {other}"),
            }
        }
        Ok(out)
    }

    /// Inference: (energy_per_atom [G], forces [N,3]).
    pub fn forward(
        &self,
        params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<(Tensor, Tensor)> {
        let inputs = self.marshal("fwd", params, batch)?;
        let outputs = self.run_raw("fwd", &inputs)?;
        let art = self.artifact("fwd")?;
        let mut energy = None;
        let mut forces = None;
        for (meta, tensor) in art.outputs.iter().zip(outputs) {
            match meta.name.as_str() {
                "energy" => energy = Some(tensor),
                "forces" => forces = Some(tensor),
                other => anyhow::bail!("unexpected fwd output {other}"),
            }
        }
        Ok((
            energy.ok_or_else(|| anyhow::anyhow!("fwd missing energy"))?,
            forces.ok_or_else(|| anyhow::anyhow!("fwd missing forces"))?,
        ))
    }

    /// Encoder-only forward: (h [N,H], v [N,3]). Takes encoder params only.
    pub fn encoder_forward(
        &self,
        encoder_params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<(Tensor, Tensor)> {
        let art = self.artifact("encoder_fwd")?;
        let mut inputs = Vec::with_capacity(art.inputs.len());
        for meta in &art.inputs {
            // encoder_fwd inputs use encoder-local names (no "encoder."
            // prefix); fall back through both spellings, else batch.
            let lit = if let Some(t) = encoder_params.get(&meta.name) {
                t.to_literal()?
            } else if let Some(t) =
                encoder_params.get(&format!("encoder.{}", meta.name))
            {
                t.to_literal()?
            } else {
                batch.field_literal(&meta.name)?
            };
            inputs.push(lit);
        }
        let outputs = self.run_raw("encoder_fwd", &inputs)?;
        let art = self.artifact("encoder_fwd")?;
        let mut h = None;
        let mut v = None;
        for (meta, tensor) in art.outputs.iter().zip(outputs) {
            match meta.name.as_str() {
                "h" => h = Some(tensor),
                "v" => v = Some(tensor),
                other => anyhow::bail!("unexpected encoder output {other}"),
            }
        }
        Ok((
            h.ok_or_else(|| anyhow::anyhow!("missing h"))?,
            v.ok_or_else(|| anyhow::anyhow!("missing v"))?,
        ))
    }
}
