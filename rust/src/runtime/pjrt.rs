//! PJRT backend selection: the real `xla` crate when built with
//! `--features pjrt`, otherwise an offline stub with the same API surface.
//!
//! The stub keeps the whole crate (data substrate, task registry, trainer
//! plumbing, comm, scalesim, CLI) compiling and testable on machines where
//! the XLA/PJRT native libraries are unavailable: `Literal` marshalling is
//! fully functional, while client construction fails with a clear message —
//! which `Engine::load` surfaces and artifact-dependent tests/examples
//! treat as "skip gracefully".

// With `--features pjrt`, re-export the real crate (the `xla` dependency
// must be uncommented in Cargo.toml — see the note there).
#[cfg(feature = "pjrt")]
pub use xla::*;

#[cfg(not(feature = "pjrt"))]
pub use stub::*;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::fmt;

    const UNAVAILABLE: &str = "PJRT runtime unavailable: hydra_mtp was built without the \
         `pjrt` feature (the `xla` crate). Uncomment the `xla` dependency in \
         Cargo.toml, rebuild with `--features pjrt`, and run `make artifacts` \
         to execute AOT artifacts";

    /// Error type mirroring `xla::Error` closely enough for `?` into anyhow.
    #[derive(Debug, Clone)]
    pub struct XlaError(pub String);

    impl fmt::Display for XlaError {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "{}", self.0)
        }
    }

    impl std::error::Error for XlaError {}

    pub type Result<T> = std::result::Result<T, XlaError>;

    fn unavailable<T>() -> Result<T> {
        Err(XlaError(UNAVAILABLE.to_string()))
    }

    /// Element dtypes (subset of the real crate's enum; the extra variants
    /// keep downstream wildcard match arms meaningful).
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum ElementType {
        Pred,
        S32,
        S64,
        F32,
        F64,
    }

    /// Host literal: dims + typed buffer. Fully functional in the stub so
    /// marshalling code paths stay exercised by unit tests.
    #[derive(Debug, Clone)]
    pub struct Literal {
        dims: Vec<i64>,
        data: LitData,
    }

    #[derive(Debug, Clone)]
    enum LitData {
        F32(Vec<f32>),
        I32(Vec<i32>),
    }

    /// Types storable in a [`Literal`].
    pub trait NativeType: Copy {
        fn wrap(v: Vec<Self>) -> LitDataOpaque;
        fn unwrap(l: &Literal) -> Result<Vec<Self>>;
    }

    /// Opaque constructor payload (keeps `LitData` private).
    pub struct LitDataOpaque(LitData);

    impl NativeType for f32 {
        fn wrap(v: Vec<f32>) -> LitDataOpaque {
            LitDataOpaque(LitData::F32(v))
        }
        fn unwrap(l: &Literal) -> Result<Vec<f32>> {
            match &l.data {
                LitData::F32(v) => Ok(v.clone()),
                LitData::I32(_) => Err(XlaError("literal is i32, expected f32".into())),
            }
        }
    }

    impl NativeType for i32 {
        fn wrap(v: Vec<i32>) -> LitDataOpaque {
            LitDataOpaque(LitData::I32(v))
        }
        fn unwrap(l: &Literal) -> Result<Vec<i32>> {
            match &l.data {
                LitData::I32(v) => Ok(v.clone()),
                LitData::F32(_) => Err(XlaError("literal is f32, expected i32".into())),
            }
        }
    }

    /// Shape descriptor of an array literal.
    pub struct ArrayShape {
        dims: Vec<i64>,
        ty: ElementType,
    }

    impl ArrayShape {
        pub fn dims(&self) -> &[i64] {
            &self.dims
        }
        pub fn ty(&self) -> ElementType {
            self.ty
        }
    }

    impl Literal {
        pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
            let LitDataOpaque(data) = T::wrap(v.to_vec());
            Literal { dims: vec![v.len() as i64], data }
        }

        pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
            let numel: i64 = dims.iter().product();
            let len = match &self.data {
                LitData::F32(v) => v.len() as i64,
                LitData::I32(v) => v.len() as i64,
            };
            if numel != len {
                return Err(XlaError(format!("cannot reshape {len} elements to {dims:?}")));
            }
            Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
        }

        pub fn array_shape(&self) -> Result<ArrayShape> {
            let ty = match &self.data {
                LitData::F32(_) => ElementType::F32,
                LitData::I32(_) => ElementType::S32,
            };
            Ok(ArrayShape { dims: self.dims.clone(), ty })
        }

        pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
            T::unwrap(self)
        }

        pub fn to_tuple(&self) -> Result<Vec<Literal>> {
            unavailable()
        }
    }

    /// Stub of the PJRT CPU client: construction fails with a clear message.
    pub struct PjRtClient;

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient> {
            unavailable()
        }

        pub fn platform_name(&self) -> String {
            "stub".to_string()
        }

        pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
            unavailable()
        }
    }

    pub struct HloModuleProto;

    impl HloModuleProto {
        pub fn from_text_file(_path: impl AsRef<std::path::Path>) -> Result<HloModuleProto> {
            unavailable()
        }
    }

    pub struct XlaComputation;

    impl XlaComputation {
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
            unavailable()
        }
    }

    pub struct PjRtBuffer;

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal> {
            unavailable()
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn literal_roundtrip_and_reshape() {
            let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
            let shape = l.array_shape().unwrap();
            assert_eq!(shape.dims(), &[2, 2]);
            assert_eq!(shape.ty(), ElementType::F32);
            assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
            assert!(l.to_vec::<i32>().is_err());
            assert!(Literal::vec1(&[1i32]).reshape(&[7]).is_err());
        }

        #[test]
        fn client_reports_unavailable() {
            let err = PjRtClient::cpu().err().unwrap();
            assert!(err.to_string().contains("pjrt"), "{err}");
        }
    }
}
