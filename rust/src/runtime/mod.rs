//! AOT runtime: the manifest contract and the PJRT execution engine.
//! (`PjRtClient::cpu()` -> `HloModuleProto::from_text_file` -> compile ->
//! execute, per /opt/xla-example/load_hlo.)

pub mod engine;
pub mod manifest;
pub mod pjrt;

pub use engine::{Engine, EvalOut, StepOut};
pub use manifest::{ArtifactMeta, Manifest, ManifestConfig};
