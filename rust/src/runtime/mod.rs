//! Execution runtime: the manifest contract, the pluggable [`Backend`]
//! trait, the native pure-rust EGNN backend (default, zero artifacts), and
//! the PJRT AOT engine (`--features pjrt` + `make artifacts`, per
//! /opt/xla-example/load_hlo).

pub mod backend;
pub mod engine;
pub mod manifest;
pub mod native;
pub mod pjrt;

pub use backend::{Backend, BackendKind, Precision};
pub use engine::{Engine, EvalOut, PjrtBackend, StepOut};
pub use manifest::{ArtifactMeta, Manifest, ManifestConfig};
pub use native::NativeBackend;
