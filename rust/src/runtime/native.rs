//! The native pure-rust execution backend: `model::egnn` behind the
//! [`Backend`] contract.
//!
//! No artifacts, no PJRT, no python — the model dimensions come from the
//! manifest config (loaded from `artifacts/manifest.json` when present,
//! synthesized from defaults otherwise), parameters are looked up by their
//! manifest leaf names, and batches are consumed straight from the
//! `GraphBatch` flat buffers with zero marshalling. Gradients come back as
//! a `ParamSet` with the exact leaf structure the trainer's collectives and
//! the AdamW optimizer expect, so the whole coordinator stack runs
//! unchanged on top.
//!
//! Serving (`crate::serve`, `Session::predictor`) bypasses the per-call
//! `EncoderParams::from_set` / `BranchParams::from_set` marshalling done
//! here: `serve::prepared::PreparedModel` builds the typed params (plus
//! their cached f32 views) once at model load and reuses a recycled
//! `model::egnn::EvalWorkspace` per worker, reproducing this backend's
//! `forward` bit-for-bit without its per-call allocations.

use crate::data::batch::GraphBatch;
use crate::model::egnn::{
    backward_observed, branch_forward, encoder_forward, loss_metrics, Batch64, BranchParams,
    EgnnDims, EncoderParams, EncoderState, GradBlock, LayerParams,
};
use crate::model::kernels::Precision;
use crate::model::params::ParamSet;
use crate::runtime::backend::{Backend, GradObserver, NoopGradObserver};
use crate::runtime::engine::{EvalOut, StepOut};
use crate::runtime::manifest::Manifest;
use crate::tensor::Tensor;

/// Stateless native backend (the only state is the immutable compute
/// [`Precision`]; everything else lives in the manifest + arguments, so
/// concurrent rank threads share it without synchronization).
#[derive(Debug, Default)]
pub struct NativeBackend {
    precision: Precision,
}

impl NativeBackend {
    /// Backend with an explicit compute precision ([`Precision::F64`] is
    /// the oracle default; [`Precision::MixedF32`] routes the matmul and
    /// silu/gate hot spots through the blocked f32 microkernels of
    /// `model::kernels`).
    pub fn new(precision: Precision) -> NativeBackend {
        NativeBackend { precision }
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    fn run_forward(
        &self,
        manifest: &Manifest,
        params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<(EgnnDims, Batch64, EncoderParams, BranchParams, EncoderState)> {
        let dims = EgnnDims::from_config_with(&manifest.config, self.precision);
        let b = Batch64::new(&dims, batch)?;
        let enc = EncoderParams::from_set(&dims, params)?;
        let br = BranchParams::from_set(&dims, params)?;
        let es = encoder_forward(&dims, &enc, &b);
        Ok((dims, b, enc, br, es))
    }
}

/// Downcast an f64 buffer into an f32 tensor of `shape`.
fn tensor_f32(shape: &[usize], data: &[f64]) -> Tensor {
    Tensor::from_f32(shape, data.iter().map(|&x| x as f32).collect())
}

/// Copy an f64 gradient buffer into the named leaf of `grads`.
fn write_leaf(grads: &mut ParamSet, name: &str, data: &[f64]) -> anyhow::Result<()> {
    let t = grads
        .get_mut(name)
        .ok_or_else(|| anyhow::anyhow!("gradient for unknown leaf '{name}'"))?;
    let dst = t.as_f32_mut();
    anyhow::ensure!(
        dst.len() == data.len(),
        "gradient leaf '{name}': {} values, expected {}",
        data.len(),
        dst.len()
    );
    for (o, &v) in dst.iter_mut().zip(data) {
        *o = v as f32;
    }
    Ok(())
}

fn write_scalar(grads: &mut ParamSet, name: &str, v: f64) -> anyhow::Result<()> {
    write_leaf(grads, name, &[v])
}

/// Write every `branch.*` gradient leaf (the backward's first-completed
/// block).
fn write_branch_leaves(grads: &mut ParamSet, gb: &BranchParams) -> anyhow::Result<()> {
    write_leaf(grads, "branch.trunk.w1", &gb.tw1)?;
    write_leaf(grads, "branch.trunk.b1", &gb.tb1)?;
    write_leaf(grads, "branch.trunk.w2", &gb.tw2)?;
    write_leaf(grads, "branch.trunk.b2", &gb.tb2)?;
    write_leaf(grads, "branch.trunk.w3", &gb.tw3)?;
    write_leaf(grads, "branch.trunk.b3", &gb.tb3)?;
    write_leaf(grads, "branch.energy.w", &gb.ew)?;
    write_scalar(grads, "branch.energy.b", gb.eb)?;
    write_leaf(grads, "branch.force.w", &gb.fw)?;
    write_scalar(grads, "branch.force.b", gb.fb)
}

/// Write one layer's `encoder.layers.{li}.*` gradient leaves.
fn write_layer_leaves(grads: &mut ParamSet, li: usize, gl: &LayerParams) -> anyhow::Result<()> {
    let name = |part: &str| format!("encoder.layers.{li}.{part}");
    write_leaf(grads, &name("edge.w1"), &gl.ew1)?;
    write_leaf(grads, &name("edge.b1"), &gl.eb1)?;
    write_leaf(grads, &name("edge.w2"), &gl.ew2)?;
    write_leaf(grads, &name("edge.b2"), &gl.eb2)?;
    write_leaf(grads, &name("edge.wg"), &gl.wg)?;
    write_scalar(grads, &name("edge.bg"), gl.bg)?;
    write_leaf(grads, &name("node.w1"), &gl.nw1)?;
    write_leaf(grads, &name("node.b1"), &gl.nb1)?;
    write_leaf(grads, &name("node.w2"), &gl.nw2)?;
    write_leaf(grads, &name("node.b2"), &gl.nb2)
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn platform(&self) -> String {
        "native".to_string()
    }

    fn train_step(
        &self,
        manifest: &Manifest,
        params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<StepOut> {
        // One write path for both the plain and the observed step keeps
        // them bit-identical by construction.
        self.train_step_observed(manifest, params, batch, &mut NoopGradObserver)
    }

    fn train_step_observed(
        &self,
        manifest: &Manifest,
        params: &ParamSet,
        batch: &GraphBatch,
        obs: &mut dyn GradObserver,
    ) -> anyhow::Result<StepOut> {
        let (dims, b, enc, br, es) = self.run_forward(manifest, params, batch)?;
        let bs = branch_forward(&dims, &br, &es, &b);
        let metrics = loss_metrics(&dims, &b, &bs);
        obs.loss_ready(metrics.loss);
        let mut grads = ParamSet::zeros_like(&manifest.params);
        backward_observed(&dims, &enc, &br, &es, &bs, &b, &mut |block, ge, gb| {
            match block {
                GradBlock::Branch => write_branch_leaves(&mut grads, gb)?,
                GradBlock::Layer(li) => write_layer_leaves(&mut grads, li, &ge.layers[li])?,
                GradBlock::Embed => write_leaf(&mut grads, "encoder.embed", &ge.embed)?,
            }
            obs.block_ready(block, &grads)
        })?;
        Ok(StepOut { loss: metrics.loss, mae_e: metrics.mae_e, mae_f: metrics.mae_f, grads })
    }

    fn eval_step(
        &self,
        manifest: &Manifest,
        params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<EvalOut> {
        let (dims, b, _enc, br, es) = self.run_forward(manifest, params, batch)?;
        let bs = branch_forward(&dims, &br, &es, &b);
        let metrics = loss_metrics(&dims, &b, &bs);
        Ok(EvalOut { loss: metrics.loss, mae_e: metrics.mae_e, mae_f: metrics.mae_f })
    }

    fn forward(
        &self,
        manifest: &Manifest,
        params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<(Tensor, Tensor)> {
        let (dims, b, _enc, br, es) = self.run_forward(manifest, params, batch)?;
        let bs = branch_forward(&dims, &br, &es, &b);
        Ok((
            tensor_f32(&[dims.g], &bs.e_pa),
            tensor_f32(&[dims.n, 3], &bs.forces),
        ))
    }

    fn encoder_forward(
        &self,
        manifest: &Manifest,
        encoder_params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<(Tensor, Tensor)> {
        let dims = EgnnDims::from_config_with(&manifest.config, self.precision);
        let b = Batch64::new(&dims, batch)?;
        let enc = EncoderParams::from_set(&dims, encoder_params)?;
        let es = encoder_forward(&dims, &enc, &b);
        Ok((
            tensor_f32(&[dims.n, dims.h], &es.h),
            tensor_f32(&[dims.n, 3], &es.v),
        ))
    }
}
