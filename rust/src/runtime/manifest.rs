//! Typed view of the model manifest: the single contract every execution
//! backend shares. Loaded from `artifacts/manifest.json` (the AOT compile
//! path) it records every artifact's flattened input/output order with
//! shapes and dtypes, the model config it was lowered with, and initializer
//! hints for the parameter leaves. [`Manifest::synthesize`] builds the same
//! structure from a [`ManifestConfig`] alone — identical leaf names, order
//! (pytree flatten order: dict keys sorted, `branch.*` then `encoder.*`),
//! shapes and initializer hints, but an empty artifact table — so the
//! native backend, `ParamSet` init, checkpointing and the trainer work with
//! zero artifacts on disk.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::data::batch::BatchDims;
use crate::model::params::{Init, LeafMeta};
use crate::tensor::DType;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<LeafMeta>,
    pub outputs: Vec<LeafMeta>,
    pub sha256: String,
}

/// Model config echoed by the manifest (subset the rust side needs).
#[derive(Debug, Clone, Copy)]
pub struct ManifestConfig {
    pub max_nodes: usize,
    pub max_edges: usize,
    pub max_graphs: usize,
    pub num_species: usize,
    pub hidden: usize,
    pub num_layers: usize,
    pub num_rbf: usize,
    pub head_hidden: usize,
    pub cutoff: f64,
    pub energy_weight: f64,
    pub force_weight: f64,
}

impl ManifestConfig {
    pub fn batch_dims(&self) -> BatchDims {
        BatchDims {
            max_nodes: self.max_nodes,
            max_edges: self.max_edges,
            max_graphs: self.max_graphs,
        }
    }

    pub fn arch_dims(&self) -> crate::model::arch::ArchDims {
        crate::model::arch::ArchDims {
            num_species: self.num_species,
            hidden: self.hidden,
            num_layers: self.num_layers,
            num_rbf: self.num_rbf,
            head_hidden: self.head_hidden,
        }
    }

    /// The dimensions the native backend runs with when no artifact
    /// manifest exists on disk (mirrors python `ModelConfig` defaults, so a
    /// later `make artifacts` produces a byte-compatible parameter layout).
    pub fn default_native() -> ManifestConfig {
        ManifestConfig {
            max_nodes: 256,
            max_edges: 2048,
            max_graphs: 16,
            num_species: 96,
            hidden: 64,
            num_layers: 4,
            num_rbf: 16,
            head_hidden: 64,
            cutoff: 6.0,
            energy_weight: 10.0,
            force_weight: 1.0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ManifestConfig,
    /// Full parameter leaf list (branch.* then encoder.*, manifest order).
    pub params: Arc<Vec<LeafMeta>>,
    pub encoder_params: Arc<Vec<LeafMeta>>,
    pub branch_params: Arc<Vec<LeafMeta>>,
    pub batch_fields: Arc<Vec<LeafMeta>>,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

fn leaf_list(j: &Json, key: &str) -> anyhow::Result<Vec<LeafMeta>> {
    j.get(key)
        .as_array()
        .ok_or_else(|| anyhow::anyhow!("manifest missing '{key}'"))?
        .iter()
        .map(LeafMeta::from_json)
        .collect()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {path:?} (run `make artifacts`): {e}"))?;
        let j = Json::parse(&text)?;

        let c = j.get("config");
        let need_i = |key: &str| -> anyhow::Result<usize> {
            c.get(key)
                .as_i64()
                .map(|v| v as usize)
                .ok_or_else(|| anyhow::anyhow!("manifest config missing '{key}'"))
        };
        let need_f = |key: &str| -> anyhow::Result<f64> {
            c.get(key)
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("manifest config missing '{key}'"))
        };
        let config = ManifestConfig {
            max_nodes: need_i("max_nodes")?,
            max_edges: need_i("max_edges")?,
            max_graphs: need_i("max_graphs")?,
            num_species: need_i("num_species")?,
            hidden: need_i("hidden")?,
            num_layers: need_i("num_layers")?,
            num_rbf: need_i("num_rbf")?,
            head_hidden: need_i("head_hidden")?,
            cutoff: need_f("cutoff")?,
            energy_weight: need_f("energy_weight")?,
            force_weight: need_f("force_weight")?,
        };

        let mut artifacts = BTreeMap::new();
        let arts = j
            .get("artifacts")
            .as_object()
            .ok_or_else(|| anyhow::anyhow!("manifest missing artifacts"))?;
        for (name, entry) in arts {
            let file = entry
                .get("file")
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("artifact {name} missing file"))?;
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    file: dir.join(file),
                    inputs: leaf_list(entry, "inputs")?,
                    outputs: leaf_list(entry, "outputs")?,
                    sha256: entry.get("sha256").as_str().unwrap_or("").to_string(),
                },
            );
        }

        Ok(Manifest {
            dir,
            config,
            params: Arc::new(leaf_list(&j, "params")?),
            encoder_params: Arc::new(leaf_list(&j, "encoder_params")?),
            branch_params: Arc::new(leaf_list(&j, "branch_params")?),
            batch_fields: Arc::new(leaf_list(&j, "batch")?),
            artifacts,
        })
    }

    /// Build a manifest from a config alone — the zero-artifact path. Leaf
    /// names, flatten order, shapes and initializer hints match exactly what
    /// `python -m compile.aot` records for the same config; the artifact
    /// table is empty, which is how backends and `validate` recognize a
    /// synthesized manifest.
    pub fn synthesize(config: ManifestConfig) -> Manifest {
        let (s, h, r, d) = (config.num_species, config.hidden, config.num_rbf, config.head_hidden);
        let w = |name: String, shape: Vec<usize>| LeafMeta {
            init: Some(Init::Lecun { fan_in: shape[0] }),
            name,
            shape,
            dtype: DType::F32,
        };
        let b = |name: String, shape: Vec<usize>| LeafMeta {
            name,
            shape,
            dtype: DType::F32,
            init: Some(Init::Zeros),
        };

        // Branch leaves, dict-key sorted: energy < force < trunk.
        let branch = vec![
            b("branch.energy.b".into(), vec![1]),
            w("branch.energy.w".into(), vec![d, 1]),
            b("branch.force.b".into(), vec![1]),
            w("branch.force.w".into(), vec![d, 1]),
            b("branch.trunk.b1".into(), vec![d]),
            b("branch.trunk.b2".into(), vec![d]),
            b("branch.trunk.b3".into(), vec![d]),
            w("branch.trunk.w1".into(), vec![h, d]),
            w("branch.trunk.w2".into(), vec![d, d]),
            w("branch.trunk.w3".into(), vec![d, d]),
        ];

        // Encoder leaves: embed < layers; per layer edge < node, keys sorted.
        let mut encoder = vec![LeafMeta {
            name: "encoder.embed".into(),
            shape: vec![s, h],
            dtype: DType::F32,
            init: Some(Init::Normal { scale: 0.5 }),
        }];
        for li in 0..config.num_layers {
            let name = |part: &str| format!("encoder.layers.{li}.{part}");
            encoder.push(b(name("edge.b1"), vec![h]));
            encoder.push(b(name("edge.b2"), vec![h]));
            encoder.push(b(name("edge.bg"), vec![1]));
            encoder.push(w(name("edge.w1"), vec![2 * h + r, h]));
            encoder.push(w(name("edge.w2"), vec![h, h]));
            encoder.push(w(name("edge.wg"), vec![h, 1]));
            encoder.push(b(name("node.b1"), vec![h]));
            encoder.push(b(name("node.b2"), vec![h]));
            encoder.push(w(name("node.w1"), vec![2 * h, h]));
            encoder.push(w(name("node.w2"), vec![h, h]));
        }

        let params: Vec<LeafMeta> =
            branch.iter().cloned().chain(encoder.iter().cloned()).collect();

        let field = |name: &str, shape: Vec<usize>, dtype: DType| LeafMeta {
            name: name.into(),
            shape,
            dtype,
            init: None,
        };
        let (n, e, g) = (config.max_nodes, config.max_edges, config.max_graphs);
        let batch_fields = vec![
            field("dist", vec![e], DType::F32),
            field("edge_dst", vec![e], DType::I32),
            field("edge_mask", vec![e], DType::F32),
            field("edge_src", vec![e], DType::I32),
            field("graph_mask", vec![g], DType::F32),
            field("inv_atoms", vec![g], DType::F32),
            field("node_graph", vec![n], DType::I32),
            field("node_mask", vec![n], DType::F32),
            field("rel_hat", vec![e, 3], DType::F32),
            field("species", vec![n], DType::I32),
            field("y_energy", vec![g], DType::F32),
            field("y_forces", vec![n, 3], DType::F32),
        ];

        Manifest {
            dir: PathBuf::new(),
            config,
            params: Arc::new(params),
            encoder_params: Arc::new(encoder),
            branch_params: Arc::new(branch),
            batch_fields: Arc::new(batch_fields),
            artifacts: BTreeMap::new(),
        }
    }

    /// Whether this manifest was synthesized (no compiled artifacts).
    pub fn is_synthesized(&self) -> bool {
        self.artifacts.is_empty()
    }

    pub fn artifact(&self, name: &str) -> anyhow::Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))
    }

    /// Consistency checks tying the manifest together (used at load time by
    /// the engine and directly by integration tests).
    pub fn validate(&self) -> anyhow::Result<()> {
        // Structural checks shared by loaded and synthesized manifests.
        anyhow::ensure!(
            self.params.len() == self.encoder_params.len() + self.branch_params.len(),
            "param leaf count ({}) != encoder ({}) + branch ({})",
            self.params.len(),
            self.encoder_params.len(),
            self.branch_params.len()
        );
        anyhow::ensure!(
            self.batch_fields.len() == 12,
            "expected 12 batch fields, manifest lists {}",
            self.batch_fields.len()
        );
        if self.is_synthesized() {
            // Native path: the closed-form P_s/P_h formulas are the ground
            // truth the synthesized leaves must reproduce exactly.
            let dims = self.config.arch_dims();
            let enc: usize = self.encoder_params.iter().map(|m| m.numel()).sum();
            let br: usize = self.branch_params.iter().map(|m| m.numel()).sum();
            anyhow::ensure!(
                enc == dims.shared_params(),
                "synthesized encoder leaves hold {enc} params, formula says {}",
                dims.shared_params()
            );
            anyhow::ensure!(
                br == dims.head_params(),
                "synthesized branch leaves hold {br} params, formula says {}",
                dims.head_params()
            );
            return Ok(());
        }
        let ts = self.artifact("train_step")?;
        anyhow::ensure!(
            ts.inputs.len() == self.params.len() + self.batch_fields.len(),
            "train_step inputs ({}) != params ({}) + batch ({})",
            ts.inputs.len(),
            self.params.len(),
            self.batch_fields.len()
        );
        // Every grads.<param> output must mirror a param leaf.
        for p in self.params.iter() {
            let gname = format!("grads.{}", p.name);
            let g = ts
                .outputs
                .iter()
                .find(|o| o.name == gname)
                .ok_or_else(|| anyhow::anyhow!("missing gradient output {gname}"))?;
            anyhow::ensure!(g.shape == p.shape, "grad shape mismatch for {}", p.name);
        }
        for name in ["loss", "mae_e", "mae_f"] {
            anyhow::ensure!(
                ts.outputs.iter().any(|o| o.name == name),
                "train_step missing output {name}"
            );
        }
        for art in self.artifacts.values() {
            anyhow::ensure!(
                art.file.exists(),
                "artifact file {:?} missing (run `make artifacts`)",
                art.file
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthesized_manifest_validates_and_matches_formulas() {
        let m = Manifest::synthesize(ManifestConfig::default_native());
        assert!(m.is_synthesized());
        m.validate().unwrap();
        // branch.* leaves strictly before encoder.* leaves, each sorted.
        let names: Vec<&str> = m.params.iter().map(|l| l.name.as_str()).collect();
        let split = names.iter().position(|n| n.starts_with("encoder.")).unwrap();
        assert!(names[..split].iter().all(|n| n.starts_with("branch.")));
        assert!(names[split..].iter().all(|n| n.starts_with("encoder.")));
        let mut sorted = names.clone();
        sorted.sort_unstable();
        assert_eq!(names, sorted, "leaves must come out in flatten (sorted) order");
        assert_eq!(m.batch_fields.len(), 12);
        // Initializer hints follow the AOT rules.
        let embed = m.params.iter().find(|l| l.name == "encoder.embed").unwrap();
        assert_eq!(embed.init, Some(Init::Normal { scale: 0.5 }));
        let w1 = m
            .params
            .iter()
            .find(|l| l.name == "encoder.layers.0.edge.w1")
            .unwrap();
        assert_eq!(w1.shape, vec![2 * 64 + 16, 64]);
        assert_eq!(w1.init, Some(Init::Lecun { fan_in: 2 * 64 + 16 }));
    }

    #[test]
    fn synthesized_manifest_respects_custom_dims() {
        let mut cfg = ManifestConfig::default_native();
        cfg.hidden = 16;
        cfg.num_layers = 2;
        cfg.num_rbf = 8;
        cfg.head_hidden = 16;
        let m = Manifest::synthesize(cfg);
        m.validate().unwrap();
        assert_eq!(m.encoder_params.len(), 1 + 2 * 10);
        assert_eq!(m.branch_params.len(), 10);
    }
}
