//! Typed view of `artifacts/manifest.json`: the contract between the AOT
//! compile path (python) and the rust runtime. Records every artifact's
//! flattened input/output order with shapes and dtypes, the model config it
//! was lowered with, and initializer hints for the parameter leaves.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::data::batch::BatchDims;
use crate::model::params::LeafMeta;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<LeafMeta>,
    pub outputs: Vec<LeafMeta>,
    pub sha256: String,
}

/// Model config echoed by the manifest (subset the rust side needs).
#[derive(Debug, Clone, Copy)]
pub struct ManifestConfig {
    pub max_nodes: usize,
    pub max_edges: usize,
    pub max_graphs: usize,
    pub num_species: usize,
    pub hidden: usize,
    pub num_layers: usize,
    pub num_rbf: usize,
    pub head_hidden: usize,
    pub cutoff: f64,
    pub energy_weight: f64,
    pub force_weight: f64,
}

impl ManifestConfig {
    pub fn batch_dims(&self) -> BatchDims {
        BatchDims {
            max_nodes: self.max_nodes,
            max_edges: self.max_edges,
            max_graphs: self.max_graphs,
        }
    }

    pub fn arch_dims(&self) -> crate::model::arch::ArchDims {
        crate::model::arch::ArchDims {
            num_species: self.num_species,
            hidden: self.hidden,
            num_layers: self.num_layers,
            num_rbf: self.num_rbf,
            head_hidden: self.head_hidden,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ManifestConfig,
    /// Full parameter leaf list (branch.* then encoder.*, manifest order).
    pub params: Arc<Vec<LeafMeta>>,
    pub encoder_params: Arc<Vec<LeafMeta>>,
    pub branch_params: Arc<Vec<LeafMeta>>,
    pub batch_fields: Arc<Vec<LeafMeta>>,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

fn leaf_list(j: &Json, key: &str) -> anyhow::Result<Vec<LeafMeta>> {
    j.get(key)
        .as_array()
        .ok_or_else(|| anyhow::anyhow!("manifest missing '{key}'"))?
        .iter()
        .map(LeafMeta::from_json)
        .collect()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {path:?} (run `make artifacts`): {e}"))?;
        let j = Json::parse(&text)?;

        let c = j.get("config");
        let need_i = |key: &str| -> anyhow::Result<usize> {
            c.get(key)
                .as_i64()
                .map(|v| v as usize)
                .ok_or_else(|| anyhow::anyhow!("manifest config missing '{key}'"))
        };
        let need_f = |key: &str| -> anyhow::Result<f64> {
            c.get(key)
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("manifest config missing '{key}'"))
        };
        let config = ManifestConfig {
            max_nodes: need_i("max_nodes")?,
            max_edges: need_i("max_edges")?,
            max_graphs: need_i("max_graphs")?,
            num_species: need_i("num_species")?,
            hidden: need_i("hidden")?,
            num_layers: need_i("num_layers")?,
            num_rbf: need_i("num_rbf")?,
            head_hidden: need_i("head_hidden")?,
            cutoff: need_f("cutoff")?,
            energy_weight: need_f("energy_weight")?,
            force_weight: need_f("force_weight")?,
        };

        let mut artifacts = BTreeMap::new();
        let arts = j
            .get("artifacts")
            .as_object()
            .ok_or_else(|| anyhow::anyhow!("manifest missing artifacts"))?;
        for (name, entry) in arts {
            let file = entry
                .get("file")
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("artifact {name} missing file"))?;
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    file: dir.join(file),
                    inputs: leaf_list(entry, "inputs")?,
                    outputs: leaf_list(entry, "outputs")?,
                    sha256: entry.get("sha256").as_str().unwrap_or("").to_string(),
                },
            );
        }

        Ok(Manifest {
            dir,
            config,
            params: Arc::new(leaf_list(&j, "params")?),
            encoder_params: Arc::new(leaf_list(&j, "encoder_params")?),
            branch_params: Arc::new(leaf_list(&j, "branch_params")?),
            batch_fields: Arc::new(leaf_list(&j, "batch")?),
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> anyhow::Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))
    }

    /// Consistency checks tying the manifest together (used at load time by
    /// the engine and directly by integration tests).
    pub fn validate(&self) -> anyhow::Result<()> {
        let ts = self.artifact("train_step")?;
        anyhow::ensure!(
            ts.inputs.len() == self.params.len() + self.batch_fields.len(),
            "train_step inputs ({}) != params ({}) + batch ({})",
            ts.inputs.len(),
            self.params.len(),
            self.batch_fields.len()
        );
        // Every grads.<param> output must mirror a param leaf.
        for p in self.params.iter() {
            let gname = format!("grads.{}", p.name);
            let g = ts
                .outputs
                .iter()
                .find(|o| o.name == gname)
                .ok_or_else(|| anyhow::anyhow!("missing gradient output {gname}"))?;
            anyhow::ensure!(g.shape == p.shape, "grad shape mismatch for {}", p.name);
        }
        for name in ["loss", "mae_e", "mae_f"] {
            anyhow::ensure!(
                ts.outputs.iter().any(|o| o.name == name),
                "train_step missing output {name}"
            );
        }
        for art in self.artifacts.values() {
            anyhow::ensure!(
                art.file.exists(),
                "artifact file {:?} missing (run `make artifacts`)",
                art.file
            );
        }
        Ok(())
    }
}
