//! The pluggable execution-backend contract.
//!
//! `Engine` is a thin dispatcher over a [`Backend`]: anything that can run
//! the four entry points of the training hot path — `train_step` (loss,
//! MAEs, named gradients keyed by the manifest's `LeafMeta` leaves),
//! `eval_step`, `forward`, and `encoder_forward` — against a `ParamSet` and
//! a padded `GraphBatch`. Two implementations exist:
//!
//! * [`crate::runtime::native::NativeBackend`] — the pure-rust EGNN engine
//!   (`model::egnn`); needs no artifacts, works on every machine, and is
//!   the default.
//! * `PjrtBackend` (in `runtime::engine`) — compiles the AOT HLO artifacts
//!   through the PJRT CPU client; requires `--features pjrt` plus
//!   `make artifacts`, and is the accelerated option.
//!
//! Which one runs is a [`BackendKind`] decision: `RunConfig`/CLI
//! `--backend`, the `HYDRA_MTP_BACKEND` environment variable (useful for CI
//! matrix legs), or auto-detection (PJRT when available, native otherwise).
//!
//! The native backend additionally computes at one of two [`Precision`]s
//! (`RunConfig.precision`, CLI `--precision`, env `HYDRA_MTP_PRECISION`):
//! the f64 oracle path, or blocked f32 microkernels with f64 accumulation
//! (see `crate::model::kernels`). PJRT ignores the knob — its numerics are
//! fixed by the compiled artifacts.
//!
//! The serving subsystem (`crate::serve`) layers a second, eval-only fast
//! path on top of the native backend: `model::egnn::EvalWorkspace` replays
//! exactly the `forward` op sequence against pre-marshalled
//! `EncoderParams`/`BranchParams` (f32 views cached once at model load)
//! while recycling every activation buffer and skipping the backward
//! intermediates. Its outputs are bit-identical to `Engine::forward` at
//! either precision (`rust/tests/integration_serving.rs`); non-native
//! backends serve through the generic `forward` entry point instead.

use crate::data::batch::GraphBatch;
use crate::model::params::ParamSet;
use crate::runtime::engine::{EvalOut, StepOut};
use crate::runtime::manifest::Manifest;
use crate::tensor::Tensor;

pub use crate::model::egnn::GradBlock;
pub use crate::model::kernels::Precision;

/// Observer of gradient-block completion inside one train step. The
/// contract every backend honors (natively streaming or by replay):
///
/// 1. `loss_ready` fires exactly once, after the forward pass and before
///    any gradient block — so a sink can decide to zero its payloads (the
///    skip-batch path) before anything is submitted.
/// 2. `block_ready` fires once per [`GradBlock`] in backward completion
///    order (`Branch`, `Layer(L-1)` … `Layer(0)`, `Embed`); when it fires,
///    that block's leaves are final in `grads` while later blocks are
///    still zero.
///
/// An error from `block_ready` aborts the step and propagates out of
/// `train_step_observed`.
pub trait GradObserver {
    fn loss_ready(&mut self, loss: f64);
    fn block_ready(&mut self, block: GradBlock, grads: &ParamSet) -> anyhow::Result<()>;
}

/// Observer that ignores every signal (the plain synchronous step).
pub struct NoopGradObserver;

impl GradObserver for NoopGradObserver {
    fn loss_ready(&mut self, _loss: f64) {}
    fn block_ready(&mut self, _block: GradBlock, _grads: &ParamSet) -> anyhow::Result<()> {
        Ok(())
    }
}

/// One execution backend for the train/eval/predict hot path. All methods
/// take the engine's manifest so a backend carries no duplicate state; they
/// must be callable concurrently from many rank threads (`Send + Sync`).
pub trait Backend: Send + Sync {
    /// Stable identifier ("native", "pjrt").
    fn name(&self) -> &'static str;

    /// Human-readable platform string (PJRT reports the client platform).
    fn platform(&self) -> String;

    /// One forward+backward pass: loss, MAEs, and gradients named after the
    /// manifest's parameter leaves.
    fn train_step(
        &self,
        manifest: &Manifest,
        params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<StepOut>;

    /// As `train_step`, signaling gradient-block completion through `obs`
    /// (see [`GradObserver`]). The default implementation runs the full
    /// step and then REPLAYS the blocks in backward completion order from
    /// the finished grad map — correct for any backend, with no overlap
    /// win. The native backend overrides this with true streaming out of
    /// its analytic backward; both paths produce bit-identical gradients.
    fn train_step_observed(
        &self,
        manifest: &Manifest,
        params: &ParamSet,
        batch: &GraphBatch,
        obs: &mut dyn GradObserver,
    ) -> anyhow::Result<StepOut> {
        let out = self.train_step(manifest, params, batch)?;
        obs.loss_ready(out.loss);
        obs.block_ready(GradBlock::Branch, &out.grads)?;
        for li in (0..manifest.config.num_layers).rev() {
            obs.block_ready(GradBlock::Layer(li), &out.grads)?;
        }
        obs.block_ready(GradBlock::Embed, &out.grads)?;
        Ok(out)
    }

    /// Metrics-only evaluation pass.
    fn eval_step(
        &self,
        manifest: &Manifest,
        params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<EvalOut>;

    /// Inference: (energy_per_atom `[G]`, forces `[N,3]`).
    fn forward(
        &self,
        manifest: &Manifest,
        params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<(Tensor, Tensor)>;

    /// Encoder-only forward: (`h [N,H]`, `v [N,3]`). Accepts encoder leaves
    /// under `encoder.*` or bare names.
    fn encoder_forward(
        &self,
        manifest: &Manifest,
        encoder_params: &ParamSet,
        batch: &GraphBatch,
    ) -> anyhow::Result<(Tensor, Tensor)>;
}

/// Which backend an `Engine` should run (`RunConfig.backend`, CLI
/// `--backend`, env `HYDRA_MTP_BACKEND`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// PJRT when the feature is compiled in and artifacts load; native
    /// otherwise. Honors `HYDRA_MTP_BACKEND` as an override.
    #[default]
    Auto,
    /// The pure-rust EGNN engine; never needs artifacts.
    Native,
    /// The PJRT AOT-artifact engine; errors when unavailable.
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> anyhow::Result<BackendKind> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(BackendKind::Auto),
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => anyhow::bail!("unknown backend '{other}' (expected auto|native|pjrt)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Auto => "auto",
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }

    /// The `HYDRA_MTP_BACKEND` environment override, or `Auto`. An invalid
    /// value warns and falls back to `Auto` rather than poisoning every
    /// engine load.
    pub fn from_env() -> BackendKind {
        match std::env::var("HYDRA_MTP_BACKEND") {
            Ok(v) if !v.is_empty() => BackendKind::parse(&v).unwrap_or_else(|e| {
                eprintln!("warning: HYDRA_MTP_BACKEND ignored: {e}");
                BackendKind::Auto
            }),
            _ => BackendKind::Auto,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parses_and_names_roundtrip() {
        for kind in [BackendKind::Auto, BackendKind::Native, BackendKind::Pjrt] {
            assert_eq!(BackendKind::parse(kind.name()).unwrap(), kind);
        }
        assert_eq!(BackendKind::parse("NATIVE").unwrap(), BackendKind::Native);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(BackendKind::default(), BackendKind::Auto);
    }
}
