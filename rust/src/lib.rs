//! # hydra-mtp
//!
//! Reproduction of *"Multi-task parallelism for robust pre-training of graph
//! foundation models on multi-source, multi-fidelity atomistic modeling
//! data"* as a three-layer rust + JAX + Pallas system:
//!
//! - **L3 (this crate)** — the paper's system contribution: a 2D-parallel
//!   (multi-task x data) training coordinator with a device mesh, ring
//!   collectives, a distributed sample store, packed dataset files, synthetic
//!   multi-fidelity data generators, an AdamW optimizer, and a calibrated
//!   supercomputer scaling simulator (Frontier / Perlmutter / Aurora).
//! - **L2 (python/compile/model.py)** — the HydraGNN-style EGNN encoder +
//!   two-level MTL branch, AOT-lowered once to HLO text.
//! - **L1 (python/compile/kernels/)** — Pallas kernels for the message
//!   passing and branch-trunk hot spots, lowered inside the same HLO.
//!
//! Python never runs on the training path: the coordinator loads
//! `artifacts/*.hlo.txt` through the PJRT CPU client (`xla` crate) and is
//! self-contained afterwards.

pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod elements;
pub mod model;
pub mod runtime;
pub mod scalesim;
pub mod tensor;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
