//! # hydra-mtp
//!
//! Reproduction of *"Multi-task parallelism for robust pre-training of graph
//! foundation models on multi-source, multi-fidelity atomistic modeling
//! data"* as a three-layer rust + JAX + Pallas system:
//!
//! - **L3 (this crate)** — the paper's system contribution: a 2D-parallel
//!   (multi-task x data) training coordinator with a device mesh, ring
//!   collectives, a distributed sample store, packed dataset files, synthetic
//!   multi-fidelity data generators, an AdamW optimizer, and a calibrated
//!   supercomputer scaling simulator (Frontier / Perlmutter / Aurora).
//! - **L2 (python/compile/model.py)** — the HydraGNN-style EGNN encoder +
//!   two-level MTL branch, AOT-lowered once to HLO text.
//! - **L1 (python/compile/kernels/)** — Pallas kernels for the message
//!   passing and branch-trunk hot spots, lowered inside the same HLO.
//!
//! ## Execution backends
//!
//! The compute core is pluggable ([`runtime::Backend`]). Two backends share
//! one manifest contract (leaf names, shapes, batch fields):
//!
//! - **native** (the default) — [`runtime::NativeBackend`]: the EGNN
//!   encoder + MTL branch re-implemented in pure rust ([`model::egnn`])
//!   with a hand-written analytic backward pass, f64 accumulation, and
//!   scoped-thread parallelism over the batch. It needs **zero artifacts**:
//!   when no `artifacts/` directory exists the manifest is synthesized from
//!   the model config, so training, evaluation, checkpointing and serving
//!   run end-to-end on any machine — `cargo run --release --example
//!   pretrain_e2e` works on a clean checkout. Gradients are validated
//!   against central finite differences in `rust/tests/gradcheck.rs`.
//! - **pjrt** (the accelerated option) — compiles `artifacts/*.hlo.txt`
//!   through the PJRT CPU client; requires `make artifacts` plus
//!   `--features pjrt`. Python never runs on the training path either way.
//!
//! Select with `Session::builder().backend(..)`, CLI `--backend
//! auto|native|pjrt`, or the `HYDRA_MTP_BACKEND` env var; `auto` prefers
//! PJRT when available and falls back to native.
//!
//! ### Precision
//!
//! The native backend computes at one of two precisions
//! ([`runtime::Precision`]; `RunConfig.precision`,
//! `Session::builder().precision(..)`, CLI `--precision f64|mixed-f32`,
//! env `HYDRA_MTP_PRECISION`):
//!
//! - **`F64`** (default) — scalar f64 kernels everywhere; the numerical
//!   oracle. Every analytic gradient is validated against central finite
//!   differences at this precision, and its results are kept byte-for-byte
//!   stable across PRs.
//! - **`MixedF32`** — blocked, register-tiled f32 microkernels with **f64
//!   accumulators** ([`model::kernels`]) for the matmul and silu/gate hot
//!   spots (the reduced-precision-compute / full-precision-accumulate
//!   recipe of the HydraGNN-lineage GFM runs); the loss reduction, scatter
//!   aggregation, gradient seeds and optimizer stay f64. Gradients are
//!   bounded leaf-by-leaf against the f64 oracle (documented tolerance in
//!   `rust/tests/gradcheck.rs`). Chunking preserves every reduction's
//!   accumulation order, so results remain **bit-deterministic for any
//!   thread count** and the checkpoint kill-at-k parity guarantees hold at
//!   either precision (`rust/tests/integration_precision.rs`).
//!
//! The *resolved* precision is recorded in each checkpoint's trajectory
//! fingerprint: resuming a run at a different precision is refused with an
//! error naming both, exactly like resuming across backends. Kernel
//! fan-out is capped at `HYDRA_MTP_THREADS` worker threads (default 8,
//! clamped to `[1, 512]`; `0` means serial). `cargo bench --bench
//! hot_paths` records `native_f64` vs `native_f32` step timings
//! side-by-side in `BENCH_hot_paths.json` (see EXPERIMENTS.md §Perf —
//! quote only CI-artifact numbers).
//!
//! ## The featurize-once data path
//!
//! Training data flows generate -> featurize -> plan -> marshal, and each
//! stage pays its cost exactly once:
//!
//! - [`coordinator::trainer::DataBundle::generate`] fans dataset generation
//!   out over scoped threads (independent RNG streams per task, bit-identical
//!   to the serial path).
//! - [`data::FeaturizedStore`] runs `radius_graph` once per structure at
//!   bundle-build time (in parallel across shards) and caches edges + node
//!   fields in flat arrays; warm-epoch planning only shuffles indices and
//!   packs cached slices — zero graph constructions after epoch one.
//! - [`data::BatchPool`] recycles `GraphBatch` buffers across epochs instead
//!   of reallocating per batch.
//! - `GraphBatch::field_literal` marshals batch fields to the runtime in
//!   place — no per-step clones into intermediate tensors.
//!
//! Every stage is bit-identical to the seed pipeline (same batches, same
//! order, same losses), proven by the parity tests in
//! `rust/tests/integration_featurized.rs`; `cargo bench --bench hot_paths`
//! tracks the speedups in `BENCH_hot_paths.json` (see EXPERIMENTS.md §Perf).
//!
//! ## The Session API
//!
//! The full lifecycle — pick a backend, generate multi-source data, train
//! with multi-task parallelism, evaluate, predict — is one facade. No
//! artifacts are required; this runs on a clean checkout:
//!
//! ```no_run
//! use hydra_mtp::{Session, TrainMode};
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut session = Session::builder()
//!     .mode(TrainMode::MtlPar)
//!     .replicas(2)
//!     .epochs(3)
//!     .build()?;                  // auto backend: native unless PJRT exists
//! let outcome = session.train()?;                       // generates data lazily
//! let scores = session.evaluate(&outcome.model)?;       // per-task test MAE
//! let mut predictor = session.predictor(&outcome.model);
//! let preds = predictor.predict(session.test_samples(5)?.as_slice())?;
//! # let _ = (scores, preds); Ok(())
//! # }
//! ```
//!
//! ## The task registry
//!
//! The set of pre-training tasks is **data, not code**: [`tasks::TaskSpec`]
//! bundles a dataset's identity, element palette, fidelity transform,
//! generator family and head configuration; the paper's five datasets are
//! presets in the process-global [`tasks::TaskRegistry`], and arbitrary
//! additional tasks register at runtime:
//!
//! ```
//! use hydra_mtp::tasks::*;
//!
//! let sixth = TaskRegistry::global().register(TaskSpec::new(
//!     "MySixthSource",
//!     vec![1, 6, 7, 8, 16],
//!     GeneratorProfile {
//!         kind: StructureKind::Molecule { min_atoms: 4, atoms_cap: 14 },
//!         relax_steps: 10,
//!         relax_step_size: 0.05,
//!         perturb_factor: 1.0,
//!     },
//!     FidelityProfile {
//!         seed_tag: 101, shift_sigma: 0.8, scale_jitter: 0.02,
//!         force_scale_jitter: 0.01, energy_noise: 0.002, force_noise: 0.004,
//!         shift_offset: 0.0,
//!     },
//! )).unwrap();
//! assert_eq!(sixth.name(), "MySixthSource");
//! ```
//!
//! Training `mtl-par` over six tasks simply builds a 6 x M mesh — head
//! count follows the task list.
//!
//! ## Checkpoint / resume / warm start
//!
//! Multi-day pre-training is only viable with fault tolerance. The
//! [`checkpoint`] module persists everything a run needs to restart at an
//! epoch boundary — parameters, AdamW moments, the metrics log, the
//! early-stopper cursor — in a versioned, CRC32-guarded binary file, and a
//! resumed run is **bit-identical** to an uninterrupted one (collectives
//! reduce in rank order, so even multi-rank meshes replay exactly; proven
//! in `rust/tests/integration_checkpoint.rs`):
//!
//! ```no_run
//! use hydra_mtp::{Session, TrainMode};
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut session = Session::builder()
//!     .artifacts("artifacts")
//!     .mode(TrainMode::MtlPar)
//!     .epochs(12)
//!     .checkpoint_dir("ckpts")        // rank 0 writes ckpts/epoch_NNNN.ckpt
//!     .build()?;
//! let outcome = session.train()?;
//!
//! // ... the job is killed; a later process picks the run back up:
//! let mut session = Session::builder()
//!     .artifacts("artifacts")
//!     .mode(TrainMode::MtlPar)
//!     .epochs(12)
//!     .build()?;
//! let resumed = session.resume("ckpts")?;   // latest epoch_*.ckpt wins
//!
//! // Persist just the model for serving / warm starts:
//! session.save_model(&resumed.model, "gfm.ckpt")?;
//! let model = hydra_mtp::Session::load_model("gfm.ckpt")?;
//! # let _ = (outcome, model); Ok(())
//! # }
//! ```
//!
//! Warm-start fine-tuning loads a pre-trained encoder, freezes it, and
//! trains only a new task's head — `Session::fine_tune(&model, new_task)`
//! — so tasks registered at runtime ride on an existing foundation model
//! without re-running pre-training. The CLI exposes the same knobs as
//! `hydra-mtp train --checkpoint-dir DIR [--resume PATH]`, and
//! `examples/pretrain_e2e.rs` demonstrates interrupt-and-resume end to end.
//!
//! ## Serving
//!
//! [`Predictor`] is a batch API; a production service sees the opposite
//! shape — many concurrent clients, one structure each. [`Session::server`]
//! starts an always-on [`serve::Server`]: a persistent worker pool behind a
//! bounded **coalescing request queue** that packs concurrent
//! single-structure requests into shared padded batches. Admission is by
//! node/edge *budget* (never request count), a full queue applies
//! backpressure (bounded wait, then a typed
//! [`serve::ServeError::Overloaded`]), and shutdown drains the queue before
//! joining the workers. Parameters are marshalled into typed structs — f32
//! weight views included — once at model load; each worker recycles one
//! eval-only activation workspace, so the steady state allocates nothing
//! per request. Coalesced outputs are **bit-identical** to sequential
//! `Predictor::predict_one` calls at either precision
//! (`rust/tests/integration_serving.rs`):
//!
//! ```no_run
//! use hydra_mtp::{Session, TrainMode};
//!
//! # fn main() -> anyhow::Result<()> {
//! let session = Session::builder().mode(TrainMode::MtlPar).build()?;
//! let model = hydra_mtp::Session::load_model("gfm.ckpt")?;
//! let server = session.server(&model)?;        // workers spawn here
//! std::thread::scope(|s| {
//!     for client in 0..8 {
//!         let server = &server;
//!         s.spawn(move || {
//!             // each client predicts one structure at a time; concurrent
//!             // requests coalesce into shared padded batches
//!             # let _ = (client, server);
//!         });
//!     }
//! });
//! server.shutdown();                           // drains, then joins
//! # Ok(())
//! # }
//! ```
//!
//! `hydra-mtp serve --model gfm.ckpt --data in.gpack` runs the same loop
//! from the CLI, and `hydra-mtp loadtest` measures coalesced-vs-sequential
//! latency (p50/p95/p99) and sustained throughput in one process —
//! `cargo bench --bench serving` records the same comparison in
//! `BENCH_serving.json` (see EXPERIMENTS.md §Serving — quote only
//! CI-artifact numbers).
//!
//! ## Overlapped gradient reduction + elastic head scheduling
//!
//! At scale the synchronous pattern — finish backward, then reduce the whole
//! gradient in one monolithic collective — leaves the fabric idle during
//! backward and the cores idle during the reduce. The trainer overlaps the
//! two without giving up a single bit of determinism:
//!
//! - **Bucketed reduction** — [`comm::BucketPlan`] partitions the manifest's
//!   leaf set into size-bounded buckets (`parallel.bucket_elems` f32 cap)
//!   ordered by *backward completion*: the native backward pass signals each
//!   block group (heads/trunk first, embedding last) through a
//!   [`runtime::backend::GradObserver`] the moment its leaf gradients are
//!   final, so
//!   early buckets start reducing while later layers are still
//!   differentiating.
//! - **The comm thread** — [`comm::OverlapReducer`] owns one per-rank
//!   reduction thread, double-buffered (two buckets in flight): `submit` is
//!   non-blocking until both slots are busy, `finish` drains in submission
//!   order. Within each bucket, ranks still reduce in rank order over
//!   exactly the same element spans, so the overlapped sum is
//!   **bit-identical** to the monolithic `allreduce_mean` — overlapped
//!   training reaches the same final parameters bit for bit in all three
//!   parallel modes, and kill-at-k checkpoint resume parity holds with
//!   overlap on (`rust/tests/integration_overlap.rs`). A rank that dies
//!   mid-bucket poisons the group exactly like the sync path: peers get a
//!   typed [`CommError::RankFailure`](comm::CommError), never a comm-thread
//!   deadlock.
//! - **Elastic head scheduling** — `mtl-par` normally gives every head the
//!   same number of data-parallel ranks, but multi-source bundles are
//!   *imbalanced*: a head with 10x the data takes 10x the steps. With
//!   `parallel.elastic` on, each head's per-step wall time is tracked as an
//!   EMA (`Coverage::step_ms`, persisted in checkpoints and the metrics
//!   JSON), and at every epoch boundary
//!   [`coordinator::scheduler::plan_head_groups_with_fallback`] re-splits
//!   the world proportionally to measured cost x steps (largest-remainder,
//!   min one rank per head); heads with no measurement yet fall back to
//!   planned-steps weighting instead of starving at the one-rank floor. The
//!   mesh is static *within* an epoch, so determinism is per-plan; resume
//!   re-seeds the EMAs from the checkpointed coverage.
//!
//! Knobs: `Session::builder().overlap(true).bucket_elems(n).elastic(true)`,
//! CLI `--overlap/--bucket-elems/--elastic`, env `HYDRA_MTP_OVERLAP`.
//! `overlap`/`bucket_elems` are fingerprint-excluded (they cannot change
//! results); `elastic` changes the training trajectory and is fingerprinted.
//! [`Comm::stats`](comm::Comm::stats) splits traffic into
//! `(elems, rounds, overlapped_elems)` so tests can assert that overlap
//! hides traffic without changing its volume, and
//! [`scalesim`]`::predicted_overlap_win` extends the perf model with the
//! overlap window (backward ~2/3 of step compute) — confronted against the
//! measured win in `rust/tests/integration_overlap.rs`. `cargo bench
//! --bench overlap` records sync-vs-overlapped step times side by side in
//! `BENCH_overlap.json` (see EXPERIMENTS.md §Overlap — quote only
//! CI-artifact numbers).
//!
//! ## Graph parallelism
//!
//! Replica and MTL parallelism shard *structures* across ranks; a bulk
//! structure too large to fit one rank's step budget needs the opposite
//! decomposition — shard the **atoms of one structure**. With
//! `parallel.graph_par` on (CLI `--graph-par`, fingerprinted: it changes
//! the trajectory versus the single-rank schedule only in world topology,
//! never in values), the trainer domain-decomposes every structure:
//!
//! - **Fixed spatial partition** — [`comm::HaloPlan`] splits the cell into
//!   a constant number of slabs (8), *independent of world size*; rank `r`
//!   of `W` owns a contiguous slab range ([`comm::segment_owner`]). The
//!   partition being world-invariant is what makes 1/2/4/8-rank runs
//!   **bit-identical**: every sum is assembled from the same 8 segment
//!   contributions in the same order, whoever computes them.
//! - **Halo exchange** — each EGNN layer's forward exchanges boundary-atom
//!   node features ([`comm::halo`]), and the backward pass reverse-flows
//!   boundary-edge position gradients; the per-step collective volume has a
//!   closed form, `HaloPlan::predicted_step_elems`, asserted **equal to the
//!   measured [`Comm::stats`](comm::Comm::stats) element count on every
//!   rank at every world** (no traffic is unaccounted). [`scalesim`]
//!   mirrors the same closed form (`graph_par_step_elems`,
//!   `graph_par_step_comm_time`) to predict halo cost at machine scale.
//! - **Checkpointed recompute** — the graph-par engine
//!   ([`model::graphpar`]) stores only per-layer block *inputs* and
//!   recomputes activations in the backward sweep, bounding memory by one
//!   layer's working set — the standard trade for structures whose
//!   activation footprint exceeds a rank.
//! - **f64 only** — graph-par pins the compute to the f64 oracle path
//!   regardless of the `precision` knob; the knob is provably ignored
//!   (MixedF32 and F64 engines produce bit-identical graph-par runs in
//!   `rust/tests/integration_graph_parallel.rs`).
//!
//! The large-structure generators ride in through the task registry:
//! [`tasks::register_large_presets`] adds `Supercell` (1000-atom repeated
//! crystal) and `AmorphousBox` (1200-atom disordered box) presets, so
//! `hydra-mtp train --mode supercell --graph-par --replicas 4` trains a
//! huge-structure task end to end. Kill-at-k resume parity and typed
//! mid-halo [`CommError::RankFailure`](comm::CommError) surfacing carry
//! over from the other modes, and the partition + exchange provably
//! reconstructs single-rank `radius_graph` neighborhoods (property test,
//! same suite). `cargo bench --bench graph_parallel` records per-step time
//! and halo bytes versus atom count in `BENCH_graph_parallel.json` (see
//! EXPERIMENTS.md §Graph parallel — quote only CI-artifact numbers).
//!
//! ## Fault tolerance
//!
//! Long pre-training runs on shared clusters fail in practice: ranks die,
//! collectives stall, a bad batch yields NaN, a checkpoint file gets
//! truncated mid-write. The crate treats each of these as a **typed,
//! recoverable** event rather than a hang or an abort:
//!
//! - **Failure-aware collectives** — every group member installs a
//!   [`comm::MemberGuard`]; a rank that panics or exits early *poisons* the
//!   group on drop, waking all waiters with
//!   [`CommError::RankFailure`](comm::CommError) naming the dead
//!   rank. Waits are bounded by a configurable timeout
//!   (`fault.comm_timeout_ms`) that surfaces as
//!   [`CommError::Timeout`](comm::CommError) — a lost rank can never
//!   deadlock the mesh.
//! - **Batch supervision** — a non-finite loss skips the batch (the rank
//!   contributes a zero gradient but still joins every collective, so the
//!   group stays step-synchronized), counts it in
//!   `EpochMetrics::skipped_batches`, and aborts only past a bounded
//!   per-epoch budget (`fault.skip_batch_budget`).
//! - **Rank-failure recovery** — `Trainer::train_with_recovery` (CLI:
//!   `hydra-mtp train --faults .. --max-restarts N`) catches a typed rank
//!   failure, rescans the checkpoint directory for the **latest CRC-valid**
//!   file (corrupt or truncated files are warned about and skipped —
//!   `--resume latest` shares the same scan), and relaunches, up to
//!   `fault.max_restarts` times. Because resume is bit-identical, the
//!   recovered run's final parameters equal the fault-free run's **bit for
//!   bit** (`rust/tests/integration_chaos.rs`).
//! - **Serving self-healing** — a panicking inference worker answers every
//!   in-flight request in its batch with `ServeError::Internal` (no waiter
//!   is ever stranded), then respawns; `ServeStats` counts respawns and
//!   internal errors.
//!
//! All of this is exercised by **deterministic fault injection**
//! ([`fault::FaultPlan`]): a seeded plan parsed from `RunConfig.fault.spec`
//! or the `HYDRA_MTP_FAULTS` env var (grammar:
//! `rank-panic@rank=R,epoch=E,step=S;corrupt-ckpt@epoch=E;...`) injects
//! rank panics, collective stalls, non-finite losses, checkpoint
//! corruption, and serve-worker panics at exact points. Each fault fires at
//! most once, so a recovered run does not re-trip it. An empty plan is a
//! guaranteed no-op: with no faults configured, every byte of behavior is
//! identical to a build without the harness.
//!
//! ## Invariants (statically enforced)
//!
//! The crate's load-bearing guarantees — bit-determinism, panic-free
//! supervision paths, collective error discipline — are cheap to break with
//! an innocent-looking edit. `hydra-lint` (the `hydra_lint` binary, module
//! [`lint`]) re-checks them on every commit as a blocking CI job, with no
//! dependencies beyond this crate itself:
//!
//! - **R1 determinism** — no `HashMap` / `HashSet` / `Instant::now` in the
//!   numeric core (`model/egnn.rs`, `model/kernels.rs`, `comm/`,
//!   `checkpoint.rs`, `data/graph.rs`). Iteration order and wall-clock must
//!   never reach reduced values, edge lists, or serialized bytes.
//! - **R2 panic-safety** — no `unwrap` / `expect` / panicking macros / range
//!   indexing on the serving hot path (`serve/`), the checkpoint
//!   decode path, or the trainer's rank-supervision path. These paths turn
//!   failures into typed errors; a panic there strands waiters or kills
//!   rank 0.
//! - **R3 collective-safety** — every `Comm` collective returns a
//!   `Result<_, CommError>` that must be propagated or matched, never
//!   unwrapped or discarded: a swallowed collective error desynchronizes
//!   the mesh.
//! - **R4 config-coverage** — every [`config::RunConfig`] field is either
//!   hashed into `trajectory_fingerprint_resolved` or listed (with a
//!   reason) in `config::FINGERPRINT_EXCLUDED`. Adding a field without
//!   deciding fails the build.
//! - **R5 env-var registry** — every `HYDRA_MTP_*` environment read is
//!   declared in [`lint::env_registry`], which also renders the
//!   `--help` environment section, so docs cannot drift from reads.
//!
//! Deliberate exceptions are annotated in place:
//! `// lint:allow(<rule>): <reason>` on (or immediately above) the offending
//! line, where `<rule>` is `nondeterministic`, `panic`, or `collective`.
//! The reason is mandatory and the lint flags annotations that suppress
//! nothing, so waivers stay accurate. Run it locally with
//! `cargo run --bin hydra_lint`.

pub mod checkpoint;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod elements;
pub mod fault;
pub mod lint;
pub mod model;
pub mod runtime;
pub mod scalesim;
pub mod serve;
pub mod session;
pub mod tasks;
pub mod tensor;
pub mod util;

pub use comm::CommError;
pub use config::{FaultConfig, RunConfig, ServeConfig, TrainMode};
pub use fault::FaultPlan;
pub use runtime::{BackendKind, Engine, Precision};
pub use serve::{ServeError, ServeStats, Server};
pub use session::{Prediction, Predictor, Session, SessionBuilder};
pub use tasks::{DatasetId, TaskRegistry, TaskSpec, ALL_DATASETS};

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
