//! Multi-client load test for the serving subsystem: the same request
//! stream through (a) sequential `Predictor::predict_one` calls and (b) N
//! concurrent clients against a [`Server`], measured in the SAME process
//! so the two legs share an engine, a model, and a machine state. Records
//! per-request latency percentiles (p50/p95/p99) and sustained
//! structures/sec for both legs, and checks the two output streams
//! bit-for-bit — the load test doubles as an end-to-end identity check.
//!
//! Consumed by the `loadtest` CLI mode and by `rust/benches/serving.rs`
//! (which writes `BENCH_serving.json` in CI).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use crate::config::ServeConfig;
use crate::coordinator::trainer::{Heads, TrainedModel};
use crate::data::structures::{AtomicStructure, DatasetId};
use crate::fault;
use crate::model::params::ParamSet;
use crate::runtime::Engine;
use crate::serve::Server;
use crate::session::{Prediction, Predictor};
use crate::util::json::Json;

/// A deterministic per-dataset model straight from the initializer —
/// the standard way to exercise serving without a training run (same
/// seeding scheme as the trainer's rank init, so any session can rebuild
/// the identical model from `(engine, tasks, seed)`).
pub fn synthetic_model(engine: &Engine, tasks: &[DatasetId], seed: u64) -> TrainedModel {
    let encoder = ParamSet::init(&engine.manifest.params, seed).subset("encoder.");
    let heads: BTreeMap<DatasetId, ParamSet> = tasks
        .iter()
        .map(|&d| {
            let s = seed ^ d.branch_init_salt();
            (d, ParamSet::init(&engine.manifest.params, s).subset("branch."))
        })
        .collect();
    TrainedModel { name: format!("synthetic-{seed}"), encoder, heads: Heads::PerDataset(heads) }
}

/// Latency/throughput summary of one leg (sequential or server).
#[derive(Debug, Clone, Copy)]
pub struct LegReport {
    pub requests: usize,
    pub clients: usize,
    pub wall_secs: f64,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
    /// Sustained structures/sec over the leg's wall clock.
    pub throughput_per_sec: f64,
    /// Mean structures per executed batch (1.0 for the sequential leg).
    pub avg_batch: f64,
}

fn percentile(sorted: &[u64], pct: usize) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let i = (sorted.len() * pct / 100).min(sorted.len() - 1);
    sorted[i]
}

fn leg(latencies: &mut [u64], clients: usize, wall_secs: f64, avg_batch: f64) -> LegReport {
    latencies.sort_unstable();
    LegReport {
        requests: latencies.len(),
        clients,
        wall_secs,
        p50_ns: percentile(latencies, 50),
        p95_ns: percentile(latencies, 95),
        p99_ns: percentile(latencies, 99),
        throughput_per_sec: if wall_secs > 0.0 {
            latencies.len() as f64 / wall_secs
        } else {
            0.0
        },
        avg_batch,
    }
}

impl LegReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::from(self.requests)),
            ("clients", Json::from(self.clients)),
            ("wall_secs", Json::from(self.wall_secs)),
            ("p50_ns", Json::from(self.p50_ns as i64)),
            ("p95_ns", Json::from(self.p95_ns as i64)),
            ("p99_ns", Json::from(self.p99_ns as i64)),
            ("throughput_per_sec", Json::from(self.throughput_per_sec)),
            ("avg_batch", Json::from(self.avg_batch)),
        ])
    }
}

/// Both legs over one request stream, plus the bit-identity verdict.
#[derive(Debug, Clone)]
pub struct LoadTestReport {
    pub precision: String,
    pub sequential: LegReport,
    pub server: LegReport,
    /// Every server prediction bitwise equal to its sequential twin.
    pub bit_identical: bool,
    /// Client threads that panicked mid-run. Their slots stay unanswered
    /// (so `bit_identical` is false), but one bad client no longer takes
    /// the whole report down.
    pub failed_clients: usize,
}

impl LoadTestReport {
    /// Server speedup over the sequential baseline (>1.0 means the
    /// coalescing path sustained more structures/sec).
    pub fn speedup(&self) -> f64 {
        if self.sequential.throughput_per_sec > 0.0 {
            self.server.throughput_per_sec / self.sequential.throughput_per_sec
        } else {
            0.0
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("precision", Json::str(&self.precision)),
            ("sequential", self.sequential.to_json()),
            ("server", self.server.to_json()),
            ("speedup", Json::from(self.speedup())),
            ("bit_identical", Json::from(self.bit_identical)),
            ("failed_clients", Json::from(self.failed_clients)),
        ])
    }
}

fn same_bits(a: &Prediction, b: &Prediction) -> bool {
    a.dataset == b.dataset
        && a.energy.to_bits() == b.energy.to_bits()
        && a.energy_per_atom.to_bits() == b.energy_per_atom.to_bits()
        && a.forces.len() == b.forces.len()
        && a.forces.iter().zip(&b.forces).all(|(x, y)| {
            x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
        })
}

/// Run both legs over `structures`: first the sequential
/// `Predictor::predict_one` baseline, then `clients` concurrent threads
/// against a fresh [`Server`] started with `cfg` — same process, same
/// engine. Any failed request is an error; output divergence is not —
/// it is reported in `bit_identical` so callers (bench, CLI) decide how
/// loudly to fail. A client thread that panics is likewise reported, in
/// `failed_clients`, rather than propagating the panic out of the run.
pub fn run_loadtest(
    engine: &Arc<Engine>,
    model: &TrainedModel,
    structures: &[AtomicStructure],
    clients: usize,
    cfg: ServeConfig,
) -> anyhow::Result<LoadTestReport> {
    anyhow::ensure!(!structures.is_empty(), "load test needs at least one structure");
    let clients = clients.max(1);

    // Leg 1: sequential per-call baseline.
    let mut predictor = Predictor::new(Arc::clone(engine), model.clone());
    let mut seq_lat = Vec::with_capacity(structures.len());
    let mut seq_out = Vec::with_capacity(structures.len());
    let t0 = Instant::now();
    for s in structures {
        let t = Instant::now();
        seq_out.push(predictor.predict_one(s)?);
        seq_lat.push(t.elapsed().as_nanos() as u64);
    }
    let seq_wall = t0.elapsed().as_secs_f64();

    // Leg 2: concurrent clients against the server, round-robin split.
    let server = Server::start(Arc::clone(engine), model.clone(), cfg)?;
    let mut srv_out: Vec<Option<Prediction>> = vec![None; structures.len()];
    let mut srv_lat = Vec::with_capacity(structures.len());
    let t0 = Instant::now();
    let mut failed_clients = 0usize;
    let results: Vec<anyhow::Result<Vec<(usize, u64, Prediction)>>> =
        std::thread::scope(|scope| {
            let server = &server;
            let mut handles = Vec::with_capacity(clients);
            for c in 0..clients {
                handles.push(scope.spawn(move || {
                    let mut got = Vec::new();
                    for (i, s) in structures.iter().enumerate() {
                        if i % clients != c {
                            continue;
                        }
                        let t = Instant::now();
                        let p = server.predict(s).map_err(|e| anyhow::anyhow!("client {c}: {e}"))?;
                        got.push((i, t.elapsed().as_nanos() as u64, p));
                    }
                    Ok(got)
                }));
            }
            handles
                .into_iter()
                .enumerate()
                .filter_map(|(c, h)| match h.join() {
                    Ok(r) => Some(r),
                    Err(p) => {
                        failed_clients += 1;
                        eprintln!(
                            "loadtest client {c} panicked: {}",
                            fault::panic_message(p.as_ref())
                        );
                        None
                    }
                })
                .collect()
        });
    let srv_wall = t0.elapsed().as_secs_f64();
    for r in results {
        for (i, lat, p) in r? {
            srv_lat.push(lat);
            srv_out[i] = Some(p);
        }
    }
    let stats = server.stats();
    server.shutdown();

    let bit_identical = seq_out.iter().zip(&srv_out).all(|(a, b)| {
        b.as_ref().is_some_and(|b| same_bits(a, b))
    });

    Ok(LoadTestReport {
        precision: engine.precision().name().to_string(),
        sequential: leg(&mut seq_lat, 1, seq_wall, 1.0),
        server: leg(&mut srv_lat, clients, srv_wall, stats.avg_batch()),
        bit_identical,
        failed_clients,
    })
}
