//! The coalescing request queue at the heart of the serving subsystem.
//!
//! Clients enqueue one featurized structure each ([`Job`]); workers drain
//! the queue with [`CoalescingQueue::next_batch`], which greedily packs as
//! many *same-task* jobs as fit the compiled node/edge budget into one
//! batch. Admission is by budget, not by request count: a worker wakes up
//! for one job and leaves with everything queued behind it that shares a
//! head and still fits. The queue is bounded; a full queue applies
//! backpressure to `submit` (a bounded wait, then a typed
//! [`ServeError::Overloaded`](crate::serve::ServeError::Overloaded)).
//!
//! Shutdown is drain-then-stop: after [`CoalescingQueue::shutdown`], new
//! submissions are refused but `next_batch` keeps handing out batches until
//! the queue is empty, then returns `None` so workers exit.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::data::batch::BatchDims;
use crate::data::graph::Edge;
use crate::data::structures::DatasetId;
use crate::serve::ServeError;
use crate::session::Prediction;

/// One enqueued inference request: a featurized structure (the client
/// thread runs `radius_graph` itself, so graph construction happens in
/// parallel across clients) plus the channel its [`Prediction`] is sent
/// back on.
pub struct Job {
    /// Task whose head serves this request.
    pub task: DatasetId,
    pub species: Vec<u8>,
    pub edges: Vec<Edge>,
    /// Completion channel; the worker sends exactly one result.
    pub tx: mpsc::Sender<Result<Prediction, ServeError>>,
}

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

/// Bounded MPMC queue that coalesces same-task jobs into budget-limited
/// batches. See the module docs for the protocol.
pub struct CoalescingQueue {
    state: Mutex<QueueState>,
    /// Signalled when a job arrives or shutdown starts (wakes workers).
    work: Condvar,
    /// Signalled when queue slots free up (wakes blocked submitters).
    space: Condvar,
    capacity: usize,
}

impl CoalescingQueue {
    pub fn new(capacity: usize) -> CoalescingQueue {
        CoalescingQueue {
            state: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            work: Condvar::new(),
            space: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Lock the queue state, recovering from poison instead of panicking:
    /// every mutation below leaves `QueueState` consistent at each unlock
    /// point (a push, a pop, or a flag write completes under one guard),
    /// and worker panics are already contained by `catch_unwind` in the
    /// worker loop — so a poisoned mutex carries no torn state, only the
    /// news that some peer panicked. Same policy as `comm::collectives`.
    fn lock_state(&self) -> MutexGuard<'_, QueueState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Enqueue `job`, waiting up to `wait` for a slot when the queue is
    /// full. Returns [`ServeError::Overloaded`] if no slot frees up in time
    /// and [`ServeError::ShuttingDown`] once shutdown has begun.
    pub fn submit(&self, job: Job, wait: Duration) -> Result<(), ServeError> {
        let deadline = Instant::now() + wait;
        let mut st = self.lock_state();
        loop {
            if st.shutdown {
                return Err(ServeError::ShuttingDown);
            }
            if st.jobs.len() < self.capacity {
                st.jobs.push_back(job);
                self.work.notify_one();
                return Ok(());
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(ServeError::Overloaded { capacity: self.capacity });
            }
            let (guard, _timeout) = self
                .space
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|p| p.into_inner());
            st = guard;
        }
    }

    /// Block until work is available, then return one coalesced batch:
    /// the oldest job plus every queued job behind it with the *same task*
    /// that still fits the node/edge budget. At most `max_graphs - 1`
    /// structures are taken (the last graph slot stays reserved for
    /// padding, so real graphs never absorb padding-node contributions and
    /// batched outputs stay bit-identical to the one-at-a-time path).
    /// Returns `None` when the queue has shut down *and* drained.
    pub fn next_batch(&self, dims: &BatchDims) -> Option<Vec<Job>> {
        let cap = if dims.max_graphs > 1 { dims.max_graphs - 1 } else { 1 };
        let mut st = self.lock_state();
        loop {
            if let Some(first) = st.jobs.pop_front() {
                let task = first.task;
                let mut nodes = first.species.len();
                let mut edges = first.edges.len();
                let mut picked = vec![first];
                let mut i = 0;
                while i < st.jobs.len() && picked.len() < cap {
                    let j = &st.jobs[i];
                    if j.task == task
                        && nodes + j.species.len() <= dims.max_nodes
                        && edges + j.edges.len() <= dims.max_edges
                    {
                        // `i < len` is loop-guarded, so `remove` always
                        // yields; the defensive arm keeps the worker loop
                        // panic-free even if that invariant ever broke.
                        match st.jobs.remove(i) {
                            Some(j) => {
                                nodes += j.species.len();
                                edges += j.edges.len();
                                picked.push(j);
                            }
                            None => break,
                        }
                    } else {
                        i += 1;
                    }
                }
                self.space.notify_all();
                return Some(picked);
            }
            if st.shutdown {
                return None;
            }
            st = self.work.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Begin shutdown: refuse new submissions, wake every waiter. Queued
    /// jobs are still drained by `next_batch`.
    pub fn shutdown(&self) {
        let mut st = self.lock_state();
        st.shutdown = true;
        self.work.notify_all();
        self.space.notify_all();
        drop(st);
    }

    /// Jobs currently queued (snapshot; for stats/tests).
    pub fn len(&self) -> usize {
        self.lock_state().jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> BatchDims {
        BatchDims { max_nodes: 10, max_edges: 20, max_graphs: 4 }
    }

    /// A job with `natoms` dummy nodes and `nedges` dummy edges.
    fn job(
        task: DatasetId,
        natoms: usize,
        nedges: usize,
    ) -> (Job, mpsc::Receiver<Result<Prediction, ServeError>>) {
        let (tx, rx) = mpsc::channel();
        let edge = Edge { src: 0, dst: 0, rel_hat: [0.0, 0.0, 1.0], dist: 1.0 };
        let j = Job { task, species: vec![1; natoms], edges: vec![edge; nedges], tx };
        (j, rx)
    }

    #[test]
    fn coalesces_same_task_jobs_within_budget() {
        let q = CoalescingQueue::new(16);
        let wait = Duration::from_millis(10);
        for _ in 0..3 {
            let (j, _rx) = job(DatasetId::Ani1x, 3, 5);
            q.submit(j, wait).unwrap();
        }
        let batch = q.next_batch(&dims()).unwrap();
        // 3+3+3 nodes <= 10 and 5+5+5 edges <= 20 and 3 <= max_graphs-1.
        assert_eq!(batch.len(), 3);
        assert!(q.is_empty());
    }

    #[test]
    fn node_budget_splits_batches() {
        let q = CoalescingQueue::new(16);
        let wait = Duration::from_millis(10);
        for _ in 0..3 {
            let (j, _rx) = job(DatasetId::Ani1x, 4, 2);
            q.submit(j, wait).unwrap();
        }
        // 4+4 <= 10 but 4+4+4 > 10: two jobs, then one.
        let d = dims();
        assert_eq!(q.next_batch(&d).unwrap().len(), 2);
        assert_eq!(q.next_batch(&d).unwrap().len(), 1);
    }

    #[test]
    fn graph_slot_cap_reserves_the_padding_slot() {
        let q = CoalescingQueue::new(16);
        let wait = Duration::from_millis(10);
        for _ in 0..5 {
            let (j, _rx) = job(DatasetId::Ani1x, 1, 1);
            q.submit(j, wait).unwrap();
        }
        // Everything fits the node/edge budget, but max_graphs = 4 caps a
        // batch at 3 structures (slot G-1 stays padding).
        let d = dims();
        assert_eq!(q.next_batch(&d).unwrap().len(), 3);
        assert_eq!(q.next_batch(&d).unwrap().len(), 2);
    }

    #[test]
    fn mixed_tasks_batch_separately_with_skip_ahead() {
        let q = CoalescingQueue::new(16);
        let wait = Duration::from_millis(10);
        let order = [DatasetId::Ani1x, DatasetId::Qm7x, DatasetId::Ani1x];
        for &t in &order {
            let (j, _rx) = job(t, 2, 2);
            q.submit(j, wait).unwrap();
        }
        let d = dims();
        // The two Ani1x jobs coalesce around the interleaved Qm7x one.
        let b1 = q.next_batch(&d).unwrap();
        assert_eq!(b1.len(), 2);
        assert!(b1.iter().all(|j| j.task == DatasetId::Ani1x));
        let b2 = q.next_batch(&d).unwrap();
        assert_eq!(b2.len(), 1);
        assert_eq!(b2[0].task, DatasetId::Qm7x);
    }

    #[test]
    fn full_queue_overloads_after_bounded_wait() {
        let q = CoalescingQueue::new(2);
        let wait = Duration::from_millis(5);
        let (j1, _r1) = job(DatasetId::Ani1x, 1, 1);
        let (j2, _r2) = job(DatasetId::Ani1x, 1, 1);
        q.submit(j1, wait).unwrap();
        q.submit(j2, wait).unwrap();
        // No workers draining: the third submit must time out.
        let (j3, _r3) = job(DatasetId::Ani1x, 1, 1);
        match q.submit(j3, wait) {
            Err(ServeError::Overloaded { capacity }) => assert_eq!(capacity, 2),
            other => panic!("expected Overloaded, got {other:?}"),
        }
    }

    #[test]
    fn shutdown_releases_blocked_submitters() {
        use std::sync::Arc;
        let q = Arc::new(CoalescingQueue::new(1));
        let wait = Duration::from_secs(30);
        let (j1, _r1) = job(DatasetId::Ani1x, 1, 1);
        q.submit(j1, wait).unwrap();
        // A second submitter blocks on the full queue with a long wait...
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            let (j2, _r2) = job(DatasetId::Ani1x, 1, 1);
            q2.submit(j2, wait)
        });
        std::thread::sleep(Duration::from_millis(50));
        // ...and shutdown must wake it promptly with the typed refusal,
        // not strand it until the 30 s wait expires.
        let t0 = Instant::now();
        q.shutdown();
        let res = h.join().unwrap();
        assert!(matches!(res, Err(ServeError::ShuttingDown)), "got {res:?}");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "blocked submitter not released promptly"
        );
    }

    #[test]
    fn shutdown_drains_then_stops() {
        let q = CoalescingQueue::new(16);
        let wait = Duration::from_millis(10);
        let (j, _rx) = job(DatasetId::Ani1x, 1, 1);
        q.submit(j, wait).unwrap();
        q.shutdown();
        // Queued work is still handed out...
        let d = dims();
        assert_eq!(q.next_batch(&d).unwrap().len(), 1);
        // ...then workers are released.
        assert!(q.next_batch(&d).is_none());
        // And new submissions are refused.
        let (j2, _r2) = job(DatasetId::Ani1x, 1, 1);
        assert!(matches!(q.submit(j2, wait), Err(ServeError::ShuttingDown)));
    }
}
