//! Production serving: always-on batched inference with a coalescing
//! request queue.
//!
//! [`Session::predictor`](crate::session::Session::predictor) is a batch
//! API: callers hand it slices and it packs them. A long-lived service has
//! the opposite shape — many concurrent clients, one structure each — and
//! calling `predict_one` per request pays a padded-batch forward per
//! structure. A [`Server`] amortizes that cost without changing a single
//! output bit:
//!
//! * **Coalescing queue** ([`queue::CoalescingQueue`]): concurrent
//!   single-structure requests are packed into one padded [`GraphBatch`]
//!   per forward. Admission is by *node/edge budget*, not request count,
//!   with at most `max_graphs - 1` structures per batch so the padding
//!   graph slot never overlaps a real one.
//! * **Persistent workers**: a pool of threads (sized by
//!   `serve.workers`, default `HYDRA_MTP_THREADS`) lives for the server
//!   lifetime; each owns a recycled batch + activation workspace
//!   ([`prepared::Workspace`]), so steady-state serving allocates nothing
//!   per request.
//! * **Prepared parameters** ([`prepared::PreparedModel`]): typed encoder /
//!   branch params with cached f32 weight views, materialized once at
//!   startup; heads sit in a small bounded LRU.
//! * **Backpressure**: the queue is bounded; `predict` waits up to
//!   `serve.enqueue_wait_ms` for a slot, then returns
//!   [`ServeError::Overloaded`]. Oversized structures are refused up front
//!   ([`ServeError::TooLarge`]) — by the same budget the queue admits by.
//! * **Graceful shutdown**: [`Server::shutdown`] (also on `Drop`) refuses
//!   new work, drains the queue, and joins the workers; in-flight clients
//!   get answers, late ones get [`ServeError::ShuttingDown`].
//! * **Self-healing workers**: a panic inside a batch (engine bug, or a
//!   fault injected via [`FaultPlan`]) is caught; every job in that batch
//!   is answered with [`ServeError::Internal`] — a waiter is never
//!   stranded — and the worker rebuilds its recycled state and keeps
//!   serving. Respawns and internally-errored requests are counted in
//!   [`ServeStats`].
//!
//! Bit-identity is the design invariant, not an accident: the eval-only
//! forward replays the training forward's exact op order, padding slots
//! never contribute to real outputs, and cached f32 views equal the
//! per-call downcasts elementwise — so N clients through a server return
//! exactly what N sequential `predict_one` calls would, at either
//! [`Precision`](crate::runtime::Precision). The integration suite
//! (`rust/tests/integration_serving.rs`) asserts this with `to_bits()`.

pub mod loadtest;
pub mod prepared;
pub mod queue;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::config::ServeConfig;
use crate::coordinator::trainer::TrainedModel;
use crate::data::batch::{BatchDims, GraphBatch};
use crate::data::graph::radius_graph;
use crate::data::structures::{AtomicStructure, DatasetId};
use crate::fault::FaultPlan;
use crate::model::kernels::thread_cap;
use crate::runtime::Engine;
use crate::session::Prediction;

use prepared::{PreparedModel, Workspace};
use queue::{CoalescingQueue, Job};

// ---------------------------------------------------------------------------
// errors
// ---------------------------------------------------------------------------

/// Typed refusals of the serving path. Everything a client can see that is
/// not a [`Prediction`].
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// The bounded queue stayed full past the configured enqueue wait.
    Overloaded { capacity: usize },
    /// The structure exceeds the compiled batch budget even alone.
    TooLarge { natoms: usize, nedges: usize, dims: BatchDims },
    /// The server is draining; no new work is admitted.
    ShuttingDown,
    /// The model has no trained head for the request's task.
    NoHead { model: String, task: DatasetId },
    /// The engine failed while executing the batch (formatted cause).
    Engine(String),
    /// A worker panicked while executing the request's batch (payload
    /// message). The request is answered — never stranded — and the worker
    /// respawns; retrying is safe.
    Internal(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded { capacity } => write!(
                f,
                "server overloaded: queue stayed at capacity ({capacity}) past the \
                 enqueue wait"
            ),
            ServeError::TooLarge { natoms, nedges, dims } => write!(
                f,
                "structure ({natoms} atoms / {nedges} edges) exceeds the compiled \
                 batch budget {dims:?}"
            ),
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
            ServeError::NoHead { model, task } => {
                write!(f, "model '{}' has no head for task {}", model, task.name())
            }
            ServeError::Engine(msg) => write!(f, "engine error: {msg}"),
            ServeError::Internal(msg) => {
                write!(f, "internal server error: worker panicked: {msg}")
            }
        }
    }
}

impl std::error::Error for ServeError {}

// ---------------------------------------------------------------------------
// stats
// ---------------------------------------------------------------------------

#[derive(Default)]
struct Counters {
    served: AtomicU64,
    batches: AtomicU64,
    rejected: AtomicU64,
    respawned: AtomicU64,
    internal_errors: AtomicU64,
}

/// Snapshot of a server's lifetime counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeStats {
    /// Requests answered with a [`Prediction`].
    pub served: u64,
    /// Padded-batch forwards executed.
    pub batches: u64,
    /// Requests refused before reaching a worker (overload / too large /
    /// no head / shutting down).
    pub rejected: u64,
    /// Worker respawns after an in-batch panic (0 on a healthy server).
    pub respawned: u64,
    /// Requests answered with [`ServeError::Internal`] because their
    /// batch's worker panicked.
    pub internal_errors: u64,
}

impl ServeStats {
    /// Mean structures per executed batch — the coalescing win.
    pub fn avg_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.served as f64 / self.batches as f64
        }
    }
}

// ---------------------------------------------------------------------------
// server
// ---------------------------------------------------------------------------

struct Shared {
    queue: CoalescingQueue,
    prepared: PreparedModel,
    dims: BatchDims,
    cutoff: f64,
    wait: Duration,
    counters: Counters,
    faults: Arc<FaultPlan>,
}

/// An always-on inference server over one [`TrainedModel`]. Construct via
/// [`Session::server`](crate::session::Session::server); call
/// [`Server::predict`] from any number of client threads (`&self` — share
/// behind an `Arc` or `std::thread::scope`).
pub struct Server {
    shared: Arc<Shared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl Server {
    /// Prepare the model, spawn the worker pool, and start accepting work.
    /// `cfg.workers == 0` sizes the pool by [`thread_cap`]
    /// (`HYDRA_MTP_THREADS`, default 8). Reads `HYDRA_MTP_FAULTS` for an
    /// injected fault plan (no-op when unset).
    pub fn start(
        engine: Arc<Engine>,
        model: TrainedModel,
        cfg: ServeConfig,
    ) -> anyhow::Result<Server> {
        let faults = Arc::new(FaultPlan::from_env()?);
        Server::start_with_faults(engine, model, cfg, faults)
    }

    /// [`Server::start`] with an explicit fault-injection plan — the chaos
    /// harness entry point. Production callers use [`Server::start`], which
    /// takes the plan from the environment (empty ⇒ zero behavior change).
    pub fn start_with_faults(
        engine: Arc<Engine>,
        model: TrainedModel,
        cfg: ServeConfig,
        faults: Arc<FaultPlan>,
    ) -> anyhow::Result<Server> {
        let dims = engine.manifest.config.batch_dims();
        let cutoff = engine.manifest.config.cutoff;
        let prepared = PreparedModel::new(engine, model);
        // Downcast weights and build the typed encoder once, at model
        // load — the per-request path only ever clones `Arc`s.
        prepared.warm()?;
        let shared = Arc::new(Shared {
            queue: CoalescingQueue::new(cfg.queue_capacity),
            prepared,
            dims,
            cutoff,
            wait: Duration::from_millis(cfg.enqueue_wait_ms),
            counters: Counters::default(),
            faults,
        });
        let pool = if cfg.workers == 0 { thread_cap() } else { cfg.workers };
        let mut workers = Vec::with_capacity(pool);
        for i in 0..pool {
            let sh = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("hydra-serve-{i}"))
                .spawn(move || worker_loop(&sh))
                .map_err(|e| anyhow::anyhow!("failed to spawn serve worker {i}: {e}"))?;
            workers.push(handle);
        }
        Ok(Server { shared, workers: Mutex::new(workers) })
    }

    /// Model being served.
    pub fn model_name(&self) -> &str {
        self.shared.prepared.name()
    }

    /// Predict one structure through the head of its source task. Blocks
    /// until a worker answers (requests queued concurrently coalesce into
    /// shared batches); returns a typed [`ServeError`] on refusal.
    pub fn predict(&self, s: &AtomicStructure) -> Result<Prediction, ServeError> {
        let sh = &*self.shared;
        let refused = |c: &Counters, e: ServeError| {
            c.rejected.fetch_add(1, Ordering::Relaxed);
            Err(e)
        };
        if !sh.prepared.has_head(s.dataset) {
            return refused(
                &sh.counters,
                ServeError::NoHead { model: sh.prepared.name().to_string(), task: s.dataset },
            );
        }
        // Featurize on the client thread: graph construction parallelizes
        // across clients instead of serializing on the workers.
        let edges = radius_graph(s, sh.cutoff);
        if !sh.dims.admits(s.natoms(), edges.len()) {
            return refused(
                &sh.counters,
                ServeError::TooLarge { natoms: s.natoms(), nedges: edges.len(), dims: sh.dims },
            );
        }
        let (tx, rx) = mpsc::channel();
        let job = Job { task: s.dataset, species: s.species.clone(), edges, tx };
        if let Err(e) = sh.queue.submit(job, sh.wait) {
            return refused(&sh.counters, e);
        }
        match rx.recv() {
            Ok(result) => result,
            Err(_) => Err(ServeError::Engine(
                "server worker terminated before replying".to_string(),
            )),
        }
    }

    /// Lifetime counters (served / batches / rejected / respawned /
    /// internal errors).
    pub fn stats(&self) -> ServeStats {
        let c = &self.shared.counters;
        ServeStats {
            served: c.served.load(Ordering::Relaxed),
            batches: c.batches.load(Ordering::Relaxed),
            rejected: c.rejected.load(Ordering::Relaxed),
            respawned: c.respawned.load(Ordering::Relaxed),
            internal_errors: c.internal_errors.load(Ordering::Relaxed),
        }
    }

    /// Graceful shutdown: refuse new submissions, drain the queue, join
    /// the workers. Idempotent; also runs on `Drop`.
    pub fn shutdown(&self) {
        self.shared.queue.shutdown();
        // Poison recovery: the list is only ever pushed to at spawn and
        // drained here, so a poisoned guard holds a perfectly usable Vec.
        let mut workers = self.workers.lock().unwrap_or_else(|p| p.into_inner());
        for h in workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One worker: recycled batch + workspace, loop until the queue drains
/// after shutdown. A panic inside a batch — an engine bug, or one injected
/// by the fault plan — is caught: every job in the batch is answered with
/// [`ServeError::Internal`] (a waiter is never stranded), the recycled
/// batch and workspace are rebuilt from scratch, and the loop continues.
fn worker_loop(sh: &Shared) {
    let mut batch = GraphBatch::empty(sh.dims);
    let mut ws = sh.prepared.workspace();
    while let Some(jobs) = sh.queue.next_batch(&sh.dims) {
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_batch(sh, &mut batch, &mut ws, &jobs);
        }));
        if let Err(p) = run {
            let msg = crate::fault::panic_message(p.as_ref());
            for j in &jobs {
                let _ = j.tx.send(Err(ServeError::Internal(msg.clone())));
            }
            sh.counters.internal_errors.fetch_add(jobs.len() as u64, Ordering::Relaxed);
            sh.counters.respawned.fetch_add(1, Ordering::Relaxed);
            // The panic may have interrupted a batch pack or forward
            // mid-update; rebuild both recycled states before continuing.
            batch = GraphBatch::empty(sh.dims);
            ws = sh.prepared.workspace();
        }
    }
}

/// Pack and execute one coalesced batch, answering every job. Runs under
/// `catch_unwind` in [`worker_loop`].
fn run_batch(sh: &Shared, batch: &mut GraphBatch, ws: &mut Workspace, jobs: &[Job]) {
    if sh.faults.serve_panic_next() {
        // lint:allow(panic): deliberate fault injection — the chaos harness's serve-worker kill
        panic!("injected fault: serve worker panics on batch");
    }
    batch.clear();
    for j in jobs {
        // Cannot fail: the queue admits by the same node/edge budget
        // the batch enforces. Guarded anyway — a packing bug must
        // surface as an error to the clients, not a wrong answer.
        if let Err(e) = batch.push_inference(&j.species, &j.edges) {
            let msg = format!("batch pack failed: {e}");
            for j in jobs {
                let _ = j.tx.send(Err(ServeError::Engine(msg.clone())));
            }
            return;
        }
    }
    match sh.prepared.run(jobs[0].task, batch, ws) {
        Ok(()) => {
            sh.counters.batches.fetch_add(1, Ordering::Relaxed);
            sh.counters.served.fetch_add(jobs.len() as u64, Ordering::Relaxed);
            let ev = ws.energy_per_atom();
            let fv = ws.forces();
            let mut node_base = 0usize;
            for (g, j) in jobs.iter().enumerate() {
                let n = j.species.len();
                let epa = ev[g] as f64;
                let mut fs = Vec::with_capacity(n);
                for k in 0..n {
                    let row = (node_base + k) * 3;
                    fs.push([fv[row] as f64, fv[row + 1] as f64, fv[row + 2] as f64]);
                }
                node_base += n;
                let _ = j.tx.send(Ok(Prediction {
                    dataset: j.task,
                    energy: epa * n as f64,
                    energy_per_atom: epa,
                    forces: fs,
                }));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for j in jobs {
                let _ = j.tx.send(Err(ServeError::Engine(msg.clone())));
            }
        }
    }
}
