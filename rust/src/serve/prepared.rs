//! A [`TrainedModel`] made serving-ready: parameters are downcast into
//! typed [`EncoderParams`] / [`BranchParams`] **once** at preparation time
//! (with the per-precision f32 weight views cached via `cache_f32`), so the
//! per-request path never re-marshals a `ParamSet` or re-downcasts a weight
//! matrix. Head materializations are held in a small bounded LRU cache —
//! the fix for the old `Predictor::full_cache`, which grew without bound
//! across tasks.
//!
//! The f64 -> f32 -> f64 round trip of `cache_f32` is exact for values that
//! started life as f32-representable training weights, and more to the
//! point the cached views feed the *same* `kernels::downcast` products the
//! uncached kernels would compute per call — so prepared-path outputs are
//! bit-identical to the per-call path at either [`Precision`]
//! (`cached_w32_kernels_match_uncached_bitwise` in `model/kernels.rs`
//! asserts this at the kernel level, `rust/tests/integration_serving.rs`
//! end to end).

use std::sync::{Arc, Mutex};

use crate::coordinator::trainer::TrainedModel;
use crate::data::batch::GraphBatch;
use crate::data::structures::DatasetId;
use crate::model::egnn::{BranchParams, EgnnDims, EncoderParams, EvalWorkspace};
use crate::model::params::ParamSet;
use crate::runtime::Engine;

/// Default bound on materialized heads kept warm per prepared model. Five
/// built-in tasks plus headroom for registered extras; deliberately small —
/// a head materialization is cheap to rebuild but not to hold in the
/// hundreds.
pub const DEFAULT_HEAD_CAP: usize = 8;

/// One cached head: the typed native branch (fast path) or the assembled
/// full `ParamSet` (pjrt fallback, consumed by `Engine::forward`).
enum HeadEntry {
    Native(Arc<BranchParams>),
    Full(Arc<ParamSet>),
}

/// Tiny LRU keyed by task: `clock` stamps each hit; eviction drops the
/// least-recently-used entry. Deterministic — no hashing, no timestamps.
struct HeadCache {
    cap: usize,
    clock: u64,
    entries: Vec<(DatasetId, u64, HeadEntry)>,
}

impl HeadCache {
    fn touch(&mut self, d: DatasetId) -> Option<&HeadEntry> {
        let i = self.entries.iter().position(|(t, _, _)| *t == d)?;
        self.clock += 1;
        self.entries[i].1 = self.clock;
        Some(&self.entries[i].2)
    }

    fn insert(&mut self, d: DatasetId, entry: HeadEntry) {
        if self.entries.len() >= self.cap {
            // `cap >= 1` makes a full cache non-empty, so the LRU scan
            // always finds a victim; `if let` keeps the worker path
            // panic-free regardless.
            if let Some((i, _)) =
                self.entries.iter().enumerate().min_by_key(|(_, (_, stamp, _))| *stamp)
            {
                self.entries.swap_remove(i);
            }
        }
        self.clock += 1;
        self.entries.push((d, self.clock, entry));
    }
}

/// Per-worker output buffers. Native workers carry a full [`EvalWorkspace`]
/// (recycled activations, eval-only forward); non-native workers carry just
/// the two output copies of an `Engine::forward` call.
pub enum Workspace {
    Native(Box<EvalWorkspace>),
    Assembled { out_e: Vec<f32>, out_f: Vec<f32> },
}

impl Workspace {
    /// Padded energy-per-atom output, `[G]`.
    pub fn energy_per_atom(&self) -> &[f32] {
        match self {
            Workspace::Native(ws) => ws.energy_per_atom(),
            Workspace::Assembled { out_e, .. } => out_e,
        }
    }

    /// Padded forces output, `[N,3]` row-major.
    pub fn forces(&self) -> &[f32] {
        match self {
            Workspace::Native(ws) => ws.forces(),
            Workspace::Assembled { out_f, .. } => out_f,
        }
    }
}

/// A trained model bound to an engine with every per-request preparation
/// cost paid up front. Shared (behind `Arc`) by all server workers; the
/// only lock on the hot path is the head-cache mutex, held just long enough
/// to clone an `Arc`.
pub struct PreparedModel {
    engine: Arc<Engine>,
    model: TrainedModel,
    dims: EgnnDims,
    /// Whether the fast typed path applies (native backend).
    native: bool,
    /// Typed encoder, f32 views cached. Built on first use (or eagerly by
    /// [`PreparedModel::warm`]); stays `None` on non-native backends,
    /// which marshal from the assembled `ParamSet` instead.
    encoder: Mutex<Option<Arc<EncoderParams>>>,
    heads: Mutex<HeadCache>,
}

impl PreparedModel {
    pub fn new(engine: Arc<Engine>, model: TrainedModel) -> PreparedModel {
        Self::with_head_cap(engine, model, DEFAULT_HEAD_CAP)
    }

    /// As [`PreparedModel::new`] with an explicit head-cache bound
    /// (tests exercise eviction with tiny caps).
    pub fn with_head_cap(engine: Arc<Engine>, model: TrainedModel, cap: usize) -> PreparedModel {
        let dims = EgnnDims::from_config_with(&engine.manifest.config, engine.precision());
        let native = engine.is_native();
        PreparedModel {
            engine,
            model,
            dims,
            native,
            encoder: Mutex::new(None),
            heads: Mutex::new(HeadCache { cap: cap.max(1), clock: 0, entries: Vec::new() }),
        }
    }

    /// Pay every startup cost now instead of on the first request: build
    /// the typed encoder and cache its f32 views. No-op on non-native
    /// backends and on repeat calls. `Server::start` calls this so the
    /// downcast happens exactly once, at model load.
    pub fn warm(&self) -> anyhow::Result<()> {
        if self.native {
            self.encoder()?;
        }
        Ok(())
    }

    fn encoder(&self) -> anyhow::Result<Arc<EncoderParams>> {
        // Cache locks recover from poison rather than panic: each cache
        // mutation (an insert or an LRU touch) completes under one guard,
        // so a panicking peer leaves a consistent — merely colder — cache.
        let mut slot = self.encoder.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(enc) = &*slot {
            return Ok(Arc::clone(enc));
        }
        let mut enc = EncoderParams::from_set(&self.dims, &self.model.encoder)?;
        enc.cache_f32();
        let enc = Arc::new(enc);
        *slot = Some(Arc::clone(&enc));
        Ok(enc)
    }

    pub fn name(&self) -> &str {
        &self.model.name
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    pub fn dims(&self) -> &EgnnDims {
        &self.dims
    }

    /// Whether the model has a head that serves `d`.
    pub fn has_head(&self, d: DatasetId) -> bool {
        self.model.try_branch_for(d).is_some()
    }

    /// Heads currently materialized (bounded by the cap; for tests/stats).
    pub fn cached_heads(&self) -> usize {
        self.heads.lock().unwrap_or_else(|p| p.into_inner()).entries.len()
    }

    /// A fresh per-worker workspace matching the engine's backend.
    pub fn workspace(&self) -> Workspace {
        if self.native {
            Workspace::Native(Box::new(EvalWorkspace::new(&self.dims)))
        } else {
            Workspace::Assembled {
                out_e: vec![0.0; self.dims.g],
                out_f: vec![0.0; self.dims.n * 3],
            }
        }
    }

    fn native_head(&self, d: DatasetId) -> anyhow::Result<Arc<BranchParams>> {
        let mut cache = self.heads.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(HeadEntry::Native(br)) = cache.touch(d) {
            return Ok(Arc::clone(br));
        }
        let set = self.model.try_branch_for(d).ok_or_else(|| {
            anyhow::anyhow!(
                "model '{}' has no trained head for task {}",
                self.model.name,
                d.name()
            )
        })?;
        let mut br = BranchParams::from_set(&self.dims, set)?;
        br.cache_f32();
        let br = Arc::new(br);
        cache.insert(d, HeadEntry::Native(Arc::clone(&br)));
        Ok(br)
    }

    fn full_head(&self, d: DatasetId) -> anyhow::Result<Arc<ParamSet>> {
        let mut cache = self.heads.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(HeadEntry::Full(full)) = cache.touch(d) {
            return Ok(Arc::clone(full));
        }
        let full = Arc::new(self.model.full_params(&self.engine, d)?);
        cache.insert(d, HeadEntry::Full(Arc::clone(&full)));
        Ok(full)
    }

    /// Run one padded batch through head `d` into `ws`. Native engines take
    /// the eval-only forward against the cached typed parameters (and count
    /// the execution); others fall back to `Engine::forward` on the cached
    /// assembled set. Outputs land in `ws.energy_per_atom()` / `ws.forces()`
    /// bit-identical to the `Engine::forward` path.
    pub fn run(&self, d: DatasetId, batch: &GraphBatch, ws: &mut Workspace) -> anyhow::Result<()> {
        match ws {
            Workspace::Native(ews) => {
                let enc = self.encoder()?;
                let br = self.native_head(d)?;
                ews.run(&self.dims, &enc, &br, batch)?;
                self.engine.record_execution();
            }
            Workspace::Assembled { out_e, out_f } => {
                let full = self.full_head(d)?;
                let (energy, forces) = self.engine.forward(&full, batch)?;
                out_e.clear();
                out_e.extend_from_slice(energy.as_f32());
                out_f.clear();
                out_f.extend_from_slice(forces.as_f32());
            }
        }
        Ok(())
    }
}
