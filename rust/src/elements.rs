//! Periodic-table data used by the synthetic dataset generators, the
//! ground-truth potential, and the Fig-1 element-frequency heatmap.
//!
//! Values are approximate literature numbers (covalent radii in Angstrom,
//! Pauling electronegativity); they only need to be *physically plausible
//! and element-distinguishing* — the ground-truth potential derives its
//! pair parameters from them, so chemically similar elements get similar
//! labels, which is exactly the structure multi-fidelity learning exploits.

/// Highest atomic number we model (Pu). The paper's aggregated data covers
/// roughly two thirds of the natural elements.
pub const MAX_Z: usize = 94;

pub struct ElementInfo {
    pub symbol: &'static str,
    /// Covalent radius, Angstrom.
    pub radius: f64,
    /// Pauling electronegativity (0 where undefined).
    pub chi: f64,
    /// Period (row) in the periodic table, 1-based.
    pub period: u8,
    /// Group (column) in the periodic table, 1-based (0 for f-block).
    pub group: u8,
}

macro_rules! elems {
    ($(($z:expr, $sym:expr, $r:expr, $chi:expr, $p:expr, $g:expr)),* $(,)?) => {
        &[ $( ElementInfo { symbol: $sym, radius: $r, chi: $chi, period: $p, group: $g } ),* ]
    };
}

/// Indexed by Z-1 (element 0 is a padding species, not listed here).
pub static ELEMENTS: &[ElementInfo] = elems![
    (1, "H", 0.31, 2.20, 1, 1),
    (2, "He", 0.28, 0.00, 1, 18),
    (3, "Li", 1.28, 0.98, 2, 1),
    (4, "Be", 0.96, 1.57, 2, 2),
    (5, "B", 0.84, 2.04, 2, 13),
    (6, "C", 0.76, 2.55, 2, 14),
    (7, "N", 0.71, 3.04, 2, 15),
    (8, "O", 0.66, 3.44, 2, 16),
    (9, "F", 0.57, 3.98, 2, 17),
    (10, "Ne", 0.58, 0.00, 2, 18),
    (11, "Na", 1.66, 0.93, 3, 1),
    (12, "Mg", 1.41, 1.31, 3, 2),
    (13, "Al", 1.21, 1.61, 3, 13),
    (14, "Si", 1.11, 1.90, 3, 14),
    (15, "P", 1.07, 2.19, 3, 15),
    (16, "S", 1.05, 2.58, 3, 16),
    (17, "Cl", 1.02, 3.16, 3, 17),
    (18, "Ar", 1.06, 0.00, 3, 18),
    (19, "K", 2.03, 0.82, 4, 1),
    (20, "Ca", 1.76, 1.00, 4, 2),
    (21, "Sc", 1.70, 1.36, 4, 3),
    (22, "Ti", 1.60, 1.54, 4, 4),
    (23, "V", 1.53, 1.63, 4, 5),
    (24, "Cr", 1.39, 1.66, 4, 6),
    (25, "Mn", 1.39, 1.55, 4, 7),
    (26, "Fe", 1.32, 1.83, 4, 8),
    (27, "Co", 1.26, 1.88, 4, 9),
    (28, "Ni", 1.24, 1.91, 4, 10),
    (29, "Cu", 1.32, 1.90, 4, 11),
    (30, "Zn", 1.22, 1.65, 4, 12),
    (31, "Ga", 1.22, 1.81, 4, 13),
    (32, "Ge", 1.20, 2.01, 4, 14),
    (33, "As", 1.19, 2.18, 4, 15),
    (34, "Se", 1.20, 2.55, 4, 16),
    (35, "Br", 1.20, 2.96, 4, 17),
    (36, "Kr", 1.16, 3.00, 4, 18),
    (37, "Rb", 2.20, 0.82, 5, 1),
    (38, "Sr", 1.95, 0.95, 5, 2),
    (39, "Y", 1.90, 1.22, 5, 3),
    (40, "Zr", 1.75, 1.33, 5, 4),
    (41, "Nb", 1.64, 1.60, 5, 5),
    (42, "Mo", 1.54, 2.16, 5, 6),
    (43, "Tc", 1.47, 1.90, 5, 7),
    (44, "Ru", 1.46, 2.20, 5, 8),
    (45, "Rh", 1.42, 2.28, 5, 9),
    (46, "Pd", 1.39, 2.20, 5, 10),
    (47, "Ag", 1.45, 1.93, 5, 11),
    (48, "Cd", 1.44, 1.69, 5, 12),
    (49, "In", 1.42, 1.78, 5, 13),
    (50, "Sn", 1.39, 1.96, 5, 14),
    (51, "Sb", 1.39, 2.05, 5, 15),
    (52, "Te", 1.38, 2.10, 5, 16),
    (53, "I", 1.39, 2.66, 5, 17),
    (54, "Xe", 1.40, 2.60, 5, 18),
    (55, "Cs", 2.44, 0.79, 6, 1),
    (56, "Ba", 2.15, 0.89, 6, 2),
    (57, "La", 2.07, 1.10, 6, 0),
    (58, "Ce", 2.04, 1.12, 6, 0),
    (59, "Pr", 2.03, 1.13, 6, 0),
    (60, "Nd", 2.01, 1.14, 6, 0),
    (61, "Pm", 1.99, 1.13, 6, 0),
    (62, "Sm", 1.98, 1.17, 6, 0),
    (63, "Eu", 1.98, 1.20, 6, 0),
    (64, "Gd", 1.96, 1.20, 6, 0),
    (65, "Tb", 1.94, 1.22, 6, 0),
    (66, "Dy", 1.92, 1.23, 6, 0),
    (67, "Ho", 1.92, 1.24, 6, 0),
    (68, "Er", 1.89, 1.24, 6, 0),
    (69, "Tm", 1.90, 1.25, 6, 0),
    (70, "Yb", 1.87, 1.10, 6, 0),
    (71, "Lu", 1.87, 1.27, 6, 3),
    (72, "Hf", 1.75, 1.30, 6, 4),
    (73, "Ta", 1.70, 1.50, 6, 5),
    (74, "W", 1.62, 2.36, 6, 6),
    (75, "Re", 1.51, 1.90, 6, 7),
    (76, "Os", 1.44, 2.20, 6, 8),
    (77, "Ir", 1.41, 2.20, 6, 9),
    (78, "Pt", 1.36, 2.28, 6, 10),
    (79, "Au", 1.36, 2.54, 6, 11),
    (80, "Hg", 1.32, 2.00, 6, 12),
    (81, "Tl", 1.45, 1.62, 6, 13),
    (82, "Pb", 1.46, 2.33, 6, 14),
    (83, "Bi", 1.48, 2.02, 6, 15),
    (84, "Po", 1.40, 2.00, 6, 16),
    (85, "At", 1.50, 2.20, 6, 17),
    (86, "Rn", 1.50, 0.00, 6, 18),
    (87, "Fr", 2.60, 0.70, 7, 1),
    (88, "Ra", 2.21, 0.90, 7, 2),
    (89, "Ac", 2.15, 1.10, 7, 0),
    (90, "Th", 2.06, 1.30, 7, 0),
    (91, "Pa", 2.00, 1.50, 7, 0),
    (92, "U", 1.96, 1.38, 7, 0),
    (93, "Np", 1.90, 1.36, 7, 0),
    (94, "Pu", 1.87, 1.28, 7, 0),
];

/// Info for atomic number `z` (1-based). Panics on 0 / out of range.
pub fn element(z: usize) -> &'static ElementInfo {
    assert!((1..=MAX_Z).contains(&z), "bad atomic number {z}");
    &ELEMENTS[z - 1]
}

pub fn symbol(z: usize) -> &'static str {
    element(z).symbol
}

/// Atomic number for a symbol, if known.
pub fn z_of(symbol: &str) -> Option<usize> {
    ELEMENTS.iter().position(|e| e.symbol == symbol).map(|i| i + 1)
}

// -- element palettes of the five source datasets (paper Section 4.1) -------

/// ANI1x: organic molecules over C, H, N, O.
pub fn ani1x_palette() -> Vec<usize> {
    ["H", "C", "N", "O"].iter().map(|s| z_of(s).unwrap()).collect()
}

/// QM7-X: small organics with up to 7 heavy atoms over C, N, O, S, Cl (+H).
pub fn qm7x_palette() -> Vec<usize> {
    ["H", "C", "N", "O", "S", "Cl"].iter().map(|s| z_of(s).unwrap()).collect()
}

/// Transition1x: reaction pathways over C,H,N,O,F,S,Cl,P,Br,I,Li,Na,K.
pub fn transition1x_palette() -> Vec<usize> {
    ["H", "C", "N", "O", "F", "S", "Cl", "P", "Br", "I", "Li", "Na", "K"]
        .iter()
        .map(|s| z_of(s).unwrap())
        .collect()
}

/// MPTrj: inorganic crystals covering 60+ elements (we take Z=1..=83 minus
/// noble gases, a reasonable proxy for the Materials Project coverage).
pub fn mptrj_palette() -> Vec<usize> {
    (1..=83).filter(|&z| ![2, 10, 18, 36, 54].contains(&z)).collect()
}

/// Alexandria: inorganic, slightly broader than MPTrj (up to Pu).
pub fn alexandria_palette() -> Vec<usize> {
    (1..=MAX_Z).filter(|&z| ![2, 10, 18, 36, 54, 86].contains(&z)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_is_complete_and_ordered() {
        assert_eq!(ELEMENTS.len(), MAX_Z);
        assert_eq!(symbol(1), "H");
        assert_eq!(symbol(6), "C");
        assert_eq!(symbol(26), "Fe");
        assert_eq!(symbol(94), "Pu");
    }

    #[test]
    fn z_of_roundtrips() {
        for z in 1..=MAX_Z {
            assert_eq!(z_of(symbol(z)), Some(z), "z={z}");
        }
        assert_eq!(z_of("Xx"), None);
    }

    #[test]
    fn radii_and_chi_plausible() {
        for z in 1..=MAX_Z {
            let e = element(z);
            assert!(e.radius > 0.2 && e.radius < 3.0, "radius of {}", e.symbol);
            assert!(e.chi >= 0.0 && e.chi < 4.5, "chi of {}", e.symbol);
            assert!((1..=7).contains(&e.period));
        }
    }

    #[test]
    fn palettes_match_paper() {
        assert_eq!(ani1x_palette().len(), 4);
        assert_eq!(qm7x_palette().len(), 6);
        assert_eq!(transition1x_palette().len(), 13);
        assert!(mptrj_palette().len() >= 60);
        assert!(alexandria_palette().len() > mptrj_palette().len());
        // Organic palettes are strict subsets of the inorganic coverage.
        let alex = alexandria_palette();
        for z in ani1x_palette() {
            assert!(alex.contains(&z));
        }
    }

    #[test]
    #[should_panic(expected = "bad atomic number")]
    fn rejects_padding_species() {
        element(0);
    }
}
